"""Serving demo: (1) real-model continuous decode with a paged cache,
(2) CIAO vs baselines on the serving cost model under pool pressure.

    PYTHONPATH=src python examples/serve_ciao.py
"""
import jax
import jax.numpy as jnp

from repro.configs import reduced_config
from repro.configs.base import RunConfig
from repro.models import model as M
from repro.parallel.sharding import local_env
from repro.serving import PoolConfig, ServeConfig, ServeEngine, synth_requests


def real_model_decode():
    print("== real-model batched decode (tiny gemma2-family) ==")
    cfg = reduced_config("gemma2-2b")
    run = RunConfig(remat_policy="none", param_dtype="float32")
    env = local_env()
    params = M.init_params(cfg, jax.random.PRNGKey(0), run)
    B = 4
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, 10), 0,
                                 cfg.vocab_size)
    logits, cache, pos = M.prefill(env, cfg, params, {"tokens": prompts},
                                   run, max_len=32)
    tok = jnp.argmax(logits, -1)[:, None]
    outs = [[] for _ in range(B)]
    for i in range(10):
        for b in range(B):
            outs[b].append(int(tok[b, 0]))
        logits, cache = M.decode_step(env, cfg, params, tok, pos + 1 + i,
                                      cache, run)
        tok = jnp.argmax(logits, -1)[:, None]
    for b in range(B):
        print(f"  seq{b}: {outs[b]}")


def ciao_policy_comparison():
    print("\n== CIAO vs baselines under KV-pool pressure ==")
    reqs = synth_requests(256, groups=10, prefix_pages=24,
                          decode_tokens=128, heavy_frac=0.25,
                          heavy_decode=1000)
    print(f"{'policy':10s} {'tok/unit':>9s} {'preempt':>8s} "
          f"{'refetch':>8s} {'goodput':>8s}")
    for pol in ("gto", "ccws", "statpcal", "ciao-p", "ciao-t", "ciao-c"):
        cfg = ServeConfig(policy=pol, groups=10,
                          pool=PoolConfig(main_pages=640,
                                          reserve_pages=192))
        st = ServeEngine(cfg).run(list(reqs))
        print(f"{pol:10s} {st.tokens_per_unit:9.3f} {st.preemptions:8d} "
              f"{st.refetched_pages:8d} {st.goodput:8.1f}")


if __name__ == "__main__":
    real_model_decode()
    ciao_policy_comparison()
