"""Paper-faithful demo: the SM simulator running all seven schedulers on
one benchmark per class (LWS / SWS / CI) — the Fig. 8 experiment in
miniature — followed by the same sweep on traces derived from the repo's
real Pallas kernels, and a 2-SM chip run where the SMs contend on the
shared L2/DRAM stage.

    PYTHONPATH=src python examples/ciao_sim_demo.py
"""
import tempfile

from repro.core import load_workload, make_workload, save_workload
from repro.core.gpu import GPUConfig, run_gpu_policy_sweep
from repro.core.simulator import run_policy_sweep

POLICIES = ("gto", "ccws", "best-swl", "statpcal", "ciao-p", "ciao-t",
            "ciao-c")


def _print_sweep(name: str, klass_label: str, res) -> None:
    gto = res["gto"].ipc
    print(f"\n{name} [{klass_label}]  (IPC normalized to GTO, 1 SM)")
    print(f"{'policy':10s} {'ipc':>6s} {'hit%':>6s} {'active':>7s} "
          f"{'vta_hits':>9s}")
    for p in POLICIES:
        r = res[p]
        print(f"{p:10s} {r.ipc / gto:6.2f} "
              f"{100 * r.l1_hit_rate:6.1f} "
              f"{r.mean_active_warps:7.1f} {r.vta_hits:9d}")


def single_sm():
    for name in ("kmn", "syrk", "backprop"):
        wl = make_workload(name, scale=0.5)
        _print_sweep(name, wl.klass, run_policy_sweep(wl, POLICIES))


def derived_kernels():
    """Kernel-derived traces (repro.workloads.derived): the flash-attn
    tiled Q/K/V walk and the gather kernel's index stream, scheduled by
    the same policies — plus the on-disk npz round trip."""
    for name in ("flashattn", "gather"):
        wl = make_workload(name, scale=0.5)
        with tempfile.TemporaryDirectory() as td:
            wl = load_workload(save_workload(wl, f"{td}/{name}"))
        _print_sweep(name, f"{wl.klass}, kernel-derived",
                     run_policy_sweep(wl, POLICIES))


def multi_sm(num_sms: int = 2):
    """Same sweep on a multi-SM chip: every SM runs a full copy of the
    workload; the shared L2 capacity and DRAM bandwidth now carry
    cross-SM interference."""
    gpu = GPUConfig(num_sms=num_sms)
    for name in ("kmn", "syrk"):
        wl = make_workload(name, scale=0.25)
        res = run_gpu_policy_sweep(wl, ("gto", "ciao-p", "ciao-c"), gpu=gpu)
        gto = res["gto"].ipc
        print(f"\n{name} [{wl.klass}]  (chip IPC normalized to GTO, "
              f"{num_sms} SMs)")
        print(f"{'policy':10s} {'ipc':>6s} {'per-SM ipc':>24s}")
        for p, r in res.items():
            per_sm = " ".join(f"{s.ipc:.3f}" for s in r.per_sm)
            print(f"{p:10s} {r.ipc / gto:6.2f} {per_sm:>24s}")


def main():
    single_sm()
    derived_kernels()
    multi_sm()


if __name__ == "__main__":
    main()
