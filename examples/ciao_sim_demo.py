"""Paper-faithful demo: the SM simulator running all seven schedulers on
one benchmark per class (LWS / SWS / CI) — the Fig. 8 experiment in
miniature.

    PYTHONPATH=src python examples/ciao_sim_demo.py
"""
from repro.core import make_workload
from repro.core.simulator import run_policy_sweep

POLICIES = ("gto", "ccws", "best-swl", "statpcal", "ciao-p", "ciao-t",
            "ciao-c")


def main():
    for name in ("kmn", "syrk", "backprop"):
        wl = make_workload(name, scale=0.5)
        res = run_policy_sweep(wl, POLICIES)
        gto = res["gto"].ipc
        print(f"\n{name} [{wl.klass}]  (IPC normalized to GTO)")
        print(f"{'policy':10s} {'ipc':>6s} {'hit%':>6s} {'active':>7s} "
              f"{'vta_hits':>9s}")
        for p in POLICIES:
            r = res[p]
            print(f"{p:10s} {r.ipc / gto:6.2f} "
                  f"{100 * r.l1_hit_rate:6.1f} "
                  f"{r.mean_active_warps:7.1f} {r.vta_hits:9d}")


if __name__ == "__main__":
    main()
