"""Quickstart: build a tiny LM, take train steps, generate greedily.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import reduced_config
from repro.configs.base import RunConfig, ShapeConfig
from repro.models import model as M
from repro.parallel.sharding import local_env
from repro.train import train_step as TS
from repro.train.data import SyntheticLM


def main():
    cfg = reduced_config("gemma2-2b")        # tiny same-family variant
    run = RunConfig(remat_policy="none", learning_rate=1e-3,
                    param_dtype="float32")
    env = local_env()
    shape = ShapeConfig(name="quick", seq_len=64, global_batch=4,
                        mode="train")

    print(f"arch={cfg.name}  params={cfg.param_count()/1e6:.2f}M  "
          f"pattern={cfg.pattern}")

    state = TS.init_train_state(cfg, run, jax.random.PRNGKey(0))
    step = jax.jit(TS.make_train_step(cfg, run, env), donate_argnums=(0,))
    data = SyntheticLM(cfg).batches(shape, env)
    for i in range(10):
        state, metrics = step(state, next(data))
        print(f"step {i}: loss={float(metrics['loss']):.4f} "
              f"gnorm={float(metrics['grad_norm']):.3f}")

    # greedy generation off the trained weights
    prompt = jax.random.randint(jax.random.PRNGKey(7), (1, 8), 0,
                                cfg.vocab_size)
    logits, cache, pos = M.prefill(env, cfg, state["params"],
                                   {"tokens": prompt}, run, max_len=32)
    toks = []
    tok = jnp.argmax(logits, -1)[:, None]
    for i in range(12):
        toks.append(int(tok[0, 0]))
        logits, cache = M.decode_step(env, cfg, state["params"], tok,
                                      pos + 1 + i, cache, run)
        tok = jnp.argmax(logits, -1)[:, None]
    print("generated:", toks)


if __name__ == "__main__":
    main()
