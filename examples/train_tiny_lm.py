"""End-to-end training driver: checkpointed, fault-tolerant, straggler-
monitored training of a small LM on the synthetic pipeline.

Default (CPU-friendly): a ~7M-param gemma2-family model, 200 steps.
``--m100`` switches to a ~100M-param config — the full driver is identical;
on this CPU container that config is only *lowered and compiled* (pass
``--steps N`` to actually train it if you have the cycles).

    PYTHONPATH=src python examples/train_tiny_lm.py --steps 200
"""
import argparse
import dataclasses

import jax

from repro.configs import reduced_config
from repro.configs.base import RunConfig, ShapeConfig
from repro.parallel.sharding import local_env
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--m100", action="store_true",
                    help="~100M-param config (compile proof on CPU)")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = reduced_config("gemma2-2b")
    if args.m100:
        cfg = dataclasses.replace(
            cfg, name="gemma2-100m", d_model=512, num_layers=8,
            num_heads=8, num_kv_heads=4, head_dim=64, d_ff=2048,
            vocab_size=32768, local_window=1024)
        print(f"100M config: {cfg.param_count()/1e6:.1f}M params")

    run = RunConfig(remat_policy="none", learning_rate=3e-3,
                    warmup_steps=20, param_dtype="float32")
    env = local_env()
    shape = ShapeConfig(name="train", seq_len=args.seq,
                        global_batch=args.batch, mode="train")
    tcfg = TrainerConfig(total_steps=args.steps, checkpoint_every=50,
                         checkpoint_dir=args.ckpt, log_every=10)
    trainer = Trainer(cfg, run, env, shape, tcfg)
    out = trainer.run_loop()
    losses = out["losses"]
    print(f"\ntrained {len(losses)} steps: "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    print(f"straggler events: {out['straggler_events']}")
    for m in trainer.metrics_log[-3:]:
        print(m)


if __name__ == "__main__":
    main()
