"""Vectorized epoch-path kernels (repro.core.epoch): a batch-of-k kernel
call over stacked planes must equal k independent batch-of-1 object
calls, for every policy family, on random counter states.

The scalar objects (InterferenceDetector, the policy classes) *are*
batch-of-1 views onto the same kernels, so this property pins exactly
what the batched engine adds on top: the batch indexing. Two identical
sets of cells are built from one seed; set A ticks through the objects
cell by cell, set B is adopted into full-batch planes (the engine's
``adopt_*`` path) and ticked by one kernel call, mirroring
``BatchedSMEngine._epoch_batch``."""
from fractions import Fraction

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import epoch as _epoch
from repro.core.interference import DetectorConfig, InterferenceDetector
from repro.core.policies import (CCWSPolicy, CIAOPolicy, StatPCALPolicy,
                                 make_policy)

N = 12          # warps per cell
K = 5           # cells per batch


def _det_cfg():
    return DetectorConfig(num_warps=N, vta_sets=N, list_entries=16,
                          high_epoch=1000, low_epoch=50)


def _rand_cell(rng, policy_name):
    """One (detector, policy) pair with randomized epoch-relevant state,
    reproducible from the rng stream."""
    det = InterferenceDetector(_det_cfg())
    pol = make_policy(policy_name, N, det)
    det.on_instruction(int(rng.integers(0, 4000)))
    det.irs_hits[:] = rng.integers(0, 60, N)
    det.vta.hits[:] = rng.integers(0, 60, N)
    det.interfering_wid[:] = rng.integers(-1, N, det.cfg.list_entries)
    det.sat_counter[:] = rng.integers(0, det.cfg.sat_max + 1,
                                      det.cfg.list_entries)
    # misalign the epoch ordinals so poll crossings vary per cell
    det._pl.low_idx[0] = rng.integers(0, 3)
    det._pl.high_idx[0] = rng.integers(0, 2)
    det._pl.high_crossings[0] = rng.integers(0, 20)
    if isinstance(pol, CCWSPolicy):
        pol.score[:] = rng.integers(pol.base, 4000, N)
    if isinstance(pol, StatPCALPolicy):
        if rng.integers(0, 2):
            # flip into bypass mode through the real epoch path
            pol.epoch_tick(None, [False] * N, 0.0)
    if isinstance(pol, CIAOPolicy):
        # push a few legitimate stack entries (stall via the public
        # API; isolation white-box, as high_epoch_tick would)
        for w in rng.choice(N, size=int(rng.integers(0, 3)),
                            replace=False):
            trig = int(rng.integers(0, N))
            if pol.mode != "p" and rng.integers(0, 2):
                pol.stall_directly(int(w), trig)
            elif not pol.isolated_mask[w]:
                pol.isolated_mask[w] = True
                det.record_isolation(int(w), trig)
                pol._iso[int(pol._iso_len[0])] = int(w)
                pol._iso_len[0] += 1
    return det, pol


def _batch_tick(dets, pols, done, util):
    """Mirror of BatchedSMEngine._epoch_batch over freshly adopted
    planes (the engine's exact call sequence, minus the stepper)."""
    k = len(dets)
    cfg = dets[0].cfg
    pl = _epoch.DetPlanes.alloc(k, cfg)
    allowed = np.ones((k, N), bool)
    isolated = np.zeros((k, N), bool)
    bypass = np.zeros((k, N), bool)
    score = np.zeros((k, N), np.int64)
    base = np.zeros(k, np.int64)
    budget = np.zeros(k, np.int64)
    sp_byp = np.zeros(k, bool)
    sp_thr = np.zeros(k, np.float64)
    sp_base = np.zeros((k, N), bool)
    stall = np.full((k, N), -1, np.int64)
    iso = np.full((k, N), -1, np.int64)
    stall_len = np.zeros(k, np.int64)
    iso_len = np.zeros(k, np.int64)
    for b, (det, pol) in enumerate(zip(dets, pols)):
        det.adopt_row(pl, b)
        pol.adopt_mask_rows(allowed[b], isolated[b], bypass[b])
        if isinstance(pol, CCWSPolicy):
            pol.adopt_score_row(score[b])
            base[b], budget[b] = pol.base, pol.budget
        if isinstance(pol, StatPCALPolicy):
            pol.adopt_statpcal_rows(sp_byp[b:b + 1], sp_thr[b:b + 1],
                                    sp_base[b])
        if isinstance(pol, CIAOPolicy):
            pol.adopt_ciao_rows(stall[b], stall_len[b:b + 1],
                                iso[b], iso_len[b:b + 1])
    idx = np.arange(k, dtype=np.int64)
    pol0 = pols[0]
    if isinstance(pol0, CCWSPolicy):
        _epoch.ccws_tick(score, base, budget, ~done, allowed, idx)
    elif isinstance(pol0, StatPCALPolicy):
        _epoch.statpcal_tick(sp_byp, util, sp_thr, sp_base, allowed,
                             bypass, idx)
    elif isinstance(pol0, CIAOPolicy):
        n_act = np.count_nonzero(allowed & ~done, axis=1)
        low, high = _epoch.poll_epochs(pl, idx, n_act)
        lo = idx[low]
        if lo.size:
            _epoch.ciao_low_tick(pl, stall, stall_len, iso, iso_len,
                                 allowed, isolated, done, n_act[low], lo)
        hi = idx[high]
        if hi.size:
            _epoch.ciao_high_tick(
                pl, stall, stall_len, iso, iso_len, allowed,
                isolated, done, allowed[hi] & ~done[hi],
                np.full(len(hi), pol0.mode in ("p", "c")),
                np.full(len(hi), pol0.mode in ("t", "c")), hi)
    return pl


FAMILY = st.sampled_from(["ccws", "statpcal", "ciao-p", "ciao-t",
                          "ciao-c"])


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10**9), FAMILY)
def test_batched_kernels_equal_per_cell_objects(seed, family):
    mk = lambda: [_rand_cell(np.random.default_rng(seed + i), family)
                  for i in range(K)]  # noqa: E731
    cells_a, cells_b = mk(), mk()
    rng = np.random.default_rng(seed ^ 0xC1A0)
    done = rng.integers(0, 2, (K, N)).astype(bool)
    done[:, 0] = False                  # keep at least one warp alive
    util = rng.random(K)

    # A: the per-cell object path (batch-of-1 views)
    for (det, pol), d, u in zip(cells_a, done, util):
        pol.epoch_tick(None, d, float(u))
    # B: one batched kernel pass over stacked planes
    pl_b = _batch_tick([d for d, _ in cells_b],
                       [p for _, p in cells_b], done, util)

    for b, ((det_a, pol_a), (det_b, pol_b)) in enumerate(
            zip(cells_a, cells_b)):
        tag = f"cell {b} ({family})"
        np.testing.assert_array_equal(
            pol_a.allowed_mask, pol_b.allowed_mask, tag)
        np.testing.assert_array_equal(
            pol_a.isolated_mask, pol_b.isolated_mask, tag)
        np.testing.assert_array_equal(
            pol_a.bypass_mask, pol_b.bypass_mask, tag)
        if isinstance(pol_a, CCWSPolicy):
            np.testing.assert_array_equal(pol_a.score, pol_b.score, tag)
        if isinstance(pol_a, StatPCALPolicy):
            assert pol_a.bypass_active == pol_b.bypass_active, tag
        if isinstance(pol_a, CIAOPolicy):
            assert pol_a.stall_stack == pol_b.stall_stack, tag
            assert pol_a.isolate_stack == pol_b.isolate_stack, tag
        # detector epoch state: the full planes row must agree, floats
        # bit-for-bit (same IEEE ops scalar vs vectorized)
        for f in _epoch.DetPlanes._ROW_FIELDS:
            np.testing.assert_array_equal(
                getattr(det_a._pl, f)[0], getattr(pl_b, f)[b],
                f"{tag}: detector plane {f}")


@settings(max_examples=80, deadline=None)
@given(st.integers(0, 10**9), st.integers(0, 1 << 11))
def test_cutoff_decisions_match_exact_rationals(seed, knum):
    """The fixed-point scaling contract behind the shared cutoff
    decisions: ``irs_cum_leq`` / ``snap_over`` evaluate the IRS compare
    as the single-rounding product compare ``hits*act <> cutoff*X``. For
    dyadic cutoffs (k/1024 — denominator a power of two) and counters in
    the simulator's range both products are exactly representable in
    f64, so the decision must equal arbitrary-precision rational
    arithmetic bit-for-bit. This is what lets the numpy, C, and XLA
    steppers share one decision kernel without drift. (The shipped
    defaults 0.01/0.005 are non-dyadic: there the compare is still
    single-rounding — one IEEE rounding total — and all three backends
    evaluate the identical expression, which the golden and mixed-batch
    equality tests pin.)"""
    rng = np.random.default_rng(seed)
    cutoff = knum / 1024.0
    exact = Fraction(knum, 1024)
    assert Fraction(cutoff) == exact        # dyadic: exactly a f64
    pl = _epoch.DetPlanes.alloc(K, _det_cfg())
    pl.irs_inst[:] = rng.integers(0, 1 << 20, K)
    pl.irs_inst[rng.integers(0, K)] = 0     # exercise the 0-IRS guard
    pl.irs_hits[:] = rng.integers(0, 1 << 16, (K, N))
    idx = np.arange(K, dtype=np.int64)
    wid = rng.integers(0, N, K)
    act = rng.integers(0, N + 1, K)

    got = _epoch.irs_cum_leq(pl, idx, wid, act, cutoff)
    for b in range(K):
        inst, a = int(pl.irs_inst[b]), int(act[b])
        h = int(pl.irs_hits[b, wid[b] % N])
        want = (inst <= 0 or a <= 0) or Fraction(h * a) <= exact * inst
        assert bool(got[b]) == want, f"irs_cum_leq cell {b}"

    hits = rng.integers(0, 1 << 16, (K, N)).astype(np.int64)
    win = rng.integers(0, 1 << 20, K).astype(np.int64)
    got2 = _epoch.snap_over(hits, win[:, None], act[:, None], cutoff)
    for b in range(K):
        for w in range(N):
            want = Fraction(int(hits[b, w]) * int(act[b])) \
                > exact * int(win[b])
            assert bool(got2[b, w]) == want, f"snap_over {b},{w}"


@pytest.mark.parametrize("family", ["ccws", "ciao-c"])
def test_repeated_ticks_stay_equal(family):
    """Several consecutive epochs (state feeding back into itself)."""
    seed = 1234
    mk = lambda: [_rand_cell(np.random.default_rng(seed + i), family)
                  for i in range(K)]  # noqa: E731
    cells_a, cells_b = mk(), mk()
    done = np.zeros((K, N), bool)
    dets_b = [d for d, _ in cells_b]
    pols_b = [p for _, p in cells_b]
    for step in range(4):
        for (det, pol) in cells_a:
            det.on_instruction(60)
            pol.epoch_tick(None, done[0], 0.0)
        for det in dets_b:
            det.on_instruction(60)
        _batch_tick(dets_b, pols_b, done, np.zeros(K))
        for (det_a, pol_a), pol_b in zip(cells_a, pols_b):
            np.testing.assert_array_equal(
                pol_a.allowed_mask, pol_b.allowed_mask, f"step {step}")
