"""Sharding resolution + multi-device pjit smoke (subprocess with forced
host devices — the main test process stays single-device)."""
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import (DECODE_RULES, DEFAULT_RULES,
                                     LONG_DECODE_RULES, ShardEnv, make_env)
from repro.launch.mesh import make_test_mesh


def _env2d():
    # 1-device mesh but with both axes named, to exercise resolution
    import numpy as np
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    return make_env(mesh, "train")


def test_rules_filter_missing_axes():
    env = _env2d()
    # bare axis name, not a 1-tuple: older jax PartitionSpec __eq__
    # doesn't normalize ('data',) == 'data'
    assert env.pspec("act_batch", None, "act_mlp") == P("data", None,
                                                        "model")


def test_divisibility_fit():
    env = _env2d()
    # dims indivisible by the axis size resolve to replicated
    import numpy as np
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                             ("data", "model"))
    env = make_env(mesh, "train")
    sp = env.pspec("p_embed", "p_heads", shape=(2304, 4))
    # model axis size 1 divides everything on this mesh; simulate 16 by API:
    assert sp == P("data", "model")


def test_decode_rules_shard_kv_seq():
    assert DECODE_RULES["act_kv_seq"] == "model"
    assert DECODE_RULES["act_heads"] is None
    assert LONG_DECODE_RULES["act_kv_seq"] == ("pod", "data", "model")
    assert LONG_DECODE_RULES["act_batch"] is None


def test_arch_overrides_merge():
    env = _env2d().with_rules({"act_seq": "model"})
    assert env.rules["act_seq"] == "model"
    assert env.rules["act_batch"] == ("pod", "data")


SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import reduced_config
    from repro.configs.base import RunConfig, ShapeConfig
    from repro.parallel.sharding import make_env, tree_shardings
    from repro.train import train_step as TS
    from repro.models import model as M

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = reduced_config("gemma2-2b")
    run = RunConfig(remat_policy="none", param_dtype="float32",
                    gradient_compression="{comp}")
    env = make_env(mesh, "train")
    step = TS.make_train_step(cfg, run, env)
    state = TS.init_train_state(cfg, run, jax.random.PRNGKey(0), npod=2)
    specs = TS.state_logical_specs(cfg, run)
    sh = tree_shardings(env, specs, state)
    state = jax.device_put(state, sh)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 33), 0,
                              cfg.vocab_size)
    batch = {{"tokens": toks[:, :-1], "targets": toks[:, 1:]}}
    bsh = tree_shardings(env, TS.batch_logical_specs(cfg, "train"), batch)
    batch = jax.device_put(batch, bsh)
    fn = jax.jit(step, in_shardings=(sh, bsh), donate_argnums=(0,))
    state2, metrics = fn(state, batch)
    loss1 = float(metrics["loss"])
    assert np.isfinite(loss1), loss1
    print("OK", loss1)
""")


@pytest.mark.parametrize("comp", ["", "int8"])
def test_multidevice_train_step(comp):
    """8 fake CPU devices, (pod=2, data=2, model=2) mesh: the full sharded
    train step runs (with and without cross-pod int8 compression)."""
    r = subprocess.run([sys.executable, "-c",
                        SUBPROCESS_SCRIPT.format(comp=comp)],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"}, cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_compression_roundtrip_quality():
    import jax.numpy as jnp
    from repro.parallel.compression import dequantize_int8, quantize_int8
    x = jax.random.normal(jax.random.PRNGKey(0), (128,)) * 3.0
    q, s = quantize_int8(x)
    err = jnp.max(jnp.abs(dequantize_int8(q, s) - x))
    assert float(err) <= float(s) * 0.51 + 1e-6   # half-ULP of the scale
