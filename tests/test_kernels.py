"""Pallas kernels vs pure-jnp oracles (interpret=True), sweeping shapes and
dtypes as required for each kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn.ops import flash_attention
from repro.kernels.flash_attn.ref import attention_ref
from repro.kernels.decode_attn.ops import decode_attention
from repro.kernels.decode_attn.ref import decode_ref
from repro.kernels.ciao_gather.ops import ciao_gather
from repro.kernels.ciao_gather.ref import cache_sim_ref, gather_ref


def _fold(q, k, v):
    b, sq, hq, d = q.shape
    g = hq // k.shape[2]
    kb = jnp.repeat(k, g, 2).transpose(0, 2, 1, 3).reshape(b * hq, -1, d)
    vb = jnp.repeat(v, g, 2).transpose(0, 2, 1, 3).reshape(b * hq, -1, d)
    qb = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, d)
    return qb, kb, vb


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5),
                                        (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("b,s,hq,hkv,d,causal,window,cap", [
    (1, 128, 2, 2, 64, True, 0, 0.0),
    (2, 256, 4, 2, 64, True, 0, 0.0),       # GQA
    (1, 128, 8, 1, 32, True, 64, 50.0),     # MQA + local + softcap
    (2, 192, 4, 4, 128, True, 0, 0.0),      # pad (192 % 128 != 0)
    (1, 128, 2, 2, 64, False, 0, 0.0),      # bidirectional
])
def test_flash_attention_vs_oracle(b, s, hq, hkv, d, causal, window, cap,
                                   dtype, atol):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=cap, interpret=True)
    qb, kb, vb = _fold(q, k, v)
    ref = attention_ref(qb.astype(jnp.float32), kb.astype(jnp.float32),
                        vb.astype(jnp.float32), causal=causal,
                        window=window, softcap=cap)
    ref = ref.reshape(b, hq, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(out.astype(jnp.float32), ref, atol=atol)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5),
                                        (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("b,s,hq,hkv,d", [
    (2, 256, 4, 2, 64),
    (3, 512, 4, 4, 128),
    (1, 300, 8, 2, 32),                     # pad
])
def test_decode_attention_vs_oracle(b, s, hq, hkv, d, dtype, atol):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (b, 1, hq, d), dtype)
    ck = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    cv = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    lens = jax.random.randint(ks[3], (b,), 1, s + 1)
    out = decode_attention(q, ck, cv, lens, interpret=True)
    qb, kb, vb = _fold(q, ck, cv)
    ref = decode_ref(qb.astype(jnp.float32), kb.astype(jnp.float32),
                     vb.astype(jnp.float32), jnp.repeat(lens, hq))
    ref = ref.reshape(b, hq, 1, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(out.astype(jnp.float32), ref, atol=atol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,d,t,c_main,c_iso", [
    (500, 128, 384, 64, 16),
    (1000, 256, 640, 128, 32),
    (64, 128, 130, 16, 8),                  # pad + tiny cache
])
def test_ciao_gather_vs_oracle(n, d, t, c_main, c_iso, dtype):
    rng = np.random.default_rng(0)
    table = jax.random.normal(jax.random.PRNGKey(2), (n, d), dtype)
    streams = rng.integers(0, 4, t).astype(np.int32)
    idx = np.where(streams == 3, rng.integers(0, 8, t),
                   rng.integers(0, n, t)).astype(np.int32)
    iso = np.array([0, 0, 0, 1], np.int32)
    out, stats = ciao_gather(table, jnp.array(idx), jnp.array(streams),
                             jnp.array(iso), c_main=c_main, c_iso=c_iso,
                             interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(gather_ref(table,
                                                        jnp.array(idx))))
    ref_stats = cache_sim_ref(idx, streams, iso, c_main=c_main,
                              c_iso=c_iso, num_streams=4)
    np.testing.assert_array_equal(np.asarray(stats), ref_stats)


def test_ciao_gather_isolation_protects_main():
    """The CIAO property at kernel level: isolating a hammering stream
    lifts the other streams' hit rates (its hot set stops evicting theirs)."""
    rng = np.random.default_rng(1)
    n, d, t = 256, 128, 2048
    table = jnp.ones((n, d), jnp.float32)
    streams = rng.integers(0, 4, t).astype(np.int32)
    # streams 0-2 each loop a small private set; stream 3 sweeps everything
    priv = (streams[:, None] * 8 + rng.integers(0, 8, (t, 1))).ravel()
    sweep = rng.integers(0, n, t)
    idx = np.where(streams == 3, sweep, priv).astype(np.int32)

    def misses(iso_bit):
        _, stats = ciao_gather(table, jnp.array(idx), jnp.array(streams),
                               jnp.array([0, 0, 0, iso_bit], np.int32),
                               c_main=32, c_iso=16, interpret=True)
        return float(np.asarray(stats)[:3, 1].sum())

    # isolating the sweeping stream cuts the victims' misses dramatically
    assert misses(1) < misses(0) / 3
