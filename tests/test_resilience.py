"""Fault-isolated sweep execution: retry ladder, backend degradation,
FailedCell quarantine, strict mode, wall-clock deadlines, and the
workload-cache corruption recovery path.

Every scenario here drives ``run_grid`` through ``faults.injected`` and
checks the central invariant: because every rung of the backend ladder
(jax / C / numpy / per-cell scalar) is bit-exact, *recovery never
changes records* — a run that retried, degraded, or regenerated a cache
file returns exactly the records of an undisturbed run.
"""
import dataclasses

import pytest

from repro.core import faults
from repro.core.faults import InjectedFault
from repro.core.runner import (ExperimentGrid, FailedCell, RunRecord,
                               last_batched_perf, load_records, run_grid,
                               save_records)

GRID = ExperimentGrid(name="res", workloads=("syrk", "kmn"),
                      policies=("gto", "ciao-c"), scale=0.05)
SWEEP = ExperimentGrid(name="res-swl", workloads=("syrk",),
                       policies=("gto", "best-swl"), scale=0.05,
                       best_swl_limits=(2, 8))


@pytest.fixture(autouse=True)
def _isolated_runs_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_RUNS_DIR", str(tmp_path / "runs"))
    monkeypatch.delenv("REPRO_RUN_LEDGER", raising=False)
    faults.clear()
    yield
    faults.clear()


def _base():
    if not hasattr(_base, "recs"):
        _base.recs = run_grid(GRID, engine="batched")
    return _base.recs


# ------------------------------------------------------- transient faults

def test_transient_dispatch_fault_is_retried_bit_identical():
    with faults.injected("chunk.dispatch@1=raise"):
        recs = run_grid(GRID, engine="batched")
    perf = last_batched_perf()
    assert perf["retries"] >= 1
    assert perf["failed_cells"] == 0
    assert recs == _base()


def test_quarter_of_dispatches_failing_still_completes():
    """The acceptance scenario's transient half: every 4th dispatch
    attempt raises, yet the run completes with identical records."""
    with faults.injected("chunk.dispatch@%4=raise"):
        recs = run_grid(GRID, engine="batched", jobs=2)
    assert recs == _base()
    assert not any(isinstance(r, FailedCell) for r in recs)


def test_strict_mode_restores_raise():
    with faults.injected("chunk.dispatch@*=raise"):
        with pytest.raises(InjectedFault):
            run_grid(GRID, engine="batched", strict=True)


# ------------------------------------------------------- poisoned cells

def test_poisoned_cell_quarantined_siblings_survive():
    """A cell that fails on every backend (batched dispatch AND scalar
    fallback) becomes a structured FailedCell; its chunk-mates are
    rescued by the per-cell fallback rung and stay bit-identical."""
    plan = ("chunk.dispatch[syrk/ciao-c]@*=raise,"
            "cell.run[syrk/ciao-c]@*=raise")
    with faults.injected(plan):
        recs = run_grid(GRID, engine="batched", retries=1)
    failed = [r for r in recs if isinstance(r, FailedCell)]
    assert len(failed) == 1
    f = failed[0]
    assert (f.workload, f.policy) == ("syrk", "ciao-c")
    assert f.error_type == "InjectedFault"
    assert f.attempts >= 2                  # ladder attempts + scalar
    assert f.backends[-1] == "scalar"       # full trail recorded
    assert not f.truncated
    ok = {(r.workload, r.policy): r for r in recs
          if isinstance(r, RunRecord)}
    base = {(r.workload, r.policy): r for r in _base()}
    for key, rec in ok.items():
        assert rec == base[key]
    assert last_batched_perf()["failed_cells"] == 1


def test_failed_cell_json_round_trip(tmp_path):
    plan = ("chunk.dispatch[syrk/ciao-c]@*=raise,"
            "cell.run[syrk/ciao-c]@*=raise")
    with faults.injected(plan):
        recs = run_grid(GRID, engine="batched")
    path = str(tmp_path / "mixed.json")
    save_records(recs, path, GRID)
    assert load_records(path) == recs


def test_limit_sweep_survives_poisoned_subcell():
    """best-swl flattens into per-limit subcells; poisoning the sweep
    cell's dispatches must still reduce the scalar fallback into one
    whole-cell record identical to the batched reduce."""
    base = run_grid(SWEEP, engine="batched")
    with faults.injected("chunk.dispatch[syrk/best-swl]@*=raise"):
        recs = run_grid(SWEEP, engine="batched")
    assert recs == base
    assert last_batched_perf()["fallback_cells"] >= 1


# ------------------------------------------------------------- deadlines

def test_deadline_never_fires_is_bit_identical():
    """Arming a (generous) deadline switches single-SM batches to
    bounded-cycle slicing; the records must not change."""
    recs = run_grid(GRID, engine="batched", deadline_s=600.0)
    assert recs == _base()
    assert last_batched_perf()["truncated_cells"] == 0


def test_deadline_mid_run_truncates_resumably(monkeypatch):
    # At test scale the whole batch finishes inside one deadline slice
    # (one run-to-completion stepper call), so shrink the slice quantum
    # to force many bounded rounds — each stalled by the injected delay
    # — and let the between-quanta deadline check fire mid-run.
    from repro.core import batched
    monkeypatch.setattr(batched, "_DEADLINE_SLICE", 500)
    with faults.injected("stepper.step@*=delay:0.02"):
        recs = run_grid(GRID, engine="batched", deadline_s=0.05)
    trunc = [r for r in recs if isinstance(r, FailedCell) and r.truncated]
    assert trunc, "expected mid-run truncation"
    assert last_batched_perf()["truncated_cells"] >= len(trunc)
    # nothing sticky: a clean rerun recovers every cell
    assert run_grid(GRID, engine="batched") == _base()


def test_fine_grained_slicing_is_bit_exact(monkeypatch):
    """Deadline slicing reuses the multi-SM quantum mechanism; even at
    an absurdly small quantum the records must not change."""
    from repro.core import batched
    monkeypatch.setattr(batched, "_DEADLINE_SLICE", 500)
    recs = run_grid(GRID, engine="batched", deadline_s=600.0)
    assert recs == _base()


def test_deadline_zero_truncates_everything():
    recs = run_grid(GRID, engine="batched", deadline_s=0.0)
    assert all(isinstance(r, FailedCell) and r.truncated for r in recs)


def test_deadline_truncates_process_engine_cells():
    grid = dataclasses.replace(GRID, name="res-proc")
    recs = run_grid(grid, engine="process", deadline_s=0.0)
    assert all(isinstance(r, FailedCell) and r.truncated for r in recs)


# ------------------------------------------------- adaptive re-sharding

def _tiny_slices(monkeypatch):
    # see test_deadline_mid_run_truncates_resumably: at test scale a
    # chunk finishes inside one deadline slice, so shrink the quantum
    # to give the between-quanta budget check a chance to fire
    from repro.core import batched
    monkeypatch.setattr(batched, "_DEADLINE_SLICE", 500)


def test_blown_chunk_budget_resharded_not_truncated(monkeypatch):
    """A chunk that exceeds ``chunk_budget_s`` is split at cell
    boundaries and its children complete — records identical to an
    unbudgeted run, nothing truncated or quarantined."""
    from repro.core.ledger import RunLedger
    base = _base()
    _tiny_slices(monkeypatch)
    with faults.injected("stepper.step@*=delay:0.02"):
        recs = run_grid(GRID, engine="batched", run_id="rs1",
                        chunk_budget_s=0.01)
    assert recs == base
    assert not any(isinstance(r, FailedCell) for r in recs)
    perf = last_batched_perf()
    assert perf["resplit_chunks"] >= 1
    assert perf["truncated_cells"] == 0
    # the split was recorded: a resume adopts the children's plan and
    # re-executes nothing
    assert RunLedger("rs1").load_resplits()
    recs2 = run_grid(GRID, engine="batched", resume="rs1")
    assert recs2 == base
    assert last_batched_perf()["stepper_s"] == 0.0


def test_chunk_budget_without_ledger_still_completes(monkeypatch):
    base = _base()
    _tiny_slices(monkeypatch)
    with faults.injected("stepper.step@*=delay:0.02"):
        recs = run_grid(GRID, engine="batched", chunk_budget_s=0.01)
    assert recs == base
    assert last_batched_perf()["resplit_chunks"] >= 1


def test_crash_at_resplit_publication_is_resumable(monkeypatch):
    """Dying between the budget blowout and the resplit record landing
    (the ``chunk.resplit`` site) loses nothing: the next worker re-runs
    or re-splits the parent chunk and records stay identical."""
    _tiny_slices(monkeypatch)
    plan = "stepper.step@*=delay:0.02,chunk.resplit@1=raise"
    with faults.injected(plan):
        with pytest.raises(InjectedFault):
            run_grid(GRID, engine="batched", run_id="rs2",
                     chunk_budget_s=0.01, strict=True)
    recs = run_grid(GRID, engine="batched", resume="rs2")
    assert recs == _base()
    assert last_batched_perf()["failed_cells"] == 0


def test_resplit_crash_publishes_nothing(monkeypatch):
    """The ``chunk.resplit`` site fires *before* the record lands: a
    crash there leaves no resplit doc behind, and the next worker
    simply re-runs (or re-splits) the whole parent chunk."""
    from repro.core.ledger import RunLedger
    _tiny_slices(monkeypatch)
    plan = "stepper.step@*=delay:0.02,chunk.resplit@1=raise"
    with faults.injected(plan):
        with pytest.raises(InjectedFault):
            run_grid(GRID, engine="batched", run_id="rs3",
                     chunk_budget_s=0.01)
    assert RunLedger("rs3").load_resplits() == {}
    recs = run_grid(GRID, engine="batched", resume="rs3")
    assert recs == _base()


# ----------------------------------------------- workload cache recovery

def test_corrupt_cache_file_regenerated_once(tmp_path, monkeypatch):
    """A corrupted on-disk workload cache entry is detected by the
    checksum (or npz parser), deleted, regenerated — and the sweep's
    records are unaffected."""
    monkeypatch.setenv("REPRO_WORKLOAD_CACHE_DIR", str(tmp_path / "wl"))
    small = dataclasses.replace(GRID, name="res-cache",
                                workloads=("syrk",), policies=("gto",))
    base = run_grid(small, engine="batched")     # seeds the cache
    with faults.injected("cache.load@1=corrupt"):
        recs = run_grid(small, engine="batched")
    assert recs == base
    # the regenerated file must now be clean and loadable
    recs2 = run_grid(small, engine="batched")
    assert recs2 == base
