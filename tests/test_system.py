"""End-to-end system tests: the dry-run lowering path on a reduced config
(in-process, small mesh) and the serve path against the real model."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import reduced_config
from repro.configs.base import RunConfig, ShapeConfig
from repro.models import model as M
from repro.parallel.sharding import local_env, make_env, tree_shardings
from repro.train import train_step as TS


def test_lower_and_compile_reduced_train():
    """The exact dry-run path (lower -> compile -> cost/memory analysis)
    works end-to-end on the test mesh."""
    cfg = reduced_config("gemma2-2b")
    run = RunConfig()
    env = local_env()
    step = TS.make_train_step(cfg, run, env)
    state_struct = TS.train_state_struct(cfg, run)
    shape = ShapeConfig(name="t", seq_len=32, global_batch=2, mode="train")
    batch_struct = M.input_specs(cfg, shape, run)
    lowered = jax.jit(step).lower(state_struct, batch_struct)
    compiled = lowered.compile()
    from repro.launch import hlo_analysis as H
    assert H.cost_analysis_dict(compiled).get("flops", 0) > 0
    res = H.analyze(compiled.as_text())
    assert res["flops"] > 0 and res["bytes"] > 0


@pytest.mark.parametrize("name", ["gemma2-2b", "mamba2-2.7b",
                                  "seamless-m4t-medium"])
def test_lower_decode_step(name):
    cfg = reduced_config(name)
    run = RunConfig()
    env = local_env()
    _, decode_fn = TS.make_serve_steps(cfg, run, env)
    shape = ShapeConfig(name="d", seq_len=64, global_batch=2, mode="decode")
    specs = M.input_specs(cfg, shape, run)
    p_struct = M.param_shapes(cfg, run)
    lowered = jax.jit(decode_fn).lower(p_struct, specs["token"],
                                       specs["pos"], specs["cache"])
    assert lowered.compile() is not None


def test_greedy_generation_deterministic():
    """Tiny real-model generation loop: prefill + N decode steps."""
    cfg = reduced_config("gemma2-2b")
    run = RunConfig(remat_policy="none", param_dtype="float32")
    env = local_env()
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key, run)
    toks = jax.random.randint(key, (1, 12), 0, cfg.vocab_size)
    logits, cache, pos = M.prefill(env, cfg, params, {"tokens": toks}, run,
                                   max_len=24)
    seq = []
    tok = jnp.argmax(logits, -1)[:, None]
    for i in range(6):
        seq.append(int(tok[0, 0]))
        logits, cache = M.decode_step(env, cfg, params, tok, pos + 1 + i,
                                      cache, run)
        tok = jnp.argmax(logits, -1)[:, None]
    # rerun -> identical sequence
    logits2, cache2, pos2 = M.prefill(env, cfg, params, {"tokens": toks},
                                      run, max_len=24)
    tok2 = jnp.argmax(logits2, -1)[:, None]
    seq2 = []
    for i in range(6):
        seq2.append(int(tok2[0, 0]))
        logits2, cache2 = M.decode_step(env, cfg, params, tok2,
                                        pos2 + 1 + i, cache2, run)
        tok2 = jnp.argmax(logits2, -1)[:, None]
    assert seq == seq2
