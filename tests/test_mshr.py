"""MSHR capacity gating (PR-2 satellite): the entry count is a real
structural limit when ``OnChipConfig.mshr_gate`` is on.

The seed modeled the MSHR as merge-only bookkeeping — ``reserve`` was
always immediately followed by ``fill``, so the 32-entry capacity was dead
code (measured outstanding-miss peaks on LWS workloads are ~110). Gating
stays off by default to preserve the golden seed-exact timing; these tests
pin both the mechanism and the off-by-default contract.
"""
import dataclasses

from repro.core.onchip import MSHR, OnChipConfig
from repro.core.simulator import SimConfig, SMSimulator
from repro.core.traces import make_workload


def test_admit_gates_at_capacity():
    m = MSHR(entries=2, gate=True)
    assert m.admit(now=0, lat=100) == 0
    assert m.admit(now=1, lat=100) == 0
    # both entries outstanding until t=100/101: the third miss queues
    # until the earliest fill (t=100) frees its entry — and takes it over,
    # so in-flight count never exceeds capacity
    delay = m.admit(now=2, lat=50)
    assert delay == 98
    assert m.full_events == 1
    assert m.outstanding(now=2) == 2
    # a fourth queued miss waits for the *next* fill (t=101), not the
    # already-consumed first one
    assert m.admit(now=2, lat=50) == 99
    assert m.outstanding(now=2) == 2
    # after every fill returned, admission is free again
    assert m.admit(now=1000, lat=10) == 0
    assert m.full_events == 2


def test_admit_ungated_is_free():
    m = MSHR(entries=1, gate=False)
    for t in range(10):
        assert m.admit(now=t, lat=1000) == 0
    assert m.full_events == 0


def test_reserve_merges_same_line():
    m = MSHR(entries=2)
    assert m.reserve(10, smem_addr=3)
    assert m.reserve(10)                    # same line merges
    assert m.reserve(11)
    assert not m.reserve(12)                # structurally full
    assert m.fill(10) == {"smem_addr": 3}
    assert m.fill(10) is None


def _run(workload, gate, entries=32):
    cfg = SimConfig(onchip=OnChipConfig(mshr_gate=gate,
                                        mshr_entries=entries))
    return SMSimulator(workload, "gto", cfg).run()


def test_gating_stalls_show_up_in_simulation():
    wl = make_workload("bicg", seed=3, scale=0.2)
    base = _run(wl, gate=False)
    gated = _run(wl, gate=True, entries=4)
    # a 4-entry MSHR on an LWS workload must fill up and cost cycles
    assert gated.stats["mshr_full"] > 0
    assert gated.cycles > base.cycles
    assert gated.instructions == base.instructions


def test_gate_off_keeps_seed_stats_schema():
    """Ungated runs must not grow a stats key — the golden equivalence
    suite compares the stats dict against seed snapshots."""
    wl = make_workload("syrk", seed=3, scale=0.1)
    res = _run(wl, gate=False)
    assert "mshr_full" not in res.stats
    gated = _run(wl, gate=True)
    assert "mshr_full" in gated.stats


def test_wide_mshr_gate_matches_ungated_timing():
    """With capacity far above the worst-case outstanding count the gate
    never fires, and timing must be identical to the ungated model."""
    wl = make_workload("syrk", seed=3, scale=0.1)
    base = _run(wl, gate=False)
    wide = _run(wl, gate=True, entries=100_000)
    assert wide.stats["mshr_full"] == 0
    assert wide.cycles == base.cycles and wide.ipc == base.ipc
    assert dataclasses.asdict(base)["timeline"] == \
        dataclasses.asdict(wide)["timeline"]
