"""Fault-injection harness (repro.core.faults): plan grammar, trigger
arithmetic, actions, and the zero-cost-when-disabled contract."""
import os
import subprocess
import sys

import pytest

from repro.core import faults
from repro.core.faults import FaultSpec, InjectedFault, parse_plan


# ------------------------------------------------------------- grammar

def test_parse_single_clause():
    plan = parse_plan("chunk.dispatch@1=raise")
    assert plan is not None and len(plan.specs) == 1
    s = plan.specs[0]
    assert (s.site, s.trigger, s.action, s.key) == \
        ("chunk.dispatch", "1", "raise", None)


def test_parse_full_grammar():
    plan = parse_plan(
        "chunk.dispatch[syrk/ciao-c]@%4=raise,"
        "cache.load@2-3=corrupt; stepper.step@5+=delay:0.25")
    assert [s.site for s in plan.specs] == \
        ["chunk.dispatch", "cache.load", "stepper.step"]
    assert plan.specs[0].key == "syrk/ciao-c"
    assert plan.specs[2].param == 0.25


def test_parse_empty_is_none():
    assert parse_plan("") is None
    assert parse_plan(" , ; ") is None


@pytest.mark.parametrize("bad", [
    "chunk.dispatch",                # no trigger/action
    "chunk.dispatch@x=raise",        # bad trigger
    "chunk.dispatch@1=explode",      # unknown action
    "chunk.dispatch@%0=raise",       # modulo zero
])
def test_parse_rejects_malformed(bad):
    with pytest.raises(ValueError):
        parse_plan(bad)


# ------------------------------------------------------------- triggers

@pytest.mark.parametrize("trigger,expect", [
    ("*", [True, True, True, True, True]),
    ("3", [False, False, True, False, False]),
    ("3+", [False, False, True, True, True]),
    ("2-4", [False, True, True, True, False]),
    ("%2", [False, True, False, True, False]),
])
def test_trigger_arithmetic(trigger, expect):
    spec = FaultSpec(site="s", action="raise", trigger=trigger)
    assert [spec.hits(n) for n in range(1, 6)] == expect


def test_counters_per_clause_and_key_scoped():
    plan = parse_plan("cell.run[syrk]@2=raise")
    plan.fire("cell.run", key="kmn/gto/base")       # key miss: no count
    plan.fire("cell.run", key="syrk/gto/base")      # count 1
    with pytest.raises(InjectedFault):
        plan.fire("cell.run", key="syrk/ciao-c/base")   # count 2 fires
    assert plan.counts == [2] and plan.fired == [1]


# ------------------------------------------------------------- actions

def test_raise_action_type():
    plan = parse_plan("records.save@*=raise")
    with pytest.raises(InjectedFault):
        plan.fire("records.save")
    # InjectedFault is a RuntimeError so generic handlers still catch it
    assert issubclass(InjectedFault, RuntimeError)


def test_delay_action_sleeps(monkeypatch):
    slept = []
    monkeypatch.setattr(faults.time, "sleep", slept.append)
    plan = parse_plan("stepper.step@*=delay:0.125")
    plan.fire("stepper.step")
    assert slept == [0.125]


def test_corrupt_action_garbles_file(tmp_path):
    p = tmp_path / "cache.npz"
    p.write_bytes(b"A" * 1000)
    plan = parse_plan("cache.load@*=corrupt")
    plan.fire("cache.load", path=str(p))
    data = p.read_bytes()
    assert len(data) == 500 and data.startswith(b"\x00CORRUPTED")


def test_corrupt_without_path_raises():
    plan = parse_plan("records.save@*=corrupt")
    with pytest.raises(InjectedFault):
        plan.fire("records.save", path=None)


# ------------------------------------------------ install / fire / env

def test_fire_is_noop_without_plan():
    faults.clear()
    assert faults.active() is None
    faults.fire("chunk.dispatch")          # must not raise


def test_injected_context_restores_previous():
    faults.clear()
    with faults.injected("cell.run@*=raise") as plan:
        assert faults.active() is plan
        with pytest.raises(InjectedFault):
            faults.fire("cell.run")
    assert faults.active() is None


def test_install_accepts_text_and_clear():
    try:
        plan = faults.install("cell.run@1=raise")
        assert faults.active() is plan
    finally:
        faults.clear()
    assert faults.active() is None


def test_env_plan_installed_at_import():
    """$REPRO_FAULT_PLAN is parsed at import so spawn workers inherit
    it; check in a subprocess to avoid touching this process's plan."""
    code = ("from repro.core import faults; "
            "p = faults.active(); "
            "assert p is not None and p.specs[0].site == 'cell.run'")
    env = dict(os.environ, REPRO_FAULT_PLAN="cell.run@1=raise",
               PYTHONPATH="src")
    subprocess.run([sys.executable, "-c", code], check=True, env=env,
                   cwd=os.path.dirname(os.path.dirname(__file__)))


# --------------------------------------------- lease / serving sites

def test_distributed_and_serving_sites_registered():
    for site in ("lease.claim", "lease.heartbeat", "chunk.resplit",
                 "worker.exit", "serve.admit", "serve.preempt",
                 "serve.page_alloc"):
        assert site in faults.SITES
        # every site name parses in a clause
        assert parse_plan(f"{site}@1=raise").specs[0].site == site


def test_lease_sites_fire_through_ledger(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_RUNS_DIR", str(tmp_path / "runs"))
    from repro.core.ledger import RunLedger
    led = RunLedger("f1")
    led.open({"grid_hash": "h"})
    with faults.injected("lease.claim@1=raise"):
        with pytest.raises(InjectedFault):
            led.claim_lease("k", "w", ttl=30.0)
    doc = led.claim_lease("k", "w", ttl=30.0)
    assert doc is not None
    with faults.injected("lease.heartbeat@1=raise"):
        with pytest.raises(InjectedFault):
            led.heartbeat_lease("k", doc)
    assert led.heartbeat_lease("k", doc) is True
