"""SM simulator: determinism + the paper's class-level ordering phenomena
(scaled-down traces so the suite stays fast)."""
import pytest

from repro.core import make_workload
from repro.core.simulator import SMSimulator, SimConfig, run_policy_sweep


@pytest.fixture(scope="module")
def sws_results():
    wl = make_workload("syrk", scale=0.5)
    return run_policy_sweep(wl, ["gto", "ccws", "ciao-p", "ciao-c"])


def test_deterministic():
    wl = make_workload("syrk", scale=0.25)
    a = SMSimulator(wl, "ciao-c").run()
    b = SMSimulator(wl, "ciao-c").run()
    assert a.ipc == b.ipc and a.stats == b.stats


def test_sws_isolation_wins(sws_results):
    """CIAO-P must beat GTO on small-working-set thrash (paper Fig. 8b/10)."""
    r = sws_results
    assert r["ciao-p"].ipc > 1.3 * r["gto"].ipc
    assert r["ciao-p"].l1_hit_rate > r["gto"].l1_hit_rate + 0.3


def test_ciao_keeps_tlp_vs_ccws(sws_results):
    """CIAO throttles fewer warps than CCWS-style locality protection."""
    r = sws_results
    assert r["ciao-p"].mean_active_warps >= r["ccws"].mean_active_warps - 1


def test_ci_class_no_throttle():
    wl = make_workload("conv2d", scale=0.5)
    res = run_policy_sweep(wl, ["gto", "ciao-c"])
    # compute-intensive: CIAO must not sacrifice TLP (paper Fig. 1/9)
    assert res["ciao-c"].ipc >= 0.95 * res["gto"].ipc
    assert res["ciao-c"].mean_active_warps > 40


def test_smem_usage_caps_isolation():
    """F_smem > 0 shrinks CIAO's borrowed region (Table II)."""
    wl_free = make_workload("syrk", scale=0.25)
    wl_used = make_workload("ss", scale=0.25)       # 50% smem used
    s_free = SMSimulator(wl_free, "ciao-p")
    s_used = SMSimulator(wl_used, "ciao-p")
    assert s_used.mem.region_blocks < s_free.mem.region_blocks


def test_best_swl_uses_profiled_limit():
    wl = make_workload("syrk", scale=0.25)
    res = run_policy_sweep(wl, ["best-swl"], best_swl_limits=(2, 8, 48))
    assert res["best-swl"].ipc > 0
