"""Loop-aware HLO analyzer: exact FLOP counting through scan bodies."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_analysis as H


def test_scan_flops_counted_with_trip_count():
    w = jax.ShapeDtypeStruct((13, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)

    def f(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        out, _ = jax.lax.scan(body, x, w)
        return out.sum()

    compiled = jax.jit(f).lower(w, x).compile()
    res = H.analyze(compiled.as_text())
    expected = 2 * 8 * 64 * 64 * 13          # one dot per iteration x 13
    assert res["flops"] == expected
    assert any(m >= 13 for m in res["loop_multipliers"].values())


def test_cost_analysis_undercounts_vs_analyzer():
    """Demonstrates why the analyzer exists: XLA's cost_analysis counts the
    while body once."""
    w = jax.ShapeDtypeStruct((10, 32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 32), jnp.float32)

    def f(w, x):
        def body(c, wi):
            return c @ wi, None
        return jax.lax.scan(body, x, w)[0].sum()

    compiled = jax.jit(f).lower(w, x).compile()
    xla_flops = H.cost_analysis_dict(compiled).get("flops", 0)
    res = H.analyze(compiled.as_text())
    assert res["flops"] >= 9 * xla_flops / 2   # ~10x undercount recovered


def test_no_loops_matches_direct():
    a = jax.ShapeDtypeStruct((32, 48), jnp.float32)
    b = jax.ShapeDtypeStruct((48, 16), jnp.float32)
    compiled = jax.jit(lambda a, b: a @ b).lower(a, b).compile()
    res = H.analyze(compiled.as_text())
    assert res["flops"] == 2 * 32 * 48 * 16
    assert res["collective_total_effective"] == 0
