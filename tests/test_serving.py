"""Serving engine: completion, pool invariants, CIAO vs GTO under pressure."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.interference import DetectorConfig, InterferenceDetector
from repro.serving import (PoolConfig, Request, ServeConfig, ServeEngine,
                           synth_requests)
from repro.serving.pages import PagePool


def _run(policy, reqs=None, **pool_kw):
    pool = PoolConfig(**{"main_pages": 640, "reserve_pages": 192,
                         "page_tokens": 16, **pool_kw})
    cfg = ServeConfig(policy=policy, groups=10, pool=pool)
    reqs = reqs if reqs is not None else synth_requests(
        256, groups=10, prefix_pages=24, decode_tokens=128,
        heavy_frac=0.25, heavy_decode=1000)
    return ServeEngine(cfg).run(list(reqs))


@pytest.mark.parametrize("policy", ["gto", "ccws", "statpcal", "ciao-p",
                                    "ciao-t", "ciao-c"])
def test_all_requests_complete(policy):
    st_ = _run(policy)
    assert st_.completed == 256
    assert st_.decoded_tokens > 0


def test_ciao_reduces_interference_cost():
    gto = _run("gto")
    cc = _run("ciao-c")
    assert gto.preemptions > 0, "workload must create pressure"
    assert cc.preemptions <= gto.preemptions
    assert cc.tokens_per_unit >= gto.tokens_per_unit


def test_no_pressure_policies_equal():
    reqs = synth_requests(40, groups=4, prefix_pages=4, decode_tokens=64,
                          heavy_frac=0.0)
    a = _run("gto", reqs=reqs, main_pages=2048)
    b = _run("ciao-c", reqs=reqs, main_pages=2048)
    assert a.preemptions == b.preemptions == 0
    assert a.work_units == b.work_units


# ------------------------------------------------------------ pool props
@given(st.lists(st.tuples(st.integers(0, 30), st.integers(0, 3),
                          st.booleans()), min_size=1, max_size=200))
@settings(max_examples=30, deadline=None)
def test_pool_invariants(ops):
    det = InterferenceDetector(DetectorConfig(num_warps=8))
    pool = PagePool(PoolConfig(main_pages=8, reserve_pages=4), det)
    pinned = {}
    for key_i, slot, iso in ops:
        r = pool.acquire((0, key_i), slot, slot, isolated=iso)
        if r != "defer":
            pinned[(0, key_i)] = slot
        # capacity never exceeded
        assert pool.counts["main"] <= 8
        assert pool.counts["reserve"] <= 4
        # bookkeeping consistent
        assert pool.counts["main"] + pool.counts["reserve"] == len(pool.pages)
    for key, slot in pinned.items():
        pool.unpin(key, slot, free=True)
    # all pinned-by-us pages released or cached; counters non-negative
    assert pool.counts["main"] >= 0 and pool.counts["reserve"] >= 0


def test_prefix_cache_reuse():
    """Second request of a session hits the cached prefix (no re-prefill)."""
    reqs = [Request(rid=0, group=0, prefix_pages=8, decode_tokens=16),
            Request(rid=1, group=0, prefix_pages=8, decode_tokens=16)]
    st_ = _run("gto", reqs=reqs, main_pages=256)
    assert st_.prefill_pages == 8        # prefix prefilled exactly once


# ------------------------------------------------------------ fault sites

def _light_reqs():
    # no-pressure workload: zero preemptions, so any goodput delta is
    # attributable to the injected fault alone
    return synth_requests(40, groups=4, prefix_pages=4, decode_tokens=64,
                          heavy_frac=0.0)


def test_admission_fault_degrades_goodput_never_corrupts():
    from repro.core import faults
    base = _run("ciao-c", reqs=_light_reqs(), main_pages=2048)
    assert base.injected_faults == 0
    with faults.injected("serve.admit@1-3=raise"):
        hurt = _run("ciao-c", reqs=_light_reqs(), main_pages=2048)
    # the fault stalls admission (this step admits nothing) ...
    assert hurt.injected_faults == 3
    assert hurt.steps > base.steps
    assert hurt.goodput < base.goodput
    # ... but never corrupts the accounting: every request completes
    # and decodes exactly the same number of tokens
    assert hurt.completed == base.completed == 40
    assert hurt.decoded_tokens == base.decoded_tokens
    assert hurt.prefill_pages == base.prefill_pages
    assert hurt.work_units == base.work_units


def test_page_alloc_and_preempt_faults_absorbed_under_pressure():
    from repro.core import faults
    plan = "serve.page_alloc@%5=raise,serve.preempt@%2=raise"
    with faults.injected(plan):
        st_ = _run("ciao-c")
    assert st_.injected_faults > 0
    assert st_.completed == 256          # nothing lost, only delayed
    assert st_.decoded_tokens > 0
    assert st_.steps > 0
