"""Multi-SM GPU model: CTA placement determinism, shared-L2/DRAM
contention, and the paper's policy ordering surviving on a 2-SM chip."""
import numpy as np
import pytest

from repro.core import make_workload
from repro.core.gpu import (CTA, CTAScheduler, GPUConfig, GPUSimulator,
                            make_ctas, run_gpu_policy_sweep)
from repro.core.simulator import SimConfig, SMSimulator


def _cta(cta_id, warps):
    z = np.zeros(1, np.int64)
    return CTA(cta_id=cta_id, copy=0,
               traces=[(z.astype(np.uint8), z)] * warps)


# ------------------------------------------------------- CTA scheduling
def test_round_robin_placement_pattern():
    ctas = [_cta(i, 4) for i in range(7)]
    placement = CTAScheduler("round-robin").assign(ctas, 3)
    assert [[c.cta_id for c in sm] for sm in placement] == \
        [[0, 3, 6], [1, 4], [2, 5]]


def test_loose_placement_balances_uneven_ctas():
    # warp counts 8,1,1,1: round-robin on 2 SMs puts 9 vs 2; loose
    # fills the lighter SM first.
    ctas = [_cta(0, 8), _cta(1, 1), _cta(2, 1), _cta(3, 1)]
    placement = CTAScheduler("loose").assign(ctas, 2)
    loads = [sum(c.num_warps for c in sm) for sm in placement]
    assert loads == [8, 3]


def test_placement_deterministic():
    wl = make_workload("syrk", scale=0.25)
    a = GPUSimulator(wl, "gto", gpu=GPUConfig(num_sms=3)).placement
    b = GPUSimulator(wl, "gto", gpu=GPUConfig(num_sms=3)).placement
    assert [[c.cta_id for c in sm] for sm in a] == \
        [[c.cta_id for c in sm] for sm in b]
    assert [[c.copy for c in sm] for sm in a] == \
        [[c.copy for c in sm] for sm in b]


def test_unknown_scheduler_rejected():
    with pytest.raises(ValueError):
        CTAScheduler("random")


def test_make_ctas_covers_all_warps():
    wl = make_workload("syrk", scale=0.25)
    ctas = make_ctas(wl, 8)
    assert sum(c.num_warps for c in ctas) == len(wl.traces)


# ------------------------------------------------------------ contention
def test_l2_contention_sublinear_scaling():
    """Two SMs sharing the L2/DRAM stage on a streaming (LWS) workload
    must deliver less than 2x the single-SM IPC (chip-level contention,
    invisible in the old single-SM model)."""
    wl = make_workload("kmn", scale=0.25)
    single = SMSimulator(wl, "gto").run()
    chip = GPUSimulator(wl, "gto", gpu=GPUConfig(num_sms=2)).run()
    assert chip.instructions == 2 * single.instructions   # replicated
    assert chip.ipc < 1.8 * single.ipc
    # both SMs make progress: neither starves behind the other
    per_sm = [r.ipc for r in chip.per_sm]
    assert min(per_sm) > 0.25 * max(per_sm)


def test_gpu_run_deterministic():
    wl = make_workload("syrk", scale=0.25)
    a = GPUSimulator(wl, "ciao-c", gpu=GPUConfig(num_sms=2)).run()
    b = GPUSimulator(wl, "ciao-c", gpu=GPUConfig(num_sms=2)).run()
    assert a.ipc == b.ipc and a.cycles == b.cycles
    assert [r.ipc for r in a.per_sm] == [r.ipc for r in b.per_sm]


def test_instance_reuse_is_idempotent():
    """begin() rebuilds all per-run state (detector, L1, policy, private
    L2/DRAM queues), so re-running the same instance is deterministic."""
    wl = make_workload("syrk", scale=0.2)
    sim = SMSimulator(wl, "statpcal")
    a, b = sim.run(), sim.run()
    assert a.ipc == b.ipc and a.stats == b.stats
    chip = GPUSimulator(wl, "ciao-c", gpu=GPUConfig(num_sms=2))
    x, y = chip.run(), chip.run()
    assert x.ipc == y.ipc and x.cycles == y.cycles


def test_distribute_mode_partitions_warps():
    wl = make_workload("syrk", scale=0.25)
    gpu = GPUSimulator(wl, "gto",
                       gpu=GPUConfig(num_sms=2, replicate=False))
    total = sum(sm.n for sm in gpu.sms)
    assert total == len(wl.traces)


# ---------------------------------------------------- policy ordering
def test_gpu_policy_ordering_sws():
    """Paper ordering survives chip-level contention on SWS: CIAO's
    isolation wins big over GTO, and CIAO-C >= CIAO-T (Fig. 8b)."""
    wl = make_workload("syrk", scale=0.25)
    res = run_gpu_policy_sweep(wl, ("gto", "ciao-p", "ciao-t", "ciao-c"),
                               gpu=GPUConfig(num_sms=2))
    gto = res["gto"].ipc
    assert res["ciao-p"].ipc > 1.3 * gto
    assert res["ciao-c"].ipc > 1.3 * gto
    assert res["ciao-c"].ipc >= 0.95 * res["ciao-t"].ipc


def test_gpu_policy_ordering_lws():
    """LWS under shared-L2/DRAM contention: CIAO-P >= GTO and CIAO-C
    holds GTO's throughput (paper Fig. 8a)."""
    wl = make_workload("kmn", scale=0.25)
    res = run_gpu_policy_sweep(wl, ("gto", "ciao-p", "ciao-c"),
                               gpu=GPUConfig(num_sms=2))
    gto = res["gto"].ipc
    assert res["ciao-p"].ipc >= gto
    assert res["ciao-c"].ipc >= 0.95 * gto
