"""JAX backend equivalence: the jitted stepper must reproduce the C and
numpy steppers bit-for-bit, per cell, in mixed batches.

Mirrors ``tests/test_batched.py``'s pinning for the third stepper:

* the golden seed-core snapshots — all single-SM golden cells as ONE
  heterogeneous jitted batch; every numeric field must match the
  snapshot exactly (which also pins jax == C == numpy, since both other
  backends are pinned to the same snapshots).
* a mixed batch across the special memory paths (CIAO-P smem
  redirection, statPCAL bypass) equal across all three steppers.
* the runner: ``engine="jax"`` records equal ``engine="batched"`` on a
  grid that mixes batchable cells, an MSHR-gated variant (per-cell
  fallback) and a multi-SM grid (jax chunks fall back to "auto").
* the gating contract: multi-SM / object-policy batches raise.
* the batch axis is vmap-able: one jitted iteration under ``jax.vmap``
  over an outer grid axis equals two independent iterations.

Everything here skips cleanly when jax is not importable — the rest of
the suite never depends on it.
"""
import dataclasses
import gzip
import json
import pathlib

import numpy as np
import pytest

from repro.core import _cstep
from repro.core import jax_backend
from repro.core.batched import BatchCell, BatchedSMEngine, run_batched
from repro.core.simulator import SimConfig
from repro.workloads import make_workload

pytestmark = pytest.mark.skipif(
    not jax_backend.available(),
    reason=f"jax unavailable: {jax_backend.unavailable_reason()}")

GOLDEN = pathlib.Path(__file__).parent / "golden" / "golden_cells.json.gz"

SIM_FIELDS = ("policy", "cycles", "instructions", "ipc", "l1_hit_rate",
              "vta_hits", "mean_active_warps", "timeline", "pairs")


def test_golden_cells_one_mixed_batch_jax():
    """All golden single-SM cells as one heterogeneous jitted batch."""
    doc = json.loads(gzip.decompress(GOLDEN.read_bytes()).decode())
    cells = [c for c in doc["cells"] if c["kind"] == "sm"]
    wls = {}
    batch = []
    for c in cells:
        key = (c["workload"], c["seed"], c["scale"])
        if key not in wls:
            wls[key] = make_workload(c["workload"], seed=c["seed"],
                                     scale=c["scale"])
        batch.append(BatchCell(wls[key], c["policy"],
                               dict(c["policy_kwargs"])))
    results = run_batched(batch, backend="jax")
    for c, res in zip(cells, results):
        got = dataclasses.asdict(res)
        got["timeline"] = [list(t) for t in got["timeline"]]
        for field in SIM_FIELDS:
            assert got[field] == c["result"][field], \
                f"{c['workload']}/{c['policy']}: mismatch in {field}"
        for key, val in c["result"]["stats"].items():
            assert got["stats"].get(key) == val, \
                f"{c['workload']}/{c['policy']}: stat {key!r} mismatch"


def test_three_steppers_agree_on_smem_paths():
    """numpy vs C vs jax across the CIAO-P smem redirection + statPCAL
    bypass paths in one mixed batch."""
    wl = make_workload("nw", seed=11, scale=0.12)       # 35% smem app
    wl2 = make_workload("syrk", seed=11, scale=0.12)
    cells = [BatchCell(wl, "ciao-p"), BatchCell(wl, "ciao-c"),
             BatchCell(wl2, "statpcal", {"limit": 2}),
             BatchCell(wl2, "ccws"), BatchCell(wl2, "best-swl",
                                               {"limit": 4})]
    ref = run_batched(cells, backend="numpy")
    got = run_batched(cells, backend="jax")
    assert got == ref
    if _cstep.available():
        assert run_batched(cells, backend="c") == ref


def test_runner_engine_jax_matches_batched(tmp_path, monkeypatch):
    """engine="jax" records equal engine="batched", including an
    MSHR-gated variant (per-cell fallback path)."""
    monkeypatch.setenv("REPRO_WORKLOAD_CACHE_DIR", str(tmp_path))
    from repro.core.onchip import OnChipConfig
    from repro.core.runner import ExperimentGrid, run_grid
    gated = SimConfig(onchip=OnChipConfig(mshr_gate=True))
    grid = ExperimentGrid(name="t", workloads=("syrk", "kmn"),
                          policies=("gto", "ciao-c", "best-swl"),
                          scale=0.06, best_swl_limits=(2, 8),
                          variants={"base": None, "gated": gated})
    assert run_grid(grid, engine="jax") == run_grid(grid,
                                                    engine="batched")


def test_runner_engine_jax_multi_sm_falls_back(tmp_path, monkeypatch):
    """Multi-SM grids under engine="jax" fall back to the default
    stepper per chunk and still produce equal records."""
    monkeypatch.setenv("REPRO_WORKLOAD_CACHE_DIR", str(tmp_path))
    from repro.core.gpu import GPUConfig
    from repro.core.runner import ExperimentGrid, run_grid
    grid = ExperimentGrid(name="t2", workloads=("syrk",),
                          policies=("gto", "ciao-c"), scale=0.05,
                          gpu=GPUConfig(num_sms=2))
    assert run_grid(grid, engine="jax") == run_grid(grid,
                                                    engine="batched")


def test_gating_contract(monkeypatch):
    """Multi-SM batches and custom policy objects are rejected with a
    reason; supports_engine mirrors what run() raises."""
    from repro.core import batched as batched_mod
    from repro.core.gpu import GPUConfig
    from repro.core.policies import GTOPolicy

    wl = make_workload("syrk", seed=0, scale=0.05)
    eng = BatchedSMEngine([BatchCell(wl, "gto")], backend="jax",
                          gpu=GPUConfig(num_sms=2))
    assert "multi-SM" in jax_backend.supports_engine(eng)
    with pytest.raises(RuntimeError, match="multi-SM"):
        eng.run()

    class OddPolicy(GTOPolicy):
        def epoch_tick(self, active, finished, mem_util=0.0):
            pass        # any override outside the known families

    real = batched_mod.make_policy
    monkeypatch.setattr(
        batched_mod, "make_policy",
        lambda name, nw, det, **kw: OddPolicy(nw, det)
        if name == "odd" else real(name, nw, det, **kw))
    eng2 = BatchedSMEngine([BatchCell(wl, "odd")], backend="jax")
    assert "object" in jax_backend.supports_engine(eng2)
    with pytest.raises(RuntimeError, match="object"):
        eng2.run()


def test_iteration_is_vmappable():
    """The state pytree's leading batch axis composes with vmap: one
    jitted iteration over an outer (2, B, ...) stacking equals two
    independent iterations (the accelerator grid-axis contract)."""
    import jax
    import jax.numpy as jnp

    wl = make_workload("bicg", seed=5, scale=0.04)
    eng = BatchedSMEngine([BatchCell(wl, "gto"),
                           BatchCell(wl, "ciao-c")], backend="jax")
    S = jax_backend._static_of(eng)
    state, cst = jax_backend._arrays_of(eng)
    with jax.experimental.enable_x64():
        step = jax.jit(
            lambda st, c: jax_backend._iteration(S, c, dict(st)))
        one = {k: np.asarray(v) for k, v in step(state, cst).items()}
        two = {k: np.asarray(v)
               for k, v in step(one, cst).items()}
        stacked = {k: jnp.stack([jnp.asarray(v), jnp.asarray(one[k])])
                   for k, v in state.items()}
        vstep = jax.jit(jax.vmap(
            lambda st, c: jax_backend._iteration(S, c, dict(st)),
            in_axes=(0, None)))
        vout = vstep(stacked, cst)
        for k in state:
            np.testing.assert_array_equal(
                np.asarray(vout[k][0]), one[k], f"vmap lane 0: {k}")
            np.testing.assert_array_equal(
                np.asarray(vout[k][1]), two[k], f"vmap lane 1: {k}")
