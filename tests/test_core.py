"""CIAO core: VTA, interference list saturation, Algorithm 1 invariants,
on-chip memory structural properties (hypothesis where it pays)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.interference import DetectorConfig, InterferenceDetector, NO_WARP
from repro.core.onchip import LINE, AddressTranslationUnit, OnChipConfig, \
    OnChipMemory, SMMT
from repro.core.policies import CIAOPolicy
from repro.core.vta import VictimTagArray


# ------------------------------------------------------------------- VTA
def test_vta_basic_hit_and_pop():
    vta = VictimTagArray()
    vta.insert(owner_wid=3, line_addr=100, evictor_wid=7)
    assert vta.probe(3, 100) == 7
    assert vta.probe(3, 100) is None          # popped on hit
    assert vta.hit_count(3) == 1


def test_vta_fifo_capacity():
    vta = VictimTagArray(tags_per_set=4)
    for i in range(6):
        vta.insert(0, i, 1)
    assert vta.probe(0, 0) is None            # pushed out by FIFO
    assert vta.probe(0, 5) == 1


def test_vta_ignores_self_eviction():
    vta = VictimTagArray()
    vta.insert(2, 55, 2)
    assert vta.probe(2, 55) is None


# --------------------------------------------------- interference list
def test_sat_counter_keeps_frequent_interferer():
    """Fig. 4c: the frequent interferer survives occasional others."""
    det = InterferenceDetector(DetectorConfig())
    for _ in range(5):
        det.on_eviction(4, 10, 32)            # W32 interferes with W4
        assert det.on_miss(4, 10) == 32
    det.on_eviction(4, 11, 42)                # one-off W42 event
    det.on_miss(4, 11)
    assert det.most_interfering(4) == 32      # counter only decremented


def test_sat_counter_replaces_on_underflow():
    det = InterferenceDetector(DetectorConfig())
    det.on_eviction(4, 10, 32)
    det.on_miss(4, 10)                        # counter = 0, wid 32
    det.on_eviction(4, 11, 42)
    det.on_miss(4, 11)                        # different -> replace at 0
    assert det.most_interfering(4) == 42


@given(st.lists(st.integers(0, 5), min_size=1, max_size=60))
@settings(max_examples=40, deadline=None)
def test_sat_counter_bounds(evictors):
    det = InterferenceDetector(DetectorConfig())
    for i, e in enumerate(evictors):
        det.on_eviction(7, 100 + i, 10 + e)
        det.on_miss(7, 100 + i)
    i = 7 % det.cfg.list_entries
    assert 0 <= det.sat_counter[i] <= det.cfg.sat_max
    assert det.most_interfering(7) in {10 + e for e in evictors}


# ------------------------------------------------------------ Algorithm 1
def _detector_with_interference(interfered=0, interferer=1, hits=50):
    det = InterferenceDetector(DetectorConfig(high_epoch=100, low_epoch=10))
    for i in range(hits):
        det.on_eviction(interfered, i, interferer)
        det.on_miss(interfered, i)
    det.on_instruction(100)
    return det


def test_algorithm1_isolate_then_stall():
    det = _detector_with_interference()
    pol = CIAOPolicy(8, det, mode="c")
    done = [False] * 8
    det.poll_epochs(8)
    pol.high_epoch_tick(list(range(8)), done)
    assert pol.flags[1].i == 1 and pol.flags[1].v == 1    # isolated first
    assert det.isolation_trigger(1) == 0
    # still interfering -> next high tick stalls it
    for i in range(50, 100):
        det.on_eviction(0, i, 1)
        det.on_miss(0, i)
    det.on_instruction(100)
    det.poll_epochs(8)
    pol.high_epoch_tick(list(range(8)), done)
    assert pol.flags[1].v == 0                            # stalled
    assert det.stall_trigger(1) == 0
    assert pol.stall_stack == [1]


def test_algorithm1_reverse_order_reactivation():
    det = _detector_with_interference()
    pol = CIAOPolicy(8, det, mode="t")
    done = [False] * 8
    pol.stall_directly(1, 0)
    pol.stall_directly(2, 0)
    assert pol.stall_stack == [1, 2]
    # trigger 0 finished -> reactivate newest first (LIFO)
    done[0] = True
    pol.low_epoch_tick(list(range(8)), done)
    assert pol.stall_stack == [1] and pol.flags[2].v == 1
    pol.low_epoch_tick(list(range(8)), done)
    assert pol.stall_stack == [] and pol.flags[1].v == 1


def test_ciao_p_never_stalls():
    det = _detector_with_interference()
    pol = CIAOPolicy(8, det, mode="p")
    det.poll_epochs(8)
    for _ in range(10):
        pol.high_epoch_tick(list(range(8)), [False] * 8)
    assert all(f.v == 1 for f in pol.flags)
    assert not pol.stall_directly(1, 0)


# ------------------------------------------------------------- on-chip
@given(st.integers(0, 2**25), st.integers(0, 47))
@settings(max_examples=60, deadline=None)
def test_atu_tag_in_opposite_bank_group(addr, wid):
    """§IV-B invariant: tag and data block live in different bank groups,
    so one shared-memory access serves both in parallel."""
    atu = AddressTranslationUnit(OnChipConfig(), region_blocks=256)
    t = atu.translate(addr * LINE, wid)
    assert t.tag_group != t.group
    assert 0 <= t.bank < 16 and t.group in (0, 1)


def test_smmt_reserve_unused():
    smmt = SMMT(48 * 1024)
    smmt.allocate("app", 16 * 1024)
    base, size = smmt.reserve_unused()
    assert base == 16 * 1024 and size == 32 * 1024
    assert smmt.unused() == 0
    with pytest.raises(ValueError):
        smmt.allocate("x", 1)


def test_onchip_migration_single_copy():
    """L1D->smem migration: the line leaves L1D when it enters smem."""
    det = InterferenceDetector(DetectorConfig())
    mem = OnChipMemory(OnChipConfig(), det)
    mem.access(0, 0)                               # fills L1D
    assert mem._l1_lookup(0)[1] is not None
    ev = mem.access(0, 0, isolated=True)           # redirected -> migrates
    assert ev == "smem_migrate"
    assert mem._l1_lookup(0)[1] is None            # single-copy invariant
    assert mem.access(0, 0, isolated=True) == "smem_hit"


def test_onchip_smem_sized_by_smmt():
    det = InterferenceDetector(DetectorConfig())
    full = OnChipMemory(OnChipConfig(), det, smem_used_bytes=0)
    half = OnChipMemory(OnChipConfig(), InterferenceDetector(DetectorConfig()),
                        smem_used_bytes=24 * 1024)
    assert half.region_blocks < full.region_blocks
