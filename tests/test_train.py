"""Train substrate: optimizers, grad accumulation, loss-chunked CE,
trainer loop with failure injection, straggler monitor."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.configs.base import RunConfig, ShapeConfig
from repro.models import model as M
from repro.parallel.sharding import local_env
from repro.train import optim as O
from repro.train import train_step as TS

ENV = local_env()
SHAPE = ShapeConfig(name="t", seq_len=64, global_batch=4, mode="train")


def _batch(cfg, key, b=4, s=64):
    toks = jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def test_adamw_decreases_quadratic():
    w = {"w": jnp.array([3.0, -2.0])}
    state = O.adamw_init(w)
    for _ in range(200):
        g = jax.tree.map(lambda x: 2 * x, w)
        upd, state = O.adamw_update(g, state, w, lr=0.05, weight_decay=0.0)
        w = jax.tree.map(lambda p, u: p + u, w, upd)
    assert float(jnp.max(jnp.abs(w["w"]))) < 0.1


def test_adafactor_factored_state_small():
    params = {"big": jnp.zeros((64, 128)), "vec": jnp.zeros((32,))}
    st = O.adafactor_init(params)
    n_state = sum(x.size for x in jax.tree.leaves(st["v"]))
    assert n_state == 64 + 128 + 32          # factored, not 64*128
    g = jax.tree.map(jnp.ones_like, params)
    upd, st = O.adafactor_update(g, st, params, lr=0.01)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(upd))


def test_clip_by_global_norm():
    tree = {"a": jnp.full((4,), 10.0)}
    clipped, norm = O.clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(O.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_grad_accum_matches_full_batch():
    cfg = reduced_config("qwen3-4b")
    key = jax.random.PRNGKey(0)
    batch = _batch(cfg, key)
    run1 = RunConfig(remat_policy="none", grad_accum=1,
                     param_dtype="float32")
    run2 = dataclasses.replace(run1, grad_accum=2)
    s1 = TS.init_train_state(cfg, run1, key)
    s2 = jax.tree.map(lambda x: x, s1)
    n1, m1 = jax.jit(TS.make_train_step(cfg, run1, ENV))(s1, batch)
    n2, m2 = jax.jit(TS.make_train_step(cfg, run2, ENV))(s2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     n1["params"], n2["params"])
    assert max(jax.tree.leaves(d)) < 5e-5


def test_loss_chunking_equivalent():
    cfg = reduced_config("qwen3-4b")
    key = jax.random.PRNGKey(0)
    batch = _batch(cfg, key)
    run_a = RunConfig(remat_policy="none", loss_chunk=0,
                      param_dtype="float32")
    run_b = dataclasses.replace(run_a, loss_chunk=16)
    params = M.init_params(cfg, key, run_a)
    la = M.loss_fn(ENV, cfg, params, batch, run_a)
    lb = M.loss_fn(ENV, cfg, params, batch, run_b)
    assert float(la) == pytest.approx(float(lb), rel=1e-5)


@pytest.mark.parametrize("policy", ["none", "dots", "full"])
def test_remat_policies_same_loss(policy):
    cfg = reduced_config("gemma2-2b")
    key = jax.random.PRNGKey(0)
    batch = _batch(cfg, key, s=32)
    run = RunConfig(remat_policy=policy, param_dtype="float32")
    params = M.init_params(cfg, key, run)
    l = M.loss_fn(ENV, cfg, params, batch, run)
    g = jax.grad(lambda p: M.loss_fn(ENV, cfg, p, batch, run))(params)
    assert jnp.isfinite(l)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(g))


def test_trainer_loss_falls_and_resumes(tmp_path):
    from repro.train.trainer import Trainer, TrainerConfig
    cfg = reduced_config("qwen3-4b")
    run = RunConfig(remat_policy="none", learning_rate=3e-3,
                    warmup_steps=10, param_dtype="float32")
    shape = ShapeConfig(name="t", seq_len=64, global_batch=8, mode="train")
    tc = TrainerConfig(total_steps=50, checkpoint_every=15,
                       checkpoint_dir=str(tmp_path), log_every=10,
                       async_checkpoint=False)
    t = Trainer(cfg, run, ENV, shape, tc, fail_at_step=20)
    with pytest.raises(RuntimeError, match="injected failure"):
        t.run_loop()
    # restart resumes from step 15 and finishes
    t2 = Trainer(cfg, run, ENV, shape, tc)
    out = t2.run_loop()
    losses = out["losses"]
    assert len(losses) == 35                       # 50 - resumed step 15
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) + 0.5


def test_straggler_monitor():
    from repro.train.trainer import StragglerMonitor
    hits = []
    mon = StragglerMonitor(threshold=3.0,
                           on_straggler=lambda s, dt, e: hits.append(s))
    for i in range(10):
        mon.observe(i, 1.0)
    assert not mon.events
    mon.observe(10, 10.0)
    assert mon.events == [10] and hits == [10]
    # outlier must not poison the EWMA
    assert mon.ewma == pytest.approx(1.0, rel=0.01)
