"""PR-2 array-core equivalence: the fused hot path must reproduce the
seed (PR-1) simulator bit-for-bit.

``tests/golden/golden_cells.json.gz`` holds `SimResult` snapshots captured by
running ``tests/golden/capture_golden.py`` against the seed core at the
PR-2 base commit (9de8cc9): one cell per workload class (LWS/SWS/CI), one
per policy family (GTO, CCWS, Best-SWL, statPCAL, CIAO-P/T/C), plus a
2-SM ``GPUSimulator`` run on a shared L2/DRAM stage. Every numeric field —
ipc, cycles, l1_hit_rate, stats, the interference pair list, even the
sampled timeline floats — must match exactly; any divergence in scheduler
order, LRU victim choice, VTA FIFO semantics, epoch snapshots, or DRAM
queueing shows up here as a hard failure.

Stats comparison is by golden key: the array core may add new counters
(e.g. ``mshr_full`` when MSHR gating is enabled), but every seed counter
must match and no golden key may disappear.
"""
import dataclasses
import gzip
import json
import pathlib

import pytest

from repro.core.gpu import GPUConfig, GPUSimulator
from repro.core.simulator import SMSimulator
from repro.core.traces import make_workload

# stored gzipped (the raw JSON is ~850KB of timeline floats); the .gz
# takes precedence — a plain .json is read only when no .gz exists
GOLDEN = pathlib.Path(__file__).parent / "golden" / "golden_cells.json"

SIM_FIELDS = ("policy", "cycles", "instructions", "ipc", "l1_hit_rate",
              "vta_hits", "mean_active_warps", "timeline", "pairs")


def _load_cells():
    gz = GOLDEN.with_suffix(".json.gz")
    if gz.exists():
        doc = json.loads(gzip.decompress(gz.read_bytes()).decode())
    else:
        doc = json.loads(GOLDEN.read_text())
    return doc["cells"]


def _cell_id(cell):
    return f"{cell['kind']}-{cell['workload']}-{cell['policy']}"


def _assert_sim_result(result, golden):
    got = dataclasses.asdict(result)
    got["timeline"] = [list(t) for t in got["timeline"]]
    for field in SIM_FIELDS:
        assert got[field] == golden[field], f"mismatch in {field}"
    for key, val in golden["stats"].items():
        assert key in got["stats"], f"stat {key!r} disappeared"
        assert got["stats"][key] == val, f"stat {key!r} mismatch"


CELLS = _load_cells()


@pytest.mark.parametrize("cell", CELLS, ids=[_cell_id(c) for c in CELLS])
def test_golden_cell(cell):
    wl = make_workload(cell["workload"], seed=cell["seed"],
                       scale=cell["scale"])
    if cell["kind"] == "sm":
        result = SMSimulator(wl, cell["policy"],
                             policy_kwargs=dict(cell["policy_kwargs"])).run()
        _assert_sim_result(result, cell["result"])
        return
    golden = cell["result"]
    got = GPUSimulator(wl, cell["policy"],
                       gpu=GPUConfig(num_sms=cell["num_sms"])).run()
    assert got.policy == golden["policy"]
    assert got.num_sms == golden["num_sms"]
    assert got.cycles == golden["cycles"]
    assert got.instructions == golden["instructions"]
    assert got.ipc == golden["ipc"]
    assert got.l1_hit_rate == golden["l1_hit_rate"]
    assert got.vta_hits == golden["vta_hits"]
    assert got.mean_active_warps == golden["mean_active_warps"]
    assert dict(got.mem_stats) == golden["mem_stats"]
    for sm_result, sm_golden in zip(got.per_sm, golden["per_sm"]):
        _assert_sim_result(sm_result, sm_golden)


def test_rerun_is_deterministic():
    """`begin()` rebuilds all per-run state: the same instance re-run
    must reproduce itself exactly (the GPU interleaving relies on it)."""
    wl = make_workload("syrk", seed=7, scale=0.2)
    sim = SMSimulator(wl, "ciao-c")
    a = sim.run()
    b = sim.run()
    assert a == b
