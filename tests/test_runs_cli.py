"""Run lifecycle CLI (python -m repro.runs): create/work/show/list/gc,
orphaned-run repair, and the CI assertion flags. Everything goes
through ``runs.main(argv)`` in-process — the same entrypoint the chaos
smoke drives as a subprocess."""
import json
import os
import time

import pytest

from repro import runs as runs_cli
from repro.core import faults
from repro.core.ledger import RunLedger, grid_hash, runs_root
from repro.core.runner import (ExperimentGrid, grid_from_doc,
                               last_batched_perf, run_grid)

GRID_ARGS = ["--workloads", "syrk,kmn", "--policies", "gto,ciao-c",
             "--scale", "0.05", "--engine", "batched", "--name", "cli"]
GRID = ExperimentGrid(name="cli", workloads=("syrk", "kmn"),
                      policies=("gto", "ciao-c"), scale=0.05)


@pytest.fixture(autouse=True)
def _isolated_runs_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_RUNS_DIR", str(tmp_path / "runs"))
    monkeypatch.delenv("REPRO_RUN_LEDGER", raising=False)
    faults.clear()
    yield
    faults.clear()


def _backdate(led, seconds):
    """Age every ledger file so staleness/gc probes see an idle run."""
    old = time.time() - seconds
    paths = [led.manifest_path]
    for sub in (led.chunk_dir, led.lease_dir, led.resplit_dir,
                led.worker_dir):
        if sub.is_dir():
            paths.extend(sub.glob("*.json"))
    for p in paths:
        os.utime(p, (old, old))


# ------------------------------------------------------- create + work

def test_create_work_show_roundtrip(capsys):
    assert runs_cli.main(["create", "run1"] + GRID_ARGS) == 0
    led = RunLedger("run1")
    assert led.load()["status"] == "pending"
    # the stored grid_doc reconstructs the exact grid (hash round trip)
    grid = grid_from_doc(led.manifest["grid_doc"])
    assert grid_hash(grid) == led.manifest["grid_hash"]
    assert runs_cli.main(["work", "run1", "--worker", "w1"]) == 0
    assert led.load()["status"] == "complete"
    out = capsys.readouterr().out
    assert "# worker w1: complete" in out
    assert runs_cli.main(["show", "run1",
                          "--assert-status", "complete"]) == 0
    assert runs_cli.main(["show", "run1",
                          "--assert-status", "running"]) == 1
    # the drained run's records equal an ordinary serial run
    base = run_grid(GRID, engine="batched")
    recs = run_grid(GRID, engine="batched", resume="run1")
    assert recs == base
    assert last_batched_perf()["stepper_s"] == 0.0


def test_create_existing_requires_force():
    assert runs_cli.main(["create", "dup"] + GRID_ARGS) == 0
    assert runs_cli.main(["create", "dup"] + GRID_ARGS) == 1
    assert runs_cli.main(["create", "dup", "--force"] + GRID_ARGS) == 0


def test_work_missing_run_errors(capsys):
    assert runs_cli.main(["work", "nope"]) == 1
    assert "no readable manifest" in capsys.readouterr().err


def test_work_records_worker_summary(capsys):
    runs_cli.main(["create", "sum1"] + GRID_ARGS)
    assert runs_cli.main(["work", "sum1", "--worker", "alpha"]) == 0
    docs = RunLedger("sum1").worker_summaries()
    assert [d["worker"] for d in docs] == ["alpha"]
    assert docs[0]["status"] == "complete"
    assert docs[0]["lease_claims"] >= 1
    capsys.readouterr()
    assert runs_cli.main(["show", "sum1", "--json"]) == 0
    info = json.loads(capsys.readouterr().out)
    assert info["workers"] == 1
    assert info["worker_summaries"][0]["worker"] == "alpha"


# ----------------------------------------------------------- list + gc

def test_list_shows_runs(capsys):
    runs_cli.main(["create", "l1"] + GRID_ARGS)
    capsys.readouterr()
    runs_cli.main(["list", "--json"])
    infos = json.loads(capsys.readouterr().out)
    assert [i["run_id"] for i in infos] == ["l1"]
    assert infos[0]["status"] == "pending"
    assert infos[0]["cells"] == 4


def test_gc_age_based_retention(capsys):
    runs_cli.main(["create", "old"] + GRID_ARGS)
    runs_cli.main(["create", "new"] + GRID_ARGS)
    _backdate(RunLedger("old"), 3 * 86400)
    # dry run removes nothing
    assert runs_cli.main(["gc", "--older-than", "1d", "--dry-run"]) == 0
    assert (runs_root() / "old").exists()
    assert runs_cli.main(["gc", "--older-than", "1d"]) == 0
    assert not (runs_root() / "old").exists()
    assert (runs_root() / "new").exists()


def test_gc_protects_live_runs_without_force(capsys):
    runs_cli.main(["create", "live"] + GRID_ARGS)
    led = RunLedger("live")
    led.load()
    led.manifest["status"] = "running"
    led._write_manifest()
    doc = led.claim_lease("c1", "w1", ttl=10_000.0)   # live heartbeat
    assert doc is not None
    _backdate(led, 3 * 86400)
    # the lease was backdated too -- refresh it so the run looks alive
    led.heartbeat_lease("c1", doc)
    assert runs_cli.main(["gc", "--older-than", "1d"]) == 0
    assert (runs_root() / "live").exists()
    assert runs_cli.main(["gc", "--older-than", "0s", "--force"]) == 0
    assert not (runs_root() / "live").exists()


def test_parse_age_grammar():
    assert runs_cli._parse_age("7d") == 7 * 86400.0
    assert runs_cli._parse_age("12h") == 12 * 3600.0
    assert runs_cli._parse_age("30m") == 1800.0
    assert runs_cli._parse_age("45s") == 45.0
    assert runs_cli._parse_age("2") == 2 * 86400.0


# -------------------------------------------------------- orphan repair

def _orphan(run_id):
    """A run whose worker died without finish(): status still
    'running', no live leases, files long silent."""
    runs_cli.main(["create", run_id] + GRID_ARGS)
    led = RunLedger(run_id)
    led.load()
    led.manifest["status"] = "running"
    led._write_manifest()
    _backdate(led, 7200)
    return led


def test_list_repairs_orphaned_running_run(capsys):
    _orphan("orph")
    capsys.readouterr()
    runs_cli.main(["list", "--stale-after", "600", "--json"])
    infos = json.loads(capsys.readouterr().out)
    assert infos[0]["status"] == "interrupted"
    # and the repair is persisted, not just displayed
    assert RunLedger("orph").load()["status"] == "interrupted"
    assert RunLedger("orph").load()["interruptions"] == 1


def test_no_repair_flag_only_reports(capsys):
    _orphan("orph2")
    capsys.readouterr()
    runs_cli.main(["list", "--stale-after", "600", "--no-repair",
                   "--json"])
    infos = json.loads(capsys.readouterr().out)
    assert infos[0]["status"] == "interrupted"      # probed...
    assert RunLedger("orph2").load()["status"] == "running"  # ...not written


def test_resume_of_orphan_counts_interruption(monkeypatch):
    monkeypatch.setenv("REPRO_BATCH_TOKEN_BUDGET", "60000")
    base = run_grid(GRID, engine="batched")
    run_grid(GRID, engine="batched", run_id="orph3")
    led = RunLedger("orph3")
    led.load()
    led.manifest["status"] = "running"
    led._write_manifest()
    _backdate(led, 7200)
    monkeypatch.setenv("REPRO_LEASE_TTL", "30")     # stale_after >= 600 still
    recs = run_grid(GRID, engine="batched", resume="orph3")
    assert recs == base
    assert led.load()["interruptions"] == 1
    assert led.load()["status"] == "complete"


def test_heartbeating_run_is_not_stale():
    runs_cli.main(["create", "hb"] + GRID_ARGS)
    led = RunLedger("hb")
    led.load()
    led.manifest["status"] = "running"
    led._write_manifest()
    _backdate(led, 7200)
    doc = led.claim_lease("c1", "w1", ttl=600.0)    # fresh heartbeat
    assert doc is not None
    assert led.probe_status(stale_after=600.0) == "running"
    led.release_lease("c1", doc)
