"""Regenerate the golden equivalence snapshots (tests/golden/golden_cells.json).

The snapshots pin the *seed* (pre-array-core, PR-1) simulator outputs:
`test_equivalence.py` asserts the vectorized core reproduces them
bit-for-bit (ipc, cycles, l1_hit_rate, vta_hits, mean_active_warps,
stats, timeline, pairs). They were captured by running this script at the
PR-2 base commit; re-running it on a later tree only confirms
self-consistency, it does not re-derive the seed baseline.

Usage: PYTHONPATH=src python tests/golden/capture_golden.py
"""
from __future__ import annotations

import dataclasses
import gzip
import json
import pathlib

from repro.core.gpu import GPUConfig, GPUSimulator
from repro.core.runner import workload_seed
from repro.core.simulator import SMSimulator
from repro.core.traces import make_workload

SCALE = 0.25

# (workload, policy, policy_kwargs) — one cell per workload class and per
# policy family, plus dedicated cells for the limit-based policies.
SM_CELLS = [
    ("bicg", "gto", {}),
    ("bicg", "ciao-c", {}),
    ("syrk", "ciao-p", {}),
    ("syrk", "ccws", {}),
    ("conv2d", "ciao-t", {}),
    ("kmn", "statpcal", {"limit": 4}),
    ("gesummv", "best-swl", {"limit": 2}),
]
# one multi-SM chip cell: 2 SMs contending on a shared L2/DRAM stage
GPU_CELLS = [
    ("syrk", "ciao-c", 2),
]


def _sim_result_doc(r) -> dict:
    d = dataclasses.asdict(r)
    d["timeline"] = [list(t) for t in d["timeline"]]
    return d


def capture() -> dict:
    cells = []
    for wl_name, policy, kwargs in SM_CELLS:
        seed = workload_seed(0, wl_name)
        wl = make_workload(wl_name, seed=seed, scale=SCALE)
        r = SMSimulator(wl, policy, policy_kwargs=dict(kwargs)).run()
        cells.append({
            "kind": "sm", "workload": wl_name, "policy": policy,
            "policy_kwargs": kwargs, "seed": seed, "scale": SCALE,
            "result": _sim_result_doc(r),
        })
    for wl_name, policy, num_sms in GPU_CELLS:
        seed = workload_seed(0, wl_name)
        wl = make_workload(wl_name, seed=seed, scale=SCALE)
        g = GPUSimulator(wl, policy, gpu=GPUConfig(num_sms=num_sms)).run()
        cells.append({
            "kind": "gpu", "workload": wl_name, "policy": policy,
            "num_sms": num_sms, "seed": seed, "scale": SCALE,
            "result": {
                "policy": g.policy, "num_sms": g.num_sms,
                "cycles": g.cycles, "instructions": g.instructions,
                "ipc": g.ipc, "l1_hit_rate": g.l1_hit_rate,
                "vta_hits": g.vta_hits,
                "mean_active_warps": g.mean_active_warps,
                "mem_stats": dict(g.mem_stats),
                "per_sm": [_sim_result_doc(r) for r in g.per_sm],
            },
        })
    return {"scale": SCALE, "cells": cells}


def main() -> None:
    out = pathlib.Path(__file__).parent / "golden_cells.json.gz"
    payload = json.dumps(capture(), indent=1, sort_keys=True).encode()
    # mtime=0 so re-captures of identical results are byte-identical
    out.write_bytes(gzip.compress(payload, 9, mtime=0))
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
