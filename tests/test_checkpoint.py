"""Checkpointing: roundtrip, atomic publish, GC, async, fingerprint,
elastic restore (same bytes under different placement)."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as C


def _state(key=0):
    k = jax.random.PRNGKey(key)
    return {"params": {"w": jax.random.normal(k, (4, 8)),
                       "b": jnp.zeros((8,))},
            "opt": {"m": jnp.ones((4, 8)), "count": jnp.zeros((), jnp.int32)},
            "step": jnp.array(7, jnp.int32)}


def test_roundtrip_exact(tmp_path):
    s = _state()
    C.save(s, tmp_path, step=7, fingerprint="abc")
    abstract = jax.eval_shape(lambda: s)
    restored, step = C.restore(abstract, tmp_path, fingerprint="abc")
    assert step == 7
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fingerprint_mismatch(tmp_path):
    C.save(_state(), tmp_path, step=1, fingerprint="abc")
    with pytest.raises(ValueError, match="fingerprint"):
        C.restore(jax.eval_shape(lambda: _state()), tmp_path,
                  fingerprint="xyz")


def test_gc_keeps_latest(tmp_path):
    for step in (1, 2, 3, 4, 5):
        C.save(_state(), tmp_path, step=step, keep=2)
    steps = sorted(int(p.name.split("_")[1])
                   for p in pathlib.Path(tmp_path).glob("step_*"))
    assert steps == [4, 5]
    assert C.latest_step(tmp_path) == 5


def test_no_partial_checkpoints_visible(tmp_path):
    C.save(_state(), tmp_path, step=3)
    for p in pathlib.Path(tmp_path).glob("step_*"):
        assert (p / "manifest.json").exists()
        assert (p / "arrays.npz").exists()
    assert not list(pathlib.Path(tmp_path).glob(".tmp_*"))


def test_async_checkpointer(tmp_path):
    ck = C.AsyncCheckpointer(tmp_path, keep=2)
    s = _state()
    ck.save(s, 1)
    ck.save(s, 2)      # implicitly waits for step 1
    ck.wait()
    assert C.latest_step(tmp_path) == 2


def test_elastic_restore_same_values(tmp_path):
    """Restore with explicit (single-device) placement — the elastic path:
    same bytes, new shardings."""
    s = _state()
    C.save(s, tmp_path, step=1)
    sh = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]), s)
    restored, _ = C.restore(jax.eval_shape(lambda: s), tmp_path,
                            shardings=sh)
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_shape_mismatch_rejected(tmp_path):
    C.save(_state(), tmp_path, step=1)
    bad = jax.eval_shape(lambda: {"params": {"w": jnp.zeros((5, 8)),
                                             "b": jnp.zeros((8,))},
                                  "opt": {"m": jnp.ones((4, 8)),
                                          "count": jnp.zeros((), jnp.int32)},
                                  "step": jnp.zeros((), jnp.int32)})
    with pytest.raises(ValueError, match="shape mismatch"):
        C.restore(bad, tmp_path)
