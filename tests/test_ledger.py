"""Run ledger (repro.core.ledger): checkpoint shards, resume semantics,
and the central property — a run interrupted after any prefix of chunks
and resumed from its ledger reassembles records **bit-identical** to an
uninterrupted run, re-executing only the incomplete chunks."""
import json
import os

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import _cstep, faults
from repro.core.faults import InjectedFault
from repro.core.ledger import RunLedger, chunk_key, grid_hash, runs_root
from repro.core.runner import (ExperimentGrid, FailedCell,
                               last_batched_perf, run_grid)

GRID = ExperimentGrid(name="led", workloads=("syrk", "kmn"),
                      policies=("gto", "ciao-c", "best-swl"), scale=0.05,
                      best_swl_limits=(2, 8))
BACKENDS = ["numpy"] + (["c"] if _cstep.available() else [])


@pytest.fixture(autouse=True)
def _isolated_runs_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_RUNS_DIR", str(tmp_path / "runs"))
    monkeypatch.delenv("REPRO_RUN_LEDGER", raising=False)
    faults.clear()
    yield
    faults.clear()


def _base():
    if not hasattr(_base, "recs"):
        _base.recs = run_grid(GRID, engine="batched")
    return _base.recs


# ------------------------------------------------------------ unit level

def test_grid_hash_tracks_grid_content():
    assert grid_hash(GRID) == grid_hash(GRID)
    other = ExperimentGrid(name="led", workloads=("syrk",),
                           policies=("gto",), scale=0.05)
    assert grid_hash(GRID) != grid_hash(other)


def test_chunk_key_is_order_independent():
    assert chunk_key(["3:0", "4:1"]) == chunk_key(["4:1", "3:0"])
    assert chunk_key(["3:0"]) != chunk_key(["4:1"])


def test_run_id_path_traversal_rejected():
    for bad in ("a/b", "../up", ".hidden"):
        with pytest.raises(ValueError):
            RunLedger(bad)


def test_manifest_written_and_finished(tmp_path):
    recs = run_grid(GRID, engine="batched", run_id="m1")
    assert recs == _base()
    man = json.loads((runs_root() / "m1" / "manifest.json").read_text())
    assert man["status"] == "complete"
    assert man["grid_hash"] == grid_hash(GRID)
    assert man["cells"] == len(recs)
    assert list((runs_root() / "m1" / "chunks").glob("*.json"))


def test_resume_missing_run_raises():
    with pytest.raises(ValueError, match="cannot resume"):
        run_grid(GRID, engine="batched", resume="never-ran")


def test_resume_grid_mismatch_raises():
    run_grid(GRID, engine="batched", run_id="g1")
    other = ExperimentGrid(name="led", workloads=("syrk",),
                           policies=("gto",), scale=0.05)
    with pytest.raises(ValueError, match="grid"):
        run_grid(other, engine="batched", resume="g1")


def test_run_id_resume_conflict_raises():
    with pytest.raises(ValueError, match="conflicts"):
        run_grid(GRID, engine="batched", run_id="a", resume="b")


def test_fresh_run_id_clears_stale_shards():
    """Reusing a run_id without resume= must start clean, not splice
    another run's shards in."""
    run_grid(GRID, engine="batched", run_id="r1")
    recs = run_grid(GRID, engine="batched", run_id="r1")
    assert recs == _base()
    assert last_batched_perf()["chunks_resumed"] == 0


def test_corrupt_shard_is_rerun_not_trusted():
    run_grid(GRID, engine="batched", run_id="c1")
    shards = sorted((runs_root() / "c1" / "chunks").glob("*.json"))
    shards[0].write_text("{ not json")
    recs = run_grid(GRID, engine="batched", resume="c1")
    assert recs == _base()
    assert not any(isinstance(r, FailedCell) for r in recs)


def test_full_resume_runs_nothing_new():
    run_grid(GRID, engine="batched", run_id="f1", jobs=2)
    recs = run_grid(GRID, engine="batched", resume="f1", jobs=2)
    assert recs == _base()
    perf = last_batched_perf()
    assert perf["chunks_resumed"] == perf["chunks"]
    assert perf["stepper_s"] == 0.0         # no chunk actually executed


def test_auto_ledger_env(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_RUN_LEDGER", "1")
    recs = run_grid(GRID, engine="batched")
    assert recs == _base()
    autos = [p for p in runs_root().iterdir() if p.name.startswith("led-")]
    assert autos, "expected an auto-generated ledger directory"


def test_process_engine_cells_get_per_cell_shards():
    grid = ExperimentGrid(name="led-proc", workloads=("syrk",),
                          policies=("gto", "ciao-p"), scale=0.2)
    base = run_grid(grid, engine="process")
    run_grid(grid, engine="process", run_id="p1")
    recs = run_grid(grid, engine="process", resume="p1")
    assert recs == base


# -------------------------------------------- interrupt → resume property

_PROP_BASE = {}    # (backend, jobs) -> uninterrupted records


def _prop_base(backend, jobs):
    if (backend, jobs) not in _PROP_BASE:
        _PROP_BASE[backend, jobs] = run_grid(GRID, engine="batched",
                                             jobs=jobs)
    return _PROP_BASE[backend, jobs]


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=1, max_value=4),
       st.sampled_from(BACKENDS),
       st.sampled_from([1, 2]))
def test_interrupted_run_resumes_bit_identical(kill_after, backend, jobs):
    """Kill a strict run after ``kill_after`` chunk dispatches, resume
    from its ledger: only incomplete chunks re-run, and the final
    records equal the uninterrupted run's bit for bit — across both
    steppers and worker counts, over a limit-sweep grid.

    Environment handling is manual (no monkeypatch): function-scoped
    fixtures don't reset between hypothesis examples."""
    import tempfile
    saved = {k: os.environ.get(k)
             for k in ("REPRO_RUNS_DIR", "REPRO_BATCHED_BACKEND")}
    os.environ["REPRO_RUNS_DIR"] = tempfile.mkdtemp(prefix="repro-led-")
    os.environ["REPRO_BATCHED_BACKEND"] = backend
    try:
        base = _prop_base(backend, jobs)
        run_id = f"prop-{kill_after}-{backend}-{jobs}"
        trigger = f"{kill_after + 1}+"   # let kill_after dispatches pass
        try:
            with faults.injected(f"chunk.dispatch@{trigger}=raise"):
                run_grid(GRID, engine="batched", jobs=jobs, strict=True,
                         run_id=run_id)
        except InjectedFault:
            pass                          # the simulated crash
        recs = run_grid(GRID, engine="batched", jobs=jobs, resume=run_id)
        assert recs == base
        perf = last_batched_perf()
        assert perf["chunks_resumed"] >= min(kill_after, perf["chunks"])
    finally:
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v
