"""Run ledger (repro.core.ledger): checkpoint shards, resume semantics,
the chunk-lease protocol for cooperating workers, and the central
property — a run interrupted after any prefix of chunks (or a worker
SIGKILLed while holding a lease) still reassembles records
**bit-identical** to an uninterrupted serial run, re-executing only the
incomplete chunks."""
import json
import os
import signal
import subprocess
import sys
import threading
import time

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import _cstep, faults
from repro.core.faults import InjectedFault
from repro.core.ledger import RunLedger, chunk_key, grid_hash, runs_root
from repro.core.runner import (ExperimentGrid, FailedCell, grid_from_doc,
                               grid_to_doc, last_batched_perf, run_grid)

GRID = ExperimentGrid(name="led", workloads=("syrk", "kmn"),
                      policies=("gto", "ciao-c", "best-swl"), scale=0.05,
                      best_swl_limits=(2, 8))
BACKENDS = ["numpy"] + (["c"] if _cstep.available() else [])


@pytest.fixture(autouse=True)
def _isolated_runs_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_RUNS_DIR", str(tmp_path / "runs"))
    monkeypatch.delenv("REPRO_RUN_LEDGER", raising=False)
    faults.clear()
    yield
    faults.clear()


def _base():
    if not hasattr(_base, "recs"):
        _base.recs = run_grid(GRID, engine="batched")
    return _base.recs


# ------------------------------------------------------------ unit level

def test_grid_hash_tracks_grid_content():
    assert grid_hash(GRID) == grid_hash(GRID)
    other = ExperimentGrid(name="led", workloads=("syrk",),
                           policies=("gto",), scale=0.05)
    assert grid_hash(GRID) != grid_hash(other)


def test_chunk_key_is_order_independent():
    assert chunk_key(["3:0", "4:1"]) == chunk_key(["4:1", "3:0"])
    assert chunk_key(["3:0"]) != chunk_key(["4:1"])


def test_run_id_path_traversal_rejected():
    for bad in ("a/b", "../up", ".hidden"):
        with pytest.raises(ValueError):
            RunLedger(bad)


def test_manifest_written_and_finished(tmp_path):
    recs = run_grid(GRID, engine="batched", run_id="m1")
    assert recs == _base()
    man = json.loads((runs_root() / "m1" / "manifest.json").read_text())
    assert man["status"] == "complete"
    assert man["grid_hash"] == grid_hash(GRID)
    assert man["cells"] == len(recs)
    assert list((runs_root() / "m1" / "chunks").glob("*.json"))


def test_resume_missing_run_raises():
    with pytest.raises(ValueError, match="cannot resume"):
        run_grid(GRID, engine="batched", resume="never-ran")


def test_resume_grid_mismatch_raises():
    run_grid(GRID, engine="batched", run_id="g1")
    other = ExperimentGrid(name="led", workloads=("syrk",),
                           policies=("gto",), scale=0.05)
    with pytest.raises(ValueError, match="grid"):
        run_grid(other, engine="batched", resume="g1")


def test_run_id_resume_conflict_raises():
    with pytest.raises(ValueError, match="conflicts"):
        run_grid(GRID, engine="batched", run_id="a", resume="b")


def test_fresh_run_id_clears_stale_shards():
    """Reusing a run_id without resume= must start clean, not splice
    another run's shards in."""
    run_grid(GRID, engine="batched", run_id="r1")
    recs = run_grid(GRID, engine="batched", run_id="r1")
    assert recs == _base()
    assert last_batched_perf()["chunks_resumed"] == 0


def test_corrupt_shard_is_rerun_not_trusted():
    run_grid(GRID, engine="batched", run_id="c1")
    shards = sorted((runs_root() / "c1" / "chunks").glob("*.json"))
    shards[0].write_text("{ not json")
    recs = run_grid(GRID, engine="batched", resume="c1")
    assert recs == _base()
    assert not any(isinstance(r, FailedCell) for r in recs)


def test_full_resume_runs_nothing_new():
    run_grid(GRID, engine="batched", run_id="f1", jobs=2)
    recs = run_grid(GRID, engine="batched", resume="f1", jobs=2)
    assert recs == _base()
    perf = last_batched_perf()
    assert perf["chunks_resumed"] == perf["chunks"]
    assert perf["stepper_s"] == 0.0         # no chunk actually executed


def test_auto_ledger_env(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_RUN_LEDGER", "1")
    recs = run_grid(GRID, engine="batched")
    assert recs == _base()
    autos = [p for p in runs_root().iterdir() if p.name.startswith("led-")]
    assert autos, "expected an auto-generated ledger directory"


def test_process_engine_cells_get_per_cell_shards():
    grid = ExperimentGrid(name="led-proc", workloads=("syrk",),
                          policies=("gto", "ciao-p"), scale=0.2)
    base = run_grid(grid, engine="process")
    run_grid(grid, engine="process", run_id="p1")
    recs = run_grid(grid, engine="process", resume="p1")
    assert recs == base


# ------------------------------------------------------ lease protocol

def test_grid_doc_round_trips_grid_hash():
    doc = grid_to_doc(GRID)
    assert grid_hash(grid_from_doc(doc)) == grid_hash(GRID)
    # docs are plain JSON: survive a serialization round trip too
    assert grid_hash(grid_from_doc(json.loads(json.dumps(doc)))) \
        == grid_hash(GRID)


def test_lease_lifecycle_claim_heartbeat_release():
    led = RunLedger("life")
    led.open({"grid_hash": "h"})
    doc = led.claim_lease("k", "w1", ttl=30.0)
    assert doc is not None and doc["takeover_of"] is None
    assert led.claim_lease("k", "w2", ttl=30.0) is None   # live elsewhere
    assert led.heartbeat_lease("k", doc) is True
    led.release_lease("k", doc)
    assert led.read_lease("k") is None
    doc2 = led.claim_lease("k", "w2", ttl=30.0)
    assert doc2 is not None and doc2["takeover_of"] is None


def test_expired_lease_taken_over_stale_heartbeat_rejected():
    led = RunLedger("exp")
    led.open({"grid_hash": "h"})
    doc = led.claim_lease("k", "w1", ttl=0.05)
    assert doc is not None
    time.sleep(0.12)
    assert led.leases()[0]["expired"]
    took = led.claim_lease("k", "w2", ttl=30.0)
    assert took is not None and took["takeover_of"] == "w1"
    # the original holder is fenced out: heartbeat and release both
    # see a foreign nonce and back off without touching the new lease
    assert led.heartbeat_lease("k", doc) is False
    led.release_lease("k", doc)
    assert led.read_lease("k")["worker"] == "w2"


def test_racing_claims_exactly_one_winner():
    """The unit-level mutual-exclusion guarantee: N threads claiming the
    same chunk at the same instant — exactly one gets the lease, every
    loser gets None and backs off."""
    led = RunLedger("race")
    led.open({"grid_hash": "h"})
    for rnd in range(6):
        key, nthreads = f"c{rnd}", 4
        barrier = threading.Barrier(nthreads)
        results = {}

        def claim(w):
            barrier.wait()
            results[w] = led.claim_lease(key, w, ttl=30.0)

        threads = [threading.Thread(target=claim, args=(f"w{k}",))
                   for k in range(nthreads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        winners = [w for w, doc in results.items() if doc is not None]
        assert len(winners) == 1, (key, winners)
        loser = next(w for w in results if w not in winners)
        assert led.claim_lease(key, loser, ttl=30.0) is None


def test_worker_exit_fault_leaves_lease_then_takeover():
    """A worker that dies right after claiming (the ``worker.exit``
    site) leaves its lease behind; a later worker takes it over once
    the TTL lapses and finishes the run bit-identically."""
    base = _base()
    with faults.injected("worker.exit@1=raise"):
        with pytest.raises(InjectedFault):
            run_grid(GRID, engine="batched", run_id="wx",
                     coordinate=True, lease_ttl_s=0.2, worker="w1")
    led = RunLedger("wx")
    leases = led.leases()
    assert leases and leases[0]["worker"] == "w1"
    time.sleep(0.25)                       # let the abandoned lease expire
    recs = run_grid(GRID, engine="batched", resume="wx",
                    coordinate=True, lease_ttl_s=0.2, worker="rescuer")
    assert recs == base
    perf = last_batched_perf()
    assert perf["lease_takeovers"] >= 1
    assert perf["lease_claims"] >= 1
    assert json.loads(led.manifest_path.read_text())["status"] == "complete"


# -------------------------------------------- interrupt → resume property

_PROP_BASE = {}    # (backend, jobs) -> uninterrupted records


def _prop_base(backend, jobs):
    if (backend, jobs) not in _PROP_BASE:
        _PROP_BASE[backend, jobs] = run_grid(GRID, engine="batched",
                                             jobs=jobs)
    return _PROP_BASE[backend, jobs]


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=1, max_value=4),
       st.sampled_from(BACKENDS),
       st.sampled_from([1, 2]))
def test_interrupted_run_resumes_bit_identical(kill_after, backend, jobs):
    """Kill a strict run after ``kill_after`` chunk dispatches, resume
    from its ledger: only incomplete chunks re-run, and the final
    records equal the uninterrupted run's bit for bit — across both
    steppers and worker counts, over a limit-sweep grid.

    Environment handling is manual (no monkeypatch): function-scoped
    fixtures don't reset between hypothesis examples."""
    import tempfile
    saved = {k: os.environ.get(k)
             for k in ("REPRO_RUNS_DIR", "REPRO_BATCHED_BACKEND")}
    os.environ["REPRO_RUNS_DIR"] = tempfile.mkdtemp(prefix="repro-led-")
    os.environ["REPRO_BATCHED_BACKEND"] = backend
    try:
        base = _prop_base(backend, jobs)
        run_id = f"prop-{kill_after}-{backend}-{jobs}"
        trigger = f"{kill_after + 1}+"   # let kill_after dispatches pass
        try:
            with faults.injected(f"chunk.dispatch@{trigger}=raise"):
                run_grid(GRID, engine="batched", jobs=jobs, strict=True,
                         run_id=run_id)
        except InjectedFault:
            pass                          # the simulated crash
        recs = run_grid(GRID, engine="batched", jobs=jobs, resume=run_id)
        assert recs == base
        perf = last_batched_perf()
        assert perf["chunks_resumed"] >= min(kill_after, perf["chunks"])
    finally:
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


# ------------------------------- cooperating worker processes (SIGKILL)

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _spawn_worker(run_id, wid, fault_plan=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(_REPO, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    env["REPRO_WORKER_ID"] = wid
    env.pop("REPRO_FAULT_PLAN", None)
    if fault_plan:
        env["REPRO_FAULT_PLAN"] = fault_plan
    return subprocess.Popen(
        [sys.executable, "-m", "repro.runs", "work", run_id,
         "--engine", "batched", "--lease-ttl", "1"],
        cwd=_REPO, env=env, text=True,
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT)


_MW_BASE = {}     # backend -> serial records


def _mw_base(backend):
    if backend not in _MW_BASE:
        _MW_BASE[backend] = run_grid(GRID, engine="batched")
    return _MW_BASE[backend]


@settings(max_examples=2, deadline=None)
@given(st.integers(min_value=2, max_value=3))
def test_multiworker_sigkill_survivors_bit_identical(nworkers):
    """The tentpole property, with real processes: 2–3 workers drain
    one run; the first is SIGKILLed while stalled inside its first
    chunk (holding the lease). Survivors take the lease over and
    finish, and the reassembled records equal a serial run bit for bit
    — on both steppers (looped inside the example: the hypothesis stub
    can't compose with parametrize). Environment handling is manual
    (no monkeypatch): function-scoped fixtures don't reset between
    hypothesis examples."""
    for backend in BACKENDS:
        _multiworker_scenario(backend, nworkers)


def _multiworker_scenario(backend, nworkers):
    import tempfile
    saved = {k: os.environ.get(k)
             for k in ("REPRO_RUNS_DIR", "REPRO_BATCHED_BACKEND",
                       "REPRO_BATCH_TOKEN_BUDGET")}
    os.environ["REPRO_RUNS_DIR"] = tempfile.mkdtemp(prefix="repro-mw-")
    os.environ["REPRO_BATCHED_BACKEND"] = backend
    # small token budget => several chunks, so there is work to steal
    os.environ["REPRO_BATCH_TOKEN_BUDGET"] = "60000"
    procs = []
    try:
        base = _mw_base(backend)
        run_id = f"mw-{backend}-{nworkers}"
        led = RunLedger(run_id)
        led.open({"grid_hash": grid_hash(GRID),
                  "grid_doc": grid_to_doc(GRID),
                  "engine": "batched", "cells": len(base)},
                 status="pending")
        # the victim stalls for 60s inside its first chunk dispatch --
        # exactly the window in which we SIGKILL it, mid-lease
        victim = _spawn_worker(run_id, "victim",
                               fault_plan="chunk.dispatch@1=delay:60")
        procs.append(victim)
        t0 = time.time()
        while time.time() - t0 < 60.0 and not led.leases():
            time.sleep(0.05)
        assert led.leases(), "victim never claimed a chunk"
        os.kill(victim.pid, signal.SIGKILL)
        victim.wait(timeout=60)
        survivors = [_spawn_worker(run_id, f"s{k}")
                     for k in range(nworkers - 1)]
        procs.extend(survivors)
        for p in survivors:
            out, _ = p.communicate(timeout=300)
            assert p.returncode == 0, out
        takeovers = sum(int(d.get("lease_takeovers", 0) or 0)
                        for d in led.worker_summaries())
        assert takeovers >= 1, led.worker_summaries()
        assert json.loads(
            led.manifest_path.read_text())["status"] == "complete"
        # reassembly re-executes nothing and equals the serial run
        recs = run_grid(GRID, engine="batched", resume=run_id)
        assert recs == base
        perf = last_batched_perf()
        assert perf["chunks_resumed"] == perf["chunks"]
        assert perf["stepper_s"] == 0.0
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v
