"""Attention: chunked online-softmax vs full softmax, masks, decode paths."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.configs.base import RunConfig
from repro.models import attention as A
from repro.models import model as M
from repro.parallel.sharding import local_env

ENV = local_env()
CFG = dataclasses.replace(reduced_config("gemma2-2b"), query_scale=0.0)


def _qkv(key, b=2, s=64, hq=4, hkv=2, d=32):
    ks = jax.random.split(key, 3)
    return (jax.random.normal(ks[0], (b, s, hq, d)),
            jax.random.normal(ks[1], (b, s, hkv, d)),
            jax.random.normal(ks[2], (b, s, hkv, d)))


@pytest.mark.parametrize("chunk", [8, 16, 64])
@pytest.mark.parametrize("mask", ["causal", "local", "full"])
def test_chunked_matches_full(chunk, mask):
    q, k, v = _qkv(jax.random.PRNGKey(0))
    cfg = dataclasses.replace(CFG, local_window=16)
    full = A.attention_core(ENV, cfg, q, k, v, mask_kind=mask, chunk=64)
    ch = A.attention_core(ENV, cfg, q, k, v, mask_kind=mask, chunk=chunk)
    np.testing.assert_allclose(full, ch, atol=2e-5)


def test_softcap_changes_output():
    q, k, v = _qkv(jax.random.PRNGKey(1))
    c0 = dataclasses.replace(CFG, attn_logit_softcap=0.0)
    c1 = dataclasses.replace(CFG, attn_logit_softcap=1.0)
    o0 = A.attention_core(ENV, c0, q, k, v, mask_kind="causal")
    o1 = A.attention_core(ENV, c1, q, k, v, mask_kind="causal")
    assert float(jnp.max(jnp.abs(o0 - o1))) > 1e-4


def test_prefix_mask_sees_future_prefix():
    """prefix tokens attend bidirectionally: token0 must differ vs causal."""
    q, k, v = _qkv(jax.random.PRNGKey(2))
    causal = A.attention_core(ENV, CFG, q, k, v, mask_kind="causal")
    prefix = A.attention_core(ENV, CFG, q, k, v, mask_kind="prefix",
                              prefix_len=8)
    assert float(jnp.max(jnp.abs(causal[:, 0] - prefix[:, 0]))) > 1e-5
    # suffix stays causal w.r.t. other suffix tokens + sees whole prefix
    np.testing.assert_allclose(causal[:, -1], prefix[:, -1], atol=1e-5)


def test_ring_cache_equivalent_to_full_for_local():
    """Local attention via ring buffer == local attention via full cache."""
    b, s, hkv, d, w = 1, 24, 2, 16, 8
    key = jax.random.PRNGKey(3)
    k = jax.random.normal(key, (b, s, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(4), (b, s, hkv, d))
    ring_k = jnp.zeros((b, w, hkv, d))
    ring_v = jnp.zeros_like(ring_k)
    ring_k, ring_v = A.write_ring_cache(ring_k, ring_v, k, v)
    q = jax.random.normal(jax.random.PRNGKey(5), (b, 1, 4, d))
    pos = jnp.array([s - 1])
    cfg = dataclasses.replace(CFG, attn_logit_softcap=0.0)
    o_ring = A.decode_attend(ENV, cfg, q, ring_k, ring_v, pos, ring=True,
                             window=w)
    full_k = jnp.zeros((b, s, hkv, d)).at[:, :s].set(k)
    o_full = A.decode_attend(ENV, cfg, q, full_k, v, pos, ring=False,
                             window=w)
    np.testing.assert_allclose(o_ring, o_full, atol=1e-5)


@pytest.mark.parametrize("name", ["gemma2-2b", "recurrentgemma-9b",
                                  "mamba2-2.7b", "seamless-m4t-medium",
                                  "paligemma-3b"])
def test_prefill_decode_consistency_fp32(name):
    """prefill+decode == full forward at fp32 (cache kept fp32)."""
    cfg = reduced_config(name)
    run = RunConfig(remat_policy="none", param_dtype="float32")
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key, run)
    B, S = 2, 20
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = 0.02 * jax.random.normal(
            key, (B, cfg.frontend_len, cfg.d_model), jnp.float32)
    if cfg.is_encoder_decoder:
        batch["src_embeds"] = 0.02 * jax.random.normal(
            key, (B, 16, cfg.d_model), jnp.float32)
    total = S + (cfg.frontend_len if cfg.frontend == "vision" else 0)
    _, cache, pos = M.prefill(ENV, cfg, params, batch, run,
                              max_len=total + 4, kv_dtype=jnp.float32)
    nxt = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0,
                             cfg.vocab_size)
    logits_d, _ = M.decode_step(ENV, cfg, params, nxt, pos + 1, cache, run)
    batch2 = dict(batch, tokens=jnp.concatenate([tokens, nxt], 1))
    x2 = M.forward_train(ENV, cfg, params, batch2, run)
    full = M._logits(ENV, cfg, params, x2[:, -1:])[:, 0]
    np.testing.assert_allclose(logits_d, full, atol=2e-2)
