"""Experiment-runner subsystem: grid expansion, deterministic seeding,
serial == multiprocessing, and the JSON round-trip contract."""
import dataclasses

import pytest

from repro.core.gpu import GPUConfig
from repro.core.interference import DetectorConfig
from repro.core.runner import (ExperimentGrid, expand_grid, load_records,
                               run_grid, save_records, index_records,
                               workload_seed)
from repro.core.simulator import SimConfig

QUICK = ExperimentGrid(name="t", workloads=("syrk",),
                       policies=("gto", "ciao-p"), scale=0.2)


def test_expand_grid_order_and_count():
    grid = ExperimentGrid(
        name="g", workloads=("syrk", "kmn"), policies=("gto", "ciao-c"),
        variants={"a": SimConfig(), "b": SimConfig(dram_gap=4)})
    cells = expand_grid(grid)
    assert len(cells) == 8
    assert [(c.workload, c.policy, c.variant) for c in cells[:3]] == \
        [("syrk", "gto", "a"), ("syrk", "gto", "b"), ("syrk", "ciao-c", "a")]


def test_unknown_workload_rejected():
    with pytest.raises(ValueError):
        expand_grid(ExperimentGrid(name="g", workloads=("nope",),
                                   policies=("gto",)))


def test_workload_seed_stable_across_policies():
    assert workload_seed(0, "syrk") == workload_seed(0, "syrk")
    assert workload_seed(0, "syrk") != workload_seed(1, "syrk")


def test_run_grid_deterministic():
    a = run_grid(QUICK)
    b = run_grid(QUICK)
    assert a == b


def test_json_round_trip_equals_in_memory(tmp_path):
    path = str(tmp_path / "grid.json")
    records = run_grid(QUICK, json_path=path)
    assert load_records(path) == records


def test_serial_matches_multiprocessing():
    serial = run_grid(QUICK, processes=1)
    parallel = run_grid(QUICK, processes=2)
    assert serial == parallel


def test_variants_apply_config():
    grid = ExperimentGrid(
        name="v", workloads=("syrk",), policies=("ciao-c",), scale=0.2,
        variants={"tight": SimConfig(detector=DetectorConfig(
            high_epoch=500, low_epoch=25)),
            "loose": SimConfig(detector=DetectorConfig(
                high_epoch=5000, low_epoch=250))})
    by = index_records(run_grid(grid))
    assert by["syrk", "ciao-c", "tight"].ipc != \
        by["syrk", "ciao-c", "loose"].ipc


def test_gpu_grid_records_per_sm(tmp_path):
    grid = dataclasses.replace(QUICK, policies=("gto",),
                               gpu=GPUConfig(num_sms=2))
    path = str(tmp_path / "gpu.json")
    records = run_grid(grid, json_path=path)
    assert records[0].num_sms == 2
    assert len(records[0].per_sm_ipc) == 2
    assert load_records(path) == records


def test_schema_guard(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text('{"schema": 99, "records": []}')
    with pytest.raises(ValueError, match="schema"):
        load_records(str(path))


def test_pairs_survive_round_trip(tmp_path):
    grid = ExperimentGrid(name="p", workloads=("kmn",),
                          policies=("gto",), scale=0.2)
    path = str(tmp_path / "p.json")
    records = run_grid(grid, json_path=path)
    assert records[0].pairs, "LWS under GTO must produce pair events"
    assert load_records(path)[0].pairs == records[0].pairs
