"""Per-arch configs: registry integrity, analytic param counts vs published
sizes, and the required reduced-config smoke test (one forward/train step on
CPU, output shapes + no NaNs) for every assigned architecture."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, REGISTRY, get_config, reduced_config
from repro.configs.base import RunConfig, ShapeConfig
from repro.configs.shapes import ALL_SHAPES, shapes_for, skipped_shapes_for
from repro.models import model as M
from repro.parallel.sharding import local_env

# published sizes (B params); tolerance covers assignment-vs-release dims
PUBLISHED = {
    "gemma2-2b": 2.6, "nemotron-4-15b": 15.0, "qwen3-4b": 4.0,
    "command-r-35b": 32.0, "recurrentgemma-9b": 8.5, "arctic-480b": 480.0,
    "granite-moe-3b-a800m": 3.3, "paligemma-3b": 2.5, "mamba2-2.7b": 2.7,
    "seamless-m4t-medium": 0.6,
}


def test_registry_complete():
    assert len(ARCH_NAMES) == 10
    assert set(PUBLISHED) == set(ARCH_NAMES)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_param_count_matches_published(name):
    got = get_config(name).param_count() / 1e9
    want = PUBLISHED[name]
    assert got == pytest.approx(want, rel=0.2), f"{name}: {got}B vs {want}B"


def test_active_params_moe():
    arctic = get_config("arctic-480b")
    assert arctic.active_param_count() < 0.05 * arctic.param_count()
    granite = get_config("granite-moe-3b-a800m")
    assert granite.active_param_count() == pytest.approx(0.88e9, rel=0.25)


def test_shape_suite():
    assert len(ALL_SHAPES) == 4
    total_cells = sum(len(shapes_for(get_config(a))) for a in ARCH_NAMES)
    skipped = sum(len(skipped_shapes_for(get_config(a))) for a in ARCH_NAMES)
    assert total_cells + skipped == 40         # the assigned 40-cell grid
    # long_500k runs only for sub-quadratic archs
    for a in ("gemma2-2b", "recurrentgemma-9b", "mamba2-2.7b"):
        assert "long_500k" in [s.name for s in shapes_for(get_config(a))]
    for a in ("nemotron-4-15b", "command-r-35b", "arctic-480b"):
        assert "long_500k" in skipped_shapes_for(get_config(a))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_reduced_smoke_forward_and_train_step(name):
    """REQUIRED smoke: reduced same-family config, one forward + train step,
    asserting output shapes and no NaNs."""
    cfg = reduced_config(name)
    run = RunConfig(remat_policy="none", learning_rate=1e-3,
                    param_dtype="float32")
    env = local_env()
    shape = ShapeConfig(name="smoke", seq_len=32, global_batch=2,
                        mode="train")
    specs = M.input_specs(cfg, shape, run)
    key = jax.random.PRNGKey(0)
    batch = {}
    for k, v in specs.items():
        if v.dtype == jnp.int32:
            batch[k] = jax.random.randint(key, v.shape, 0, cfg.vocab_size)
        else:
            batch[k] = 0.02 * jax.random.normal(key, v.shape, jnp.float32)
    params = M.init_params(cfg, key, run)
    x = M.forward_train(env, cfg, params, batch, run)
    expect_seq = (batch["tokens"].shape[1] +
                  (cfg.frontend_len if cfg.frontend == "vision" else 0))
    assert x.shape == (2, expect_seq, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(x)))

    from repro.train import train_step as TS
    step = TS.make_train_step(cfg, run, env)
    state = TS.init_train_state(cfg, run, key)
    state, metrics = jax.jit(step)(state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
