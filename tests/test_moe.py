"""MoE: shard_map dispatch vs dense oracle; capacity-drop semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import moe as MOE
from repro.parallel.sharding import local_env

ENV = local_env()


def _setup(name, **over):
    cfg = dataclasses.replace(reduced_config(name), **over)
    key = jax.random.PRNGKey(0)
    params, _ = MOE.moe_init(cfg, key, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    return cfg, params, x


@pytest.mark.parametrize("name", ["arctic-480b", "granite-moe-3b-a800m"])
def test_moe_matches_dense_oracle(name):
    """With generous capacity nothing drops: sort-based dispatch == dense."""
    cfg, params, x = _setup(name)
    out = MOE.moe_apply(ENV, cfg, params, x, capacity_factor=8.0)
    ref = MOE.moe_ref(cfg, params, x)
    np.testing.assert_allclose(out, ref, atol=1e-4)


def test_moe_capacity_drops_tokens():
    cfg, params, x = _setup("arctic-480b")
    full = MOE.moe_apply(ENV, cfg, params, x, capacity_factor=8.0)
    tight = MOE.moe_apply(ENV, cfg, params, x, capacity_factor=0.15)
    # dropping changes outputs (some tokens lose expert contributions)
    assert float(jnp.max(jnp.abs(full - tight))) > 1e-5
    # dropped tokens produce zeros, never NaNs
    assert bool(jnp.all(jnp.isfinite(tight)))


def test_moe_grads_flow():
    cfg, params, x = _setup("granite-moe-3b-a800m")

    def loss(p):
        return jnp.sum(MOE.moe_apply(ENV, cfg, p, x, capacity_factor=8.0) ** 2)

    g = jax.grad(loss)(params)
    gn = sum(float(jnp.sum(jnp.abs(v))) for v in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
