"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on 1 CPU device;
only launch/dryrun.py forces the 512 placeholder devices (in its own
process)."""
import os
import tempfile

import jax
import pytest

# Isolate the on-disk workload cache (repro.core.runner): it is keyed by
# (name, seed, scale) only, so a stale results/workloads/ entry from
# before a generator edit would silently feed old traces into the suite.
# A fresh per-session directory keeps tests self-contained. The shipped
# curated set is skipped for the same reason (generator edits must be
# exercised); tests/test_workloads.py re-enables it explicitly to verify
# the manifest.
os.environ["REPRO_WORKLOAD_CACHE_DIR"] = tempfile.mkdtemp(
    prefix="repro-wl-cache-")
os.environ["REPRO_NO_CURATED"] = "1"

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    # dev dependency (pyproject [test] extra); sandboxes without it get a
    # deterministic no-shrink stand-in so the property tests still run.
    from repro._compat import hypothesis_stub
    hypothesis_stub.install()

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def env():
    from repro.parallel.sharding import local_env
    return local_env()


@pytest.fixture(scope="session")
def run32():
    from repro.configs.base import RunConfig
    return RunConfig(remat_policy="none", param_dtype="float32")
