"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on 1 CPU device;
only launch/dryrun.py forces the 512 placeholder devices (in its own
process)."""
import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def env():
    from repro.parallel.sharding import local_env
    return local_env()


@pytest.fixture(scope="session")
def run32():
    from repro.configs.base import RunConfig
    return RunConfig(remat_policy="none", param_dtype="float32")
