"""Batched lockstep engine equivalence: both steppers (numpy, C) must
reproduce ``SMSimulator`` bit-for-bit, per cell, in mixed batches.

Three layers of pinning:

* the golden seed-core snapshots (``tests/golden/``) — all seven
  single-SM cells run as ONE heterogeneous batch (mixed workloads,
  policies, policy_kwargs) per backend; every numeric field must match
  the snapshot exactly, like ``tests/test_equivalence.py`` does for the
  scalar core. The 8th (2-SM GPU) cell is covered via the runner
  fallback test below.
* a hypothesis property: a batch-of-1 run is bit-identical to a fresh
  ``SMSimulator`` for random registry workloads × policy families.
* the runner: ``engine="batched"`` / ``"process"`` / ``"auto"`` produce
  equal records on a grid that mixes batchable cells with a multi-SM
  variant (exercising the per-cell fallback), and the Best-SWL limit
  sweep reduces to the same winner.
"""
import dataclasses
import gzip
import json
import pathlib

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import _cstep
from repro.core.batched import (BatchCell, BatchedSMEngine, run_batched,
                                supports_config)
from repro.core.simulator import SimConfig, SMSimulator
from repro.workloads import make_workload

GOLDEN = pathlib.Path(__file__).parent / "golden" / "golden_cells.json.gz"

BACKENDS = ["numpy"] + (["c"] if _cstep.available() else [])


def _golden_sm_cells():
    doc = json.loads(gzip.decompress(GOLDEN.read_bytes()).decode())
    return [c for c in doc["cells"] if c["kind"] == "sm"]


SIM_FIELDS = ("policy", "cycles", "instructions", "ipc", "l1_hit_rate",
              "vta_hits", "mean_active_warps", "timeline", "pairs")


def _assert_matches_golden(result, golden):
    got = dataclasses.asdict(result)
    got["timeline"] = [list(t) for t in got["timeline"]]
    for field in SIM_FIELDS:
        assert got[field] == golden[field], f"mismatch in {field}"
    for key, val in golden["stats"].items():
        assert got["stats"].get(key) == val, f"stat {key!r} mismatch"


@pytest.mark.parametrize("backend", BACKENDS)
def test_golden_cells_one_mixed_batch(backend):
    """All golden single-SM cells as one heterogeneous lockstep batch."""
    cells = _golden_sm_cells()
    wls = {}
    batch = []
    for c in cells:
        key = (c["workload"], c["seed"], c["scale"])
        if key not in wls:
            wls[key] = make_workload(c["workload"], seed=c["seed"],
                                     scale=c["scale"])
        batch.append(BatchCell(wls[key], c["policy"],
                               dict(c["policy_kwargs"])))
    results = run_batched(batch, backend=backend)
    for c, res in zip(cells, results):
        _assert_matches_golden(res, c["result"])


@pytest.mark.skipif(not _cstep.available(),
                    reason=_cstep.unavailable_reason())
def test_backends_agree_on_smem_paths():
    """numpy vs C stepper on the CIAO-P smem redirection + bypass paths
    (migration, smem evictions, statPCAL bypass) in one batch."""
    wl = make_workload("nw", seed=11, scale=0.12)      # 35% smem app
    wl2 = make_workload("syrk", seed=11, scale=0.12)
    cells = [BatchCell(wl, "ciao-p"), BatchCell(wl, "ciao-c"),
             BatchCell(wl2, "statpcal", {"limit": 2}),
             BatchCell(wl2, "ciao-t")]
    a = run_batched(cells, backend="numpy")
    b = run_batched(cells, backend="c")
    assert a == b


def test_unsupported_config_rejected():
    cfg = SimConfig(l2_bank_gap=4)
    assert not supports_config(cfg)
    wl = make_workload("syrk", seed=0, scale=0.05)
    with pytest.raises(ValueError):
        BatchedSMEngine([BatchCell(wl, "gto")], cfg)


@pytest.mark.parametrize("cfg", [
    SimConfig(max_cycles=20_000),               # cycle-cap exit path
    SimConfig(num_warps=16, dep_every=3, max_mlp=2, dram_channels=2),
    SimConfig(dep_every=0),                     # no dependent uses
    SimConfig(l2_bytes=256, dram_channels=0),   # L2/DRAM clamp corners
], ids=["cycle-cap", "small-sm", "no-dep", "clamps"])
def test_config_corners_match_scalar(cfg):
    wl = make_workload("bicg", seed=9, scale=0.15)
    refs = [SMSimulator(wl, p, cfg).run() for p in ("gto", "ciao-c")]
    for backend in BACKENDS:
        got = BatchedSMEngine(
            [BatchCell(wl, "gto"), BatchCell(wl, "ciao-c")], cfg,
            backend=backend).run()
        for r, g in zip(refs, got):
            assert dataclasses.asdict(g) == dataclasses.asdict(r), backend


POLICY_STRAT = st.sampled_from(
    ["gto", "ccws", "best-swl", "statpcal", "ciao-p", "ciao-t", "ciao-c"])
WORKLOAD_STRAT = st.sampled_from(
    ["bicg", "kmn", "syrk", "gesummv", "backprop", "nw", "gather"])


@settings(max_examples=8, deadline=None)
@given(WORKLOAD_STRAT, POLICY_STRAT, st.integers(0, 1000))
def test_batch_of_one_matches_scalar(workload, policy, seed):
    """Property: a batch-of-1 run is bit-identical to SMSimulator."""
    wl = make_workload(workload, seed=seed, scale=0.06)
    kwargs = {"limit": 4} if policy in ("best-swl", "statpcal") else None
    ref = SMSimulator(wl, policy, policy_kwargs=kwargs).run()
    for backend in BACKENDS:
        got = run_batched([BatchCell(wl, policy, kwargs)],
                          backend=backend)[0]
        assert dataclasses.asdict(got) == dataclasses.asdict(ref), backend


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 10**6))
def test_mixed_batch_matches_scalar(seed):
    """Property: cells keep their identity inside a shuffled batch —
    every cell of a mixed batch equals its own scalar run."""
    rng = np.random.default_rng(seed)
    names = ["bicg", "syrk", "kmn", "conv2d"]
    policies = ["gto", "ciao-c", "ciao-p", "ccws"]
    wls = {n: make_workload(n, seed=seed % 997, scale=0.06)
           for n in names}
    cells = []
    for _ in range(6):
        n = names[int(rng.integers(len(names)))]
        p = policies[int(rng.integers(len(policies)))]
        cells.append((n, p))
    batch = [BatchCell(wls[n], p) for n, p in cells]
    for backend in BACKENDS:
        got = run_batched(batch, backend=backend)
        for (n, p), res in zip(cells, got):
            ref = SMSimulator(wls[n], p).run()
            assert dataclasses.asdict(res) == dataclasses.asdict(ref)


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 10**6))
def test_heterogeneous_knob_batch_matches_scalar(seed):
    """Property: per-row config planes — a batch whose cells carry
    different scalar knobs (epoch cutoffs, throttle depths, latencies,
    aging, cycle caps) across every policy family is bit-identical to
    per-cell scalar runs on all available backends."""
    from repro.core import jax_backend
    from repro.core.interference import DetectorConfig
    rng = np.random.default_rng(seed)
    names = ["bicg", "syrk", "kmn", "nw"]
    policies = ["gto", "ccws", "statpcal", "best-swl",
                "ciao-c", "ciao-p", "ciao-t"]
    wls = {n: make_workload(n, seed=seed % 997, scale=0.06)
           for n in names}
    cells, refs = [], []
    for _ in range(5):
        n = names[int(rng.integers(len(names)))]
        p = policies[int(rng.integers(len(policies)))]
        low = int(rng.integers(20, 120))
        cfg = SimConfig(
            lat_dram=int(rng.integers(200, 400)),
            lat_l2=int(rng.integers(60, 160)),
            dram_gap=int(rng.integers(4, 16)),
            max_cycles=int(rng.integers(30_000, 200_000)),
            detector=DetectorConfig(
                low_epoch=low,
                high_epoch=low * int(rng.integers(2, 25)),
                low_cutoff=round(float(rng.uniform(0.1, 0.9)), 2),
                high_cutoff=round(float(rng.uniform(0.1, 0.9)), 2),
                aging_high_epochs=int(rng.integers(0, 4))))
        kwargs = ({"limit": int(rng.integers(2, 12))}
                  if p in ("best-swl", "statpcal") else None)
        cells.append(BatchCell(wls[n], p, kwargs, cfg=cfg))
        refs.append(SMSimulator(wls[n], p, cfg,
                                policy_kwargs=kwargs).run())
    backends = BACKENDS + (["jax"] if jax_backend.available() else [])
    for backend in backends:
        got = run_batched(cells, backend=backend)
        for ref, res in zip(refs, got):
            assert dataclasses.asdict(res) == dataclasses.asdict(ref), \
                backend


# -------------------------------------------------------------- multi-SM
@pytest.mark.parametrize("backend", BACKENDS)
def test_multi_sm_batch_matches_gpusim(backend):
    """A 2-SM shared-L2 batch (mixed policies, incl. the smem and CCWS
    paths) is bit-exact per cell against per-cell GPUSimulator runs —
    the (SM x cell) stacking with shared post-L1 planes must replay the
    chip's slice-interleaved schedule exactly."""
    from repro.core.gpu import GPUConfig, GPUSimulator
    gpu = GPUConfig(num_sms=2)
    wls = {n: make_workload(n, seed=7, scale=0.05)
           for n in ("syrk", "bicg", "nw")}
    cells = [("syrk", "gto"), ("syrk", "ciao-c"), ("bicg", "ccws"),
             ("nw", "ciao-p"), ("bicg", "statpcal")]
    got = BatchedSMEngine([BatchCell(wls[n], p) for n, p in cells],
                          backend=backend, gpu=gpu).run()
    for (n, p), g in zip(cells, got):
        ref = GPUSimulator(wls[n], p, gpu=gpu).run()
        assert dataclasses.asdict(g) == dataclasses.asdict(ref), (n, p)


def test_multi_sm_loose_scheduler_and_partition():
    """The CTA-placement variants (loose scheduler, partitioned
    workload) batch bit-exactly too."""
    from repro.core.gpu import GPUConfig, GPUSimulator
    wl = make_workload("bicg", seed=3, scale=0.05)
    for gpu in (GPUConfig(num_sms=2, cta_scheduler="loose"),
                GPUConfig(num_sms=2, replicate=False)):
        ref = GPUSimulator(wl, "ciao-c", gpu=gpu).run()
        for backend in BACKENDS:
            got = BatchedSMEngine([BatchCell(wl, "ciao-c")],
                                  backend=backend, gpu=gpu).run()[0]
            assert dataclasses.asdict(got) == dataclasses.asdict(ref)


# ---------------------------------------------------------------- runner
def test_runner_engines_agree(tmp_path, monkeypatch):
    """batched == process == auto records, including an MSHR-gated
    variant cell that must fall back to per-cell execution, and Best-SWL
    cells whose offline limit sweep the batched path flattens and
    reduces."""
    monkeypatch.setenv("REPRO_WORKLOAD_CACHE_DIR", str(tmp_path))
    from repro.core.onchip import OnChipConfig
    from repro.core.runner import ExperimentGrid, run_grid
    gated = SimConfig(onchip=OnChipConfig(mshr_gate=True))
    grid = ExperimentGrid(name="t", workloads=("syrk", "kmn"),
                          policies=("gto", "ciao-c", "best-swl"),
                          scale=0.06, best_swl_limits=(2, 8),
                          variants={"base": None, "gated": gated})
    r_proc = run_grid(grid, engine="process")
    r_batch = run_grid(grid, engine="batched")
    r_auto = run_grid(grid, engine="auto")
    assert r_proc == r_batch == r_auto


def test_runner_cutoff_sweep_forms_one_group(tmp_path, monkeypatch):
    """A cutoff × throttle-depth sweep (heterogeneous knobs, one shape
    class) runs as ONE batched group under the relaxed grouping key and
    still matches the per-cell process engine record-for-record."""
    monkeypatch.setenv("REPRO_WORKLOAD_CACHE_DIR", str(tmp_path))
    from repro.core.interference import DetectorConfig
    from repro.core.runner import (ExperimentGrid, last_batched_perf,
                                   run_grid)
    variants = {}
    for cut in (0.25, 0.5, 0.75):
        for le in (40, 80):
            variants[f"c{cut}-e{le}"] = SimConfig(
                detector=DetectorConfig(low_cutoff=cut, low_epoch=le,
                                        high_epoch=le * 20))
    grid = ExperimentGrid(name="sweep", workloads=("syrk", "kmn"),
                          policies=("ciao-c", "best-swl"), scale=0.06,
                          best_swl_limits=(2, 8), variants=variants)
    r_batch = run_grid(grid, engine="batched")
    perf = last_batched_perf()
    assert perf["groups"] == 1            # one shape class, not 6 configs
    monkeypatch.setenv("REPRO_BATCH_GROUPING", "exact")
    r_exact = run_grid(grid, engine="batched")
    assert last_batched_perf()["groups"] == len(variants)
    monkeypatch.delenv("REPRO_BATCH_GROUPING")
    r_proc = run_grid(grid, engine="process")
    assert r_batch == r_exact == r_proc


def test_runner_multi_sm_grid_batches(tmp_path, monkeypatch):
    """A 2-SM shared-L2 grid goes through the batched engine (no
    fallback) and its records equal per-cell execution."""
    monkeypatch.setenv("REPRO_WORKLOAD_CACHE_DIR", str(tmp_path))
    from repro.core.gpu import GPUConfig
    from repro.core.runner import (ExperimentGrid, _batchable,
                                   expand_grid, run_grid)
    gpu_grid = ExperimentGrid(name="t2", workloads=("syrk",),
                              policies=("gto", "ciao-c", "best-swl"),
                              scale=0.06, best_swl_limits=(2, 8),
                              gpu=GPUConfig(num_sms=2))
    assert all(_batchable(c) for c in expand_grid(gpu_grid))
    assert run_grid(gpu_grid, engine="batched") == \
        run_grid(gpu_grid, engine="process")


def test_workload_disk_cache_round_trip(tmp_path, monkeypatch):
    """The on-disk cache returns workloads that simulate identically to
    freshly generated ones (first call writes, second call loads)."""
    monkeypatch.setenv("REPRO_WORKLOAD_CACHE_DIR", str(tmp_path))
    import repro.core.runner as runner
    runner._cached_workload.cache_clear()
    a = runner._cached_workload("syrk", 123, 0.06)
    assert list(tmp_path.glob("*.npz")), "cache file not written"
    runner._cached_workload.cache_clear()
    b = runner._cached_workload("syrk", 123, 0.06)   # disk hit
    ra = SMSimulator(a, "ciao-c").run()
    rb = SMSimulator(b, "ciao-c").run()
    assert dataclasses.asdict(ra) == dataclasses.asdict(rb)
    runner._cached_workload.cache_clear()


def test_numpy_fallback_when_cstep_disabled(monkeypatch):
    """REPRO_NO_CSTEP forces the portable stepper through auto."""
    wl = make_workload("syrk", seed=2, scale=0.05)
    eng = BatchedSMEngine([BatchCell(wl, "gto")], backend="auto")
    monkeypatch.setattr(_cstep, "_lib", None)
    monkeypatch.setattr(_cstep, "_err", "forced off for test")
    res = eng.run()
    assert eng.backend == "numpy"
    ref = SMSimulator(wl, "gto").run()
    assert dataclasses.asdict(res[0]) == dataclasses.asdict(ref)
