"""Workload subsystem: IR compile determinism, token round-trips, the
on-disk format, registry consistency, and the kernel-derived traces."""
import pathlib
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.workloads import (AluBurst, HotLines, Interleave, MemBurst, Mix,
                             PhaseSpec, REGISTRY, ReuseWindow, SharedTable,
                             Stream, WORKLOADS, WorkloadSpec,
                             compile_workload, decode_trace, encode_trace,
                             encode_workload, gather_index_stream,
                             load_workload, make_workload, save_workload,
                             workload_names)
from repro.workloads.registry import WorkloadEntry

DEP_EVERY = 2


def _tokens_of(wl):
    return encode_workload(wl.traces, DEP_EVERY)


# ------------------------------------------------------------- IR compile
def _spec_from(seed_offset, n_inst, mem_rate, hot_count, ws, passes,
               two_phase):
    base = 16 * 1024 * 1024
    warps = tuple(
        (Interleave(n_inst, mem_rate,
                    Mix(0.4, HotLines((w + 1) * base, hot_count),
                        Stream((w + 1) * base + 4 * 1024 * 1024))),
         AluBurst(7),
         Interleave(n_inst // 2, mem_rate,
                    ReuseWindow((w + 1) * base, ws, passes, ws)),
         MemBurst(5, SharedTable(4096)))
        for w in range(4))
    phases = [PhaseSpec(warps, seed_offset)]
    if two_phase:
        phases.append(PhaseSpec(warps, seed_offset + 1))
    return WorkloadSpec("prop", "LWS", tuple(phases), 128)


@settings(max_examples=20)
@given(st.integers(min_value=0, max_value=50),
       st.integers(min_value=10, max_value=400),
       st.floats(min_value=0.0, max_value=1.0),
       st.integers(min_value=1, max_value=4),
       st.sampled_from([256, 512, 1024]),
       st.integers(min_value=1, max_value=8),
       st.booleans())
def test_compile_save_load_round_trip(seed_offset, n_inst, mem_rate,
                                      hot_count, ws, passes, two_phase):
    """compile -> save -> load -> identical token streams, for arbitrary
    IR programs exercising every primitive."""
    spec = _spec_from(seed_offset, n_inst, mem_rate, hot_count, ws, passes,
                      two_phase)
    wl = compile_workload(spec, seed=3)
    assert _tokens_of(wl) == _tokens_of(compile_workload(spec, seed=3))
    with tempfile.TemporaryDirectory() as td:
        path = save_workload(wl, pathlib.Path(td) / "wl")
        loaded = load_workload(path)
    assert loaded.name == wl.name and loaded.klass == wl.klass
    assert loaded.smem_used_bytes == wl.smem_used_bytes
    assert _tokens_of(loaded) == _tokens_of(wl)


@settings(max_examples=20)
@given(st.lists(st.tuples(st.booleans(),
                          st.integers(min_value=0, max_value=1 << 40)),
                min_size=0, max_size=60),
       st.sampled_from([0, 1, 2, 3]))
def test_token_encode_decode_inverse(insts, dep_every):
    """decode_trace inverts encode_trace exactly (dep bit stripped)."""
    kinds = np.asarray([int(m) for m, _ in insts], np.uint8)
    addrs = np.asarray([(a // 128) * 128 if m else 0 for m, a in insts],
                       np.int64)
    toks = encode_trace(kinds, addrs, dep_every)
    k2, a2 = decode_trace(toks)
    assert np.array_equal(k2, kinds)
    assert np.array_equal(a2, addrs)
    assert encode_trace(k2, a2, dep_every) == toks


# ------------------------------------------------- registry + determinism
@pytest.mark.parametrize("name", sorted(WORKLOADS))
def test_every_registered_workload_deterministic_and_scaled(name):
    a = make_workload(name, seed=11, scale=0.25)
    b = make_workload(name, seed=11, scale=0.25)
    for (k1, a1), (k2, a2) in zip(a.traces, b.traces):
        assert np.array_equal(k1, k2) and np.array_equal(a1, a2)
    assert (a.name, a.klass, a.smem_used_bytes, a.n_wrp) == \
        (b.name, b.klass, b.smem_used_bytes, b.n_wrp)
    # a different seed must change the trace content — except flashattn,
    # a purely deterministic tiled-kernel walk with no random component
    if name != "flashattn":
        c = make_workload(name, seed=12, scale=0.25)
        assert any(not np.array_equal(a1, c1)
                   for (_, a1), (_, c1) in zip(a.traces, c.traces))
    # scale really shrinks the trace (atax used to silently ignore it)
    full = make_workload(name, seed=11, scale=1.0)
    assert sum(len(k) for k, _ in a.traces) < \
        sum(len(k) for k, _ in full.traces)


def test_workloads_view_tracks_registry():
    assert dict(WORKLOADS) == {n: e.klass for n, e in REGISTRY.items()}
    assert set(workload_names("derived")) == \
        {"flashattn", "decodeattn", "gather"}
    assert all(WORKLOADS[n] == "KRN" for n in workload_names("derived"))
    # live view: a late registration appears without rebuilding anything
    REGISTRY["_tmp"] = WorkloadEntry("_tmp", "LWS", lambda s, sc: None)
    try:
        assert WORKLOADS["_tmp"] == "LWS" and "_tmp" in WORKLOADS
    finally:
        del REGISTRY["_tmp"]
    assert "_tmp" not in WORKLOADS


def test_unknown_workload_and_duplicate_registration():
    from repro.workloads import register_workload
    with pytest.raises(KeyError, match="unknown workload"):
        make_workload("nope")
    with pytest.raises(ValueError, match="already registered"):
        register_workload("syrk", "SWS", lambda s, sc: None)


def test_traces_shim_reexports():
    from repro.core import traces
    import repro.workloads as w
    assert traces.make_workload is w.make_workload
    assert traces.WORKLOADS is w.WORKLOADS
    assert traces.Workload is w.Workload


# --------------------------------------------------------- on-disk format
def test_format_version_guard(tmp_path):
    import json
    bad = tmp_path / "bad.npz"
    header = json.dumps({"format": 99, "num_warps": 0, "line": 128})
    np.savez(bad, header=np.array(header))
    with pytest.raises(ValueError, match="unsupported workload format"):
        load_workload(bad)


def test_line_size_guard(tmp_path):
    import json
    bad = tmp_path / "bad.npz"
    header = json.dumps({"format": 1, "num_warps": 0, "line": 64})
    np.savez(bad, header=np.array(header))
    with pytest.raises(ValueError, match="line size"):
        load_workload(bad)


def test_content_checksum_round_trip(tmp_path):
    """v2 files carry a CRC-32 over the trace content; a clean
    save→load round trip must verify and reproduce the traces."""
    from repro.workloads.io import _traces_crc, save_workload
    wl = make_workload("syrk", seed=3, scale=0.1)
    path = save_workload(wl, tmp_path / "syrk")
    back = load_workload(path)
    for (k0, a0), (k1, a1) in zip(wl.traces, back.traces):
        assert np.array_equal(k0, k1) and np.array_equal(a0, a1)
    # the checksum hashes values, not storage: lists and arrays agree
    as_arrays = [(np.asarray(k, np.uint8), np.asarray(a, np.int64))
                 for k, a in wl.traces]
    assert _traces_crc(wl.traces) == _traces_crc(as_arrays)


def test_content_checksum_detects_tampering(tmp_path):
    """Flipping one address in a saved file must fail the checksum —
    this is the guard the runner's cache-regeneration path relies on."""
    import json
    from repro.workloads.io import save_workload
    wl = make_workload("syrk", seed=3, scale=0.1)
    path = save_workload(wl, tmp_path / "syrk")
    with np.load(path, allow_pickle=False) as npz:
        arrays = {k: npz[k] for k in npz.files}
    arrays["addrs_0"] = arrays["addrs_0"].copy()
    arrays["addrs_0"][0] ^= 128
    with open(path, "wb") as fh:
        np.savez_compressed(fh, **arrays)
    with pytest.raises(ValueError, match="content checksum"):
        load_workload(path)
    # ...but a v1 file (no crc in the header) still loads untampered
    header = json.loads(str(arrays["header"]))
    del header["crc"]
    header["format"] = 1
    arrays["header"] = np.array(json.dumps(header))
    with open(path, "wb") as fh:
        np.savez_compressed(fh, **arrays)
    assert load_workload(path).name == "syrk"


# --------------------------------------------------------- derived traces
def test_gather_stream_matches_kernel_ref():
    """The gather workload's index stream is a valid input to the
    kernel's cache oracle: irregular (isolated) streams must show far
    worse locality under cache_sim_ref than the windowed regular ones."""
    from repro.kernels.ciao_gather.ref import cache_sim_ref
    indices, streams, iso_map = gather_index_stream(seed=5, scale=0.2)
    stats = cache_sim_ref(indices.astype(np.int32), streams, iso_map,
                          c_main=256, c_iso=64,
                          num_streams=len(iso_map))
    reg = stats[iso_map == 0]
    irr = stats[iso_map == 1]
    hit_rate = lambda s: s[:, 0].sum() / max(s.sum(), 1)
    assert hit_rate(reg) > hit_rate(irr)
    assert irr.sum() > 0 and reg.sum() > 0


def test_derived_workloads_simulate():
    """Kernel-derived workloads run end-to-end under a CIAO policy."""
    from repro.core.simulator import SMSimulator
    for name in workload_names("derived"):
        wl = make_workload(name, seed=1, scale=0.2)
        r = SMSimulator(wl, "ciao-c").run()
        assert r.instructions == sum(len(k) for k, _ in wl.traces[:48])
        assert 0 < r.ipc <= 1.0
        assert r.l1_hit_rate > 0


def test_flashattn_causal_skew():
    """Causal block-skipping: later q-block warps walk more KV tiles."""
    wl = make_workload("flashattn", seed=0, scale=0.5)
    lens = [len(k) for k, _ in wl.traces[:12]]   # head 0's q rows
    assert lens == sorted(lens) and lens[0] < lens[-1]


# ----------------------------------------------------------- curated set
def test_curated_manifest_intact():
    """The shipped curated trace set matches its checksum manifest and
    loads into the same traces the generators produce (cross-machine
    sweeps must see identical workloads)."""
    from repro.workloads import curated
    assert curated.verify_manifest() == []
    files = curated.load_manifest()
    assert files, "curated set must ship at least one workload"
    # spot-check one entry end to end against fresh generation
    name, seed, scale = "syrk", None, curated.DEFAULT_SCALE
    from repro.core.runner import workload_seed
    seed = workload_seed(curated.DEFAULT_SEED, name)
    wl = curated.load_curated(name, seed, scale)
    assert wl is None  # disabled by conftest's REPRO_NO_CURATED
    import os
    os.environ.pop("REPRO_NO_CURATED")
    try:
        wl = curated.load_curated(name, seed, scale)
        ref = make_workload(name, seed=seed, scale=scale)
        assert wl is not None and len(wl.traces) == len(ref.traces)
        for (k0, a0), (k1, a1) in zip(wl.traces, ref.traces):
            assert np.array_equal(k0, k1) and np.array_equal(a0, a1)
    finally:
        os.environ["REPRO_NO_CURATED"] = "1"


def test_curated_checksum_mismatch_raises(tmp_path, monkeypatch):
    """A tampered curated file must fail loudly, not feed stale traces."""
    import json as _json

    from repro.workloads import curated
    monkeypatch.delenv("REPRO_NO_CURATED", raising=False)
    monkeypatch.setenv("REPRO_CURATED_DIR", str(tmp_path))
    fname = "kmn-s1-x0.1.npz"
    (tmp_path / fname).write_bytes(b"not an npz")
    (tmp_path / "MANIFEST.json").write_text(_json.dumps(
        {"version": 1, "files": {fname: "0" * 64}}))
    with pytest.raises(ValueError, match="checksum"):
        curated.load_curated("kmn", 1, 0.1)
    assert curated.verify_manifest(tmp_path) == [
        f"checksum mismatch: {fname}"]
