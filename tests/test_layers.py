"""Primitive-layer unit tests + properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import layers as L


def test_rmsnorm_unit_scale():
    p, _ = L.rmsnorm_init(16)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16)) * 7.0
    y = L.rmsnorm(p, x)
    rms = jnp.sqrt(jnp.mean(y.astype(jnp.float32) ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_rope_preserves_norm_and_relativity():
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 2, 32))
    pos = jnp.arange(8)
    y = L.rope(x, pos, 10000.0)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 32))
    k = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 1, 32))
    def dot_at(i, j):
        qi = L.rope(jnp.broadcast_to(q, (1, 1, 1, 32)), jnp.array([i]), 1e4)
        kj = L.rope(jnp.broadcast_to(k, (1, 1, 1, 32)), jnp.array([j]), 1e4)
        return float(jnp.sum(qi * kj))
    assert dot_at(5, 3) == pytest.approx(dot_at(9, 7), rel=1e-4)


def test_softcap_bounds():
    x = jnp.linspace(-500, 500, 101)
    y = L.softcap(x, 50.0)
    assert float(jnp.max(jnp.abs(y))) <= 50.0
    np.testing.assert_allclose(L.softcap(x, 0.0), x)


def test_conv1d_step_matches_full():
    key = jax.random.PRNGKey(0)
    p, _ = L.conv1d_init(key, 4, 8, jnp.float32)
    x = jax.random.normal(key, (2, 10, 8))
    full = L.conv1d_apply(p, x)
    state = jnp.zeros((2, 3, 8))
    outs = []
    for t in range(10):
        o, state = L.conv1d_step(p, x[:, t], state)
        outs.append(o)
    step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(full, step, atol=1e-5)


@pytest.mark.parametrize("act", ["swiglu", "geglu", "gelu", "squared_relu"])
def test_mlp_variants(act, env):
    p, specs = L.mlp_init(jax.random.PRNGKey(0), 16, 32, act, jnp.float32)
    assert ("w_gate" in p) == (act in ("swiglu", "geglu"))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 16))
    y = L.mlp_apply(env, p, x, act)
    assert y.shape == x.shape
    assert jnp.all(jnp.isfinite(y))


@given(st.integers(2, 64), st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_embed_roundtrip_shapes(vocab, dm):
    p, _ = L.embed_init(jax.random.PRNGKey(0), vocab, dm * 8, jnp.float32)
    assert p["table"].shape == (vocab, dm * 8)
