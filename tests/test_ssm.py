"""SSD (mamba2) and RG-LRU: chunk invariance + step/full consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import rglru as R
from repro.models import ssd as S
from repro.parallel.sharding import local_env

ENV = local_env()


def test_ssd_chunk_invariance():
    cfg = reduced_config("mamba2-2.7b")
    params, _ = S.ssd_init(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    outs = []
    for chunk in (4, 8, 16, 32):
        c = dataclasses.replace(cfg, ssm_chunk=chunk)
        outs.append(S.ssd_forward(ENV, c, params, x))
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, atol=3e-4)


def test_ssd_step_matches_forward():
    cfg = reduced_config("mamba2-2.7b")
    params, _ = S.ssd_init(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (1, 12, cfg.d_model))
    full = S.ssd_forward(ENV, cfg, params, x)
    h = jnp.zeros((1, cfg.ssm_num_heads, cfg.ssm_head_dim,
                   cfg.ssm_state_dim))
    conv = jnp.zeros((1, cfg.conv_width - 1,
                      cfg.d_inner + 2 * cfg.ssm_state_dim))
    outs = []
    state = (h, conv)
    for t in range(12):
        o, state = S.ssd_step(ENV, cfg, params, x[:, t:t + 1], state)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(full, step, atol=3e-4)


def test_rglru_chunk_invariance_and_step():
    cfg = reduced_config("recurrentgemma-9b")
    params, _ = R.rglru_init(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_model))
    o1 = R.rglru_forward(ENV, cfg, params, x, chunk=4)
    o2 = R.rglru_forward(ENV, cfg, params, x, chunk=24)
    np.testing.assert_allclose(o1, o2, atol=1e-5)

    rw = cfg.rglru_width or cfg.d_model
    state = (jnp.zeros((2, rw)), jnp.zeros((2, cfg.conv_width - 1, rw)))
    outs = []
    for t in range(24):
        o, state = R.rglru_step(ENV, cfg, params, x[:, t:t + 1], state)
        outs.append(o)
    np.testing.assert_allclose(o1, jnp.concatenate(outs, 1), atol=1e-4)


def test_rglru_decay_bounded():
    """RG-LRU state stays bounded (|a|<1 contraction) under long input."""
    cfg = reduced_config("recurrentgemma-9b")
    params, _ = R.rglru_init(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 256, cfg.d_model))
    out, (h, _) = R.rglru_forward(ENV, cfg, params, x, return_state=True)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(jnp.max(jnp.abs(h))) < 1e3
