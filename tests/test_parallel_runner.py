"""Parallel chunk scheduler determinism: ``run_grid(engine="batched",
jobs=k)`` must return records *identical* to the serial run for every
worker count, both steppers, single- and multi-SM grids — execution
order, chunk sharding, and thread interleaving may never leak into
results. Plus the streaming/memory-budget contract: a tiny
``$REPRO_BATCH_TOKEN_BUDGET`` forces many small engines whose records
still match and whose concurrent plane footprint stays below the
one-big-engine peak.
"""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import _cstep
from repro.core.runner import (ExperimentGrid, batch_workers,
                               last_batched_perf, run_grid)

BACKENDS = ["numpy"] + (["c"] if _cstep.available() else [])

GRID = ExperimentGrid(name="par", workloads=("syrk", "kmn", "bicg"),
                      policies=("gto", "ciao-c", "best-swl"),
                      scale=0.06, best_swl_limits=(2, 8))


def _ms_grid():
    from repro.core.gpu import GPUConfig
    return ExperimentGrid(name="par2sm", workloads=("syrk", "bicg"),
                          policies=("gto", "ciao-c"), scale=0.05,
                          gpu=GPUConfig(num_sms=2))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("jobs", [1, 2, 4])
def test_jobs_identity_single_sm(backend, jobs, monkeypatch):
    monkeypatch.setenv("REPRO_BATCHED_BACKEND", backend)
    serial = run_grid(GRID, engine="batched")
    got = run_grid(GRID, engine="batched", jobs=jobs)
    perf = last_batched_perf()
    assert got == serial
    assert perf["workers"] == jobs
    if jobs > 1:
        # sharding must actually produce work for the pool
        assert perf["chunks"] >= min(jobs, len(serial))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("jobs", [2, 4])
def test_jobs_identity_multi_sm(backend, jobs, monkeypatch):
    monkeypatch.setenv("REPRO_BATCHED_BACKEND", backend)
    grid = _ms_grid()
    serial = run_grid(grid, engine="batched")
    assert run_grid(grid, engine="batched", jobs=jobs) == serial


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 10**6), st.integers(2, 5))
def test_jobs_identity_property(seed, jobs):
    """Property: worker-count independence holds for arbitrary trace
    seeds, not just the pinned grid above."""
    grid = ExperimentGrid(name="parh", workloads=("syrk", "gesummv"),
                          policies=("gto", "ccws", "ciao-c"),
                          scale=0.05, seed=seed)
    assert run_grid(grid, engine="batched", jobs=jobs) == \
        run_grid(grid, engine="batched", jobs=1)


@pytest.mark.parametrize("backend", BACKENDS)
def test_tiny_budget_streams_chunks(backend, monkeypatch):
    """A tiny token budget must split the grid into many engines
    (streaming) without changing records, and the concurrent plane
    high-water mark must drop below the one-big-engine footprint."""
    monkeypatch.setenv("REPRO_BATCHED_BACKEND", backend)
    serial = run_grid(GRID, engine="batched")
    big = last_batched_perf()
    assert big["chunks"] == big["batches"] >= 1
    monkeypatch.setenv("REPRO_BATCH_TOKEN_BUDGET", "20000")
    streamed = run_grid(GRID, engine="batched")
    perf = last_batched_perf()
    assert streamed == serial
    assert perf["chunks"] > big["chunks"]
    # bounded engine count: one chunk per flattened subcell at worst
    n_sub = sum(len(GRID.best_swl_limits) if p == "best-swl" else 1
                for p in GRID.policies for _ in GRID.workloads)
    assert perf["chunks"] <= n_sub
    assert 0 < perf["peak_token_plane_bytes"] \
        < big["peak_token_plane_bytes"]


def test_tiny_budget_parallel_identity(monkeypatch):
    """Streaming and the thread pool compose: small chunks over 3
    workers still reassemble to the serial records."""
    serial = run_grid(GRID, engine="batched")
    monkeypatch.setenv("REPRO_BATCH_TOKEN_BUDGET", "20000")
    assert run_grid(GRID, engine="batched", jobs=3) == serial


def test_workers_env_knob(monkeypatch):
    assert batch_workers(None) == 1
    assert batch_workers(3) == 3
    monkeypatch.setenv("REPRO_BATCH_WORKERS", "2")
    assert batch_workers(None) == 2
    assert batch_workers(4) == 4          # explicit argument wins
    run_grid(GRID, engine="batched")      # jobs unset -> env applies
    assert last_batched_perf()["workers"] == 2


def test_numpy_rounds_reported(monkeypatch):
    """The numpy stepper reports real pause-drain rounds (the old
    scheme always left rounds == 0) and its drain time is accounted
    disjointly from stepper time."""
    monkeypatch.setenv("REPRO_BATCHED_BACKEND", "numpy")
    run_grid(GRID, engine="batched")
    perf = last_batched_perf()
    assert perf["rounds"] >= 1
    assert perf["drain_s"] >= 0.0
    assert perf["stepper_s"] > 0.0
