"""Cross-pod gradient compression with error feedback (pjit-native).

On a multi-pod mesh the ``pod`` axis rides the slowest links, so the
per-step gradient exchange across pods dominates the collective term. We
compress that hop only:

  1. the batch is split ``(npod, B/npod, ...)`` and per-pod gradients are
     taken with ``vmap(grad)`` — intra-pod reduction (data/model axes)
     stays full precision, handled by GSPMD;
  2. each pod quantizes its gradient shard to **int8 + per-tensor fp32
     scale** (plus the error-feedback residual from the previous step);
  3. an ``optimization_barrier`` pins the quantization *before* the
     resharding constraint, so GSPMD's all-gather over ``pod`` carries s8
     on the wire (verified in the compiled HLO: ``all-gather(s8[...])``);
  4. pods dequantize and average; the quantization residual is carried in
     an error-feedback accumulator (EF-SGD, Seide et al.) so compression
     is unbiased over time.

Bytes on the pod hop: 1 byte/param instead of 4 — a 4x reduction of the
inter-pod collective term.

NOTE an earlier implementation used a partial-manual ``shard_map`` over
``pod``; that path crashes XLA CPU 0.8.x natively during SPMD partitioning
and was replaced by this constraint-driven formulation, which compiles and
*runs* on every mesh we test.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


def quantize_int8(x, axes=None) -> Tuple[jax.Array, jax.Array]:
    if axes is None:
        scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    else:
        scale = jnp.max(jnp.abs(x), axis=axes, keepdims=True) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def pod_mean_compressed(grads_p, err, mesh, shardings=None):
    """Mean per-pod gradients over ``pod`` in int8 with error feedback.

    grads_p/err: pytrees whose leaves carry a leading ``npod`` dim sharded
    over the ``pod`` mesh axis. ``shardings`` (optional, same tree shape):
    the pod-stacked shardings to preserve on dims 1.. — without them the
    constraints would replicate the intra-pod grad shards and blow up the
    exchange. Returns (mean_grads, new_err)."""
    def one(g, e, sh):
        pod_sh = sh if sh is not None else NamedSharding(mesh, P("pod"))
        spec = pod_sh.spec
        rep_spec = P(*((None,) + tuple(spec)[1:]))
        rep_sh = NamedSharding(mesh, rep_spec)
        g = jax.lax.with_sharding_constraint(
            g.astype(jnp.float32), pod_sh) + e
        axes = tuple(range(1, g.ndim))
        q, scale = quantize_int8(g, axes=axes)
        q = jax.lax.with_sharding_constraint(q, pod_sh)
        q, scale = jax.lax.optimization_barrier((q, scale))
        new_e = g - dequantize_int8(q, scale)
        q_rep = jax.lax.with_sharding_constraint(q, rep_sh)
        s_rep = jax.lax.with_sharding_constraint(
            scale, NamedSharding(mesh, P(None)))
        mean = jnp.mean(dequantize_int8(q_rep, s_rep), axis=0)
        return mean, new_e

    flat_g, td = jax.tree.flatten(grads_p)
    flat_e = td.flatten_up_to(err)
    flat_sh = (td.flatten_up_to(shardings) if shardings is not None
               else [None] * len(flat_g))
    out = [one(g, e, s) for g, e, s in zip(flat_g, flat_e, flat_sh)]
    return td.unflatten([o[0] for o in out]), td.unflatten([o[1] for o in out])


def init_error_feedback(params, npod: int = 1):
    return jax.tree.map(
        lambda p: jnp.zeros((npod,) + p.shape, jnp.float32), params)
