"""Logical-axis sharding: names -> mesh axes -> NamedSharding/constraints.

Model code never names physical mesh axes; it annotates arrays with logical
axis names ("act_batch", "p_heads", ...). A :class:`ShardEnv` resolves those
through a *rules* table onto whatever mesh is active, silently dropping
physical axes the mesh doesn't have (so the same model code runs on the
1-device CPU test mesh, the 256-chip single pod and the 512-chip pod pair).

Baseline parallelism (the §Perf baseline; hillclimbs edit rules):
  * FSDP: weight "p_embed"/"p_ff_in" dims over ``data``
  * TP:   heads / mlp hidden / vocab / experts over ``model``
  * DP:   activation batch over ``pod`` + ``data``
  * SP (decode): KV-cache sequence over ``model`` (flash-decode style
    partial-softmax combine is induced by GSPMD)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Union[None, str, Tuple[str, ...]]

# ---------------------------------------------------------------------------
# Rule tables. Keys are logical axis names; values are physical mesh axes.
# ---------------------------------------------------------------------------
DEFAULT_RULES: Dict[str, Axes] = {
    # --- activations ---
    "act_batch": ("pod", "data"),
    "act_seq": None,
    "act_kv_seq": None,
    "act_heads": "model",
    "act_kv_heads": "model",
    "act_embed": None,
    "act_mlp": "model",
    "act_vocab": "model",
    "act_experts": "model",
    "act_inner": "model",       # ssm/rglru recurrent width
    # --- params ---
    "p_vocab": "model",
    "p_embed": "data",          # FSDP shard of the model dim
    "p_heads": "model",
    "p_mlp": "model",
    "p_experts": "model",       # EP (arctic)
    "p_expert_ff": None,        # per-expert ff; "model" in TP-expert mode
    "p_ff_in": "data",          # FSDP shard of FFN input dim
    "p_inner": "model",         # ssm/rglru inner width
    "p_state": None,
    "layers": None,
    "p_none": None,
    "pod_stack": "pod",         # leading per-pod dim (compression err state)
}

# Decode: batch stays on data, KV sequence sharded over model (SP); heads
# replicated (kv_heads < model size for every assigned arch).
DECODE_RULES: Dict[str, Axes] = {
    **DEFAULT_RULES,
    "act_heads": None,
    "act_kv_heads": None,
    "act_kv_seq": "model",
    "act_mlp": "model",
}

# long_500k: batch=1 -> nothing for data/pod to do on activations; spread the
# half-million-token KV across every chip.
LONG_DECODE_RULES: Dict[str, Axes] = {
    **DECODE_RULES,
    "act_batch": None,
    "act_kv_seq": ("pod", "data", "model"),
}

RULE_SETS = {
    "train": DEFAULT_RULES,
    "prefill": DEFAULT_RULES,
    "decode": DECODE_RULES,
    "long_decode": LONG_DECODE_RULES,
}


@dataclasses.dataclass(frozen=True)
class ShardEnv:
    """Mesh + logical rules, threaded through model code."""

    mesh: Mesh
    rules: Mapping[str, Axes]

    # -- resolution --------------------------------------------------------
    def _resolve(self, name: Optional[str]) -> Axes:
        if name is None:
            return None
        if name not in self.rules:
            raise KeyError(f"unknown logical axis {name!r}")
        axes = self.rules[name]
        if axes is None:
            return None
        if isinstance(axes, str):
            axes = (axes,)
        present = tuple(a for a in axes if a in self.mesh.axis_names)
        if not present:
            return None
        return present if len(present) > 1 else present[0]

    def _fit(self, axes: Axes, dim: int) -> Axes:
        """Drop trailing mesh axes until ``dim`` is divisible by the shard
        product (kv_heads=4 cannot shard 16 ways; vocab 49155 is odd; ...)."""
        if axes is None:
            return None
        tup = (axes,) if isinstance(axes, str) else tuple(axes)
        while tup:
            prod = 1
            for a in tup:
                prod *= self.mesh.shape[a]
            if dim % prod == 0:
                break
            tup = tup[:-1]
        if not tup:
            return None
        return tup if len(tup) > 1 else tup[0]

    def pspec(self, *logical: Optional[str], shape=None) -> P:
        axes = [self._resolve(n) for n in logical]
        if shape is not None:
            axes = [self._fit(a, d) for a, d in zip(axes, shape)]
        # a mesh axis may appear in at most one dimension: first one wins
        # (lets rule overrides like act_seq=model coexist with act_mlp=model)
        used: set = set()
        deduped = []
        for a in axes:
            tup = () if a is None else ((a,) if isinstance(a, str) else tuple(a))
            kept = tuple(x for x in tup if x not in used)
            used.update(kept)
            if not kept:
                deduped.append(None)
            elif len(kept) == 1:
                deduped.append(kept[0])
            else:
                deduped.append(kept)
        return P(*deduped)

    def sharding(self, *logical: Optional[str], shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.pspec(*logical, shape=shape))

    def constrain(self, x, *logical: Optional[str]):
        """with_sharding_constraint by logical names ('' / None = replicated dim)."""
        if self.mesh.empty or self.mesh.size == 1:
            return x
        names = [n if n else None for n in logical]
        return jax.lax.with_sharding_constraint(
            x, self.sharding(*names, shape=x.shape))

    # -- axis sizes ---------------------------------------------------------
    def axis_size(self, *axes: str) -> int:
        n = 1
        for a in axes:
            if a in self.mesh.axis_names:
                n *= self.mesh.shape[a]
        return n

    @property
    def tp(self) -> int:
        return self.axis_size("model")

    @property
    def dp(self) -> int:
        return self.axis_size("pod", "data")

    @property
    def fsdp(self) -> int:
        return self.axis_size("data")

    def with_rules(self, overrides: Mapping[str, Axes]) -> "ShardEnv":
        merged = dict(self.rules)
        merged.update(overrides)
        return dataclasses.replace(self, rules=merged)

    def without_axes(self, *axes: str) -> "ShardEnv":
        """Strip mesh axes from every rule — needed inside shard_map bodies
        that are Manual over those axes (constraints may only name Auto
        axes)."""
        drop = set(axes)

        def strip(v: Axes) -> Axes:
            if v is None:
                return None
            tup = (v,) if isinstance(v, str) else tuple(v)
            kept = tuple(a for a in tup if a not in drop)
            if not kept:
                return None
            return kept if len(kept) > 1 else kept[0]

        return dataclasses.replace(
            self, rules={k: strip(v) for k, v in self.rules.items()})


def make_env(mesh: Mesh, mode: str = "train",
             overrides: Sequence[Tuple[str, Axes]] = ()) -> ShardEnv:
    rules = dict(RULE_SETS[mode])
    for k, v in overrides:
        rules[k] = v
    return ShardEnv(mesh=mesh, rules=rules)


def local_env(mode: str = "train") -> ShardEnv:
    """1-device env for CPU tests: constraints become no-ops, shard_map still
    runs (all axes size 1)."""
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(dev, ("data", "model"))
    return make_env(mesh, mode)


def is_spec_leaf(x) -> bool:
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)


def tree_shardings(env: ShardEnv, logical_tree, struct_tree=None) -> Any:
    """Map a pytree of logical-axis tuples to NamedShardings.

    With ``struct_tree`` (matching ShapeDtypeStructs/arrays), resolution is
    divisibility-aware per dimension — required for jit in_shardings, which
    reject uneven sharding."""
    if struct_tree is None:
        return jax.tree.map(lambda spec: env.sharding(*spec),
                            logical_tree, is_leaf=is_spec_leaf)

    flat_specs, treedef = jax.tree.flatten(logical_tree, is_leaf=is_spec_leaf)
    flat_structs = treedef.flatten_up_to(struct_tree)
    out = []
    for spec, st in zip(flat_specs, flat_structs):
        shape = getattr(st, "shape", ())
        if len(spec) != len(shape):
            spec = tuple(spec[:len(shape)]) + (None,) * max(
                0, len(shape) - len(spec))
        out.append(env.sharding(*spec, shape=shape))
    return treedef.unflatten(out)
