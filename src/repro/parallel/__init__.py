from repro.parallel.sharding import (  # noqa: F401
    DEFAULT_RULES,
    DECODE_RULES,
    LONG_DECODE_RULES,
    ShardEnv,
    make_env,
    local_env,
)
