"""Workloads derived from the repo's real JAX/Pallas kernels.

The synthetic families (:mod:`repro.workloads.synthetic`) parametrize the
paper's benchmark classes; these workloads instead *walk the actual
access patterns* of the kernels under ``src/repro/kernels``, turning each
kernel's grid + BlockSpec index maps (and, for the gather, its ref
implementation's index stream) into per-warp address streams the
simulator can schedule. Related contention studies evaluate on real
kernel streams precisely because synthetic traces under-represent phase
behavior and inter-warp skew; registering these alongside the synthetic
families lets every CIAO policy sweep run over them with zero new
plumbing (``ExperimentGrid`` / ``benchmarks.run`` just name them).

All three are pure-numpy walks of the kernels' index maps — no jax import
(grid fan-out workers must not pay XLA startup). Addresses are modeled at
cache-line granularity: one table/tensor row of 32 fp32 (128B) = one
line, so a Pallas block of ``b`` rows is ``b`` consecutive lines.

* ``flashattn`` — :mod:`repro.kernels.flash_attn.kernel`: grid
  ``(BH, num_q_blocks, num_kv_blocks)``, KV innermost; index maps
  ``q -> (bh, qi)``, ``k/v -> (bh, ki)``; causal tiles above the diagonal
  are skipped (the ``pl.when`` guard). One warp per (bh, q-block) row:
  its Q tile is re-read every KV step (private reuse — SWS-like), while
  warps of the same head stream the *same* K/V tiles (shared lines with
  skewed overlap: late q-rows touch many more tiles than early ones).
* ``decodeattn`` — :mod:`repro.kernels.decode_attn.kernel`: grid
  ``(BH, num_kv_blocks)``; one warp per head; the single q row is hot,
  the per-head KV cache streams once (LWS-like), and per-sequence
  ``lengths`` skew makes long-context heads the heavy interferers.
* ``gather`` — :mod:`repro.kernels.ciao_gather.ref.cache_sim_ref`'s
  index stream: per-stream (= per-warp) gathers into one shared table;
  most streams walk strided windows with re-reference, a few *irregular*
  streams hammer uniform-random rows — the SpMV/KMeans index-array
  pattern of §VI that CIAO isolates.

``make_workload("flashattn"|"decodeattn"|"gather", seed, scale)`` builds
them like any other workload; ``scale`` shrinks tile sizes / sequence
lengths rather than warp count, so contention structure survives at
smoke scales.

All three walks take an optional ``jitter`` knob (default 0.0 — **off is
bit-exact**: no RNG stream is consumed, pinned by the golden cells and
``tests/test_workloads.py``). Kernel-derived traces issue dense
``MemBurst`` runs whose every 2nd op is a dependent use under
``dep_every=2``, so with synchronized arrival the warps' MLP is capped
in lockstep — one suspected cause of the PR-3 ranking gap (ROADMAP:
derived traces favor GTO, tau ≈ -0.24). ``jitter=f`` prepends each warp
a private ALU burst drawn uniformly from ``[0, f ×  warp-instructions)``
(a dedicated RNG stream, so the walk itself is unchanged), staggering
warp arrival the way real CTA rasterization does. The registry exposes
jittered twins (``flashattn-jit`` etc., origin ``derived-jit``,
``jitter=0.25``) and ``benchmarks/bench_workloads.py`` sweeps them as a
third group next to synthetic/derived.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.workloads.ir import (AluBurst, Explicit, MemBurst, PhaseSpec,
                                SMEM_TOTAL, Workload, WorkloadSpec,
                                compile_workload)
from repro.workloads.registry import register_workload
from repro.workloads.tokens import LINE

__all__ = ["flashattn_workload", "decodeattn_workload", "gather_workload",
           "gather_index_stream"]

# distinct tensor bases, far apart (same spirit as the synthetic bases)
_Q_BASE = 1 << 30
_K_BASE = 2 << 30
_V_BASE = 3 << 30
_TABLE_BASE = 4 << 30


def _lines(base: int, start_row: int, rows: int) -> np.ndarray:
    return base + LINE * (start_row + np.arange(rows, dtype=np.int64))


def _jitter_rng(seed: int, jitter: float):
    """Dedicated arrival-jitter stream: None when the knob is off, so a
    ``jitter=0`` build consumes no RNG and stays bit-exact."""
    return np.random.default_rng([seed, 0x6A17]) if jitter else None


def _with_jitter(segs: List, rng, jitter: float) -> Tuple:
    """Prepend a per-warp ALU burst of up to ``jitter`` × the warp's own
    instruction count, staggering arrival like CTA rasterization."""
    if rng is None:
        return tuple(segs)
    n_inst = sum(s.n for s in segs)
    skew = int(rng.integers(0, max(1, int(jitter * n_inst))))
    if skew:
        segs = [AluBurst(skew)] + segs
    return tuple(segs)


# ------------------------------------------------------------- flash attn
def flashattn_workload(seed: int = 0, scale: float = 1.0, *,
                       heads: int = 4, q_blocks: int = 12,
                       block_rows: int = 16, causal: bool = True,
                       window_blocks: int = 0,
                       jitter: float = 0.0) -> Workload:
    """One warp per (head, q-block) grid row (heads * q_blocks warps).

    Walks the kernel's KV-innermost grid: warp (h, qi) re-reads its Q
    tile and streams K/V tiles ki = 0..qi (causal block skipping, or a
    ``window_blocks`` local band), with an ALU burst per tile for the two
    MXU matmuls + online-softmax update.
    """
    if window_blocks and not causal:
        # the kernel only honors `window` under causal masking
        raise ValueError("window_blocks requires causal=True")
    rows = max(2, int(block_rows * scale))
    seq_rows = q_blocks * rows
    rng_j = _jitter_rng(seed, jitter)
    warps: List[Tuple] = []
    for h in range(heads):
        for qi in range(q_blocks):
            q_tile = _lines(_Q_BASE, h * seq_rows + qi * rows, rows)
            lo = 0
            hi = qi if causal else q_blocks - 1
            if window_blocks:
                lo = max(0, qi - window_blocks + 1)
            segs = []
            for ki in range(lo, hi + 1):
                k_tile = _lines(_K_BASE, h * seq_rows + ki * rows, rows)
                v_tile = _lines(_V_BASE, h * seq_rows + ki * rows, rows)
                step = np.concatenate([q_tile, k_tile, v_tile])
                segs.append(MemBurst(len(step), Explicit.of(step)))
                segs.append(AluBurst(3 * rows))
            warps.append(_with_jitter(segs, rng_j, jitter))
    spec = WorkloadSpec(
        "flashattn", "KRN", (PhaseSpec(tuple(warps)),),
        smem_used_bytes=int(0.50 * SMEM_TOTAL),   # (m, l, acc) scratch
        apki=500)
    return compile_workload(spec, seed)


# ------------------------------------------------------------ decode attn
def decodeattn_workload(seed: int = 0, scale: float = 1.0, *,
                        num_heads: int = 48, block_rows: int = 16,
                        base_blocks: int = 10,
                        long_every: int = 6, long_factor: int = 4,
                        jitter: float = 0.0) -> Workload:
    """One warp per (batch*head) grid row. Per-sequence KV lengths are
    skewed: every ``long_every``-th head serves a ``long_factor``x longer
    context (the straggler sequences of a serving batch) — those heads
    stream far more KV lines and become the Fig. 4-style heavy
    interferers."""
    rng = np.random.default_rng(seed)
    rng_j = _jitter_rng(seed, jitter)
    rows = max(2, int(block_rows * scale))
    max_blocks = base_blocks * long_factor
    cache_rows = max_blocks * rows                 # per-head KV stride
    warps: List[Tuple] = []
    for h in range(num_heads):
        blocks = base_blocks if h % long_every else \
            base_blocks * long_factor
        # +/-25% jitter so heads don't finish in lockstep
        blocks = max(1, int(blocks * (0.75 + 0.5 * rng.random())))
        blocks = min(blocks, max_blocks)
        q_line = _lines(_Q_BASE, h, 1)
        segs = []
        for ki in range(blocks):
            k_tile = _lines(_K_BASE, h * cache_rows + ki * rows, rows)
            v_tile = _lines(_V_BASE, h * cache_rows + ki * rows, rows)
            step = np.concatenate([q_line, k_tile, v_tile])
            segs.append(MemBurst(len(step), Explicit.of(step)))
            segs.append(AluBurst(rows))
        warps.append(_with_jitter(segs, rng_j, jitter))
    spec = WorkloadSpec(
        "decodeattn", "KRN", (PhaseSpec(tuple(warps)),),
        smem_used_bytes=int(0.25 * SMEM_TOTAL),   # (m, l, acc) scratch
        apki=600)
    return compile_workload(spec, seed)


# ----------------------------------------------------------------- gather
def gather_index_stream(seed: int = 0, scale: float = 1.0, *,
                        num_streams: int = 48, reqs_per_stream: int = 1500,
                        table_rows: int = 4096, window_rows: int = 12,
                        irregular_every: int = 8
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(indices, streams, iso_map) in ``cache_sim_ref``'s input layout,
    with requests round-robin across streams (the kernel's interleaved
    request order). Regular streams gather strided windows re-referenced
    a few times; every ``irregular_every``-th stream draws uniform-random
    rows over the whole table (the index-array hammering CIAO flags).
    ``iso_map`` marks the irregular streams, matching what the host-side
    detector would feed the kernel."""
    rng = np.random.default_rng(seed)
    t = max(8, int(reqs_per_stream * scale))
    per_stream = []
    iso_map = np.zeros(num_streams, np.int32)
    for s in range(num_streams):
        if irregular_every and s % irregular_every == irregular_every - 1:
            iso_map[s] = 1
            per_stream.append(rng.integers(0, table_rows, t))
        else:
            # strided windows: sweep `window_rows` rows 3x, then jump
            starts = rng.integers(0, table_rows - window_rows,
                                  max(t // (3 * window_rows), 1) + 1)
            walk = np.concatenate([s0 + np.tile(np.arange(window_rows), 3)
                                   for s0 in starts])
            per_stream.append(walk[:t])
    indices = np.empty(num_streams * t, np.int64)
    streams = np.empty(num_streams * t, np.int32)
    for s, idxs in enumerate(per_stream):
        indices[s::num_streams] = idxs
        streams[s::num_streams] = s
    return indices, streams, iso_map


def gather_workload(seed: int = 0, scale: float = 1.0, *,
                    num_streams: int = 48, alu_chunk: int = 64,
                    alu_len: int = 16, jitter: float = 0.0) -> Workload:
    """Per-warp view of the gather kernel: warp w issues stream w's
    requests in order (address = table row * LINE — one 32-fp32 row per
    line), with a short ALU burst every ``alu_chunk`` requests (the
    copy-out / index arithmetic between gathers)."""
    indices, streams, _iso = gather_index_stream(
        seed, scale, num_streams=num_streams)
    rng_j = _jitter_rng(seed, jitter)
    warps: List[Tuple] = []
    for w in range(num_streams):
        addrs = _TABLE_BASE + LINE * indices[streams == w]
        segs = []
        for i in range(0, len(addrs), alu_chunk):
            chunk = addrs[i:i + alu_chunk]
            segs.append(MemBurst(len(chunk), Explicit.of(chunk)))
            segs.append(AluBurst(alu_len))
        warps.append(_with_jitter(segs, rng_j, jitter))
    spec = WorkloadSpec(
        "gather", "KRN", (PhaseSpec(tuple(warps)),),
        smem_used_bytes=0, apki=800)
    return compile_workload(spec, seed)


JITTER_DEFAULT = 0.25


def _register_derived() -> None:
    register_workload("flashattn", "KRN",
                      lambda seed, scale: flashattn_workload(seed, scale),
                      origin="derived")
    register_workload("decodeattn", "KRN",
                      lambda seed, scale: decodeattn_workload(seed, scale),
                      origin="derived")
    register_workload("gather", "KRN",
                      lambda seed, scale: gather_workload(seed, scale),
                      origin="derived")
    # arrival-jittered twins (ROADMAP ranking-gap study, first step):
    # separate origin so the plain derived group is unchanged
    register_workload(
        "flashattn-jit", "KRN",
        lambda seed, scale: flashattn_workload(seed, scale,
                                               jitter=JITTER_DEFAULT),
        origin="derived-jit")
    register_workload(
        "decodeattn-jit", "KRN",
        lambda seed, scale: decodeattn_workload(seed, scale,
                                                jitter=JITTER_DEFAULT),
        origin="derived-jit")
    register_workload(
        "gather-jit", "KRN",
        lambda seed, scale: gather_workload(seed, scale,
                                            jitter=JITTER_DEFAULT),
        origin="derived-jit")


_register_derived()
