"""Versioned on-disk workload format: one ``.npz`` with a JSON header.

Layout (format version 2):

* ``header`` — a JSON string array: ``format`` (int version), ``name``,
  ``klass``, ``smem_used_bytes``, ``n_wrp``, ``apki``, ``num_warps``,
  ``line`` (the cache-line size the addresses assume), and ``crc`` —
  a CRC-32 over every trace array's raw bytes, in warp order.
* ``kinds_<i>`` / ``addrs_<i>`` — per-warp trace arrays (uint8 / int64),
  compressed.

``load_workload`` refuses files written with an unknown format version or
a mismatched line size (addresses are line-aligned byte addresses — a
different ``LINE`` would silently re-shape every cache set index), and
verifies the content checksum so a corrupted cache file (torn write,
bit rot) raises instead of feeding garbage traces into a sweep — the
runner's cache layer deletes and regenerates on that error. Version-1
files (no checksum — the shipped curated set) still load. The
round-trip is exact: ``load_workload(save_workload(wl))`` tokenizes
identically to ``wl`` (property-tested in ``tests/test_workloads.py``).
"""
from __future__ import annotations

import json
import pathlib
import zlib
from typing import Sequence, Tuple, Union

import numpy as np

from repro.workloads.ir import Workload
from repro.workloads.tokens import LINE

FORMAT_VERSION = 2
_READABLE_FORMATS = (1, 2)     # v1 = pre-checksum (curated shipped set)


def _traces_crc(traces: Sequence[Tuple[np.ndarray, np.ndarray]]) -> int:
    """CRC-32 over the trace content (values, not storage): every warp's
    kinds bytes then addrs bytes, in warp order."""
    crc = 0
    for kinds, addrs in traces:
        crc = zlib.crc32(np.ascontiguousarray(kinds, np.uint8), crc)
        crc = zlib.crc32(np.ascontiguousarray(addrs, np.int64), crc)
    return crc & 0xFFFFFFFF


def save_workload(wl: Workload, path: Union[str, pathlib.Path]) -> str:
    """Write ``wl`` to ``path`` (``.npz`` appended if missing)."""
    p = pathlib.Path(path)
    traces = [(np.asarray(kinds, np.uint8), np.asarray(addrs, np.int64))
              for kinds, addrs in wl.traces]
    header = {
        "format": FORMAT_VERSION,
        "name": wl.name,
        "klass": wl.klass,
        "smem_used_bytes": int(wl.smem_used_bytes),
        "n_wrp": int(wl.n_wrp),
        "apki": float(wl.apki),
        "num_warps": len(wl.traces),
        "line": LINE,
        "crc": _traces_crc(traces),
    }
    arrays = {"header": np.array(json.dumps(header, sort_keys=True))}
    for i, (kinds, addrs) in enumerate(traces):
        arrays[f"kinds_{i}"] = kinds
        arrays[f"addrs_{i}"] = addrs
    target = p if p.suffix == ".npz" else pathlib.Path(str(p) + ".npz")
    target.parent.mkdir(parents=True, exist_ok=True)
    with open(target, "wb") as fh:
        np.savez_compressed(fh, **arrays)
    return str(target)


def load_workload(path: Union[str, pathlib.Path]) -> Workload:
    with np.load(pathlib.Path(path), allow_pickle=False) as npz:
        header = json.loads(str(npz["header"]))
        fmt = header.get("format")
        if fmt not in _READABLE_FORMATS:
            raise ValueError(
                f"unsupported workload format {fmt!r} in {path} "
                f"(this build reads versions {_READABLE_FORMATS})")
        if header.get("line", LINE) != LINE:
            raise ValueError(
                f"workload {path} was captured with line size "
                f"{header['line']}, this build uses {LINE}")
        traces = [(npz[f"kinds_{i}"], npz[f"addrs_{i}"])
                  for i in range(header["num_warps"])]
        if "crc" in header:
            got = _traces_crc(traces)
            if got != header["crc"]:
                raise ValueError(
                    f"workload {path} failed its content checksum "
                    f"(stored {header['crc']:#010x}, computed "
                    f"{got:#010x}) — the file is corrupt")
    return Workload(header["name"], header["klass"], traces,
                    header["smem_used_bytes"], header["n_wrp"],
                    header["apki"])
