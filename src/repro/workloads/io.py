"""Versioned on-disk workload format: one ``.npz`` with a JSON header.

Layout (format version 1):

* ``header`` — a JSON string array: ``format`` (int version), ``name``,
  ``klass``, ``smem_used_bytes``, ``n_wrp``, ``apki``, ``num_warps``,
  ``line`` (the cache-line size the addresses assume).
* ``kinds_<i>`` / ``addrs_<i>`` — per-warp trace arrays (uint8 / int64),
  compressed.

``load_workload`` refuses files written with an unknown format version or
a mismatched line size (addresses are line-aligned byte addresses — a
different ``LINE`` would silently re-shape every cache set index). The
round-trip is exact: ``load_workload(save_workload(wl))`` tokenizes
identically to ``wl`` (property-tested in ``tests/test_workloads.py``).
"""
from __future__ import annotations

import json
import pathlib
from typing import Union

import numpy as np

from repro.workloads.ir import Workload
from repro.workloads.tokens import LINE

FORMAT_VERSION = 1


def save_workload(wl: Workload, path: Union[str, pathlib.Path]) -> str:
    """Write ``wl`` to ``path`` (``.npz`` appended if missing)."""
    p = pathlib.Path(path)
    header = {
        "format": FORMAT_VERSION,
        "name": wl.name,
        "klass": wl.klass,
        "smem_used_bytes": int(wl.smem_used_bytes),
        "n_wrp": int(wl.n_wrp),
        "apki": float(wl.apki),
        "num_warps": len(wl.traces),
        "line": LINE,
    }
    arrays = {"header": np.array(json.dumps(header, sort_keys=True))}
    for i, (kinds, addrs) in enumerate(wl.traces):
        arrays[f"kinds_{i}"] = np.asarray(kinds, np.uint8)
        arrays[f"addrs_{i}"] = np.asarray(addrs, np.int64)
    target = p if p.suffix == ".npz" else pathlib.Path(str(p) + ".npz")
    target.parent.mkdir(parents=True, exist_ok=True)
    with open(target, "wb") as fh:
        np.savez_compressed(fh, **arrays)
    return str(target)


def load_workload(path: Union[str, pathlib.Path]) -> Workload:
    with np.load(pathlib.Path(path), allow_pickle=False) as npz:
        header = json.loads(str(npz["header"]))
        fmt = header.get("format")
        if fmt != FORMAT_VERSION:
            raise ValueError(
                f"unsupported workload format {fmt!r} in {path} "
                f"(this build reads version {FORMAT_VERSION})")
        if header.get("line", LINE) != LINE:
            raise ValueError(
                f"workload {path} was captured with line size "
                f"{header['line']}, this build uses {LINE}")
        traces = [(npz[f"kinds_{i}"], npz[f"addrs_{i}"])
                  for i in range(header["num_warps"])]
    return Workload(header["name"], header["klass"], traces,
                    header["smem_used_bytes"], header["n_wrp"],
                    header["apki"])
