"""Token-stream contract between workload traces and the simulator core.

A per-warp trace is a pair ``(kinds uint8, addrs int64)`` — one entry per
instruction, kind 0 = ALU, kind 1 = MEM with a byte address. The array
core (:mod:`repro.core.simulator`) does not consume traces directly: it
dispatches one *token* per scheduler pick, where

* a **negative** token ``-n`` is a batched run of ``n`` ALU instructions,
* a **non-negative** token encodes a memory op as
  ``(byte_address << 1) | dep`` — ``dep`` is the dependent-use bit
  (load-to-use stall) baked in from the ``dep_every`` pattern so the hot
  loop needs no per-op memory-ordinal bookkeeping.

This module owns that encoding. It was extracted verbatim from
``SMSimulator.begin`` (PR 2) so workload generation, on-disk persistence,
and the simulator all share one stable contract; the golden cells of
``tests/test_equivalence.py`` pin it bit-for-bit. Any change to the token
layout must bump the on-disk format version in :mod:`repro.workloads.io`.

``encode_trace`` / ``decode_trace`` are exact inverses on the (kinds,
addrs) representation: decoding reconstructs the full per-instruction
arrays (the dep bit is derivable from ``dep_every`` and is dropped).
"""
from __future__ import annotations

from typing import Iterable, List, Sequence, Tuple

import numpy as np

# Cache-line size shared by trace generation and the cache models. The
# simulator asserts this matches ``repro.core.onchip.LINE`` (the import is
# one-way, core -> workloads, to keep this package dependency-free).
LINE = 128

assert LINE & (LINE - 1) == 0, "LINE must be a power of two"
# token -> line-address shift: the line is (tok >> 1) // LINE, i.e.
# tok >> (1 + log2(LINE))
TOKEN_LINE_SHIFT = 1 + LINE.bit_length() - 1


def encode_trace(kinds, addrs, dep_every: int) -> List[int]:
    """Compile one per-warp trace into its token stream (vectorized).

    Every ``dep_every``-th memory op (1-based) gets the dependent-use bit;
    ``dep_every=0`` disables dependent uses entirely.
    """
    k_arr = np.asarray(kinds)
    a_arr = np.asarray(addrs, np.int64)
    length = len(k_arr)
    midx = np.flatnonzero(k_arr)
    n_mem = len(midx)
    if not n_mem:
        return [-length] if length else []
    # ALU-run length immediately before each memory op
    gaps = np.diff(np.concatenate(([-1], midx))) - 1
    mem_toks = a_arr[midx] * 2
    if dep_every:
        dep = (np.arange(1, n_mem + 1) % dep_every) == 0
        mem_toks += dep
    inter = np.empty(2 * n_mem, np.int64)
    inter[0::2] = -gaps
    inter[1::2] = mem_toks
    keep = np.ones(2 * n_mem, bool)
    keep[0::2] = gaps > 0
    toks = inter[keep].tolist()
    tail = length - (int(midx[-1]) + 1)
    if tail:
        toks.append(-tail)
    return toks


def encode_workload(traces: Sequence[Tuple[np.ndarray, np.ndarray]],
                    dep_every: int,
                    num_warps: int = 0) -> List[List[int]]:
    """Token streams for the first ``num_warps`` traces (0 = all)."""
    if num_warps:
        traces = traces[:num_warps]
    return [encode_trace(k, a, dep_every) for k, a in traces]


def decode_trace(tokens: Iterable[int]) -> Tuple[np.ndarray, np.ndarray]:
    """Invert :func:`encode_trace` back to per-instruction (kinds, addrs).

    The dependent-use bit is stripped (it is a pure function of
    ``dep_every`` and the memory-op ordinal, re-derived on encode).
    """
    kinds: List[int] = []
    addrs: List[int] = []
    for tok in tokens:
        if tok < 0:
            kinds.extend([0] * (-tok))
            addrs.extend([0] * (-tok))
        else:
            kinds.append(1)
            addrs.append(tok >> 1)
    return (np.asarray(kinds, np.uint8), np.asarray(addrs, np.int64))


def token_line(tok: int) -> int:
    """Cache-line index of a (non-negative) memory token."""
    return tok >> TOKEN_LINE_SHIFT


def pad_token_streams(streams: Sequence[Sequence[int]],
                      num_warps: int = 0,
                      width: int = 0,
                      fill: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Pad one cell's per-warp token streams into a rectangular plane.

    Returns ``(tokens, lengths)``: ``tokens`` is ``(num_warps, width)``
    int64 (warps/width default to the stream count / longest stream;
    shorter streams are padded with ``fill``), ``lengths`` the per-warp
    token counts. Consumers must guard reads with ``lengths`` — the fill
    value is not a sentinel (0 is a valid memory token).
    """
    n = num_warps or len(streams)
    lens = np.zeros(n, np.int64)
    lens[:len(streams)] = [len(s) for s in streams[:n]]
    w = width or (int(lens.max()) if n else 0)
    toks = np.full((n, max(w, 1)), fill, np.int64)
    for i, s in enumerate(streams[:n]):
        if len(s) > w:
            raise ValueError(f"stream {i} longer ({len(s)}) than width {w}")
        toks[i, :len(s)] = s
    return toks, lens


def stack_token_streams(per_cell: Sequence[Sequence[Sequence[int]]],
                        num_warps: int,
                        fill: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Stack many cells' token streams into one ``(B, num_warps, width)``
    batch plane (the batched engine's layout; ``width`` = longest stream
    anywhere). Returns ``(tokens, lengths)`` with ``lengths`` shaped
    ``(B, num_warps)``."""
    b = len(per_cell)
    w = max((len(s) for cell in per_cell for s in cell), default=0)
    toks = np.full((b, num_warps, max(w, 1)), fill, np.int64)
    lens = np.zeros((b, num_warps), np.int64)
    for i, cell in enumerate(per_cell):
        t, ln = pad_token_streams(cell, num_warps=num_warps,
                                  width=max(w, 1), fill=fill)
        toks[i] = t
        lens[i] = ln
    return toks, lens
