"""Workload subsystem: declarative access-pattern IR, synthetic benchmark
families, Pallas-kernel-derived traces, token compilation, and a
versioned on-disk format.

Entry points:

* :func:`make_workload` / :data:`WORKLOADS` / :data:`REGISTRY` — the
  registry (``repro.core.traces`` re-exports these for back-compat).
* :mod:`repro.workloads.ir` — primitives + :func:`compile_workload`.
* :mod:`repro.workloads.tokens` — the trace -> token-stream contract the
  simulator consumes.
* :mod:`repro.workloads.io` — :func:`save_workload` /
  :func:`load_workload` (npz + JSON header, format-versioned).
* :mod:`repro.workloads.derived` — traces walked out of the repo's real
  Pallas kernels (flashattn / decodeattn / gather), registered alongside
  the synthetic families.
"""
from repro.workloads.ir import (  # noqa: F401
    AluBurst, Explicit, HotLines, Interleave, MemBurst, Mix, PhaseSpec,
    ReuseWindow, SharedTable, SMEM_TOTAL, Stream, Workload, WorkloadSpec,
    compile_workload)
from repro.workloads.tokens import (  # noqa: F401
    LINE, TOKEN_LINE_SHIFT, decode_trace, encode_trace, encode_workload,
    token_line)
from repro.workloads.registry import (  # noqa: F401
    REGISTRY, WORKLOADS, WorkloadEntry, make_workload, register_workload,
    workload_names)
from repro.workloads.synthetic import (  # noqa: F401
    ci_spec, ci_workload, lws_spec, lws_workload, sws_spec, sws_workload,
    two_phase_spec, two_phase_workload)
from repro.workloads import derived as _derived  # noqa: F401  (registers)
from repro.workloads.derived import (  # noqa: F401
    decodeattn_workload, flashattn_workload, gather_index_stream,
    gather_workload)
from repro.workloads.io import (  # noqa: F401
    FORMAT_VERSION, load_workload, save_workload)
