"""Curated on-disk trace set: identical workloads on every machine.

The runner's workload cache (``results/workloads/``) is transient — each
machine regenerates and caches locally, so two machines only see the
same traces because generation is seeded. This module adds a *shipped*
set: a small directory of versioned ``.npz`` workloads committed to the
repository (``results/workloads/curated/``) together with a
``MANIFEST.json`` of SHA-256 checksums. Cross-machine sweeps load these
instead of regenerating, and the checksums turn silent drift (a stale
file, a partial checkout, a generator edit without a re-ship) into a
hard error.

Lookup order in :func:`repro.core.runner._cached_workload` is: in-memory
LRU -> local cache dir -> **curated set** -> generate. Set
``$REPRO_NO_CURATED=1`` to skip the curated set (the test suite does, so
generator edits are always exercised), or ``$REPRO_CURATED_DIR`` to point
at a different shipped set.

Rebuild after a generator change::

    python -m repro.workloads.curated --build

which regenerates every manifest entry (or ``--workloads ... --scale
... --seed ...`` to curate a new slice) and rewrites the manifest.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import pathlib
from typing import Dict, List, Optional

MANIFEST = "MANIFEST.json"
MANIFEST_VERSION = 1
# the grid slice shipped by default: the quick-set workloads at the
# benchmark quick scale, under the fig8 grid's base seed
DEFAULT_WORKLOADS = ("kmn", "bicg", "syrk", "gesummv", "conv2d", "nw")
DEFAULT_SCALE = 0.2
DEFAULT_SEED = 0


def curated_dir() -> pathlib.Path:
    env = os.environ.get("REPRO_CURATED_DIR")
    if env:
        return pathlib.Path(env)
    # src/repro/workloads/curated.py -> repo root is three levels up
    return pathlib.Path(__file__).resolve().parents[3] \
        / "results" / "workloads" / "curated"


def enabled() -> bool:
    return not os.environ.get("REPRO_NO_CURATED")


def _fname(name: str, seed: int, scale: float) -> str:
    return f"{name}-s{seed}-x{scale:g}.npz"


def load_manifest(root: Optional[pathlib.Path] = None) -> Dict[str, str]:
    """filename -> sha256 of the shipped set ({} when absent)."""
    root = root if root is not None else curated_dir()
    path = root / MANIFEST
    if not path.exists():
        return {}
    doc = json.loads(path.read_text())
    if doc.get("version") != MANIFEST_VERSION:
        raise ValueError(f"unsupported curated manifest version in {path}")
    return dict(doc["files"])


def _sha256(path: pathlib.Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def load_curated(name: str, seed: int, scale: float):
    """Load a workload from the curated set, or None when it is not
    shipped. A shipped file whose checksum disagrees with the manifest
    raises — a corrupt or stale curated set must never silently feed a
    sweep."""
    if not enabled():
        return None
    root = curated_dir()
    fname = _fname(name, seed, scale)
    digest = load_manifest(root).get(fname)
    if digest is None:
        return None
    path = root / fname
    if not path.exists():
        raise FileNotFoundError(
            f"curated manifest lists {fname} but the file is missing "
            f"under {root}")
    got = _sha256(path)
    if got != digest:
        raise ValueError(
            f"curated workload {fname} checksum mismatch "
            f"(manifest {digest[:12]}…, file {got[:12]}…) — re-ship with "
            f"`python -m repro.workloads.curated --build`")
    from repro.workloads.io import load_workload
    return load_workload(path)


def verify_manifest(root: Optional[pathlib.Path] = None) -> List[str]:
    """Check every manifest entry (existence + checksum). Returns a list
    of human-readable problems; empty means the set is intact."""
    root = root if root is not None else curated_dir()
    problems: List[str] = []
    files = load_manifest(root)
    if not files:
        return [f"no curated manifest under {root}"]
    for fname, digest in sorted(files.items()):
        path = root / fname
        if not path.exists():
            problems.append(f"missing: {fname}")
        elif _sha256(path) != digest:
            problems.append(f"checksum mismatch: {fname}")
    return problems


def build(workloads=DEFAULT_WORKLOADS, scale: float = DEFAULT_SCALE,
          seed: int = DEFAULT_SEED,
          root: Optional[pathlib.Path] = None) -> pathlib.Path:
    """(Re)generate the curated set and rewrite the manifest. Existing
    manifest entries not in this build are regenerated too, so a partial
    build never leaves stale hashes behind."""
    from repro.core.runner import workload_seed
    from repro.workloads import make_workload
    from repro.workloads.io import save_workload
    root = root if root is not None else curated_dir()
    root.mkdir(parents=True, exist_ok=True)
    entries = {}
    wanted = {(w, workload_seed(seed, w), scale) for w in workloads}
    # keep previously curated slices alive by re-deriving their keys
    for fname in load_manifest(root) if (root / MANIFEST).exists() else {}:
        stem = fname[:-len(".npz")]
        name, s, x = stem.rsplit("-s", 1)[0], None, None
        try:
            rest = stem[len(name) + 2:]
            s_str, x_str = rest.split("-x", 1)
            s, x = int(s_str), float(x_str)
        except ValueError:
            continue
        wanted.add((name, s, x))
    for name, s, x in sorted(wanted):
        wl = make_workload(name, seed=s, scale=x)
        path = root / _fname(name, s, x)
        save_workload(wl, path)
        entries[path.name] = _sha256(path)
    doc = {"version": MANIFEST_VERSION, "files": entries}
    (root / MANIFEST).write_text(json.dumps(doc, indent=1, sort_keys=True)
                                 + "\n")
    return root


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--build", action="store_true",
                    help="regenerate the curated set + manifest")
    ap.add_argument("--verify", action="store_true",
                    help="verify the shipped set against the manifest")
    ap.add_argument("--workloads", nargs="*", default=list(DEFAULT_WORKLOADS))
    ap.add_argument("--scale", type=float, default=DEFAULT_SCALE)
    ap.add_argument("--seed", type=int, default=DEFAULT_SEED)
    args = ap.parse_args()
    if args.build:
        root = build(tuple(args.workloads), args.scale, args.seed)
        print(f"curated set rebuilt under {root}")
        return 0
    problems = verify_manifest()
    for p in problems:
        print(f"PROBLEM: {p}")
    print("curated set OK" if not problems else
          f"{len(problems)} problem(s)")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main())
