"""Workload registry: one table, every consumer derives from it.

Entries map a benchmark name to its class label and a builder
``(seed, scale) -> Workload``. ``WORKLOADS`` (the name -> class mapping
the runner validates against and benchmarks group by) is a live *view*
over the registry — there is no duplicate literal to drift, and workloads
registered later (e.g. by downstream code via :func:`register_workload`)
appear in it automatically.

Synthetic entries carry the paper's Table II parametrization (``N_wrp``
profiled Best-SWL limits, ``smem_frac`` per-app shared-memory use).
Kernel-derived entries are registered by :mod:`repro.workloads.derived`
under class ``KRN``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterator, Mapping

from repro.workloads.ir import Workload, compile_workload
from repro.workloads.synthetic import (ci_spec, lws_spec, sws_spec,
                                       two_phase_spec)

Builder = Callable[[int, float], Workload]


@dataclasses.dataclass(frozen=True)
class WorkloadEntry:
    name: str
    klass: str                     # LWS | SWS | CI | KRN
    build: Builder
    origin: str = "synthetic"      # synthetic | derived


REGISTRY: Dict[str, WorkloadEntry] = {}


def register_workload(name: str, klass: str, build: Builder,
                      origin: str = "synthetic") -> None:
    if name in REGISTRY:
        raise ValueError(f"workload {name!r} already registered")
    REGISTRY[name] = WorkloadEntry(name, klass, build, origin)


def make_workload(name: str, seed: int = 0, scale: float = 1.0) -> Workload:
    try:
        entry = REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown workload {name!r}; registered: "
                       f"{sorted(REGISTRY)}") from None
    return entry.build(seed, scale)


def workload_names(origin: str = "") -> list:
    """Registered names, optionally filtered by origin
    ('synthetic' | 'derived')."""
    return [n for n, e in REGISTRY.items() if not origin or
            e.origin == origin]


class _WorkloadClassView(Mapping):
    """name -> class, derived live from the registry (no drift)."""

    def __getitem__(self, name: str) -> str:
        return REGISTRY[name].klass

    def __iter__(self) -> Iterator[str]:
        return iter(REGISTRY)

    def __len__(self) -> int:
        return len(REGISTRY)

    def __repr__(self) -> str:
        return f"WORKLOADS({dict(self)!r})"


WORKLOADS: Mapping[str, str] = _WorkloadClassView()


# ------------------------------------------------------ synthetic entries
def _spec_entry(name: str, klass: str, spec_of) -> None:
    """Register a builder that compiles ``spec_of(scale)`` at ``seed``.
    Per-entry seed offsets are baked into the spec itself (the ``_off``
    wrapper below shifts every phase's ``seed_offset``), reproducing the
    pre-IR ``make_workload`` table bit-for-bit."""
    def build(seed: int, scale: float) -> Workload:
        return compile_workload(spec_of(scale), seed)
    register_workload(name, klass, build)


def _n(x: int, scale: float) -> int:
    return int(x * scale)


def _register_synthetic() -> None:
    # --- LWS (Table II: ATAX/BICG/MVT N_wrp=2, KMN=4, Kmeans=2) ---
    # atax is two-phase (Fig. 9); scale applies per phase (the pre-IR
    # generator silently ignored it — fixed here).
    _spec_entry("atax", "LWS", lambda s: two_phase_spec(
        "atax", inst_per_phase=_n(2500, s)))
    _spec_entry("bicg", "LWS", lambda s: lws_spec(
        "bicg", inst_per_warp=_n(4000, s), heavy_warps=6, n_wrp=2))
    _spec_entry("mvt", "LWS", lambda s: _off(lws_spec(
        "mvt", inst_per_warp=_n(4000, s), heavy_warps=4, hot_rate=0.35,
        n_wrp=2), 2))
    _spec_entry("kmn", "LWS", lambda s: _off(lws_spec(
        "kmn", inst_per_warp=_n(4000, s), mem_rate=0.40, heavy_warps=10,
        smem_frac=0.01, n_wrp=4), 3))
    _spec_entry("kmeans", "LWS", lambda s: _off(lws_spec(
        "kmeans", inst_per_warp=_n(5000, s), mem_rate=0.45, heavy_warps=8,
        heavy_mem_rate=0.8, n_wrp=2), 4))
    # --- SWS (GESUMMV/SYR2K/SYRK N_wrp=2/6/6; PVC/SS use smem) ---
    _spec_entry("gesummv", "SWS", lambda s: _off(sws_spec(
        "gesummv", inst_per_warp=_n(4000, s), mem_rate=0.5,
        ws_per_warp=1024, n_wrp=2), 5))
    _spec_entry("syr2k", "SWS", lambda s: _off(sws_spec(
        "syr2k", inst_per_warp=_n(4000, s), ws_per_warp=1024, n_wrp=6), 6))
    _spec_entry("syrk", "SWS", lambda s: _off(sws_spec(
        "syrk", inst_per_warp=_n(4000, s), ws_per_warp=768, n_wrp=6), 7))
    _spec_entry("ii", "SWS", lambda s: _off(sws_spec(
        "ii", inst_per_warp=_n(4000, s), mem_rate=0.3, ws_per_warp=1280,
        n_wrp=4), 8))
    _spec_entry("pvc", "SWS", lambda s: _off(sws_spec(
        "pvc", inst_per_warp=_n(4000, s), ws_per_warp=896, smem_frac=0.33,
        n_wrp=48), 9))
    _spec_entry("ss", "SWS", lambda s: _off(sws_spec(
        "ss", inst_per_warp=_n(4000, s), ws_per_warp=896, smem_frac=0.50,
        n_wrp=48), 10))
    # --- CI (Backprop smem 13%, Hotspot 19%, NW 35%) ---
    _spec_entry("gaussian", "CI", lambda s: _off(ci_spec(
        "gaussian", inst_per_warp=_n(4000, s), mem_rate=0.05,
        n_wrp=48), 11))
    _spec_entry("conv2d", "CI", lambda s: _off(ci_spec(
        "conv2d", inst_per_warp=_n(4000, s), mem_rate=0.03, n_wrp=36), 12))
    _spec_entry("backprop", "CI", lambda s: _off(ci_spec(
        "backprop", inst_per_warp=_n(4000, s), mem_rate=0.08, hot_rate=0.6,
        smem_frac=0.13, n_wrp=36), 13))
    _spec_entry("hotspot", "CI", lambda s: _off(ci_spec(
        "hotspot", inst_per_warp=_n(4000, s), mem_rate=0.02,
        smem_frac=0.19, n_wrp=48), 14))
    _spec_entry("nw", "CI", lambda s: _off(ci_spec(
        "nw", inst_per_warp=_n(4000, s), mem_rate=0.05, hot_rate=0.4,
        smem_frac=0.35, n_wrp=48), 15))


def _off(spec, delta: int):
    """Shift every phase's seed offset by ``delta`` (the pre-IR registry
    seeded each family at ``seed + k``)."""
    phases = tuple(dataclasses.replace(p, seed_offset=p.seed_offset + delta)
                   for p in spec.phases)
    return dataclasses.replace(spec, phases=phases)


_register_synthetic()
