"""Synthetic workload families modeled on the paper's benchmark classes
(Table II: PolyBench / Mars / Rodinia — LWS, SWS, CI), expressed in the
declarative IR of :mod:`repro.workloads.ir`.

* **LWS** (ATAX, BICG, MVT, KMN, Kmeans): streaming over working sets far
  larger than L1D with medium-distance re-reference windows, plus a few
  *heavy* warps hammering at ~2x the memory rate (the index-array access
  of SpMV/KMeans, §VI) — the source of the skewed interference of Fig. 4.
* **SWS** (GESUMMV, SYR2K, SYRK, II, PVC, SS, SM, WC): per-warp working
  sets of ~1KB with heavy reuse; 48 warps thrash 16KB L1D, but the union
  fits in L1D + unused shared memory — the CIAO-P sweet spot.
* **CI** (Gaussian, 2DCONV, CORR, Backprop, Hotspot, NN, NW): mostly ALU,
  low APKI, with periodic bursts touching a shared table — enough VTA hits
  to bait locality-aware throttling into sacrificing TLP.

``smem_frac`` (fraction of shared memory the app itself uses — Table II)
caps the space CIAO-P can borrow.

Every builder returns a compiled :class:`~repro.workloads.ir.Workload`.
The IR lowering consumes the RNG in exactly the order the pre-IR
generators of ``core/traces.py`` did, so traces are bit-identical to the
seed for every registered (name, seed, scale) — pinned by the golden
cells of ``tests/test_equivalence.py``.
"""
from __future__ import annotations

from typing import Tuple

from repro.workloads.ir import (AluBurst, HotLines, Interleave, Mix,
                                PhaseSpec, ReuseWindow, SharedTable,
                                SMEM_TOTAL, Stream, Workload, WorkloadSpec,
                                compile_workload)

__all__ = ["lws_spec", "sws_spec", "ci_spec", "two_phase_spec",
           "lws_workload", "sws_workload", "ci_workload",
           "two_phase_workload", "SMEM_TOTAL"]


def _lws_phase(*, num_warps: int, inst_per_warp: int, mem_rate: float,
               heavy_warps: int, heavy_mem_rate: float,
               hot_lines_per_warp: int, hot_rate: float,
               seed_offset: int = 0) -> PhaseSpec:
    """Every warp streams a large region (no reuse — pure eviction
    pressure) and re-references a few private hot lines. A few *heavy*
    warps stream at ~2x the memory rate with almost no hot reuse of their
    own — the severe, non-uniform interferers of Fig. 4: they evict
    everyone's hot lines, earn the interference-list blame, and are the
    right warps to isolate (CIAO-P) or stall (CIAO-T)."""
    stride = max(1, num_warps // max(heavy_warps, 1))
    heavy_set = set(range(1, num_warps, stride))  # spread across WIDs
    heavy_set = set(list(heavy_set)[:heavy_warps])
    warps = []
    for w in range(num_warps):
        heavy = w in heavy_set
        base = (w + 1) * 16 * 1024 * 1024
        warps.append((Interleave(
            inst_per_warp,
            heavy_mem_rate if heavy else mem_rate,
            Mix(0.02 if heavy else hot_rate,
                HotLines(base, hot_lines_per_warp),
                Stream(base + 4 * 1024 * 1024))),))
    return PhaseSpec(tuple(warps), seed_offset)


def lws_spec(name: str, *, num_warps=48, inst_per_warp=4000, mem_rate=0.35,
             heavy_warps=8, heavy_mem_rate=0.70, hot_lines_per_warp=2,
             hot_rate=0.45, smem_frac=0.0, n_wrp=0) -> WorkloadSpec:
    phase = _lws_phase(num_warps=num_warps, inst_per_warp=inst_per_warp,
                       mem_rate=mem_rate, heavy_warps=heavy_warps,
                       heavy_mem_rate=heavy_mem_rate,
                       hot_lines_per_warp=hot_lines_per_warp,
                       hot_rate=hot_rate)
    return WorkloadSpec(name, "LWS", (phase,),
                        int(smem_frac * SMEM_TOTAL), n_wrp,
                        apki=mem_rate * 1000)


def sws_spec(name: str, *, num_warps=48, inst_per_warp=4000, mem_rate=0.35,
             ws_per_warp=1024, passes=64, smem_frac=0.0,
             n_wrp=0) -> WorkloadSpec:
    warps = []
    for w in range(num_warps):
        base = (w + 1) * 4 * 1024 * 1024
        warps.append((Interleave(
            inst_per_warp, mem_rate,
            ReuseWindow(base, ws_per_warp, passes, ws_per_warp)),))
    return WorkloadSpec(name, "SWS", (PhaseSpec(tuple(warps)),),
                        int(smem_frac * SMEM_TOTAL), n_wrp,
                        apki=mem_rate * 1000)


def _ci_phase(*, num_warps: int, inst_per_warp: int, mem_rate: float,
              hot_lines_per_warp: int, hot_rate: float, shared_bytes: int,
              seed_offset: int = 0) -> PhaseSpec:
    """Compute-intensive: ~95% ALU, but the few memory ops mix per-warp
    hot lines (frequent re-reference -> VTA hits when evicted) with a
    shared table larger than L1D (eviction pressure). The VTA hits bait
    CCWS into score-based throttling — a pure TLP loss on compute-bound
    code — while the *absolute* hit rate stays far below CIAO's IRS
    high-cutoff (Eq. 1 normalizes by instructions), so CIAO leaves TLP
    alone. This is exactly the Backprop asymmetry of Fig. 1/9."""
    table = SharedTable(shared_bytes)
    warps = []
    for w in range(num_warps):
        base = (w + 1) * 4 * 1024 * 1024
        warps.append((Interleave(
            inst_per_warp, mem_rate,
            Mix(hot_rate, HotLines(base, hot_lines_per_warp), table)),))
    return PhaseSpec(tuple(warps), seed_offset)


def ci_spec(name: str, *, num_warps=48, inst_per_warp=4000, mem_rate=0.05,
            hot_lines_per_warp=2, hot_rate=0.5, shared_bytes=24 * 1024,
            smem_frac=0.0, n_wrp=0) -> WorkloadSpec:
    phase = _ci_phase(num_warps=num_warps, inst_per_warp=inst_per_warp,
                      mem_rate=mem_rate,
                      hot_lines_per_warp=hot_lines_per_warp,
                      hot_rate=hot_rate, shared_bytes=shared_bytes)
    return WorkloadSpec(name, "CI", (phase,),
                        int(smem_frac * SMEM_TOTAL), n_wrp,
                        apki=mem_rate * 1000)


def two_phase_spec(name: str, *, inst_per_phase=2500) -> WorkloadSpec:
    """ATAX-like: memory-intensive phase then compute-intensive phase
    (Fig. 9) within one kernel. Phase 2 compiles from ``seed + 1``,
    matching the seed generator's two sub-workloads."""
    a = _lws_phase(num_warps=48, inst_per_warp=inst_per_phase,
                   mem_rate=0.45, heavy_warps=6, heavy_mem_rate=0.70,
                   hot_lines_per_warp=2, hot_rate=0.45, seed_offset=0)
    b = _ci_phase(num_warps=48, inst_per_warp=inst_per_phase,
                  mem_rate=0.05, hot_lines_per_warp=2, hot_rate=0.5,
                  shared_bytes=24 * 1024, seed_offset=1)
    return WorkloadSpec(name, "LWS", (a, b), 0, 0, apki=250)


# ------------------------------------------------- compiled-form wrappers
# Back-compat with the pre-IR ``core/traces.py`` generator functions.
def lws_workload(name: str, *, seed=0, **kw) -> Workload:
    return compile_workload(lws_spec(name, **kw), seed)


def sws_workload(name: str, *, seed=0, **kw) -> Workload:
    return compile_workload(sws_spec(name, **kw), seed)


def ci_workload(name: str, *, seed=0, **kw) -> Workload:
    return compile_workload(ci_spec(name, **kw), seed)


def two_phase_workload(name: str, *, seed=0, **kw) -> Workload:
    return compile_workload(two_phase_spec(name, **kw), seed)
