"""Declarative access-pattern IR for simulator workloads.

A :class:`WorkloadSpec` describes a workload as *phases* (concatenated in
time, paper Fig. 9's intra-kernel phase changes); each phase holds one
*warp program* per warp; a warp program is a tuple of *segments*; segments
reference *address sources*. One :func:`compile_workload` lowers the spec
to the per-warp ``(kinds, addrs)`` trace arrays the simulator tokenizes
(:mod:`repro.workloads.tokens`).

Address sources (evaluated to ``n_mem`` line-aligned byte addresses):

* :class:`Stream` — fresh line per memory op (pure eviction pressure).
* :class:`HotLines` — a few lines re-referenced round-robin (stencil
  edges / accumulators / index-array entries).
* :class:`SharedTable` — a fixed table walked in order and tiled, shared
  between warps that name the same base (inter-warp interference bait).
* :class:`ReuseWindow` — a window swept ``passes`` times line-by-line
  before sliding (potential locality that interference destroys).
* :class:`Explicit` — a literal line-address sequence, tiled to length;
  the hook :mod:`repro.workloads.derived` uses to inject address streams
  walked out of real Pallas kernels.
* :class:`Mix` — elementwise Bernoulli select between two sources (both
  advance every op, only the chosen address issues).

Segments:

* :class:`AluBurst` — ``n`` pure-ALU instructions.
* :class:`Interleave` — ``n_inst`` instructions with memory ops drawn
  Bernoulli(``mem_rate``), addresses from a source.
* :class:`MemBurst` — ``n`` back-to-back memory instructions with a
  deterministic address sequence (how kernel-derived traces emit the
  exact block walk of a Pallas grid).

Determinism contract: every phase owns one ``np.random.default_rng(seed +
seed_offset)`` stream consumed warp-by-warp, segment-by-segment in a
fixed order — for an :class:`Interleave`, the kind vector is drawn first,
then the source is evaluated (:class:`Mix` draws its selector and an
*irregular* :class:`ReuseWindow` its per-window permutations — after the
kind draw, unlike the pre-IR ``_reuse_window_stream`` helper, which no
registered workload used with ``irregular``; every other source is
deterministic). The synthetic families in
:mod:`repro.workloads.synthetic` rely on this order to stay bit-identical
to the pre-IR generators of ``core/traces.py`` (pinned by the golden
cells of ``tests/test_equivalence.py``).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple, Union

import numpy as np

from repro.workloads.tokens import LINE

SMEM_TOTAL = 48 * 1024


# ------------------------------------------------------- address sources
@dataclasses.dataclass(frozen=True)
class Stream:
    """Fresh line per memory op: ``base + LINE * i``."""
    base: int


@dataclasses.dataclass(frozen=True)
class HotLines:
    """``count`` lines at ``base`` re-referenced round-robin."""
    base: int
    count: int = 2


@dataclasses.dataclass(frozen=True)
class SharedTable:
    """A ``table_bytes`` region at ``base`` walked line-by-line, tiled."""
    table_bytes: int
    base: int = 0


@dataclasses.dataclass(frozen=True)
class ReuseWindow:
    """Sliding re-reference window over ``total_bytes``, each window swept
    ``passes`` times; ``irregular`` permutes lines within a window."""
    base: int
    window_bytes: int
    passes: int
    total_bytes: int
    irregular: bool = False


@dataclasses.dataclass(frozen=True)
class Explicit:
    """A literal line-address stream (int64 byte addresses), tiled."""
    addrs: Tuple[int, ...]

    @staticmethod
    def of(array_like) -> "Explicit":
        return Explicit(tuple(int(a) for a in np.asarray(array_like)))


@dataclasses.dataclass(frozen=True)
class Mix:
    """Elementwise select: Bernoulli(``p``) picks ``hot``, else ``cold``.
    Both sources are evaluated full-length (their streams advance whether
    chosen or not — the seed generators' semantics)."""
    p: float
    hot: "Source"
    cold: "Source"


Source = Union[Stream, HotLines, SharedTable, ReuseWindow, Explicit, Mix]


# ---------------------------------------------------------------- segments
@dataclasses.dataclass(frozen=True)
class AluBurst:
    """``n`` pure-ALU instructions."""
    n: int


@dataclasses.dataclass(frozen=True)
class Interleave:
    """``n_inst`` instructions; each is MEM with prob ``mem_rate``,
    addresses pulled from ``addr``."""
    n_inst: int
    mem_rate: float
    addr: Source


@dataclasses.dataclass(frozen=True)
class MemBurst:
    """``n`` consecutive memory instructions, addresses from ``addr``."""
    n: int
    addr: Source


Segment = Union[AluBurst, Interleave, MemBurst]
WarpProgram = Tuple[Segment, ...]


@dataclasses.dataclass(frozen=True)
class PhaseSpec:
    """One phase: a program per warp, compiled from its own RNG stream
    (``seed + seed_offset``). Phases concatenate per-warp in time."""
    warps: Tuple[WarpProgram, ...]
    seed_offset: int = 0


@dataclasses.dataclass
class Workload:
    """Compiled workload — what the simulator consumes (duck-typed with
    the GPU model's per-SM sub-workloads)."""
    name: str
    klass: str                     # LWS | SWS | CI | KRN
    traces: List[Tuple[np.ndarray, np.ndarray]]
    smem_used_bytes: int
    n_wrp: int = 0                 # profiled Best-SWL limit hint (0 = sweep)
    apki: float = 0.0


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    name: str
    klass: str
    phases: Tuple[PhaseSpec, ...]
    smem_used_bytes: int = 0
    n_wrp: int = 0
    apki: float = 0.0


# ---------------------------------------------------------------- compile
def _reuse_window_stream(src: ReuseWindow, rng) -> np.ndarray:
    lines_per_window = max(src.window_bytes // LINE, 1)
    n_windows = max(src.total_bytes // src.window_bytes, 1)
    out = []
    for wdx in range(n_windows):
        wbase = src.base + wdx * src.window_bytes
        lines = wbase + LINE * np.arange(lines_per_window)
        if src.irregular:
            lines = rng.permutation(lines)
        for _ in range(src.passes):
            out.append(lines)
    return np.concatenate(out) if out else np.zeros(1, np.int64)


def _tile_to(stream: np.ndarray, n: int) -> np.ndarray:
    reps = int(np.ceil(n / max(len(stream), 1)))
    return np.tile(stream, reps)[:n]


def eval_source(src: Source, n_mem: int, rng) -> np.ndarray:
    """``n_mem`` byte addresses from a source. RNG is consumed only by
    ``Mix`` (the selector draw) and irregular ``ReuseWindow`` (the
    per-window permutations), in declaration order."""
    if isinstance(src, Stream):
        return src.base + LINE * np.arange(n_mem, dtype=np.int64)
    if isinstance(src, HotLines):
        hot = src.base + LINE * np.arange(src.count, dtype=np.int64)
        return hot[np.arange(n_mem) % max(src.count, 1)]
    if isinstance(src, SharedTable):
        lines = src.base + LINE * np.arange(
            max(src.table_bytes // LINE, 1), dtype=np.int64)
        return _tile_to(lines, n_mem)
    if isinstance(src, ReuseWindow):
        return _tile_to(_reuse_window_stream(src, rng), n_mem)
    if isinstance(src, Explicit):
        return _tile_to(np.asarray(src.addrs, np.int64), n_mem)
    if isinstance(src, Mix):
        hot_seq = eval_source(src.hot, n_mem, rng)
        cold_seq = eval_source(src.cold, n_mem, rng)
        use_hot = rng.random(n_mem) < src.p
        return np.where(use_hot, hot_seq, cold_seq)
    raise TypeError(f"unknown address source {src!r}")


def compile_segment(seg: Segment, rng) -> Tuple[np.ndarray, np.ndarray]:
    if isinstance(seg, AluBurst):
        return (np.zeros(seg.n, np.uint8), np.zeros(seg.n, np.int64))
    if isinstance(seg, Interleave):
        kinds = (rng.random(seg.n_inst) < seg.mem_rate).astype(np.uint8)
        n_mem = int(kinds.sum())
        addrs = np.zeros(seg.n_inst, np.int64)
        addrs[kinds == 1] = eval_source(seg.addr, n_mem, rng)
        return (kinds, addrs)
    if isinstance(seg, MemBurst):
        return (np.ones(seg.n, np.uint8),
                eval_source(seg.addr, seg.n, rng))
    raise TypeError(f"unknown segment {seg!r}")


def compile_program(prog: WarpProgram, rng
                    ) -> Tuple[np.ndarray, np.ndarray]:
    parts = [compile_segment(seg, rng) for seg in prog]
    if len(parts) == 1:
        return parts[0]
    return (np.concatenate([k for k, _ in parts]) if parts
            else np.zeros(0, np.uint8),
            np.concatenate([a for _, a in parts]) if parts
            else np.zeros(0, np.int64))


def compile_workload(spec: WorkloadSpec, seed: int = 0) -> Workload:
    """Lower a spec to trace arrays. Each phase compiles all its warps
    from one RNG; phases then concatenate per-warp (zip semantics: the
    warp count is the minimum over phases, matching the seed two-phase
    generator)."""
    per_phase: List[List[Tuple[np.ndarray, np.ndarray]]] = []
    for phase in spec.phases:
        rng = np.random.default_rng(seed + phase.seed_offset)
        per_phase.append([compile_program(p, rng) for p in phase.warps])
    if len(per_phase) == 1:
        traces = per_phase[0]
    else:
        traces = []
        for warp_parts in zip(*per_phase):
            traces.append((np.concatenate([k for k, _ in warp_parts]),
                           np.concatenate([a for _, a in warp_parts])))
    return Workload(spec.name, spec.klass, traces, spec.smem_used_bytes,
                    spec.n_wrp, spec.apki)
