"""Loop-aware analysis of post-SPMD optimized HLO text.

``compiled.cost_analysis()`` visits every computation **once**, so anything
inside a ``while`` body (every ``lax.scan`` — i.e. our layer stacks, KV-chunk
scans, SSD chunk scans, grad-accumulation) is undercounted by its trip count.
This module re-derives the three roofline inputs from the HLO text with loop
multipliers applied:

  * ``flops``       — 2 * prod(output dims) * prod(contracting dims) for every
                      ``dot`` (+ convolution), x loop multiplier. Elementwise
                      FLOPs are excluded (documented; matches MFU convention).
  * ``bytes``       — per top-level op: output + operand bytes (fusion bodies
                      excluded — a fusion's operands/results are the real HBM
                      boundary), slice-like ops counted at slice size,
                      x loop multiplier. An *upper bound* on HBM traffic on a
                      real TPU (CPU-backend fusion is weaker than TPU).
  * ``collectives`` — per kind, effective link bytes (ring multipliers:
                      all-reduce 2(K-1)/K, all-gather/reduce-scatter/
                      all-to-all (K-1)/K, collective-permute 1), x loop
                      multiplier. K parsed from replica_groups.

Loop multipliers: computations are walked from ENTRY; a ``while`` body/cond
inherits caller_multiplier x trip_count, where trip_count is recovered from
the loop condition's integer constant (standard 0..N jax scan lowering).
"""
from __future__ import annotations

import math
import re
from typing import Any, Dict, List, Optional, Tuple

def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``Compiled.cost_analysis()`` normalized across jax versions: older
    releases return a one-dict-per-device list, newer ones a flat dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})


DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

COLL_FACTORS = {
    "all-reduce": lambda k: 2.0 * (k - 1) / k,
    "all-gather": lambda k: (k - 1) / k,
    "reduce-scatter": lambda k: (k - 1) / k,
    "all-to-all": lambda k: (k - 1) / k,
    "collective-permute": lambda k: 1.0,
}
COLLECTIVE_KINDS = tuple(COLL_FACTORS)

# ops whose operands are not full-size reads
_SLICE_LIKE = ("dynamic-slice", "slice", "gather")
_UPDATE_LIKE = ("dynamic-update-slice", "scatter")
_NO_TRAFFIC = ("parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "iota", "partition-id", "replica-id",
               "while", "conditional", "call", "custom-call")

_SHAPE_TOKEN = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\s*\{")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(.*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\((.*)$")
_PARAM_DECL = re.compile(r"([\w.\-]+):\s*((?:\([^)]*\))|[a-z0-9]+\[[0-9,]*\])")
_OPERAND = re.compile(r"%([\w.\-]+)")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_CALLED = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)="
                     r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")


def _shape_elems_bytes(txt: str) -> Tuple[int, int]:
    """Total (elements, bytes) over every shape token in txt (handles tuples)."""
    elems = 0
    byts = 0
    for dt, dims in _SHAPE_TOKEN.findall(txt):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * DTYPE_BYTES[dt]
    return elems, byts


def _first_shape_dims(txt: str) -> Optional[List[int]]:
    m = _SHAPE_TOKEN.search(txt)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return dims


class Op:
    __slots__ = ("name", "shape_txt", "kind", "rest")

    def __init__(self, name, shape_txt, kind, rest):
        self.name, self.shape_txt, self.kind, self.rest = name, shape_txt, kind, rest


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.params: Dict[str, str] = {}
        self.ops: List[Op] = []
        self.symbols: Dict[str, str] = {}     # name -> shape text
        self.callees: List[Tuple[str, str]] = []  # (relation, callee)


def parse_module(hlo: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        hm = _COMP_HEADER.match(stripped)
        if hm and stripped.endswith("{"):
            cur = Computation(hm.group(1))
            comps[cur.name] = cur
            if stripped.startswith("ENTRY"):
                entry = cur.name
            header = stripped
            for pname, pshape in _PARAM_DECL.findall(header):
                cur.params[pname] = pshape
                cur.symbols[pname] = pshape
            continue
        if stripped == "}" or cur is None:
            continue
        om = _OP_LINE.match(line)
        if not om:
            continue
        name, shape_txt, kind, rest = om.groups()
        cur.symbols[name] = shape_txt
        cur.ops.append(Op(name, shape_txt, kind, rest))
        for cm in _CALLED.finditer(rest):
            rel = cm.group(0).split("=")[0]
            for callee in cm.group(1).split(","):
                cur.callees.append((rel, callee.strip().lstrip("%")))
    return comps, entry


def _trip_count(cond: Computation) -> int:
    """Trip count from the loop condition's integer constant (0..N scans)."""
    best = 1
    for op in cond.ops:
        txt = f"{op.kind}({op.rest}"
        for c in _CONST_INT.findall(txt):
            best = max(best, int(c))
    return best


def _multipliers(comps: Dict[str, Computation], entry: str) -> Dict[str, float]:
    """computation -> execution multiplier, walking while bodies."""
    mult: Dict[str, float] = {entry: 1.0}
    fusion_called: set = set()
    for c in comps.values():
        for rel, callee in c.callees:
            if rel in ("calls", "to_apply"):
                fusion_called.add(callee)

    # BFS from entry through while/conditional/call structure
    import collections
    q = collections.deque([entry])
    seen = {entry}
    while q:
        name = q.popleft()
        comp = comps.get(name)
        if comp is None:
            continue
        m = mult[name]
        for op in comp.ops:
            if op.kind == "while":
                body = cond = None
                bm = re.search(r"body=%?([\w.\-]+)", op.rest)
                cm = re.search(r"condition=%?([\w.\-]+)", op.rest)
                if bm:
                    body = bm.group(1)
                if cm:
                    cond = cm.group(1)
                trips = _trip_count(comps[cond]) if cond in comps else 1
                for callee, f in ((body, trips), (cond, trips + 1)):
                    if callee and callee in comps:
                        mult[callee] = mult.get(callee, 0.0) + m * f
                        if callee not in seen:
                            seen.add(callee)
                            q.append(callee)
        # non-while calls (conditional branches etc.): multiplier x1
        for rel, callee in comp.callees:
            if rel in ("body", "condition", "calls", "to_apply"):
                continue
            if callee in comps:
                mult[callee] = mult.get(callee, 0.0) + m
                if callee not in seen:
                    seen.add(callee)
                    q.append(callee)
    # drop fusion bodies from the executable set
    for f in fusion_called:
        mult.pop(f, None)
    return mult


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems, _ = _shape_elems_bytes(op.shape_txt)
    lhs_m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    operands = _OPERAND.findall(op.rest.split(")")[0])
    if not operands:
        return 0.0
    lhs_shape = comp.symbols.get(operands[0])
    if lhs_shape is None:
        return 2.0 * out_elems  # unknown operand; degrade gracefully
    dims = _first_shape_dims(lhs_shape) or []
    contract = 1
    if lhs_m:
        for d in lhs_m.group(1).split(","):
            if d and int(d) < len(dims):
                contract *= dims[int(d)]
    return 2.0 * out_elems * contract


def _op_bytes(op: Op, comp: Computation) -> float:
    _, out_b = _shape_elems_bytes(op.shape_txt)
    if op.kind in _SLICE_LIKE:
        return 2.0 * out_b
    if op.kind in _UPDATE_LIKE:
        return 3.0 * out_b
    operand_names = _OPERAND.findall(op.rest.split("), ")[0])
    in_b = 0
    for on in operand_names:
        sh = comp.symbols.get(on)
        if sh is not None:
            in_b += _shape_elems_bytes(sh)[1]
    return float(out_b + in_b)


def _group_size(rest: str) -> int:
    gi = _GROUPS_IOTA.search(rest)
    if gi:
        return int(gi.group(2))
    gl = _GROUPS_LIST.search(rest)
    if gl:
        return len([x for x in gl.group(1).split(",") if x.strip()])
    return 1


def analyze(hlo: str) -> Dict[str, Any]:
    comps, entry = parse_module(hlo)
    if entry is None:
        return {"error": "no entry computation"}
    mult = _multipliers(comps, entry)

    flops = 0.0
    bytes_total = 0.0
    bytes_hbm_model = 0.0   # TPU-fusion model: dot/conv/slice/DUS/collective
    coll_eff: Dict[str, float] = {}
    coll_raw: Dict[str, float] = {}
    coll_ops = 0
    for cname, m in mult.items():
        comp = comps.get(cname)
        if comp is None:
            continue
        for op in comp.ops:
            kind = op.kind
            base_kind = kind[:-6] if kind.endswith("-start") else kind
            if base_kind in COLLECTIVE_KINDS:
                _, b = _shape_elems_bytes(op.shape_txt)
                k = _group_size(op.rest)
                if k > 1:
                    coll_eff[base_kind] = coll_eff.get(base_kind, 0.0) + \
                        m * b * COLL_FACTORS[base_kind](k)
                    coll_raw[base_kind] = coll_raw.get(base_kind, 0.0) + m * b
                    coll_ops += 1
                ob = m * _op_bytes(op, comp)
                bytes_total += ob
                bytes_hbm_model += ob
                continue
            if kind in ("dot", "convolution"):
                flops += m * _dot_flops(op, comp)
                bytes_hbm_model += m * _op_bytes(op, comp)
            elif kind in _SLICE_LIKE or kind in _UPDATE_LIKE:
                bytes_hbm_model += m * _op_bytes(op, comp)
            if kind in _NO_TRAFFIC:
                continue
            bytes_total += m * _op_bytes(op, comp)

    return {
        "flops": flops,
        "bytes": bytes_total,
        "bytes_hbm_model": bytes_hbm_model,
        "collective_bytes_effective": coll_eff,
        "collective_bytes_raw": coll_raw,
        "collective_total_effective": sum(coll_eff.values()),
        "collective_total_raw": sum(coll_raw.values()),
        "collective_num_ops": coll_ops,
        "num_computations": len(comps),
        "num_executable": len(mult),
        "loop_multipliers": {k: v for k, v in sorted(
            mult.items(), key=lambda kv: -kv[1])[:8] if v > 1.0},
    }
