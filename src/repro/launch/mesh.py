"""Production meshes. Functions, not module constants — importing this module
never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a ``pod`` axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(devices=None):
    """Small mesh over whatever devices exist (CPU tests / subprocesses)."""
    import numpy as np
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    model = 1
    for m in (4, 2, 1):
        if n % m == 0:
            model = m
            break
    arr = np.array(devices).reshape(n // model, model)
    return jax.sharding.Mesh(arr, ("data", "model"))
