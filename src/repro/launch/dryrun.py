import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import (jax locks the device
count at first init); this module is the only place the 512 placeholder
devices are created — tests and benches see 1 device.

For each cell this driver:
  1. builds the production mesh (16x16 single pod / 2x16x16 pod pair),
  2. lowers train_step (train_4k) or prefill/decode serve steps with
     ShapeDtypeStruct inputs sharded per the logical rules,
  3. compiles, prints memory_analysis() (proves it fits) and
     cost_analysis() (FLOPs/bytes for the roofline),
  4. parses the post-SPMD HLO for collective ops and sums their bytes,
  5. writes artifacts/dryrun/<arch>__<shape>__<mesh>[__tag].json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import pathlib
import re
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ALL_SHAPES, ARCH_NAMES, get_config, shapes_for
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.parallel.sharding import make_env, tree_shardings
from repro.train import train_step as TS

ARTIFACTS = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _mem_analysis_dict(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    out = {}
    for field in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
        v = getattr(ma, field, None)
        if v is not None:
            out[field] = int(v)
    if out:
        out["total_hbm_bytes"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0))
    return out


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, run: RunConfig,
               rule_overrides=()):
    """Build and lower the cell's step function. Returns jax.stages.Lowered."""
    mode = shape.mode
    rules_mode = ("long_decode" if (mode == "decode" and shape.seq_len > 100_000)
                  else mode)
    env = make_env(mesh, rules_mode,
                   overrides=tuple(cfg.sharding_overrides)
                   + tuple(rule_overrides))

    if mode == "train":
        step = TS.make_train_step(cfg, run, env)
        npod = mesh.shape["pod"] if "pod" in mesh.axis_names else 1
        state_struct = TS.train_state_struct(cfg, run, npod=npod)
        state_specs = TS.state_logical_specs(cfg, run)
        state_sh = tree_shardings(env, state_specs, state_struct)
        batch_struct = M.input_specs(cfg, shape, run)
        batch_sh = tree_shardings(env, TS.batch_logical_specs(cfg, "train"),
                                  batch_struct)
        jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         donate_argnums=(0,))
        return jitted.lower(state_struct, batch_struct), env

    params_struct = M.param_shapes(cfg, run)
    p_sh = tree_shardings(env, M.param_specs(cfg), params_struct)
    if mode == "prefill":
        prefill_fn, _ = TS.make_serve_steps(cfg, run, env)
        batch_struct = M.input_specs(cfg, shape, run)
        batch_sh = tree_shardings(env, TS.batch_logical_specs(cfg, "prefill"),
                                  batch_struct)
        jitted = jax.jit(prefill_fn, in_shardings=(p_sh, batch_sh))
        return jitted.lower(params_struct, batch_struct), env

    # decode
    _, decode_fn = TS.make_serve_steps(cfg, run, env)
    specs = M.input_specs(cfg, shape, run)
    bls = TS.batch_logical_specs(cfg, "decode")
    tok_sh = tree_shardings(env, bls["token"], specs["token"])
    pos_sh = tree_shardings(env, bls["pos"], specs["pos"])
    cache_sh = tree_shardings(env, bls["cache"], specs["cache"])
    jitted = jax.jit(decode_fn,
                     in_shardings=(p_sh, tok_sh, pos_sh, cache_sh),
                     donate_argnums=(3,))
    return jitted.lower(params_struct, specs["token"], specs["pos"],
                        specs["cache"]), env


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             run: Optional[RunConfig] = None, tag: str = "",
             save: bool = True, verbose: bool = True,
             rule_overrides=()) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = ALL_SHAPES[shape_name]
    run = run or RunConfig()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    lowered, env = lower_cell(cfg, shape, mesh, run,
                              rule_overrides=rule_overrides)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = H.cost_analysis_dict(compiled)
    mem = _mem_analysis_dict(compiled)
    t0 = time.time()
    hlo = H.analyze(compiled.as_text())
    t_analyze = time.time() - t0
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag,
        "num_devices": mesh.size,
        "mode": shape.mode,
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
        # loop-aware per-device numbers (see hlo_analysis.py)
        "flops_per_device": float(hlo.get("flops", 0.0)),
        "bytes_per_device": float(hlo.get("bytes", 0.0)),
        "bytes_hbm_model_per_device": float(hlo.get("bytes_hbm_model", 0.0)),
        "collectives": hlo,
        # raw cost_analysis for reference (undercounts while-loop bodies)
        "cost_analysis_raw": {k: float(v) for k, v in cost.items()
                              if isinstance(v, (int, float))},
        "memory_analysis": mem,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "analyze_s": round(t_analyze, 2),
        "run_config": dataclasses.asdict(run),
    }
    if verbose:
        print(f"== {arch} x {shape_name} x {mesh_kind}"
              + (f" [{tag}]" if tag else ""))
        print(f"   lower {t_lower:.1f}s compile {t_compile:.1f}s | "
              f"flops/dev {result['flops_per_device']:.3e} | "
              f"bytes/dev {result['bytes_per_device']:.3e} | "
              f"coll_eff {hlo['collective_total_effective']:.3e}B "
              f"({hlo['collective_num_ops']} ops)")
        print(f"   memory: {mem}")
    if save:
        ARTIFACTS.mkdir(parents=True, exist_ok=True)
        name = f"{arch}__{shape_name}__{mesh_kind}" + (f"__{tag}" if tag else "")
        (ARTIFACTS / f"{name}.json").write_text(json.dumps(result, indent=1))
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=list(ALL_SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every applicable (arch x shape) cell")
    ap.add_argument("--tag", default="", help="variant tag for artifacts")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--loss-chunk", type=int, default=None)
    ap.add_argument("--compression", default=None)
    ap.add_argument("--rule", action="append", default=[],
                    help="logical=axis[:axis2] sharding-rule override, "
                         "e.g. --rule act_seq=model --rule p_embed=")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    rule_overrides = []
    for r in args.rule:
        k, _, v = r.partition("=")
        axes = tuple(a for a in v.split(":") if a) or None
        if axes and len(axes) == 1:
            axes = axes[0]
        rule_overrides.append((k, axes))

    overrides = {}
    if args.remat is not None:
        overrides["remat_policy"] = args.remat
    if args.loss_chunk is not None:
        overrides["loss_chunk"] = args.loss_chunk
    if args.compression is not None:
        overrides["gradient_compression"] = args.compression
    run = dataclasses.replace(RunConfig(), **overrides)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for arch in ARCH_NAMES:
            for sh in shapes_for(get_config(arch)):
                cells.append((arch, sh.name))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch/--shape required unless --all")
        cells.append((args.arch, args.shape))

    failures = []
    for arch, sh in cells:
        for mk in meshes:
            name = f"{arch}__{sh}__{mk}" + (f"__{args.tag}" if args.tag else "")
            if args.skip_existing and (ARTIFACTS / f"{name}.json").exists():
                print(f"-- skip {name} (exists)")
                continue
            try:
                run_cell(arch, sh, mk, run=run, tag=args.tag,
                         rule_overrides=tuple(rule_overrides))
            except Exception as e:  # record and continue
                failures.append((name, repr(e)[:500]))
                print(f"!! FAIL {name}: {e}")
    if failures:
        print(f"\n{len(failures)} failures:")
        for n, e in failures:
            print(" ", n, e)
        raise SystemExit(1)
    print("\nall cells OK")


if __name__ == "__main__":
    main()
