"""Run lifecycle CLI: inspect, garbage-collect, create and *work*
ledgered runs (``python -m repro.runs ...``).

The run ledger (:mod:`repro.core.ledger`) accumulates one directory per
run under ``results/runs/`` — sweeps, auto-ledgered crash recordings
(``$REPRO_RUN_LEDGER=1``), chaos CI artifacts. This module is the
operator's toolbox over that tree:

* ``list``   — every run with status/progress/age; orphaned ``running``
  runs (process died, leases/heartbeats stale) are repaired to
  ``interrupted`` on sight.
* ``show``   — one run's manifest plus per-chunk shard/lease/resplit
  state and worker summaries; ``--assert-status`` /
  ``--assert-min-takeovers`` make it a CI assertion tool.
* ``gc``     — age-based retention (``--older-than 7d``); live runs are
  protected unless ``--force``.
* ``create`` — seed a run's ledger (manifest with a full ``grid_doc``,
  status ``pending``) without executing anything, so K workers can be
  pointed at it.
* ``work``   — join a run as one cooperating worker:
  ``python -m repro.runs work <run_id> [--jobs N]`` on each host drains
  the run's chunks via lease claiming/heartbeat/takeover
  (``run_grid(coordinate=True)``); records land bit-identical to a
  serial run no matter how many workers join, die, or duplicate work.

Exit codes: 0 ok; 1 usage/run errors; 4 a ``work`` run finished but
with quarantined/truncated cells; 70 worker died on a fatal heartbeat
(fault-injected or lease stolen — the chaos path).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import List, Optional

from repro.core import ledger as _ledger


def _fmt_age(seconds: float) -> str:
    seconds = max(seconds, 0.0)
    for unit, span in (("d", 86400.0), ("h", 3600.0), ("m", 60.0)):
        if seconds >= span:
            return f"{seconds / span:.1f}{unit}"
    return f"{seconds:.0f}s"


def _parse_age(text: str) -> float:
    """``7d`` / ``12h`` / ``30m`` / ``45s`` (bare numbers are days)."""
    text = text.strip().lower()
    mult = {"d": 86400.0, "h": 3600.0, "m": 60.0, "s": 1.0}
    if text and text[-1] in mult:
        return float(text[:-1]) * mult[text[-1]]
    return float(text) * 86400.0


def _ledgers() -> List[_ledger.RunLedger]:
    root = _ledger.runs_root()
    if not root.is_dir():
        return []
    out = []
    for path in sorted(root.iterdir()):
        if path.is_dir() and (path / "manifest.json").exists():
            try:
                out.append(_ledger.RunLedger(path.name))
            except ValueError:
                continue
    return out


def _run_info(led: _ledger.RunLedger, stale_after: Optional[float],
              repair: bool) -> dict:
    led.load()
    if repair:
        led.repair_if_stale(stale_after)
        status = str(led.manifest.get("status", "unknown"))
    else:
        status = led.probe_status(stale_after)
    leases = led.leases()
    return {
        "run_id": led.run_id,
        "status": status,
        "cells": led.manifest.get("cells"),
        "shards": len(led.completed_keys()),
        "leases_live": sum(1 for l in leases if not l["expired"]),
        "leases_expired": sum(1 for l in leases if l["expired"]),
        "resplits": len(led.load_resplits()),
        "workers": len(led.worker_summaries()),
        "interruptions": int(led.manifest.get("interruptions", 0) or 0),
        "age_s": time.time() - led.last_activity_ts(),
        "engine": led.manifest.get("engine"),
    }


# ------------------------------------------------------------ subcommands

def _cmd_list(args) -> int:
    infos = [_run_info(led, args.stale_after, repair=not args.no_repair)
             for led in _ledgers()]
    if args.json:
        print(json.dumps(infos, indent=1, sort_keys=True))
        return 0
    if not infos:
        print(f"# no runs under {_ledger.runs_root()}")
        return 0
    hdr = f"{'RUN':<32} {'STATUS':<12} {'SHARDS':>6} {'CELLS':>5} " \
          f"{'LEASES':>6} {'AGE':>7}"
    print(hdr)
    for inf in infos:
        leases = f"{inf['leases_live']}+{inf['leases_expired']}e" \
            if inf["leases_expired"] else str(inf["leases_live"])
        print(f"{inf['run_id']:<32} {inf['status']:<12} "
              f"{inf['shards']:>6} {str(inf['cells'] or '?'):>5} "
              f"{leases:>6} {_fmt_age(inf['age_s']):>7}")
    return 0


def _takeovers(led: _ledger.RunLedger) -> int:
    total = 0
    for doc in led.worker_summaries():
        total += int(doc.get("lease_takeovers", 0) or 0)
    # in-flight takeovers not yet summarized
    total += sum(1 for l in led.leases() if l.get("takeover_of"))
    return total


def _cmd_show(args) -> int:
    led = _ledger.RunLedger(args.run_id)
    try:
        led.load()
    except ValueError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 1
    info = _run_info(led, args.stale_after, repair=not args.no_repair)
    info["grid"] = led.manifest.get("grid")
    info["grid_hash"] = led.manifest.get("grid_hash")
    info["takeovers"] = _takeovers(led)
    info["chunks"] = [{"key": k, "state": "done"}
                      for k in led.completed_keys()]
    for lease in led.leases():
        info["chunks"].append({
            "key": lease["key"], "state": "leased",
            "worker": lease.get("worker"),
            "age_s": round(lease["age"], 3),
            "expired": lease["expired"],
            "takeover_of": lease.get("takeover_of")})
    info["resplit_parents"] = sorted(led.load_resplits())
    info["worker_summaries"] = led.worker_summaries()
    if args.json:
        print(json.dumps(info, indent=1, sort_keys=True))
    else:
        print(f"run {info['run_id']}: status={info['status']} "
              f"cells={info['cells']} shards={info['shards']} "
              f"engine={info['engine']} age={_fmt_age(info['age_s'])} "
              f"interruptions={info['interruptions']} "
              f"takeovers={info['takeovers']}")
        for chunk in info["chunks"]:
            if chunk["state"] == "done":
                print(f"  chunk {chunk['key']}  done")
            else:
                tag = " EXPIRED" if chunk["expired"] else ""
                took = (f" takeover_of={chunk['takeover_of']}"
                        if chunk.get("takeover_of") else "")
                print(f"  chunk {chunk['key']}  leased by "
                      f"{chunk['worker']} ({_fmt_age(chunk['age_s'])} "
                      f"ago){tag}{took}")
        for parent in info["resplit_parents"]:
            print(f"  resplit {parent} -> children adopted")
        for doc in info["worker_summaries"]:
            print(f"  worker {doc.get('worker')}: "
                  f"status={doc.get('status')} "
                  f"claims={doc.get('lease_claims')} "
                  f"takeovers={doc.get('lease_takeovers')} "
                  f"wall={doc.get('wall_s')}s")
    if args.assert_status and info["status"] != args.assert_status:
        print(f"error: status {info['status']!r} != "
              f"{args.assert_status!r}", file=sys.stderr)
        return 1
    if args.assert_min_takeovers is not None \
            and info["takeovers"] < args.assert_min_takeovers:
        print(f"error: takeovers {info['takeovers']} < "
              f"{args.assert_min_takeovers}", file=sys.stderr)
        return 1
    return 0


def _cmd_gc(args) -> int:
    cutoff = _parse_age(args.older_than)
    now = time.time()
    removed, kept = [], []
    for led in _ledgers():
        led.load()
        age = now - led.last_activity_ts()
        status = led.probe_status(args.stale_after)
        if age < cutoff:
            kept.append((led.run_id, "young", age))
            continue
        if status == "running" and not args.force:
            kept.append((led.run_id, "live", age))
            continue
        removed.append((led.run_id, status, age))
        if not args.dry_run:
            led.remove()
    verb = "would remove" if args.dry_run else "removed"
    for run_id, status, age in removed:
        print(f"# {verb} {run_id} ({status}, idle {_fmt_age(age)})")
    for run_id, why, age in kept:
        if why == "live":
            print(f"# kept {run_id}: still running (use --force)")
    print(f"# gc: {len(removed)} {verb.split()[-1]}, {len(kept)} kept")
    return 0


def _cmd_create(args) -> int:
    from repro.core import runner as _runner
    grid = _runner.ExperimentGrid(
        name=args.name or args.run_id,
        workloads=tuple(args.workloads.split(",")),
        policies=tuple(args.policies.split(",")),
        scale=args.scale, seed=args.seed,
        gpu=(_runner.GPUConfig(num_sms=args.num_sms)
             if args.num_sms and args.num_sms > 1 else None),
        best_swl_limits=tuple(int(x) for x in args.limits.split(","))
        if args.limits else (2, 4, 6, 8, 16, 32, 48))
    led = _ledger.RunLedger(args.run_id)
    if led.manifest_path.exists() and not args.force:
        print(f"error: run {args.run_id!r} already exists "
              f"(--force recreates)", file=sys.stderr)
        return 1
    ghash = _ledger.grid_hash(grid)
    led.open({"grid_hash": ghash, "grid": _runner._grid_meta(grid),
              "grid_doc": _runner.grid_to_doc(grid),
              "engine": args.engine, "jobs": None, "strict": False,
              "cells": len(_runner.expand_grid(grid))},
             status="pending")
    print(f"# created run {args.run_id}: "
          f"{led.manifest['cells']} cells, grid {ghash[:10]}, "
          f"status pending — drain with "
          f"`python -m repro.runs work {args.run_id}`")
    return 0


def _cmd_work(args) -> int:
    from repro.core import runner as _runner
    led = _ledger.RunLedger(args.run_id)
    try:
        manifest = led.load()
    except ValueError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 1
    grid_doc = manifest.get("grid_doc")
    if not grid_doc:
        print(f"error: run {args.run_id!r} has no grid_doc in its "
              "manifest (created before distributed runs?) — "
              "cannot reconstruct the grid", file=sys.stderr)
        return 1
    grid = _runner.grid_from_doc(grid_doc)
    wid = args.worker or _ledger.worker_id()
    engine = args.engine or manifest.get("engine") or "auto"
    t0 = time.monotonic()
    status = "crashed"
    try:
        records = _runner.run_grid(
            grid, engine=engine, jobs=args.jobs, strict=args.strict,
            retries=args.retries, deadline_s=args.deadline,
            resume=args.run_id, coordinate=True,
            chunk_budget_s=args.chunk_budget,
            lease_ttl_s=args.lease_ttl, worker=wid,
            heartbeat_fatal=True)
        failed = [r for r in records
                  if isinstance(r, _runner.FailedCell)]
        status = ("truncated" if any(f.truncated for f in failed)
                  else "partial" if failed else "complete")
        if args.out:
            _runner.save_records(records, args.out, grid=grid)
    finally:
        perf = _runner.last_batched_perf()
        doc = {"status": status,
               "wall_s": round(time.monotonic() - t0, 3),
               "cells": len(_runner.expand_grid(grid))}
        for key in ("chunks", "chunks_resumed", "resplit_chunks",
                    "failed_cells", "lease_claims", "lease_conflicts",
                    "lease_takeovers", "lease_wait_s", "heartbeats",
                    "heartbeat_failures", "leases_stolen"):
            if key in perf:
                doc[key] = perf[key]
        try:
            led.save_worker_summary(wid, doc)
        except OSError:
            pass
    print(f"# worker {wid}: {status} in {doc['wall_s']}s — "
          f"claims={doc.get('lease_claims', 0):.0f} "
          f"conflicts={doc.get('lease_conflicts', 0):.0f} "
          f"takeovers={doc.get('lease_takeovers', 0):.0f} "
          f"resplits={doc.get('resplit_chunks', 0):.0f} "
          f"failed={doc.get('failed_cells', 0):.0f}")
    return 0 if status == "complete" else 4


# ------------------------------------------------------------------ main

def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.runs",
        description="Run-ledger lifecycle tools (see module docstring). "
                    "$REPRO_RUNS_DIR overrides the ledger root.")
    sub = ap.add_subparsers(dest="cmd", required=True)

    def common(p):
        p.add_argument("--stale-after", type=float, default=None,
                       help="seconds of silence before a 'running' run "
                            "counts as interrupted (default "
                            "max($REPRO_LEASE_TTL, 600))")
        p.add_argument("--no-repair", action="store_true",
                       help="report staleness but do not rewrite "
                            "manifests")
        p.add_argument("--json", action="store_true")

    p = sub.add_parser("list", help="list runs with status/progress/age")
    common(p)
    p.set_defaults(fn=_cmd_list)

    p = sub.add_parser("show", help="one run's manifest + chunk state")
    p.add_argument("run_id")
    common(p)
    p.add_argument("--assert-status", default=None,
                   help="exit 1 unless the run has this status")
    p.add_argument("--assert-min-takeovers", type=int, default=None,
                   help="exit 1 unless >= N lease takeovers happened")
    p.set_defaults(fn=_cmd_show)

    p = sub.add_parser("gc", help="age-based retention over results/runs")
    p.add_argument("--older-than", required=True,
                   help="remove runs idle longer than this (7d, 12h, "
                        "30m, 45s; bare number = days)")
    p.add_argument("--dry-run", action="store_true")
    p.add_argument("--force", action="store_true",
                   help="remove even runs that look live")
    p.add_argument("--stale-after", type=float, default=None)
    p.set_defaults(fn=_cmd_gc)

    p = sub.add_parser("create",
                       help="seed a run's ledger (status pending) for "
                            "workers to drain")
    p.add_argument("run_id")
    p.add_argument("--workloads", required=True,
                   help="comma-separated workload names")
    p.add_argument("--policies", required=True,
                   help="comma-separated policy names")
    p.add_argument("--scale", type=float, default=0.5)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--limits", default=None,
                   help="comma-separated best-swl/statpcal limit sweep")
    p.add_argument("--num-sms", type=int, default=1)
    p.add_argument("--engine", default="auto")
    p.add_argument("--name", default=None,
                   help="grid name (default: the run id)")
    p.add_argument("--force", action="store_true")
    p.set_defaults(fn=_cmd_create)

    p = sub.add_parser("work",
                       help="join a run as one cooperating worker")
    p.add_argument("run_id")
    p.add_argument("--jobs", type=int, default=None)
    p.add_argument("--engine", default=None,
                   help="override the engine recorded in the manifest")
    p.add_argument("--strict", action="store_true")
    p.add_argument("--retries", type=int, default=1)
    p.add_argument("--deadline", type=float, default=None,
                   help="wall-clock bound for this worker (seconds)")
    p.add_argument("--chunk-budget", type=float, default=None,
                   help="per-chunk wall-clock budget; chunks over it "
                        "are re-sharded at cell boundaries")
    p.add_argument("--lease-ttl", type=float, default=None,
                   help="chunk lease TTL (default $REPRO_LEASE_TTL "
                        "or 30s)")
    p.add_argument("--worker", default=None,
                   help="worker id (default <hostname>-<pid>)")
    p.add_argument("--out", default=None,
                   help="also save assembled records JSON here")
    p.set_defaults(fn=_cmd_work)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
