"""Minimal property-testing fallback for environments without
``hypothesis``.

``tests/conftest.py`` calls :func:`install` when the real package is
missing (it is a dev dependency — see ``pyproject.toml`` — but some
sandboxes can't install it). The stub registers ``hypothesis`` /
``hypothesis.strategies`` modules implementing the small API surface our
tests use: ``given``, ``settings``, and the ``integers`` / ``booleans`` /
``floats`` / ``sampled_from`` / ``lists`` / ``tuples`` strategies.

``given`` re-runs the test body ``max_examples`` times with values drawn
from a per-test deterministic RNG (seeded by crc32 of the test name), so
runs are reproducible. No shrinking, no database — failures report the
drawn arguments and nothing more.
"""
from __future__ import annotations

import random
import sys
import types
import zlib

_DEFAULT_MAX_EXAMPLES = 25


class SearchStrategy:
    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value=None, max_value=None) -> SearchStrategy:
    lo = -(2 ** 31) if min_value is None else min_value
    hi = 2 ** 31 if max_value is None else max_value
    return SearchStrategy(lambda rng: rng.randint(lo, hi))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.random() < 0.5)


def floats(min_value=0.0, max_value=1.0, **_kw) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.uniform(min_value, max_value))


def sampled_from(elements) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(lambda rng: elements[rng.randrange(len(elements))])


def lists(elements: SearchStrategy, min_size=0, max_size=None,
          **_kw) -> SearchStrategy:
    hi = max_size if max_size is not None else min_size + 10

    def draw(rng):
        n = rng.randint(min_size, hi)
        return [elements.example_from(rng) for _ in range(n)]
    return SearchStrategy(draw)


def tuples(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: tuple(s.example_from(rng) for s in strategies))


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, **_kw):
    def decorate(fn):
        fn._stub_max_examples = max_examples
        return fn
    return decorate


def given(*strategies: SearchStrategy):
    def decorate(fn):
        # *args-only signature so pytest doesn't mistake the strategy
        # parameters for fixtures.
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples",
                                _DEFAULT_MAX_EXAMPLES))
            seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())
            rng = random.Random(seed)
            for _ in range(n):
                drawn = tuple(s.example_from(rng) for s in strategies)
                try:
                    fn(*args, *drawn, **kwargs)
                except Exception as exc:
                    raise AssertionError(
                        f"{fn.__name__} failed on drawn arguments "
                        f"{drawn!r}: {exc}") from exc
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.__dict__.update(fn.__dict__)
        return wrapper
    return decorate


def install() -> None:
    """Register the stub as ``hypothesis`` if the real one is absent."""
    if "hypothesis" in sys.modules:
        return
    mod = types.ModuleType("hypothesis")
    mod.__doc__ = "stub (repro._compat.hypothesis_stub)"
    mod.given = given
    mod.settings = settings
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "booleans", "floats", "sampled_from", "lists",
                 "tuples"):
        setattr(st, name, globals()[name])
    st.SearchStrategy = SearchStrategy
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
