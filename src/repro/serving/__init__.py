from repro.serving.pages import PagePool, PoolConfig  # noqa: F401
from repro.serving.engine import (  # noqa: F401
    Request, ServeConfig, ServeEngine, ServeStats, synth_requests)
