"""Paged KV-cache pool with pinning, prefix cache, and CIAO victim tracking.

The serving-side analogue of the paper's on-chip memory (DESIGN.md §2.2):

* **main pool**    = L1D — holds pinned pages of running sequences plus the
  unpinned *prefix cache* (session groups share system-prompt pages,
  vLLM-style). Only unpinned pages are evictable (LRU).
* **reserve pool** = the *unused shared memory*: provisioned for prefill
  bursts, idle in steady state. CIAO-P redirects the private-page
  allocations of *interfering* sequences here.

Victim tracking feeds the same :class:`InterferenceDetector` as the SM
simulator. Owners are stable ids: private pages are owned by their slot,
prefix pages by a *group pseudo-warp* (id >= slots), so a later request of
the same session probes the right VTA set — a hit means "this group is
being thrashed by that evictor slot" and costs a re-prefill.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, Optional, Set, Tuple

from repro.core.interference import InterferenceDetector

PageKey = Tuple[int, int]


@dataclasses.dataclass
class PoolConfig:
    main_pages: int = 512
    reserve_pages: int = 128      # "unused shared memory"
    page_tokens: int = 16


class _Page:
    __slots__ = ("owner", "pins", "pool")

    def __init__(self, owner: int, pool: str):
        self.owner = owner
        self.pins: Set[int] = set()
        self.pool = pool


class PagePool:
    def __init__(self, cfg: PoolConfig, detector: InterferenceDetector):
        self.cfg = cfg
        self.det = detector
        self.pages: Dict[PageKey, _Page] = {}
        self.lru: "OrderedDict[PageKey, None]" = OrderedDict()  # unpinned only
        self.counts = {"main": 0, "reserve": 0}
        self.stats = {"hit": 0, "alloc": 0, "evict": 0, "vta_hits": 0,
                      "defer": 0}

    def _cap(self, pool: str) -> int:
        return self.cfg.main_pages if pool == "main" else self.cfg.reserve_pages

    def _evictable(self, pool: str) -> Optional[PageKey]:
        for key in self.lru:
            if self.pages[key].pool == pool:
                return key
        return None

    def _evict(self, key: PageKey, evictor_slot: int) -> None:
        page = self.pages.pop(key)
        self.lru.pop(key, None)
        self.counts[page.pool] -= 1
        self.stats["evict"] += 1
        self.det.on_eviction(page.owner, hash(key) & 0x7FFFFFFF, evictor_slot)

    # -------------------------------------------------------------- public
    def acquire(self, key: PageKey, owner: int, slot: int,
                *, isolated: bool = False) -> str:
        """Pin ``key`` for ``slot``. Returns 'hit' | 'alloc' | 'refetch'
        (alloc of a recently evicted page -> re-prefill) | 'defer' (no
        space: caller must back off this step)."""
        page = self.pages.get(key)
        if page is not None:
            if not page.pins:
                self.lru.pop(key, None)
            page.pins.add(slot)
            self.stats["hit"] += 1
            return "hit"
        pool = "reserve" if isolated else "main"
        cap = self._cap(pool)
        if cap <= 0:
            return "defer"
        while self.counts[pool] >= cap:
            victim = self._evictable(pool)
            if victim is None:
                self.stats["defer"] += 1
                return "defer"
            self._evict(victim, slot)
        refetch = self.det.on_miss(owner, hash(key) & 0x7FFFFFFF) is not None
        if refetch:
            self.stats["vta_hits"] += 1
        page = _Page(owner, pool)
        page.pins.add(slot)
        self.pages[key] = page
        self.counts[pool] += 1
        self.stats["alloc"] += 1
        return "refetch" if refetch else "alloc"

    def unpin(self, key: PageKey, slot: int, *, free: bool = False) -> None:
        page = self.pages.get(key)
        if page is None:
            return
        page.pins.discard(slot)
        if free and not page.pins:
            self.pages.pop(key, None)
            self.lru.pop(key, None)
            self.counts[page.pool] -= 1
        elif not page.pins:
            self.lru[key] = None          # becomes evictable (cached)

    def occupancy(self) -> Tuple[int, int]:
        return self.counts["main"], self.counts["reserve"]

    def pinned_count(self, owner_min: int = 0, pool: str = "") -> int:
        """Number of currently pinned pages with owner id >= owner_min,
        optionally restricted to one pool."""
        return sum(1 for p in self.pages.values()
                   if p.pins and p.owner >= owner_min
                   and (not pool or p.pool == pool))
