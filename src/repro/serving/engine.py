"""Continuous-batching decode engine with CIAO scheduling (DESIGN.md §2.2).

Sequences ("warps") share the paged KV pool ("L1D") and a reserve pool
("unused shared memory"). The engine drives the *same* Algorithm 1
implementation as the SM simulator — :class:`repro.core.policies.CIAOPolicy`
over an :class:`InterferenceDetector` — with "instructions" = scheduled
decode tokens and *session groups* as pseudo-warps (ids >= slots) owning the
shared prefix-cache pages:

  * a sequence whose private-page allocations keep evicting session prefix
    caches gets **isolated** (CIAO-P): its new pages come from the reserve
    pool — prefix caches stop thrashing, batch occupancy untouched;
  * if the reserve pool itself thrashes, the most-interfering sequence is
    **paused** (CIAO-T) and resumed in reverse order (Algorithm 1).

Policies: gto | ccws | statpcal | ciao-p | ciao-t | ciao-c.
(`ccws` = locality-priority analogue: under pool pressure it throttles the
sequences with the *least* prefix reuse; `statpcal` = bypass: blamed
interferers' pages are not cached, paying a streaming cost instead.)

The model is abstracted behind a cost model (1 unit per decoded token,
``page_tokens`` units per [re-]prefilled page) so benches are exact and
fast; ``examples/serve_ciao.py`` wires a real JAX model runner instead.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core import faults
from repro.core.faults import InjectedFault
from repro.core.interference import DetectorConfig, InterferenceDetector
from repro.core.policies import CIAOPolicy
from repro.serving.pages import PagePool, PoolConfig


@dataclasses.dataclass
class Request:
    rid: int
    group: int                 # session group (shares a cached prefix)
    prefix_pages: int          # shared prompt length, in pages
    decode_tokens: int         # tokens to generate
    arrived: int = 0
    progress: int = 0          # tokens generated before a preemption


@dataclasses.dataclass
class ServeConfig:
    slots: int = 48                        # concurrent sequences
    groups: int = 16                       # session-group pseudo-warps
    pool: PoolConfig = dataclasses.field(default_factory=PoolConfig)
    policy: str = "ciao-c"
    # admission estimates decode length (real engines don't know it):
    # requests exceeding the estimate are the overcommit/interference source
    expected_decode_tokens: int = 128
    detector: DetectorConfig = dataclasses.field(
        default_factory=lambda: DetectorConfig(high_epoch=512, low_epoch=64))
    max_steps: int = 1_000_000


@dataclasses.dataclass
class ServeStats:
    steps: int = 0
    decoded_tokens: int = 0
    prefill_pages: int = 0
    refetched_pages: int = 0
    deferred: int = 0
    preemptions: int = 0
    recompute_tokens: int = 0
    work_units: float = 0.0        # decode tokens + (re)prefill/recompute cost
    completed: int = 0
    occupancy_sum: float = 0.0
    injected_faults: int = 0       # absorbed serve.* fault injections

    @property
    def tokens_per_unit(self) -> float:
        return self.decoded_tokens / max(self.work_units, 1e-9)

    @property
    def goodput(self) -> float:
        """decoded tokens per engine step (serving IPC analogue)."""
        return self.decoded_tokens / max(self.steps, 1)

    @property
    def mean_occupancy(self) -> float:
        return self.occupancy_sum / max(self.steps, 1)


class _Seq:
    __slots__ = ("req", "pos", "own_pages", "prefix_keys", "done", "defers")

    def __init__(self, req: Request):
        self.req = req
        self.pos = req.progress
        self.own_pages: List = []
        self.prefix_keys: List = []
        self.done = False
        self.defers = 0


class ServeEngine:
    def __init__(self, cfg: ServeConfig):
        self.cfg = cfg
        n_ids = cfg.slots + cfg.groups          # slots + group pseudo-warps
        det_cfg = dataclasses.replace(cfg.detector, num_warps=n_ids,
                                      list_entries=max(64, n_ids))
        self.det = InterferenceDetector(det_cfg)
        self.pool = PagePool(cfg.pool, self.det)
        self.policy: Optional[CIAOPolicy] = None
        if cfg.policy in ("ciao-p", "ciao-t", "ciao-c"):
            self.policy = CIAOPolicy(n_ids, self.det, mode=cfg.policy[-1])
        self.slots: List[Optional[_Seq]] = [None] * cfg.slots
        self.waiting: List[Request] = []
        self.stats = ServeStats()
        self._ccws_blocked: Set[int] = set()
        self._bypass: Set[int] = set()

    def _pages_needed(self, req: Request, page_tokens: int) -> int:
        # private pages only, using the *estimated* decode length — the
        # engine does not know the true length; heavy requests exceed the
        # estimate, creating the overcommit CIAO then has to manage.
        est = max(self.cfg.expected_decode_tokens, req.progress)
        return -(-est // page_tokens)

    def _group_id(self, group: int) -> int:
        return self.cfg.slots + (group % self.cfg.groups)

    # ------------------------------------------------------------ requests
    def submit(self, reqs: Sequence[Request]) -> None:
        self.waiting.extend(reqs)

    def _admit(self) -> None:
        # occupancy-based admission: only *actually pinned* pages count
        # against the budget (cached prefix pages are evictable); a request
        # is admitted when its estimated need fits the real headroom.
        budget = int(0.92 * self.cfg.pool.main_pages) \
            - self.pool.pinned_count(pool="main")
        for i in range(self.cfg.slots):
            if self.slots[i] is None and self.waiting:
                # fired before any pool mutation: an injected admission
                # fault (absorbed in step()) skips this step's admissions
                # but can never leak pins or lose the request
                faults.fire("serve.admit",
                            key=f"rid:{self.waiting[0].rid}")
                need = self._pages_needed(self.waiting[0],
                                          self.cfg.pool.page_tokens) \
                    + self.waiting[0].prefix_pages
                if need > budget:
                    return          # no headroom: don't deadlock the pool
                req = self.waiting.pop(0)
                budget -= need
                seq = _Seq(req)
                gid = self._group_id(req.group)
                ok = True
                for p in range(req.prefix_pages):
                    key = (1_000_000 + req.group, p)
                    r = self.pool.acquire(key, gid, i)
                    if r == "defer":
                        ok = False
                        break
                    seq.prefix_keys.append(key)
                    if r in ("alloc", "refetch"):
                        self.stats.prefill_pages += 1
                        self.stats.work_units += self.cfg.pool.page_tokens
                        if r == "refetch":
                            self.stats.refetched_pages += 1
                if not ok:
                    # roll back pins, requeue the request
                    for key in seq.prefix_keys:
                        self.pool.unpin(key, i)
                    self.waiting.insert(0, req)
                    return
                # recompute the KV of previously generated tokens after a
                # preemption (vLLM recompute-preemption cost model)
                if req.progress:
                    self.stats.recompute_tokens += req.progress
                    self.stats.work_units += req.progress
                    for p in range(-(-req.progress // self.cfg.pool.page_tokens)):
                        key = (req.rid, p)
                        if self.pool.acquire(key, i, i,
                                             isolated=self._isolated(i)) != "defer":
                            seq.own_pages.append(key)
                self.slots[i] = seq

    # ------------------------------------------------------------- policy
    def _allowed(self, slot: int) -> bool:
        if self.policy is not None:
            return self.policy.allow(slot)
        if self.cfg.policy == "ccws":
            return slot not in self._ccws_blocked
        return True

    def _isolated(self, slot: int) -> bool:
        return self.policy is not None and self.policy.is_isolated(slot)

    # ---------------------------------------------------------------- step
    def step(self) -> int:
        """One decode step over the running batch. Returns tokens decoded."""
        try:
            self._admit()
        except InjectedFault:
            self.stats.injected_faults += 1   # admission down this step
        decoded = 0
        for i, seq in enumerate(self.slots):
            if seq is None or seq.done or not self._allowed(i):
                continue
            # page boundary first: need a fresh private KV page to write to
            if seq.pos % self.cfg.pool.page_tokens == 0:
                key = (seq.req.rid, seq.pos // self.cfg.pool.page_tokens)
                if self.cfg.policy == "statpcal" and i in self._bypass:
                    self.stats.work_units += 2.0   # uncached stream cost
                else:
                    try:
                        faults.fire("serve.page_alloc",
                                    key=f"rid:{seq.req.rid}")
                        r = self.pool.acquire(key, i, i,
                                              isolated=self._isolated(i))
                    except InjectedFault:
                        # transient allocation failure: feed the normal
                        # defer/preempt path, accounting stays exact
                        self.stats.injected_faults += 1
                        r = "defer"
                    if r == "defer":
                        self.stats.deferred += 1
                        seq.defers += 1
                        # reserve-pool thrash: the isolated interferer's
                        # redirection stopped being effective -> stall it
                        # instead of letting it force preemptions (§III-C)
                        if self.policy is not None and self._isolated(i) \
                                and self.policy.mode != "p":
                            trig = self.det.isolation_trigger(i)
                            if trig < 0:
                                trig = self._group_id(seq.req.group)
                            if self.policy.stall_directly(i, trig):
                                seq.defers = 0
                                continue
                        if seq.defers > 2:
                            self._preempt_youngest(exclude=i)
                            seq.defers = 0
                        continue
                    seq.defers = 0
                    if r == "refetch":
                        self.stats.refetched_pages += 1
                        self.stats.work_units += self.cfg.pool.page_tokens
                    seq.own_pages.append(key)
            seq.pos += 1
            decoded += 1
            self.det.on_instruction()
            self.stats.work_units += 1.0
            if seq.pos >= seq.req.decode_tokens:
                seq.done = True
                self.stats.completed += 1
                for key in seq.own_pages:
                    self.pool.unpin(key, i, free=True)
                for key in seq.prefix_keys:
                    self.pool.unpin(key, i)        # stays cached for reuse
                self.slots[i] = None
                if self.policy is not None:
                    self.policy.on_warp_done(i)

        # epoch-driven scheduling decisions (groups are never 'done')
        n_ids = self.cfg.slots + self.cfg.groups
        done_flags = [(i < self.cfg.slots
                       and (self.slots[i] is None or self.slots[i].done))
                      for i in range(n_ids)]
        if decoded == 0 and self.policy is not None:
            # everything stalled: advance the epoch clock so reactivation
            # (Algorithm 1 low-cutoff test) can fire
            self.det.on_instruction(self.cfg.detector.low_epoch)
        if self.policy is not None:
            self.policy.epoch_tick(list(range(n_ids)), done_flags)
        elif self.cfg.policy == "ccws":
            self._ccws_tick()
        elif self.cfg.policy == "statpcal":
            self._statpcal_tick()

        self.stats.steps += 1
        self.stats.decoded_tokens += decoded
        self.stats.occupancy_sum += sum(
            1 for s in self.slots if s and not s.done)
        return decoded

    def _preempt_youngest(self, exclude: int) -> None:
        """Free the youngest running sequence's pages (recompute later)."""
        try:
            faults.fire("serve.preempt", key=f"exclude:{exclude}")
        except InjectedFault:
            self.stats.injected_faults += 1   # skip this preemption round
            return
        victim = None
        for i, s in enumerate(self.slots):
            if s is None or s.done or i == exclude:
                continue
            if victim is None or s.req.rid > self.slots[victim].req.rid:
                victim = i
        if victim is None:
            return
        seq = self.slots[victim]
        for key in seq.own_pages:
            self.pool.unpin(key, victim, free=True)
        for key in seq.prefix_keys:
            self.pool.unpin(key, victim)
        req = dataclasses.replace(seq.req, progress=seq.pos)
        self.waiting.insert(0, req)
        self.slots[victim] = None
        self.stats.preemptions += 1
        if self.policy is not None:
            self.policy.on_warp_done(victim)

    def _ccws_tick(self) -> None:
        main_occ, _ = self.pool.occupancy()
        self._ccws_blocked.clear()
        if main_occ < int(0.95 * self.cfg.pool.main_pages):
            return
        scores = sorted((s.req.prefix_pages, i)
                        for i, s in enumerate(self.slots) if s and not s.done)
        for _, i in scores[: len(scores) // 2]:
            self._ccws_blocked.add(i)

    def _statpcal_tick(self) -> None:
        main_occ, _ = self.pool.occupancy()
        self._bypass = set()
        if main_occ >= int(0.95 * self.cfg.pool.main_pages):
            for i in range(self.cfg.slots + self.cfg.groups):
                j = self.det.most_interfering(i)
                if 0 <= j < self.cfg.slots:
                    self._bypass.add(j)

    # ----------------------------------------------------------------- run
    def run(self, reqs: Sequence[Request]) -> ServeStats:
        self.submit(list(reqs))
        idle = 0
        while (any(s for s in self.slots) or self.waiting) and \
                self.stats.steps < self.cfg.max_steps:
            d = self.step()
            idle = idle + 1 if d == 0 else 0
            if idle > 10_000:
                break   # wedged (policy throttled everything) — bail out
        return self.stats


def synth_requests(n: int = 256, *, groups: int = 8, prefix_pages: int = 24,
                   decode_tokens: int = 160, heavy_frac: float = 0.2,
                   heavy_decode: int = 1200, seed: int = 0) -> List[Request]:
    """Sessions share big prefixes; a few 'heavy' long-decode requests grow
    private KV aggressively — the serving interferers."""
    rng = np.random.default_rng(seed)
    out = []
    for rid in range(n):
        heavy = rng.random() < heavy_frac
        out.append(Request(
            rid=rid,
            group=int(rng.integers(0, groups)),
            prefix_pages=prefix_pages,
            decode_tokens=heavy_decode if heavy else decode_tokens,
        ))
    return out
