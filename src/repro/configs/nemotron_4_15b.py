"""nemotron-4-15b [dense]: GQA + squared-ReLU MLP.

32L d_model=6144 48H (GQA kv=8, head_dim=128) d_ff=24576 vocab=256000
[arXiv:2402.16819; unverified]. Non-gated squared-ReLU MLP, untied
embeddings, rotary embeddings. Pure full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    pattern=("global",),
    mlp_activation="squared_relu",
    tie_embeddings=False,
    embed_scale=False,
    rope_theta=10000.0,
    supports_long_context=False,
)
