"""granite-moe-3b-a800m [moe]: 40-expert top-8 fine-grained MoE.

32L d_model=1536 24H (GQA kv=8, head_dim=64) d_ff=512 (per expert)
vocab=49155, MoE 40e top-8 [ibm-granite/granite-3.0 family; hf]. (The
assignment line says "40e top-8"; the bracketed hf pointer mentions 32e -
we follow the explicit config: 40 experts.) 40 % 16 != 0, so experts use
TP-inside-expert (per-expert d_ff sharded over the model axis) instead of
EP - see DESIGN.md. Pure full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    pattern=("global",),
    num_experts=40,
    num_experts_per_tok=8,
    moe_d_ff=512,
    moe_dense_residual=False,
    moe_parallelism="tp",
    mlp_activation="swiglu",
    tie_embeddings=True,
    embed_scale=False,
    rope_theta=10000.0,
    supports_long_context=False,
)
