"""seamless-m4t-medium [audio]: encoder-decoder multimodal backbone.

12L (decoder) + 12L encoder, d_model=1024 16H (MHA kv=16, head_dim=64)
d_ff=4096 vocab=256206 [arXiv:2308.11596; hf]. The audio frontend
(w2v-BERT conformer) is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings fed to the text/unit encoder.
Decoder cross-attends to the encoder output; decode shapes run the decoder
step (self-attn KV cache + cross-attn KV over the 32k source). Full
attention everywhere -> long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    pattern=("global",),
    mlp_activation="gelu",
    attn_bias=True,
    is_encoder_decoder=True,
    num_encoder_layers=12,
    frontend="audio",
    frontend_len=0,
    tie_embeddings=True,
    embed_scale=False,
    rope_theta=10000.0,
    supports_long_context=False,
)
