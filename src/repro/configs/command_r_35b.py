"""command-r-35b [dense]: GQA, no-bias, parallel attn+FFN residual blocks.

40L d_model=8192 64H (GQA kv=8, head_dim=128) d_ff=22528 vocab=256000
[hf:CohereForAI/c4ai-command-r-v01; unverified]. Parallel residual blocks
(attention and FFN read the same norm, summed into the residual), tied
embeddings, large rope theta. Pure full attention -> long_500k skipped.
Largest KV-per-token of the assigned set -> serving interference showcase.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    pattern=("global",),
    parallel_block=True,
    mlp_activation="swiglu",
    tie_embeddings=True,
    embed_scale=False,
    rope_theta=8_000_000.0,
    supports_long_context=False,
)
