"""gemma2-2b [dense]: local+global alternating attention, logit softcaps.

26L d_model=2304 8H (GQA kv=4, head_dim=256) d_ff=9216 vocab=256000
[arXiv:2408.00118; hf]. Sliding window 4096 on local layers; attn softcap 50,
final softcap 30; gelu-gated MLP; tied embeddings; query scale 1/sqrt(256).
Alternating local attention bounds KV growth, so long_500k decode runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    pattern=("local", "global"),
    local_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    query_scale=256 ** -0.5,
    mlp_activation="geglu",
    tie_embeddings=True,
    embed_scale=True,
    rope_theta=10000.0,
    supports_long_context=True,
)
