"""The four assigned input-shape suites (same for every LM-family arch).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of ``seq_len``), not ``train_step``. ``long_500k`` requires
sub-quadratic attention and is skipped for pure full-attention archs
(``ModelConfig.supports_long_context`` — see DESIGN.md §5).
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import ModelConfig, ShapeConfig

TRAIN_4K = ShapeConfig(name="train_4k", seq_len=4096, global_batch=256, mode="train")
PREFILL_32K = ShapeConfig(name="prefill_32k", seq_len=32768, global_batch=32, mode="prefill")
DECODE_32K = ShapeConfig(name="decode_32k", seq_len=32768, global_batch=128, mode="decode")
LONG_500K = ShapeConfig(name="long_500k", seq_len=524288, global_batch=1, mode="decode")

ALL_SHAPES: Dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


def shapes_for(cfg: ModelConfig) -> List[ShapeConfig]:
    """Applicable shapes for an architecture (skips noted in DESIGN.md §5)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.supports_long_context:
        out.append(LONG_500K)
    return out


def skipped_shapes_for(cfg: ModelConfig) -> List[str]:
    return [] if cfg.supports_long_context else [LONG_500K.name]
