"""Base configuration dataclasses for the repro framework.

Every architecture in ``src/repro/configs/<arch>.py`` instantiates a
:class:`ModelConfig`; every benchmark shape is a :class:`ShapeConfig`;
meshes and runtime knobs live in :class:`MeshConfig` / :class:`RunConfig`.

Configs are plain frozen dataclasses (no framework dependency) so they can be
hashed, used as jit static args, and serialized into checkpoints/manifests.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Mapping, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Block kinds understood by models/model.py. A layer stack is described by a
# repeating ``pattern`` of these (e.g. ("local", "global") for gemma2's
# alternating attention); remainder layers (depth % len(pattern)) are applied
# unscanned at the top of the stack.
# ---------------------------------------------------------------------------
BLOCK_GLOBAL_ATTN = "global"  # full (causal/prefix) attention
BLOCK_LOCAL_ATTN = "local"    # sliding-window attention
BLOCK_RGLRU = "rglru"         # RG-LRU recurrent block (recurrentgemma)
BLOCK_SSD = "ssd"             # Mamba-2 state-space duality block
VALID_BLOCKS = (BLOCK_GLOBAL_ATTN, BLOCK_LOCAL_ATTN, BLOCK_RGLRU, BLOCK_SSD)

ATTN_BLOCKS = (BLOCK_GLOBAL_ATTN, BLOCK_LOCAL_ATTN)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description. One instance per assigned architecture."""

    name: str
    family: str                      # dense | hybrid | moe | vlm | ssm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # Layer stack -----------------------------------------------------------
    pattern: Tuple[str, ...] = (BLOCK_GLOBAL_ATTN,)
    local_window: int = 0            # sliding window for BLOCK_LOCAL_ATTN

    # Attention variants ----------------------------------------------------
    use_qk_norm: bool = False        # qwen3-style RMSNorm on q/k heads
    attn_logit_softcap: float = 0.0  # gemma2: tanh softcap on attn logits
    final_logit_softcap: float = 0.0 # gemma2: tanh softcap on lm logits
    query_scale: float = 0.0         # 0 -> 1/sqrt(head_dim)
    rope_theta: float = 10000.0
    parallel_block: bool = False     # command-r: attn & ffn in parallel
    attn_bias: bool = False

    # MLP -------------------------------------------------------------------
    mlp_activation: str = "swiglu"   # swiglu | geglu | gelu | squared_relu

    # MoE -------------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0
    moe_dense_residual: bool = False # arctic: dense FFN residual alongside MoE
    # "ep": experts sharded over model axis (requires num_experts % tp == 0)
    # "tp": experts replicated, per-expert d_ff sharded over model axis
    moe_parallelism: str = "ep"

    # SSM / recurrent -------------------------------------------------------
    ssm_state_dim: int = 0           # Mamba2 N
    ssm_head_dim: int = 64           # Mamba2 P
    ssm_expand: int = 2              # d_inner = expand * d_model
    ssm_chunk: int = 256             # SSD chunk length
    conv_width: int = 4              # depthwise conv width (mamba2 / rglru)
    rglru_width: int = 0             # RG-LRU recurrence width (0 -> d_model)

    # Encoder-decoder -------------------------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0

    # Modality frontend (stub: input_specs provides precomputed embeddings) --
    frontend: str = ""               # "" | "vision" | "audio"
    frontend_len: int = 256          # number of prefix embedding positions
    prefix_lm: bool = False          # full attention over the prefix segment

    # Embeddings ------------------------------------------------------------
    tie_embeddings: bool = True
    embed_scale: bool = True         # gemma-style sqrt(d_model) embed scaling
    norm_eps: float = 1e-6

    # Sharding / runtime overrides (merged over parallel/sharding.py defaults)
    sharding_overrides: Tuple[Tuple[str, Any], ...] = ()
    # Optimizer memory class: "adamw" (fp32 m+v) or "adafactor" (factored).
    optimizer: str = "adamw"
    # Sub-quadratic decode support: archs with every-layer full attention
    # cannot run long_500k (see DESIGN.md §5).
    supports_long_context: bool = False

    # ------------------------------------------------------------------ utils
    def __post_init__(self):
        for b in self.pattern:
            if b not in VALID_BLOCKS:
                raise ValueError(f"unknown block kind {b!r} in pattern")
        if self.num_heads and self.num_heads % max(self.num_kv_heads, 1):
            raise ValueError("num_heads must be a multiple of num_kv_heads")
        if self.num_experts and self.num_experts_per_tok <= 0:
            raise ValueError("MoE config needs num_experts_per_tok > 0")

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def attention_free(self) -> bool:
        return all(b not in ATTN_BLOCKS for b in self.pattern)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def scan_repeats(self) -> int:
        return self.num_layers // len(self.pattern)

    @property
    def remainder_blocks(self) -> Tuple[str, ...]:
        rem = self.num_layers % len(self.pattern)
        return self.pattern[:rem]

    def block_counts(self) -> Mapping[str, int]:
        counts: dict = {}
        for b in self.pattern:
            counts[b] = counts.get(b, 0) + self.scan_repeats
        for b in self.remainder_blocks:
            counts[b] += 1
        return counts

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        n = 0
        n += v * d                                    # token embedding
        if not self.tie_embeddings:
            n += v * d                                # lm head
        gated = self.mlp_activation in ("swiglu", "geglu")
        per_block = {}
        qkv = d * self.num_heads * self.head_dim + 2 * d * self.num_kv_heads * self.head_dim
        o = self.num_heads * self.head_dim * d
        attn = qkv + o + (2 * self.head_dim if self.use_qk_norm else 0)
        mlp = d * ff * (3 if gated else 2)
        per_block[BLOCK_GLOBAL_ATTN] = attn + 2 * d + (mlp + d if not self.num_experts else 0)
        per_block[BLOCK_LOCAL_ATTN] = per_block[BLOCK_GLOBAL_ATTN]
        rw = self.rglru_width or d
        per_block[BLOCK_RGLRU] = (d * rw * 2 + rw * d + 3 * rw + rw * self.conv_width
                                  + 2 * d + mlp)
        di, ns, p = self.d_inner, self.ssm_state_dim, self.ssm_head_dim
        nh = di // p if p else 0
        per_block[BLOCK_SSD] = (d * (2 * di + 2 * ns + nh) + di * d
                                + (di + 2 * ns) * self.conv_width + 2 * nh + di + 2 * d)
        if self.num_experts:
            e_ff = self.moe_d_ff or ff
            moe = self.num_experts * d * e_ff * (3 if gated else 2) + d * self.num_experts
            if self.moe_dense_residual:
                moe += d * ff * (3 if gated else 2)
            per_block[BLOCK_GLOBAL_ATTN] += moe
            per_block[BLOCK_LOCAL_ATTN] += moe
        for kind, cnt in self.block_counts().items():
            n += cnt * per_block[kind]
        n += d                                        # final norm
        if self.is_encoder_decoder:
            # encoder self-attn blocks + decoder cross-attn additions
            n += self.num_encoder_layers * (attn + mlp + 3 * d)
            n += self.num_layers * (attn + d)         # cross attention
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if not self.num_experts:
            return self.param_count()
        d = self.d_model
        gated = self.mlp_activation in ("swiglu", "geglu")
        e_ff = self.moe_d_ff or self.d_ff
        per_expert = d * e_ff * (3 if gated else 2)
        inactive = (self.num_experts - self.num_experts_per_tok) * per_expert
        n_attn_blocks = sum(c for k, c in self.block_counts().items() if k in ATTN_BLOCKS)
        return self.param_count() - inactive * n_attn_blocks

    def fingerprint(self) -> str:
        blob = json.dumps(dataclasses.asdict(self), sort_keys=True, default=str)
        return hashlib.sha1(blob.encode()).hexdigest()[:12]


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One benchmark cell's input shape. ``mode`` selects the lowered fn."""

    name: str
    seq_len: int
    global_batch: int
    mode: str                        # "train" | "prefill" | "decode"

    def __post_init__(self):
        if self.mode not in ("train", "prefill", "decode"):
            raise ValueError(self.mode)


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Runtime knobs for train/serve; defaults are the *baseline* used for
    the paper-faithful §Perf baselines — hillclimbs override these."""

    remat_policy: str = "full"       # full | dots | none
    grad_accum: int = 1
    loss_chunk: int = 0              # 0 = unchunked CE; >0 = seq-chunked remat CE
    attn_chunk: int = 0              # 0 = auto; kv-chunk for online-softmax attn
    gradient_compression: str = ""   # "" | "int8" (cross-pod, error feedback)
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    max_grad_norm: float = 1.0
    seed: int = 0
    param_dtype: str = "bfloat16"
    decode_kv_seq_shard: bool = True  # shard KV cache seq dim over model axis


# v5e-class roofline constants (per chip) used by benchmarks/roofline.py.
PEAK_BF16_FLOPS = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link
