"""Architecture registry: ``get_config(name)`` / ``reduced_config(name)``.

Full configs are only exercised via the dry-run (ShapeDtypeStruct, no
allocation); reduced configs are the CPU smoke-test variants (same family,
same block pattern incl. remainder layers, tiny dims).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.base import ModelConfig, ShapeConfig  # noqa: F401
from repro.configs import shapes as shapes_mod
from repro.configs.shapes import ALL_SHAPES, shapes_for, skipped_shapes_for  # noqa: F401

from repro.configs.gemma2_2b import CONFIG as _gemma2
from repro.configs.nemotron_4_15b import CONFIG as _nemotron
from repro.configs.qwen3_4b import CONFIG as _qwen3
from repro.configs.command_r_35b import CONFIG as _commandr
from repro.configs.recurrentgemma_9b import CONFIG as _rgemma
from repro.configs.arctic_480b import CONFIG as _arctic
from repro.configs.granite_moe_3b_a800m import CONFIG as _granite
from repro.configs.paligemma_3b import CONFIG as _paligemma
from repro.configs.mamba2_2_7b import CONFIG as _mamba2
from repro.configs.seamless_m4t_medium import CONFIG as _seamless

REGISTRY: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _gemma2, _nemotron, _qwen3, _commandr, _rgemma,
        _arctic, _granite, _paligemma, _mamba2, _seamless,
    )
}

ARCH_NAMES: List[str] = list(REGISTRY)


def get_config(name: str) -> ModelConfig:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}") from None


def reduced_config(name: str) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests.

    Keeps the block pattern *and* exercises the remainder-layer path when the
    full config has one (e.g. recurrentgemma's 38 = 3*12 + 2).
    """
    cfg = get_config(name)
    pat = len(cfg.pattern)
    rem = cfg.num_layers % pat
    num_layers = 2 * pat + rem
    num_kv = min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0
    q_per_kv = cfg.q_per_kv if cfg.num_heads else 0
    num_heads = num_kv * min(q_per_kv, 2) if cfg.num_heads else 0
    head_dim = 32 if cfg.head_dim else 0
    experts = min(cfg.num_experts, 8)
    top_k = min(cfg.num_experts_per_tok, max(experts // 2, 1)) if experts else 0
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=num_layers,
        d_model=128,
        num_heads=num_heads,
        num_kv_heads=num_kv,
        head_dim=head_dim,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        local_window=16 if cfg.local_window else 0,
        num_experts=experts,
        num_experts_per_tok=top_k,
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        ssm_state_dim=16 if cfg.ssm_state_dim else 0,
        ssm_head_dim=16 if cfg.ssm_state_dim else cfg.ssm_head_dim,
        ssm_chunk=8,
        rglru_width=128 if cfg.rglru_width else 0,
        num_encoder_layers=2 if cfg.num_encoder_layers else 0,
        frontend_len=8 if cfg.frontend == "vision" else cfg.frontend_len,
        query_scale=0.0,
    )


SMOKE_SHAPE = ShapeConfig(name="smoke", seq_len=64, global_batch=2, mode="train")
