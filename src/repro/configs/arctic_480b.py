"""arctic-480b [moe]: 128-expert top-2 MoE with a dense FFN residual.

35L d_model=7168 56H (GQA kv=8, head_dim=128) d_ff=4864 vocab=32000,
MoE 128e top-2 [hf:Snowflake/snowflake-arctic-base; hf]. Dense-MoE hybrid:
every block runs a small dense FFN residual in parallel with the routed
experts. Expert parallelism over the model axis (128 % 16 == 0 -> 8
experts/chip, all-to-all dispatch). Adafactor optimizer state: Adam's fp32
m/v for 480B params (5.8 TB) exceeds a 512-chip v5e pod-pair's HBM; see
DESIGN.md. Pure full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    pattern=("global",),
    num_experts=128,
    num_experts_per_tok=2,
    moe_d_ff=4864,
    moe_dense_residual=True,
    moe_parallelism="ep",
    mlp_activation="swiglu",
    tie_embeddings=False,
    embed_scale=False,
    rope_theta=10000.0,
    optimizer="adafactor",
    supports_long_context=False,
)
