"""mamba2-2.7b [ssm]: attention-free SSD (state-space duality) stack.

64L d_model=2560 (attn-free) vocab=50280, ssm_state=128 [arXiv:2405.21060;
unverified]. d_inner = 2*d_model = 5120, SSD head dim P=64 -> 80 heads,
depthwise conv width 4, chunked SSD with chunk=256 (MXU-friendly block
matmuls). No MLP blocks (pure Mamba-2 stack). Attention-free -> CIAO's
KV-page interference is inapplicable at serving (documented in DESIGN.md
§5); the ciao_gather kernel still applies to state-block staging.
O(1) decode state -> long_500k runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    pattern=("ssd",),
    ssm_state_dim=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    conv_width=4,
    mlp_activation="gelu",
    tie_embeddings=True,
    embed_scale=False,
    norm_eps=1e-5,
    supports_long_context=True,
)
