"""qwen3-4b [dense]: qk-norm + GQA.

36L d_model=2560 32H (GQA kv=8, head_dim=128) d_ff=9728 vocab=151936
[hf:Qwen/Qwen3-8B family; hf]. RMSNorm on q/k heads (qk_norm), SwiGLU,
tied embeddings, rope theta 1e6. Pure full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151936,
    pattern=("global",),
    use_qk_norm=True,
    mlp_activation="swiglu",
    tie_embeddings=True,
    embed_scale=False,
    rope_theta=1_000_000.0,
    supports_long_context=False,
)
