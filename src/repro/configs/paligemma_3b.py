"""paligemma-3b [vlm]: SigLIP vision frontend (stub) + gemma backbone.

18L d_model=2048 8H (MQA kv=1, head_dim=256) d_ff=16384 vocab=257216
[arXiv:2407.07726; hf]. The SigLIP tower is a STUB per the assignment:
``input_specs()`` provides precomputed, projected patch embeddings
(frontend_len=256 positions) which are prepended to the text embeddings;
attention is prefix-LM (full attention over the image prefix, causal over
text). Pure full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    pattern=("global",),
    mlp_activation="geglu",
    frontend="vision",
    frontend_len=256,
    prefix_lm=True,
    tie_embeddings=True,
    embed_scale=True,
    rope_theta=10000.0,
    supports_long_context=False,
)
