"""recurrentgemma-9b [hybrid]: RG-LRU + local attention, 1 attn : 2 recurrent.

38L d_model=4096 16H (MQA kv=1, head_dim=256) d_ff=12288 vocab=256000
[arXiv:2402.19427; unverified]. Pattern (rglru, rglru, local) x12 + 2
remainder recurrent layers; sliding window 2048; gelu-gated MLP; tied
embeddings; final logit softcap 30. Hybrid (O(1) recurrent state + windowed
KV) -> long_500k decode runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    pattern=("rglru", "rglru", "local"),
    local_window=2048,
    rglru_width=4096,
    conv_width=4,
    final_logit_softcap=30.0,
    mlp_activation="geglu",
    tie_embeddings=True,
    embed_scale=True,
    rope_theta=10000.0,
    supports_long_context=True,
)
