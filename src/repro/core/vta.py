"""Victim Tag Array (paper §II-C, Fig. 3b; Table I: 8 tags/set, 48 sets, FIFO).

Each cache tag carries the WID of the warp that brought the line in. On
eviction we store (victim address, evictor WID) into the VTA *set of the
owner warp* (the warp whose data was evicted). When a warp's memory request
misses L1D but hits its own VTA set, the warp is re-referencing data it
recently lost — a *VTA hit*, the unit of interference evidence:

  * the stored evictor WID identifies the interfering warp,
  * the per-warp VTA-hit counter feeds IRS (Eq. 1).

CIAO uses 8 entries/warp — half of CCWS' 16 (paper §V-F).

Storage is flat tables indexed ``set * tags_per_set + slot``, managed as
per-set circular FIFOs (head + count): ``insert`` is O(1) scalar stores
with no shifting, unlike the seed's deque-of-tuples sets. A per-set
membership dict (addr -> multiplicity) mirrors the occupied slots so the
dominant ``probe`` outcome — a VTA miss — is a single O(1) hash lookup;
only actual VTA hits walk the (≤ tags_per_set) slots to find and pop the
*oldest* matching entry, preserving the seed's FIFO-scan semantics.
``hits`` is a NumPy int64 vector — the detector's epoch snapshots read all
per-warp counters in one vector op instead of 48 calls per crossing.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


class VictimTagArray:
    __slots__ = ("num_sets", "tags_per_set", "addr", "evictor", "_head",
                 "_count", "_member", "hits", "inserts")

    def __init__(self, num_sets: int = 48, tags_per_set: int = 8):
        self.num_sets = num_sets
        self.tags_per_set = tags_per_set
        nf = num_sets * tags_per_set
        self.addr = [-1] * nf               # flat: set * tags_per_set + slot
        self.evictor = [-1] * nf
        # circular-FIFO bookkeeping per set
        self._head = [0] * num_sets
        self._count = [0] * num_sets
        # addr -> number of occupied slots holding it (duplicates possible)
        self._member = [dict() for _ in range(num_sets)]
        self.hits = np.zeros(num_sets, np.int64)  # per-warp VTA-hit counters
        self.inserts = 0

    def reset_counters(self) -> None:
        self.hits = np.zeros(self.num_sets, np.int64)

    def insert(self, owner_wid: int, line_addr: int, evictor_wid: int) -> None:
        """Record an eviction of ``owner_wid``'s line caused by ``evictor_wid``."""
        if owner_wid == evictor_wid:
            return  # self-eviction is capacity pressure, not interference
        k = self.tags_per_set
        s = owner_wid % self.num_sets
        base = s * k
        member = self._member[s]
        h = self._head[s]
        c = self._count[s]
        if c == k:                          # full: FIFO-drop the oldest
            f = base + h
            old = self.addr[f]
            left = member[old] - 1
            if left:
                member[old] = left
            else:
                del member[old]
            self.addr[f] = line_addr
            self.evictor[f] = evictor_wid
            self._head[s] = (h + 1) % k
        else:
            f = base + (h + c) % k
            self.addr[f] = line_addr
            self.evictor[f] = evictor_wid
            self._count[s] = c + 1
        member[line_addr] = member.get(line_addr, 0) + 1
        self.inserts += 1

    def probe(self, wid: int, line_addr: int) -> Optional[int]:
        """On an L1D miss by ``wid``: VTA hit returns the evictor WID that
        caused the earlier eviction (and pops the entry); miss returns None.
        A duplicate address hits its *oldest* entry, like the seed scan."""
        s = wid % self.num_sets
        member = self._member[s]
        if line_addr not in member:         # the common case: one dict probe
            return None
        k = self.tags_per_set
        base = s * k
        addr = self.addr
        evic = self.evictor
        h = self._head[s]
        c = self._count[s]
        for j in range(c):                  # oldest-first logical order
            i = base + (h + j) % k
            if addr[i] == line_addr:
                ev = evic[i]
                # close the gap: shift the logically-younger entries back
                for jj in range(j, c - 1):
                    i0 = base + (h + jj) % k
                    i1 = base + (h + jj + 1) % k
                    addr[i0] = addr[i1]
                    evic[i0] = evic[i1]
                last = base + (h + c - 1) % k
                addr[last] = -1
                evic[last] = -1
                self._count[s] = c - 1
                left = member[line_addr] - 1
                if left:
                    member[line_addr] = left
                else:
                    del member[line_addr]
                self.hits[s] += 1
                return ev
        raise AssertionError("VTA membership index out of sync")

    def hit_count(self, wid: int) -> int:
        return int(self.hits[wid % self.num_sets])
