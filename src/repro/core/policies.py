"""Warp scheduling policies: GTO, CCWS, Best-SWL, statPCAL, CIAO-P/T/C.

All policies share the interface the SM simulator drives:

  * ``allow(wid)``        — may this warp issue this cycle? (throttling)
  * ``is_isolated(wid)``  — are its memory requests redirected to smem?
  * ``is_bypass(wid)``    — statPCAL L1D bypass?
  * ``select(ready)``     — pick the next warp (all use GTO order, §V-A)
  * ``epoch_tick(...)``   — epoch-boundary decisions (Algorithm 1 for CIAO)

The per-warp decisions are additionally materialized as cached NumPy bool
masks (``allowed_mask`` / ``isolated_mask`` / ``bypass_mask``) so the
simulator's dispatch loop reads array elements instead of making millions
of ``allow()`` calls. The masks only change where policy state changes —
``epoch_tick``, ``on_mem_event``-driven decisions, ``on_warp_done`` — and
every change bumps ``mask_version`` so the simulator can cache derived
masks (e.g. allowed & ~done) between changes. The scalar methods stay as
thin mask reads for external users (serving engine, tests).

CIAO's ``epoch_tick`` is Algorithm 1 with one high-cutoff action per epoch
(the paper applies one isolate/stall per scheduling event and "repeats this
step" across epochs) and reverse-order reactivation at low-cutoff epochs
(§III-C): stalls/redirections are undone newest-first, each guarded by the
IRS of the interfered warp recorded in the pair list.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.interference import InterferenceDetector, NO_WARP

POLICY_NAMES = ("gto", "ccws", "best-swl", "statpcal",
                "ciao-p", "ciao-t", "ciao-c")


class BasePolicy:
    name = "base"

    def __init__(self, num_warps: int, detector: InterferenceDetector):
        self.n = num_warps
        self.det = detector
        self.last_wid: Optional[int] = None
        self.allowed_mask = np.ones(num_warps, bool)
        self.isolated_mask = np.zeros(num_warps, bool)
        self.bypass_mask = np.zeros(num_warps, bool)
        self.mask_version = 0

    # -- issue control ----------------------------------------------------
    def allow(self, wid: int) -> bool:
        return bool(self.allowed_mask[wid])

    def is_isolated(self, wid: int) -> bool:
        return bool(self.isolated_mask[wid])

    def is_bypass(self, wid: int) -> bool:
        return bool(self.bypass_mask[wid])

    # -- GTO (greedy-then-oldest) selection (shared by all, §V-A) ---------
    def select(self, ready: Sequence[int]) -> int:
        if self.last_wid is not None and self.last_wid in ready:
            return self.last_wid
        wid = min(ready)          # oldest = lowest WID
        self.last_wid = wid
        return wid

    # -- hooks -------------------------------------------------------------
    def on_mem_event(self, wid: int, event: str) -> None:
        pass

    def on_warp_done(self, wid: int) -> None:
        pass

    def epoch_tick(self, active: Sequence[int], finished: Sequence[bool],
                   mem_util: float = 0.0) -> None:
        pass

    def num_allowed(self) -> int:
        return int(self.allowed_mask.sum())


class GTOPolicy(BasePolicy):
    name = "gto"


class BestSWLPolicy(BasePolicy):
    """Static wavefront limiting: only the oldest ``limit`` *unfinished*
    warps run; the best limit is found by an offline sweep (paper profiles
    per benchmark, column N_wrp of Table II)."""

    name = "best-swl"

    def __init__(self, num_warps, detector, limit: int = 48):
        super().__init__(num_warps, detector)
        self.limit = max(1, limit)
        self.allowed = set(range(min(self.limit, num_warps)))
        self._next = min(self.limit, num_warps)
        self._rebuild_masks()

    def _rebuild_masks(self) -> None:
        m = np.zeros(self.n, bool)
        if self.allowed:
            m[list(self.allowed)] = True
        self.allowed_mask = m
        self.mask_version += 1

    def on_warp_done(self, wid: int) -> None:
        if wid in self.allowed:
            self.allowed.discard(wid)
            if self._next < self.n:
                self.allowed.add(self._next)
                self._next += 1
            self._rebuild_masks()


class CCWSPolicy(BasePolicy):
    """Cache-Conscious Wavefront Scheduling [12] (score-based variant).

    Each warp carries a lost-locality score (LLS) bumped on its own VTA hits
    and decaying over time. When the total score exceeds the cutoff, the
    *lowest-scoring* warps are throttled — protecting high-locality warps,
    the exact opposite of CIAO's target selection."""

    name = "ccws"

    def __init__(self, num_warps, detector, base_score: int = 64,
                 bump: int = 512, budget_per_warp: int = 128):
        super().__init__(num_warps, detector)
        self.score = np.full(num_warps, base_score, np.int64)
        self.base = base_score
        self.bump = bump
        self.budget = budget_per_warp * num_warps
        self.blocked: set = set()

    def on_mem_event(self, wid: int, event: str) -> None:
        if event == "vta_hit":
            self.score[wid] += self.bump

    def epoch_tick(self, active, finished, mem_util=0.0) -> None:
        # decay
        self.score = np.maximum(self.base,
                                self.score - np.maximum(1, self.score // 8))
        fin = np.asarray(finished, bool)
        if active is None:                  # simulator fast path: all warps
            act = np.arange(len(fin))
        else:
            act = np.asarray(list(active), np.int64)
        alive = act[~fin[act]]
        # stable argsort on -score == the old stable sorted(key=-score),
        # minus the per-epoch Python key-lambda cost (this runs every 50
        # instructions on the hot path)
        order = alive[np.argsort(-self.score[alive], kind="stable")]
        self.blocked.clear()
        run_sum = 0
        first = order[0] if len(order) else -1
        for w in order:
            run_sum += int(self.score[w])
            if run_sum > self.budget and w != first:
                self.blocked.add(int(w))
        m = np.ones(self.n, bool)
        if self.blocked:
            m[list(self.blocked)] = False
        self.allowed_mask = m
        self.mask_version += 1


class StatPCALPolicy(BestSWLPolicy):
    """statPCAL [27]-style bypass scheme: static limit like Best-SWL, but
    when L2/DRAM bandwidth is underutilized the throttled warps are released
    in *bypass* mode (skip L1D, go straight to the memory hierarchy)."""

    name = "statpcal"

    def __init__(self, num_warps, detector, limit: int = 48,
                 util_threshold: float = 0.6):
        self.bypass_active = False
        self.util_threshold = util_threshold
        super().__init__(num_warps, detector, limit)

    def _rebuild_masks(self) -> None:
        m = np.zeros(self.n, bool)
        if self.allowed:
            m[list(self.allowed)] = True
        if self.bypass_active:
            self.allowed_mask = np.ones(self.n, bool)
            self.bypass_mask = ~m
        else:
            self.allowed_mask = m
            self.bypass_mask = np.zeros(self.n, bool)
        self.mask_version += 1

    def epoch_tick(self, active, finished, mem_util=0.0) -> None:
        was = self.bypass_active
        self.bypass_active = mem_util < self.util_threshold
        if self.bypass_active != was:
            self._rebuild_masks()


@dataclasses.dataclass
class WarpFlags:
    v: int = 1   # 1 = active, 0 = stalled
    i: int = 0   # 1 = isolated (memory requests redirected to smem)


class CIAOPolicy(BasePolicy):
    """Algorithm 1. mode: 'p' (isolate only), 't' (throttle only), 'c' (both).

    The per-warp V (active) and I (isolated) bits ARE the cached masks:
    ``allowed_mask[w]`` is V, ``isolated_mask[w]`` is I. ``flags`` stays
    available as a read-only snapshot for tools and tests."""

    def __init__(self, num_warps, detector, mode: str = "c"):
        super().__init__(num_warps, detector)
        assert mode in ("p", "t", "c")
        self.mode = mode
        self.name = f"ciao-{mode}"
        self.stall_stack: List[int] = []      # reverse-order reactivation
        self.isolate_stack: List[int] = []

    # -- state queries ------------------------------------------------------
    @property
    def flags(self) -> List[WarpFlags]:
        return [WarpFlags(int(v), int(i)) for v, i
                in zip(self.allowed_mask, self.isolated_mask)]

    # -- Algorithm 1 --------------------------------------------------------
    # IRS decisions use the *high-epoch windowed* snapshot (Eq. 1 over the
    # last high-cutoff epoch): "CIAO should track the latest IRS_i" (§IV-A).
    # The same signal gates reactivation (against low-cutoff), giving one
    # high-epoch worth of hysteresis: once an interferer is isolated or
    # stalled, the interfered warp's next window shows the true residual
    # interference and the action is undone if it fell below low-cutoff.
    # `active` may be None, meaning "all warps 0..len(finished)" — the
    # simulator's fast path, which skips the fancy-indexing of the general
    # (subset) form used by direct callers and tests.
    def _alive_mask(self, active, finished) -> np.ndarray:
        fin = np.asarray(finished, bool)
        if active is None:
            return self.allowed_mask[:len(fin)] & ~fin
        act = np.asarray(active, np.int64)
        m = np.zeros(self.n, bool)
        m[act[self.allowed_mask[act] & ~fin[act]]] = True
        return m

    def _n_active(self, active, finished) -> int:
        return max(1, int(np.count_nonzero(
            self._alive_mask(active, finished))))

    def low_epoch_tick(self, active, finished) -> None:
        # Reactivation uses the *cumulative* IRS of Algorithm 1 verbatim
        # (VTAHit[k]/(InstNo/ActiveWarpNo) with per-kernel counters):
        # actions persist until the trigger's rate dilutes below low-cutoff
        # or the trigger finishes — matching the paper's phase-granular
        # behaviour (Fig. 9) and preventing isolate/un-isolate oscillation.
        cfg = self.det.cfg
        n_act = self._n_active(active, finished)
        # reactivate stalled warps, newest first (lines 4-10)
        if self.stall_stack:
            w = self.stall_stack[-1]
            k = self.det.stall_trigger(w)
            if k == NO_WARP or finished[k] or \
                    self.det.irs(k, n_act) <= cfg.low_cutoff:
                self.stall_stack.pop()
                self.allowed_mask[w] = True
                self.mask_version += 1
                self.det.clear_stall(w)
        # un-redirect isolated warps, newest first (lines 11-19)
        if self.isolate_stack:
            w = self.isolate_stack[-1]
            if not self.allowed_mask[w]:
                return    # stalled while isolated: reactivate first
            k = self.det.isolation_trigger(w)
            if k == NO_WARP or finished[k] or \
                    self.det.irs(k, n_act) <= cfg.low_cutoff:
                self.isolate_stack.pop()
                self.isolated_mask[w] = False
                self.mask_version += 1
                self.det.clear_isolation(w)

    def high_epoch_tick(self, active, finished) -> None:
        cfg = self.det.cfg
        alive = np.flatnonzero(self._alive_mask(active, finished)).tolist()
        if len(alive) <= 1:
            return
        # most-interfered active warp first (lines 20-28; one action/epoch)
        scored = sorted(alive, key=lambda w: -self.det.irs_high(w))
        for i in scored:
            if self.det.irs_high(i) <= cfg.high_cutoff:
                break
            j = self.det.most_interfering(i)
            if j == NO_WARP or j == i or finished[j]:
                continue
            if self.mode in ("p", "c") and not self.isolated_mask[j] \
                    and self.allowed_mask[j]:
                self.isolated_mask[j] = True
                self.mask_version += 1
                self.det.record_isolation(j, i)
                self.isolate_stack.append(int(j))
                return
            if self.mode in ("t", "c") and self.allowed_mask[j] \
                    and (self.isolated_mask[j] or self.mode == "t"):
                if sum(1 for w in alive if w != j) < 1:
                    return
                self.allowed_mask[j] = False
                self.mask_version += 1
                self.det.record_stall(j, i)
                self.stall_stack.append(int(j))
                return
        return

    def stall_directly(self, j: int, trigger: int) -> bool:
        """§III-C: stall an interferer whose redirection stopped being
        effective (shared-memory thrash / reserve-pool defer). Used by the
        serving engine; the SM simulator reaches the same state through
        high_epoch_tick."""
        if self.mode == "p" or not self.allowed_mask[j]:
            return False
        self.allowed_mask[j] = False
        self.mask_version += 1
        self.det.record_stall(j, trigger)
        self.stall_stack.append(int(j))
        return True

    def epoch_tick(self, active, finished, mem_util=0.0) -> None:
        n_active = int(np.count_nonzero(
            self._alive_mask(active, finished)))
        low, high = self.det.poll_epochs(n_active)
        if low:
            self.low_epoch_tick(active, finished)
        if high:
            self.high_epoch_tick(active, finished)


def make_policy(name: str, num_warps: int, detector: InterferenceDetector,
                **kw) -> BasePolicy:
    name = name.lower()
    if name == "gto":
        return GTOPolicy(num_warps, detector)
    if name == "ccws":
        return CCWSPolicy(num_warps, detector, **kw)
    if name == "best-swl":
        return BestSWLPolicy(num_warps, detector, **kw)
    if name == "statpcal":
        return StatPCALPolicy(num_warps, detector, **kw)
    if name in ("ciao-p", "ciao-t", "ciao-c"):
        return CIAOPolicy(num_warps, detector, mode=name[-1])
    raise ValueError(name)
