"""Warp scheduling policies: GTO, CCWS, Best-SWL, statPCAL, CIAO-P/T/C.

All policies share the interface the SM simulator drives:

  * ``allow(wid)``        — may this warp issue this cycle? (throttling)
  * ``is_isolated(wid)``  — are its memory requests redirected to smem?
  * ``is_bypass(wid)``    — statPCAL L1D bypass?
  * ``select(ready)``     — pick the next warp (all use GTO order, §V-A)
  * ``epoch_tick(...)``   — epoch-boundary decisions (Algorithm 1 for CIAO)

The per-warp decisions are materialized as cached NumPy bool masks
(``allowed_mask`` / ``isolated_mask`` / ``bypass_mask``) so the
simulator's dispatch loop reads array elements instead of making millions
of ``allow()`` calls. The masks only change where policy state changes —
``epoch_tick``, ``on_mem_event``-driven decisions, ``on_warp_done`` — and
every change bumps ``mask_version`` so the simulator can cache derived
masks (e.g. allowed & ~done) between changes.

The epoch-boundary math itself lives in :mod:`repro.core.epoch` as
vectorized batch-first kernels; the ``epoch_tick`` methods here are
**batch-of-1 views** onto those kernels, and all mask/score/stack updates
are strictly *in place* (arrays are never reassigned). That lets the
batched engine (:mod:`repro.core.batched`) re-point a policy's arrays at
rows of its stacked batch planes (``adopt_*_rows``) and run the very same
kernels once per pause-drain for every flagged cell — scalar and batched
paths share one implementation, pinned bit-for-bit by the golden cells.

CIAO's ``epoch_tick`` is Algorithm 1 with one high-cutoff action per epoch
(the paper applies one isolate/stall per scheduling event and "repeats this
step" across epochs) and reverse-order reactivation at low-cutoff epochs
(§III-C): stalls/redirections are undone newest-first, each guarded by the
IRS of the interfered warp recorded in the pair list.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core import epoch as _epoch
from repro.core.interference import InterferenceDetector, NO_WARP

POLICY_NAMES = ("gto", "ccws", "best-swl", "statpcal",
                "ciao-p", "ciao-t", "ciao-c")


class BasePolicy:
    name = "base"

    def __init__(self, num_warps: int, detector: InterferenceDetector):
        self.n = num_warps
        self.det = detector
        self.last_wid: Optional[int] = None
        self.allowed_mask = np.ones(num_warps, bool)
        self.isolated_mask = np.zeros(num_warps, bool)
        self.bypass_mask = np.zeros(num_warps, bool)
        self.mask_version = 0

    # -- issue control ----------------------------------------------------
    def allow(self, wid: int) -> bool:
        return bool(self.allowed_mask[wid])

    def is_isolated(self, wid: int) -> bool:
        return bool(self.isolated_mask[wid])

    def is_bypass(self, wid: int) -> bool:
        return bool(self.bypass_mask[wid])

    # -- GTO (greedy-then-oldest) selection (shared by all, §V-A) ---------
    def select(self, ready: Sequence[int]) -> int:
        if self.last_wid is not None and self.last_wid in ready:
            return self.last_wid
        wid = min(ready)          # oldest = lowest WID
        self.last_wid = wid
        return wid

    # -- hooks -------------------------------------------------------------
    def on_mem_event(self, wid: int, event: str) -> None:
        pass

    def on_warp_done(self, wid: int) -> None:
        pass

    def epoch_tick(self, active: Sequence[int], finished: Sequence[bool],
                   mem_util: float = 0.0) -> None:
        pass

    def next_epoch_after(self, li: int) -> int:
        """Next instruction count at which ``epoch_tick`` can have an
        observable effect — the per-cell next-trigger table. The base
        tick is a no-op, so passive policies (GTO, Best-SWL) park at
        infinity; the simulator still syncs detector counters at exit.
        Families with per-epoch state (CCWS decay, statPCAL bandwidth
        probe) fire every low-cutoff epoch."""
        return 1 << 62

    def _low_epoch_after(self, li: int) -> int:
        low = self.det.cfg.low_epoch
        return (li // low + 1) * low

    def num_allowed(self) -> int:
        return int(self.allowed_mask.sum())

    # -- batched-engine adoption -------------------------------------------
    def adopt_mask_rows(self, allowed_row: np.ndarray,
                        isolated_row: np.ndarray,
                        bypass_row: np.ndarray) -> None:
        """Re-point the cached masks at rows of the batched engine's
        stacked planes (current state is copied in). Mask updates are
        in-place everywhere, so object writes (``on_warp_done`` rebuilds)
        and batch-kernel writes land in the same memory."""
        allowed_row[:] = self.allowed_mask
        isolated_row[:] = self.isolated_mask
        bypass_row[:] = self.bypass_mask
        self.allowed_mask = allowed_row
        self.isolated_mask = isolated_row
        self.bypass_mask = bypass_row

    def _fin_row(self, finished) -> np.ndarray:
        """Full-width finished flags (trigger checks index by raw wid)."""
        fin = np.zeros(self.n, bool)
        f = np.asarray(finished, bool)
        fin[:len(f)] = f
        return fin


class GTOPolicy(BasePolicy):
    name = "gto"


class BestSWLPolicy(BasePolicy):
    """Static wavefront limiting: only the oldest ``limit`` *unfinished*
    warps run; the best limit is found by an offline sweep (paper profiles
    per benchmark, column N_wrp of Table II)."""

    name = "best-swl"

    def __init__(self, num_warps, detector, limit: int = 48):
        super().__init__(num_warps, detector)
        self.limit = max(1, limit)
        self.allowed = set(range(min(self.limit, num_warps)))
        self._next = min(self.limit, num_warps)
        self._rebuild_masks()

    def _rebuild_masks(self) -> None:
        m = self.allowed_mask
        m[:] = False
        if self.allowed:
            m[list(self.allowed)] = True
        self.mask_version += 1

    def on_warp_done(self, wid: int) -> None:
        if wid in self.allowed:
            self.allowed.discard(wid)
            if self._next < self.n:
                self.allowed.add(self._next)
                self._next += 1
            self._rebuild_masks()


class CCWSPolicy(BasePolicy):
    """Cache-Conscious Wavefront Scheduling [12] (score-based variant).

    Each warp carries a lost-locality score (LLS) bumped on its own VTA hits
    and decaying over time. When the total score exceeds the cutoff, the
    *lowest-scoring* warps are throttled — protecting high-locality warps,
    the exact opposite of CIAO's target selection."""

    name = "ccws"

    def __init__(self, num_warps, detector, base_score: int = 64,
                 bump: int = 512, budget_per_warp: int = 128):
        super().__init__(num_warps, detector)
        self.score = np.full(num_warps, base_score, np.int64)
        self.base = base_score
        self.bump = bump
        self.budget = budget_per_warp * num_warps
        self.blocked: set = set()
        self._base1 = np.full(1, base_score, np.int64)
        self._budget1 = np.full(1, self.budget, np.int64)

    def on_mem_event(self, wid: int, event: str) -> None:
        if event == "vta_hit":
            self.score[wid] += self.bump

    def adopt_score_row(self, score_row: np.ndarray) -> None:
        """Re-point the LLS scores at a batched-plane row. The decay is
        in-place, so the C stepper's score pointer stays valid forever."""
        score_row[:] = self.score
        self.score = score_row

    def next_epoch_after(self, li: int) -> int:
        return self._low_epoch_after(li)     # decay runs every epoch

    def epoch_tick(self, active, finished, mem_util=0.0) -> None:
        fin = np.asarray(finished, bool)
        alive = np.zeros(self.n, bool)
        if active is None:                  # simulator fast path: all warps
            alive[:len(fin)] = ~fin
        else:
            act = np.asarray(list(active), np.int64)
            alive[act[~fin[act]]] = True
        blocked = _epoch.ccws_tick(self.score[None], self._base1,
                                   self._budget1, alive[None],
                                   self.allowed_mask[None], _epoch.IDX0)
        self.blocked = set(map(int, np.flatnonzero(blocked[0])))
        self.mask_version += 1


class StatPCALPolicy(BestSWLPolicy):
    """statPCAL [27]-style bypass scheme: static limit like Best-SWL, but
    when L2/DRAM bandwidth is underutilized the throttled warps are released
    in *bypass* mode (skip L1D, go straight to the memory hierarchy)."""

    name = "statpcal"

    def __init__(self, num_warps, detector, limit: int = 48,
                 util_threshold: float = 0.6):
        self._bypass1 = np.zeros(1, bool)
        self._thresh1 = np.full(1, util_threshold, np.float64)
        self._base_mask = np.zeros(num_warps, bool)
        self.util_threshold = util_threshold
        super().__init__(num_warps, detector, limit)

    @property
    def bypass_active(self) -> bool:
        return bool(self._bypass1[0])

    @bypass_active.setter
    def bypass_active(self, value: bool) -> None:
        self._bypass1[0] = value

    def adopt_statpcal_rows(self, bypass1: np.ndarray, thresh1: np.ndarray,
                            base_row: np.ndarray) -> None:
        bypass1[:] = self._bypass1
        thresh1[:] = self._thresh1
        base_row[:] = self._base_mask
        self._bypass1 = bypass1
        self._thresh1 = thresh1
        self._base_mask = base_row

    def _rebuild_masks(self) -> None:
        bm = self._base_mask
        bm[:] = False
        if self.allowed:
            bm[list(self.allowed)] = True
        if self.bypass_active:
            self.allowed_mask[:] = True
            self.bypass_mask[:] = ~bm
        else:
            self.allowed_mask[:] = bm
            self.bypass_mask[:] = False
        self.mask_version += 1

    def epoch_tick(self, active, finished, mem_util=0.0) -> None:
        changed = _epoch.statpcal_tick(
            self._bypass1, np.asarray([mem_util], np.float64),
            self._thresh1, self._base_mask[None], self.allowed_mask[None],
            self.bypass_mask[None], _epoch.IDX0)
        if changed[0]:
            self.mask_version += 1

    def next_epoch_after(self, li: int) -> int:
        return self._low_epoch_after(li)     # bandwidth probe every epoch


@dataclasses.dataclass
class WarpFlags:
    v: int = 1   # 1 = active, 0 = stalled
    i: int = 0   # 1 = isolated (memory requests redirected to smem)


class CIAOPolicy(BasePolicy):
    """Algorithm 1. mode: 'p' (isolate only), 't' (throttle only), 'c' (both).

    The per-warp V (active) and I (isolated) bits ARE the cached masks:
    ``allowed_mask[w]`` is V, ``isolated_mask[w]`` is I. ``flags`` stays
    available as a read-only snapshot for tools and tests. The
    reverse-order reactivation stacks are fixed (n,)-deep LIFO arrays
    (a warp is on each stack at most once) so the epoch kernels can stack
    them across cells; ``stall_stack``/``isolate_stack`` remain list
    views for tools and tests."""

    def __init__(self, num_warps, detector, mode: str = "c"):
        super().__init__(num_warps, detector)
        assert mode in ("p", "t", "c")
        self.mode = mode
        self.name = f"ciao-{mode}"
        self._stall = np.full(num_warps, NO_WARP, np.int64)
        self._stall_len = np.zeros(1, np.int64)
        self._iso = np.full(num_warps, NO_WARP, np.int64)
        self._iso_len = np.zeros(1, np.int64)

    # -- state queries ------------------------------------------------------
    @property
    def flags(self) -> List[WarpFlags]:
        return [WarpFlags(int(v), int(i)) for v, i
                in zip(self.allowed_mask, self.isolated_mask)]

    @property
    def stall_stack(self) -> List[int]:
        return [int(w) for w in self._stall[:int(self._stall_len[0])]]

    @property
    def isolate_stack(self) -> List[int]:
        return [int(w) for w in self._iso[:int(self._iso_len[0])]]

    def adopt_ciao_rows(self, stall_row: np.ndarray, stall_len: np.ndarray,
                        iso_row: np.ndarray, iso_len: np.ndarray) -> None:
        stall_row[:] = self._stall
        stall_len[:] = self._stall_len
        iso_row[:] = self._iso
        iso_len[:] = self._iso_len
        self._stall = stall_row
        self._stall_len = stall_len
        self._iso = iso_row
        self._iso_len = iso_len

    # -- Algorithm 1 --------------------------------------------------------
    # IRS decisions use the *high-epoch windowed* snapshot (Eq. 1 over the
    # last high-cutoff epoch): "CIAO should track the latest IRS_i" (§IV-A).
    # The same signal gates reactivation (against low-cutoff), giving one
    # high-epoch worth of hysteresis: once an interferer is isolated or
    # stalled, the interfered warp's next window shows the true residual
    # interference and the action is undone if it fell below low-cutoff.
    # `active` may be None, meaning "all warps 0..len(finished)" — the
    # simulator's fast path, which skips the fancy-indexing of the general
    # (subset) form used by direct callers and tests.
    def _alive_mask(self, active, finished) -> np.ndarray:
        fin = np.asarray(finished, bool)
        if active is None:
            m = np.zeros(self.n, bool)
            m[:len(fin)] = self.allowed_mask[:len(fin)] & ~fin
            return m
        act = np.asarray(active, np.int64)
        m = np.zeros(self.n, bool)
        m[act[self.allowed_mask[act] & ~fin[act]]] = True
        return m

    def _n_active(self, active, finished) -> int:
        return max(1, int(np.count_nonzero(
            self._alive_mask(active, finished))))

    def low_epoch_tick(self, active, finished) -> None:
        # Reactivation uses the *cumulative* IRS of Algorithm 1 verbatim
        # (VTAHit[k]/(InstNo/ActiveWarpNo) with per-kernel counters):
        # actions persist until the trigger's rate dilutes below low-cutoff
        # or the trigger finishes — matching the paper's phase-granular
        # behaviour (Fig. 9) and preventing isolate/un-isolate oscillation.
        n_act = np.asarray([self._n_active(active, finished)], np.int64)
        changed = _epoch.ciao_low_tick(
            self.det._pl, self._stall[None], self._stall_len,
            self._iso[None], self._iso_len, self.allowed_mask[None],
            self.isolated_mask[None], self._fin_row(finished)[None],
            n_act, _epoch.IDX0)
        if changed[0]:
            self.mask_version += 1

    def high_epoch_tick(self, active, finished) -> None:
        changed = _epoch.ciao_high_tick(
            self.det._pl, self._stall[None], self._stall_len,
            self._iso[None], self._iso_len, self.allowed_mask[None],
            self.isolated_mask[None], self._fin_row(finished)[None],
            self._alive_mask(active, finished)[None],
            np.asarray([self.mode in ("p", "c")]),
            np.asarray([self.mode in ("t", "c")]), _epoch.IDX0)
        if changed[0]:
            self.mask_version += 1

    def stall_directly(self, j: int, trigger: int) -> bool:
        """§III-C: stall an interferer whose redirection stopped being
        effective (shared-memory thrash / reserve-pool defer). Used by the
        serving engine; the SM simulator reaches the same state through
        high_epoch_tick."""
        if self.mode == "p" or not self.allowed_mask[j]:
            return False
        self.allowed_mask[j] = False
        self.mask_version += 1
        self.det.record_stall(j, trigger)
        sl = int(self._stall_len[0])
        self._stall[sl] = j
        self._stall_len[0] = sl + 1
        return True

    def epoch_tick(self, active, finished, mem_util=0.0) -> None:
        n_active = int(np.count_nonzero(
            self._alive_mask(active, finished)))
        low, high = self.det.poll_epochs(n_active)
        if low:
            self.low_epoch_tick(active, finished)
        if high:
            self.high_epoch_tick(active, finished)

    def next_epoch_after(self, li: int) -> int:
        # empty reactivation stacks -> low-cutoff epochs are provably
        # no-ops (Algorithm 1 lines 4-19 touch nothing, the low-window
        # snapshot feeds no decision), so skip to the next high-cutoff
        # boundary; stacks only grow at high-epoch actions, so this is
        # exact. Same table the batched engine precomputes.
        cfg = self.det.cfg
        low, high = cfg.low_epoch, cfg.high_epoch
        if int(self._stall_len[0]) or int(self._iso_len[0]) \
                or high <= low or high % low != 0:
            return (li // low + 1) * low
        return (li // high + 1) * high


def make_policy(name: str, num_warps: int, detector: InterferenceDetector,
                **kw) -> BasePolicy:
    name = name.lower()
    if name == "gto":
        return GTOPolicy(num_warps, detector)
    if name == "ccws":
        return CCWSPolicy(num_warps, detector, **kw)
    if name == "best-swl":
        return BestSWLPolicy(num_warps, detector, **kw)
    if name == "statpcal":
        return StatPCALPolicy(num_warps, detector, **kw)
    if name in ("ciao-p", "ciao-t", "ciao-c"):
        return CIAOPolicy(num_warps, detector, mode=name[-1])
    raise ValueError(name)
