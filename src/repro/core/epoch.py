"""Vectorized epoch-path math: one implementation, batch-first.

CIAO's scheduling decisions fire only at epoch boundaries, yet they used
to be replayed cell-by-cell through Python objects whenever the batched
engine (:mod:`repro.core.batched`) drained its pause flags — the last
per-cell serialization left in the sweep path. This module re-expresses
every epoch-boundary transform as an array kernel over *stacked* state
planes with a leading batch axis:

* :func:`poll_epochs` — the detector's low/high epoch-crossing detection,
  windowed IRS snapshots (Eq. 1 over the epoch that just ended) and
  counter aging, for any subset ``idx`` of cells at once.
* :func:`ccws_tick` — CCWS score decay + lost-locality throttling
  (stable sort + cumulative budget) across cells.
* :func:`statpcal_tick` — the statPCAL bandwidth-driven bypass flip.
* :func:`ciao_low_tick` — Algorithm 1 lines 4-19 (reverse-order
  reactivation, one pop per stack per epoch) across cells.
* :func:`ciao_high_tick` — Algorithm 1 lines 20-28 (one isolate/stall
  action per high epoch) across cells: candidate scoring, the stable
  descending-IRS walk and the single action are all batched scatters.

The **scalar objects are batch-of-1 views**: ``InterferenceDetector``
keeps its state in a single-row :class:`DetPlanes` and
``poll_epochs``/``irs``/…—as well as the CCWS/statPCAL/CIAO
``epoch_tick`` methods in :mod:`repro.core.policies` — delegate to these
kernels with ``B == 1``. The batched engine re-points each cell's
detector/policy at a row of its full-batch planes (:meth:`DetPlanes.row`)
and calls the same kernels once per pause-drain for *all* flagged cells.
That makes the vectorized forms the single implementation the scalar
``SMSimulator`` also exercises, so the golden cells of
``tests/test_equivalence.py`` pin them bit-for-bit and
``tests/test_epoch.py`` property-tests batch == per-cell on random
counter states.

Bit-exactness notes: every arithmetic step mirrors the scalar semantics
elementwise — int64 floor divisions and stable sorts wherever the scalar
code relied on Python's stable ``sorted``/``argsort``. The IRS state is
**fixed-point**: snapshots are stored as the integer triple
``(hits, window, active)`` and every cutoff decision is the
single-rounding float64 compare ``hits*active <> cutoff*window``. All
integer operands stay far below 2**53, so the int64->float64
conversions are exact, the compare performs exactly one IEEE rounding
per side, and the decision is bit-deterministic across numpy, the C
stepper, and XLA — no accumulated float state ever crosses an epoch
boundary.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

NO_WARP = -1
# sort key for dead warps: larger than any -score / any finite key
_DEAD_KEY = np.iinfo(np.int64).max

# reusable batch-of-1 index (the scalar objects' delegation path)
IDX0 = np.zeros(1, np.int64)


# --------------------------------------------------------------- planes
@dataclasses.dataclass
class DetPlanes:
    """Stacked per-cell detector state (one row per cell).

    The arrays are the *canonical* storage: ``InterferenceDetector``
    exposes them through thin properties, and
    :meth:`InterferenceDetector.adopt_row` re-points a detector at a row
    of a full-batch instance so object reads and kernel writes share
    memory.
    """
    cfg: object                      # DetectorConfig (duck-typed)
    inst_total: np.ndarray           # (B,) i64  Inst-total counter
    irs_inst: np.ndarray             # (B,) i64  aged Eq. 1 denominator
    low_idx: np.ndarray              # (B,) i64  last-seen epoch ordinals
    high_idx: np.ndarray             # (B,) i64
    low_base_inst: np.ndarray        # (B,) i64  window bases
    high_base_inst: np.ndarray       # (B,) i64
    high_crossings: np.ndarray       # (B,) i64  aging counter
    irs_hits: np.ndarray             # (B, nw) i64  aged per-warp VTA hits
    low_base_hits: np.ndarray        # (B, nw) i64
    high_base_hits: np.ndarray       # (B, nw) i64
    # fixed-point windowed IRS snapshots: value = hits * act / win
    low_snap_hits: np.ndarray        # (B, nw) i64  hits in the window
    high_snap_hits: np.ndarray       # (B, nw) i64
    low_snap_win: np.ndarray         # (B,) i64  window length (>= 1)
    high_snap_win: np.ndarray        # (B,) i64
    low_snap_act: np.ndarray         # (B,) i64  active warps (>= 1)
    high_snap_act: np.ndarray        # (B,) i64
    vta_hits: np.ndarray             # (B, v_sets) i64 (aliases vta.hits)
    interfering: np.ndarray          # (B, list_entries) i64
    sat: np.ndarray                  # (B, list_entries) i64
    pair_list: np.ndarray            # (B, list_entries, 2) i64
    # per-row config planes: scalar detector knobs promoted to columns
    # so heterogeneous sweeps batch (shape-affecting fields stay on cfg)
    low_epoch: np.ndarray            # (B,) i64
    high_epoch: np.ndarray           # (B,) i64
    aging_high: np.ndarray           # (B,) i64  0 disables aging
    low_cutoff: np.ndarray           # (B,) f64
    high_cutoff: np.ndarray          # (B,) f64
    wid_sets: np.ndarray             # (nw,) i64  wid -> vta set index

    @classmethod
    def alloc(cls, b: int, cfg) -> "DetPlanes":
        i64 = np.int64
        nw, le = cfg.num_warps, cfg.list_entries
        return cls(
            cfg=cfg,
            inst_total=np.zeros(b, i64),
            irs_inst=np.zeros(b, i64),
            low_idx=np.zeros(b, i64),
            high_idx=np.zeros(b, i64),
            low_base_inst=np.zeros(b, i64),
            high_base_inst=np.zeros(b, i64),
            high_crossings=np.zeros(b, i64),
            irs_hits=np.zeros((b, nw), i64),
            low_base_hits=np.zeros((b, nw), i64),
            high_base_hits=np.zeros((b, nw), i64),
            low_snap_hits=np.zeros((b, nw), i64),
            high_snap_hits=np.zeros((b, nw), i64),
            low_snap_win=np.ones(b, i64),
            high_snap_win=np.ones(b, i64),
            low_snap_act=np.ones(b, i64),
            high_snap_act=np.ones(b, i64),
            vta_hits=np.zeros((b, cfg.vta_sets), i64),
            interfering=np.full((b, le), NO_WARP, i64),
            sat=np.zeros((b, le), i64),
            pair_list=np.full((b, le, 2), NO_WARP, i64),
            low_epoch=np.full(b, cfg.low_epoch, i64),
            high_epoch=np.full(b, cfg.high_epoch, i64),
            aging_high=np.full(b, cfg.aging_high_epochs, i64),
            low_cutoff=np.full(b, cfg.low_cutoff, np.float64),
            high_cutoff=np.full(b, cfg.high_cutoff, np.float64),
            wid_sets=np.arange(nw, dtype=i64) % cfg.vta_sets,
        )

    _ROW_FIELDS = ("inst_total", "irs_inst", "low_idx", "high_idx",
                   "low_base_inst", "high_base_inst", "high_crossings",
                   "irs_hits", "low_base_hits", "high_base_hits",
                   "low_snap_hits", "high_snap_hits", "low_snap_win",
                   "high_snap_win", "low_snap_act", "high_snap_act",
                   "vta_hits", "interfering", "sat", "pair_list",
                   "low_epoch", "high_epoch", "aging_high",
                   "low_cutoff", "high_cutoff")

    def row(self, b: int) -> "DetPlanes":
        """A batch-of-1 *view* of row ``b`` (shares memory)."""
        kw = {f: getattr(self, f)[b:b + 1] for f in self._ROW_FIELDS}
        return DetPlanes(cfg=self.cfg, wid_sets=self.wid_sets, **kw)

    def copy_row_from(self, other: "DetPlanes", b: int) -> None:
        """Copy ``other``'s single row into row ``b`` of this batch."""
        for f in self._ROW_FIELDS:
            getattr(self, f)[b] = getattr(other, f)[0]


# -------------------------------------------------------- detector poll
def poll_epochs(pl: DetPlanes, idx: np.ndarray, active: np.ndarray
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Low/high epoch-crossing poll for cells ``idx`` (robust to batched
    instruction counting: an ordinal jump of any size is one crossing).

    ``active`` holds each cell's active-warp count (clamped to >= 1
    here, like the scalar code). Returns ``(crossed_low, crossed_high)``
    bool arrays aligned with ``idx``. Mutates the planes in place:
    windowed IRS snapshots at crossings, counter aging every
    ``aging_high_epochs`` high crossings.
    """
    act = np.maximum(np.asarray(active, np.int64), 1)
    it = pl.inst_total[idx]
    nlow = it // pl.low_epoch[idx]
    low = nlow != pl.low_idx[idx]
    if low.any():
        sub = idx[low]
        pl.low_idx[sub] = nlow[low]
        window = np.maximum(it[low] - pl.low_base_inst[sub], 1)
        cur = pl.vta_hits[sub][:, pl.wid_sets]
        pl.low_snap_hits[sub] = cur - pl.low_base_hits[sub]
        pl.low_snap_win[sub] = window
        pl.low_snap_act[sub] = act[low]
        pl.low_base_hits[sub] = cur
        pl.low_base_inst[sub] = it[low]
    nhigh = it // pl.high_epoch[idx]
    high = nhigh != pl.high_idx[idx]
    if high.any():
        sub = idx[high]
        pl.high_idx[sub] = nhigh[high]
        window = np.maximum(it[high] - pl.high_base_inst[sub], 1)
        cur = pl.vta_hits[sub][:, pl.wid_sets]
        pl.high_snap_hits[sub] = cur - pl.high_base_hits[sub]
        pl.high_snap_win[sub] = window
        pl.high_snap_act[sub] = act[high]
        pl.high_base_hits[sub] = cur
        pl.high_base_inst[sub] = it[high]
        pl.high_crossings[sub] += 1
        ag = pl.aging_high[sub]
        aged = sub[(ag > 0)
                   & (pl.high_crossings[sub]
                      % np.where(ag > 0, ag, 1) == 0)]
        if len(aged):
            pl.irs_inst[aged] //= 2
            pl.irs_hits[aged] //= 2
    return low, high


def irs_cumulative(pl: DetPlanes, idx: np.ndarray, wid: np.ndarray,
                   active: np.ndarray) -> np.ndarray:
    """Eq. 1 over the aged cumulative counters, vectorized:
    ``irs_hits[wid] * active / irs_inst`` with the scalar guards
    (zero denominator -> 0.0). Reporting only — cutoff *decisions* go
    through :func:`irs_cum_leq` so they stay single-rounding."""
    inst = pl.irs_inst[idx]
    act = np.asarray(active, np.int64)
    ok = (inst > 0) & (act > 0)
    hits = pl.irs_hits[idx, wid % pl.cfg.num_warps]
    return np.where(ok, (hits * act) / np.where(inst > 0, inst, 1), 0.0)


def irs_cum_leq(pl: DetPlanes, idx: np.ndarray, wid: np.ndarray,
                active: np.ndarray, cutoff: float) -> np.ndarray:
    """Cutoff decision on the cumulative IRS: True where
    ``irs_hits[wid] / (irs_inst / active) <= cutoff`` (or the guards
    degrade the IRS to 0.0, which any cutoff >= 0 admits). Evaluated as
    the single-rounding compare ``hits*act <= cutoff*inst`` — the
    fixed-point decision contract shared by numpy, C, and XLA."""
    inst = pl.irs_inst[idx]
    act = np.asarray(active, np.int64)
    hits = pl.irs_hits[idx, wid % pl.cfg.num_warps]
    bad = (inst <= 0) | (act <= 0)
    return bad | ((hits * act) <= cutoff * inst.astype(np.float64))


def snap_over(hits: np.ndarray, win: np.ndarray, act: np.ndarray,
              cutoff: float) -> np.ndarray:
    """Windowed-snapshot cutoff decision: True where the fixed-point
    snapshot ``hits / (win / act)`` exceeds ``cutoff``, evaluated as the
    single-rounding compare ``hits*act > cutoff*win``."""
    return (hits * act) > cutoff * np.asarray(win, np.float64)


# ----------------------------------------------------------------- CCWS
def ccws_tick(score: np.ndarray, base: np.ndarray, budget: np.ndarray,
              alive: np.ndarray, allowed: np.ndarray,
              idx: np.ndarray) -> np.ndarray:
    """CCWS epoch: decay every warp's lost-locality score, then throttle
    the lowest-scoring warps once the running (descending-score) sum
    exceeds the budget — never the top-scoring warp.

    ``score`` (B, n) int64 is decayed in place (never reassigned — the C
    stepper holds a pointer to each row); ``alive`` (k, n) marks the
    unfinished warps of cells ``idx``; ``allowed`` (B, n) bool rows are
    rewritten. Returns the (k, n) blocked mask (the scalar object's
    ``blocked`` set, for the batch-of-1 delegation).
    """
    s = score[idx]
    s -= np.maximum(1, s // 8)
    np.maximum(s, base[idx, None], out=s)
    score[idx] = s
    # stable argsort on -score with dead warps keyed last == the scalar
    # `alive[argsort(-score[alive], kind="stable")]` ordering
    key = np.where(alive, -s, _DEAD_KEY)
    order = np.argsort(key, axis=1, kind="stable")
    s_sorted = np.take_along_axis(s, order, 1)
    a_sorted = np.take_along_axis(alive, order, 1)
    csum = np.cumsum(np.where(a_sorted, s_sorted, 0), axis=1)
    blk_sorted = a_sorted & (csum > budget[idx, None])
    blk_sorted[:, 0] = False             # the top-score warp always runs
    blocked = np.zeros_like(blk_sorted)
    np.put_along_axis(blocked, order, blk_sorted, 1)
    allowed[idx] = ~blocked
    return blocked


# ------------------------------------------------------------- statPCAL
def statpcal_tick(bypass_active: np.ndarray, util: np.ndarray,
                  threshold: np.ndarray, base_mask: np.ndarray,
                  allowed: np.ndarray, bypass: np.ndarray,
                  idx: np.ndarray) -> np.ndarray:
    """statPCAL epoch: flip to bypass mode while DRAM bandwidth is
    underutilized. ``base_mask`` (B, n) holds the static-limit allowed
    set; masks are rewritten only for cells whose mode flipped. Returns
    the changed mask aligned with ``idx``."""
    new = util < threshold[idx]
    changed = new != bypass_active[idx]
    if changed.any():
        sub = idx[changed]
        nb = new[changed]
        bypass_active[sub] = nb
        bm = base_mask[sub]
        allowed[sub] = np.where(nb[:, None], True, bm)
        bypass[sub] = np.where(nb[:, None], ~bm, False)
    return changed


# ------------------------------------------------------------------ CIAO
def ciao_low_tick(pl: DetPlanes, stall: np.ndarray, stall_len: np.ndarray,
                  iso: np.ndarray, iso_len: np.ndarray,
                  allowed: np.ndarray, isolated: np.ndarray,
                  fin: np.ndarray, n_act: np.ndarray,
                  idx: np.ndarray) -> np.ndarray:
    """Algorithm 1 lines 4-19 across cells ``idx``: pop at most one
    stalled and one isolated warp per cell, newest first, each guarded
    by the *cumulative* IRS of the trigger recorded in the pair list.

    ``stall``/``iso`` are (B, n) LIFO planes with (B,) depths;
    ``allowed``/``isolated`` (B, n) bool; ``fin`` (B, n) the finished
    flags the trigger checks read; ``n_act`` the per-cell active-warp
    counts (clamped >= 1 like ``CIAOPolicy._n_active``). Returns the
    changed mask aligned with ``idx``."""
    cfg = pl.cfg
    le = cfg.list_entries
    act = np.maximum(np.asarray(n_act, np.int64), 1)
    changed = np.zeros(len(idx), bool)

    # reactivate stalled warps, newest first (lines 4-10)
    has = stall_len[idx] > 0
    top = stall[idx, np.maximum(stall_len[idx] - 1, 0)]
    topc = np.where(has, top, 0)
    k = pl.pair_list[idx, topc % le, 1]
    kc = np.where(k >= 0, k, 0)
    pop = has & ((k == NO_WARP) | fin[idx, kc]
                 | irs_cum_leq(pl, idx, kc, act, pl.low_cutoff[idx]))
    if pop.any():
        sub = idx[pop]
        w = stall[sub, stall_len[sub] - 1]
        stall_len[sub] -= 1
        allowed[sub, w] = True
        pl.pair_list[sub, w % le, 1] = NO_WARP
        changed |= pop

    # un-redirect isolated warps, newest first (lines 11-19); a warp
    # stalled while isolated must reactivate first — read `allowed`
    # *after* the pops above, like the scalar order
    hasi = iso_len[idx] > 0
    topi = iso[idx, np.maximum(iso_len[idx] - 1, 0)]
    tic = np.where(hasi, topi, 0)
    ok = hasi & allowed[idx, tic]
    k2 = pl.pair_list[idx, tic % le, 0]
    k2c = np.where(k2 >= 0, k2, 0)
    pop2 = ok & ((k2 == NO_WARP) | fin[idx, k2c]
                 | irs_cum_leq(pl, idx, k2c, act, pl.low_cutoff[idx]))
    if pop2.any():
        sub = idx[pop2]
        w = iso[sub, iso_len[sub] - 1]
        iso_len[sub] -= 1
        isolated[sub, w] = False
        pl.pair_list[sub, w % le, 0] = NO_WARP
        changed |= pop2
    return changed


def ciao_high_tick(pl: DetPlanes, stall: np.ndarray,
                   stall_len: np.ndarray, iso: np.ndarray,
                   iso_len: np.ndarray, allowed: np.ndarray,
                   isolated: np.ndarray, fin: np.ndarray,
                   alive: np.ndarray, mode_p: np.ndarray,
                   mode_t: np.ndarray, idx: np.ndarray) -> np.ndarray:
    """Algorithm 1 lines 20-28 across cells ``idx``: walk each cell's
    active warps by descending high-epoch IRS and take (at most) one
    isolate/stall action per cell.

    ``alive`` (k, n) bool and ``mode_p``/``mode_t`` (k,) bool align with
    ``idx``; the stack/mask planes are full-batch like
    :func:`ciao_low_tick`. The per-cell action walk is fully batched:
    every condition reads pre-tick state and at most one scatter fires
    per cell, so cells cannot interact. Candidate order is the stable
    descending sort on the snapshot's integer ``hits`` — within a cell
    the snapshot is ``hits * (act/win)`` with one positive scale, so the
    hits order *is* the IRS order. Returns the (k,) changed mask."""
    cfg = pl.cfg
    nw, le = cfg.num_warps, cfg.list_entries
    k, n = alive.shape
    changed = np.zeros(k, bool)
    if not k:
        return changed
    act = pl.high_snap_act[idx][:, None]
    win = pl.high_snap_win[idx][:, None]
    hits = pl.high_snap_hits[idx][:, np.arange(n) % nw]
    # `snap > cutoff` gate; the scalar walk's sorted-order break at the
    # first snap <= cutoff equals dropping every non-exceeding warp
    cand = alive & snap_over(hits, win, act,
                             pl.high_cutoff[idx][:, None]) \
        & (np.count_nonzero(alive, axis=1) > 1)[:, None]
    order = np.argsort(np.where(cand, -hits, _DEAD_KEY), axis=1,
                       kind="stable")          # (k, n) warp ids, desc IRS
    cand_s = np.take_along_axis(cand, order, 1)
    rows = idx[:, None]
    j = pl.interfering[rows, order % le]
    jc = np.where(j >= 0, j, 0)
    valid = cand_s & (j != NO_WARP) & (j != order) & ~fin[rows, jc]
    iso_j = isolated[rows, jc]
    alw_j = allowed[rows, jc]
    p_ok = valid & mode_p[:, None] & ~iso_j & alw_j
    t_ok = valid & mode_t[:, None] & alw_j & (iso_j | ~mode_p[:, None])
    hit = p_ok | t_ok
    changed = hit.any(axis=1)
    sel = np.flatnonzero(changed)
    if not sel.size:
        return changed
    pos = np.argmax(hit[sel], axis=1)          # first actionable walk pos
    take_p = p_ok[sel, pos]
    jj = j[sel, pos]                           # the victim warp
    ii = order[sel, pos]                       # the interferer
    ps, ts = sel[take_p], sel[~take_p]
    if ps.size:
        bp, jp, ip = idx[ps], jj[take_p], ii[take_p]
        isolated[bp, jp] = True
        pl.pair_list[bp, jp % le, 0] = ip
        iso[bp, iso_len[bp]] = jp
        iso_len[bp] += 1
    if ts.size:
        bt, jt, it = idx[ts], jj[~take_p], ii[~take_p]
        allowed[bt, jt] = False
        pl.pair_list[bt, jt % le, 1] = it
        stall[bt, stall_len[bt]] = jt
        stall_len[bt] += 1
    return changed
