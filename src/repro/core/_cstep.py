"""ctypes loader for the batched engine's C stepper.

Compiles ``_cstep.c`` on first use with the system C compiler (``$CC``,
``cc``, or ``gcc`` — no Python headers needed; the kernel is driven
through ``ctypes`` over the engine's stacked numpy arrays) and caches
the shared object under ``$REPRO_CSTEP_CACHE`` (default: the system temp
dir), keyed by a hash of the C source. Everything degrades gracefully:
:func:`available` returns False when there is no compiler, compilation
fails, or ``$REPRO_NO_CSTEP`` is set, and the batched engine falls back
to its pure-numpy lockstep stepper.

The :class:`Params` field order mirrors the ``Params`` struct in
``_cstep.c`` exactly — change both together.
"""
from __future__ import annotations

import ctypes
import hashlib
import os
import pathlib
import shutil
import subprocess
import tempfile
import threading
from typing import Optional

import numpy as np

from repro.core import faults

_c_i64 = ctypes.c_longlong
_c_f64 = ctypes.c_double
_p_i64 = ctypes.POINTER(ctypes.c_longlong)
_p_i8 = ctypes.POINTER(ctypes.c_byte)
_p_u64 = ctypes.POINTER(ctypes.c_uint64)
_p_f64 = ctypes.POINTER(ctypes.c_double)


class Params(ctypes.Structure):
    _fields_ = [
        # dimensions
        ("B", _c_i64), ("n", _c_i64), ("L", _c_i64), ("P", _c_i64),
        ("nf", _c_i64), ("l1_sets", _c_i64), ("l1_ways", _c_i64),
        ("vnf", _c_i64), ("v_sets", _c_i64), ("v_k", _c_i64),
        ("l2nf", _c_i64), ("l2_sets", _c_i64), ("l2_ways", _c_i64),
        ("nrb", _c_i64), ("dram_channels", _c_i64),
        ("nw", _c_i64), ("list_entries", _c_i64), ("sat_max", _c_i64),
        # config scalars (shape-class constants)
        ("xor_hash", _c_i64), ("reuse_filter", _c_i64),
        ("max_mlp", _c_i64), ("line_shift", _c_i64),
        # per-row config planes (knobs varying within a shape class)
        ("lat_l1", _p_i64), ("lat_smem", _p_i64), ("lat_migrate", _p_i64),
        ("lat_l2", _p_i64), ("lat_dram", _p_i64), ("dram_gap", _p_i64),
        ("low_epoch", _p_i64),
        # per-warp planes
        ("ready", _p_i64), ("toks", _p_i64), ("op_idx", _p_i64),
        ("n_ops", _p_i64), ("pend", _p_i64),
        ("done", _p_i8), ("avail", _p_i8), ("iso", _p_i8),
        ("byp", _p_i8), ("live", _p_i8), ("runnable", _p_i8),
        ("u_of", _p_i64), ("n_of", _p_i64), ("region_blocks", _p_i64),
        ("mem_of", _p_i64), ("until", _p_i64),
        # per-row scalars
        ("cycle", _p_i64), ("instr", _p_i64), ("li", _p_i64),
        ("next_epoch", _p_i64), ("window_mark", _p_i64),
        ("last_wid", _p_i64), ("tick", _p_i64), ("l2_tick", _p_i64),
        # cache planes
        ("l1_tags", _p_i64), ("l1_owners", _p_i64), ("l1_stamp", _p_i64),
        ("l1_reused", _p_i8),
        ("smem_tags", _p_i64), ("smem_owner", _p_i64),
        ("v_addr", _p_i64), ("v_evic", _p_i64), ("v_head", _p_i64),
        ("v_count", _p_i64), ("v_inserts", _p_i64),
        ("l2_tags", _p_i64), ("l2_stamp", _p_i64),
        ("l2_hits", _p_i64), ("l2_misses", _p_i64),
        ("dram_free", _p_i64), ("dram_requests", _p_i64),
        # event counters
        ("cnt_l1_hit", _p_i64), ("cnt_l1_miss", _p_i64),
        ("cnt_smem_hit", _p_i64), ("cnt_smem_miss", _p_i64),
        ("cnt_smem_migrate", _p_i64), ("cnt_bypass", _p_i64),
        ("cnt_evictions", _p_i64), ("cnt_smem_evictions", _p_i64),
        ("cnt_vta_hits", _p_i64), ("vta_hit_events", _p_i64),
        ("cnt_dram_reqs", _p_i64),
        # control
        ("pause", _p_i64), ("last_done_wid", _p_i64),
        # detector hooks
        ("det_ptrs", _p_u64), ("score_ptrs", _p_u64),
        ("score_bump", _p_i64), ("pair_dense", _p_i64),
        # in-stepper epoch / warp-done / timeline servicing
        ("timeline_every", _c_i64), ("tl_cap", _c_i64),
        ("high_epoch", _p_i64), ("aging_high", _p_i64),
        ("stride_ok", _p_i64),
        ("low_cutoff", _p_f64), ("high_cutoff", _p_f64),
        ("fam", _p_i8), ("mode_p", _p_i8), ("mode_t", _p_i8),
        ("allowed_pl", _p_i8), ("isolated_pl", _p_i8),
        ("bypass_pl", _p_i8),
        ("sp_bypass", _p_i8), ("sp_base", _p_i8),
        ("sp_thresh", _p_f64),
        ("det_inst_total", _p_i64), ("det_irs_inst", _p_i64),
        ("irs_off", _p_i64),
        ("low_idx", _p_i64), ("high_idx", _p_i64),
        ("low_base_inst", _p_i64), ("high_base_inst", _p_i64),
        ("high_crossings", _p_i64),
        ("low_base_hits", _p_i64), ("high_base_hits", _p_i64),
        ("low_snap_hits", _p_i64), ("high_snap_hits", _p_i64),
        ("low_snap_win", _p_i64), ("high_snap_win", _p_i64),
        ("low_snap_act", _p_i64), ("high_snap_act", _p_i64),
        ("pair_list", _p_i64), ("wid_sets", _p_i64),
        ("ccws_base", _p_i64), ("ccws_budget", _p_i64),
        ("ciao_stall", _p_i64), ("ciao_iso", _p_i64),
        ("stall_len", _p_i64), ("iso_len", _p_i64),
        ("wd_kind", _p_i64), ("swl_next", _p_i64),
        ("remaining", _p_i64),
        ("tl_cycle", _p_i64), ("tl_act", _p_i64), ("tl_n", _p_i64),
        ("tl_last_instr", _p_i64), ("tl_last_cycle", _p_i64),
        ("tl_dipc", _p_f64),
    ]


_lib = None
_err: Optional[str] = None
# compile-and-load is not reentrant (mkstemp + subprocess + os.replace
# + CDLL): serialise it so parallel chunk workers racing on first use
# build the .so once. The cross-*process* race stays handled by the
# atomic os.replace into the hash-keyed cache path.
_LOAD_LOCK = threading.Lock()


def _compiler() -> Optional[str]:
    return os.environ.get("CC") or shutil.which("cc") or shutil.which("gcc")


def _load() -> None:
    global _lib, _err
    if _lib is not None or _err is not None:
        return
    with _LOAD_LOCK:
        if _lib is not None or _err is not None:
            return
        _load_locked()


def _load_locked() -> None:
    global _lib, _err
    if os.environ.get("REPRO_NO_CSTEP"):
        _err = "disabled via REPRO_NO_CSTEP"
        return
    try:
        src_path = pathlib.Path(__file__).with_name("_cstep.c")
        src = src_path.read_bytes()
        tag = hashlib.sha256(src).hexdigest()[:16]
        cache_dir = pathlib.Path(
            os.environ.get("REPRO_CSTEP_CACHE")
            or tempfile.gettempdir()).expanduser()
        cache_dir.mkdir(parents=True, exist_ok=True)
        so = cache_dir / f"repro_cstep_{tag}.so"
        if not so.exists():
            cc = _compiler()
            if not cc:
                _err = "no C compiler on PATH (cc/gcc/$CC)"
                return
            fd, tmp = tempfile.mkstemp(suffix=".so", dir=str(cache_dir))
            os.close(fd)
            try:
                # -ffp-contract=off: the fixed-point decision compares
                # must perform exactly one rounding per side (no FMA),
                # bit-matching numpy/XLA (gcc defaults to =fast at -O2)
                subprocess.run(
                    [cc, "-O2", "-ffp-contract=off", "-shared", "-fPIC",
                     "-o", tmp, str(src_path)],
                    check=True, capture_output=True)
                os.replace(tmp, so)  # atomic: concurrent builders race-safe
            finally:
                if os.path.exists(tmp):
                    os.unlink(tmp)
        lib = ctypes.CDLL(str(so))
        lib.step_cells.argtypes = [ctypes.POINTER(Params)]
        lib.step_cells.restype = None
        _lib = lib
    except Exception as exc:  # compile/load failure -> numpy fallback
        _err = f"{type(exc).__name__}: {exc}"


def available() -> bool:
    _load()
    return _lib is not None


def unavailable_reason() -> str:
    _load()
    return _err or "available"


def _i64(a):
    return a.ctypes.data_as(_p_i64)


def _i8(a):
    return a.ctypes.data_as(_p_i8)


def _f64(a):
    return a.ctypes.data_as(_p_f64)


def bind(eng, det_ptrs, score_ptrs, bumps) -> Params:
    """Build the Params view over the engine's stacked arrays. The
    returned struct holds only *borrowed* pointers — ``_keep`` pins the
    pointer tables; the engine itself owns everything else."""
    p = Params()
    p.B, p.n, p.L, p.P = eng.B, eng.n_warps, eng.L, eng.P
    p.nf, p.l1_sets, p.l1_ways = eng.nf, eng.l1_sets, eng.l1_ways
    p.vnf, p.v_sets, p.v_k = eng.vnf, eng.v_sets, eng.v_k
    p.l2nf, p.l2_sets, p.l2_ways = eng.l2nf, eng.l2_sets, eng.l2_ways
    p.nrb, p.dram_channels = eng.nrb, eng.dram_channels
    p.nw, p.list_entries, p.sat_max = eng.nw, eng.list_entries, eng.sat_max
    p.xor_hash = int(eng.xor_hash)
    p.reuse_filter = int(eng.reuse_filter)
    p.max_mlp = eng.max_mlp
    # per-row config planes (heterogeneous knobs within a shape class)
    p.lat_l1, p.lat_smem, p.lat_migrate = \
        _i64(eng.lat_l1), _i64(eng.lat_smem), _i64(eng.lat_migrate)
    p.lat_l2, p.lat_dram, p.dram_gap = \
        _i64(eng.lat_l2), _i64(eng.lat_dram), _i64(eng.dram_gap)
    p.low_epoch = _i64(eng.low_epoch)
    from repro.workloads.tokens import TOKEN_LINE_SHIFT
    p.line_shift = TOKEN_LINE_SHIFT
    p.ready, p.toks = _i64(eng.ready), _i64(eng.toks)
    p.op_idx, p.n_ops, p.pend = \
        _i64(eng.op_idx), _i64(eng.n_ops), _i64(eng.pend)
    p.done, p.avail = _i8(eng.done), _i8(eng.avail)
    p.iso, p.byp, p.live = _i8(eng.iso), _i8(eng.byp), _i8(eng.live)
    p.runnable = _i8(eng.runnable)
    p.u_of, p.n_of = _i64(eng.u_of), _i64(eng.n_of)
    p.region_blocks = _i64(eng.region_blocks)
    p.mem_of, p.until = _i64(eng.mem_of), _i64(eng.until)
    p.cycle, p.instr, p.li = \
        _i64(eng.cycle), _i64(eng.instr), _i64(eng.li)
    p.next_epoch, p.window_mark = \
        _i64(eng.next_epoch), _i64(eng.window_mark)
    p.last_wid, p.tick, p.l2_tick = \
        _i64(eng.last_wid), _i64(eng.tick), _i64(eng.l2_tick)
    p.l1_tags, p.l1_owners, p.l1_stamp = \
        _i64(eng.l1_tags), _i64(eng.l1_owners), _i64(eng.l1_stamp)
    p.l1_reused = _i8(eng.l1_reused)
    p.smem_tags, p.smem_owner = \
        _i64(eng.smem_tags), _i64(eng.smem_owner)
    p.v_addr, p.v_evic = _i64(eng.v_addr), _i64(eng.v_evic)
    p.v_head, p.v_count = _i64(eng.v_head), _i64(eng.v_count)
    p.v_inserts = _i64(eng.v_inserts)
    p.l2_tags, p.l2_stamp = _i64(eng.l2_tags), _i64(eng.l2_stamp)
    p.l2_hits, p.l2_misses = _i64(eng.l2_hits), _i64(eng.l2_misses)
    p.dram_free, p.dram_requests = \
        _i64(eng.dram_free), _i64(eng.dram_requests)
    for name in ("l1_hit", "l1_miss", "smem_hit", "smem_miss",
                 "smem_migrate", "bypass", "evictions", "smem_evictions",
                 "vta_hits"):
        setattr(p, "cnt_" + name, _i64(getattr(eng, "cnt_" + name)))
    p.vta_hit_events = _i64(eng.vta_hit_events)
    p.cnt_dram_reqs = _i64(eng.cnt_dram_reqs)
    p.pause, p.last_done_wid = _i64(eng.pause), _i64(eng.last_done_wid)
    p.det_ptrs = det_ptrs.ctypes.data_as(_p_u64)
    p.score_ptrs = score_ptrs.ctypes.data_as(_p_u64)
    p.score_bump = _i64(bumps)
    p.pair_dense = _i64(eng.pair_dense)
    # in-stepper epoch / warp-done / timeline servicing; the detector
    # knob columns live in the engine's DetPlanes (per-row planes)
    p.high_epoch = _i64(eng.high_epoch)
    p.aging_high = _i64(eng.det_pl.aging_high)
    stride_i64 = eng._stride_ok.astype(np.int64)
    p.stride_ok = _i64(stride_i64)
    p.timeline_every = eng.timeline_every
    p.tl_cap = eng.tl_cap
    p.low_cutoff = _f64(eng.det_pl.low_cutoff)
    p.high_cutoff = _f64(eng.det_pl.high_cutoff)
    p.fam = _i8(eng.fam)
    p.mode_p, p.mode_t = _i8(eng.mode_p), _i8(eng.mode_t)
    p.allowed_pl = _i8(eng.allowed_pl)
    p.isolated_pl = _i8(eng.isolated_pl)
    p.bypass_pl = _i8(eng.bypass_pl)
    p.sp_bypass, p.sp_base = _i8(eng.sp_bypass), _i8(eng.sp_base)
    p.sp_thresh = _f64(eng.sp_thresh)
    pl = eng.det_pl
    p.det_inst_total = _i64(pl.inst_total)
    p.det_irs_inst = _i64(pl.irs_inst)
    p.irs_off = _i64(eng.irs_off)
    p.low_idx, p.high_idx = _i64(pl.low_idx), _i64(pl.high_idx)
    p.low_base_inst = _i64(pl.low_base_inst)
    p.high_base_inst = _i64(pl.high_base_inst)
    p.high_crossings = _i64(pl.high_crossings)
    p.low_base_hits = _i64(pl.low_base_hits)
    p.high_base_hits = _i64(pl.high_base_hits)
    p.low_snap_hits = _i64(pl.low_snap_hits)
    p.high_snap_hits = _i64(pl.high_snap_hits)
    p.low_snap_win = _i64(pl.low_snap_win)
    p.high_snap_win = _i64(pl.high_snap_win)
    p.low_snap_act = _i64(pl.low_snap_act)
    p.high_snap_act = _i64(pl.high_snap_act)
    p.pair_list = _i64(pl.pair_list)
    p.wid_sets = _i64(pl.wid_sets)
    p.ccws_base = _i64(eng.ccws_base)
    p.ccws_budget = _i64(eng.ccws_budget)
    p.ciao_stall, p.ciao_iso = _i64(eng.ciao_stall), _i64(eng.ciao_iso)
    p.stall_len, p.iso_len = _i64(eng.stall_len), _i64(eng.iso_len)
    p.wd_kind, p.swl_next = _i64(eng.wd_kind), _i64(eng.swl_next)
    p.remaining = _i64(eng.remaining)
    p.tl_cycle, p.tl_act = _i64(eng.tl_cycle), _i64(eng.tl_act)
    p.tl_n = _i64(eng.tl_n)
    p.tl_last_instr = _i64(eng.last_instr)
    p.tl_last_cycle = _i64(eng.last_cycle)
    p.tl_dipc = _f64(eng.tl_dipc)
    p._keep = (det_ptrs, score_ptrs, bumps, stride_i64, eng)
    return p


def step(params: Params) -> None:
    # fault-injection site for the resilience tests/chaos smoke: lets a
    # FaultPlan fail or stall individual stepper rounds deterministically
    # (zero-cost None check when no plan is installed)
    faults.fire("stepper.step")
    _lib.step_cells(ctypes.byref(params))
