"""Synthetic workload traces modeled on the paper's benchmark classes
(Table II: PolyBench / Mars / Rodinia — LWS, SWS, CI).

Each workload is a set of per-warp instruction traces (kind: 0=ALU, 1=MEM
with a byte address). Classes are parametrized to reproduce the access
structure the paper attributes to each:

* **LWS** (ATAX, BICG, MVT, KMN, Kmeans): streaming over working sets far
  larger than L1D with medium-distance re-reference windows, plus a few
  *irregular* warps hammering a small shared region (the index-array access
  of SpMV/KMeans, §VI) — the source of the skewed interference of Fig. 4.
* **SWS** (GESUMMV, SYR2K, SYRK, II, PVC, SS, SM, WC): per-warp working
  sets of ~1KB with heavy reuse; 48 warps thrash 16KB L1D, but the union
  fits in L1D + unused shared memory — the CIAO-P sweet spot.
* **CI** (Gaussian, 2DCONV, CORR, Backprop, Hotspot, NN, NW): mostly ALU,
  low APKI, with periodic bursts touching a shared table — enough VTA hits
  to bait locality-aware throttling into sacrificing TLP.

``F_smem`` (fraction of shared memory the app itself uses — Table II) caps
the space CIAO-P can borrow.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

LINE = 128
SMEM_TOTAL = 48 * 1024


@dataclasses.dataclass
class Workload:
    name: str
    klass: str                     # LWS | SWS | CI
    traces: List[Tuple[np.ndarray, np.ndarray]]
    smem_used_bytes: int
    n_wrp: int = 0                 # profiled Best-SWL limit hint (0 = sweep)
    apki: float = 0.0


def _interleave(n_inst: int, mem_rate: float, addr_stream: np.ndarray,
                rng) -> Tuple[np.ndarray, np.ndarray]:
    kinds = (rng.random(n_inst) < mem_rate).astype(np.uint8)
    n_mem = int(kinds.sum())
    reps = int(np.ceil(n_mem / max(len(addr_stream), 1)))
    mem_addrs = np.tile(addr_stream, reps)[:n_mem]
    addrs = np.zeros(n_inst, np.int64)
    addrs[kinds == 1] = mem_addrs
    return kinds, addrs


def _reuse_window_stream(base: int, window_bytes: int, passes: int,
                         total_bytes: int, rng, irregular: bool = False
                         ) -> np.ndarray:
    """Slide a re-reference window over a region: each window is swept
    ``passes`` times line-by-line before sliding (potential locality that
    interference destroys)."""
    lines_per_window = max(window_bytes // LINE, 1)
    n_windows = max(total_bytes // window_bytes, 1)
    out = []
    for wdx in range(n_windows):
        wbase = base + wdx * window_bytes
        lines = wbase + LINE * np.arange(lines_per_window)
        if irregular:
            lines = rng.permutation(lines)
        for _ in range(passes):
            out.append(lines)
    return np.concatenate(out) if out else np.zeros(1, np.int64)


def lws_workload(name: str, *, num_warps=48, inst_per_warp=4000,
                 mem_rate=0.35, heavy_warps=8, heavy_mem_rate=0.70,
                 hot_lines_per_warp=2, hot_rate=0.45,
                 smem_frac=0.0, n_wrp=0, seed=0) -> Workload:
    """Every warp streams a large region (no reuse — pure eviction pressure)
    and re-references a few private hot lines (stencil edges / accumulators /
    index-array entries). A few *heavy* warps stream at ~2x the memory rate
    with no hot reuse of their own — the severe, non-uniform interferers of
    Fig. 4: they evict everyone's hot lines, earn the interference-list
    blame, and are the right warps to isolate (CIAO-P) or stall (CIAO-T)."""
    rng = np.random.default_rng(seed)
    traces = []
    stride = max(1, num_warps // max(heavy_warps, 1))
    heavy_set = set(range(1, num_warps, stride))  # spread across WIDs
    heavy_set = set(list(heavy_set)[:heavy_warps])
    for w in range(num_warps):
        heavy = w in heavy_set
        rate = heavy_mem_rate if heavy else mem_rate
        kinds = (rng.random(inst_per_warp) < rate).astype(np.uint8)
        n_mem = int(kinds.sum())
        base = (w + 1) * 16 * 1024 * 1024
        hot = base + LINE * np.arange(hot_lines_per_warp)
        stream_lines = base + 4 * 1024 * 1024 + LINE * np.arange(n_mem)
        use_hot = rng.random(n_mem) < (0.02 if heavy else hot_rate)
        hot_seq = hot[np.arange(n_mem) % hot_lines_per_warp]
        mem_addrs = np.where(use_hot, hot_seq, stream_lines)
        addrs = np.zeros(inst_per_warp, np.int64)
        addrs[kinds == 1] = mem_addrs
        traces.append((kinds, addrs))
    return Workload(name, "LWS", traces,
                    int(smem_frac * SMEM_TOTAL), n_wrp,
                    apki=mem_rate * 1000)


def sws_workload(name: str, *, num_warps=48, inst_per_warp=4000,
                 mem_rate=0.35, ws_per_warp=1024, passes=64,
                 smem_frac=0.0, n_wrp=0, seed=0) -> Workload:
    rng = np.random.default_rng(seed)
    traces = []
    for w in range(num_warps):
        base = (w + 1) * 4 * 1024 * 1024
        stream = _reuse_window_stream(base, ws_per_warp, passes,
                                      ws_per_warp, rng)
        traces.append(_interleave(inst_per_warp, mem_rate, stream, rng))
    return Workload(name, "SWS", traces,
                    int(smem_frac * SMEM_TOTAL), n_wrp,
                    apki=mem_rate * 1000)


def ci_workload(name: str, *, num_warps=48, inst_per_warp=4000,
                mem_rate=0.05, hot_lines_per_warp=2, hot_rate=0.5,
                shared_bytes=24 * 1024, smem_frac=0.0, n_wrp=0,
                seed=0) -> Workload:
    """Compute-intensive: ~95% ALU, but the few memory ops mix per-warp hot
    lines (frequent re-reference -> VTA hits when evicted) with a shared
    table larger than L1D (eviction pressure). The VTA hits bait CCWS into
    score-based throttling — a pure TLP loss on compute-bound code — while
    the *absolute* hit rate stays far below CIAO's IRS high-cutoff (Eq. 1
    normalizes by instructions), so CIAO leaves TLP alone. This is exactly
    the Backprop asymmetry of Fig. 1/9."""
    rng = np.random.default_rng(seed)
    traces = []
    shared_lines = LINE * np.arange(max(shared_bytes // LINE, 1))
    for w in range(num_warps):
        kinds = (rng.random(inst_per_warp) < mem_rate).astype(np.uint8)
        n_mem = int(kinds.sum())
        base = (w + 1) * 4 * 1024 * 1024
        hot = base + LINE * np.arange(hot_lines_per_warp)
        hot_seq = hot[np.arange(n_mem) % hot_lines_per_warp]
        sh = np.tile(shared_lines, int(np.ceil(
            n_mem / len(shared_lines))))[:n_mem]
        use_hot = rng.random(n_mem) < hot_rate
        mem_addrs = np.where(use_hot, hot_seq, sh)
        addrs = np.zeros(inst_per_warp, np.int64)
        addrs[kinds == 1] = mem_addrs
        traces.append((kinds, addrs))
    return Workload(name, "CI", traces,
                    int(smem_frac * SMEM_TOTAL), n_wrp,
                    apki=mem_rate * 1000)


def two_phase_workload(name: str, *, seed=0) -> Workload:
    """ATAX-like: memory-intensive phase then compute-intensive phase
    (Fig. 9) within one kernel."""
    a = lws_workload("phase1", inst_per_warp=2500, heavy_warps=6,
                     mem_rate=0.45, seed=seed)
    b = ci_workload("phase2", inst_per_warp=2500, mem_rate=0.05,
                    seed=seed + 1)
    traces = []
    for (k1, a1), (k2, a2) in zip(a.traces, b.traces):
        traces.append((np.concatenate([k1, k2]), np.concatenate([a1, a2])))
    return Workload(name, "LWS", traces, 0, 0, apki=250)


# --------------------------------------------------------------- registry
def make_workload(name: str, seed: int = 0, scale: float = 1.0) -> Workload:
    n = lambda x: int(x * scale)
    table = {
        # --- LWS (Table II: ATAX/BICG/MVT N_wrp=2, KMN=4, Kmeans=2) ---
        "atax": lambda: two_phase_workload("atax", seed=seed),
        "bicg": lambda: lws_workload("bicg", inst_per_warp=n(4000),
                                     heavy_warps=6, n_wrp=2, seed=seed),
        "mvt": lambda: lws_workload("mvt", inst_per_warp=n(4000),
                                    heavy_warps=4, hot_rate=0.35, n_wrp=2,
                                    seed=seed + 2),
        "kmn": lambda: lws_workload("kmn", inst_per_warp=n(4000),
                                    mem_rate=0.40, heavy_warps=10,
                                    smem_frac=0.01, n_wrp=4, seed=seed + 3),
        "kmeans": lambda: lws_workload("kmeans", inst_per_warp=n(5000),
                                       mem_rate=0.45, heavy_warps=8,
                                       heavy_mem_rate=0.8, n_wrp=2,
                                       seed=seed + 4),
        # --- SWS (GESUMMV/SYR2K/SYRK N_wrp=2/6/6; PVC/SS use smem) ---
        "gesummv": lambda: sws_workload("gesummv", inst_per_warp=n(4000),
                                        mem_rate=0.5, ws_per_warp=1024,
                                        n_wrp=2, seed=seed + 5),
        "syr2k": lambda: sws_workload("syr2k", inst_per_warp=n(4000),
                                      ws_per_warp=1024, n_wrp=6,
                                      seed=seed + 6),
        "syrk": lambda: sws_workload("syrk", inst_per_warp=n(4000),
                                     ws_per_warp=768, n_wrp=6, seed=seed + 7),
        "ii": lambda: sws_workload("ii", inst_per_warp=n(4000), mem_rate=0.3,
                                   ws_per_warp=1280, n_wrp=4, seed=seed + 8),
        "pvc": lambda: sws_workload("pvc", inst_per_warp=n(4000),
                                    ws_per_warp=896, smem_frac=0.33,
                                    n_wrp=48, seed=seed + 9),
        "ss": lambda: sws_workload("ss", inst_per_warp=n(4000),
                                   ws_per_warp=896, smem_frac=0.50, n_wrp=48,
                                   seed=seed + 10),
        # --- CI (Backprop smem 13%, Hotspot 19%, NW 35%) ---
        "gaussian": lambda: ci_workload("gaussian", inst_per_warp=n(4000),
                                        mem_rate=0.05, n_wrp=48,
                                        seed=seed + 11),
        "conv2d": lambda: ci_workload("conv2d", inst_per_warp=n(4000),
                                      mem_rate=0.03, n_wrp=36,
                                      seed=seed + 12),
        "backprop": lambda: ci_workload("backprop", inst_per_warp=n(4000),
                                        mem_rate=0.08, hot_rate=0.6,
                                        smem_frac=0.13, n_wrp=36,
                                        seed=seed + 13),
        "hotspot": lambda: ci_workload("hotspot", inst_per_warp=n(4000),
                                       mem_rate=0.02, smem_frac=0.19,
                                       n_wrp=48, seed=seed + 14),
        "nw": lambda: ci_workload("nw", inst_per_warp=n(4000), mem_rate=0.05,
                                  hot_rate=0.4, smem_frac=0.35, n_wrp=48,
                                  seed=seed + 15),
    }
    return table[name]()


WORKLOADS: Dict[str, str] = {
    "atax": "LWS", "bicg": "LWS", "mvt": "LWS", "kmn": "LWS",
    "kmeans": "LWS",
    "gesummv": "SWS", "syr2k": "SWS", "syrk": "SWS", "ii": "SWS",
    "pvc": "SWS", "ss": "SWS",
    "gaussian": "CI", "conv2d": "CI", "backprop": "CI", "hotspot": "CI",
    "nw": "CI",
}
