"""Back-compat shim: the workload subsystem moved to
:mod:`repro.workloads` (declarative IR, synthetic families, Pallas-kernel
-derived traces, token contract, on-disk format).

Everything this module used to define is re-exported so existing imports
(``from repro.core.traces import make_workload, WORKLOADS, Workload``)
keep working. New code should import :mod:`repro.workloads` directly.
"""
from repro.workloads import (  # noqa: F401
    LINE, SMEM_TOTAL, WORKLOADS, Workload, ci_workload, lws_workload,
    make_workload, register_workload, sws_workload, two_phase_workload)
