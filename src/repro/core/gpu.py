"""Multi-SM GPU model: CTA placement + interleaved SM execution on a
shared L2/DRAM stage (paper Table I: 15 SMs, one 768KB L2, shared DRAM).

Pieces:

* **CTAScheduler** — distributes CTAs (groups of ``warps_per_cta``
  consecutive warp traces) across SMs. ``round-robin`` is the classic
  GPGPU-Sim placement (CTA *i* → SM *i mod N*); ``loose`` greedily places
  each CTA on the least-loaded SM by warp count (ties → lowest SM id), so
  uneven CTA sizes still balance. Both are deterministic.

* **GPUSimulator** — instantiates ``num_sms`` :class:`SMSimulator` cores
  around ONE shared :class:`~repro.core.memory.MemoryHierarchy` and
  advances them in ``slice_cycles``-long interleaved time slices; within a
  slice each SM runs event-driven, and the shared per-bank / per-channel
  queues carry contention across SMs. Each SM keeps its own interference
  detector and CIAO policy instance, as in the paper (the VTA and
  interference lists are per-SM structures).

Workload placement has two modes. With ``replicate=True`` (default) every
SM receives a full copy of the workload's CTAs, with copy *k*'s addresses
offset by ``k << addr_offset_bits`` — distinct data that contends for the
shared L2 capacity and DRAM bandwidth, like independent thread blocks of
the same kernel working on different tiles. With ``replicate=False`` the
workload's own CTAs are partitioned across SMs (fewer warps per SM).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.simulator import SimConfig, SimResult, SMSimulator


@dataclasses.dataclass
class GPUConfig:
    num_sms: int = 2
    warps_per_cta: int = 8
    cta_scheduler: str = "round-robin"   # 'round-robin' | 'loose'
    slice_cycles: int = 512              # SM interleave granularity
    replicate: bool = True               # full workload copy per SM
    addr_offset_bits: int = 28           # per-copy address stride (256MB)


@dataclasses.dataclass
class CTA:
    cta_id: int
    copy: int                            # workload replica index
    traces: List[Tuple[np.ndarray, np.ndarray]]

    @property
    def num_warps(self) -> int:
        return len(self.traces)


class CTAScheduler:
    """Deterministic CTA → SM placement."""

    KINDS = ("round-robin", "loose")

    def __init__(self, kind: str = "round-robin"):
        if kind not in self.KINDS:
            raise ValueError(f"unknown CTA scheduler {kind!r}")
        self.kind = kind

    def assign(self, ctas: Sequence[CTA], num_sms: int) -> List[List[CTA]]:
        placement: List[List[CTA]] = [[] for _ in range(num_sms)]
        if self.kind == "round-robin":
            for i, cta in enumerate(ctas):
                placement[i % num_sms].append(cta)
        else:  # loose: least-loaded by warp count, ties -> lowest SM id
            load = [0] * num_sms
            for cta in ctas:
                sm = min(range(num_sms), key=lambda s: (load[s], s))
                placement[sm].append(cta)
                load[sm] += cta.num_warps
        return placement


@dataclasses.dataclass
class _SubWorkload:
    """Per-SM slice of a workload (duck-typed for SMSimulator)."""
    name: str
    klass: str
    traces: List[Tuple[np.ndarray, np.ndarray]]
    smem_used_bytes: int
    n_wrp: int = 0


@dataclasses.dataclass
class GPUResult:
    policy: str
    num_sms: int
    cycles: int                  # chip time = max over SMs
    instructions: int            # summed over SMs
    ipc: float                   # chip IPC = instructions / cycles
    l1_hit_rate: float           # mean over SMs
    vta_hits: int                # summed
    mean_active_warps: float     # mean over SMs
    mem_stats: Dict[str, int]    # shared L2/DRAM counters
    per_sm: List[SimResult]


def make_ctas(workload, warps_per_cta: int) -> List[CTA]:
    """Chunk a workload's warp traces into CTAs of consecutive warps."""
    traces = workload.traces
    step = max(warps_per_cta, 1)
    return [CTA(cta_id=i // step, copy=0, traces=list(traces[i:i + step]))
            for i in range(0, len(traces), step)]


def _offset_cta(cta: CTA, copy: int, offset: int) -> CTA:
    if not offset:
        return dataclasses.replace(cta, copy=copy)
    traces = [(k, a + offset) for k, a in cta.traces]
    return CTA(cta_id=cta.cta_id, copy=copy, traces=traces)


def place_ctas(workload, gpu: GPUConfig) -> List[List[CTA]]:
    """CTA placement for a workload on ``gpu``: replicate/offset copies,
    then the deterministic CTA scheduler. One list of CTAs per SM."""
    base_ctas = make_ctas(workload, gpu.warps_per_cta)
    if gpu.replicate:
        ctas: List[CTA] = []
        for copy in range(gpu.num_sms):
            off = copy << gpu.addr_offset_bits
            ctas.extend(_offset_cta(c, copy, off) for c in base_ctas)
    else:
        ctas = base_ctas
    return CTAScheduler(gpu.cta_scheduler).assign(ctas, gpu.num_sms)


def sm_subworkloads(workload, gpu: GPUConfig) -> List[_SubWorkload]:
    """The per-SM trace slices of ``workload`` under ``gpu`` placement —
    the exact workloads each of :class:`GPUSimulator`'s SMs receives.
    Shared with the batched engine (:mod:`repro.core.batched`), which
    stacks the same slices as (SM x cell) rows, so both execution paths
    see identical per-SM traces."""
    subs = []
    for sm_ctas in place_ctas(workload, gpu):
        traces = [t for cta in sm_ctas for t in cta.traces]
        subs.append(_SubWorkload(
            name=getattr(workload, "name", "workload"),
            klass=getattr(workload, "klass", ""),
            traces=traces,
            smem_used_bytes=workload.smem_used_bytes,
            n_wrp=getattr(workload, "n_wrp", 0)))
    return subs


class GPUSimulator:
    """N SMs contending on one shared post-L1 memory hierarchy."""

    def __init__(self, workload, policy_name: str,
                 cfg: Optional[SimConfig] = None,
                 gpu: Optional[GPUConfig] = None,
                 policy_kwargs: Optional[dict] = None):
        self.cfg = cfg = cfg if cfg is not None else SimConfig()
        self.gpu = gpu = gpu if gpu is not None else GPUConfig()
        self.policy_name = policy_name
        self.mem_sys = cfg.make_hierarchy()

        self.placement = place_ctas(workload, gpu)
        self.sms: List[SMSimulator] = []
        for sm_ctas in self.placement:
            sub = _SubWorkload(
                name=getattr(workload, "name", "workload"),
                klass=getattr(workload, "klass", ""),
                traces=[t for cta in sm_ctas for t in cta.traces],
                smem_used_bytes=workload.smem_used_bytes,
                n_wrp=getattr(workload, "n_wrp", 0))
            self.sms.append(SMSimulator(sub, policy_name, cfg,
                                        policy_kwargs=policy_kwargs,
                                        mem_system=self.mem_sys))

    def run(self) -> GPUResult:
        cfg, gpu = self.cfg, self.gpu
        self.mem_sys.reset()
        for sm in self.sms:
            sm.begin()
        t = 0
        while t < cfg.max_cycles and any(not sm.finished for sm in self.sms):
            t += gpu.slice_cycles
            for sm in self.sms:
                if not sm.finished:
                    sm.advance(t)
        results = [sm.result() for sm in self.sms]
        cycles = max((r.cycles for r in results), default=1)
        instr = sum(r.instructions for r in results)
        # chip-level rates average only SMs that received work, so idle
        # SMs (zero CTAs) don't drag the aggregate toward zero
        busy = [r for r in results if r.instructions] or results
        return GPUResult(
            policy=results[0].policy if results else self.policy_name,
            num_sms=gpu.num_sms,
            cycles=cycles,
            instructions=instr,
            ipc=instr / max(cycles, 1),
            l1_hit_rate=float(np.mean([r.l1_hit_rate for r in busy]))
            if busy else 0.0,
            vta_hits=sum(r.vta_hits for r in results),
            mean_active_warps=float(np.mean(
                [r.mean_active_warps for r in busy])) if busy else 0.0,
            mem_stats=self.mem_sys.stats(),
            per_sm=results,
        )


def run_gpu_policy_sweep(workload, policies: Sequence[str],
                         cfg: Optional[SimConfig] = None,
                         gpu: Optional[GPUConfig] = None,
                         best_swl_limits: Sequence[int] = (2, 4, 6, 8, 16,
                                                           32, 48),
                         ) -> Dict[str, GPUResult]:
    """Multi-SM analogue of :func:`repro.core.simulator.run_policy_sweep`:
    Best-SWL/statPCAL get their offline per-benchmark limit sweep."""
    out: Dict[str, GPUResult] = {}
    for p in policies:
        if p in ("best-swl", "statpcal"):
            best: Optional[GPUResult] = None
            limits = ([workload.n_wrp] if getattr(workload, "n_wrp", 0)
                      else best_swl_limits)
            for lim in limits:
                r = GPUSimulator(workload, p, cfg, gpu,
                                 policy_kwargs={"limit": lim}).run()
                if best is None or r.ipc > best.ipc:
                    best = r
            out[p] = best
        else:
            out[p] = GPUSimulator(workload, p, cfg, gpu).run()
    return out
