"""On-chip memory model: L1D + shared-memory-as-cache (paper §II-A, §IV-B).

GTX480-like SM (Table I): 16KB L1D, 128-byte lines, 4-way LRU, XOR set-index
hashing [26]; 48KB shared memory in the same physical structure (32 banks).
The CIAO additions:

* **SMMT** — Shared Memory Management Table; one entry per CTA (base, size).
  CIAO reads it to find the *unused* region and reserves that region (a new
  SMMT entry) for its direct-mapped victim-isolation cache.

* **Address translation unit** (Fig. 7c) — splits a global address into
  byte-offset F (3b, 8-byte bank rows), bank B (4b, 16 banks/group), bank
  group G (1b), row R (up to 8b), remainder = tag. A 128-byte data block is
  striped across the 16 banks of group ``G``; its 31-bit tag (25b addr + 6b
  WID) lives in the *opposite* group (``1-G``) so tag probe and data access
  proceed in parallel, bank-conflict-free — asserted structurally in tests.
  The hot path only needs the direct-mapped block index, so ``access`` does
  not materialize a :class:`TranslatedAddr` per request; the full split is
  exercised by the structural tests and available to tools.

* **MSHR** — entries extended with the translated shared-memory address so
  L2 fill responses can be routed straight into shared memory; L1D->smem
  *migration* moves a present line through the response queue (single-copy
  coherence invariant, §III-B "Performance optimization and coherence").
  Occupancy gating happens at latency-assignment time in the simulator
  (:meth:`MSHR.admit`), where the fill completion time is known; with
  ``OnChipConfig.mshr_gate`` off (default, seed-exact timing) the structure
  is merge-only bookkeeping.

State layout — the PR-2 array-core design, tuned by measurement:

* The seed's per-set Python lists (``tags``/``owners``/``reused`` nested
  per set, LRU as ``list.remove``/``append``) are replaced by *flat*
  tables indexed ``set * ways + way``: tag/owner/reused/stamp planes with
  LRU as monotonic touch timestamps (victim = min stamp of the set's
  slice; first-tie order recovers the seed's initial way order).
* Lookup is an O(1) ``line -> flat slot`` residency dict maintained
  alongside the tag plane (the software analogue of a way predictor);
  fills and invalidations keep it exact.
* The flat tables are plain Python int lists, not ndarrays: the hot path
  mutates one scalar slot per event, and a CPython list store is ~6x
  cheaper than a NumPy scalar store (measured on the bicg/ciao-c harness;
  an earlier all-ndarray version of this file benched *slower* than the
  seed). NumPy stays where state is read as a vector — the detector/VTA
  hit counters, policy masks, and the simulator's ready/done scan arrays.

Latencies are attached by the simulator; this module returns event kinds:
  'l1_hit' | 'l1_miss' | 'smem_hit' | 'smem_miss' | 'smem_migrate' | 'bypass'
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, Optional, Tuple

from repro.core.interference import InterferenceDetector

LINE = 128


@dataclasses.dataclass
class OnChipConfig:
    l1_bytes: int = 16 * 1024
    line_bytes: int = LINE
    ways: int = 4
    smem_bytes: int = 48 * 1024
    smem_banks: int = 32
    bank_row_bytes: int = 8          # 64-bit accesses per bank
    xor_hash: bool = True            # set-index hashing [26]
    mshr_entries: int = 32
    # When True the MSHR's entry count is a real structural limit: a miss
    # arriving with all entries outstanding queues until the earliest fill
    # returns (surfaced as the ``mshr_full`` stat). Off by default because
    # the seed timing model admitted unlimited outstanding misses (observed
    # peaks ~110 on LWS workloads) and the golden equivalence suite pins
    # that behavior; flip it on to study a finite-MSHR machine.
    mshr_gate: bool = False
    # Refinement over the paper (ablatable): a 1-bit "reused" flag per L1D
    # line; only evictions of *reused* lines enter the VTA. Streaming
    # victims (never re-referenced) otherwise flood the 8-entry per-warp
    # FIFO and push out the genuine lost-locality evidence.
    reuse_filter: bool = False

    @property
    def num_sets(self) -> int:
        return self.l1_bytes // (self.line_bytes * self.ways)


class SMMT:
    """Shared Memory Management Table (§II-A, [17])."""

    def __init__(self, total_bytes: int):
        self.total = total_bytes
        self.entries: Dict[str, Tuple[int, int]] = {}  # name -> (base, size)

    def allocate(self, name: str, size: int) -> int:
        base = sum(s for _, s in self.entries.values())
        if base + size > self.total:
            raise ValueError("shared memory exhausted")
        self.entries[name] = (base, size)
        return base

    def unused(self) -> int:
        return self.total - sum(s for _, s in self.entries.values())

    def reserve_unused(self, name: str = "__ciao__") -> Tuple[int, int]:
        size = self.unused()
        base = self.allocate(name, size)
        return base, size

    def release(self, name: str) -> None:
        self.entries.pop(name, None)


@dataclasses.dataclass
class TranslatedAddr:
    """Fig. 7c field split of a block address within the reserved region."""
    byte_off: int     # F: 3 bits
    bank: int         # B: 4 bits
    group: int        # G: 1 bit
    row: int          # R: row index within the region
    tag: int          # remaining bits (+ 6-bit WID stored alongside)
    tag_group: int    # == 1 - group (opposite bank group)
    tag_bank: int
    tag_row: int


class AddressTranslationUnit:
    """Global address -> shared-memory (row, group, bank) + tag placement."""

    def __init__(self, cfg: OnChipConfig, region_blocks: int):
        self.cfg = cfg
        self.region_blocks = max(region_blocks, 1)

    def translate(self, addr: int, wid: int = 0) -> TranslatedAddr:
        block = addr // LINE
        idx = block % self.region_blocks          # direct-mapped block index
        byte_off = addr % self.cfg.bank_row_bytes                 # F (3b)
        bank = (addr // self.cfg.bank_row_bytes) % 16             # B (4b)
        group = idx % 2                                           # G (1b)
        row = idx // 2                                            # R
        tag = block // self.region_blocks                         # remainder
        # tag goes to the opposite bank group; two tags share one bank row,
        # 32 tags per row of one group. Position derived from the data
        # block's (F,B) bits, G flipped (Fig. 7c).
        tag_group = 1 - group
        tag_bank = idx % 16
        tag_row = idx // 32
        return TranslatedAddr(byte_off, bank, group, row, tag,
                              tag_group, tag_bank, tag_row)


class MSHR:
    """Miss-status holding registers: same-line merge plus (optionally) a
    real occupancy limit.

    ``reserve``/``fill`` keep the seed's merge bookkeeping (one entry per
    in-flight line, extended with the translated shared-memory address for
    fill routing). ``admit`` models the structural limit: the simulator
    calls it once per miss with the miss's completion time, and when all
    ``capacity`` entries are outstanding the request queues until the
    earliest fill frees one — the returned delay is added to the miss
    latency and counted in ``full_events``.
    """

    def __init__(self, entries: int, gate: bool = False):
        self.capacity = entries
        self.gate = gate
        self.full_events = 0
        self._release: list = []            # min-heap of fill times
        self.pending: Dict[int, Dict] = {}  # global line addr -> info

    def reserve(self, line_addr: int, smem_addr: Optional[int] = None) -> bool:
        if line_addr in self.pending:
            return True
        if len(self.pending) >= self.capacity:
            return False
        self.pending[line_addr] = {"smem_addr": smem_addr}
        return True

    def fill(self, line_addr: int) -> Optional[Dict]:
        return self.pending.pop(line_addr, None)

    def outstanding(self, now: int) -> int:
        """Entries still waiting on a fill at cycle ``now``."""
        h = self._release
        while h and h[0] <= now:
            heapq.heappop(h)
        return len(h)

    def admit(self, now: int, lat: int) -> int:
        """Admit a miss issued at ``now`` whose fill takes ``lat`` cycles.
        Returns the extra queueing delay (0 unless gated and full)."""
        if not self.gate:
            return 0
        h = self._release
        while h and h[0] <= now:
            heapq.heappop(h)
        if len(h) >= self.capacity:
            # queue until the earliest outstanding fill frees its entry —
            # and consume that entry, so a second queued miss waits for
            # the *next* fill instead of sharing the same slot
            delay = h[0] - now
            self.full_events += 1
            heapq.heapreplace(h, now + delay + lat)
            return delay
        heapq.heappush(h, now + lat)
        return 0


EV_L1_HIT, EV_SMEM_HIT, EV_SMEM_MIGRATE, EV_L1_MISS, EV_SMEM_MISS, \
    EV_BYPASS = range(6)
EVENT_NAMES = ("l1_hit", "smem_hit", "smem_migrate", "l1_miss",
               "smem_miss", "bypass")


class OnChipMemory:
    """L1D + optional CIAO shared-memory cache region, with VTA feedback.

    Hot entry point is :meth:`access_ex`, which returns a small event code
    (``EV_*``) plus a did-the-VTA-hit flag — the simulator maps codes to
    latencies by tuple index and feeds the flag to the policy without
    re-reading detector counters. :meth:`access` is the seed-compatible
    string-event wrapper. Event counters are instance attributes
    (``n_l1_hit``...); ``stats`` materializes the seed's dict on demand.
    """

    __slots__ = ("cfg", "det", "tags", "owners", "reused", "stamp", "_tick",
                 "_line_index", "smmt", "region_blocks", "atu", "smem_tags",
                 "smem_owner", "mshr", "_vta", "n_l1_hit", "n_l1_miss",
                 "n_smem_hit", "n_smem_miss", "n_smem_migrate", "n_bypass",
                 "n_evictions", "n_smem_evictions", "n_vta_hits")

    def __init__(self, cfg: OnChipConfig, detector: InterferenceDetector,
                 smem_used_bytes: int = 0):
        self.cfg = cfg
        self.det = detector
        self._vta = detector.vta
        ns = cfg.num_sets
        nf = ns * cfg.ways
        # flat tag/owner/reused/stamp planes, indexed set*ways + way
        self.tags = [-1] * nf
        self.owners = [-1] * nf
        self.reused = [False] * nf
        self.stamp = [0] * nf
        self._tick = 1
        self._line_index: Dict[int, int] = {}   # resident line -> flat slot
        self.smmt = SMMT(cfg.smem_bytes)
        if smem_used_bytes:
            self.smmt.allocate("app", smem_used_bytes)
        base, size = self.smmt.reserve_unused()
        # tags+data co-resident: each 128B block costs 128B + 4B tag share
        self.region_blocks = size // (LINE + 4)
        self.atu = AddressTranslationUnit(cfg, self.region_blocks)
        nrb = max(self.region_blocks, 1)
        # direct-mapped region: flat tag/owner tables
        self.smem_tags = [-1] * nrb
        self.smem_owner = [-1] * nrb
        self.mshr = MSHR(cfg.mshr_entries, gate=cfg.mshr_gate)
        self.n_l1_hit = self.n_l1_miss = 0
        self.n_smem_hit = self.n_smem_miss = self.n_smem_migrate = 0
        self.n_bypass = self.n_evictions = self.n_smem_evictions = 0
        self.n_vta_hits = 0

    @property
    def stats(self) -> Dict[str, int]:
        return {"l1_hit": self.n_l1_hit, "l1_miss": self.n_l1_miss,
                "smem_hit": self.n_smem_hit, "smem_miss": self.n_smem_miss,
                "smem_migrate": self.n_smem_migrate,
                "bypass": self.n_bypass, "evictions": self.n_evictions,
                "smem_evictions": self.n_smem_evictions,
                "vta_hits": self.n_vta_hits}

    # ------------------------------------------------------------- L1D path
    def _set_index(self, line_addr: int) -> int:
        ns = self.cfg.num_sets
        idx = line_addr % ns
        if self.cfg.xor_hash:
            idx ^= (line_addr // ns) % ns
        return idx % ns

    def _l1_lookup(self, line_addr: int) -> Tuple[int, Optional[int]]:
        s = self._set_index(line_addr)
        f = self._line_index.get(line_addr)
        if f is None:
            return s, None
        return s, f - s * self.cfg.ways

    def _l1_touch(self, s: int, w: int) -> None:
        self.stamp[s * self.cfg.ways + w] = self._tick
        self._tick += 1

    def _l1_victim(self, s: int) -> int:
        """LRU victim: the way with the smallest touch stamp (first tie
        wins, preserving the seed's initial way order)."""
        ways = self.cfg.ways
        stamp = self.stamp
        base = s * ways
        best = base
        bs = stamp[base]
        for f in range(base + 1, base + ways):
            v = stamp[f]
            if v < bs:
                bs = v
                best = f
        return best

    def _l1_fill(self, wid: int, line_addr: int,
                 s: Optional[int] = None) -> None:
        if s is None:
            s = self._set_index(line_addr)
        f = self._l1_victim(s)
        old_tag = self.tags[f]
        if old_tag >= 0:
            self.n_evictions += 1
            if self.reused[f] or not self.cfg.reuse_filter:
                self._vta.insert(self.owners[f], old_tag, wid)
            del self._line_index[old_tag]
        self.tags[f] = line_addr
        self.owners[f] = wid
        self.reused[f] = False
        self._line_index[line_addr] = f
        self.stamp[f] = self._tick
        self._tick += 1

    def _l1_invalidate(self, line_addr: int) -> bool:
        f = self._line_index.pop(line_addr, None)
        if f is None:
            return False
        self.tags[f] = -1
        self.owners[f] = -1
        return True

    # ------------------------------------------------------------ smem path
    def _smem_access(self, wid: int, line_addr: int) -> Tuple[int, bool]:
        """Returns (EV_* code, vta_hit)."""
        if self.region_blocks <= 0:
            return EV_SMEM_MISS, False
        idx = line_addr % self.region_blocks
        old = self.smem_tags[idx]
        if old == line_addr:
            self.n_smem_hit += 1
            return EV_SMEM_HIT, False
        # miss: victim tracking in the SAME detector/VTA (§III-C)
        if old >= 0:
            self.n_smem_evictions += 1
            self._vta.insert(self.smem_owner[idx], old, wid)
        vta_hit = self.det.on_miss(wid, line_addr) is not None
        if vta_hit:
            self.n_vta_hits += 1
        # migration: single-copy coherence — if L1D still holds the line,
        # evict it through the response queue into smem (§IV-B).
        migrated = self._l1_invalidate(line_addr)
        self.smem_tags[idx] = line_addr
        self.smem_owner[idx] = wid
        if migrated:
            self.n_smem_migrate += 1
            return EV_SMEM_MIGRATE, vta_hit
        self.n_smem_miss += 1
        return EV_SMEM_MISS, vta_hit

    # --------------------------------------------------------------- access
    def access_ex(self, wid: int, addr: int, isolated: bool = False,
                  bypass: bool = False) -> Tuple[int, bool]:
        """One memory request, hot form: returns (EV_* event code,
        vta_hit flag); the simulator adds latency and does the detector's
        instruction counting in batch. ``isolated``: CIAO-P redirection to
        smem. ``bypass``: statPCAL-style L1D bypass."""
        line_addr = addr // LINE
        if bypass:
            self.n_bypass += 1
            return EV_BYPASS, False
        if isolated:
            return self._smem_access(wid, line_addr)
        f = self._line_index.get(line_addr)
        if f is not None:                    # resident: O(1) residency hit
            self.n_l1_hit += 1
            self.reused[f] = True
            self.stamp[f] = self._tick
            self._tick += 1
            return EV_L1_HIT, False
        self.n_l1_miss += 1
        vta_hit = self.det.on_miss(wid, line_addr) is not None
        if vta_hit:
            self.n_vta_hits += 1
        cfg = self.cfg
        ns = cfg.num_sets
        s = line_addr % ns
        if cfg.xor_hash:
            s = (s ^ ((line_addr // ns) % ns)) % ns
        self._l1_fill(wid, line_addr, s)
        return EV_L1_MISS, vta_hit

    def access(self, wid: int, addr: int, isolated: bool = False,
               bypass: bool = False, count_instruction: bool = True) -> str:
        """Seed-compatible wrapper: counts one detector instruction (unless
        ``count_instruction=False``) and returns the event kind string."""
        if count_instruction:
            det = self.det
            det.inst_total += 1
            det.irs_inst += 1
        code, _ = self.access_ex(wid, addr, isolated, bypass)
        return EVENT_NAMES[code]

    def hit_rate(self) -> float:
        h = self.n_l1_hit + self.n_smem_hit
        tot = h + self.n_l1_miss + self.n_smem_miss + self.n_smem_migrate
        return h / tot if tot else 0.0
