"""Trace-driven SM timing simulator (paper §V-A methodology, Table I).

A single GTX480-like SM: 48 warps, single-issue scheduler, L1D/shared
memory via :mod:`repro.core.onchip`, a 768KB 8-way L2, and DRAM with
bandwidth queueing. Memory events map to latencies; blocked warps wake on
completion; fully-blocked stretches are skipped event-driven so long traces
stay fast in pure Python.

This is deliberately a *relative*-fidelity model: it reproduces the paper's
scheduler ordering phenomena (cache thrashing under GTO, CCWS' TLP loss on
compute-intensive codes, CIAO-P's isolation wins on small working sets,
CIAO-T on large ones, CIAO-C on both) rather than absolute GPU IPC.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.interference import DetectorConfig, InterferenceDetector
from repro.core.onchip import LINE, OnChipConfig, OnChipMemory
from repro.core.policies import BasePolicy, make_policy


def _default_detector() -> DetectorConfig:
    # Epochs scaled to our trace lengths (~200K instructions vs the paper's
    # tens of millions). The paper's own sensitivity sweep (Fig. 11a) shows
    # <15% IPC change across 1K..50K-instruction epochs; benchmarks sweep
    # this again (bench_sensitivity).
    return DetectorConfig(high_epoch=1000, low_epoch=50)


@dataclasses.dataclass
class SimConfig:
    num_warps: int = 48
    lat_l1: int = 1
    lat_smem: int = 1
    lat_migrate: int = 12         # response-queue round trip (§IV-B)
    lat_l2: int = 120
    lat_dram: int = 320
    dram_gap: int = 8             # cycles/request of DRAM bandwidth
    max_mlp: int = 4              # outstanding memory requests per warp
    # every 2nd memory op is a dependent use (load-to-use stall): the warp
    # blocks until that request returns. This is what actually interleaves
    # warps on a real SM (GTO only switches when the greedy warp stalls).
    dep_every: int = 2
    l2_bytes: int = 768 * 1024
    l2_ways: int = 8
    max_cycles: int = 20_000_000
    detector: DetectorConfig = dataclasses.field(default_factory=_default_detector)
    onchip: OnChipConfig = dataclasses.field(default_factory=OnChipConfig)


@dataclasses.dataclass
class SimResult:
    policy: str
    cycles: int
    instructions: int
    ipc: float
    l1_hit_rate: float
    vta_hits: int
    mean_active_warps: float
    stats: Dict[str, int]
    timeline: List[Tuple[int, float, int]]  # (cycle, ipc_window, active)


class L2Cache:
    def __init__(self, size: int, ways: int):
        self.sets = size // (LINE * ways)
        self.ways = ways
        self.tags = [[-1] * ways for _ in range(self.sets)]
        self.lru = [list(range(ways)) for _ in range(self.sets)]
        self.hits = 0
        self.misses = 0

    def access(self, line_addr: int) -> bool:
        s = line_addr % self.sets
        row = self.tags[s]
        for w in range(self.ways):
            if row[w] == line_addr:
                self.lru[s].remove(w)
                self.lru[s].append(w)
                self.hits += 1
                return True
        victim = self.lru[s][0]
        row[victim] = line_addr
        self.lru[s].remove(victim)
        self.lru[s].append(victim)
        self.misses += 1
        return False


class SMSimulator:
    def __init__(self, workload, policy_name: str, cfg: SimConfig = SimConfig(),
                 policy_kwargs: Optional[dict] = None):
        """workload: object with .traces (list of (kinds u8, addrs i64)) and
        .smem_used_bytes (fraction of shared memory the app reserves)."""
        self.cfg = cfg
        self.det = InterferenceDetector(cfg.detector)
        self.mem = OnChipMemory(cfg.onchip, self.det,
                                smem_used_bytes=workload.smem_used_bytes)
        self.l2 = L2Cache(cfg.l2_bytes, cfg.l2_ways)
        self.policy: BasePolicy = make_policy(
            policy_name, cfg.num_warps, self.det, **(policy_kwargs or {}))
        self.traces = workload.traces
        self.n = min(cfg.num_warps, len(self.traces))

    def _mem_latency(self, wid: int, addr: int) -> int:
        c = self.cfg
        isolated = self.policy.is_isolated(wid)
        bypass = self.policy.is_bypass(wid)
        event = self.mem.access(wid, addr, isolated=isolated, bypass=bypass)
        if event == "l1_hit":
            return c.lat_l1
        if event == "smem_hit":
            return c.lat_smem
        if event == "smem_migrate":
            return c.lat_migrate
        # goes to L2 (and maybe DRAM)
        if self.l2.access(addr // LINE):
            lat = c.lat_l2
        else:
            lat = c.lat_dram
            self.dram_reqs += 1
            # bandwidth queueing
            start = max(self.cycle, self.dram_free)
            self.dram_free = start + c.dram_gap
            lat += start - self.cycle
        return lat

    def run(self, timeline_every: int = 20_000) -> SimResult:
        c = self.cfg
        n = self.n
        pc = [0] * n
        ready_at = [0] * n
        pending: List[List[int]] = [[] for _ in range(n)]
        mem_ord = [0] * n
        lens = [len(k) for k, _ in self.traces]
        done = [lens[w] == 0 for w in range(n)]
        remaining = sum(1 for w in range(n) if not done[w])
        instr = 0
        self.cycle = 0
        self.dram_free = 0
        self.dram_reqs = 0
        active_samples = []
        timeline = []
        last_instr = 0
        last_cycle = 0
        window_mark = timeline_every
        low_epoch = c.detector.low_epoch
        epoch_counter = 0
        all_wids = list(range(n))

        kinds = [np.asarray(k) for k, _ in self.traces]
        addrs = [np.asarray(a) for _, a in self.traces]
        # next-memory-instruction index, for batching ALU runs
        next_mem = []
        for k_arr in kinds:
            nm = np.full(len(k_arr) + 1, len(k_arr), np.int64)
            prev = len(k_arr)
            for i in range(len(k_arr) - 1, -1, -1):
                if k_arr[i]:
                    prev = i
                nm[i] = prev
            next_mem.append(nm)

        policy = self.policy
        det = self.det

        while remaining and self.cycle < c.max_cycles:
            # pick a warp: greedy (keep last), else oldest ready & allowed
            wid = policy.last_wid
            if wid is None or done[wid] or ready_at[wid] > self.cycle \
                    or not policy.allow(wid):
                wid = -1
                best = None
                for w in range(n):
                    if done[w] or not policy.allow(w):
                        continue
                    if ready_at[w] <= self.cycle:
                        wid = w
                        break
                    if best is None or ready_at[w] < best:
                        best = ready_at[w]
                if wid < 0:
                    if best is not None:
                        self.cycle = best           # event-driven skip
                    else:
                        # everything throttled: advance to let epochs fire
                        self.cycle += low_epoch
                        det.on_instruction(low_epoch)
                        policy.epoch_tick(all_wids, done, self._mem_util())
                    continue
                policy.last_wid = wid

            p = pc[wid]
            if kinds[wid][p]:
                addr = int(addrs[wid][p])
                before = det.vta_hit_events
                lat = self._mem_latency(wid, addr)
                if det.vta_hit_events > before:
                    policy.on_mem_event(wid, "vta_hit")
                mem_ord[wid] += 1
                done_t = self.cycle + lat
                if c.dep_every and mem_ord[wid] % c.dep_every == 0:
                    # dependent use: block until this request returns
                    ready_at[wid] = done_t
                else:
                    # hit-under-miss: keep issuing until max_mlp outstanding
                    pend = pending[wid]
                    pend.append(done_t)
                    if len(pend) > 8:
                        pend[:] = [t for t in pend if t > self.cycle]
                    outstanding = [t for t in pend if t > self.cycle]
                    if len(outstanding) >= c.max_mlp:
                        ready_at[wid] = min(outstanding)
                    else:
                        ready_at[wid] = self.cycle + 1
                adv = 1
                self.cycle += 1
            else:
                # batch the ALU run up to the next memory instruction
                run_end = int(next_mem[wid][p])
                adv = run_end - p
                det.on_instruction(adv)
                self.cycle += adv
                ready_at[wid] = self.cycle
            pc[wid] += adv
            instr += adv
            if pc[wid] >= lens[wid]:
                done[wid] = True
                remaining -= 1
                policy.on_warp_done(wid)
                if policy.last_wid == wid:
                    policy.last_wid = None

            new_epoch = det.inst_total // low_epoch
            if new_epoch != epoch_counter:
                epoch_counter = new_epoch
                policy.epoch_tick(all_wids, done, self._mem_util())

            if instr >= window_mark:
                act = policy.num_allowed()
                active_samples.append(act)
                dc = max(self.cycle - last_cycle, 1)
                timeline.append((self.cycle, (instr - last_instr) / dc, act))
                last_instr = instr
                last_cycle = self.cycle
                window_mark += timeline_every

        ipc = instr / max(self.cycle, 1)
        return SimResult(
            policy=self.policy.name,
            cycles=self.cycle,
            instructions=instr,
            ipc=ipc,
            l1_hit_rate=self.mem.hit_rate(),
            vta_hits=self.det.vta_hit_events,
            mean_active_warps=(float(np.mean(active_samples))
                               if active_samples else float(self.n)),
            stats=dict(self.mem.stats),
            timeline=timeline,
        )

    def _mem_util(self) -> float:
        if self.cycle == 0:
            return 0.0
        return min(1.0, self.dram_reqs * self.cfg.dram_gap / self.cycle)


def run_policy_sweep(workload, policies: Sequence[str],
                     cfg: SimConfig = SimConfig(),
                     best_swl_limits: Sequence[int] = (2, 4, 6, 8, 16, 32, 48),
                     ) -> Dict[str, SimResult]:
    """Run each policy; Best-SWL/statPCAL get their offline limit sweep
    (the paper profiles N_wrp per benchmark, Table II)."""
    out: Dict[str, SimResult] = {}
    for p in policies:
        if p in ("best-swl", "statpcal"):
            best: Optional[SimResult] = None
            limits = ([workload.n_wrp] if getattr(workload, "n_wrp", 0)
                      else best_swl_limits)
            for lim in limits:
                r = SMSimulator(workload, p, cfg,
                                policy_kwargs={"limit": lim}).run()
                if best is None or r.ipc > best.ipc:
                    best = r
            out[p] = best
        else:
            out[p] = SMSimulator(workload, p, cfg).run()
    return out
