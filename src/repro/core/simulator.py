"""Trace-driven SM timing simulator (paper §V-A methodology, Table I).

A single GTX480-like SM: 48 warps, single-issue scheduler, L1D/shared
memory via :mod:`repro.core.onchip`, and a post-L1 stage — 768KB 8-way
banked L2 + DRAM bandwidth queueing — modeled by
:mod:`repro.core.memory`. Memory events map to latencies; blocked warps
wake on completion; fully-blocked stretches are skipped event-driven so
long traces stay fast in pure Python.

The hot path is flat array/table state end to end: ``ready_at``/``done``
live in ``array('q')``/ndarray buffers (scalar ops through the buffer,
scheduler scans vectorized over zero-copy NumPy views), the dispatch scan
is a vectorized mask pick (allowed & ~done & ready) instead of a per-warp
``policy.allow()`` loop, per-warp traces are pre-compiled to token streams
(one token per dispatch: batched ALU run, or a memory op with the
dependent-use bit baked in), and the policy masks
(:mod:`repro.core.policies`) are cached between the epoch /
warp-completion events that can change them. The epoch-boundary decision
math the ``epoch_tick`` calls reach — detector IRS snapshots, CCWS decay,
statPCAL bypass, CIAO Algorithm 1 — is the batch-first kernel set of
:mod:`repro.core.epoch`, which this scalar path exercises as batch-of-1
views and the batched engine (:mod:`repro.core.batched`) runs over whole
grids at once: one implementation, two batch widths. The full per-access
model is fused into :meth:`SMSimulator.advance` (see its docstring).
Behavior is bit-identical to the seed per-instruction loop — pinned by
``tests/test_equivalence.py`` against golden seed-core snapshots.

The post-L1 :class:`~repro.core.memory.MemoryHierarchy` may be private
(single-SM, the default) or shared between SMs: ``GPUSimulator``
(:mod:`repro.core.gpu`) passes one instance to every SM and advances them
in interleaved time slices via the :meth:`SMSimulator.begin` /
:meth:`SMSimulator.advance` stepping API, so SMs contend on the L2 banks
and DRAM channels. :meth:`SMSimulator.run` wraps the same API for the
classic run-to-completion use.

This is deliberately a *relative*-fidelity model: it reproduces the paper's
scheduler ordering phenomena (cache thrashing under GTO, CCWS' TLP loss on
compute-intensive codes, CIAO-P's isolation wins on small working sets,
CIAO-T on large ones, CIAO-C on both) rather than absolute GPU IPC.
"""
from __future__ import annotations

import dataclasses
from array import array
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.interference import DetectorConfig, InterferenceDetector
from repro.core.memory import MemoryHierarchy
from repro.core.onchip import LINE, OnChipConfig, OnChipMemory
from repro.core.policies import BasePolicy, make_policy
from repro.workloads import tokens as _tokens


# blocked-warp sentinel for the fused scheduler skip (far above any
# reachable ready_at but well inside int64)
_HUGE = 1 << 62

# The trace -> token encoding is owned by repro.workloads.tokens (shared
# with workload persistence); the cache models and the token contract
# must agree on the line size for the tok -> line shift to hold.
assert _tokens.LINE == LINE, "workload token contract disagrees on LINE"
_TOK_LINE_SHIFT = _tokens.TOKEN_LINE_SHIFT


def _default_detector() -> DetectorConfig:
    # Epochs scaled to our trace lengths (~200K instructions vs the paper's
    # tens of millions). The paper's own sensitivity sweep (Fig. 11a) shows
    # <15% IPC change across 1K..50K-instruction epochs; benchmarks sweep
    # this again (bench_sensitivity).
    return DetectorConfig(high_epoch=1000, low_epoch=50)


@dataclasses.dataclass
class SimConfig:
    num_warps: int = 48
    lat_l1: int = 1
    lat_smem: int = 1
    lat_migrate: int = 12         # response-queue round trip (§IV-B)
    lat_l2: int = 120
    lat_dram: int = 320
    dram_gap: int = 8             # cycles/request of DRAM bandwidth/channel
    dram_channels: int = 1
    l2_banks: int = 8
    l2_bank_gap: int = 0          # 0 = unqueued L2 (seed single-SM timing)
    max_mlp: int = 4              # outstanding memory requests per warp
    # every 2nd memory op is a dependent use (load-to-use stall): the warp
    # blocks until that request returns. This is what actually interleaves
    # warps on a real SM (GTO only switches when the greedy warp stalls).
    dep_every: int = 2
    l2_bytes: int = 768 * 1024
    l2_ways: int = 8
    max_cycles: int = 20_000_000
    detector: DetectorConfig = dataclasses.field(default_factory=_default_detector)
    onchip: OnChipConfig = dataclasses.field(default_factory=OnChipConfig)

    def make_hierarchy(self) -> MemoryHierarchy:
        return MemoryHierarchy(
            l2_bytes=self.l2_bytes, l2_ways=self.l2_ways, lat_l2=self.lat_l2,
            lat_dram=self.lat_dram, dram_gap=self.dram_gap,
            l2_banks=self.l2_banks, l2_bank_gap=self.l2_bank_gap,
            dram_channels=self.dram_channels)


@dataclasses.dataclass
class SimResult:
    policy: str
    cycles: int
    instructions: int
    ipc: float
    l1_hit_rate: float
    vta_hits: int
    mean_active_warps: float
    stats: Dict[str, int]
    timeline: List[Tuple[int, float, int]]  # (cycle, ipc_window, active)
    # interference pair events (evictor_wid, victim_wid, count), most
    # frequent first — the Fig. 4 skew data
    pairs: List[List[int]] = dataclasses.field(default_factory=list)


class SMSimulator:
    """One SM. Either ``run()`` to completion, or step it cooperatively:

        sm.begin()
        while not sm.finished:
            sm.advance(until_cycle)     # runs until local cycle >= until
        result = sm.result()
    """

    def __init__(self, workload, policy_name: str,
                 cfg: Optional[SimConfig] = None,
                 policy_kwargs: Optional[dict] = None,
                 mem_system: Optional[MemoryHierarchy] = None):
        """workload: object with .traces (list of (kinds u8, addrs i64)) and
        .smem_used_bytes (fraction of shared memory the app reserves).
        ``mem_system``: a shared post-L1 hierarchy; private when None."""
        self.cfg = cfg = cfg if cfg is not None else SimConfig()
        self._policy_name = policy_name
        self._policy_kwargs = policy_kwargs or {}
        self._smem_used_bytes = workload.smem_used_bytes
        self._mem_private = mem_system is None
        self.mem_sys = mem_system if mem_system is not None \
            else cfg.make_hierarchy()
        self.traces = workload.traces
        self.n = min(cfg.num_warps, len(self.traces))
        self._build_sm_state()
        self._begun = False

    def _build_sm_state(self) -> None:
        """Fresh detector + on-chip memory + policy (per-run state)."""
        cfg = self.cfg
        self.det = InterferenceDetector(cfg.detector)
        self.mem = OnChipMemory(cfg.onchip, self.det,
                                smem_used_bytes=self._smem_used_bytes)
        self.policy: BasePolicy = make_policy(
            self._policy_name, cfg.num_warps, self.det,
            **self._policy_kwargs)

    # -------------------------------------------------------- stepping API
    def begin(self) -> None:
        """Reset run state; must precede ``advance``. Re-running an
        instance gives identical results: detector, L1/smem, policy, and
        (when private) the L2/DRAM hierarchy are all rebuilt. A shared
        hierarchy is left alone — its owner (``GPUSimulator``) resets it
        once for all SMs."""
        if self._begun:
            self._build_sm_state()
        if self._mem_private:
            self.mem_sys.reset()
        n = self.n
        cfg = self.cfg
        # ready_at is an array('q') buffer with a zero-copy NumPy view on
        # top: scalar reads/writes in the dispatch loop go through the
        # buffer (a fraction of a NumPy scalar store), the scheduler scans
        # run vectorized over the shared memory via the view
        self._ready_buf = array("q", bytes(8 * n))
        self.ready_at = np.frombuffer(self._ready_buf, dtype=np.int64)
        self.pending: List[List[int]] = [[] for _ in range(n)]
        self.lens = [len(k) for k, _ in self.traces]
        self.done = np.asarray([self.lens[w] == 0 for w in range(n)], bool)
        self.remaining = int(n - np.count_nonzero(self.done))
        self.instr = 0
        self.cycle = 0
        self.dram_reqs = 0
        self.active_samples: List[int] = []
        self.timeline: List[Tuple[int, float, int]] = []
        self._last_instr = 0
        self._last_cycle = 0
        self._window_mark = self.timeline_every
        self._all_wids = np.arange(n)
        # Each per-warp trace is pre-compiled (vectorized) into a token
        # stream consumed one token per dispatch — see
        # repro.workloads.tokens for the encoding (batched ALU runs as
        # negative tokens; memory ops carry the dependent-use bit baked in
        # from the dep_every pattern, so the loop needs no per-op memory
        # ordinal bookkeeping).
        self._ops: List[List[int]] = _tokens.encode_workload(
            self.traces, cfg.dep_every, n)
        self._op_idx = [0] * n
        self._n_ops = [len(t) for t in self._ops]
        # cached dispatch mask: policy.allowed_mask & ~done, refreshed only
        # after the calls that can change it (epoch_tick / on_warp_done);
        # same buffer+view trick as ready_at, isolated/bypass as list twins
        self._mask_version = -1
        self._avail_buf = array("b", bytes(n))
        self._avail = np.frombuffer(self._avail_buf, dtype=np.bool_)
        self._iso_list = [False] * n
        self._byp_list = [False] * n
        self._cand = np.zeros(n, bool)        # scratch for scheduler scans
        self._mshr_gate = cfg.onchip.mshr_gate
        # per-cell epoch next-trigger table (policy-informed; persists
        # across advance() slices): passive policies park at infinity,
        # CIAO with empty reactivation stacks skips to the next
        # high-cutoff boundary — identical decisions, 20x fewer
        # epoch_tick trips on idle CIAO cells (the batched engine
        # precomputes the same table)
        self._next_epoch = self.policy.next_epoch_after(0)
        self._begun = True

    timeline_every: int = 20_000

    @property
    def finished(self) -> bool:
        return self._begun and self.remaining == 0

    def advance(self, until: int) -> None:
        """Advance the SM until its local cycle reaches ``until`` (clamped
        there when every warp is blocked past the slice boundary, so a
        co-scheduled SM can interleave) or all warps finish.

        This is the fused hot path: the full per-access chain — L1D lookup
        and fill, shared-memory redirection, VTA insert/probe, interference
        bookkeeping, L2 tags and DRAM queueing — is inlined here over
        pre-bound local variables, with every counter kept in a local and
        flushed to the owning objects around ``epoch_tick`` calls (their
        only mid-run reader) and at exit. On the measurement box a CPython
        attribute round-trip costs ~4 simple local ops, so the unfused
        call-per-access version of this loop runs ~3x slower; the class
        methods in :mod:`repro.core.onchip` / :mod:`repro.core.memory` /
        :mod:`repro.core.vta` remain the reference implementations over the
        *same* state, and ``tests/test_equivalence.py`` pins this loop
        bit-for-bit against golden seed-core runs (all policies, smem and
        migrate paths, a shared-L2 multi-SM run).
        """
        c = self.cfg
        n = self.n
        until = min(until, c.max_cycles)
        pending = self.pending
        ready_np, ready = self.ready_at, self._ready_buf
        done = self.done
        ops, op_idx, n_ops = self._ops, self._op_idx, self._n_ops
        low_epoch = c.detector.low_epoch
        max_mlp = c.max_mlp
        lat_l1, lat_smem = c.lat_l1, c.lat_smem
        lat_migrate, lat_l2, lat_dram = c.lat_migrate, c.lat_l2, c.lat_dram
        timeline_every = self.timeline_every
        policy = self.policy
        on_mem_event = policy.on_mem_event
        epoch_tick = policy.epoch_tick
        det = self.det
        mem = self.mem
        mem_sys = self.mem_sys
        mshr = mem.mshr
        mshr_gate = self._mshr_gate
        wids_arr = self._all_wids
        active_samples, timeline = self.active_samples, self.timeline

        # ---- L1D / smem state (repro.core.onchip layout) ----
        oc = c.onchip
        l1_index = mem._line_index
        l1_tags, l1_owners = mem.tags, mem.owners
        l1_reused, l1_stamp = mem.reused, mem.stamp
        tick = mem._tick
        l1_sets, l1_ways = oc.num_sets, oc.ways
        xor_hash, reuse_filter = oc.xor_hash, oc.reuse_filter
        region_blocks = mem.region_blocks
        smem_tags, smem_owner = mem.smem_tags, mem.smem_owner
        n_l1_hit, n_l1_miss = mem.n_l1_hit, mem.n_l1_miss
        n_smem_hit, n_smem_miss = mem.n_smem_hit, mem.n_smem_miss
        n_smem_migrate, n_bypass = mem.n_smem_migrate, mem.n_bypass
        n_evictions, n_smem_evictions = mem.n_evictions, mem.n_smem_evictions
        n_vta_hits = mem.n_vta_hits

        # ---- VTA / detector state (repro.core.vta / .interference) ----
        vta = det.vta
        v_addr, v_evic = vta.addr, vta.evictor
        v_head, v_count, v_member = vta._head, vta._count, vta._member
        v_hits = vta.hits
        v_sets, v_k = vta.num_sets, vta.tags_per_set
        v_inserts = vta.inserts
        vta_hit_events = det.vta_hit_events
        irs_hits, pair_counts = det.irs_hits, det.pair_counts
        interfering, sat_counter = det.interfering_wid, det.sat_counter
        dcfg = det.cfg
        nw, list_entries, sat_max = dcfg.num_warps, dcfg.list_entries, \
            dcfg.sat_max

        def _vta_insert(owner, victim_line, evictor):
            """Circular-FIFO insert (fused ``vta.insert``); the caller has
            already excluded self-eviction."""
            nonlocal v_inserts
            s = owner % v_sets
            base = s * v_k
            memb = v_member[s]
            h = v_head[s]
            cc = v_count[s]
            if cc == v_k:                       # full: FIFO-drop the oldest
                f = base + h
                dropped = v_addr[f]
                left = memb[dropped] - 1
                if left:
                    memb[dropped] = left
                else:
                    del memb[dropped]
                v_addr[f] = victim_line
                v_evic[f] = evictor
                v_head[s] = (h + 1) % v_k
            else:
                f = base + (h + cc) % v_k
                v_addr[f] = victim_line
                v_evic[f] = evictor
                v_count[s] = cc + 1
            memb[victim_line] = memb.get(victim_line, 0) + 1
            v_inserts += 1

        def _vta_probe_hit(wid, line):
            """FIFO pop of the oldest match + interference-list/pair-count
            bookkeeping (the fused ``interference.on_miss`` hit path); the
            caller has already confirmed membership."""
            nonlocal vta_hit_events, n_vta_hits
            s = wid % v_sets
            base = s * v_k
            memb = v_member[s]
            h = v_head[s]
            cc = v_count[s]
            evictor = -1
            for j in range(cc):                 # oldest-first logical order
                f = base + (h + j) % v_k
                if v_addr[f] == line:
                    evictor = v_evic[f]
                    # close the gap: shift logically-younger entries back
                    for jj in range(j, cc - 1):
                        f0 = base + (h + jj) % v_k
                        f1 = base + (h + jj + 1) % v_k
                        v_addr[f0] = v_addr[f1]
                        v_evic[f0] = v_evic[f1]
                    fl = base + (h + cc - 1) % v_k
                    v_addr[fl] = -1
                    v_evic[fl] = -1
                    v_count[s] = cc - 1
                    left = memb[line] - 1
                    if left:
                        memb[line] = left
                    else:
                        del memb[line]
                    v_hits[s] += 1
                    break
            vta_hit_events += 1
            n_vta_hits += 1
            irs_hits[wid % nw] += 1
            key = (evictor, wid)
            pair_counts[key] = pair_counts.get(key, 0) + 1
            i = wid % list_entries
            if interfering[i] == evictor:
                if sat_counter[i] < sat_max:
                    sat_counter[i] += 1
            elif interfering[i] == -1:
                interfering[i] = evictor
                sat_counter[i] = 0
            elif sat_counter[i] == 0:
                interfering[i] = evictor
            else:
                sat_counter[i] -= 1

        # ---- post-L1 stage (repro.core.memory); the inline fast path
        # covers the default unqueued L2 — nonzero bank gaps (the GPU
        # contention variant) go through the object methods ----
        l2 = mem_sys.l2
        fast_l2 = l2.bank_gap == 0
        l2t = l2.tags
        l2_index, l2_tags, l2_stamp = l2t._line_index, l2t.tags, l2t.stamp
        l2_tick, l2_hits, l2_misses = l2t._tick, l2t.hits, l2t.misses
        l2_sets, l2_ways = l2t.sets, l2t.ways
        dram = mem_sys.dram
        dram_free, dram_gap = dram.free_at, dram.gap
        dram_channels, dram_requests = dram.channels, dram.requests
        dram_reqs = self.dram_reqs

        cycle, instr = self.cycle, self.instr
        remaining = self.remaining
        window_mark = self._window_mark
        last_instr, last_cycle = self._last_instr, self._last_cycle
        mask_ver = self._mask_version
        avail_np, avail = self._avail, self._avail_buf
        iso, byp = self._iso_list, self._byp_list
        cand = self._cand
        li = det.inst_total                       # local mirrors; irs_inst
        irs_off = li - det.irs_inst               # tracks li minus an offset
                                                  # that only aging changes
        next_epoch = self._next_epoch
        last_wid = policy.last_wid
        if last_wid is None:
            last_wid = -1
        # the policy masks only change inside epoch_tick / on_warp_done, so
        # the cached avail/iso/byp twins are refreshed right after those
        # call sites (and here, on entry) instead of every loop iteration
        if policy.mask_version != mask_ver:
            mask_ver = policy.mask_version
            avail_np[:] = policy.allowed_mask[:n] & ~done
            iso = policy.isolated_mask.tolist()
            byp = policy.bypass_mask.tolist()

        while remaining and cycle < until:
            # pick a warp: greedy (keep last), else oldest ready & allowed
            wid = last_wid
            if wid < 0 or not avail[wid] or ready[wid] > cycle:
                np.less_equal(ready_np, cycle, out=cand)
                cand &= avail_np
                w = int(cand.argmax())
                if cand[w]:
                    wid = last_wid = w
                else:
                    # nobody ready now: jump to the earliest wake-up and
                    # dispatch in the same iteration (fused event skip)
                    sched = np.where(avail_np, ready_np, _HUGE)
                    w = int(sched.argmin())
                    if not avail[w]:
                        # everything throttled: advance to let epochs fire
                        cycle += low_epoch
                        li += low_epoch
                        det.inst_total, det.irs_inst = li, li - irs_off
                        if fast_l2:
                            util = dram_requests * dram_gap / \
                                (dram_channels * cycle) if cycle > 0 else 0.0
                            if util > 1.0:
                                util = 1.0
                        else:
                            util = mem_sys.utilization(cycle)
                        epoch_tick(None, done, util)
                        irs_off = li - det.irs_inst   # aging moves this
                        if policy.mask_version != mask_ver:
                            mask_ver = policy.mask_version
                            avail_np[:] = policy.allowed_mask[:n] & ~done
                            iso = policy.isolated_mask.tolist()
                            byp = policy.bypass_mask.tolist()
                        continue
                    best = ready[w]
                    if best >= until:
                        # clamp to the slice boundary for the co-scheduled
                        # SMs; the next advance() call resumes from here
                        cycle = until
                        continue
                    cycle = best
                    # greedy still wins a tie at the new cycle; otherwise
                    # the lowest-wid warp ready at `best` issues (argmin's
                    # first-tie rule = the seed's lowest-index scan)
                    lw = last_wid
                    if lw >= 0 and avail[lw] and ready[lw] <= best:
                        wid = lw
                    else:
                        wid = last_wid = w

            p = op_idx[wid]
            tok = ops[wid][p]
            if tok >= 0:                          # memory instruction
                li += 1
                line = tok >> _TOK_LINE_SHIFT   # == (tok >> 1) // LINE
                vta_hit = False
                # ---------------- on-chip stage (fused onchip.access_ex)
                if byp[wid]:                      # statPCAL bypass
                    n_bypass += 1
                    lat = None                    # -> post-L1 stage
                elif iso[wid]:                    # CIAO-P smem redirection
                    if region_blocks <= 0:        # no borrowed region at all
                        lat = None
                    else:
                        idx = line % region_blocks
                        old = smem_tags[idx]
                        if old == line:
                            n_smem_hit += 1
                            lat = lat_smem
                        else:
                            if old >= 0:
                                # victim goes to the owner warp's VTA set
                                n_smem_evictions += 1
                                owner = smem_owner[idx]
                                if owner != wid:
                                    _vta_insert(owner, old, wid)
                            # VTA probe (fused interference.on_miss)
                            if line in v_member[wid % v_sets]:
                                _vta_probe_hit(wid, line)
                                vta_hit = True
                            # migration: single-copy coherence (§IV-B)
                            f = l1_index.pop(line, None)
                            if f is not None:
                                l1_tags[f] = -1
                                l1_owners[f] = -1
                                n_smem_migrate += 1
                                lat = lat_migrate
                                if mshr_gate:
                                    lat += mshr.admit(cycle, lat)
                            else:
                                n_smem_miss += 1
                                lat = None        # smem miss -> post-L1
                            smem_tags[idx] = line
                            smem_owner[idx] = wid
                else:
                    f = l1_index.get(line)
                    if f is not None:             # L1D hit
                        n_l1_hit += 1
                        l1_reused[f] = True
                        l1_stamp[f] = tick
                        tick += 1
                        lat = lat_l1
                    else:                         # L1D miss
                        n_l1_miss += 1
                        # VTA probe (fused interference.on_miss)
                        if line in v_member[wid % v_sets]:
                            _vta_probe_hit(wid, line)
                            vta_hit = True
                        # L1 fill (fused onchip._l1_fill): XOR set index,
                        # stamp-LRU victim, evicted line to the VTA
                        s1 = line % l1_sets
                        if xor_hash:
                            s1 = (s1 ^ ((line // l1_sets) % l1_sets)) \
                                % l1_sets
                        base1 = s1 * l1_ways
                        f = base1
                        bs = l1_stamp[base1]
                        for g in range(base1 + 1, base1 + l1_ways):
                            v = l1_stamp[g]
                            if v < bs:
                                bs = v
                                f = g
                        old = l1_tags[f]
                        if old >= 0:
                            n_evictions += 1
                            owner = l1_owners[f]
                            if (l1_reused[f] or not reuse_filter) \
                                    and owner != wid:
                                _vta_insert(owner, old, wid)
                            del l1_index[old]
                        l1_tags[f] = line
                        l1_owners[f] = wid
                        l1_reused[f] = False
                        l1_index[line] = f
                        l1_stamp[f] = tick
                        tick += 1
                        lat = None                # miss -> post-L1 stage

                # ------------- post-L1 stage (fused memory.MemoryHierarchy)
                if lat is None:
                    if fast_l2:
                        f2 = l2_index.get(line)
                        if f2 is not None:        # L2 hit
                            l2_hits += 1
                            lat = lat_l2
                        else:                     # L2 miss -> DRAM queue
                            base2 = (line % l2_sets) * l2_ways
                            f2 = base2
                            bs = l2_stamp[base2]
                            for g in range(base2 + 1, base2 + l2_ways):
                                v = l2_stamp[g]
                                if v < bs:
                                    bs = v
                                    f2 = g
                            old2 = l2_tags[f2]
                            if old2 >= 0:
                                del l2_index[old2]
                            l2_tags[f2] = line
                            l2_index[line] = f2
                            l2_misses += 1
                            ch = (line >> 2) % dram_channels
                            free = dram_free[ch]
                            start = cycle if cycle > free else free
                            dram_free[ch] = start + dram_gap
                            dram_requests += 1
                            dram_reqs += 1
                            lat = lat_dram + start - cycle
                        l2_stamp[f2] = l2_tick
                        l2_tick += 1
                    else:
                        lat, level = mem_sys.access(line, cycle)
                        if level == "dram":
                            dram_reqs += 1
                    if mshr_gate and not byp[wid]:
                        lat += mshr.admit(cycle, lat)

                if vta_hit:
                    on_mem_event(wid, "vta_hit")
                done_t = cycle + lat
                if tok & 1:
                    # dependent use: block until this request returns
                    ready[wid] = done_t
                else:
                    # hit-under-miss: keep issuing until max_mlp outstanding
                    pend = pending[wid]
                    pend.append(done_t)
                    if len(pend) > max_mlp:
                        pend[:] = [t for t in pend if t > cycle]
                    # single pass over the (small) queue: count the still-
                    # outstanding requests and find the earliest return
                    outstanding = 0
                    earliest = 1 << 62
                    for t in pend:
                        if t > cycle:
                            outstanding += 1
                            if t < earliest:
                                earliest = t
                    if outstanding >= max_mlp:
                        ready[wid] = earliest
                    else:
                        ready[wid] = cycle + 1
                adv = 1
                cycle += 1
            else:
                # batched ALU run up to the next memory instruction
                adv = -tok
                li += adv
                cycle += adv
                ready[wid] = cycle
            p += 1
            op_idx[wid] = p
            instr += adv
            if p >= n_ops[wid]:
                done[wid] = True
                avail[wid] = 0
                remaining -= 1
                policy.on_warp_done(wid)
                if last_wid == wid:
                    last_wid = -1
                if policy.mask_version != mask_ver:
                    mask_ver = policy.mask_version
                    avail_np[:] = policy.allowed_mask[:n] & ~done
                    iso = policy.isolated_mask.tolist()
                    byp = policy.bypass_mask.tolist()

            if li >= next_epoch:
                det.inst_total, det.irs_inst = li, li - irs_off
                if fast_l2:
                    util = dram_requests * dram_gap / \
                        (dram_channels * cycle) if cycle > 0 else 0.0
                    if util > 1.0:
                        util = 1.0
                else:
                    util = mem_sys.utilization(cycle)
                epoch_tick(None, done, util)
                irs_off = li - det.irs_inst      # aging moves this
                # re-read the trigger table after the tick (stack pushes
                # switch CIAO back to low-epoch granularity)
                next_epoch = policy.next_epoch_after(li)
                if policy.mask_version != mask_ver:
                    mask_ver = policy.mask_version
                    avail_np[:] = policy.allowed_mask[:n] & ~done
                    iso = policy.isolated_mask.tolist()
                    byp = policy.bypass_mask.tolist()

            if instr >= window_mark:
                act = policy.num_allowed()
                active_samples.append(act)
                dc = cycle - last_cycle
                if dc < 1:
                    dc = 1
                timeline.append((cycle, (instr - last_instr) / dc, act))
                last_instr = instr
                last_cycle = cycle
                window_mark += timeline_every

        # ---- flush local mirrors back to the owning objects ----
        det.inst_total, det.irs_inst = li, li - irs_off
        det.vta_hit_events = vta_hit_events
        vta.inserts = v_inserts
        mem._tick = tick
        mem.n_l1_hit, mem.n_l1_miss = n_l1_hit, n_l1_miss
        mem.n_smem_hit, mem.n_smem_miss = n_smem_hit, n_smem_miss
        mem.n_smem_migrate, mem.n_bypass = n_smem_migrate, n_bypass
        mem.n_evictions = n_evictions
        mem.n_smem_evictions = n_smem_evictions
        mem.n_vta_hits = n_vta_hits
        if fast_l2:
            l2t._tick = l2_tick
            l2t.hits, l2t.misses = l2_hits, l2_misses
            dram.requests = dram_requests
        self.dram_reqs = dram_reqs
        policy.last_wid = last_wid if last_wid >= 0 else None
        self.cycle, self.instr = cycle, instr
        self.remaining = remaining
        self._next_epoch = next_epoch
        self._window_mark = window_mark
        self._last_instr, self._last_cycle = last_instr, last_cycle
        self._mask_version = mask_ver
        self._iso_list, self._byp_list = iso, byp

    def result(self) -> SimResult:
        ipc = self.instr / max(self.cycle, 1)
        pairs = sorted(([e, w, c] for (e, w), c
                        in self.det.pair_counts.items()),
                       key=lambda t: (-t[2], t[0], t[1]))
        stats = dict(self.mem.stats, dram_reqs=self.dram_reqs)
        if self.mem.mshr.gate:
            stats["mshr_full"] = self.mem.mshr.full_events
        return SimResult(
            policy=self.policy.name,
            cycles=self.cycle,
            instructions=self.instr,
            ipc=ipc,
            l1_hit_rate=self.mem.hit_rate(),
            vta_hits=self.det.vta_hit_events,
            mean_active_warps=(float(np.mean(self.active_samples))
                               if self.active_samples else float(self.n)),
            stats=stats,
            timeline=list(self.timeline),
            pairs=pairs,
        )

    # ------------------------------------------------------- classic entry
    def run(self, timeline_every: int = 20_000) -> SimResult:
        self.timeline_every = timeline_every
        self.begin()
        self.advance(self.cfg.max_cycles)
        return self.result()

    def _mem_util(self) -> float:
        return self.mem_sys.utilization(self.cycle)


def run_policy_sweep(workload, policies: Sequence[str],
                     cfg: Optional[SimConfig] = None,
                     best_swl_limits: Sequence[int] = (2, 4, 6, 8, 16, 32, 48),
                     ) -> Dict[str, SimResult]:
    """Run each policy; Best-SWL/statPCAL get their offline limit sweep
    (the paper profiles N_wrp per benchmark, Table II)."""
    cfg = cfg if cfg is not None else SimConfig()
    out: Dict[str, SimResult] = {}
    for p in policies:
        if p in ("best-swl", "statpcal"):
            best: Optional[SimResult] = None
            limits = ([workload.n_wrp] if getattr(workload, "n_wrp", 0)
                      else best_swl_limits)
            for lim in limits:
                r = SMSimulator(workload, p, cfg,
                                policy_kwargs={"limit": lim}).run()
                if best is None or r.ipc > best.ipc:
                    best = r
            out[p] = best
        else:
            out[p] = SMSimulator(workload, p, cfg).run()
    return out
