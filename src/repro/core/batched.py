"""Batched lockstep SM engine: whole experiment grids as one program.

The scalar core (:mod:`repro.core.simulator`) hit the measured ceiling of
a per-cell CPython dispatch loop; every figure sweep, though, runs dozens
of *independent* (workload, policy, seed, variant) cells over the same
deterministic integer state machine. This module stacks the per-cell
state ``SMSimulator`` keeps as scalars/lists — warp cursors, token
streams (padded/stacked via :func:`repro.workloads.tokens.
stack_token_streams`), L1/smem tag planes, VTA FIFOs, policy masks,
detector counters, L2 tags and DRAM queues — along a leading batch axis,
and advances all rows of a homogeneous group (same :class:`SimConfig`)
together.

Two interchangeable steppers drive the *same* stacked arrays:

* ``numpy`` — the lockstep stepper: one scheduler dispatch per live row
  per iteration, the full per-access chain (greedy/oldest pick, L1D way
  scan, VTA insert, L2 tags, DRAM queueing, MLP pending queues) as
  masked vectorized updates. Runs everywhere.
* ``c`` — the same per-dispatch state machine transliterated to C
  (thread-free, int64 only), compiled on demand with the system C
  compiler via :mod:`repro.core._cstep` and driven through ``ctypes``
  over the identical array layout. When no compiler is available the
  engine silently uses the numpy stepper.

``backend="auto"`` picks ``c`` when available. Both steppers are
**bit-exact per cell** against ``SMSimulator``/``GPUSimulator``: only
the deterministic integer per-dispatch chain runs inside a stepper —
rows pause at epoch boundaries, warp completions, timeline samples,
fully-throttled stretches and slice boundaries, and the epoch-boundary
decision math (detector IRS snapshots, all seven policy families'
``epoch_tick``) is serviced by ONE vectorized pass per pause-drain over
the stacked planes, using the same :mod:`repro.core.epoch` kernels the
scalar objects delegate to with ``B == 1``. The per-cell detector and
policy objects are re-pointed at rows of those planes (``adopt_*``), so
object reads and kernel writes share memory and remain the single
implementation. ``tests/test_batched.py`` pins both steppers against
the golden cells; ``tests/test_epoch.py`` property-tests the kernels.

**Epoch next-trigger tables.** Policies that keep the base no-op
``epoch_tick`` (GTO, Best-SWL) park their epoch trigger at infinity.
CIAO cells whose reactivation stacks are empty have provably no-op
low-cutoff epochs (Algorithm 1 lines 4-19 touch nothing, and the
low-window IRS snapshot feeds no decision), so their next trigger is
precomputed at the next *high*-cutoff boundary — the steppers run
straight through the 20 intervening low epochs instead of pausing into
Python for each. Stacks only grow at high-epoch actions, so the table
is exact; it is rebuilt after every serviced epoch.

**Multi-SM grids** batch too: a ``GPUConfig`` stacks each cell as
``num_sms`` rows — the same per-SM trace slices
:func:`repro.core.gpu.sm_subworkloads` gives ``GPUSimulator`` — whose
post-L1 planes (L2 tags, DRAM channel queues, the chip-wide request
counter) are shared through a row -> hierarchy indirection (``mem_of``).
Rows replay the scalar chip's slice-interleaved schedule exactly: SM 0
of every cell advances to the slice boundary, then SM 1, ...; rows of
different cells share nothing and run concurrently inside a phase.

Not every cell batches: two scalar-core configuration corners (queued
L2 banks, MSHR occupancy gating) are modeled through object methods the
steppers do not replicate. :func:`supports_config` is the gate; the
runner (:mod:`repro.core.runner`) falls back to per-cell execution for
those.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import epoch as _epoch
from repro.core import faults
from repro.core.gpu import GPUConfig, GPUResult, sm_subworkloads
from repro.core.interference import InterferenceDetector
from repro.core.onchip import LINE, SMMT
from repro.core.policies import (BasePolicy, BestSWLPolicy, CCWSPolicy,
                                 CIAOPolicy, StatPCALPolicy, make_policy)
from repro.core.simulator import SimConfig, SimResult, _HUGE
from repro.workloads import tokens as _tokens

_SHIFT = _tokens.TOKEN_LINE_SHIFT

# pause-reason bits shared with the C stepper (src/repro/core/_cstep.c)
P_EPOCH = 1
P_TIMELINE = 2
P_WARPDONE = 4
P_THROTTLE = 8
P_CAP = 16          # legacy alias: a slice stop at the cycle cap
P_SLICE = 32

P_FINALIZE = 64     # C stepper: row completed, Python only finalizes

# policy families for the vectorized epoch dispatch
F_PASSIVE = 0       # no-op epoch_tick (GTO, Best-SWL): never pauses
F_CCWS = 1
F_STATP = 2
F_CIAO = 3
F_OBJECT = 4        # unknown subclass: per-cell object fallback

# warp-done families for the vectorized retirement dispatch
WD_NOOP = 0         # BasePolicy.on_warp_done (GTO, CCWS, CIAO)
WD_SWL = 1          # Best-SWL rotation: allowed_pl row IS the set
WD_STATP = 2        # statPCAL rotation on the base set + mode rebuild
WD_OBJECT = 3       # unknown subclass: per-cell object fallback


class DeadlineExceeded(RuntimeError):
    """Raised by :meth:`BatchedSMEngine.run` when the wall-clock
    ``deadline`` passes mid-run. The engine's state is mid-flight and
    not salvageable; callers (``run_grid(deadline_s=...)``) mark the
    chunk's cells truncated-but-resumable and cancel pending chunks."""


# when a wall-clock deadline is armed, single-SM batches run in bounded
# per-row `until` quanta (the same slice mechanism multi-SM chips always
# use, so results stay bit-identical) instead of one run-to-completion
# stepper call — the deadline is checked between quanta. 100k cycles is
# ~1ms of C-stepper work per row: fine-grained enough for second-scale
# deadlines, coarse enough that slicing overhead stays in the noise.
_DEADLINE_SLICE = 100_000


def supports_config(cfg: SimConfig, gpu: Optional[GPUConfig] = None) -> bool:
    """Can the batched engine reproduce this config bit-exactly?

    The scalar core's fused fast path requires an unqueued L2
    (``l2_bank_gap == 0``) and no MSHR occupancy gating; those corners go
    through object methods (``MemoryHierarchy.access`` / ``MSHR.admit``)
    that the steppers do not replicate. Multi-SM chips (``gpu``) batch
    under the same conditions — the shared post-L1 stage is stacked as
    per-hierarchy planes and the slice-interleaved SM schedule is
    replayed exactly."""
    return cfg.l2_bank_gap == 0 and not cfg.onchip.mshr_gate


def config_shape_key(cfg: SimConfig,
                     gpu: Optional[GPUConfig] = None) -> tuple:
    """The plane-shape-affecting fields of a config. Cells whose configs
    agree on this key batch together: the remaining scalar knobs
    (latencies, DRAM gap, epoch lengths, cutoffs, aging period, cycle
    cap) ride in per-row config planes, so a cutoff x throttle-depth
    sweep is ONE batch per shape class. The runner groups on this key.
    """
    d = cfg.detector
    return (cfg.num_warps, cfg.dep_every, cfg.max_mlp,
            cfg.dram_channels, cfg.l2_bytes, cfg.l2_ways,
            cfg.l2_banks, cfg.l2_bank_gap, repr(cfg.onchip),
            d.num_warps, d.list_entries, d.vta_sets,
            d.vta_tags_per_set, d.sat_max,
            repr(gpu) if gpu is not None else None)


@dataclasses.dataclass
class BatchCell:
    """One grid cell: a workload under one policy. ``cfg`` optionally
    carries a per-cell :class:`SimConfig` whose scalar *knob* fields
    (latencies, epoch lengths, cutoffs, cycle cap) may differ from the
    rest of the batch; shape-affecting fields must agree batch-wide
    (:func:`config_shape_key`). ``cfg=None`` uses the engine's config."""
    workload: Any
    policy: str
    policy_kwargs: Optional[dict] = None
    cfg: Optional[SimConfig] = None


class BatchedSMEngine:
    """Run B cells (single-SM, or ``gpu.num_sms`` rows each) to
    completion in lockstep.

    Usage::

        results = BatchedSMEngine(cells, cfg).run()      # List[SimResult]
        results = BatchedSMEngine(cells, cfg, gpu=g).run()  # List[GPUResult]
    """

    timeline_every: int = 20_000

    def __init__(self, cells: Sequence[BatchCell],
                 cfg: Optional[SimConfig] = None,
                 backend: str = "auto",
                 gpu: Optional[GPUConfig] = None):
        self.cells = list(cells)
        if not self.cells:
            raise ValueError("empty batch")
        base = cfg if cfg is not None else SimConfig()
        # per-cell configs: knob fields vary row-wise, shape fields must
        # agree (the runner groups on config_shape_key before building)
        self.cell_cfgs = [c.cfg if c.cfg is not None else base
                          for c in self.cells]
        self.cfg = cfg = self.cell_cfgs[0]
        key0 = config_shape_key(cfg, gpu)
        for other in self.cell_cfgs[1:]:
            if config_shape_key(other, gpu) != key0:
                raise ValueError(
                    "heterogeneous batch: cells disagree on "
                    "shape-affecting config fields; group by "
                    "config_shape_key first")
        for ccfg in self.cell_cfgs:
            if not supports_config(ccfg, gpu):
                raise ValueError(
                    "config not supported by the batched engine "
                    "(l2_bank_gap != 0 or mshr_gate); use SMSimulator")
        if backend not in ("auto", "numpy", "c", "jax"):
            raise ValueError(f"unknown backend {backend!r}")
        self._backend_req = backend
        self.gpu = gpu
        self.S = gpu.num_sms if gpu is not None else 1
        self.n_cells = len(self.cells)
        self.B = self.n_cells * self.S        # rows
        # time-breakdown accumulators (seconds); stepper and drain are
        # disjoint for both the C and numpy paths (each round is a
        # run-to-pause stepper stretch followed by one batched drain)
        self.perf: Dict[str, float] = {"build_s": 0.0, "stepper_s": 0.0,
                                       "drain_s": 0.0, "rounds": 0.0}
        t0 = time.perf_counter()
        self._build_state()
        self.perf["build_s"] = time.perf_counter() - t0

    # ------------------------------------------------------------ set-up
    def _row_workloads(self) -> List[Any]:
        """One trace-carrying workload per row: the cell's workload for
        single-SM batches, its per-SM slices for multi-SM batches."""
        if self.gpu is None:
            return [cell.workload for cell in self.cells]
        subs_of: Dict[int, List[Any]] = {}
        rows: List[Any] = []
        for cell in self.cells:
            wl = cell.workload
            subs = subs_of.get(id(wl))
            if subs is None:
                subs = subs_of[id(wl)] = sm_subworkloads(wl, self.gpu)
            rows.extend(subs)
        return rows

    def _build_state(self) -> None:
        cfg = self.cfg
        B, S = self.B, self.S
        oc = cfg.onchip
        dcfg = cfg.detector
        self.n_warps = n = cfg.num_warps
        self.max_mlp = cfg.max_mlp
        self.l1_sets, self.l1_ways = oc.num_sets, oc.ways
        self.xor_hash, self.reuse_filter = oc.xor_hash, oc.reuse_filter
        self.v_sets, self.v_k = dcfg.vta_sets, dcfg.vta_tags_per_set
        self.nw, self.list_entries = dcfg.num_warps, dcfg.list_entries
        self.sat_max = dcfg.sat_max
        # same clamps as L2TagArray / DRAMModel (a tiny L2 still has one
        # set; zero channels still means one)
        self.l2_sets = max(cfg.l2_bytes // (LINE * cfg.l2_ways), 1)
        self.l2_ways = cfg.l2_ways
        self.dram_channels = max(cfg.dram_channels, 1)
        nf = self.l1_sets * self.l1_ways
        vnf = self.v_sets * self.v_k
        l2nf = self.l2_sets * self.l2_ways
        P = self.max_mlp + 1
        i64, b8 = np.int64, np.bool_

        # row -> cell / hierarchy / SM-phase indirection: multi-SM rows
        # of one cell share a post-L1 hierarchy plane (mem_of) and are
        # stepped one SM phase at a time
        self.cell_of = np.repeat(np.arange(self.n_cells, dtype=i64), S)
        self.sm_of = np.tile(np.arange(S, dtype=i64), self.n_cells)
        self.mem_of = self.cell_of if S > 1 else np.arange(B, dtype=i64)
        self.M = self.n_cells if S > 1 else B
        self._phase_rows = [np.flatnonzero(self.sm_of == k)
                            for k in range(S)]

        # per-row config planes: the scalar knobs that may differ cell
        # to cell inside one shape class, expanded cell -> rows (rows of
        # a multi-SM cell share their cell's config). The detector knobs
        # (cutoffs, epochs, aging) live in det_pl and arrive through
        # adopt_row from each cell's own DetectorConfig.
        def _knob(get):
            vals = np.asarray([get(c) for c in self.cell_cfgs], i64)
            return vals[self.cell_of]
        self.lat_l1 = _knob(lambda c: c.lat_l1)
        self.lat_smem = _knob(lambda c: c.lat_smem)
        self.lat_migrate = _knob(lambda c: c.lat_migrate)
        self.lat_l2 = _knob(lambda c: c.lat_l2)
        self.lat_dram = _knob(lambda c: c.lat_dram)
        self.dram_gap = _knob(lambda c: c.dram_gap)
        self.max_cycles = _knob(lambda c: c.max_cycles)
        self.low_epoch = _knob(lambda c: c.detector.low_epoch)
        self.high_epoch = _knob(lambda c: c.detector.high_epoch)

        # per-row objects: the decision logic lives in the shared epoch
        # planes; the objects are row views over them (adopt_* below)
        self.dets: List[InterferenceDetector] = []
        self.policies: List[BasePolicy] = []
        self.n_of = np.zeros(B, i64)
        self.region_blocks = np.zeros(B, i64)
        streams_per_row: List[List[List[int]]] = []
        tot_per_u: List[int] = []
        uniq: Dict[Tuple[int, int], int] = {}   # (id(wl), sm) -> u index
        self.u_of = np.zeros(B, i64)
        row_wls = self._row_workloads()
        rb_of: Dict[int, int] = {}
        for b in range(B):
            wl = row_wls[b]
            cell = self.cells[int(self.cell_of[b])]
            det = InterferenceDetector(
                self.cell_cfgs[int(self.cell_of[b])].detector)
            self.dets.append(det)
            self.policies.append(make_policy(
                cell.policy, n, det, **(cell.policy_kwargs or {})))
            self.n_of[b] = min(n, len(wl.traces))
            # CIAO-P region size exactly as OnChipMemory.__init__ does it
            rb = rb_of.get(wl.smem_used_bytes)
            if rb is None:
                smmt = SMMT(oc.smem_bytes)
                if wl.smem_used_bytes:
                    smmt.allocate("app", wl.smem_used_bytes)
                _, size = smmt.reserve_unused()
                rb = rb_of[wl.smem_used_bytes] = size // (LINE + 4)
            self.region_blocks[b] = rb
            key = (id(self.cells[int(self.cell_of[b])].workload),
                   int(self.sm_of[b]))
            u = uniq.get(key)
            if u is None:
                u = uniq[key] = len(streams_per_row)
                # memoized on the workload object: a sweep that chunks
                # one workload into many engine builds encodes its token
                # streams once, not once per chunk (workloads come out
                # of the runner's cache, so the object is shared)
                # $REPRO_NO_TOKEN_MEMO=1 restores the per-build encode
                # (the pre-plane behavior, kept for bench A/B)
                use_memo = not os.environ.get("REPRO_NO_TOKEN_MEMO")
                mkey = (cfg.dep_every, n)
                memo = (getattr(wl, "_token_enc", None)
                        if use_memo else None)
                if memo is None or memo[0] != mkey:
                    enc = _tokens.encode_workload(
                        wl.traces, cfg.dep_every, n)
                    tot = sum((-t if t < 0 else 1)
                              for w in enc for t in w)
                    memo = (mkey, enc, tot)
                    if use_memo:
                        try:
                            wl._token_enc = memo
                        except (AttributeError, TypeError):
                            pass           # slotted/frozen workloads
                streams_per_row.append(memo[1])
                tot_per_u.append(memo[2])
            self.u_of[b] = u
        # token streams stacked once per distinct (workload, SM) slice
        # (rows of the same slice share planes through u_of)
        self.toks, n_ops_u = _tokens.stack_token_streams(
            streams_per_row, n)
        self.L = self.toks.shape[2]
        self.n_ops = n_ops_u[self.u_of]            # (B, n) per-row copy
        # exact per-row instruction total (ALU tokens retire |tok|, mem
        # tokens 1): bounds the timeline sample count, so the sample
        # arrays can be preallocated once and shared with the C stepper
        tot_u = np.asarray(tot_per_u, i64)
        self.total_instr = tot_u[self.u_of]

        nrb = max(int(self.region_blocks.max()), 1)

        # ---- stacked hot state (one row per SM) ----
        self.ready = np.zeros((B, n), i64)
        self.done = self.n_ops == 0                # includes padded warps
        self.avail = np.zeros((B, n), b8)
        self.iso = np.zeros((B, n), b8)
        self.byp = np.zeros((B, n), b8)
        self.op_idx = np.zeros((B, n), i64)
        self.pend = np.zeros((B, n, P), i64)
        self.P = P
        self.remaining = np.asarray(
            [int(self.n_of[b]) - int(np.count_nonzero(
                self.done[b, :self.n_of[b]])) for b in range(B)], i64)
        self.cycle = np.zeros(B, i64)
        self.instr = np.zeros(B, i64)
        self.li = np.zeros(B, i64)
        self.irs_off = np.zeros(B, i64)
        self.last_wid = np.full(B, -1, i64)
        self.window_mark = np.full(B, self.timeline_every, i64)
        self.last_instr = np.zeros(B, i64)
        self.last_cycle = np.zeros(B, i64)
        self.mask_ver = np.full(B, -1, i64)
        self.tick = np.ones(B, i64)                # OnChipMemory._tick
        self.l1_tags = np.full((B, nf), -1, i64)
        self.l1_owners = np.full((B, nf), -1, i64)
        self.l1_reused = np.zeros((B, nf), b8)
        self.l1_stamp = np.zeros((B, nf), i64)
        self.smem_tags = np.full((B, nrb), -1, i64)
        self.smem_owner = np.full((B, nrb), -1, i64)
        self.nrb = nrb
        self.v_addr = np.full((B, vnf), -1, i64)
        self.v_evic = np.full((B, vnf), -1, i64)
        self.v_head = np.zeros((B, self.v_sets), i64)
        self.v_count = np.zeros((B, self.v_sets), i64)
        self.v_inserts = np.zeros(B, i64)
        # post-L1 planes are per *hierarchy* (per cell for multi-SM),
        # addressed through mem_of; pure stat counters stay per row
        M = self.M
        self.l2_tags = np.full((M, l2nf), -1, i64)
        self.l2_stamp = np.zeros((M, l2nf), i64)
        self.l2_tick = np.ones(M, i64)             # LRUTags._tick
        self.l2_hits = np.zeros(B, i64)
        self.l2_misses = np.zeros(B, i64)
        self.dram_free = np.zeros((M, self.dram_channels), i64)
        self.dram_requests = np.zeros(M, i64)      # chip-wide (feeds util)
        self.cnt_dram_reqs = np.zeros(B, i64)      # per-SM (SimResult stat)
        for name in ("l1_hit", "l1_miss", "smem_hit", "smem_miss",
                     "smem_migrate", "bypass", "evictions",
                     "smem_evictions", "vta_hits"):
            setattr(self, "cnt_" + name, np.zeros(B, i64))
        self.vta_hit_events = np.zeros(B, i64)
        self.pause = np.zeros(B, i64)
        self.live = np.ones(B, b8)
        # rows become runnable only inside their SM phase (_run_sliced);
        # after every phase the set drains back to all-False
        self.runnable = np.zeros(B, b8)
        self.until = self.max_cycles.copy()
        self.nf, self.vnf, self.l2nf = nf, vnf, l2nf

        # ---- epoch planes: detector + policy state, adopted row-wise ----
        self.det_pl = _epoch.DetPlanes.alloc(B, dcfg)
        self.allowed_pl = np.ones((B, n), b8)
        self.isolated_pl = np.zeros((B, n), b8)
        self.bypass_pl = np.zeros((B, n), b8)
        self.score_pl = np.zeros((B, n), i64)
        self.ccws_base = np.zeros(B, i64)
        self.ccws_budget = np.zeros(B, i64)
        self.sp_bypass = np.zeros(B, b8)
        self.sp_thresh = np.zeros(B, np.float64)
        self.sp_base = np.zeros((B, n), b8)
        self.ciao_stall = np.full((B, n), -1, i64)
        self.ciao_iso = np.full((B, n), -1, i64)
        self.stall_len = np.zeros(B, i64)
        self.iso_len = np.zeros(B, i64)
        self.fam = np.zeros(B, np.int8)
        self.mode_p = np.zeros(B, b8)
        self.mode_t = np.zeros(B, b8)
        self.wd_kind = np.zeros(B, np.int64)
        self.swl_next = np.zeros(B, i64)
        for b, pol in enumerate(self.policies):
            self.dets[b].adopt_row(self.det_pl, b)
            pol.adopt_mask_rows(self.allowed_pl[b], self.isolated_pl[b],
                                self.bypass_pl[b])
            ow = type(pol).on_warp_done
            if ow is BasePolicy.on_warp_done:
                self.wd_kind[b] = WD_NOOP
            elif isinstance(pol, StatPCALPolicy) \
                    and ow is BestSWLPolicy.on_warp_done \
                    and type(pol)._rebuild_masks \
                    is StatPCALPolicy._rebuild_masks:
                self.wd_kind[b] = WD_STATP
            elif isinstance(pol, BestSWLPolicy) \
                    and not isinstance(pol, StatPCALPolicy) \
                    and ow is BestSWLPolicy.on_warp_done \
                    and type(pol)._rebuild_masks \
                    is BestSWLPolicy._rebuild_masks:
                self.wd_kind[b] = WD_SWL
            else:
                self.wd_kind[b] = WD_OBJECT
            if isinstance(pol, BestSWLPolicy):
                self.swl_next[b] = pol._next
            if type(pol).epoch_tick is BasePolicy.epoch_tick:
                self.fam[b] = F_PASSIVE
            elif isinstance(pol, CCWSPolicy):
                self.fam[b] = F_CCWS
                pol.adopt_score_row(self.score_pl[b])
                self.ccws_base[b] = pol.base
                self.ccws_budget[b] = pol.budget
            elif isinstance(pol, StatPCALPolicy):
                self.fam[b] = F_STATP
                pol.adopt_statpcal_rows(self.sp_bypass[b:b + 1],
                                        self.sp_thresh[b:b + 1],
                                        self.sp_base[b])
            elif isinstance(pol, CIAOPolicy):
                self.fam[b] = F_CIAO
                pol.adopt_ciao_rows(self.ciao_stall[b],
                                    self.stall_len[b:b + 1],
                                    self.ciao_iso[b],
                                    self.iso_len[b:b + 1])
                self.mode_p[b] = pol.mode in ("p", "c")
                self.mode_t[b] = pol.mode in ("t", "c")
            else:           # custom subclass: per-cell object fallback
                self.fam[b] = F_OBJECT
        # a custom epoch_tick may read policy state the vectorized
        # retirement would leave stale — keep those rows fully on objects
        self.wd_kind[self.fam == F_OBJECT] = WD_OBJECT

        # next-trigger table: passive cells never pause for epochs; CIAO
        # cells with empty stacks skip straight to the high boundary
        # (per row — heterogeneous epoch lengths stride independently)
        self._stride_ok = ((self.high_epoch % self.low_epoch == 0)
                           & (self.high_epoch > self.low_epoch))
        self.next_epoch = np.where(
            self.fam == F_PASSIVE, _HUGE,
            np.where((self.fam == F_CIAO) & self._stride_ok,
                     self.high_epoch, self.low_epoch)).astype(i64)

        # flat zero-copy views + index constants for the numpy stepper
        # (per-call numpy overhead dominates at these batch widths, so
        # every hoisted allocation counts)
        self._ready_f = self.ready.reshape(-1)
        self._avail_f = self.avail.reshape(-1)
        self._done_f = self.done.reshape(-1)
        self._iso_f = self.iso.reshape(-1)
        self._byp_f = self.byp.reshape(-1)
        self._op_idx_f = self.op_idx.reshape(-1)
        self._n_ops_f = self.n_ops.reshape(-1)
        self._toks_f = self.toks.reshape(-1)
        self._pend_f = self.pend.reshape(-1)
        self._l1_tags_f = self.l1_tags.reshape(-1)
        self._l1_owners_f = self.l1_owners.reshape(-1)
        self._l1_reused_f = self.l1_reused.reshape(-1)
        self._l1_stamp_f = self.l1_stamp.reshape(-1)
        self._smem_tags_f = self.smem_tags.reshape(-1)
        self._smem_owner_f = self.smem_owner.reshape(-1)
        self._v_addr_f = self.v_addr.reshape(-1)
        self._v_evic_f = self.v_evic.reshape(-1)
        self._v_head_f = self.v_head.reshape(-1)
        self._v_count_f = self.v_count.reshape(-1)
        self._l2_tags_f = self.l2_tags.reshape(-1)
        self._l2_stamp_f = self.l2_stamp.reshape(-1)
        self._dram_free_f = self.dram_free.reshape(-1)
        ar = np.arange
        self._arB = ar(B, dtype=i64)
        self._ar_ways = ar(self.l1_ways, dtype=i64)
        self._ar_vk = ar(self.v_k, dtype=i64)
        self._ar_l2w = ar(self.l2_ways, dtype=i64)
        self._ar_P = ar(P, dtype=i64)
        self._row_n = self._arB * n
        self._row_nf = self._arB * nf
        self._row_vnf = self._arB * vnf
        self._row_vsets = self._arB * self.v_sets
        self._row_l2nf = self.mem_of * l2nf
        self._row_nrb = self._arB * nrb
        self._row_ch = self.mem_of * self.dram_channels
        self._tok_base = self.u_of * (n * self.L)

        self._alloc_timelines()
        self.results: List[Optional[SimResult]] = [None] * B
        # pair counts: the numpy stepper updates det.pair_counts directly
        # (VTA hits are rare); the C stepper fills a dense (n+1, n) plane
        # merged at finalize — keys are (evictor, raw wid), row 0 is the
        # evictor==-1 guard row (unreachable when the membership scan
        # found a match).
        self.pair_dense = np.zeros((B, (n + 1) * n), np.int64)
        # which warp the C stepper just retired (P_WARPDONE payload)
        self.last_done_wid = np.zeros(B, np.int64)
        for b in range(B):
            self._refresh_masks(b)
            if self.remaining[b] == 0:
                self._finalize(b)

    def _alloc_timelines(self) -> None:
        """Preallocate the stacked timeline-sample arrays. Capacity is
        exact: a sample fires when ``instr >= window_mark`` and advances
        the mark by ``timeline_every``, and ``instr`` never exceeds the
        row's token-stream total, so a row records at most
        ``total_instr // timeline_every + 1`` samples. The C stepper
        records into these arrays through raw pointers, so they must
        never be reallocated once a run has bound them."""
        K = int((self.total_instr // max(self.timeline_every, 1)).max()) \
            + 2
        self.tl_cap = K
        self.tl_cycle = np.zeros((self.B, K), np.int64)
        self.tl_dipc = np.zeros((self.B, K), np.float64)
        self.tl_act = np.zeros((self.B, K), np.int64)
        self.tl_n = np.zeros(self.B, np.int64)

    # --------------------------------------------------- shared handlers
    # Everything below mirrors, per row, what SMSimulator.advance does
    # outside the per-dispatch chain. The steppers guarantee these run at
    # exactly the same points in each row's instruction stream.
    def _refresh_masks(self, b: int) -> None:
        """Re-derive the dispatch masks of row ``b`` from the (aliased)
        policy masks. Padded/done warps drop out through ``done``."""
        pol = self.policies[b]
        self.mask_ver[b] = pol.mask_version
        self.avail[b] = pol.allowed_mask & ~self.done[b]
        self.iso[b] = pol.isolated_mask
        self.byp[b] = pol.bypass_mask

    def _maybe_refresh(self, b: int) -> None:
        if self.policies[b].mask_version != self.mask_ver[b]:
            self._refresh_masks(b)

    def _util(self, b: int) -> float:
        cyc = int(self.cycle[b])
        if cyc <= 0:
            return 0.0
        util = int(self.dram_requests[self.mem_of[b]]) \
            * int(self.dram_gap[b]) / (self.dram_channels * cyc)
        return 1.0 if util > 1.0 else util

    def _util_vec(self, idx: np.ndarray) -> np.ndarray:
        """statPCAL's DRAM utilization, per flagged row (chip-wide
        request count over the row's local cycle — exactly the scalar
        fused path's formula)."""
        cyc = self.cycle[idx]
        reqs = self.dram_requests[self.mem_of[idx]]
        util = np.where(cyc > 0,
                        reqs * self.dram_gap[idx]
                        / np.maximum(self.dram_channels * cyc, 1), 0.0)
        return np.minimum(util, 1.0)

    def _epoch_batch(self, idx: np.ndarray, anchor: np.ndarray) -> None:
        """Service the epoch boundary for every row in ``idx`` with ONE
        vectorized pass per policy family over the stacked planes — the
        replacement for the per-cell ``policy.epoch_tick`` replay.
        ``anchor`` marks rows whose next-trigger entry advances (epoch
        pauses); throttled rows keep their anchor, like the scalar loop.
        """
        if not idx.size:
            return
        pl = self.det_pl
        li = self.li
        pl.inst_total[idx] = li[idx]
        pl.irs_inst[idx] = li[idx] - self.irs_off[idx]
        fam = self.fam[idx]
        sel = fam == F_CCWS
        if sel.any():
            c = idx[sel]
            _epoch.ccws_tick(self.score_pl, self.ccws_base,
                             self.ccws_budget, ~self.done[c],
                             self.allowed_pl, c)
        sel = fam == F_STATP
        if sel.any():
            s = idx[sel]
            _epoch.statpcal_tick(self.sp_bypass, self._util_vec(s),
                                 self.sp_thresh, self.sp_base,
                                 self.allowed_pl, self.bypass_pl, s)
        sel = fam == F_CIAO
        if sel.any():
            g = idx[sel]
            n_act = np.count_nonzero(self.allowed_pl[g] & ~self.done[g],
                                     axis=1)
            low, high = _epoch.poll_epochs(pl, g, n_act)
            lo = g[low]
            if lo.size:
                _epoch.ciao_low_tick(pl, self.ciao_stall, self.stall_len,
                                     self.ciao_iso, self.iso_len,
                                     self.allowed_pl, self.isolated_pl,
                                     self.done, n_act[low], lo)
            hi = g[high]
            if hi.size:
                # alive after the low tick, like the scalar order
                _epoch.ciao_high_tick(
                    pl, self.ciao_stall, self.stall_len,
                    self.ciao_iso, self.iso_len, self.allowed_pl,
                    self.isolated_pl, self.done,
                    self.allowed_pl[hi] & ~self.done[hi],
                    self.mode_p[hi], self.mode_t[hi], hi)
        sel = fam == F_OBJECT
        if sel.any():
            for b in idx[sel]:
                self._epoch_object(int(b))
        self.irs_off[idx] = li[idx] - pl.irs_inst[idx]    # aging moves it
        # masks may have changed: refresh the derived dispatch rows
        self.avail[idx] = self.allowed_pl[idx] & ~self.done[idx]
        self.iso[idx] = self.isolated_pl[idx]
        self.byp[idx] = self.bypass_pl[idx]
        a = idx[anchor]
        if a.size:
            lo = self.low_epoch[a]
            nxt = (li[a] // lo + 1) * lo
            skip = self._stride_ok[a] & (self.fam[a] == F_CIAO) & \
                ((self.stall_len[a] + self.iso_len[a]) == 0)
            if skip.any():
                hi = self.high_epoch[a]
                nxt = np.where(skip, (li[a] // hi + 1) * hi, nxt)
            self.next_epoch[a] = nxt

    def _epoch_object(self, b: int) -> None:
        """Fallback for policy classes the vectorized dispatch does not
        know (custom subclasses): replay through the object, exactly like
        the scalar loop."""
        pol = self.policies[b]
        pol.epoch_tick(None, self.done[b, :int(self.n_of[b])],
                       self._util(b))
        self._maybe_refresh(b)

    def _warp_done_rows(self, rows: np.ndarray, wids: np.ndarray) -> None:
        """Vectorized warp retirement (the former per-cell
        ``policy.on_warp_done`` replay). Does not finalize — the scalar
        loop still runs the epoch and timeline checks on the dispatch
        that retires the last warp, so callers finalize after those.

        Best-SWL's released-set rotation runs as batch scatters: the
        ``allowed_pl`` row *is* the allowed set (``sp_base`` for
        statPCAL, whose mode rebuild is reapplied from the flag planes).
        Unknown subclasses replay through the object."""
        kind = self.wd_kind[rows]
        self.remaining[rows[kind < WD_OBJECT]] -= 1
        for k, mask_pl in ((WD_SWL, self.allowed_pl),
                           (WD_STATP, self.sp_base)):
            km = kind == k
            if not km.any():
                continue
            r, w = rows[km], wids[km]
            in_set = mask_pl[r, w]
            rr, ww = r[in_set], w[in_set]
            if not rr.size:
                continue
            mask_pl[rr, ww] = False
            nx = self.swl_next[rr]
            can = nx < self.n_warps
            mask_pl[rr[can], nx[can]] = True
            self.swl_next[rr[can]] += 1
            if k == WD_STATP:
                byp = self.sp_bypass[rr][:, None]
                bm = self.sp_base[rr]
                self.allowed_pl[rr] = byp | bm
                self.bypass_pl[rr] = np.where(byp, ~bm, False)
            self.avail[rr] = self.allowed_pl[rr] & ~self.done[rr]
            self.byp[rr] = self.bypass_pl[rr]
        obj = kind == WD_OBJECT
        for b, w in zip(rows[obj], wids[obj]):
            b = int(b)
            self.remaining[b] -= 1
            self.policies[b].on_warp_done(int(w))
            self._maybe_refresh(b)

    def _timeline_rows(self, rows: np.ndarray) -> None:
        """Vectorized timeline sampling into the stacked arrays (the
        former per-cell list appends)."""
        act = np.count_nonzero(self.allowed_pl[rows], axis=1)
        k = self.tl_n[rows]
        cyc, ins = self.cycle[rows], self.instr[rows]
        dc = np.maximum(cyc - self.last_cycle[rows], 1)
        self.tl_cycle[rows, k] = cyc
        self.tl_dipc[rows, k] = (ins - self.last_instr[rows]) / dc
        self.tl_act[rows, k] = act
        self.tl_n[rows] = k + 1
        self.last_instr[rows] = ins
        self.last_cycle[rows] = cyc
        self.window_mark[rows] += self.timeline_every

    def _slice_stop(self, rows: np.ndarray) -> None:
        """Rows that reached their slice boundary stop for this phase;
        a boundary at the cycle cap ends the row for good."""
        self.runnable[rows] = False
        for b in rows[self.until[rows] >= self.max_cycles[rows]]:
            self._finalize(int(b))

    def _vta_probe_pop(self, b: int, wid: int, line: int) -> None:
        """Fused ``_vta_probe_hit`` against batch rows + the real
        detector (the caller's scan already confirmed membership)."""
        det = self.dets[b]
        v_addr, v_evic = self.v_addr[b], self.v_evic[b]
        v_k = self.v_k
        s = wid % self.v_sets
        base = s * v_k
        h = int(self.v_head[b, s])
        cc = int(self.v_count[b, s])
        evictor = -1
        for j in range(cc):                 # oldest-first logical order
            f = base + (h + j) % v_k
            if v_addr[f] == line:
                evictor = int(v_evic[f])
                for jj in range(j, cc - 1):
                    f0 = base + (h + jj) % v_k
                    f1 = base + (h + jj + 1) % v_k
                    v_addr[f0] = v_addr[f1]
                    v_evic[f0] = v_evic[f1]
                fl = base + (h + cc - 1) % v_k
                v_addr[fl] = -1
                v_evic[fl] = -1
                self.v_count[b, s] = cc - 1
                det.vta.hits[s] += 1
                break
        self.vta_hit_events[b] += 1
        self.cnt_vta_hits[b] += 1
        det.irs_hits[wid % self.nw] += 1
        key = (evictor, wid)
        det.pair_counts[key] = det.pair_counts.get(key, 0) + 1
        i = wid % self.list_entries
        interfering, sat = det.interfering_wid, det.sat_counter
        if interfering[i] == evictor:
            if sat[i] < self.sat_max:
                sat[i] += 1
        elif interfering[i] == -1:
            interfering[i] = evictor
            sat[i] = 0
        elif sat[i] == 0:
            interfering[i] = evictor
        else:
            sat[i] -= 1
        self.policies[b].on_mem_event(wid, "vta_hit")

    def _finalize(self, b: int) -> None:
        if self.results[b] is not None:
            return
        self.live[b] = False
        self.runnable[b] = False
        det = self.dets[b]
        # same exit flush as the scalar advance (inst counters are not
        # part of SimResult, but the detector object should read true)
        li = int(self.li[b])
        det.inst_total, det.irs_inst = li, li - int(self.irs_off[b])
        det.vta.inserts += int(self.v_inserts[b])
        det.vta_hit_events = int(self.vta_hit_events[b])
        # merge the C stepper's dense pair counts (no-op under numpy)
        dense = self.pair_dense[b]
        for flat in np.flatnonzero(dense):
            e, w = divmod(int(flat), self.n_warps)
            key = (e - 1, w)
            det.pair_counts[key] = det.pair_counts.get(key, 0) \
                + int(dense[flat])
        instr, cycle = int(self.instr[b]), int(self.cycle[b])
        pairs = sorted(([e, w, c] for (e, w), c in det.pair_counts.items()),
                       key=lambda t: (-t[2], t[0], t[1]))
        stats = {
            "l1_hit": int(self.cnt_l1_hit[b]),
            "l1_miss": int(self.cnt_l1_miss[b]),
            "smem_hit": int(self.cnt_smem_hit[b]),
            "smem_miss": int(self.cnt_smem_miss[b]),
            "smem_migrate": int(self.cnt_smem_migrate[b]),
            "bypass": int(self.cnt_bypass[b]),
            "evictions": int(self.cnt_evictions[b]),
            "smem_evictions": int(self.cnt_smem_evictions[b]),
            "vta_hits": int(self.cnt_vta_hits[b]),
            # this SM's own request count (equals the hierarchy's when
            # the hierarchy is private, i.e. single-SM batches)
            "dram_reqs": int(self.cnt_dram_reqs[b]),
        }
        h = stats["l1_hit"] + stats["smem_hit"]
        tot = h + stats["l1_miss"] + stats["smem_miss"] \
            + stats["smem_migrate"]
        k = int(self.tl_n[b])
        timeline = [(int(c), float(d), int(a))
                    for c, d, a in zip(self.tl_cycle[b, :k],
                                       self.tl_dipc[b, :k],
                                       self.tl_act[b, :k])]
        self.results[b] = SimResult(
            policy=self.policies[b].name,
            cycles=cycle,
            instructions=instr,
            ipc=instr / max(cycle, 1),
            l1_hit_rate=h / tot if tot else 0.0,
            vta_hits=int(self.vta_hit_events[b]),
            mean_active_warps=(float(np.mean(self.tl_act[b, :k])) if k
                               else float(self.n_of[b])),
            stats=stats,
            timeline=timeline,
            pairs=pairs,
        )

    # ------------------------------------------------------------- run
    def run(self, timeline_every: int = 20_000,
            deadline: Optional[float] = None):
        """Run every cell to completion (one-shot). Returns a
        ``SimResult`` per cell for single-SM batches, a ``GPUResult``
        per cell for multi-SM batches.

        ``deadline`` is an absolute ``time.monotonic()`` instant; when
        it passes mid-run the engine raises :class:`DeadlineExceeded`.
        The C/numpy steppers check it between bounded-cycle quanta
        (see ``_DEADLINE_SLICE``); the jax backend dispatches one XLA
        program for the whole batch, so a deadline is only observed
        between chunks by the runner, not inside the program."""
        self.deadline = deadline
        if timeline_every != self.timeline_every:
            self.timeline_every = timeline_every
            self.window_mark[:] = timeline_every
            self._alloc_timelines()    # before any stepper binds pointers
        backend = self._backend_req
        if backend == "auto":
            from repro.core import _cstep
            backend = "c" if _cstep.available() else "numpy"
        if backend == "c":
            from repro.core import _cstep
            if not _cstep.available():
                raise RuntimeError(
                    f"C stepper unavailable: {_cstep.unavailable_reason()}")
            self._run_sliced(self._make_c_round(_cstep))
        elif backend == "jax":
            from repro.core import jax_backend
            jax_backend.run_engine(self)
        else:
            self._run_sliced(self._np_round)
        self.backend = backend
        if self.gpu is not None:
            return self._collect_gpu()
        return [r for r in self.results]

    def _run_sliced(self, round_fn) -> None:
        """The chip schedule: advance SM phase k of every cell to the
        slice boundary, then phase k+1, ... — exactly
        ``GPUSimulator.run``'s interleaving. Single-SM batches are the
        degenerate S=1, slice=max_cycles case (one phase to completion).
        """
        cap = int(self.max_cycles.max())
        slice_cycles = self.gpu.slice_cycles if self.gpu is not None \
            else cap
        deadline = self.deadline
        if deadline is not None and self.gpu is None:
            # arm the slice mechanism on single-SM batches so the
            # run-to-completion stepper call becomes bounded quanta the
            # deadline can interleave; bit-identical to the unsliced
            # run (rows only finalize when `until` hits max_cycles)
            slice_cycles = min(slice_cycles, _DEADLINE_SLICE)
        perf = self.perf
        t = 0
        while t < cap and self.live.any():
            if deadline is not None and time.monotonic() >= deadline:
                raise DeadlineExceeded(
                    f"wall-clock deadline passed at batch cycle {t}")
            t += slice_cycles
            until = np.minimum(t, self.max_cycles)
            for rows in self._phase_rows:
                alive = rows[self.live[rows]]
                if not alive.size:
                    continue
                self.until[alive] = until[alive]
                self.runnable[alive] = True
                t0 = time.perf_counter()
                round_fn()
                perf["stepper_s"] += time.perf_counter() - t0
        # chip cycle cap with rows still running: results at current state
        for b in np.flatnonzero(self.live):
            self._finalize(int(b))

    # --------------------------------------------------------- C stepper
    def _make_c_round(self, cstep):
        self._score_ptrs = np.zeros(self.B, np.uint64)
        bumps = np.zeros(self.B, np.int64)
        for b, pol in enumerate(self.policies):
            if isinstance(pol, CCWSPolicy):
                # the score row is a batch-plane row decayed in place, so
                # this pointer stays valid for the whole run
                self._score_ptrs[b] = pol.score.ctypes.data
                bumps[b] = pol.bump
        det_ptrs = np.zeros((self.B, 4), np.uint64)
        for b, det in enumerate(self.dets):
            det_ptrs[b, 0] = det.irs_hits.ctypes.data
            det_ptrs[b, 1] = det.vta.hits.ctypes.data
            det_ptrs[b, 2] = det.interfering_wid.ctypes.data
            det_ptrs[b, 3] = det.sat_counter.ctypes.data
        params = cstep.bind(self, det_ptrs, self._score_ptrs, bumps)
        perf = self.perf

        def round_fn():
            live, runnable = self.live, self.runnable
            while bool((live & runnable).any()):
                if self.deadline is not None \
                        and time.monotonic() >= self.deadline:
                    raise DeadlineExceeded(
                        "wall-clock deadline passed mid-round")
                t0 = time.perf_counter()
                cstep.step(params)
                t1 = time.perf_counter()
                self._drain_pauses()
                t2 = time.perf_counter()
                perf["drain_s"] += t2 - t1
                perf["stepper_s"] -= t2 - t1   # counted by _run_sliced
                perf["rounds"] += 1
        return round_fn

    def _drain_pauses(self) -> None:
        """Service every paused row with one vectorized pass per pause
        kind (the former per-cell Python replay). Per-row order matches
        the scalar loop: warp-done, epoch, timeline, then finalize."""
        idx = np.flatnonzero(self.pause)
        if not idx.size:
            return
        flags = self.pause[idx]
        self.pause[idx] = 0
        slc = idx[(flags & P_SLICE) != 0]
        if slc.size:
            self._slice_stop(slc)
        thr = idx[(flags & P_THROTTLE) != 0]
        if thr.size:
            # everything throttled: advance to let epochs fire. Note the
            # scalar loop does NOT re-anchor next_epoch here.
            self.cycle[thr] += self.low_epoch[thr]
            self.li[thr] += self.low_epoch[thr]
        wd = idx[(flags & P_WARPDONE) != 0]
        if wd.size:
            # the stepper already flipped done/avail/last_wid
            self._warp_done_rows(wd, self.last_done_wid[wd])
        ep = idx[(flags & P_EPOCH) != 0]
        if ep.size or thr.size:
            allb = np.concatenate([ep, thr])
            anchor = np.zeros(len(allb), bool)
            anchor[:len(ep)] = True
            self._epoch_batch(allb, anchor)
        tl = idx[(flags & P_TIMELINE) != 0]
        if tl.size:
            self._timeline_rows(tl)
        for b in wd[self.remaining[wd] == 0]:
            self._finalize(int(b))
        # rows the C stepper retired entirely in-stepper
        for b in idx[(flags & P_FINALIZE) != 0]:
            self._finalize(int(b))

    # ------------------------------------------------- numpy lockstep
    # drain cadence: service accumulated pauses every this many
    # iterations. Servicing an epoch costs ~0.4ms of fixed numpy call
    # overhead regardless of how many rows it covers, so batching the
    # crossings of a whole stretch (vs the old service-inline-per-
    # iteration scheme) amortises that overhead over every row that
    # crossed. The cadence caps the other side of the trade: a paused
    # row sits out at most this many iterations, and since one masked
    # iteration costs full batch width no matter how many rows are
    # active, letting pauses pile up until the batch fully stalls
    # (C-style whole-round drains) *inflates* total iterations — rows
    # without epochs (GTO/Best-SWL never pause) would run to completion
    # while everyone else waits (measured 1.7x stepper blow-up).
    _NP_DRAIN_EVERY = 8

    def _np_round(self) -> None:
        """Run-to-pause stretches with a bounded cadence: iterate rows
        that have no pending pause flag, every ``_NP_DRAIN_EVERY``
        iterations service *all* paused rows in one batched
        ``_drain_pauses`` pass. Rows are independent simulations —
        delaying a paused row in wall-time while the rest of the batch
        advances cannot change that row's own event sequence — so
        results are bit-identical to the inline scheme.
        """
        perf = self.perf
        every = self._NP_DRAIN_EVERY
        live, runnable, pause = self.live, self.runnable, self.pause
        while bool((live & runnable).any()):
            faults.fire("stepper.step")
            if self.deadline is not None \
                    and time.monotonic() >= self.deadline:
                raise DeadlineExceeded(
                    "wall-clock deadline passed mid-round")
            k = 0
            while k < every and \
                    bool((live & runnable & (pause == 0)).any()):
                self._np_iteration()
                k += 1
            if pause.any():
                t0 = time.perf_counter()
                self._drain_pauses()
                dt = time.perf_counter() - t0
                perf["drain_s"] += dt
                perf["stepper_s"] -= dt    # counted by _run_sliced
                perf["rounds"] += 1

    def _np_iteration(self) -> None:
        """One lockstep iteration: one scheduler dispatch per runnable
        row, all rows advanced by masked vectorized updates. Mirrors one
        trip through the scalar ``while`` loop of ``SMSimulator.advance``.
        Rows that cross an epoch (or hit a later check with a pause
        already pending) raise a pause flag and sit out until the
        round's drain services them.
        """
        act = self.live & self.runnable & (self.pause == 0)
        cycle = self.cycle
        # rows at their slice boundary stop (scalar loop condition)
        hit = act & (cycle >= self.until)
        if hit.any():
            self._slice_stop(np.flatnonzero(hit))
            act &= ~hit
            if not act.any():
                return
        rowoff = self._row_n
        ready_f, avail_f = self._ready_f, self._avail_f

        # ---- warp selection (greedy-then-oldest + fused event skip) ----
        lw = self.last_wid
        lw_ok = lw >= 0
        lwc = np.where(lw_ok, lw, 0)
        g_idx = rowoff + lwc
        greedy = act & lw_ok & avail_f[g_idx] & (ready_f[g_idx] <= cycle)
        wid = np.where(greedy, lw, -1)
        need = act & ~greedy
        if need.any():
            cand = (self.ready <= cycle[:, None]) & self.avail
            w = cand.argmax(1)
            found = need & cand.reshape(-1)[rowoff + w]
            wid = np.where(found, w, wid)
            self.last_wid = lw = np.where(found, w, lw)
            skip = need & ~found
            if skip.any():
                sched = np.where(self.avail, self.ready, _HUGE)
                w2 = sched.argmin(1)
                thr = skip & ~avail_f[rowoff + w2]
                if thr.any():
                    # everything throttled: advance to let epochs fire
                    # (the scalar loop does NOT re-anchor next_epoch)
                    # serviced inline, not deferred to the round drain:
                    # a throttled row may need many consecutive
                    # low_epoch advances and pausing each one would
                    # stall the row for a whole round per advance
                    ti = np.flatnonzero(thr)
                    cycle[ti] += self.low_epoch[ti]
                    self.li[ti] += self.low_epoch[ti]
                    t0 = time.perf_counter()
                    self._epoch_batch(ti, np.zeros(len(ti), bool))
                    dt = time.perf_counter() - t0
                    self.perf["drain_s"] += dt
                    self.perf["stepper_s"] -= dt
                sk = skip & ~thr
                if sk.any():
                    best = ready_f[rowoff + w2]
                    clamp = sk & (best >= self.until)
                    if clamp.any():
                        ci = np.flatnonzero(clamp)
                        cycle[ci] = self.until[ci]
                        self._slice_stop(ci)
                        sk &= ~clamp
                    np.copyto(cycle, best, where=sk)
                    lw_ok2 = lw >= 0
                    lwc2 = np.where(lw_ok2, lw, 0)
                    t_idx = rowoff + lwc2
                    tie = sk & lw_ok2 & avail_f[t_idx] & \
                        (ready_f[t_idx] <= best)
                    wid = np.where(tie, lw, wid)
                    w2sel = sk & ~tie
                    wid = np.where(w2sel, w2, wid)
                    self.last_wid = np.where(w2sel, w2, self.last_wid)

        disp = act & (wid >= 0)
        if not disp.any():
            return
        widc = np.where(disp, wid, 0)
        rw = rowoff + widc

        # ---- token fetch ----
        oi = self._op_idx_f[rw]
        tok = self._toks_f[self._tok_base + widc * self.L + oi]
        alu = disp & (tok < 0)
        mem = disp & ~alu

        adv = np.where(alu, -tok, 0) + mem        # instructions retired
        new_ready = ready_f[rw]

        if mem.any():
            new_ready = self._np_mem_chain(mem, tok, widc, rw, cycle,
                                           new_ready)
        # ALU: batched run up to the next memory instruction
        new_ready = np.where(alu, cycle + adv, new_ready)

        adv = np.where(disp, adv, 0)
        self.li += adv
        cycle += adv                               # mem rows: +1
        ready_f[rw] = new_ready
        oi_new = oi + disp
        self._op_idx_f[rw] = oi_new
        self.instr += adv

        fin = disp & (oi_new >= self._n_ops_f[rw])
        if fin.any():
            done_f = self._done_f
            done_f[rw] = done_f[rw] | fin
            avail_f[rw] = avail_f[rw] & ~fin
            np.copyto(self.last_wid, -1, where=fin)
            fi = np.flatnonzero(fin)
            self._warp_done_rows(fi, widc[fi])
        # epoch crossings pause for the round drain: one batched
        # _epoch_batch call then services every row that crossed this
        # round (the call's fixed overhead dominates at 1-2 rows)
        ep = disp & (self.li >= self.next_epoch)
        if ep.any():
            self.pause[np.flatnonzero(ep)] |= P_EPOCH
        # later checks on a dispatch that already pended a pause must
        # defer too, preserving the scalar per-dispatch order (epoch →
        # timeline → finalize); ep is the only pause set above, so it
        # is exactly the pending mask here
        tl = disp & (self.instr >= self.window_mark)
        if tl.any():
            tl_now = tl & ~ep
            if tl_now.any():
                self._timeline_rows(np.flatnonzero(tl_now))
            tl_defer = tl & ep
            if tl_defer.any():
                self.pause[np.flatnonzero(tl_defer)] |= P_TIMELINE
        if fin.any():
            for b in fi[self.remaining[fi] == 0]:
                if self.pause[b]:
                    self.pause[b] |= P_FINALIZE
                else:
                    self._finalize(int(b))

    def _np_mem_chain(self, mem, tok, widc, rw, cycle, new_ready):
        """The fused per-access chain, vectorized over the batch axis.
        Returns the updated new_ready; all state scatters happen here.
        Post-L1 scatters go through masked row subsets: rows sharing a
        hierarchy plane (multi-SM cells) never collide because only one
        SM phase is runnable at a time, and within the subset the target
        slots are distinct."""
        line = tok >> _SHIFT
        bypm = mem & self._byp_f[rw]
        isom = mem & self._iso_f[rw] & ~bypm
        norm = mem & ~bypm & ~isom
        self.cnt_bypass += bypm
        post = bypm.copy()
        lat = np.zeros(self.B, np.int64)

        # ---- L1 way scan: shared by the normal path (hit/miss) and the
        # CIAO-P migration probe (residency == the scalar dict) ----
        l1_sets = self.l1_sets
        s1 = line % l1_sets
        if self.xor_hash:
            s1 = (s1 ^ ((line // l1_sets) % l1_sets)) % l1_sets
        base1 = self._row_nf + s1 * self.l1_ways
        way_idx = base1[:, None] + self._ar_ways
        tags_f = self._l1_tags_f
        eq = tags_f[way_idx] == line[:, None]
        resident = eq.any(1)
        f_hit = base1 + eq.argmax(1)

        hit = norm & resident
        miss = norm & ~resident
        self.cnt_l1_hit += hit
        self.cnt_l1_miss += miss
        reused_f, stamp_f = self._l1_reused_f, self._l1_stamp_f
        owners_f = self._l1_owners_f
        if hit.any():
            reused_f[f_hit] = reused_f[f_hit] | hit
            stamp_f[f_hit] = np.where(hit, self.tick, stamp_f[f_hit])
            lat = np.where(hit, self.lat_l1, lat)

        # ---- CIAO-P smem region: evictions first (they insert into the
        # VTA before the probe, unlike the L1 fill which inserts after) --
        smiss = None
        if isom.any():
            rb = self.region_blocks
            no_region = isom & (rb <= 0)
            post |= no_region
            iso2 = isom & ~no_region
            sidx = line % np.maximum(rb, 1)
            sflat = self._row_nrb + sidx
            st_f, so_f = self._smem_tags_f, self._smem_owner_f
            sold = st_f[sflat]
            shit = iso2 & (sold == line)
            self.cnt_smem_hit += shit
            lat = np.where(shit, self.lat_smem, lat)
            smiss = iso2 & ~shit
            if smiss.any():
                sevict = smiss & (sold >= 0)
                self.cnt_smem_evictions += sevict
                sown = so_f[sflat]
                ins = sevict & (sown != widc)
                if ins.any():
                    self._np_vta_insert(ins, sown, sold, widc)
            else:
                smiss = None

        # ---- VTA probe (after smem inserts, before L1-fill inserts) ----
        pm = miss if smiss is None else miss | smiss
        if pm.any():
            sv = widc % self.v_sets
            vslots = (self._row_vnf + sv * self.v_k)[:, None] + self._ar_vk
            vhit = pm & (self._v_addr_f[vslots] == line[:, None]).any(1)
            if vhit.any():
                for b in np.flatnonzero(vhit):
                    self._vta_probe_pop(b, int(widc[b]), int(line[b]))

        # ---- L1 fill (miss path) ----
        if miss.any():
            vic = base1 + stamp_f[way_idx].argmin(1)
            old = tags_f[vic]
            oldown = owners_f[vic]
            oldreu = reused_f[vic]
            evict = miss & (old >= 0)
            self.cnt_evictions += evict
            ins = evict & (oldown != widc)
            if self.reuse_filter:
                ins &= oldreu
            if ins.any():
                self._np_vta_insert(ins, oldown, old, widc)
            tags_f[vic] = np.where(miss, line, old)
            owners_f[vic] = np.where(miss, widc, oldown)
            reused_f[vic] = np.where(miss, False, oldreu)
            stamp_f[vic] = np.where(miss, self.tick, stamp_f[vic])
            post |= miss

        # ---- smem migration / fill (after the probe, like the scalar) --
        if smiss is not None:
            mig = smiss & resident
            if mig.any():
                # single-copy coherence: pull the line out of L1D
                tags_f[f_hit] = np.where(mig, -1, tags_f[f_hit])
                owners_f[f_hit] = np.where(mig, -1, owners_f[f_hit])
                self.cnt_smem_migrate += mig
                lat = np.where(mig, self.lat_migrate, lat)
            smiss2 = smiss & ~mig
            self.cnt_smem_miss += smiss2
            post |= smiss2
            st_f[sflat] = np.where(smiss, line, sold)
            so_f[sflat] = np.where(smiss, widc, so_f[sflat])

        self.tick += norm

        # ---- post-L1 stage: L2 tags + DRAM bandwidth queueing ----
        if post.any():
            b2 = self._row_l2nf + (line % self.l2_sets) * self.l2_ways
            wi2 = b2[:, None] + self._ar_l2w
            t2_f, st2_f = self._l2_tags_f, self._l2_stamp_f
            eq2 = t2_f[wi2] == line[:, None]
            l2res = eq2.any(1)
            h2 = post & l2res
            m2 = post & ~l2res
            self.l2_hits += h2
            lat = np.where(h2, self.lat_l2, lat)
            f2 = b2 + eq2.argmax(1)
            if m2.any():
                vic2 = b2 + st2_f[wi2].argmin(1)
                t2_f[vic2[m2]] = line[m2]
                self.l2_misses += m2
                chf = self._row_ch + (line >> 2) % self.dram_channels
                chm = chf[m2]
                df_f = self._dram_free_f
                free = df_f[chm]
                start = np.maximum(cycle[m2], free)
                df_f[chm] = start + self.dram_gap[m2]
                self.dram_requests[self.mem_of[m2]] += 1
                self.cnt_dram_reqs += m2
                lat[m2] = self.lat_dram[m2] + start - cycle[m2]
                f2 = np.where(m2, vic2, f2)
            fp = f2[post]
            st2_f[fp] = self.l2_tick[self.mem_of[post]]
            self.l2_tick[self.mem_of[post]] += 1

        # ---- dependent use vs hit-under-miss pending queue ----
        done_t = cycle + lat
        dep = mem & ((tok & 1) == 1)
        nondep = mem & ~dep
        new_ready = np.where(dep, done_t, new_ready)
        if nondep.any():
            pbase = rw * self.P
            prow = pbase[:, None] + self._ar_P
            pend_f = self._pend_f
            rows = pend_f[prow]
            slot = rows.argmin(1)           # a stale (<= cycle) slot
            pslot = pbase + slot
            nv = np.where(nondep, done_t, pend_f[pslot])
            pend_f[pslot] = nv
            rows[self._arB, slot] = nv
            valid = rows > cycle[:, None]
            outstanding = valid.sum(1)
            earliest = np.where(valid, rows, _HUGE).min(1)
            new_ready = np.where(
                nondep,
                np.where(outstanding >= self.max_mlp, earliest, cycle + 1),
                new_ready)
        return new_ready

    def _np_vta_insert(self, mask, owner, victim_line, evictor) -> None:
        """Vectorized circular-FIFO insert (the caller has excluded
        self-eviction). One insert per row per iteration, so the fancy
        scatters never collide."""
        v_k = self.v_k
        s = owner % self.v_sets
        srow = self._row_vsets + s
        head_f, count_f = self._v_head_f, self._v_count_f
        h = head_f[srow]
        cc = count_f[srow]
        full = cc == v_k
        slot = self._row_vnf + s * v_k + np.where(full, h, (h + cc) % v_k)
        va_f, ve_f = self._v_addr_f, self._v_evic_f
        va_f[slot] = np.where(mask, victim_line, va_f[slot])
        ve_f[slot] = np.where(mask, evictor, ve_f[slot])
        head_f[srow] = np.where(mask & full, (h + 1) % v_k, h)
        count_f[srow] = np.where(mask & ~full, cc + 1, cc)
        self.v_inserts += mask

    # ------------------------------------------------- cell aggregation
    def _collect_gpu(self) -> List[GPUResult]:
        """Aggregate per-SM rows into per-cell GPUResults, exactly like
        ``GPUSimulator.run``."""
        out: List[GPUResult] = []
        S = self.S
        for c in range(self.n_cells):
            rows = list(range(c * S, (c + 1) * S))
            per = [self.results[r] for r in rows]
            cycles = max((r.cycles for r in per), default=1)
            instr = sum(r.instructions for r in per)
            # chip-level rates average only SMs that received work
            busy = [r for r in per if r.instructions] or per
            out.append(GPUResult(
                policy=per[0].policy if per else
                self.policies[rows[0]].name,
                num_sms=S,
                cycles=cycles,
                instructions=instr,
                ipc=instr / max(cycles, 1),
                l1_hit_rate=float(np.mean([r.l1_hit_rate for r in busy]))
                if busy else 0.0,
                vta_hits=sum(r.vta_hits for r in per),
                mean_active_warps=float(np.mean(
                    [r.mean_active_warps for r in busy])) if busy else 0.0,
                mem_stats={
                    "l2_hits": int(self.l2_hits[rows].sum()),
                    "l2_misses": int(self.l2_misses[rows].sum()),
                    "dram_reqs": int(self.dram_requests[
                        self.mem_of[rows[0]]]),
                },
                per_sm=per,
            ))
        return out


def run_batched(cells: Sequence[BatchCell],
                cfg: Optional[SimConfig] = None,
                backend: str = "auto",
                timeline_every: int = 20_000,
                gpu: Optional[GPUConfig] = None):
    """Convenience wrapper: build the engine, run to completion."""
    return BatchedSMEngine(cells, cfg, backend, gpu=gpu).run(timeline_every)
