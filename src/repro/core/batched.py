"""Batched lockstep SM engine: B independent grid cells as one program.

The scalar core (:mod:`repro.core.simulator`) hit the measured ceiling of
a per-cell CPython dispatch loop; every figure sweep, though, runs dozens
of *independent* (workload, policy, seed, variant) cells over the same
deterministic integer state machine. This module stacks the per-cell
state ``SMSimulator`` keeps as scalars/lists — warp cursors, token
streams (padded/stacked via :func:`repro.workloads.tokens.
stack_token_streams`), L1/smem tag planes, VTA FIFOs, policy masks,
detector counters, L2 tags and DRAM queues — along a leading batch axis,
and advances B homogeneous cells (same :class:`SimConfig`) together.

Two interchangeable steppers drive the *same* stacked arrays:

* ``numpy`` — the lockstep stepper: one scheduler dispatch per live cell
  per iteration, the full per-access chain (greedy/oldest pick, L1D way
  scan, VTA insert, L2 tags, DRAM queueing, MLP pending queues) as
  masked vectorized updates, so one ``np.take``/fancy-scatter chain
  replaces B Python dispatch iterations. Runs everywhere.
* ``c`` — the same per-dispatch state machine transliterated to C
  (thread-free, int64 only), compiled on demand with the system C
  compiler via :mod:`repro.core._cstep` and driven through ``ctypes``
  over the identical array layout. This retires the ROADMAP
  "C-extension experiment for the dispatch loop" item; when no compiler
  is available the engine silently uses the numpy stepper.

``backend="auto"`` picks ``c`` when available. Both steppers are
**bit-exact per cell** against ``SMSimulator``: every floating-point
quantity (IRS snapshots, timeline IPC windows, DRAM utilization) and
every policy/detector *decision* is computed in Python against the real
per-cell :class:`~repro.core.policies.BasePolicy` /
:class:`~repro.core.interference.InterferenceDetector` objects — the
steppers pause a cell whenever it reaches an epoch boundary, a warp
completion, a timeline sample, or a fully-throttled stretch, and shared
Python handlers replay exactly what the scalar loop does at those
points. Only the deterministic integer per-dispatch chain is
vectorized/compiled. ``tests/test_batched.py`` pins both steppers
against the golden cells and property-tests batch-of-1 equality.

Not every cell batches: multi-SM chips need interleaved stepping, and
two scalar-core configuration corners (queued L2 banks, MSHR occupancy
gating) are modeled through object methods the steppers do not
replicate. :func:`supports_config` is the gate; the runner
(:mod:`repro.core.runner`) falls back to per-cell execution for those.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.interference import InterferenceDetector
from repro.core.onchip import LINE, SMMT
from repro.core.policies import BasePolicy, CCWSPolicy, make_policy
from repro.core.simulator import SimConfig, SimResult, _HUGE
from repro.workloads import tokens as _tokens

_SHIFT = _tokens.TOKEN_LINE_SHIFT

# pause-reason bits shared with the C stepper (src/repro/core/_cstep.c)
P_EPOCH = 1
P_TIMELINE = 2
P_WARPDONE = 4
P_THROTTLE = 8
P_CAP = 16


def supports_config(cfg: SimConfig) -> bool:
    """Can the batched engine reproduce this config bit-exactly?

    The scalar core's fused fast path requires an unqueued L2
    (``l2_bank_gap == 0``) and no MSHR occupancy gating; those corners go
    through object methods (``MemoryHierarchy.access`` / ``MSHR.admit``)
    that the steppers do not replicate.
    """
    return cfg.l2_bank_gap == 0 and not cfg.onchip.mshr_gate


@dataclasses.dataclass
class BatchCell:
    """One grid cell: a workload under one policy. The config is shared
    by the whole batch (homogeneous-group contract)."""
    workload: Any
    policy: str
    policy_kwargs: Optional[dict] = None


class BatchedSMEngine:
    """Run B single-SM cells to completion in lockstep.

    Usage::

        results = BatchedSMEngine(cells, cfg).run()   # List[SimResult]
    """

    timeline_every: int = 20_000

    def __init__(self, cells: Sequence[BatchCell],
                 cfg: Optional[SimConfig] = None,
                 backend: str = "auto"):
        self.cfg = cfg = cfg if cfg is not None else SimConfig()
        if not supports_config(cfg):
            raise ValueError(
                "config not supported by the batched engine "
                "(l2_bank_gap != 0 or mshr_gate); use SMSimulator")
        if backend not in ("auto", "numpy", "c"):
            raise ValueError(f"unknown backend {backend!r}")
        self._backend_req = backend
        self.cells = list(cells)
        self.B = len(self.cells)
        if not self.B:
            raise ValueError("empty batch")
        self._build_state()

    # ------------------------------------------------------------ set-up
    def _build_state(self) -> None:
        cfg = self.cfg
        B = self.B
        oc = cfg.onchip
        dcfg = cfg.detector
        self.n_warps = n = cfg.num_warps
        self.low_epoch = dcfg.low_epoch
        self.max_mlp = cfg.max_mlp
        self.max_cycles = cfg.max_cycles
        self.l1_sets, self.l1_ways = oc.num_sets, oc.ways
        self.xor_hash, self.reuse_filter = oc.xor_hash, oc.reuse_filter
        self.v_sets, self.v_k = dcfg.vta_sets, dcfg.vta_tags_per_set
        self.nw, self.list_entries = dcfg.num_warps, dcfg.list_entries
        self.sat_max = dcfg.sat_max
        # same clamps as L2TagArray / DRAMModel (a tiny L2 still has one
        # set; zero channels still means one)
        self.l2_sets = max(cfg.l2_bytes // (LINE * cfg.l2_ways), 1)
        self.l2_ways = cfg.l2_ways
        self.dram_gap = cfg.dram_gap
        self.dram_channels = max(cfg.dram_channels, 1)
        nf = self.l1_sets * self.l1_ways
        vnf = self.v_sets * self.v_k
        l2nf = self.l2_sets * self.l2_ways
        P = self.max_mlp + 1

        # per-cell objects: the decision logic (policies, detector floats)
        # is NOT re-implemented — the steppers call into these
        self.dets: List[InterferenceDetector] = []
        self.policies: List[BasePolicy] = []
        self.n_of = np.zeros(B, np.int64)
        self.region_blocks = np.zeros(B, np.int64)
        streams_per_cell: List[List[List[int]]] = []
        uniq: Dict[int, int] = {}          # id(workload) -> u index
        self.u_of = np.zeros(B, np.int64)
        for b, cell in enumerate(self.cells):
            wl = cell.workload
            det = InterferenceDetector(dcfg)
            self.dets.append(det)
            self.policies.append(make_policy(
                cell.policy, n, det, **(cell.policy_kwargs or {})))
            self.n_of[b] = min(n, len(wl.traces))
            # CIAO-P region size exactly as OnChipMemory.__init__ does it
            smmt = SMMT(oc.smem_bytes)
            if wl.smem_used_bytes:
                smmt.allocate("app", wl.smem_used_bytes)
            _, size = smmt.reserve_unused()
            self.region_blocks[b] = size // (LINE + 4)
            u = uniq.get(id(wl))
            if u is None:
                u = uniq[id(wl)] = len(streams_per_cell)
                streams_per_cell.append(_tokens.encode_workload(
                    wl.traces, cfg.dep_every, n))
            self.u_of[b] = u
        # token streams stacked once per distinct workload (cells of the
        # same workload share rows through u_of)
        self.toks, n_ops_u = _tokens.stack_token_streams(
            streams_per_cell, n)
        self.L = self.toks.shape[2]
        self.n_ops = n_ops_u[self.u_of]            # (B, n) per-cell copy
        nrb = max(int(self.region_blocks.max()), 1)

        # ---- stacked hot state (one row per cell) ----
        i64, b8 = np.int64, np.bool_
        self.ready = np.zeros((B, n), i64)
        self.done = self.n_ops == 0                # includes padded warps
        self.avail = np.zeros((B, n), b8)
        self.iso = np.zeros((B, n), b8)
        self.byp = np.zeros((B, n), b8)
        self.op_idx = np.zeros((B, n), i64)
        self.pend = np.zeros((B, n, P), i64)
        self.P = P
        self.remaining = np.asarray(
            [int(self.n_of[b]) - int(np.count_nonzero(
                self.done[b, :self.n_of[b]])) for b in range(B)], i64)
        self.cycle = np.zeros(B, i64)
        self.instr = np.zeros(B, i64)
        self.li = np.zeros(B, i64)
        self.irs_off = np.zeros(B, i64)
        self.last_wid = np.full(B, -1, i64)
        # cells whose policy keeps the base no-op epoch_tick (GTO,
        # Best-SWL) have NO observable epoch behavior — the scalar loop's
        # epoch block only syncs detector counters nothing reads and
        # calls a pass. Park their epoch trigger at infinity so the
        # steppers never pause them for it (finalize still syncs the
        # detector mirrors).
        passive = np.asarray(
            [type(p).epoch_tick is BasePolicy.epoch_tick
             for p in self.policies], bool)
        self.next_epoch = np.where(passive, _HUGE,
                                   self.low_epoch).astype(i64)
        self.window_mark = np.full(B, self.timeline_every, i64)
        self.last_instr = np.zeros(B, i64)
        self.last_cycle = np.zeros(B, i64)
        self.mask_ver = np.full(B, -1, i64)
        self.tick = np.ones(B, i64)                # OnChipMemory._tick
        self.l1_tags = np.full((B, nf), -1, i64)
        self.l1_owners = np.full((B, nf), -1, i64)
        self.l1_reused = np.zeros((B, nf), b8)
        self.l1_stamp = np.zeros((B, nf), i64)
        self.smem_tags = np.full((B, nrb), -1, i64)
        self.smem_owner = np.full((B, nrb), -1, i64)
        self.nrb = nrb
        self.v_addr = np.full((B, vnf), -1, i64)
        self.v_evic = np.full((B, vnf), -1, i64)
        self.v_head = np.zeros((B, self.v_sets), i64)
        self.v_count = np.zeros((B, self.v_sets), i64)
        self.v_inserts = np.zeros(B, i64)
        self.l2_tags = np.full((B, l2nf), -1, i64)
        self.l2_stamp = np.zeros((B, l2nf), i64)
        self.l2_tick = np.ones(B, i64)             # LRUTags._tick
        self.l2_hits = np.zeros(B, i64)
        self.l2_misses = np.zeros(B, i64)
        self.dram_free = np.zeros((B, self.dram_channels), i64)
        self.dram_requests = np.zeros(B, i64)
        for name in ("l1_hit", "l1_miss", "smem_hit", "smem_miss",
                     "smem_migrate", "bypass", "evictions",
                     "smem_evictions", "vta_hits"):
            setattr(self, "cnt_" + name, np.zeros(B, i64))
        self.vta_hit_events = np.zeros(B, i64)
        self.pause = np.zeros(B, i64)
        self.live = np.ones(B, b8)
        self.nf, self.vnf, self.l2nf = nf, vnf, l2nf

        # flat zero-copy views + index constants for the numpy stepper
        # (per-call numpy overhead dominates at these batch widths, so
        # every hoisted allocation counts)
        self._ready_f = self.ready.reshape(-1)
        self._avail_f = self.avail.reshape(-1)
        self._done_f = self.done.reshape(-1)
        self._iso_f = self.iso.reshape(-1)
        self._byp_f = self.byp.reshape(-1)
        self._op_idx_f = self.op_idx.reshape(-1)
        self._n_ops_f = self.n_ops.reshape(-1)
        self._toks_f = self.toks.reshape(-1)
        self._pend_f = self.pend.reshape(-1)
        self._l1_tags_f = self.l1_tags.reshape(-1)
        self._l1_owners_f = self.l1_owners.reshape(-1)
        self._l1_reused_f = self.l1_reused.reshape(-1)
        self._l1_stamp_f = self.l1_stamp.reshape(-1)
        self._smem_tags_f = self.smem_tags.reshape(-1)
        self._smem_owner_f = self.smem_owner.reshape(-1)
        self._v_addr_f = self.v_addr.reshape(-1)
        self._v_evic_f = self.v_evic.reshape(-1)
        self._v_head_f = self.v_head.reshape(-1)
        self._v_count_f = self.v_count.reshape(-1)
        self._l2_tags_f = self.l2_tags.reshape(-1)
        self._l2_stamp_f = self.l2_stamp.reshape(-1)
        self._dram_free_f = self.dram_free.reshape(-1)
        ar = np.arange
        self._arB = ar(B, dtype=np.int64)
        self._ar_ways = ar(self.l1_ways, dtype=np.int64)
        self._ar_vk = ar(self.v_k, dtype=np.int64)
        self._ar_l2w = ar(self.l2_ways, dtype=np.int64)
        self._ar_P = ar(P, dtype=np.int64)
        self._row_n = self._arB * n
        self._row_nf = self._arB * nf
        self._row_vnf = self._arB * vnf
        self._row_vsets = self._arB * self.v_sets
        self._row_l2nf = self._arB * l2nf
        self._row_nrb = self._arB * nrb
        self._row_ch = self._arB * self.dram_channels
        self._tok_base = self.u_of * (n * self.L)

        self.timelines: List[List[Tuple[int, float, int]]] = \
            [[] for _ in range(B)]
        self.active_samples: List[List[int]] = [[] for _ in range(B)]
        self.results: List[Optional[SimResult]] = [None] * B
        # pair counts: the numpy stepper updates det.pair_counts directly
        # (VTA hits are rare); the C stepper fills a dense (n+1, n) plane
        # merged at finalize — keys are (evictor, raw wid), row 0 is the
        # evictor==-1 guard row (unreachable when the membership scan
        # found a match).
        self.pair_dense = np.zeros((B, (n + 1) * n), np.int64)
        # which warp the C stepper just retired (P_WARPDONE payload)
        self.last_done_wid = np.zeros(B, np.int64)
        for b in range(B):
            self._refresh_masks(b)
            if self.remaining[b] == 0:
                self._finalize(b)

    # --------------------------------------------------- shared handlers
    # Everything below mirrors, line for line, what SMSimulator.advance
    # does outside the per-dispatch chain. The steppers guarantee these
    # run at exactly the same points in each cell's instruction stream.
    def _refresh_masks(self, b: int) -> None:
        pol = self.policies[b]
        self.mask_ver[b] = pol.mask_version
        nb = int(self.n_of[b])
        self.avail[b, :nb] = pol.allowed_mask[:nb] & ~self.done[b, :nb]
        if nb < self.n_warps:
            self.avail[b, nb:] = False
        self.iso[b, :nb] = pol.isolated_mask[:nb]
        self.byp[b, :nb] = pol.bypass_mask[:nb]

    def _maybe_refresh(self, b: int) -> None:
        if self.policies[b].mask_version != self.mask_ver[b]:
            self._refresh_masks(b)

    def _util(self, b: int) -> float:
        cyc = int(self.cycle[b])
        if cyc <= 0:
            return 0.0
        util = int(self.dram_requests[b]) * self.dram_gap / \
            (self.dram_channels * cyc)
        return 1.0 if util > 1.0 else util

    def _epoch_call(self, b: int) -> None:
        det = self.dets[b]
        li = int(self.li[b])
        det.inst_total, det.irs_inst = li, li - int(self.irs_off[b])
        pol = self.policies[b]
        pol.epoch_tick(None, self.done[b, :int(self.n_of[b])],
                       self._util(b))
        self.irs_off[b] = li - det.irs_inst       # aging moves this
        self._maybe_refresh(b)
        if isinstance(pol, CCWSPolicy):
            # CCWS epoch decay reassigns the score buffer; re-point the
            # C stepper at the new one
            self._score_ptr_refresh(b)

    def _handle_epoch(self, b: int) -> None:
        li = int(self.li[b])
        self.next_epoch[b] = (li // self.low_epoch + 1) * self.low_epoch
        self._epoch_call(b)

    def _handle_throttle(self, b: int) -> None:
        # everything throttled: advance to let epochs fire. Note the
        # scalar loop does NOT re-anchor next_epoch here.
        self.cycle[b] += self.low_epoch
        self.li[b] += self.low_epoch
        self._epoch_call(b)

    def _handle_warp_done(self, b: int, wid: int) -> None:
        # NOTE: does not finalize — the scalar loop still runs the epoch
        # and timeline checks on the dispatch that retires the last warp,
        # so the caller finalizes after those handlers.
        self.remaining[b] -= 1
        self.policies[b].on_warp_done(wid)
        self._maybe_refresh(b)

    def _handle_timeline(self, b: int) -> None:
        act = self.policies[b].num_allowed()
        self.active_samples[b].append(act)
        dc = int(self.cycle[b]) - int(self.last_cycle[b])
        if dc < 1:
            dc = 1
        self.timelines[b].append(
            (int(self.cycle[b]),
             (int(self.instr[b]) - int(self.last_instr[b])) / dc, act))
        self.last_instr[b] = self.instr[b]
        self.last_cycle[b] = self.cycle[b]
        self.window_mark[b] += self.timeline_every

    def _vta_probe_pop(self, b: int, wid: int, line: int) -> None:
        """Fused ``_vta_probe_hit`` against batch rows + the real
        detector (the caller's scan already confirmed membership)."""
        det = self.dets[b]
        v_addr, v_evic = self.v_addr[b], self.v_evic[b]
        v_k = self.v_k
        s = wid % self.v_sets
        base = s * v_k
        h = int(self.v_head[b, s])
        cc = int(self.v_count[b, s])
        evictor = -1
        for j in range(cc):                 # oldest-first logical order
            f = base + (h + j) % v_k
            if v_addr[f] == line:
                evictor = int(v_evic[f])
                for jj in range(j, cc - 1):
                    f0 = base + (h + jj) % v_k
                    f1 = base + (h + jj + 1) % v_k
                    v_addr[f0] = v_addr[f1]
                    v_evic[f0] = v_evic[f1]
                fl = base + (h + cc - 1) % v_k
                v_addr[fl] = -1
                v_evic[fl] = -1
                self.v_count[b, s] = cc - 1
                det.vta.hits[s] += 1
                break
        self.vta_hit_events[b] += 1
        self.cnt_vta_hits[b] += 1
        det.irs_hits[wid % self.nw] += 1
        key = (evictor, wid)
        det.pair_counts[key] = det.pair_counts.get(key, 0) + 1
        i = wid % self.list_entries
        interfering, sat = det.interfering_wid, det.sat_counter
        if interfering[i] == evictor:
            if sat[i] < self.sat_max:
                sat[i] += 1
        elif interfering[i] == -1:
            interfering[i] = evictor
            sat[i] = 0
        elif sat[i] == 0:
            interfering[i] = evictor
        else:
            sat[i] -= 1
        self.policies[b].on_mem_event(wid, "vta_hit")

    def _finalize(self, b: int) -> None:
        if self.results[b] is not None:
            return
        self.live[b] = False
        det = self.dets[b]
        # same exit flush as the scalar advance (inst counters are not
        # part of SimResult, but the detector object should read true)
        li = int(self.li[b])
        det.inst_total, det.irs_inst = li, li - int(self.irs_off[b])
        det.vta.inserts += int(self.v_inserts[b])
        det.vta_hit_events = int(self.vta_hit_events[b])
        # merge the C stepper's dense pair counts (no-op under numpy)
        dense = self.pair_dense[b]
        for flat in np.flatnonzero(dense):
            e, w = divmod(int(flat), self.n_warps)
            key = (e - 1, w)
            det.pair_counts[key] = det.pair_counts.get(key, 0) \
                + int(dense[flat])
        instr, cycle = int(self.instr[b]), int(self.cycle[b])
        pairs = sorted(([e, w, c] for (e, w), c in det.pair_counts.items()),
                       key=lambda t: (-t[2], t[0], t[1]))
        stats = {
            "l1_hit": int(self.cnt_l1_hit[b]),
            "l1_miss": int(self.cnt_l1_miss[b]),
            "smem_hit": int(self.cnt_smem_hit[b]),
            "smem_miss": int(self.cnt_smem_miss[b]),
            "smem_migrate": int(self.cnt_smem_migrate[b]),
            "bypass": int(self.cnt_bypass[b]),
            "evictions": int(self.cnt_evictions[b]),
            "smem_evictions": int(self.cnt_smem_evictions[b]),
            "vta_hits": int(self.cnt_vta_hits[b]),
            # private hierarchy: the SM's request count IS the DRAM's
            "dram_reqs": int(self.dram_requests[b]),
        }
        h = stats["l1_hit"] + stats["smem_hit"]
        tot = h + stats["l1_miss"] + stats["smem_miss"] \
            + stats["smem_migrate"]
        samples = self.active_samples[b]
        self.results[b] = SimResult(
            policy=self.policies[b].name,
            cycles=cycle,
            instructions=instr,
            ipc=instr / max(cycle, 1),
            l1_hit_rate=h / tot if tot else 0.0,
            vta_hits=int(self.vta_hit_events[b]),
            mean_active_warps=(float(np.mean(samples)) if samples
                               else float(self.n_of[b])),
            stats=stats,
            timeline=list(self.timelines[b]),
            pairs=pairs,
        )

    # ------------------------------------------------------------- run
    def run(self, timeline_every: int = 20_000) -> List[SimResult]:
        """Run every cell to completion (one-shot: like
        ``SMSimulator.run`` but for the whole batch)."""
        if timeline_every != self.timeline_every:
            self.timeline_every = timeline_every
            self.window_mark[:] = timeline_every
        backend = self._backend_req
        if backend == "auto":
            from repro.core import _cstep
            backend = "c" if _cstep.available() else "numpy"
        if backend == "c":
            from repro.core import _cstep
            if not _cstep.available():
                raise RuntimeError(
                    f"C stepper unavailable: {_cstep.unavailable_reason()}")
            self._run_c(_cstep)
        else:
            self._run_numpy()
        self.backend = backend
        return [r for r in self.results]

    # ------------------------------------------------- numpy lockstep
    def _run_numpy(self) -> None:
        while bool(self.live.any()):
            self._np_iteration()

    def _np_iteration(self) -> None:
        """One lockstep iteration: one scheduler dispatch per live cell,
        all cells advanced by masked vectorized updates. Mirrors one trip
        through the scalar ``while`` loop of ``SMSimulator.advance``."""
        live = self.live
        cycle = self.cycle
        # cells at the cycle cap stop (scalar loop condition)
        if cycle.max() >= self.max_cycles:
            cap = live & (cycle >= self.max_cycles)
            if cap.any():
                for b in np.flatnonzero(cap):
                    self._finalize(b)
                if not live.any():
                    return
        rowoff = self._row_n
        ready_f, avail_f = self._ready_f, self._avail_f

        # ---- warp selection (greedy-then-oldest + fused event skip) ----
        lw = self.last_wid
        lw_ok = lw >= 0
        lwc = np.where(lw_ok, lw, 0)
        g_idx = rowoff + lwc
        greedy = live & lw_ok & avail_f[g_idx] & (ready_f[g_idx] <= cycle)
        wid = np.where(greedy, lw, -1)
        need = live & ~greedy
        if need.any():
            cand = (self.ready <= cycle[:, None]) & self.avail
            w = cand.argmax(1)
            found = need & cand.reshape(-1)[rowoff + w]
            wid = np.where(found, w, wid)
            self.last_wid = lw = np.where(found, w, lw)
            skip = need & ~found
            if skip.any():
                sched = np.where(self.avail, self.ready, _HUGE)
                w2 = sched.argmin(1)
                thr = skip & ~avail_f[rowoff + w2]
                if thr.any():
                    for b in np.flatnonzero(thr):
                        self._handle_throttle(b)
                sk = skip & ~thr
                if sk.any():
                    best = ready_f[rowoff + w2]
                    clamp = sk & (best >= self.max_cycles)
                    if clamp.any():
                        cycle[clamp] = self.max_cycles
                        for b in np.flatnonzero(clamp):
                            self._finalize(b)
                        sk &= ~clamp
                    np.copyto(cycle, best, where=sk)
                    lw_ok2 = lw >= 0
                    lwc2 = np.where(lw_ok2, lw, 0)
                    t_idx = rowoff + lwc2
                    tie = sk & lw_ok2 & avail_f[t_idx] & \
                        (ready_f[t_idx] <= best)
                    wid = np.where(tie, lw, wid)
                    w2sel = sk & ~tie
                    wid = np.where(w2sel, w2, wid)
                    self.last_wid = np.where(w2sel, w2, self.last_wid)

        disp = self.live & (wid >= 0)
        if not disp.any():
            return
        widc = np.where(disp, wid, 0)
        rw = rowoff + widc

        # ---- token fetch ----
        oi = self._op_idx_f[rw]
        tok = self._toks_f[self._tok_base + widc * self.L + oi]
        alu = disp & (tok < 0)
        mem = disp & ~alu

        adv = np.where(alu, -tok, 0) + mem        # instructions retired
        new_ready = ready_f[rw]

        if mem.any():
            new_ready = self._np_mem_chain(mem, tok, widc, rw, cycle,
                                           new_ready)
        # ALU: batched run up to the next memory instruction
        new_ready = np.where(alu, cycle + adv, new_ready)

        adv = np.where(disp, adv, 0)
        self.li += adv
        cycle += adv                               # mem rows: +1
        ready_f[rw] = new_ready
        oi_new = oi + disp
        self._op_idx_f[rw] = oi_new
        self.instr += adv

        fin = disp & (oi_new >= self._n_ops_f[rw])
        if fin.any():
            done_f = self._done_f
            done_f[rw] = done_f[rw] | fin
            avail_f[rw] = avail_f[rw] & ~fin
            np.copyto(self.last_wid, -1, where=fin)
            for b in np.flatnonzero(fin):
                self._handle_warp_done(b, int(widc[b]))
        ep = disp & (self.li >= self.next_epoch)
        if ep.any():
            for b in np.flatnonzero(ep):
                self._handle_epoch(b)
        tl = disp & (self.instr >= self.window_mark)
        if tl.any():
            for b in np.flatnonzero(tl):
                self._handle_timeline(b)
        if fin.any():
            for b in np.flatnonzero(fin):
                if self.remaining[b] == 0:
                    self._finalize(b)

    def _np_mem_chain(self, mem, tok, widc, rw, cycle, new_ready):
        """The fused per-access chain, vectorized over the batch axis.
        Returns the updated new_ready; all state scatters happen here."""
        cfg = self.cfg
        line = tok >> _SHIFT
        bypm = mem & self._byp_f[rw]
        isom = mem & self._iso_f[rw] & ~bypm
        norm = mem & ~bypm & ~isom
        self.cnt_bypass += bypm
        post = bypm.copy()
        lat = np.zeros(self.B, np.int64)

        # ---- L1 way scan: shared by the normal path (hit/miss) and the
        # CIAO-P migration probe (residency == the scalar dict) ----
        l1_sets = self.l1_sets
        s1 = line % l1_sets
        if self.xor_hash:
            s1 = (s1 ^ ((line // l1_sets) % l1_sets)) % l1_sets
        base1 = self._row_nf + s1 * self.l1_ways
        way_idx = base1[:, None] + self._ar_ways
        tags_f = self._l1_tags_f
        eq = tags_f[way_idx] == line[:, None]
        resident = eq.any(1)
        f_hit = base1 + eq.argmax(1)

        hit = norm & resident
        miss = norm & ~resident
        self.cnt_l1_hit += hit
        self.cnt_l1_miss += miss
        reused_f, stamp_f = self._l1_reused_f, self._l1_stamp_f
        owners_f = self._l1_owners_f
        if hit.any():
            reused_f[f_hit] = reused_f[f_hit] | hit
            stamp_f[f_hit] = np.where(hit, self.tick, stamp_f[f_hit])
            lat = np.where(hit, cfg.lat_l1, lat)

        # ---- CIAO-P smem region: evictions first (they insert into the
        # VTA before the probe, unlike the L1 fill which inserts after) --
        smiss = None
        if isom.any():
            rb = self.region_blocks
            no_region = isom & (rb <= 0)
            post |= no_region
            iso2 = isom & ~no_region
            sidx = line % np.maximum(rb, 1)
            sflat = self._row_nrb + sidx
            st_f, so_f = self._smem_tags_f, self._smem_owner_f
            sold = st_f[sflat]
            shit = iso2 & (sold == line)
            self.cnt_smem_hit += shit
            lat = np.where(shit, cfg.lat_smem, lat)
            smiss = iso2 & ~shit
            if smiss.any():
                sevict = smiss & (sold >= 0)
                self.cnt_smem_evictions += sevict
                sown = so_f[sflat]
                ins = sevict & (sown != widc)
                if ins.any():
                    self._np_vta_insert(ins, sown, sold, widc)
            else:
                smiss = None

        # ---- VTA probe (after smem inserts, before L1-fill inserts) ----
        pm = miss if smiss is None else miss | smiss
        if pm.any():
            sv = widc % self.v_sets
            vslots = (self._row_vnf + sv * self.v_k)[:, None] + self._ar_vk
            vhit = pm & (self._v_addr_f[vslots] == line[:, None]).any(1)
            if vhit.any():
                for b in np.flatnonzero(vhit):
                    self._vta_probe_pop(b, int(widc[b]), int(line[b]))

        # ---- L1 fill (miss path) ----
        if miss.any():
            vic = base1 + stamp_f[way_idx].argmin(1)
            old = tags_f[vic]
            oldown = owners_f[vic]
            oldreu = reused_f[vic]
            evict = miss & (old >= 0)
            self.cnt_evictions += evict
            ins = evict & (oldown != widc)
            if self.reuse_filter:
                ins &= oldreu
            if ins.any():
                self._np_vta_insert(ins, oldown, old, widc)
            tags_f[vic] = np.where(miss, line, old)
            owners_f[vic] = np.where(miss, widc, oldown)
            reused_f[vic] = np.where(miss, False, oldreu)
            stamp_f[vic] = np.where(miss, self.tick, stamp_f[vic])
            post |= miss

        # ---- smem migration / fill (after the probe, like the scalar) --
        if smiss is not None:
            mig = smiss & resident
            if mig.any():
                # single-copy coherence: pull the line out of L1D
                tags_f[f_hit] = np.where(mig, -1, tags_f[f_hit])
                owners_f[f_hit] = np.where(mig, -1, owners_f[f_hit])
                self.cnt_smem_migrate += mig
                lat = np.where(mig, cfg.lat_migrate, lat)
            smiss2 = smiss & ~mig
            self.cnt_smem_miss += smiss2
            post |= smiss2
            st_f[sflat] = np.where(smiss, line, sold)
            so_f[sflat] = np.where(smiss, widc, so_f[sflat])

        self.tick += norm

        # ---- post-L1 stage: L2 tags + DRAM bandwidth queueing ----
        if post.any():
            b2 = self._row_l2nf + (line % self.l2_sets) * self.l2_ways
            wi2 = b2[:, None] + self._ar_l2w
            t2_f, st2_f = self._l2_tags_f, self._l2_stamp_f
            eq2 = t2_f[wi2] == line[:, None]
            l2res = eq2.any(1)
            h2 = post & l2res
            m2 = post & ~l2res
            self.l2_hits += h2
            lat = np.where(h2, cfg.lat_l2, lat)
            f2 = b2 + eq2.argmax(1)
            if m2.any():
                vic2 = b2 + st2_f[wi2].argmin(1)
                t2_f[vic2] = np.where(m2, line, t2_f[vic2])
                self.l2_misses += m2
                chf = self._row_ch + (line >> 2) % self.dram_channels
                df_f = self._dram_free_f
                free = df_f[chf]
                start = np.maximum(cycle, free)
                df_f[chf] = np.where(m2, start + self.dram_gap, free)
                self.dram_requests += m2
                lat = np.where(m2, cfg.lat_dram + start - cycle, lat)
                f2 = np.where(m2, vic2, f2)
            st2_f[f2] = np.where(post, self.l2_tick, st2_f[f2])
            self.l2_tick += post

        # ---- dependent use vs hit-under-miss pending queue ----
        done_t = cycle + lat
        dep = mem & ((tok & 1) == 1)
        nondep = mem & ~dep
        new_ready = np.where(dep, done_t, new_ready)
        if nondep.any():
            pbase = rw * self.P
            prow = pbase[:, None] + self._ar_P
            pend_f = self._pend_f
            rows = pend_f[prow]
            slot = rows.argmin(1)           # a stale (<= cycle) slot
            pslot = pbase + slot
            nv = np.where(nondep, done_t, pend_f[pslot])
            pend_f[pslot] = nv
            rows[self._arB, slot] = nv
            valid = rows > cycle[:, None]
            outstanding = valid.sum(1)
            earliest = np.where(valid, rows, _HUGE).min(1)
            new_ready = np.where(
                nondep,
                np.where(outstanding >= self.max_mlp, earliest, cycle + 1),
                new_ready)
        return new_ready

    def _np_vta_insert(self, mask, owner, victim_line, evictor) -> None:
        """Vectorized circular-FIFO insert (the caller has excluded
        self-eviction). One insert per cell per iteration, so the fancy
        scatters never collide."""
        v_k = self.v_k
        s = owner % self.v_sets
        srow = self._row_vsets + s
        head_f, count_f = self._v_head_f, self._v_count_f
        h = head_f[srow]
        cc = count_f[srow]
        full = cc == v_k
        slot = self._row_vnf + s * v_k + np.where(full, h, (h + cc) % v_k)
        va_f, ve_f = self._v_addr_f, self._v_evic_f
        va_f[slot] = np.where(mask, victim_line, va_f[slot])
        ve_f[slot] = np.where(mask, evictor, ve_f[slot])
        head_f[srow] = np.where(mask & full, (h + 1) % v_k, h)
        count_f[srow] = np.where(mask & ~full, cc + 1, cc)
        self.v_inserts += mask

    # --------------------------------------------------------- C stepper
    def _score_ptr_refresh(self, b: int) -> None:
        ptrs = getattr(self, "_score_ptrs", None)
        if ptrs is not None:
            ptrs[b] = self.policies[b].score.ctypes.data

    def _run_c(self, cstep) -> None:
        self._score_ptrs = np.zeros(self.B, np.uint64)
        bumps = np.zeros(self.B, np.int64)
        for b, pol in enumerate(self.policies):
            if isinstance(pol, CCWSPolicy):
                self._score_ptrs[b] = pol.score.ctypes.data
                bumps[b] = pol.bump
        det_ptrs = np.zeros((self.B, 4), np.uint64)
        for b, det in enumerate(self.dets):
            det_ptrs[b, 0] = det.irs_hits.ctypes.data
            det_ptrs[b, 1] = det.vta.hits.ctypes.data
            det_ptrs[b, 2] = det.interfering_wid.ctypes.data
            det_ptrs[b, 3] = det.sat_counter.ctypes.data
        params = cstep.bind(self, det_ptrs, self._score_ptrs, bumps)
        while bool(self.live.any()):
            cstep.step(params)
            self._drain_pauses()

    def _drain_pauses(self) -> None:
        for b in np.flatnonzero(self.pause):
            flags = int(self.pause[b])
            self.pause[b] = 0
            if flags & P_THROTTLE:
                self._handle_throttle(b)
                continue
            if flags & P_CAP:
                self._finalize(b)
                continue
            if flags & P_WARPDONE:
                # the stepper already flipped done/avail/last_wid
                self._handle_warp_done(b, int(self.last_done_wid[b]))
            if flags & P_EPOCH:
                self._handle_epoch(b)
            if flags & P_TIMELINE:
                self._handle_timeline(b)
            if flags & P_WARPDONE and self.remaining[b] == 0:
                self._finalize(b)


def run_batched(cells: Sequence[BatchCell],
                cfg: Optional[SimConfig] = None,
                backend: str = "auto",
                timeline_every: int = 20_000) -> List[SimResult]:
    """Convenience wrapper: build the engine, run to completion."""
    return BatchedSMEngine(cells, cfg, backend).run(timeline_every)
