/* C stepper for the batched lockstep SM engine (repro.core.batched).
 *
 * A direct transliteration of the scalar hot path in
 * repro/core/simulator.py::SMSimulator.advance, operating on the SAME
 * stacked batch arrays the numpy stepper uses (one row per cell). Each
 * call advances every live, unpaused cell until it reaches a pause
 * point — epoch boundary, warp completion, timeline sample, fully-
 * throttled stretch, or the cycle cap — where control returns to Python
 * so the real policy/detector objects replay the decision logic. Only
 * deterministic int64 arithmetic lives here; every float stays in
 * Python (bit-exactness contract, see tests/test_batched.py).
 *
 * Compiled on demand by repro/core/_cstep.py with the system C compiler
 * (no Python.h — driven through ctypes). Field order of Params must
 * match the ctypes.Structure in _cstep.py exactly.
 */
#include <stdint.h>

typedef int64_t i64;
typedef signed char i8;
typedef uint64_t u64;

enum {
    P_EPOCH = 1,
    P_TIMELINE = 2,
    P_WARPDONE = 4,
    P_THROTTLE = 8,
    P_CAP = 16,   /* legacy: slice stops at the cycle cap use P_SLICE */
    P_SLICE = 32  /* reached until[b] (slice boundary or cycle cap)   */
};

#define HUGE_T ((i64)1 << 62)

typedef struct {
    /* dimensions */
    i64 B, n, L, P;
    i64 nf, l1_sets, l1_ways;
    i64 vnf, v_sets, v_k;
    i64 l2nf, l2_sets, l2_ways;
    i64 nrb, dram_channels;
    i64 nw, list_entries, sat_max;
    /* config scalars */
    i64 xor_hash, reuse_filter;
    i64 lat_l1, lat_smem, lat_migrate, lat_l2, lat_dram, dram_gap;
    i64 max_mlp, low_epoch, max_cycles, line_shift;
    /* per-warp planes (B x n [x ...]) */
    i64 *ready, *toks, *op_idx, *n_ops, *pend;
    i8 *done, *avail, *iso, *byp, *live, *runnable;
    i64 *u_of, *n_of, *region_blocks, *mem_of, *until;
    /* per-row scalars */
    i64 *cycle, *instr, *li, *next_epoch, *window_mark;
    i64 *last_wid, *tick, *l2_tick;
    /* cache planes */
    i64 *l1_tags, *l1_owners, *l1_stamp;
    i8 *l1_reused;
    i64 *smem_tags, *smem_owner;
    i64 *v_addr, *v_evic, *v_head, *v_count, *v_inserts;
    i64 *l2_tags, *l2_stamp, *l2_hits, *l2_misses;
    i64 *dram_free, *dram_requests;
    /* event counters */
    i64 *cnt_l1_hit, *cnt_l1_miss, *cnt_smem_hit, *cnt_smem_miss;
    i64 *cnt_smem_migrate, *cnt_bypass, *cnt_evictions;
    i64 *cnt_smem_evictions, *cnt_vta_hits, *vta_hit_events;
    i64 *cnt_dram_reqs;   /* per-row; dram_requests is per hierarchy */
    /* control */
    i64 *pause, *last_done_wid;
    /* detector hooks: det_ptrs[b*4 + {irs_hits, vta_hits, interf, sat}];
       score_ptrs[b] is CCWS's score buffer (0 = policy has no
       on_mem_event hook) */
    u64 *det_ptrs, *score_ptrs;
    i64 *score_bump;
    i64 *pair_dense; /* B x (n+1) x n, row 0 = evictor==-1 guard */
} Params;

static i64 l1_set(const Params *p, i64 line)
{
    i64 s = line % p->l1_sets;
    if (p->xor_hash)
        s = (s ^ ((line / p->l1_sets) % p->l1_sets)) % p->l1_sets;
    return s;
}

/* circular-FIFO insert; the caller has excluded self-eviction */
static void vta_insert(const Params *p, i64 b, i64 owner, i64 line,
                       i64 evictor)
{
    i64 k = p->v_k;
    i64 s = owner % p->v_sets;
    i64 *addr = p->v_addr + b * p->vnf + s * k;
    i64 *evic = p->v_evic + b * p->vnf + s * k;
    i64 *head = p->v_head + b * p->v_sets + s;
    i64 *cnt = p->v_count + b * p->v_sets + s;
    if (*cnt == k) { /* full: FIFO-drop the oldest */
        addr[*head] = line;
        evic[*head] = evictor;
        *head = (*head + 1) % k;
    } else {
        i64 f = (*head + *cnt) % k;
        addr[f] = line;
        evic[f] = evictor;
        *cnt += 1;
    }
    p->v_inserts[b] += 1;
}

/* membership scan + FIFO pop of the oldest match + interference-list
 * bookkeeping (the fused interference.on_miss). Returns 1 on a VTA hit.
 * Physical slots outside the logical FIFO window are always -1, so the
 * membership scan over all k slots equals the scalar core's dict. */
static int vta_probe(const Params *p, i64 b, i64 wid, i64 line)
{
    i64 k = p->v_k;
    i64 s = wid % p->v_sets;
    i64 *addr = p->v_addr + b * p->vnf + s * k;
    int member = 0;
    for (i64 j = 0; j < k; j++)
        if (addr[j] == line) { member = 1; break; }
    if (!member)
        return 0;
    i64 *evic = p->v_evic + b * p->vnf + s * k;
    i64 h = p->v_head[b * p->v_sets + s];
    i64 cc = p->v_count[b * p->v_sets + s];
    i64 evictor = -1;
    for (i64 j = 0; j < cc; j++) { /* oldest-first logical order */
        i64 f = (h + j) % k;
        if (addr[f] == line) {
            evictor = evic[f];
            for (i64 jj = j; jj < cc - 1; jj++) {
                i64 f0 = (h + jj) % k;
                i64 f1 = (h + jj + 1) % k;
                addr[f0] = addr[f1];
                evic[f0] = evic[f1];
            }
            i64 fl = (h + cc - 1) % k;
            addr[fl] = -1;
            evic[fl] = -1;
            p->v_count[b * p->v_sets + s] = cc - 1;
            ((i64 *)(uintptr_t)p->det_ptrs[b * 4 + 1])[s] += 1;
            break;
        }
    }
    p->vta_hit_events[b] += 1;
    p->cnt_vta_hits[b] += 1;
    ((i64 *)(uintptr_t)p->det_ptrs[b * 4 + 0])[wid % p->nw] += 1;
    p->pair_dense[b * (p->n + 1) * p->n + (evictor + 1) * p->n + wid] += 1;
    i64 i = wid % p->list_entries;
    i64 *interf = (i64 *)(uintptr_t)p->det_ptrs[b * 4 + 2];
    i64 *sat = (i64 *)(uintptr_t)p->det_ptrs[b * 4 + 3];
    if (interf[i] == evictor) {
        if (sat[i] < p->sat_max)
            sat[i] += 1;
    } else if (interf[i] == -1) {
        interf[i] = evictor;
        sat[i] = 0;
    } else if (sat[i] == 0) {
        interf[i] = evictor;
    } else {
        sat[i] -= 1;
    }
    return 1;
}

static void run_cell(const Params *p, i64 b)
{
    const i64 n = p->n, L = p->L, P = p->P;
    i64 *ready = p->ready + b * n;
    i64 *op_idx = p->op_idx + b * n;
    i64 *n_ops = p->n_ops + b * n;
    i64 *pend = p->pend + b * n * P;
    i8 *done = p->done + b * n;
    i8 *avail = p->avail + b * n;
    i8 *iso = p->iso + b * n;
    i8 *byp = p->byp + b * n;
    const i64 *toks = p->toks + p->u_of[b] * n * L;
    i64 *l1_tags = p->l1_tags + b * p->nf;
    i64 *l1_owners = p->l1_owners + b * p->nf;
    i64 *l1_stamp = p->l1_stamp + b * p->nf;
    i8 *l1_reused = p->l1_reused + b * p->nf;
    i64 *smem_tags = p->smem_tags + b * p->nrb;
    i64 *smem_owner = p->smem_owner + b * p->nrb;
    /* post-L1 planes are per hierarchy: rows of a multi-SM cell share
     * them (only one SM phase is runnable at a time, so the cached
     * l2_tick never races another row) */
    const i64 m = p->mem_of[b];
    i64 *l2_tags = p->l2_tags + m * p->l2nf;
    i64 *l2_stamp = p->l2_stamp + m * p->l2nf;
    i64 *dram_free = p->dram_free + m * p->dram_channels;
    i64 *score = p->score_ptrs[b]
        ? (i64 *)(uintptr_t)p->score_ptrs[b] : (i64 *)0;
    i64 cycle = p->cycle[b], li = p->li[b], instr = p->instr[b];
    i64 last_wid = p->last_wid[b];
    i64 tick = p->tick[b], l2_tick = p->l2_tick[m];
    i64 rb = p->region_blocks[b];
    const i64 until = p->until[b];
    i64 flags = 0;

    for (;;) {
        if (cycle >= until) { /* slice boundary / cycle cap */
            flags = P_SLICE;
            break;
        }
        /* pick a warp: greedy (keep last), else oldest ready & allowed */
        i64 wid = last_wid;
        if (wid < 0 || !avail[wid] || ready[wid] > cycle) {
            i64 w = -1;
            for (i64 i = 0; i < n; i++)
                if (avail[i] && ready[i] <= cycle) { w = i; break; }
            if (w >= 0) {
                wid = last_wid = w;
            } else {
                /* fused event skip: jump to the earliest wake-up */
                i64 best = HUGE_T, w2 = -1;
                for (i64 i = 0; i < n; i++)
                    if (avail[i] && ready[i] < best) {
                        best = ready[i];
                        w2 = i;
                    }
                if (w2 < 0) { /* everything throttled */
                    flags = P_THROTTLE;
                    break;
                }
                if (best >= until) {
                    /* clamp to the slice boundary, like the scalar
                     * advance(); the next phase resumes from here */
                    cycle = until;
                    flags = P_SLICE;
                    break;
                }
                cycle = best;
                if (last_wid >= 0 && avail[last_wid] &&
                        ready[last_wid] <= best)
                    wid = last_wid; /* greedy still wins the tie */
                else
                    wid = last_wid = w2;
            }
        }
        i64 tok = toks[wid * L + op_idx[wid]];
        i64 adv;
        if (tok >= 0) { /* memory instruction */
            li += 1;
            i64 line = tok >> p->line_shift;
            int vta_hit = 0;
            i64 lat = -1; /* -1 == "to the post-L1 stage" */
            if (byp[wid]) { /* statPCAL bypass */
                p->cnt_bypass[b] += 1;
            } else if (iso[wid]) { /* CIAO-P smem redirection */
                if (rb > 0) {
                    i64 idx = line % rb;
                    i64 old = smem_tags[idx];
                    if (old == line) {
                        p->cnt_smem_hit[b] += 1;
                        lat = p->lat_smem;
                    } else {
                        if (old >= 0) {
                            p->cnt_smem_evictions[b] += 1;
                            i64 owner = smem_owner[idx];
                            if (owner != wid)
                                vta_insert(p, b, owner, old, wid);
                        }
                        if (vta_probe(p, b, wid, line))
                            vta_hit = 1;
                        /* migration: single-copy coherence */
                        i64 base1 = l1_set(p, line) * p->l1_ways;
                        i64 f = -1;
                        for (i64 g = base1; g < base1 + p->l1_ways; g++)
                            if (l1_tags[g] == line) { f = g; break; }
                        if (f >= 0) {
                            l1_tags[f] = -1;
                            l1_owners[f] = -1;
                            p->cnt_smem_migrate[b] += 1;
                            lat = p->lat_migrate;
                        } else {
                            p->cnt_smem_miss[b] += 1;
                        }
                        smem_tags[idx] = line;
                        smem_owner[idx] = wid;
                    }
                }
            } else { /* L1D path */
                i64 base1 = l1_set(p, line) * p->l1_ways;
                i64 f = -1;
                for (i64 g = base1; g < base1 + p->l1_ways; g++)
                    if (l1_tags[g] == line) { f = g; break; }
                if (f >= 0) { /* L1D hit */
                    p->cnt_l1_hit[b] += 1;
                    l1_reused[f] = 1;
                    l1_stamp[f] = tick++;
                    lat = p->lat_l1;
                } else { /* miss: probe VTA, fill with stamp-LRU victim */
                    p->cnt_l1_miss[b] += 1;
                    if (vta_probe(p, b, wid, line))
                        vta_hit = 1;
                    i64 vic = base1;
                    i64 bs = l1_stamp[base1];
                    for (i64 g = base1 + 1; g < base1 + p->l1_ways; g++)
                        if (l1_stamp[g] < bs) {
                            bs = l1_stamp[g];
                            vic = g;
                        }
                    i64 old = l1_tags[vic];
                    if (old >= 0) {
                        p->cnt_evictions[b] += 1;
                        i64 owner = l1_owners[vic];
                        if ((l1_reused[vic] || !p->reuse_filter) &&
                                owner != wid)
                            vta_insert(p, b, owner, old, wid);
                    }
                    l1_tags[vic] = line;
                    l1_owners[vic] = wid;
                    l1_reused[vic] = 0;
                    l1_stamp[vic] = tick++;
                }
            }
            if (lat < 0) { /* post-L1: L2 tags + DRAM queueing */
                i64 base2 = (line % p->l2_sets) * p->l2_ways;
                i64 f2 = -1;
                for (i64 g = base2; g < base2 + p->l2_ways; g++)
                    if (l2_tags[g] == line) { f2 = g; break; }
                if (f2 >= 0) { /* L2 hit */
                    p->l2_hits[b] += 1;
                    lat = p->lat_l2;
                } else { /* L2 miss -> DRAM channel queue */
                    f2 = base2;
                    i64 bs = l2_stamp[base2];
                    for (i64 g = base2 + 1; g < base2 + p->l2_ways; g++)
                        if (l2_stamp[g] < bs) {
                            bs = l2_stamp[g];
                            f2 = g;
                        }
                    l2_tags[f2] = line;
                    p->l2_misses[b] += 1;
                    i64 ch = (line >> 2) % p->dram_channels;
                    i64 start = cycle > dram_free[ch] ? cycle
                                                      : dram_free[ch];
                    dram_free[ch] = start + p->dram_gap;
                    p->dram_requests[m] += 1;
                    p->cnt_dram_reqs[b] += 1;
                    lat = p->lat_dram + start - cycle;
                }
                l2_stamp[f2] = l2_tick++;
            }
            if (vta_hit && score) /* CCWS on_mem_event("vta_hit") */
                score[wid] += p->score_bump[b];
            i64 done_t = cycle + lat;
            if (tok & 1) { /* dependent use: block until it returns */
                ready[wid] = done_t;
            } else { /* hit-under-miss up to max_mlp outstanding */
                i64 *pd = pend + wid * P;
                i64 mi = 0;
                for (i64 k2 = 1; k2 < P; k2++)
                    if (pd[k2] < pd[mi]) mi = k2;
                pd[mi] = done_t; /* overwrite a stale (<= cycle) slot */
                i64 outstanding = 0, earliest = HUGE_T;
                for (i64 k2 = 0; k2 < P; k2++)
                    if (pd[k2] > cycle) {
                        outstanding += 1;
                        if (pd[k2] < earliest)
                            earliest = pd[k2];
                    }
                ready[wid] = outstanding >= p->max_mlp ? earliest
                                                       : cycle + 1;
            }
            adv = 1;
            cycle += 1;
        } else { /* batched ALU run up to the next memory instruction */
            adv = -tok;
            li += adv;
            cycle += adv;
            ready[wid] = cycle;
        }
        i64 pn = ++op_idx[wid];
        instr += adv;
        flags = 0;
        if (pn >= n_ops[wid]) {
            done[wid] = 1;
            avail[wid] = 0;
            if (last_wid == wid)
                last_wid = -1;
            p->last_done_wid[b] = wid;
            flags |= P_WARPDONE;
        }
        if (li >= p->next_epoch[b])
            flags |= P_EPOCH;
        if (instr >= p->window_mark[b])
            flags |= P_TIMELINE;
        if (flags)
            break;
    }
    p->pause[b] = flags;
    p->cycle[b] = cycle;
    p->li[b] = li;
    p->instr[b] = instr;
    p->last_wid[b] = last_wid;
    p->tick[b] = tick;
    p->l2_tick[m] = l2_tick;
}

void step_cells(const Params *p)
{
    for (i64 b = 0; b < p->B; b++) {
        if (!p->live[b] || !p->runnable[b] || p->pause[b])
            continue;
        run_cell(p, b);
    }
}
