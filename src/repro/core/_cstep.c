/* C stepper for the batched lockstep SM engine (repro.core.batched).
 *
 * A direct transliteration of the scalar hot path in
 * repro/core/simulator.py::SMSimulator.advance, operating on the SAME
 * stacked batch arrays the numpy stepper uses (one row per cell). Each
 * call advances every live, unpaused cell until a slice boundary.
 * Epoch boundaries, warp retirements, timeline samples and throttled
 * stretches of the known policy families (CCWS, statPCAL, CIAO,
 * Best-SWL rotation) are serviced HERE, in-stepper, as transliterations
 * of the repro.core.epoch kernels; a cell pauses back into Python only
 * for unknown policy subclasses (F_OBJECT / WD_OBJECT rows) and for
 * row finalization. Decision floats follow the fixed-point contract of
 * repro/core/epoch.py: integer counters below 2**53, each cutoff
 * decision a single-rounding double compare (hits*act <> cutoff*win),
 * so numpy and C agree bit-for-bit (tests/test_batched.py). Compile
 * with -ffp-contract=off so no compare side is fused.
 *
 * Compiled on demand by repro/core/_cstep.py with the system C compiler
 * (no Python.h — driven through ctypes). Field order of Params must
 * match the ctypes.Structure in _cstep.py exactly.
 */
#include <stdint.h>

typedef int64_t i64;
typedef signed char i8;
typedef uint64_t u64;

enum {
    P_EPOCH = 1,
    P_TIMELINE = 2,
    P_WARPDONE = 4,
    P_THROTTLE = 8,
    P_CAP = 16,   /* legacy: slice stops at the cycle cap use P_SLICE */
    P_SLICE = 32, /* reached until[b] (slice boundary or cycle cap)   */
    P_FINALIZE = 64 /* row completed in-stepper; Python only finalizes */
};

/* policy families / warp-done kinds (mirror repro.core.batched) */
enum { F_PASSIVE = 0, F_CCWS = 1, F_STATP = 2, F_CIAO = 3, F_OBJECT = 4 };
enum { WD_NOOP = 0, WD_SWL = 1, WD_STATP = 2, WD_OBJECT = 3 };

#define HUGE_T ((i64)1 << 62)

typedef struct {
    /* dimensions */
    i64 B, n, L, P;
    i64 nf, l1_sets, l1_ways;
    i64 vnf, v_sets, v_k;
    i64 l2nf, l2_sets, l2_ways;
    i64 nrb, dram_channels;
    i64 nw, list_entries, sat_max;
    /* config scalars (shape-class constants) */
    i64 xor_hash, reuse_filter;
    i64 max_mlp, line_shift;
    /* per-row config planes: knobs that vary cell to cell within one
     * shape class, indexed [b] like mem_of */
    i64 *lat_l1, *lat_smem, *lat_migrate, *lat_l2, *lat_dram, *dram_gap;
    i64 *low_epoch;
    /* per-warp planes (B x n [x ...]) */
    i64 *ready, *toks, *op_idx, *n_ops, *pend;
    i8 *done, *avail, *iso, *byp, *live, *runnable;
    i64 *u_of, *n_of, *region_blocks, *mem_of, *until;
    /* per-row scalars */
    i64 *cycle, *instr, *li, *next_epoch, *window_mark;
    i64 *last_wid, *tick, *l2_tick;
    /* cache planes */
    i64 *l1_tags, *l1_owners, *l1_stamp;
    i8 *l1_reused;
    i64 *smem_tags, *smem_owner;
    i64 *v_addr, *v_evic, *v_head, *v_count, *v_inserts;
    i64 *l2_tags, *l2_stamp, *l2_hits, *l2_misses;
    i64 *dram_free, *dram_requests;
    /* event counters */
    i64 *cnt_l1_hit, *cnt_l1_miss, *cnt_smem_hit, *cnt_smem_miss;
    i64 *cnt_smem_migrate, *cnt_bypass, *cnt_evictions;
    i64 *cnt_smem_evictions, *cnt_vta_hits, *vta_hit_events;
    i64 *cnt_dram_reqs;   /* per-row; dram_requests is per hierarchy */
    /* control */
    i64 *pause, *last_done_wid;
    /* detector hooks: det_ptrs[b*4 + {irs_hits, vta_hits, interf, sat}];
       score_ptrs[b] is CCWS's score buffer (0 = policy has no
       on_mem_event hook) */
    u64 *det_ptrs, *score_ptrs;
    i64 *score_bump;
    i64 *pair_dense; /* B x (n+1) x n, row 0 = evictor==-1 guard */
    /* ---- in-stepper epoch / warp-done / timeline servicing ---- */
    i64 timeline_every, tl_cap;
    i64 *high_epoch, *aging_high, *stride_ok;   /* per-row knobs */
    double *low_cutoff, *high_cutoff;
    i8 *fam, *mode_p, *mode_t;          /* policy family / CIAO modes */
    i8 *allowed_pl, *isolated_pl, *bypass_pl;   /* policy mask planes */
    i8 *sp_bypass, *sp_base;            /* statPCAL mode + base set */
    double *sp_thresh;
    i64 *det_inst_total, *det_irs_inst, *irs_off;
    i64 *low_idx, *high_idx, *low_base_inst, *high_base_inst;
    i64 *high_crossings, *low_base_hits, *high_base_hits;
    i64 *low_snap_hits, *high_snap_hits;
    i64 *low_snap_win, *high_snap_win, *low_snap_act, *high_snap_act;
    i64 *pair_list, *wid_sets;
    i64 *ccws_base, *ccws_budget;
    i64 *ciao_stall, *ciao_iso, *stall_len, *iso_len;
    i64 *wd_kind, *swl_next, *remaining;
    i64 *tl_cycle, *tl_act, *tl_n, *tl_last_instr, *tl_last_cycle;
    double *tl_dipc;
} Params;

static i64 l1_set(const Params *p, i64 line)
{
    i64 s = line % p->l1_sets;
    if (p->xor_hash)
        s = (s ^ ((line / p->l1_sets) % p->l1_sets)) % p->l1_sets;
    return s;
}

/* circular-FIFO insert; the caller has excluded self-eviction */
static void vta_insert(const Params *p, i64 b, i64 owner, i64 line,
                       i64 evictor)
{
    i64 k = p->v_k;
    i64 s = owner % p->v_sets;
    i64 *addr = p->v_addr + b * p->vnf + s * k;
    i64 *evic = p->v_evic + b * p->vnf + s * k;
    i64 *head = p->v_head + b * p->v_sets + s;
    i64 *cnt = p->v_count + b * p->v_sets + s;
    if (*cnt == k) { /* full: FIFO-drop the oldest */
        addr[*head] = line;
        evic[*head] = evictor;
        *head = (*head + 1) % k;
    } else {
        i64 f = (*head + *cnt) % k;
        addr[f] = line;
        evic[f] = evictor;
        *cnt += 1;
    }
    p->v_inserts[b] += 1;
}

/* membership scan + FIFO pop of the oldest match + interference-list
 * bookkeeping (the fused interference.on_miss). Returns 1 on a VTA hit.
 * Physical slots outside the logical FIFO window are always -1, so the
 * membership scan over all k slots equals the scalar core's dict. */
static int vta_probe(const Params *p, i64 b, i64 wid, i64 line)
{
    i64 k = p->v_k;
    i64 s = wid % p->v_sets;
    i64 *addr = p->v_addr + b * p->vnf + s * k;
    int member = 0;
    for (i64 j = 0; j < k; j++)
        if (addr[j] == line) { member = 1; break; }
    if (!member)
        return 0;
    i64 *evic = p->v_evic + b * p->vnf + s * k;
    i64 h = p->v_head[b * p->v_sets + s];
    i64 cc = p->v_count[b * p->v_sets + s];
    i64 evictor = -1;
    for (i64 j = 0; j < cc; j++) { /* oldest-first logical order */
        i64 f = (h + j) % k;
        if (addr[f] == line) {
            evictor = evic[f];
            for (i64 jj = j; jj < cc - 1; jj++) {
                i64 f0 = (h + jj) % k;
                i64 f1 = (h + jj + 1) % k;
                addr[f0] = addr[f1];
                evic[f0] = evic[f1];
            }
            i64 fl = (h + cc - 1) % k;
            addr[fl] = -1;
            evic[fl] = -1;
            p->v_count[b * p->v_sets + s] = cc - 1;
            ((i64 *)(uintptr_t)p->det_ptrs[b * 4 + 1])[s] += 1;
            break;
        }
    }
    p->vta_hit_events[b] += 1;
    p->cnt_vta_hits[b] += 1;
    ((i64 *)(uintptr_t)p->det_ptrs[b * 4 + 0])[wid % p->nw] += 1;
    p->pair_dense[b * (p->n + 1) * p->n + (evictor + 1) * p->n + wid] += 1;
    i64 i = wid % p->list_entries;
    i64 *interf = (i64 *)(uintptr_t)p->det_ptrs[b * 4 + 2];
    i64 *sat = (i64 *)(uintptr_t)p->det_ptrs[b * 4 + 3];
    if (interf[i] == evictor) {
        if (sat[i] < p->sat_max)
            sat[i] += 1;
    } else if (interf[i] == -1) {
        interf[i] = evictor;
        sat[i] = 0;
    } else if (sat[i] == 0) {
        interf[i] = evictor;
    } else {
        sat[i] -= 1;
    }
    return 1;
}

/* ------------- in-stepper epoch / warp-done / timeline service -------
 * Per-row transliterations of the repro.core.epoch kernels. Each
 * mirrors what BatchedSMEngine's vectorized drain would do for one row,
 * at exactly the same point in the row's instruction stream. */

/* re-derive the dispatch masks from the policy mask planes (the tail
 * of _epoch_batch) */
static void refresh_row(const Params *p, i64 b)
{
    const i64 n = p->n;
    i8 *avail = p->avail + b * n;
    i8 *iso = p->iso + b * n;
    i8 *byp = p->byp + b * n;
    const i8 *al = p->allowed_pl + b * n;
    const i8 *is = p->isolated_pl + b * n;
    const i8 *bp = p->bypass_pl + b * n;
    const i8 *done = p->done + b * n;
    for (i64 i = 0; i < n; i++) {
        avail[i] = al[i] && !done[i];
        iso[i] = is[i];
        byp[i] = bp[i];
    }
}

/* CCWS: score decay + lost-locality throttling (epoch.ccws_tick) */
static void ccws_tick_row(const Params *p, i64 b)
{
    const i64 n = p->n;
    i64 *s = p->score_ptrs[b] ? (i64 *)(uintptr_t)p->score_ptrs[b]
                              : (i64 *)0;
    if (!s)
        return;
    const i64 base = p->ccws_base[b], budget = p->ccws_budget[b];
    const i8 *done = p->done + b * n;
    i8 *al = p->allowed_pl + b * n;
    for (i64 i = 0; i < n; i++) {
        i64 d = s[i] / 8;
        if (d < 1)
            d = 1;
        s[i] -= d;
        if (s[i] < base)
            s[i] = base;
    }
    /* stable sort: alive warps by descending score, dead warps last
     * (keys match epoch.ccws_tick's -score / _DEAD_KEY argsort) */
    i64 order[n];
    for (i64 i = 0; i < n; i++)
        order[i] = i;
    for (i64 i = 1; i < n; i++) {
        i64 o = order[i];
        i64 key = done[o] ? HUGE_T : -s[o];
        i64 j = i - 1;
        while (j >= 0) {
            i64 oj = order[j];
            i64 kj = done[oj] ? HUGE_T : -s[oj];
            if (kj <= key)
                break;
            order[j + 1] = oj;
            j--;
        }
        order[j + 1] = o;
    }
    i64 csum = 0;
    for (i64 r = 0; r < n; r++) {
        i64 w = order[r];
        i64 blocked = 0;
        if (!done[w]) {
            csum += s[w];
            blocked = (csum > budget) && (r > 0);
        }
        al[w] = !blocked;
    }
}

/* statPCAL: bandwidth-driven bypass flip (epoch.statpcal_tick); util
 * is the single-rounding double of BatchedSMEngine._util_vec */
static void statp_tick_row(const Params *p, i64 b, i64 cycle)
{
    const i64 n = p->n;
    double util = 0.0;
    if (cycle > 0) {
        i64 den = p->dram_channels * cycle;
        if (den < 1)
            den = 1;
        util = (double)(p->dram_requests[p->mem_of[b]] * p->dram_gap[b])
            / (double)den;
        if (util > 1.0)
            util = 1.0;
    }
    int nb = util < p->sp_thresh[b];
    if (nb == (int)p->sp_bypass[b])
        return;
    p->sp_bypass[b] = (i8)nb;
    i8 *al = p->allowed_pl + b * n;
    i8 *bp = p->bypass_pl + b * n;
    const i8 *bm = p->sp_base + b * n;
    for (i64 i = 0; i < n; i++) {
        al[i] = nb ? 1 : bm[i];
        bp[i] = nb ? !bm[i] : 0;
    }
}

/* the pair-list trigger guard of Algorithm 1 lines 4-19: cumulative
 * IRS of trigger k at or below the low cutoff (epoch.irs_cum_leq) */
static int ciao_pop_ok(const Params *p, i64 b, i64 k, i64 act,
                       const i8 *done)
{
    if (k == -1 || done[k])
        return 1;
    i64 inst = p->det_irs_inst[b];
    if (inst <= 0 || act <= 0)
        return 1;
    const i64 *ih = (const i64 *)(uintptr_t)p->det_ptrs[b * 4 + 0];
    i64 hits = ih[k % p->nw];
    return (double)(hits * act) <= p->low_cutoff[b] * (double)inst;
}

/* epoch-crossing poll + windowed IRS snapshots + aging
 * (epoch.poll_epochs for one row) */
static void ciao_poll_row(const Params *p, i64 b, i64 act,
                          int *lowp, int *highp)
{
    const i64 nw = p->nw;
    i64 it = p->det_inst_total[b];
    const i64 *vh = (const i64 *)(uintptr_t)p->det_ptrs[b * 4 + 1];
    i64 nlow = it / p->low_epoch[b];
    *lowp = nlow != p->low_idx[b];
    if (*lowp) {
        p->low_idx[b] = nlow;
        i64 win = it - p->low_base_inst[b];
        if (win < 1)
            win = 1;
        for (i64 w = 0; w < nw; w++) {
            i64 cur = vh[p->wid_sets[w]];
            p->low_snap_hits[b * nw + w] =
                cur - p->low_base_hits[b * nw + w];
            p->low_base_hits[b * nw + w] = cur;
        }
        p->low_snap_win[b] = win;
        p->low_snap_act[b] = act;
        p->low_base_inst[b] = it;
    }
    i64 nhigh = it / p->high_epoch[b];
    *highp = nhigh != p->high_idx[b];
    if (*highp) {
        p->high_idx[b] = nhigh;
        i64 win = it - p->high_base_inst[b];
        if (win < 1)
            win = 1;
        for (i64 w = 0; w < nw; w++) {
            i64 cur = vh[p->wid_sets[w]];
            p->high_snap_hits[b * nw + w] =
                cur - p->high_base_hits[b * nw + w];
            p->high_base_hits[b * nw + w] = cur;
        }
        p->high_snap_win[b] = win;
        p->high_snap_act[b] = act;
        p->high_base_inst[b] = it;
        p->high_crossings[b] += 1;
        if (p->aging_high[b] &&
                p->high_crossings[b] % p->aging_high[b] == 0) {
            p->det_irs_inst[b] /= 2;
            i64 *ih = (i64 *)(uintptr_t)p->det_ptrs[b * 4 + 0];
            for (i64 w = 0; w < nw; w++)
                ih[w] /= 2;
        }
    }
}

/* Algorithm 1 lines 4-19: pop at most one stalled and one isolated
 * warp, newest first (epoch.ciao_low_tick for one row) */
static void ciao_low_row(const Params *p, i64 b, i64 act)
{
    const i64 n = p->n, le = p->list_entries;
    const i8 *done = p->done + b * n;
    i8 *al = p->allowed_pl + b * n;
    i8 *is = p->isolated_pl + b * n;
    i64 *pair = p->pair_list + b * le * 2;
    i64 sl = p->stall_len[b];
    if (sl > 0) {
        i64 w = p->ciao_stall[b * n + sl - 1];
        if (ciao_pop_ok(p, b, pair[(w % le) * 2 + 1], act, done)) {
            p->stall_len[b] = sl - 1;
            al[w] = 1;
            pair[(w % le) * 2 + 1] = -1;
        }
    }
    /* a warp stalled while isolated must reactivate first — `allowed`
     * is read after the stall pop, like the scalar order */
    i64 il = p->iso_len[b];
    if (il > 0) {
        i64 w = p->ciao_iso[b * n + il - 1];
        if (al[w] &&
                ciao_pop_ok(p, b, pair[(w % le) * 2 + 0], act, done)) {
            p->iso_len[b] = il - 1;
            is[w] = 0;
            pair[(w % le) * 2 + 0] = -1;
        }
    }
}

/* Algorithm 1 lines 20-28: walk active warps by descending high-epoch
 * IRS, take at most one isolate/stall action (epoch.ciao_high_tick) */
static void ciao_high_row(const Params *p, i64 b)
{
    const i64 n = p->n, nw = p->nw, le = p->list_entries;
    const i8 *done = p->done + b * n;
    i8 *al = p->allowed_pl + b * n;
    i8 *is = p->isolated_pl + b * n;
    i64 *pair = p->pair_list + b * le * 2;
    const i64 *interf = (const i64 *)(uintptr_t)p->det_ptrs[b * 4 + 2];
    const i64 *hits = p->high_snap_hits + b * nw;
    i64 scored[n];
    i64 na = 0;
    for (i64 i = 0; i < n; i++)
        if (al[i] && !done[i])
            scored[na++] = i;
    if (na <= 1) /* never act on the last active warp */
        return;
    /* stable sort by descending snapshot hits (== descending IRS:
     * within a row the snapshot is hits * (act/win), one positive
     * scale), ties by warp id */
    for (i64 i = 1; i < na; i++) {
        i64 o = scored[i];
        i64 key = -hits[o % nw];
        i64 j = i - 1;
        while (j >= 0 && -hits[scored[j] % nw] > key) {
            scored[j + 1] = scored[j];
            j--;
        }
        scored[j + 1] = o;
    }
    i64 act = p->high_snap_act[b], win = p->high_snap_win[b];
    int mp = p->mode_p[b], mt = p->mode_t[b];
    for (i64 r = 0; r < na; r++) {
        i64 i = scored[r];
        i64 h = hits[i % nw];
        if (!((double)(h * act) > p->high_cutoff[b] * (double)win))
            break; /* sorted descending: nothing further exceeds */
        i64 j = interf[i % le];
        if (j == -1 || j == i || done[j])
            continue;
        if (mp && !is[j] && al[j]) {
            is[j] = 1;
            pair[(j % le) * 2 + 0] = i;
            p->ciao_iso[b * n + p->iso_len[b]] = j;
            p->iso_len[b] += 1;
            return;
        }
        if (mt && al[j] && (is[j] || !mp)) {
            al[j] = 0;
            pair[(j % le) * 2 + 1] = i;
            p->ciao_stall[b * n + p->stall_len[b]] = j;
            p->stall_len[b] += 1;
            return;
        }
    }
}

/* Service one epoch boundary in-stepper (the per-row equivalent of
 * BatchedSMEngine._epoch_batch). Returns 0 when the row's policy is an
 * unknown subclass (F_OBJECT) and must pause into Python instead.
 * `anchor` advances the next-trigger table (epoch pauses do, throttle
 * stretches do not, like the scalar loop). */
static int service_epoch(const Params *p, i64 b, int anchor, i64 cycle,
                         i64 li)
{
    i64 fam = p->fam[b];
    if (fam == F_OBJECT)
        return 0;
    p->det_inst_total[b] = li;
    p->det_irs_inst[b] = li - p->irs_off[b];
    if (fam == F_CCWS) {
        ccws_tick_row(p, b);
    } else if (fam == F_STATP) {
        statp_tick_row(p, b, cycle);
    } else if (fam == F_CIAO) {
        const i64 n = p->n;
        const i8 *done = p->done + b * n;
        const i8 *al = p->allowed_pl + b * n;
        i64 act = 0;
        for (i64 i = 0; i < n; i++)
            act += al[i] && !done[i];
        if (act < 1)
            act = 1;
        int low = 0, high = 0;
        ciao_poll_row(p, b, act, &low, &high);
        if (low)
            ciao_low_row(p, b, act);
        if (high)
            ciao_high_row(p, b);
    }
    p->irs_off[b] = li - p->det_irs_inst[b]; /* aging moves it */
    refresh_row(p, b);
    if (anchor) {
        i64 lo = p->low_epoch[b];
        i64 nxt = (li / lo + 1) * lo;
        if (p->stride_ok[b] && fam == F_CIAO
                && p->stall_len[b] + p->iso_len[b] == 0) {
            i64 hi = p->high_epoch[b];
            nxt = (li / hi + 1) * hi;
        }
        p->next_epoch[b] = nxt;
    }
    return 1;
}

/* record one timeline sample (BatchedSMEngine._timeline_rows) */
static void service_timeline(const Params *p, i64 b, i64 cycle, i64 instr)
{
    const i64 n = p->n;
    const i8 *al = p->allowed_pl + b * n;
    i64 na = 0;
    for (i64 i = 0; i < n; i++)
        na += al[i];
    i64 k = p->tl_n[b];
    if (k < p->tl_cap) { /* capacity-proved; guard against corruption */
        i64 dc = cycle - p->tl_last_cycle[b];
        if (dc < 1)
            dc = 1;
        p->tl_cycle[b * p->tl_cap + k] = cycle;
        p->tl_dipc[b * p->tl_cap + k] =
            (double)(instr - p->tl_last_instr[b]) / (double)dc;
        p->tl_act[b * p->tl_cap + k] = na;
        p->tl_n[b] = k + 1;
    }
    p->tl_last_instr[b] = instr;
    p->tl_last_cycle[b] = cycle;
    p->window_mark[b] += p->timeline_every;
}

/* warp retirement for the known kinds (BatchedSMEngine._warp_done_rows:
 * Best-SWL / statPCAL released-set rotation); the caller has already
 * flipped done/avail and handles WD_OBJECT by pausing */
static void warp_done_row(const Params *p, i64 b, i64 wid)
{
    const i64 n = p->n;
    i64 kind = p->wd_kind[b];
    p->remaining[b] -= 1;
    if (kind == WD_SWL) {
        i8 *al = p->allowed_pl + b * n;
        if (al[wid]) {
            al[wid] = 0;
            i64 nx = p->swl_next[b];
            if (nx < n) {
                al[nx] = 1;
                p->swl_next[b] = nx + 1;
                p->avail[b * n + nx] = !p->done[b * n + nx];
            }
        }
    } else if (kind == WD_STATP) {
        i8 *bm = p->sp_base + b * n;
        if (bm[wid]) {
            bm[wid] = 0;
            i64 nx = p->swl_next[b];
            if (nx < n) {
                bm[nx] = 1;
                p->swl_next[b] = nx + 1;
            }
            i8 *al = p->allowed_pl + b * n;
            i8 *bp = p->bypass_pl + b * n;
            i8 *avail = p->avail + b * n;
            i8 *byp = p->byp + b * n;
            const i8 *done = p->done + b * n;
            int ba = p->sp_bypass[b];
            for (i64 i = 0; i < n; i++) {
                al[i] = ba || bm[i];
                bp[i] = ba ? !bm[i] : 0;
                avail[i] = al[i] && !done[i];
                byp[i] = bp[i];
            }
        }
    }
}

static void run_cell(const Params *p, i64 b)
{
    const i64 n = p->n, L = p->L, P = p->P;
    i64 *ready = p->ready + b * n;
    i64 *op_idx = p->op_idx + b * n;
    i64 *n_ops = p->n_ops + b * n;
    i64 *pend = p->pend + b * n * P;
    i8 *done = p->done + b * n;
    i8 *avail = p->avail + b * n;
    i8 *iso = p->iso + b * n;
    i8 *byp = p->byp + b * n;
    const i64 *toks = p->toks + p->u_of[b] * n * L;
    i64 *l1_tags = p->l1_tags + b * p->nf;
    i64 *l1_owners = p->l1_owners + b * p->nf;
    i64 *l1_stamp = p->l1_stamp + b * p->nf;
    i8 *l1_reused = p->l1_reused + b * p->nf;
    i64 *smem_tags = p->smem_tags + b * p->nrb;
    i64 *smem_owner = p->smem_owner + b * p->nrb;
    /* post-L1 planes are per hierarchy: rows of a multi-SM cell share
     * them (only one SM phase is runnable at a time, so the cached
     * l2_tick never races another row) */
    const i64 m = p->mem_of[b];
    i64 *l2_tags = p->l2_tags + m * p->l2nf;
    i64 *l2_stamp = p->l2_stamp + m * p->l2nf;
    i64 *dram_free = p->dram_free + m * p->dram_channels;
    i64 *score = p->score_ptrs[b]
        ? (i64 *)(uintptr_t)p->score_ptrs[b] : (i64 *)0;
    i64 cycle = p->cycle[b], li = p->li[b], instr = p->instr[b];
    i64 last_wid = p->last_wid[b];
    i64 tick = p->tick[b], l2_tick = p->l2_tick[m];
    i64 rb = p->region_blocks[b];
    const i64 until = p->until[b];
    /* this row's config-plane knobs, hoisted out of the hot loop */
    const i64 lat_l1 = p->lat_l1[b], lat_smem = p->lat_smem[b];
    const i64 lat_migrate = p->lat_migrate[b], lat_l2 = p->lat_l2[b];
    const i64 lat_dram = p->lat_dram[b], dram_gap = p->dram_gap[b];
    const i64 low_epoch = p->low_epoch[b];
    i64 flags = 0;

    for (;;) {
        if (cycle >= until) { /* slice boundary / cycle cap */
            flags = P_SLICE;
            break;
        }
        /* pick a warp: greedy (keep last), else oldest ready & allowed */
        i64 wid = last_wid;
        if (wid < 0 || !avail[wid] || ready[wid] > cycle) {
            i64 w = -1;
            for (i64 i = 0; i < n; i++)
                if (avail[i] && ready[i] <= cycle) { w = i; break; }
            if (w >= 0) {
                wid = last_wid = w;
            } else {
                /* fused event skip: jump to the earliest wake-up */
                i64 best = HUGE_T, w2 = -1;
                for (i64 i = 0; i < n; i++)
                    if (avail[i] && ready[i] < best) {
                        best = ready[i];
                        w2 = i;
                    }
                if (w2 < 0) { /* everything throttled */
                    if (p->fam[b] == F_OBJECT) {
                        flags = P_THROTTLE;
                        break;
                    }
                    /* advance to let epochs fire, service in-stepper
                     * (no re-anchor of next_epoch, like the scalar
                     * loop), then retry selection; the slice check
                     * above bounds the stretch */
                    cycle += low_epoch;
                    li += low_epoch;
                    service_epoch(p, b, 0, cycle, li);
                    continue;
                }
                if (best >= until) {
                    /* clamp to the slice boundary, like the scalar
                     * advance(); the next phase resumes from here */
                    cycle = until;
                    flags = P_SLICE;
                    break;
                }
                cycle = best;
                if (last_wid >= 0 && avail[last_wid] &&
                        ready[last_wid] <= best)
                    wid = last_wid; /* greedy still wins the tie */
                else
                    wid = last_wid = w2;
            }
        }
        i64 tok = toks[wid * L + op_idx[wid]];
        i64 adv;
        if (tok >= 0) { /* memory instruction */
            li += 1;
            i64 line = tok >> p->line_shift;
            int vta_hit = 0;
            i64 lat = -1; /* -1 == "to the post-L1 stage" */
            if (byp[wid]) { /* statPCAL bypass */
                p->cnt_bypass[b] += 1;
            } else if (iso[wid]) { /* CIAO-P smem redirection */
                if (rb > 0) {
                    i64 idx = line % rb;
                    i64 old = smem_tags[idx];
                    if (old == line) {
                        p->cnt_smem_hit[b] += 1;
                        lat = lat_smem;
                    } else {
                        if (old >= 0) {
                            p->cnt_smem_evictions[b] += 1;
                            i64 owner = smem_owner[idx];
                            if (owner != wid)
                                vta_insert(p, b, owner, old, wid);
                        }
                        if (vta_probe(p, b, wid, line))
                            vta_hit = 1;
                        /* migration: single-copy coherence */
                        i64 base1 = l1_set(p, line) * p->l1_ways;
                        i64 f = -1;
                        for (i64 g = base1; g < base1 + p->l1_ways; g++)
                            if (l1_tags[g] == line) { f = g; break; }
                        if (f >= 0) {
                            l1_tags[f] = -1;
                            l1_owners[f] = -1;
                            p->cnt_smem_migrate[b] += 1;
                            lat = lat_migrate;
                        } else {
                            p->cnt_smem_miss[b] += 1;
                        }
                        smem_tags[idx] = line;
                        smem_owner[idx] = wid;
                    }
                }
            } else { /* L1D path */
                i64 base1 = l1_set(p, line) * p->l1_ways;
                i64 f = -1;
                for (i64 g = base1; g < base1 + p->l1_ways; g++)
                    if (l1_tags[g] == line) { f = g; break; }
                if (f >= 0) { /* L1D hit */
                    p->cnt_l1_hit[b] += 1;
                    l1_reused[f] = 1;
                    l1_stamp[f] = tick++;
                    lat = lat_l1;
                } else { /* miss: probe VTA, fill with stamp-LRU victim */
                    p->cnt_l1_miss[b] += 1;
                    if (vta_probe(p, b, wid, line))
                        vta_hit = 1;
                    i64 vic = base1;
                    i64 bs = l1_stamp[base1];
                    for (i64 g = base1 + 1; g < base1 + p->l1_ways; g++)
                        if (l1_stamp[g] < bs) {
                            bs = l1_stamp[g];
                            vic = g;
                        }
                    i64 old = l1_tags[vic];
                    if (old >= 0) {
                        p->cnt_evictions[b] += 1;
                        i64 owner = l1_owners[vic];
                        if ((l1_reused[vic] || !p->reuse_filter) &&
                                owner != wid)
                            vta_insert(p, b, owner, old, wid);
                    }
                    l1_tags[vic] = line;
                    l1_owners[vic] = wid;
                    l1_reused[vic] = 0;
                    l1_stamp[vic] = tick++;
                }
            }
            if (lat < 0) { /* post-L1: L2 tags + DRAM queueing */
                i64 base2 = (line % p->l2_sets) * p->l2_ways;
                i64 f2 = -1;
                for (i64 g = base2; g < base2 + p->l2_ways; g++)
                    if (l2_tags[g] == line) { f2 = g; break; }
                if (f2 >= 0) { /* L2 hit */
                    p->l2_hits[b] += 1;
                    lat = lat_l2;
                } else { /* L2 miss -> DRAM channel queue */
                    f2 = base2;
                    i64 bs = l2_stamp[base2];
                    for (i64 g = base2 + 1; g < base2 + p->l2_ways; g++)
                        if (l2_stamp[g] < bs) {
                            bs = l2_stamp[g];
                            f2 = g;
                        }
                    l2_tags[f2] = line;
                    p->l2_misses[b] += 1;
                    i64 ch = (line >> 2) % p->dram_channels;
                    i64 start = cycle > dram_free[ch] ? cycle
                                                      : dram_free[ch];
                    dram_free[ch] = start + dram_gap;
                    p->dram_requests[m] += 1;
                    p->cnt_dram_reqs[b] += 1;
                    lat = lat_dram + start - cycle;
                }
                l2_stamp[f2] = l2_tick++;
            }
            if (vta_hit && score) /* CCWS on_mem_event("vta_hit") */
                score[wid] += p->score_bump[b];
            i64 done_t = cycle + lat;
            if (tok & 1) { /* dependent use: block until it returns */
                ready[wid] = done_t;
            } else { /* hit-under-miss up to max_mlp outstanding */
                i64 *pd = pend + wid * P;
                i64 mi = 0;
                for (i64 k2 = 1; k2 < P; k2++)
                    if (pd[k2] < pd[mi]) mi = k2;
                pd[mi] = done_t; /* overwrite a stale (<= cycle) slot */
                i64 outstanding = 0, earliest = HUGE_T;
                for (i64 k2 = 0; k2 < P; k2++)
                    if (pd[k2] > cycle) {
                        outstanding += 1;
                        if (pd[k2] < earliest)
                            earliest = pd[k2];
                    }
                ready[wid] = outstanding >= p->max_mlp ? earliest
                                                       : cycle + 1;
            }
            adv = 1;
            cycle += 1;
        } else { /* batched ALU run up to the next memory instruction */
            adv = -tok;
            li += adv;
            cycle += adv;
            ready[wid] = cycle;
        }
        i64 pn = ++op_idx[wid];
        instr += adv;
        flags = 0;
        int fin = 0;
        if (pn >= n_ops[wid]) {
            done[wid] = 1;
            avail[wid] = 0;
            if (last_wid == wid)
                last_wid = -1;
            p->last_done_wid[b] = wid;
            if (p->wd_kind[b] == WD_OBJECT) {
                flags |= P_WARPDONE;
            } else {
                warp_done_row(p, b, wid);
                if (p->remaining[b] == 0)
                    fin = 1; /* finalize after epoch/timeline below */
            }
        }
        /* once any pause pends for Python, later checks on this
         * dispatch must pause too — the drain replays them in the
         * scalar order (warp-done, epoch, timeline) */
        if (li >= p->next_epoch[b]) {
            if (flags || !service_epoch(p, b, 1, cycle, li))
                flags |= P_EPOCH;
        }
        if (instr >= p->window_mark[b]) {
            if (flags)
                flags |= P_TIMELINE;
            else
                service_timeline(p, b, cycle, instr);
        }
        if (fin)
            flags |= P_FINALIZE;
        if (flags)
            break;
    }
    p->pause[b] = flags;
    p->cycle[b] = cycle;
    p->li[b] = li;
    p->instr[b] = instr;
    p->last_wid[b] = last_wid;
    p->tick[b] = tick;
    p->l2_tick[m] = l2_tick;
}

void step_cells(const Params *p)
{
    for (i64 b = 0; b < p->B; b++) {
        if (!p->live[b] || !p->runnable[b] || p->pause[b])
            continue;
        run_cell(p, b);
    }
}
