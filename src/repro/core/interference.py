"""Cache-interference detection (paper §III-A, §IV-A, Fig. 6).

Faithful implementation of:

* **Interference list** — 64 entries indexed by interfered WID, each holding
  a 6-bit interfering WID + 2-bit saturating counter. The counter tracks the
  *most recently and frequently* interfering warp: same-warp events increment
  (saturating at 3), different-warp events decrement; the stored WID is
  replaced only when the counter underflows at 0 (Fig. 4c).

* **Pair list** — 64 entries x two 6-bit fields: field 0 records which
  interfered warp triggered the *redirection* (isolation) of this warp,
  field 1 which triggered its *stall*. -1 = empty. Used by Algorithm 1 to
  undo actions in reverse order.

* **IRS** (Eq. 1): ``IRS_i = F_vta_hits(i) / (N_exec_inst / N_active_warps)``
  evaluated on two epochs — the high-cutoff epoch (5000 instructions, decide
  isolate/stall) and the low-cutoff epoch (100 instructions, decide
  reactivate/un-redirect). Cutoffs 0.01 / 0.005 (§IV-A; sensitivity §V-E).

The same detector instance is shared by the on-chip memory model (CIAO-P)
and the warp scheduler (CIAO-T) — paper §III-C notes L1D and shared-memory
interference do not mix, so one VTA suffices.

All per-warp counters, the interference/pair lists, and the epoch/IRS
bookkeeping live in a **batch-of-1** :class:`repro.core.epoch.DetPlanes`
row: the epoch math itself (crossing detection, windowed IRS snapshots,
aging) is the vectorized kernel :func:`repro.core.epoch.poll_epochs`,
which the batched engine calls over whole batches of cells at once and
this object calls with ``B == 1``. :meth:`adopt_row` re-points a detector
at a row of a full-batch plane so the engine's kernel writes and the
object's reads share memory.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import epoch as _epoch
from repro.core.vta import VictimTagArray

NO_WARP = -1


@dataclasses.dataclass
class DetectorConfig:
    num_warps: int = 48
    list_entries: int = 64           # §V-F: 64-entry interference/pair lists
    vta_sets: int = 48
    vta_tags_per_set: int = 8
    high_cutoff: float = 0.01
    low_cutoff: float = 0.005
    high_epoch: int = 5000           # instructions
    low_epoch: int = 100
    sat_max: int = 3                 # 2-bit saturating counter
    # Counter aging (refinement, ablatable): every N high epochs the
    # cumulative VTA-hit counters and the IRS instruction counter are
    # halved (hardware: shift right). Preserves Eq. 1 ratios but bounds the
    # history horizon so reactivation (low-cutoff test) tracks phase
    # changes instead of the whole-kernel average. 0 disables.
    aging_high_epochs: int = 10


def _plane_prop(name, doc=None):
    """2-D plane row: expose the (nw,)/(le,)-shaped row of the detector's
    batch-of-1 planes as a plain array attribute."""
    def get(self):
        return getattr(self._pl, name)[0]
    return property(get, doc=doc)


def _scalar_prop(name, doc=None):
    """1-D plane row: expose element 0 as a plain int attribute."""
    def get(self):
        return int(getattr(self._pl, name)[0])

    def set_(self, value):
        getattr(self._pl, name)[0] = value
    return property(get, set_, doc=doc)


class InterferenceDetector:
    __slots__ = ("cfg", "vta", "_pl", "pair_counts", "vta_hit_events")

    def __init__(self, cfg: Optional[DetectorConfig] = None):
        # None default: a shared mutable DetectorConfig() default instance
        # would leak state (e.g. epoch overrides) between detectors.
        self.cfg = cfg = cfg if cfg is not None else DetectorConfig()
        self.vta = VictimTagArray(cfg.vta_sets, cfg.vta_tags_per_set)
        # canonical state: a batch-of-1 row of the vectorized epoch planes
        self._pl = _epoch.DetPlanes.alloc(1, cfg)
        # the VTA's per-set hit counters ARE the plane row (epoch
        # snapshots and the batched engine's C stepper write through it)
        self.vta.hits = self._pl.vta_hits[0]
        self.vta_hit_events = 0
        # (evictor, victim) -> event count; the Fig. 4 non-uniformity data.
        self.pair_counts: Dict[Tuple[int, int], int] = {}

    # plane-backed attributes (same names/shapes as the former ndarrays
    # and ints; the arrays are row views, so elementwise mutation by the
    # hot loops lands in the planes the epoch kernels read)
    interfering_wid = _plane_prop("interfering")
    sat_counter = _plane_prop("sat")
    pair_list = _plane_prop("pair_list")
    irs_hits = _plane_prop("irs_hits")
    inst_total = _scalar_prop("inst_total")
    irs_inst = _scalar_prop("irs_inst")

    def adopt_row(self, planes: "_epoch.DetPlanes", b: int) -> None:
        """Re-point this detector at row ``b`` of a full-batch plane set
        (used by the batched engine). Current state is copied in; from
        then on object reads and batch-kernel writes share memory."""
        planes.copy_row_from(self._pl, b)
        self._pl = planes.row(b)
        self.vta.hits = planes.vta_hits[b]

    # ------------------------------------------------------------- events
    def on_instruction(self, n: int = 1) -> None:
        self._pl.inst_total[0] += n
        self._pl.irs_inst[0] += n

    def on_eviction(self, owner_wid: int, line_addr: int,
                    evictor_wid: int) -> None:
        self.vta.insert(owner_wid, line_addr, evictor_wid)

    def on_miss(self, wid: int, line_addr: int) -> Optional[int]:
        """Probe VTA; on a VTA hit update the interference list (Fig. 4c)
        and return the interfering WID."""
        vta = self.vta
        # the dominant outcome is a VTA miss: answer it with one dict probe
        # before paying for the full FIFO walk
        if line_addr not in vta._member[wid % vta.num_sets]:
            return None
        evictor = vta.probe(wid, line_addr)
        if evictor is None:  # pragma: no cover - membership implies a hit
            return None
        self.vta_hit_events += 1
        self.irs_hits[wid % self.cfg.num_warps] += 1
        key = (evictor, wid)
        self.pair_counts[key] = self.pair_counts.get(key, 0) + 1
        i = wid % self.cfg.list_entries
        interfering, sat = self.interfering_wid, self.sat_counter
        if interfering[i] == evictor:
            sat[i] = min(sat[i] + 1, self.cfg.sat_max)
        elif interfering[i] == NO_WARP:
            interfering[i] = evictor
            sat[i] = 0
        else:
            if sat[i] == 0:
                interfering[i] = evictor   # replace on underflow
            else:
                sat[i] -= 1
        return evictor

    # ---------------------------------------------------------------- IRS
    def irs(self, wid: int, active_warps: int) -> float:
        """Eq. 1 over the aged cumulative counters."""
        if self.irs_inst == 0 or active_warps <= 0:
            return 0.0
        per_warp_inst = self.irs_inst / active_warps
        if per_warp_inst <= 0:
            return 0.0
        return self.irs_hits[wid % self.cfg.num_warps] / per_warp_inst

    def poll_epochs(self, active_warps: int) -> Tuple[bool, bool]:
        """Check for low/high epoch crossings (robust to batched instruction
        counting). At each crossing, snapshot the *windowed* IRS — Eq. 1
        evaluated over the epoch that just ended, so IRS tracks "the latest
        IRS_i" (§IV-A) and falls once an interferer is isolated/stalled.

        Batch-of-1 delegation to :func:`repro.core.epoch.poll_epochs` —
        the same kernel the batched engine runs over whole batches."""
        low, high = _epoch.poll_epochs(
            self._pl, _epoch.IDX0,
            np.asarray([active_warps], np.int64))
        return bool(low[0]), bool(high[0])

    def irs_low(self, wid: int) -> float:
        """Last low-epoch windowed IRS, from the fixed-point snapshot
        triple (reporting; cutoff decisions use the int compare)."""
        pl = self._pl
        h = int(pl.low_snap_hits[0, wid % self.cfg.num_warps])
        return h * int(pl.low_snap_act[0]) / int(pl.low_snap_win[0])

    def irs_high(self, wid: int) -> float:
        pl = self._pl
        h = int(pl.high_snap_hits[0, wid % self.cfg.num_warps])
        return h * int(pl.high_snap_act[0]) / int(pl.high_snap_win[0])

    def most_interfering(self, wid: int) -> int:
        return int(self._pl.interfering[0, wid % self.cfg.list_entries])

    # ------------------------------------------------------------ pair list
    def record_isolation(self, interfering: int, interfered: int) -> None:
        self._pl.pair_list[0, interfering % self.cfg.list_entries, 0] = \
            interfered

    def record_stall(self, interfering: int, interfered: int) -> None:
        self._pl.pair_list[0, interfering % self.cfg.list_entries, 1] = \
            interfered

    def isolation_trigger(self, wid: int) -> int:
        return int(self._pl.pair_list[0, wid % self.cfg.list_entries, 0])

    def stall_trigger(self, wid: int) -> int:
        return int(self._pl.pair_list[0, wid % self.cfg.list_entries, 1])

    def clear_isolation(self, wid: int) -> None:
        self._pl.pair_list[0, wid % self.cfg.list_entries, 0] = NO_WARP

    def clear_stall(self, wid: int) -> None:
        self._pl.pair_list[0, wid % self.cfg.list_entries, 1] = NO_WARP

    # -------------------------------------------------------------- epochs
    def at_high_epoch(self) -> bool:
        return self.inst_total > 0 and \
            self.inst_total % self.cfg.high_epoch == 0

    def at_low_epoch(self) -> bool:
        return self.inst_total > 0 and \
            self.inst_total % self.cfg.low_epoch == 0
