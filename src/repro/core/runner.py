"""Unified experiment runner: declarative policy × workload × config grids.

Every benchmark used to hand-roll its own sweep loop around
``SMSimulator``. This module replaces those with one subsystem:

* :class:`ExperimentGrid` — a declarative spec: workload names, policy
  names, named :class:`SimConfig` variants, trace scale, base seed, and an
  optional multi-SM :class:`~repro.core.gpu.GPUConfig`.
* :func:`run_grid` — expands the grid into cells and runs them through
  one of three engines (``engine=`` argument):

  - ``"batched"`` — group compatible cells (same SimConfig + GPU shape,
    batchable per :func:`repro.core.batched.supports_config` — this
    includes multi-SM chips, stacked as (SM × cell) rows over shared
    L2/DRAM planes), dispatch the groups to the
    :class:`~repro.core.batched.BatchedSMEngine` lockstep engine
    in-process, and run whatever does not batch (queued-L2/MSHR-gated
    variants) per cell. Best-SWL / statPCAL offline limit sweeps are
    flattened into the batch (one subcell per limit) and reduced
    afterwards.
  - ``"process"`` — the spawn-pool fan-out (``processes`` workers, spawn
    context so no JAX fork hazards), the pre-batched path.
  - ``"auto"`` (default) — ``"batched"`` when at least
    ``AUTO_MIN_BATCH`` cells are batchable, else ``"process"``.

  Records come back in grid order either way, and results are
  bit-identical across engines and parallelism (asserted in
  ``tests/test_batched.py``). Workload traces are seeded from
  ``crc32(grid.seed, workload)`` only — every policy/variant of a
  workload sees identical traces.
* :func:`save_records` / :func:`load_records` — JSON persistence; a
  reloaded file compares equal (``==``) to the in-memory records.
* an on-disk workload cache under ``results/workloads/`` (override via
  ``$REPRO_WORKLOAD_CACHE_DIR``; empty disables): grid workers and the
  batched group-builder ``load_workload`` instead of regenerating
  (trace generation costs ~100ms/workload; an npz load is ~10x
  cheaper), with atomic writes so concurrent spawn workers never see a
  torn file. Behind it sits the *shipped* curated set
  (:mod:`repro.workloads.curated`): checksum-manifested ``.npz`` files
  committed to the repo, so cross-machine sweeps load identical traces.

Example::

    grid = ExperimentGrid(name="fig8", workloads=("syrk", "kmn"),
                          policies=("gto", "ciao-c"))
    records = run_grid(grid, processes=4, json_path="results/fig8.json")
    by = index_records(records)
    rel = by["syrk", "ciao-c", "base"].ipc / by["syrk", "gto", "base"].ipc
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import itertools
import json
import multiprocessing
import os
import pathlib
import threading
import time
import zlib
from concurrent.futures import ThreadPoolExecutor
from typing import (Any, Dict, List, Mapping, Optional, Sequence, Tuple,
                    Union)

from repro.core import faults
from repro.core import ledger as _ledger
from repro.core.gpu import GPUConfig, run_gpu_policy_sweep
from repro.core.simulator import SimConfig, run_policy_sweep
from repro.workloads import WORKLOADS, make_workload
from repro.workloads.io import load_workload, save_workload

SCHEMA_VERSION = 1
BASE_VARIANT = "base"
ENGINES = ("auto", "batched", "process", "jax")
# "auto" switches to the batched engine for grids at least this wide
AUTO_MIN_BATCH = 8


@dataclasses.dataclass
class ExperimentGrid:
    name: str
    workloads: Sequence[str]
    policies: Sequence[str]
    # label -> SimConfig; None/empty means a single default-config variant
    variants: Optional[Mapping[str, SimConfig]] = None
    scale: float = 0.5
    seed: int = 0
    gpu: Optional[GPUConfig] = None      # None = single-SM
    best_swl_limits: Sequence[int] = (2, 4, 6, 8, 16, 32, 48)

    def variant_items(self) -> List[Tuple[str, Optional[SimConfig]]]:
        if not self.variants:
            return [(BASE_VARIANT, None)]
        return list(self.variants.items())


@dataclasses.dataclass
class RunRecord:
    """One grid cell's outcome. All fields JSON-round-trip exactly."""
    grid: str
    workload: str
    klass: str
    policy: str
    variant: str
    num_sms: int
    seed: int
    scale: float
    ipc: float
    cycles: int
    instructions: int
    l1_hit_rate: float
    vta_hits: int
    mean_active_warps: float
    stats: Dict[str, int]
    # interference pair events [evictor, victim, count], most frequent
    # first (single-SM only; empty for multi-SM chips)
    pairs: List[List[int]] = dataclasses.field(default_factory=list)
    per_sm_ipc: List[float] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class FailedCell:
    """A grid cell quarantined by the resilience layer instead of
    crashing the sweep: the last error, how many execution attempts were
    made, and the backend-degradation trail that was walked (e.g.
    ``["c", "c", "numpy", "scalar"]``). ``truncated`` cells were not
    *broken* — the wall-clock ``deadline_s`` passed before they ran;
    re-run with ``resume=`` to fill them in. Persisted alongside
    ``RunRecord`` by :func:`save_records` (``"failed": true`` marker)
    and skipped by :func:`index_records`."""
    grid: str
    workload: str
    policy: str
    variant: str
    num_sms: int
    seed: int
    scale: float
    error: str
    error_type: str
    attempts: int
    backends: List[str] = dataclasses.field(default_factory=list)
    truncated: bool = False


AnyRecord = Union[RunRecord, FailedCell]


@dataclasses.dataclass
class _Cell:
    grid: str
    workload: str
    policy: str
    variant: str
    cfg: Optional[SimConfig]
    scale: float
    seed: int
    gpu: Optional[GPUConfig]
    best_swl_limits: Sequence[int]


def workload_seed(base_seed: int, workload: str) -> int:
    """Deterministic per-workload trace seed, shared by every policy and
    variant so comparisons stay apples-to-apples."""
    return zlib.crc32(f"{base_seed}:{workload}".encode()) & 0x7FFFFFFF


def workload_cache_dir() -> Optional[pathlib.Path]:
    """Directory of the on-disk workload cache (None = disabled)."""
    val = os.environ.get("REPRO_WORKLOAD_CACHE_DIR", "results/workloads")
    return pathlib.Path(val) if val else None


# in-memory workload cache: an explicit LRU instead of functools'
# lru_cache so parallel chunk workers get per-key locking — two threads
# asking for the same (name, seed, scale) must not both pay the
# generate/disk-load, and an OrderedDict mutation is not atomic under
# free-threaded access patterns we want to be robust to.
_WL_CACHE_SIZE = 256
_WL_CACHE: "collections.OrderedDict[Tuple[str, int, float], Any]" = \
    collections.OrderedDict()
_WL_GUARD = threading.Lock()                   # protects the two dicts
_WL_KEY_LOCKS: Dict[Tuple[str, int, float], threading.Lock] = {}


def _load_or_make_workload(name: str, seed: int, scale: float):
    """Disk cache → curated set → generate (with atomic disk write).

    On disk: ``results/workloads/<name>-s<seed>-x<scale>.npz`` via the
    versioned :mod:`repro.workloads.io` format, so spawn workers and the
    batched group-builder load instead of regenerate. Writes go through
    a per-pid temp file + ``os.replace`` (atomic), so concurrent workers
    racing on the same cell never read a torn file. A cache file that
    fails to load (torn write survivor, bad disk, checksum mismatch —
    the format carries a content CRC) is *deleted* before regenerating,
    so the bad bytes are re-parsed at most once instead of on every
    future run.
    """
    cache = workload_cache_dir()
    path = None
    if cache is not None:
        path = cache / f"{name}-s{seed}-x{scale:g}.npz"
        if path.exists():
            try:
                faults.fire("cache.load", key=path.name, path=str(path))
                return load_workload(path)
            except Exception:
                # corrupt/truncated/stale cache entry: remove it and
                # fall through to curated/generate (which re-writes it)
                with contextlib.suppress(OSError):
                    path.unlink()
    # the shipped, checksum-manifested curated set (cross-machine
    # reproducibility); $REPRO_NO_CURATED skips it
    from repro.workloads.curated import load_curated
    wl = load_curated(name, seed, scale)
    if wl is not None:
        return wl
    wl = make_workload(name, seed=seed, scale=scale)
    if path is not None:
        tmp = cache / (f".{name}-s{seed}-x{scale:g}"
                       f".{os.getpid()}.{threading.get_ident()}.tmp.npz")
        try:
            save_workload(wl, tmp)
            os.replace(tmp, path)
        except Exception:
            with contextlib.suppress(OSError):
                tmp.unlink()
    return wl


def _cached_workload(name: str, seed: int, scale: float):
    """Two-level, thread-safe workload cache.

    In memory: a grid re-uses one workload across every policy × variant
    cell (generation costs ~100ms per workload and used to be repeated
    per cell); 256 entries so wide grids don't thrash. Safe to share
    across threads because nothing mutates trace arrays — the simulator
    compiles its own token streams and the GPU model's address-offset
    copies allocate fresh arrays. A per-key lock serialises the miss
    path (one generation per workload, not one per worker thread) while
    hits on other keys proceed concurrently.
    """
    key = (name, seed, scale)
    with _WL_GUARD:
        wl = _WL_CACHE.get(key, None)
        if wl is not None:
            _WL_CACHE.move_to_end(key)
            return wl
        klock = _WL_KEY_LOCKS.setdefault(key, threading.Lock())
    with klock:
        with _WL_GUARD:                       # another thread filled it
            wl = _WL_CACHE.get(key, None)
            if wl is not None:
                _WL_CACHE.move_to_end(key)
                return wl
        wl = _load_or_make_workload(name, seed, scale)
        with _WL_GUARD:
            _WL_CACHE[key] = wl
            _WL_CACHE.move_to_end(key)
            while len(_WL_CACHE) > _WL_CACHE_SIZE:
                _WL_CACHE.popitem(last=False)
    return wl


def _workload_cache_clear() -> None:
    with _WL_GUARD:
        _WL_CACHE.clear()
        _WL_KEY_LOCKS.clear()


# keep the lru_cache-style handle the tests (and any callers) rely on
_cached_workload.cache_clear = _workload_cache_clear


def _run_cell(cell: _Cell) -> RunRecord:
    wl = _cached_workload(cell.workload,
                          workload_seed(cell.seed, cell.workload),
                          cell.scale)
    if cell.gpu is not None:
        res = run_gpu_policy_sweep(
            wl, [cell.policy], cfg=cell.cfg, gpu=cell.gpu,
            best_swl_limits=tuple(cell.best_swl_limits))[cell.policy]
        return RunRecord(
            grid=cell.grid, workload=cell.workload, klass=wl.klass,
            policy=cell.policy, variant=cell.variant,
            num_sms=cell.gpu.num_sms, seed=cell.seed, scale=cell.scale,
            ipc=res.ipc, cycles=res.cycles, instructions=res.instructions,
            l1_hit_rate=res.l1_hit_rate, vta_hits=res.vta_hits,
            mean_active_warps=res.mean_active_warps,
            stats=dict(res.mem_stats),
            per_sm_ipc=[r.ipc for r in res.per_sm])
    res = run_policy_sweep(wl, [cell.policy], cfg=cell.cfg,
                           best_swl_limits=tuple(cell.best_swl_limits)
                           )[cell.policy]
    return RunRecord(
        grid=cell.grid, workload=cell.workload, klass=wl.klass,
        policy=cell.policy, variant=cell.variant, num_sms=1,
        seed=cell.seed, scale=cell.scale,
        ipc=res.ipc, cycles=res.cycles, instructions=res.instructions,
        l1_hit_rate=res.l1_hit_rate, vta_hits=res.vta_hits,
        mean_active_warps=res.mean_active_warps, stats=dict(res.stats),
        pairs=[list(p) for p in res.pairs])


def expand_grid(grid: ExperimentGrid) -> List[_Cell]:
    cells = []
    for w in grid.workloads:
        if w not in WORKLOADS:
            raise ValueError(f"unknown workload {w!r}")
        for p in grid.policies:
            for label, cfg in grid.variant_items():
                cells.append(_Cell(
                    grid=grid.name, workload=w, policy=p, variant=label,
                    cfg=cfg, scale=grid.scale, seed=grid.seed,
                    gpu=grid.gpu, best_swl_limits=grid.best_swl_limits))
    return cells


def _batchable(cell: _Cell) -> bool:
    from repro.core.batched import supports_config
    return supports_config(
        cell.cfg if cell.cfg is not None else SimConfig(), cell.gpu)


# token-plane budget per batched chunk: unique workloads are stacked
# (B, num_warps, longest-stream) int64, so bound the padded plane
_BATCH_TOKEN_BUDGET = 192 * 1024 * 1024
_BATCH_MAX_CELLS = 256


def batch_token_budget() -> int:
    """Per-chunk token-plane byte budget; ``$REPRO_BATCH_TOKEN_BUDGET``
    overrides the 192 MiB default (small values force chunk streaming —
    many small engines built, run, and freed in sequence)."""
    val = os.environ.get("REPRO_BATCH_TOKEN_BUDGET", "")
    if val:
        with contextlib.suppress(ValueError):
            return max(int(val), 1)
    return _BATCH_TOKEN_BUDGET


def batch_grouping() -> str:
    """Batched-engine grouping mode: ``"shape"`` (default) groups cells
    by :func:`repro.core.batched.config_shape_key` — the shape-affecting
    config fields only — so a cutoff × throttle-depth sweep forms ONE
    batch per shape class, with the varying knobs riding as per-row
    config planes. ``$REPRO_BATCH_GROUPING=exact`` restores the legacy
    per-``repr(SimConfig)`` grouping (one group per distinct config),
    kept for A/B measurement in ``bench_batched``."""
    val = os.environ.get("REPRO_BATCH_GROUPING", "shape")
    return "exact" if val == "exact" else "shape"


def batch_workers(requested: Optional[int] = None) -> int:
    """Worker-thread count for the batched engine: the explicit
    ``jobs``/``processes`` argument wins, else ``$REPRO_BATCH_WORKERS``,
    else 1 (serial)."""
    if requested is not None:
        return max(int(requested), 1)
    val = os.environ.get("REPRO_BATCH_WORKERS", "")
    if val:
        with contextlib.suppress(ValueError):
            return max(int(val), 1)
    return 1


class _PlaneMeter:
    """High-water mark of concurrently-live stacked token-plane bytes.

    Chunk streaming only helps if the freed planes actually bound the
    footprint, so every worker registers its engine's plane on build and
    releases it after reduce; the peak is reported in the run's perf."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.cur = 0
        self.peak = 0

    def add(self, n: int) -> None:
        with self._lock:
            self.cur += n
            if self.cur > self.peak:
                self.peak = self.cur

    def sub(self, n: int) -> None:
        with self._lock:
            self.cur -= n


# per-thread handle for the compat shim below; the perf dict itself is
# per-run (returned by _run_cells_batched), so concurrent run_grid calls
# in different threads no longer race on a mutated module global
_TLS = threading.local()


def last_batched_perf() -> Dict[str, float]:
    """Breakdown of this thread's most recent batched ``run_grid``
    (empty if none ran). Compat shim over the per-run perf dict —
    keys:

    * ``group_build_s`` — workload load + sweep flattening + chunking
    * ``engine_build_s`` — state stacking inside BatchedSMEngine
    * ``stepper_s`` / ``drain_s`` — in-stepper vs pause-drain time
      (summed across workers, so with ``jobs > 1`` they exceed wall)
    * ``rounds`` / ``batches`` / ``chunks`` — loop + chunking counts
    * ``groups`` — config groups formed (shape classes under the
      default grouping; distinct configs under
      ``$REPRO_BATCH_GROUPING=exact``)
    * ``workers`` — thread-pool width used
    * ``peak_token_plane_bytes`` — high-water mark of concurrently
      live stacked token planes (the streaming memory bound)
    """
    perf = getattr(_TLS, "batched_perf", None)
    return dict(perf) if perf else {}


def _shard_chunks(chunks: List[Tuple], workers: int) -> List[Tuple]:
    """Split oversized chunks so at least ``workers`` chunks exist (when
    the cell count allows): a grid that chunked into fewer batches than
    workers would leave cores idle. Halving the largest chunk at a cell
    boundary is *exact* — cells in a batch never share planes with each
    other (each cell carries its own hierarchy; multi-SM rows only share
    planes within their own cell), so any partition of a batch runs the
    identical per-cell program."""
    if workers <= 1:
        return chunks
    out = list(chunks)
    while len(out) < workers:
        k = max(range(len(out)), key=lambda n: len(out[n][2]))
        cfg, gpu, chunk = out[k]
        if len(chunk) < 2:
            break
        mid = len(chunk) // 2
        out[k] = (cfg, gpu, chunk[:mid])
        out.insert(k + 1, (cfg, gpu, chunk[mid:]))
    return out


def _backend_ladder(backend: Optional[str]) -> List[str]:
    """The degradation ladder for one requested backend: the rungs a
    failing chunk walks down before the per-cell scalar fallback. Every
    rung is bit-exact vs every other (pinned by the golden and engine-
    equality suites), so degrading a chunk cannot change its records —
    only its speed."""
    from repro.core import _cstep
    have_c = _cstep.available()
    if backend in (None, "auto"):
        return (["c"] if have_c else []) + ["numpy"]
    if backend == "jax":
        return ["jax"] + (["c"] if have_c else []) + ["numpy"]
    if backend == "c":
        return ["c", "numpy"]
    return [backend]


def _failed_cell(cell: _Cell, exc: BaseException, attempts: int,
                 trail: Sequence[str], truncated: bool = False
                 ) -> FailedCell:
    return FailedCell(
        grid=cell.grid, workload=cell.workload, policy=cell.policy,
        variant=cell.variant,
        num_sms=(cell.gpu.num_sms if cell.gpu is not None else 1),
        seed=cell.seed, scale=cell.scale,
        error=str(exc), error_type=type(exc).__name__,
        attempts=attempts, backends=list(trail), truncated=truncated)


@dataclasses.dataclass
class _Coop:
    """Cooperative multi-worker execution state (``run_grid(...,
    coordinate=True)``): this worker's identity, the chunk-lease TTL,
    the heartbeat keeper thread, the poll cadence for chunks leased to
    other workers, and the shared lease counters merged into
    :func:`last_batched_perf` at the end of the run."""
    worker: str
    ttl: float
    keeper: Any
    poll_s: float
    stats: Dict[str, float]


def _cell_fault_key(cell: _Cell) -> str:
    return f"{cell.workload}/{cell.policy}/{cell.variant}"


def _run_cells_batched(cells: Sequence[_Cell],
                       backend: Optional[str] = None,
                       workers: int = 1,
                       strict: bool = False,
                       retries: int = 1,
                       deadline: Optional[float] = None,
                       run_ledger=None,
                       gidx: Optional[Sequence[int]] = None,
                       chunk_budget: Optional[float] = None,
                       coop: Optional[_Coop] = None,
                       ) -> Tuple[List[AnyRecord], Dict[str, float]]:
    """Run batchable cells through the lockstep engine: flatten Best-SWL
    / statPCAL limit sweeps into per-limit subcells, group by (SimConfig,
    GPU shape), chunk groups under a token-plane memory budget, run each
    chunk as one batch, and reduce the sweeps back (first-best on ties,
    exactly like ``run_policy_sweep`` / ``run_gpu_policy_sweep``).

    ``backend`` overrides ``$REPRO_BATCHED_BACKEND`` (the engine's
    stepper choice). ``"jax"`` applies to single-SM chunks only;
    multi-SM chunks silently fall back to ``"auto"`` — the jax stepper
    does not interleave SM phases over shared post-L1 planes yet.

    ``workers > 1`` dispatches chunks to a thread pool. The C stepper
    calls ``step_cells`` via ctypes, which releases the GIL, so threads
    scale across cores with zero pickling; each chunk's token planes are
    stacked inside its worker (streaming) and freed once its results are
    extracted, so memory stays bounded by budget × workers, not grid
    size. Chunks launch largest-first (LPT) but records are reassembled
    by cell index, so output is byte-identical to the serial order at
    any worker count. Returns ``(records, perf)``.

    **Fault isolation** (``strict=False``): each chunk executes behind
    per-future error capture. A failing chunk is retried ``retries``
    times on its first backend, then walks the degradation ladder
    (jax → C → numpy — all bit-exact, so records are unaffected), then
    falls back to per-cell scalar execution; cells that still fail are
    quarantined as :class:`FailedCell` entries while the rest of the
    sweep completes. ``strict=True`` restores the fail-fast raise.
    ``deadline`` (absolute ``time.monotonic()``) cancels chunks that
    have not started and truncates running ones mid-flight; their cells
    come back as ``FailedCell(truncated=True)``. ``run_ledger`` saves a
    shard per fully-successful chunk (keyed by the global cell ids in
    ``gidx``) and skips chunks whose shard already exists.

    ``chunk_budget`` bounds each *chunk's* wall clock (seconds, not an
    absolute time like ``deadline``): a chunk that blows its budget is
    not truncated but **re-sharded** — split at cell boundaries into
    child chunks (recorded in the ledger's ``resplits/`` so resumed or
    cooperating workers adopt the same plan) that re-enter the queue,
    so chronically slow chunks converge to single cells instead of
    starving the run. Uses the same bounded-cycle quantum slicing as
    ``deadline``. ``coop`` (built by ``run_grid(coordinate=True)``)
    makes chunk execution lease-based: each chunk is claimed in the
    ledger before running, heartbeated while running, and released
    after its shard lands; chunks leased to other live workers are
    polled until their shard appears or their lease expires (takeover).
    """
    import time as _time

    from repro.core.batched import (BatchCell, BatchedSMEngine,
                                    DeadlineExceeded, config_shape_key)
    if backend is None:
        backend = os.environ.get("REPRO_BATCHED_BACKEND", "auto")
    if backend == "jax":
        workers = 1          # one XLA dispatch queue; threads just queue
    if gidx is None:
        gidx = list(range(len(cells)))
    perf: Dict[str, float] = dict(
        group_build_s=0.0, engine_build_s=0.0, stepper_s=0.0,
        drain_s=0.0, rounds=0.0, batches=0.0, chunks=0.0, groups=0.0,
        workers=float(workers), peak_token_plane_bytes=0.0,
        retries=0.0, fallback_cells=0.0, failed_cells=0.0,
        truncated_cells=0.0, chunks_resumed=0.0, shard_errors=0.0,
        resplit_chunks=0.0)
    t0 = _time.perf_counter()
    grouping = batch_grouping()
    # (cell index, limit ordinal, BatchCell); grouped by shape class
    # (config_shape_key) by default — knobs that differ within a group
    # ride as per-row config planes — or by exact config repr when
    # $REPRO_BATCH_GROUPING=exact
    groups: Dict[Any, List[Tuple[int, int, BatchCell]]] = {}
    for i, cell in enumerate(cells):
        wl = _cached_workload(cell.workload,
                              workload_seed(cell.seed, cell.workload),
                              cell.scale)
        cfg = cell.cfg if cell.cfg is not None else SimConfig()
        if grouping == "shape":
            key = config_shape_key(cfg, cell.gpu)
        else:
            key = (repr(cell.cfg) if cell.cfg is not None else "default",
                   repr(cell.gpu))
        sub = groups.setdefault(key, [])
        if cell.policy in ("best-swl", "statpcal"):
            limits = ([wl.n_wrp] if getattr(wl, "n_wrp", 0)
                      else list(cell.best_swl_limits))
            # per-limit subcells share the parent cfg object — the limit
            # lives in policy kwargs, not a cloned SimConfig
            for j, lim in enumerate(limits):
                sub.append((i, j, BatchCell(wl, cell.policy,
                                            {"limit": lim}, cfg=cfg)))
        else:
            sub.append((i, 0, BatchCell(wl, cell.policy, cfg=cfg)))
    perf["groups"] = float(len(groups))
    chunks = []
    for key, sub in groups.items():
        first = cells[sub[0][0]]
        for chunk in _chunk_batch(sub, first.gpu):
            chunks.append((first.cfg, first.gpu, chunk))
    chunks = _shard_chunks(chunks, workers)
    perf["chunks"] = float(len(chunks))
    # LPT order: start the biggest chunks first so the tail of the run
    # is short chunks, not one straggler. Determinism is unaffected —
    # results merge by (cell index, limit ordinal) below.
    order = sorted(range(len(chunks)),
                   key=lambda n: (-len(chunks[n][2]), n))
    perf["group_build_s"] += _time.perf_counter() - t0

    meter = _PlaneMeter()

    def _item_id(t) -> str:
        return f"{gidx[t[0]]}:{t[1]}"

    def _key_of(chunk):
        # content-addressed ledger key (global cell ids, so a resume
        # with a different worker count / chunk plan still matches what
        # it can)
        return (_ledger.chunk_key([_item_id(t) for t in chunk])
                if run_ledger is not None else None)

    def _fkey_of(chunk):
        # human-readable fault key for $REPRO_FAULT_PLAN targeting
        return ",".join(sorted({_cell_fault_key(cells[i])
                                for i, _, _ in chunk}))

    chunk_keys = [_key_of(chunk) for _, _, chunk in chunks]
    fault_keys = [_fkey_of(chunk) for _, _, chunk in chunks]
    local_of = {g: i for i, g in enumerate(gidx)}

    # adopt recorded budget resplits: chunks a previous (or concurrent)
    # worker split are replaced by the same children, so every worker's
    # plan converges on identical content-addressed keys. Child item
    # order is canonical (sorted ids) so duplicate executions write
    # byte-identical shards.
    if run_ledger is not None:
        saved = run_ledger.load_resplits()
        examine = collections.deque(range(len(chunks))) if saved else ()
        while examine:
            n = examine.popleft()
            kid_ids = saved.get(chunk_keys[n])
            if not kid_ids or len(kid_ids) < 2:
                continue          # a real split always has ≥2 children
            cfg, gpu, chunk = chunks[n]
            by_id = {_item_id(t): t for t in chunk}
            ids_flat = [cid for kid in kid_ids for cid in kid]
            if (len(ids_flat) != len(set(ids_flat))
                    or set(ids_flat) != set(by_id)):
                continue          # malformed/foreign record: run whole
            kids = [[by_id[cid] for cid in sorted(kid)]
                    for kid in kid_ids]
            chunks[n] = (cfg, gpu, kids[0])
            chunk_keys[n] = _key_of(kids[0])
            fault_keys[n] = _fkey_of(kids[0])
            examine.append(n)
            for kid in kids[1:]:
                chunks.append((cfg, gpu, kid))
                chunk_keys.append(_key_of(kid))
                fault_keys.append(_fkey_of(kid))
                examine.append(len(chunks) - 1)
        perf["chunks"] = float(len(chunks))
        order = sorted(range(len(chunks)),
                       key=lambda n: (-len(chunks[n][2]), n))

    def _resume_chunk(n: int):
        """("resumed", triples, recs) from the ledger shard, or None."""
        if run_ledger is None:
            return None
        items = run_ledger.load_chunk(chunk_keys[n])
        if items is None:
            return None
        triples, recs = [], []
        try:
            for it in items:
                i = local_of[it["i"]]
                if it["kind"] == "record":
                    recs.append((i, RunRecord(**it["rec"])))
                else:
                    triples.append((i, int(it["j"]),
                                    _ledger.doc_to_result(it)))
        except (KeyError, TypeError, ValueError):
            return None            # stale/foreign shard: just re-run
        return ("resumed", triples, recs)

    def _save_shard(n: int, items: List[dict]) -> None:
        """Best-effort: a shard that fails to write costs a re-run on
        resume, never the run itself."""
        if run_ledger is None:
            return
        try:
            run_ledger.save_chunk(chunk_keys[n], items)
        except Exception:
            perf["shard_errors"] += 1

    def _split_chunk(chunk):
        """Deterministic halving for budget resplits: at cell
        boundaries when the chunk spans several cells, at subcell
        boundaries for a single sweep cell; ``None`` for a single item
        (nothing smaller to converge to). Children use canonical
        (sorted-id) item order, matching the plan-time reapplication
        above, so duplicate executions write byte-identical shards."""
        cell_is = sorted({i for i, _, _ in chunk})
        if len(cell_is) >= 2:
            head = set(cell_is[:len(cell_is) // 2])
            kids = ([t for t in chunk if t[0] in head],
                    [t for t in chunk if t[0] not in head])
        elif len(chunk) >= 2:
            kids = (chunk[:len(chunk) // 2], chunk[len(chunk) // 2:])
        else:
            return None
        return [sorted(kid, key=_item_id) for kid in kids]

    def _exec_chunk(n: int, cfg, gpu, chunk, cell_is):
        be = ("auto" if (backend == "jax" and gpu is not None)
              else backend)
        ladder = _backend_ladder(be)
        attempts = 0
        trail: List[str] = []
        budget = chunk_budget
        for rung_no, rung in enumerate(ladder):
            # transient failures are retried on the first rung before
            # degrading; later rungs get one attempt each
            slots = retries + 1 if rung_no == 0 else 1
            while slots > 0:
                slots -= 1
                attempts += 1
                trail.append(rung)
                try:
                    faults.fire("chunk.dispatch", key=fault_keys[n])
                    eng = BatchedSMEngine([bc for _, _, bc in chunk],
                                          cfg, backend=rung, gpu=gpu)
                    nbytes = int(eng.toks.nbytes)
                    meter.add(nbytes)
                    try:
                        dl = deadline
                        if budget is not None:
                            cut = _time.monotonic() + budget
                            dl = cut if dl is None else min(dl, cut)
                        triples = [(i, j, res) for (i, j, _), res
                                   in zip(chunk, eng.run(deadline=dl))]
                        eperf = dict(eng.perf)
                    finally:
                        meter.sub(nbytes)
                    # eng (and its stacked planes) dies here — streaming
                    _save_shard(n, [
                        dict(_ledger.result_to_doc(res), i=gidx[i], j=j)
                        for i, j, res in triples])
                    return ("ok", triples, eperf, attempts, trail)
                except DeadlineExceeded:
                    if deadline is not None \
                            and _time.monotonic() >= deadline:
                        return ("truncated", cell_is, attempts, trail)
                    # the chunk blew its own wall-clock budget: split it
                    # so stragglers converge instead of starving the run
                    kids = _split_chunk(chunk)
                    if kids is None:
                        # single item — run it unbudgeted; the probe
                        # attempt is not charged as a retry
                        budget = None
                        slots += 1
                        attempts -= 1
                        trail.pop()
                        continue
                    faults.fire("chunk.resplit", key=fault_keys[n])
                    if run_ledger is not None:
                        try:
                            run_ledger.save_resplit(
                                chunk_keys[n],
                                [[_item_id(t) for t in kid]
                                 for kid in kids])
                        except Exception:
                            perf["shard_errors"] += 1
                    perf["resplit_chunks"] += 1
                    return ("resplit", n, kids)
                except Exception:
                    if strict:
                        raise
        # every engine rung failed: per-cell scalar fallback, the one
        # path that needs no batched stepper at all
        trail = trail + ["scalar"]
        recs, fails = [], []
        for i in cell_is:
            cell = cells[i]
            try:
                faults.fire("cell.run", key=_cell_fault_key(cell))
                recs.append((i, _run_cell(cell)))
            except DeadlineExceeded:
                fails.append((i, _failed_cell(
                    cell, RuntimeError("wall-clock deadline exceeded"),
                    attempts + 1, trail, truncated=True)))
            except Exception as exc:
                fails.append((i, _failed_cell(cell, exc, attempts + 1,
                                              trail)))
        return ("fallback", recs, fails, attempts, trail)

    def _run_chunk(n: int):
        cfg, gpu, chunk = chunks[n]
        resumed = _resume_chunk(n)
        if resumed is not None:
            return resumed
        cell_is = sorted({i for i, _, _ in chunk})
        if deadline is not None and _time.monotonic() >= deadline:
            return ("truncated", cell_is, 0, [])
        lease = None
        if coop is not None:
            lease = run_ledger.claim_lease(chunk_keys[n], coop.worker,
                                           coop.ttl)
            if lease is None:
                coop.stats["lease_conflicts"] += 1
                return ("leased", n)
            coop.stats["lease_claims"] += 1
            if lease.get("takeover_of"):
                coop.stats["lease_takeovers"] += 1
            # deterministic crash site: a `raise` here dies holding the
            # lease — exactly what a SIGKILLed worker leaves behind
            faults.fire("worker.exit", key=fault_keys[n])
            coop.keeper.add(chunk_keys[n], lease)
        try:
            out = _exec_chunk(n, cfg, gpu, chunk, cell_is)
        finally:
            if lease is not None:
                coop.keeper.remove(chunk_keys[n])
        if lease is not None:
            # released on *any* tagged outcome (the shard — when one was
            # earned — is already on disk); an exception above skips
            # this, leaving the lease to expire like a real crash
            run_ledger.release_lease(chunk_keys[n], lease)
        return out

    chunks_mu = threading.Lock()

    def _register_children(parent_n: int, kids) -> List[int]:
        cfg, gpu, _ = chunks[parent_n]
        new = []
        with chunks_mu:
            for kid in kids:
                chunks.append((cfg, gpu, kid))
                chunk_keys.append(_key_of(kid))
                fault_keys.append(_fkey_of(kid))
                new.append(len(chunks) - 1)
            perf["chunks"] = float(len(chunks))
        return new

    outs: List[Tuple] = []
    waiting: List[int] = []   # chunks leased to other live workers

    def _collect(out) -> List[int]:
        """Main-thread result triage; returns chunk indices to
        (re)queue — a resplit chunk's children."""
        if out[0] == "resplit":
            return _register_children(out[1], out[2])
        if out[0] == "leased":
            waiting.append(out[1])
            return []
        outs.append(out)
        return []

    if workers > 1 and len(chunks) > 1:
        from concurrent.futures import FIRST_COMPLETED
        from concurrent.futures import wait as _fwait
        with ThreadPoolExecutor(max_workers=workers) as pool:
            futs = {pool.submit(_run_chunk, n) for n in order}
            while futs:
                done, futs = _fwait(futs,
                                    return_when=FIRST_COMPLETED)
                requeue: List[int] = []
                for f in done:
                    requeue.extend(_collect(f.result()))
                futs |= {pool.submit(_run_chunk, n) for n in requeue}
    else:
        queue = collections.deque(order)
        while queue:
            queue.extend(_collect(_run_chunk(queue.popleft())))

    # cooperative wait loop: poll chunks leased to other workers until
    # their shard lands (resumed), their lease expires (takeover — the
    # claim inside _run_chunk succeeds), or the deadline passes
    while waiting:
        if deadline is not None and _time.monotonic() >= deadline:
            for n in waiting:
                outs.append(("truncated",
                             sorted({i for i, _, _ in chunks[n][2]}),
                             0, []))
            waiting = []
            break
        progressed = False
        queue = collections.deque(waiting)
        waiting = []
        while queue:
            out = _run_chunk(queue.popleft())
            if out[0] == "leased":
                waiting.append(out[1])
            elif out[0] == "resplit":
                queue.extend(_register_children(out[1], out[2]))
                progressed = True
            else:
                outs.append(out)
                progressed = True
        if waiting and not progressed:
            coop.stats["lease_wait_s"] += coop.poll_s
            _time.sleep(coop.poll_s)

    results: Dict[int, List] = {}
    rec_map: Dict[int, RunRecord] = {}
    fail_map: Dict[int, FailedCell] = {}
    for out in outs:
        kind = out[0]
        if kind == "ok":
            _, triples, eperf, attempts, _ = out
            for i, j, res in triples:
                results.setdefault(i, []).append((j, res))
            perf["engine_build_s"] += eperf["build_s"]
            perf["stepper_s"] += eperf["stepper_s"]
            perf["drain_s"] += eperf["drain_s"]
            perf["rounds"] += eperf["rounds"]
            perf["batches"] += 1
            perf["retries"] += attempts - 1
        elif kind == "resumed":
            _, triples, recs = out
            for i, j, res in triples:
                results.setdefault(i, []).append((j, res))
            rec_map.update(recs)
            perf["chunks_resumed"] += 1
        elif kind == "fallback":
            _, recs, fails, attempts, _ = out
            rec_map.update(recs)
            fail_map.update(fails)
            perf["retries"] += attempts - 1
            perf["fallback_cells"] += len(recs) + len(fails)
        else:                                  # truncated
            _, cell_is, attempts, trail = out
            perf["retries"] += max(attempts - 1, 0)
            for i in cell_is:
                fail_map[i] = _failed_cell(
                    cells[i],
                    RuntimeError("wall-clock deadline exceeded"),
                    attempts, trail, truncated=True)
    perf["failed_cells"] = float(len(fail_map))
    perf["truncated_cells"] = float(
        sum(1 for f in fail_map.values() if f.truncated))
    perf["peak_token_plane_bytes"] = float(meter.peak)

    t0 = _time.perf_counter()
    records: List[AnyRecord] = []
    for i, cell in enumerate(cells):
        # priority: quarantined failure > whole-cell fallback/resumed
        # record > sweep reduce of the batched subcell results. A cell
        # whose subcells were split across chunks can carry both partial
        # triples and a whole-cell record — the record is the complete
        # answer (scalar == batched is pinned by the equality suite)
        if i in fail_map:
            records.append(fail_map[i])
            continue
        if i in rec_map:
            records.append(rec_map[i])
            continue
        sweep = sorted(results[i])
        best = None
        for _, res in sweep:
            if best is None or res.ipc > best.ipc:
                best = res
        wl = _cached_workload(cell.workload,
                              workload_seed(cell.seed, cell.workload),
                              cell.scale)
        if cell.gpu is not None:
            records.append(RunRecord(
                grid=cell.grid, workload=cell.workload, klass=wl.klass,
                policy=cell.policy, variant=cell.variant,
                num_sms=cell.gpu.num_sms, seed=cell.seed,
                scale=cell.scale,
                ipc=best.ipc, cycles=best.cycles,
                instructions=best.instructions,
                l1_hit_rate=best.l1_hit_rate, vta_hits=best.vta_hits,
                mean_active_warps=best.mean_active_warps,
                stats=dict(best.mem_stats),
                per_sm_ipc=[r.ipc for r in best.per_sm]))
        else:
            records.append(RunRecord(
                grid=cell.grid, workload=cell.workload, klass=wl.klass,
                policy=cell.policy, variant=cell.variant, num_sms=1,
                seed=cell.seed, scale=cell.scale,
                ipc=best.ipc, cycles=best.cycles,
                instructions=best.instructions,
                l1_hit_rate=best.l1_hit_rate, vta_hits=best.vta_hits,
                mean_active_warps=best.mean_active_warps,
                stats=dict(best.stats),
                pairs=[list(p) for p in best.pairs]))
    perf["group_build_s"] += _time.perf_counter() - t0
    return records, perf


def _chunk_batch(sub: Sequence[Tuple],
                 gpu: Optional[GPUConfig] = None) -> List[List[Tuple]]:
    """Split one config group into engine-sized chunks: the stacked
    token plane (unique workloads × num_warps × longest stream; one
    slice per SM for multi-SM groups) stays under
    :func:`batch_token_budget` and chunks hold at most
    ``_BATCH_MAX_CELLS`` cells. Cells arrive in grid order, so
    same-workload cells stay contiguous and padding stays tight."""
    budget = batch_token_budget()
    sm_factor = gpu.num_sms if gpu is not None else 1
    chunks: List[List[Tuple]] = []
    cur: List[Tuple] = []
    uniq: set = set()
    max_len = 1
    for item in sub:
        wl = item[2].workload
        wid = id(wl)
        new_uniq = uniq | {wid}
        new_len = max(max_len,
                      max((len(k) for k, _ in wl.traces), default=1))
        est = len(new_uniq) * len(wl.traces) * new_len * 8 * sm_factor
        if cur and (len(cur) >= _BATCH_MAX_CELLS
                    or est > budget):
            chunks.append(cur)
            cur, uniq, max_len = [], set(), 1
            new_uniq = {wid}
            new_len = max((len(k) for k, _ in wl.traces), default=1)
        cur.append(item)
        uniq = new_uniq
        max_len = new_len
    if cur:
        chunks.append(cur)
    return chunks


def _run_cell_safe(cell: _Cell):
    """Spawn-pool-safe guarded cell execution: returns a tagged tuple
    instead of raising, so one broken cell cannot kill the pool map.
    (Top-level so it pickles; the fault plan reaches workers through
    ``$REPRO_FAULT_PLAN`` in the inherited environment.)"""
    try:
        faults.fire("cell.run", key=_cell_fault_key(cell))
        return ("ok", _run_cell(cell))
    except Exception as exc:
        return ("err", type(exc).__name__, str(exc))


# process-unique sequence for auto-generated run ids ($REPRO_RUN_LEDGER)
_RUN_SEQ = itertools.count()


def _auto_run_id(grid: ExperimentGrid, ghash: str) -> str:
    return f"{grid.name}-{ghash[:10]}-p{os.getpid()}-{next(_RUN_SEQ)}"


def run_grid(grid: ExperimentGrid, processes: Optional[int] = None,
             json_path: Optional[str] = None,
             engine: str = "auto",
             jobs: Optional[int] = None,
             strict: bool = False,
             retries: int = 1,
             deadline_s: Optional[float] = None,
             run_id: Optional[str] = None,
             resume: Optional[str] = None,
             chunk_budget_s: Optional[float] = None,
             coordinate: bool = False,
             lease_ttl_s: Optional[float] = None,
             worker: Optional[str] = None,
             heartbeat_fatal: bool = False) -> List[AnyRecord]:
    """Run every cell; see the module docstring for the three engines.
    ``jobs`` (preferred name; ``processes`` is the legacy alias) sets
    the parallelism: the batched engine fans chunks over that many
    worker *threads* (the ctypes stepper releases the GIL), while the
    process engine — and any cells the batched engine cannot take —
    fans over a spawn pool of that many workers. Records come back in
    grid order and bit-identical regardless of execution order, engine,
    or worker count.

    Resilience (see also the README's "Resilience & fault injection"):

    * ``strict=False`` (default) fault-isolates execution — failing
      chunks retry ``retries`` times, degrade down the backend ladder,
      then fall back per cell; cells that still fail come back as
      :class:`FailedCell` entries instead of an exception.
      ``strict=True`` restores fail-fast raising.
    * ``deadline_s`` bounds the run's wall clock: the steppers slice
      their run-to-completion calls into bounded-cycle quanta, pending
      chunks are cancelled once the deadline passes, and unfinished
      cells return ``FailedCell(truncated=True)`` — resumable.
    * ``run_id`` opens a run ledger under ``results/runs/<run_id>/``
      (checkpoint shards per completed chunk); ``resume=<run_id>``
      reopens one and re-runs only the chunks without shards, yielding
      records bit-identical to an uninterrupted run. Setting
      ``$REPRO_RUN_LEDGER=1`` auto-ledgers every run under a generated
      id (a crash flight recorder).
    * ``chunk_budget_s`` bounds each chunk's wall clock: a chunk that
      exceeds it is **re-sharded** at cell boundaries into child chunks
      that re-enter the queue (and are recorded in the ledger so
      resumes/co-workers adopt the same plan) — stragglers converge to
      single cells instead of starving the run or being truncated.
    * ``coordinate=True`` (requires ``run_id``/``resume``) makes this
      process one of N cooperating workers draining the same run:
      chunks are claimed via ledger leases (TTL ``lease_ttl_s``,
      default ``$REPRO_LEASE_TTL`` or 30s), heartbeated while running,
      and reclaimed from crashed workers once their lease expires.
      Records stay bit-identical to a serial run regardless of worker
      count, crashes, or duplicate completions (see the ledger module
      docstring). ``worker`` names this worker (default
      ``<hostname>-<pid>``); ``heartbeat_fatal=True`` (the
      ``python -m repro.runs work`` entrypoint sets it) turns a failed
      or stolen heartbeat into immediate worker death (exit 70) so a
      wedged worker cannot double-spend a reclaimed chunk's time.
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; one of {ENGINES}")
    if engine == "jax":
        from repro.core import jax_backend
        if not jax_backend.available():
            raise RuntimeError("engine='jax' requested but "
                               + jax_backend.unavailable_reason())
    if jobs is None:
        jobs = processes
    if resume is not None:
        if run_id is not None and run_id != resume:
            raise ValueError(f"run_id={run_id!r} conflicts with "
                             f"resume={resume!r}")
        run_id = resume
    ghash = _ledger.grid_hash(grid)
    if run_id is None and os.environ.get("REPRO_RUN_LEDGER", ""):
        run_id = _auto_run_id(grid, ghash)
    if coordinate and run_id is None:
        raise ValueError("coordinate=True requires run_id= or resume= "
                         "— cooperating workers meet at a ledger")
    led = None
    if run_id is not None:
        led = _ledger.RunLedger(run_id)
        # cooperating workers must never wipe each other's shards: a
        # coordinate open of an existing run always resumes it
        led.open({"grid_hash": ghash, "grid": _grid_meta(grid),
                  "grid_doc": grid_to_doc(grid),
                  "engine": engine, "jobs": jobs, "strict": strict,
                  "cells": len(expand_grid(grid))},
                 resume=(resume is not None
                         or (coordinate and led.manifest_path.exists())))
    coop = None
    if coordinate:
        ttl = (float(lease_ttl_s) if lease_ttl_s is not None
               else _ledger.lease_ttl())
        wid = worker or _ledger.worker_id()
        on_fatal = None
        if heartbeat_fatal:
            def on_fatal(reason: str) -> None:
                import sys
                print(f"# worker {wid}: fatal: {reason}",
                      file=sys.stderr, flush=True)
                os._exit(70)
        keeper = _ledger.LeaseKeeper(led, ttl, on_fatal=on_fatal)
        keeper.start()
        coop = _Coop(worker=wid, ttl=ttl, keeper=keeper,
                     poll_s=min(max(ttl / 4.0, 0.05), 1.0),
                     stats=dict(lease_claims=0.0, lease_conflicts=0.0,
                                lease_takeovers=0.0, lease_wait_s=0.0))
    deadline = (time.monotonic() + deadline_s
                if deadline_s is not None else None)
    cells = expand_grid(grid)
    records: List[Optional[AnyRecord]] = [None] * len(cells)
    batched_ran = False
    if engine != "process":
        batch_idx = [i for i, c in enumerate(cells) if _batchable(c)]
        if engine in ("batched", "jax") \
                or len(batch_idx) >= AUTO_MIN_BATCH:
            try:
                recs, perf = _run_cells_batched(
                    [cells[i] for i in batch_idx],
                    backend="jax" if engine == "jax" else None,
                    workers=batch_workers(jobs),
                    strict=strict, retries=retries, deadline=deadline,
                    run_ledger=led, gidx=batch_idx,
                    chunk_budget=chunk_budget_s, coop=coop)
            except BaseException:
                # a strict-mode fault must not leak the heartbeat thread
                if coop is not None:
                    coop.keeper.stop()
                raise
            _TLS.batched_perf = perf
            batched_ran = True
            for i, rec in zip(batch_idx, recs):
                records[i] = rec
    rest = [i for i in range(len(cells)) if records[i] is None]
    if rest and led is not None:
        # per-cell shards for the scalar/process path
        still = []
        for i in rest:
            items = led.load_chunk(_ledger.chunk_key([f"cell:{i}"]))
            rec = _rest_shard_to_record(items)
            if rec is not None:
                records[i] = rec
            else:
                still.append(i)
        rest = still
    if rest and coop is not None:
        try:
            rest = _run_rest_coop(cells, rest, records, led, coop,
                                  deadline, strict)
        except BaseException:
            coop.keeper.stop()
            raise
    if rest and deadline is not None and time.monotonic() >= deadline:
        for i in rest:
            records[i] = _failed_cell(
                cells[i], RuntimeError("wall-clock deadline exceeded"),
                0, [], truncated=True)
        rest = []
    if rest:
        nproc = min(jobs or 1, len(rest))
        runner = _run_cell if strict else _run_cell_safe
        if nproc > 1:
            ctx = multiprocessing.get_context("spawn")
            with ctx.Pool(nproc) as pool:
                rest_out = pool.map(runner, [cells[i] for i in rest])
        else:
            rest_out = [runner(cells[i]) for i in rest]
        for i, out in zip(rest, rest_out):
            records[i] = _rest_out_to_record(cells[i], out, strict)
            if led is not None and isinstance(records[i], RunRecord):
                _save_rest_shard(led, i, records[i])
    if coop is not None:
        coop.keeper.stop()
        coop.keeper.join(timeout=5.0)
        merged = (dict(getattr(_TLS, "batched_perf", None) or {})
                  if batched_ran else {})
        merged.update(coop.stats)
        merged.update({k: float(v)
                       for k, v in coop.keeper.stats().items()})
        _TLS.batched_perf = merged
    if led is not None:
        failed = [r for r in records if isinstance(r, FailedCell)]
        status = ("truncated" if any(f.truncated for f in failed)
                  else "partial" if failed else "complete")
        led.finish(status)
    if json_path:
        save_records(records, json_path, grid=grid)
    return records


def _rest_out_to_record(cell: _Cell, out, strict: bool) -> AnyRecord:
    """Normalize a scalar-path execution outcome (a record in strict
    mode, a ``_run_cell_safe`` tagged tuple otherwise) to a record."""
    if strict:
        return out
    if out[0] == "ok":
        return out[1]
    return FailedCell(
        grid=cell.grid, workload=cell.workload, policy=cell.policy,
        variant=cell.variant,
        num_sms=(cell.gpu.num_sms if cell.gpu else 1),
        seed=cell.seed, scale=cell.scale,
        error=out[2], error_type=out[1], attempts=1,
        backends=["scalar"])


def _save_rest_shard(led, i: int, rec: RunRecord) -> None:
    """Best-effort per-cell shard for the scalar/process path."""
    try:
        led.save_chunk(_ledger.chunk_key([f"cell:{i}"]),
                       [{"kind": "record", "i": i,
                         "rec": dataclasses.asdict(rec)}])
    except Exception:
        pass               # best-effort, like the chunk shards


def _run_rest_coop(cells, rest, records, led, coop, deadline,
                   strict: bool) -> List[int]:
    """Cooperative (lease-based) execution of the scalar-path cells:
    claim ``cell:<i>`` leases, run, shard, release; cells leased to
    other live workers are polled until their shard lands or their
    lease expires. Returns the cell indices left unfinished (deadline
    passed) — the caller truncates them."""
    runner = _run_cell if strict else _run_cell_safe
    waiting = list(rest)
    while waiting:
        if deadline is not None and time.monotonic() >= deadline:
            return waiting
        progressed = False
        still = []
        for i in waiting:
            key = _ledger.chunk_key([f"cell:{i}"])
            rec = _rest_shard_to_record(led.load_chunk(key))
            if rec is not None:
                records[i] = rec
                progressed = True
                continue
            lease = led.claim_lease(key, coop.worker, coop.ttl)
            if lease is None:
                coop.stats["lease_conflicts"] += 1
                still.append(i)
                continue
            coop.stats["lease_claims"] += 1
            if lease.get("takeover_of"):
                coop.stats["lease_takeovers"] += 1
            faults.fire("worker.exit", key=_cell_fault_key(cells[i]))
            coop.keeper.add(key, lease)
            try:
                out = runner(cells[i])
            finally:
                coop.keeper.remove(key)
            records[i] = _rest_out_to_record(cells[i], out, strict)
            if isinstance(records[i], RunRecord):
                _save_rest_shard(led, i, records[i])
            led.release_lease(key, lease)
            progressed = True
        waiting = still
        if waiting and not progressed:
            coop.stats["lease_wait_s"] += coop.poll_s
            time.sleep(coop.poll_s)
    return []


def _rest_shard_to_record(items) -> Optional[RunRecord]:
    if not items:
        return None
    try:
        it = items[0]
        if it["kind"] != "record":
            return None
        return RunRecord(**it["rec"])
    except (KeyError, TypeError, ValueError):
        return None


def default_processes() -> int:
    return max(os.cpu_count() or 1, 1)


# ------------------------------------------------------------ persistence
def grid_to_doc(grid: ExperimentGrid) -> dict:
    """Full, *reconstructible* grid serialization, stored in run
    manifests so a ``python -m repro.runs work`` worker can rebuild the
    grid from the ledger alone (contrast :func:`_grid_meta`, a
    human-oriented summary). Round-trips through
    :func:`grid_from_doc` preserving ``grid_hash``."""
    def cfg_doc(cfg: Optional[SimConfig]):
        return dataclasses.asdict(cfg) if cfg is not None else None
    return {
        "name": grid.name,
        "workloads": list(grid.workloads),
        "policies": list(grid.policies),
        "variants": ({k: cfg_doc(v)
                      for k, v in dict(grid.variants).items()}
                     if grid.variants else None),
        "scale": grid.scale,
        "seed": grid.seed,
        "gpu": dataclasses.asdict(grid.gpu) if grid.gpu else None,
        "best_swl_limits": list(grid.best_swl_limits),
    }


def grid_from_doc(doc: Mapping) -> ExperimentGrid:
    from repro.core.simulator import DetectorConfig, OnChipConfig

    def cfg_from(d):
        if d is None:
            return None
        d = dict(d)
        if isinstance(d.get("detector"), dict):
            d["detector"] = DetectorConfig(**d["detector"])
        if isinstance(d.get("onchip"), dict):
            d["onchip"] = OnChipConfig(**d["onchip"])
        return SimConfig(**d)

    variants = doc.get("variants")
    return ExperimentGrid(
        name=doc["name"],
        workloads=list(doc["workloads"]),
        policies=list(doc["policies"]),
        variants=({k: cfg_from(v) for k, v in variants.items()}
                  if variants else None),
        scale=doc.get("scale", 0.5),
        seed=doc.get("seed", 0),
        gpu=GPUConfig(**doc["gpu"]) if doc.get("gpu") else None,
        best_swl_limits=list(doc.get("best_swl_limits",
                                     (2, 4, 6, 8, 16, 32, 48))))


def _grid_meta(grid: ExperimentGrid) -> dict:
    return {
        "name": grid.name,
        "workloads": list(grid.workloads),
        "policies": list(grid.policies),
        "variants": list(dict(grid.variants).keys()) if grid.variants else
                    [BASE_VARIANT],
        "scale": grid.scale,
        "seed": grid.seed,
        "num_sms": grid.gpu.num_sms if grid.gpu else 1,
    }


def _record_to_doc(r: AnyRecord) -> dict:
    d = dataclasses.asdict(r)
    if isinstance(r, FailedCell):
        d["failed"] = True
    return d


def _doc_to_record(d: dict) -> AnyRecord:
    d = dict(d)
    if d.pop("failed", False):
        return FailedCell(**d)
    return RunRecord(**d)


def save_records(records: Sequence[AnyRecord], path: str,
                 grid: Optional[ExperimentGrid] = None) -> str:
    """Atomic JSON persistence (unique temp + fsync + ``os.replace``):
    an interrupted run never leaves a torn ``results/*.json`` — readers
    see the old complete file or the new complete file, nothing in
    between. Quarantined :class:`FailedCell` entries persist alongside
    ``RunRecord`` rows with a ``"failed": true`` marker."""
    faults.fire("records.save", key=str(path), path=None)
    doc = {"schema": SCHEMA_VERSION,
           "grid": _grid_meta(grid) if grid else None,
           "records": [_record_to_doc(r) for r in records]}
    p = pathlib.Path(path)
    _ledger._atomic_write(p, json.dumps(doc, indent=1, sort_keys=True))
    return str(p)


def load_records(path: str) -> List[AnyRecord]:
    doc = json.loads(pathlib.Path(path).read_text())
    if doc.get("schema") != SCHEMA_VERSION:
        raise ValueError(
            f"unsupported results schema {doc.get('schema')!r} in {path}")
    return [_doc_to_record(r) for r in doc["records"]]


# -------------------------------------------------------------- analysis
def index_records(records: Sequence[AnyRecord]
                  ) -> Dict[Tuple[str, str, str], RunRecord]:
    """(workload, policy, variant) -> record. Quarantined
    :class:`FailedCell` entries are skipped — downstream analysis reads
    successful cells only."""
    return {(r.workload, r.policy, r.variant): r for r in records
            if isinstance(r, RunRecord)}


def geomean(values: Sequence[float]) -> float:
    import math
    if not values:
        return 0.0
    return math.exp(sum(math.log(max(v, 1e-9)) for v in values)
                    / len(values))
