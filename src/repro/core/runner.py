"""Unified experiment runner: declarative policy × workload × config grids.

Every benchmark used to hand-roll its own sweep loop around
``SMSimulator``. This module replaces those with one subsystem:

* :class:`ExperimentGrid` — a declarative spec: workload names, policy
  names, named :class:`SimConfig` variants, trace scale, base seed, and an
  optional multi-SM :class:`~repro.core.gpu.GPUConfig`.
* :func:`run_grid` — expands the grid into cells, runs them serially or
  fanned out over a ``multiprocessing`` pool (spawn context, so no JAX
  fork hazards), and returns one :class:`RunRecord` per cell in grid
  order. Workload traces are seeded from ``crc32(grid.seed, workload)``
  only — every policy/variant of a workload sees identical traces, and
  results are bit-identical between serial and parallel execution.
* :func:`save_records` / :func:`load_records` — JSON persistence; a
  reloaded file compares equal (``==``) to the in-memory records.

Best-SWL / statPCAL cells run the paper's offline ``N_wrp`` limit sweep
inside the cell (Table II), exactly like ``run_policy_sweep``.

Example::

    grid = ExperimentGrid(name="fig8", workloads=("syrk", "kmn"),
                          policies=("gto", "ciao-c"))
    records = run_grid(grid, processes=4, json_path="results/fig8.json")
    by = index_records(records)
    rel = by["syrk", "ciao-c", "base"].ipc / by["syrk", "gto", "base"].ipc
"""
from __future__ import annotations

import dataclasses
import functools
import json
import multiprocessing
import os
import pathlib
import zlib
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.gpu import GPUConfig, run_gpu_policy_sweep
from repro.core.simulator import SimConfig, run_policy_sweep
from repro.workloads import WORKLOADS, make_workload

SCHEMA_VERSION = 1
BASE_VARIANT = "base"


@dataclasses.dataclass
class ExperimentGrid:
    name: str
    workloads: Sequence[str]
    policies: Sequence[str]
    # label -> SimConfig; None/empty means a single default-config variant
    variants: Optional[Mapping[str, SimConfig]] = None
    scale: float = 0.5
    seed: int = 0
    gpu: Optional[GPUConfig] = None      # None = single-SM
    best_swl_limits: Sequence[int] = (2, 4, 6, 8, 16, 32, 48)

    def variant_items(self) -> List[Tuple[str, Optional[SimConfig]]]:
        if not self.variants:
            return [(BASE_VARIANT, None)]
        return list(self.variants.items())


@dataclasses.dataclass
class RunRecord:
    """One grid cell's outcome. All fields JSON-round-trip exactly."""
    grid: str
    workload: str
    klass: str
    policy: str
    variant: str
    num_sms: int
    seed: int
    scale: float
    ipc: float
    cycles: int
    instructions: int
    l1_hit_rate: float
    vta_hits: int
    mean_active_warps: float
    stats: Dict[str, int]
    # interference pair events [evictor, victim, count], most frequent
    # first (single-SM only; empty for multi-SM chips)
    pairs: List[List[int]] = dataclasses.field(default_factory=list)
    per_sm_ipc: List[float] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _Cell:
    grid: str
    workload: str
    policy: str
    variant: str
    cfg: Optional[SimConfig]
    scale: float
    seed: int
    gpu: Optional[GPUConfig]
    best_swl_limits: Sequence[int]


def workload_seed(base_seed: int, workload: str) -> int:
    """Deterministic per-workload trace seed, shared by every policy and
    variant so comparisons stay apples-to-apples."""
    return zlib.crc32(f"{base_seed}:{workload}".encode()) & 0x7FFFFFFF


@functools.lru_cache(maxsize=32)
def _cached_workload(name: str, seed: int, scale: float):
    """Per-process workload cache: a grid re-uses one workload across every
    policy × variant cell (trace generation costs ~100ms per workload and
    used to be repeated per cell). Safe to share because nothing mutates
    trace arrays — the simulator compiles its own token streams and the
    GPU model's address-offset copies allocate fresh arrays. Each spawn
    worker keeps its own cache; ``pool.map`` chunks cells in grid order, so
    same-workload cells land contiguously and hit it."""
    return make_workload(name, seed=seed, scale=scale)


def _run_cell(cell: _Cell) -> RunRecord:
    wl = _cached_workload(cell.workload,
                          workload_seed(cell.seed, cell.workload),
                          cell.scale)
    if cell.gpu is not None:
        res = run_gpu_policy_sweep(
            wl, [cell.policy], cfg=cell.cfg, gpu=cell.gpu,
            best_swl_limits=tuple(cell.best_swl_limits))[cell.policy]
        return RunRecord(
            grid=cell.grid, workload=cell.workload, klass=wl.klass,
            policy=cell.policy, variant=cell.variant,
            num_sms=cell.gpu.num_sms, seed=cell.seed, scale=cell.scale,
            ipc=res.ipc, cycles=res.cycles, instructions=res.instructions,
            l1_hit_rate=res.l1_hit_rate, vta_hits=res.vta_hits,
            mean_active_warps=res.mean_active_warps,
            stats=dict(res.mem_stats),
            per_sm_ipc=[r.ipc for r in res.per_sm])
    res = run_policy_sweep(wl, [cell.policy], cfg=cell.cfg,
                           best_swl_limits=tuple(cell.best_swl_limits)
                           )[cell.policy]
    return RunRecord(
        grid=cell.grid, workload=cell.workload, klass=wl.klass,
        policy=cell.policy, variant=cell.variant, num_sms=1,
        seed=cell.seed, scale=cell.scale,
        ipc=res.ipc, cycles=res.cycles, instructions=res.instructions,
        l1_hit_rate=res.l1_hit_rate, vta_hits=res.vta_hits,
        mean_active_warps=res.mean_active_warps, stats=dict(res.stats),
        pairs=[list(p) for p in res.pairs])


def expand_grid(grid: ExperimentGrid) -> List[_Cell]:
    cells = []
    for w in grid.workloads:
        if w not in WORKLOADS:
            raise ValueError(f"unknown workload {w!r}")
        for p in grid.policies:
            for label, cfg in grid.variant_items():
                cells.append(_Cell(
                    grid=grid.name, workload=w, policy=p, variant=label,
                    cfg=cfg, scale=grid.scale, seed=grid.seed,
                    gpu=grid.gpu, best_swl_limits=grid.best_swl_limits))
    return cells


def run_grid(grid: ExperimentGrid, processes: Optional[int] = None,
             json_path: Optional[str] = None) -> List[RunRecord]:
    """Run every cell; ``processes`` > 1 fans out over a spawn pool.
    Records come back in grid order regardless of execution order."""
    cells = expand_grid(grid)
    nproc = min(processes or 1, len(cells))
    if nproc > 1:
        ctx = multiprocessing.get_context("spawn")
        with ctx.Pool(nproc) as pool:
            records = pool.map(_run_cell, cells)
    else:
        records = [_run_cell(c) for c in cells]
    if json_path:
        save_records(records, json_path, grid=grid)
    return records


def default_processes() -> int:
    return max(os.cpu_count() or 1, 1)


# ------------------------------------------------------------ persistence
def _grid_meta(grid: ExperimentGrid) -> dict:
    return {
        "name": grid.name,
        "workloads": list(grid.workloads),
        "policies": list(grid.policies),
        "variants": list(dict(grid.variants).keys()) if grid.variants else
                    [BASE_VARIANT],
        "scale": grid.scale,
        "seed": grid.seed,
        "num_sms": grid.gpu.num_sms if grid.gpu else 1,
    }


def save_records(records: Sequence[RunRecord], path: str,
                 grid: Optional[ExperimentGrid] = None) -> str:
    doc = {"schema": SCHEMA_VERSION,
           "grid": _grid_meta(grid) if grid else None,
           "records": [dataclasses.asdict(r) for r in records]}
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    tmp = p.with_suffix(p.suffix + ".tmp")
    tmp.write_text(json.dumps(doc, indent=1, sort_keys=True))
    tmp.replace(p)
    return str(p)


def load_records(path: str) -> List[RunRecord]:
    doc = json.loads(pathlib.Path(path).read_text())
    if doc.get("schema") != SCHEMA_VERSION:
        raise ValueError(f"unsupported results schema in {path}")
    return [RunRecord(**r) for r in doc["records"]]


# -------------------------------------------------------------- analysis
def index_records(records: Sequence[RunRecord]
                  ) -> Dict[Tuple[str, str, str], RunRecord]:
    """(workload, policy, variant) -> record."""
    return {(r.workload, r.policy, r.variant): r for r in records}


def geomean(values: Sequence[float]) -> float:
    import math
    if not values:
        return 0.0
    return math.exp(sum(math.log(max(v, 1e-9)) for v in values)
                    / len(values))
