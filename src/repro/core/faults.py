"""Deterministic fault injection for the sweep execution layer.

Every recovery path in the runner (retry ladder, backend degradation,
cache regeneration, ledger resume) needs a way to *provoke* the failure
it guards against, deterministically, in tests and in the CI chaos
smoke. This module is that mechanism: a :class:`FaultPlan` names
execution **sites** and fires an **action** on counted **triggers**.

Sites are plain strings fired by the code under test via
:func:`fire`; the ones wired up today:

* ``chunk.dispatch`` — per batched-chunk execution attempt
  (``repro.core.runner._run_cells_batched``); the fault key is the
  sorted ``workload/policy/variant`` set of the chunk's cells.
* ``stepper.step``   — per C-stepper ``step_cells`` call
  (``repro.core._cstep.step``) and per numpy-stepper drain round.
* ``cache.load``     — per on-disk workload-cache read
  (``runner._load_or_make_workload``); ``path`` is the cache file.
* ``records.save``   — per results/ledger JSON write
  (``runner.save_records``, ``ledger.RunLedger.save_chunk``).
* ``cell.run``       — per scalar (per-cell) execution, both the
  spawn-pool path and the batched engine's final fallback rung.
* ``lease.claim``    — per chunk-lease claim attempt in cooperative
  multi-worker runs (``ledger.RunLedger.claim_lease``); ``path`` is
  the lease file.
* ``lease.heartbeat`` — per lease heartbeat
  (``ledger.RunLedger.heartbeat_lease``, fired from the worker's
  ``LeaseKeeper`` thread); with ``runs work``'s fatal handler a
  ``raise`` here kills the worker mid-chunk — the canonical
  "crashed holder" chaos clause.
* ``chunk.resplit``  — when a chunk blows its ``chunk_budget_s`` and
  is about to be split into child chunks (``runner``); a ``raise``
  models dying before the resplit record is published.
* ``worker.exit``    — immediately after a successful lease claim in
  the cooperative chunk path (``runner``); a ``raise`` deterministically
  simulates a worker dying while holding a lease.
* ``serve.admit``    — per admission attempt in the serving engine
  (``serving.engine.ServeEngine._admit``); key is the candidate rid.
* ``serve.preempt``  — per preemption decision
  (``serving.engine.ServeEngine._preempt_youngest``).
* ``serve.page_alloc`` — per mid-decode KV-page allocation in
  ``serving.engine.ServeEngine.step``; a ``raise`` is absorbed as a
  transient allocation failure (the sequence defers/preempts).

Plan grammar (also the ``$REPRO_FAULT_PLAN`` environment variable)::

    plan    := clause (',' clause)*
    clause  := site ['[' keysub ']'] '@' trigger '=' action [':' param]
    trigger := '*' | N | N'+' | N'-'M | '%'K
    action  := 'raise' | 'corrupt' | 'delay'

A clause's counter increments on every :func:`fire` of its site whose
``key`` contains ``keysub`` (no ``[...]`` matches every key). Triggers
are 1-based occurrence counts: ``3`` fires on exactly the third
matching occurrence, ``3+`` from the third on, ``2-4`` on the second
through fourth, ``%4`` on every fourth (25% of occurrences), ``*``
always. Actions: ``raise`` throws :class:`InjectedFault`; ``corrupt``
deterministically garbles the file at the site's ``path`` (truncate to
half + overwrite the head) so the *reader's* integrity checking is
exercised — sites without a path fall back to ``raise``; ``delay:S``
sleeps ``S`` seconds (for deadline tests).

Examples::

    chunk.dispatch@1=raise                  # first dispatch fails once
    chunk.dispatch@%4=raise                 # every 4th dispatch fails
    chunk.dispatch[syrk/ciao-c]@*=raise     # poison chunks with a cell
    cache.load@1=corrupt                    # corrupt 1st cache read
    stepper.step@2=delay:0.05               # stall the 2nd stepper call

**Zero cost when disabled**: with no plan installed, :func:`fire` is a
single module-global ``None`` check. Counters are lock-protected, so
parallel chunk workers see a consistent (if interleaving-dependent)
occurrence order; plans meant to be scheduling-independent should use
``*``, ``%K``, or key-scoped clauses.

Install programmatically with :func:`install` / :func:`clear` (tests
use the :func:`injected` context manager); ``$REPRO_FAULT_PLAN`` is
parsed once at import, so subprocesses (spawn-pool workers, CI bench
runs) inherit the plan through the environment — with their *own*
counters, one plan instance per process.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import re
import threading
import time
from typing import List, Optional

SITES = ("chunk.dispatch", "stepper.step", "cache.load", "records.save",
         "cell.run", "lease.claim", "lease.heartbeat", "chunk.resplit",
         "worker.exit", "serve.admit", "serve.preempt", "serve.page_alloc")
ACTIONS = ("raise", "corrupt", "delay")


class InjectedFault(RuntimeError):
    """The exception thrown by ``raise`` (and path-less ``corrupt``)
    actions — a distinct type so recovery-path tests can tell injected
    failures from genuine bugs."""


@dataclasses.dataclass
class FaultSpec:
    """One plan clause. ``trigger`` keeps the raw grammar text;
    :meth:`hits` evaluates it against this clause's occurrence count."""
    site: str
    action: str
    trigger: str = "*"
    key: Optional[str] = None      # substring matched against fire(key=)
    param: float = 0.0             # delay seconds

    def __post_init__(self) -> None:
        if self.action not in ACTIONS:
            raise ValueError(f"unknown fault action {self.action!r}; "
                             f"one of {ACTIONS}")
        self.hits(1)               # validate the trigger grammar eagerly

    def hits(self, count: int) -> bool:
        """Does occurrence number ``count`` (1-based) trip this spec?"""
        t = self.trigger
        if t == "*":
            return True
        if t.startswith("%"):
            k = int(t[1:])
            if k <= 0:
                raise ValueError(f"bad fault trigger {t!r}")
            return count % k == 0
        if t.endswith("+"):
            return count >= int(t[:-1])
        if "-" in t:
            lo, hi = t.split("-", 1)
            return int(lo) <= count <= int(hi)
        return count == int(t)


_CLAUSE = re.compile(
    r"^(?P<site>[\w.]+)"
    r"(?:\[(?P<key>[^\]]*)\])?"
    r"@(?P<trigger>\*|%\d+|\d+\+|\d+-\d+|\d+)"
    r"=(?P<action>\w+)"
    r"(?::(?P<param>[\d.]+))?$")


def parse_plan(text: str) -> Optional["FaultPlan"]:
    """Parse the plan grammar above; ``None`` for an empty plan."""
    specs: List[FaultSpec] = []
    for raw in re.split(r"[,;]", text or ""):
        raw = raw.strip()
        if not raw:
            continue
        m = _CLAUSE.match(raw)
        if m is None:
            raise ValueError(
                f"bad fault clause {raw!r}; expected "
                "site[key]@trigger=action[:param] — e.g. "
                "chunk.dispatch@1=raise or stepper.step@2=delay:0.1")
        specs.append(FaultSpec(
            site=m.group("site"), action=m.group("action"),
            trigger=m.group("trigger"), key=m.group("key"),
            param=float(m.group("param") or 0.0)))
    return FaultPlan(specs) if specs else None


class FaultPlan:
    """A set of clauses with per-clause occurrence counters."""

    def __init__(self, specs: List[FaultSpec]):
        self.specs = list(specs)
        self.counts = [0] * len(self.specs)
        self.fired = [0] * len(self.specs)
        self._lock = threading.Lock()

    def fire(self, site: str, key: str = "",
             path: Optional[str] = None) -> None:
        actions = []
        with self._lock:
            for k, spec in enumerate(self.specs):
                if spec.site != site:
                    continue
                if spec.key is not None and spec.key not in key:
                    continue
                self.counts[k] += 1
                if spec.hits(self.counts[k]):
                    self.fired[k] += 1
                    actions.append(spec)
        for spec in actions:           # act outside the lock
            if spec.action == "delay":
                time.sleep(spec.param)
            elif spec.action == "corrupt" and path is not None:
                _corrupt_file(path)
            else:
                raise InjectedFault(
                    f"injected fault at {site} "
                    f"(trigger {spec.trigger}, key={key!r})")


def _corrupt_file(path: str) -> None:
    """Deterministically garble ``path`` in place: truncate to half and
    overwrite the head, so readers see a structurally broken file (a
    torn write / bad sector stand-in) rather than a clean absence."""
    try:
        size = os.path.getsize(path)
        with open(path, "r+b") as fh:
            fh.truncate(max(size // 2, 16))
            fh.seek(0)
            fh.write(b"\x00CORRUPTED\x00\xff\xff\xff\xff\x00")
    except OSError as exc:
        raise InjectedFault(f"corrupt action failed on {path}: {exc}")


# the installed plan; None = disabled (the fast path below)
_PLAN: Optional[FaultPlan] = None


def fire(site: str, key: str = "", path: Optional[str] = None) -> None:
    """Fire a site. With no plan installed this is one global load and
    a ``None`` check — cheap enough for per-round stepper sites."""
    plan = _PLAN
    if plan is None:
        return
    plan.fire(site, key, path)


def active() -> Optional[FaultPlan]:
    return _PLAN


def install(plan) -> Optional[FaultPlan]:
    """Install a plan (a :class:`FaultPlan` or grammar text); returns
    the installed plan. ``None``/empty clears."""
    global _PLAN
    if isinstance(plan, str):
        plan = parse_plan(plan)
    _PLAN = plan
    return _PLAN


def clear() -> None:
    global _PLAN
    _PLAN = None


@contextlib.contextmanager
def injected(plan):
    """``with faults.injected("chunk.dispatch@1=raise"): ...`` — install
    for the block, restore the previous plan after."""
    global _PLAN
    prev = _PLAN
    install(plan)
    try:
        yield _PLAN
    finally:
        _PLAN = prev


# $REPRO_FAULT_PLAN: parsed once at import so child processes inherit
# the plan (each with fresh counters)
_env_plan = os.environ.get("REPRO_FAULT_PLAN", "")
if _env_plan:
    install(_env_plan)
