"""Run ledger: durable checkpoint/resume state for ``run_grid`` sweeps.

Long sweeps (auto-tuner searches, 10^5-request serving replays) must
survive a crash without discarding completed work. The ledger is the
on-disk flight recorder that makes that possible:

    results/runs/<run_id>/
        manifest.json           # grid hash/doc, engine, status
        chunks/<key>.json       # one shard per completed chunk
        leases/<key>.json       # live chunk claims (multi-worker runs)
        resplits/<key>.json     # budget-blown chunks split into children
        workers/<wid>.json      # per-worker exit summaries

Each *shard* holds the serialized results of one fault-isolated chunk
(per-limit subcell results for the batched engine, whole-cell records
for the scalar path), written atomically (temp + ``os.replace``) only
after the chunk fully succeeds. ``run_grid(..., resume=run_id)`` loads
every shard whose key matches the new run's chunk plan, re-runs the
rest, and reassembles by (cell index, limit ordinal) — so the final
records are **bit-identical** to an uninterrupted run (JSON floats are
serialized via ``repr`` and round-trip doubles exactly; the property
tests in ``tests/test_ledger.py`` pin this).

Chunk keys are *content-addressed* — a hash of the global (cell, limit
ordinal) ids a chunk covers — not positional. A resume with a
different worker count shards the plan differently; keys that still
match are reused, the rest re-run. Correctness never depends on the
plans matching, only the grid hash must (validated at open).

The manifest's ``status`` walks ``pending`` (created, nothing ran) /
``running`` → ``complete`` / ``partial`` (quarantined failures) /
``truncated`` (deadline hit). A crash leaves ``running`` — resumable,
and repaired to ``interrupted`` once its leases/heartbeats go stale
(see :meth:`RunLedger.probe_status`).

**Chunk leases (multi-worker runs).** N cooperating processes — or
hosts sharing the ledger filesystem — drain one run by *claiming*
chunks before executing them. A lease is a JSON file carrying the
worker id, a unique nonce, a heartbeat timestamp and a TTL:

* **claim** — the lease body is written to a unique temp file and
  *published* with ``os.link`` (atomic-exclusive: exactly one claimer
  wins a race; losers see ``FileExistsError`` and back off). An
  *expired* lease (heartbeat older than its TTL — a crashed or wedged
  worker) is first moved aside with ``os.replace``, which again only
  one stealer can win; the winner then claims fresh. Filesystems
  without hard links fall back to write-then-verify (the read-back
  nonce must match), which leaves a microscopic duplicate-execution
  window — harmless, see below.
* **heartbeat** — the holder periodically rewrites its lease (unique
  temp + ``os.replace``) with a fresh timestamp, after verifying the
  nonce on disk is still its own; a stolen lease means *back off*.
* **release** — the lease is unlinked after the chunk's shard lands.

Mutual exclusion is an *optimization*, never a correctness
requirement: shard keys are content-addressed and every backend is
bit-exact, so two workers completing the same chunk write
byte-identical shards and ``os.replace`` last-writer-wins on identical
bytes. The reassembled records cannot depend on worker count, crashes,
or duplicate completions. The guarantee assumes the ledger lives on a
filesystem with atomic ``rename``/``link`` (any local fs, NFSv3+) and
worker clocks skewed by less than the lease TTL.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pathlib
import shutil
import socket
import threading
import time
from typing import Any, Dict, List, Optional, Sequence

from repro.core import faults
from repro.core.gpu import GPUResult
from repro.core.simulator import SimResult

LEDGER_SCHEMA = 1
DEFAULT_ROOT = "results/runs"

# default chunk-lease time-to-live: a lease whose heartbeat is older
# than this is considered abandoned and reclaimable by survivors.
DEFAULT_LEASE_TTL = 30.0
# a *non-cooperative* run never heartbeats (its only activity is shard
# writes), so "running" manifests are only repaired to "interrupted"
# after this much silence unless a tighter bound is requested.
DEFAULT_STALE_AFTER = 600.0


def runs_root() -> pathlib.Path:
    """Ledger root directory; ``$REPRO_RUNS_DIR`` overrides."""
    return pathlib.Path(os.environ.get("REPRO_RUNS_DIR", "") or DEFAULT_ROOT)


def lease_ttl() -> float:
    """Chunk-lease TTL in seconds; ``$REPRO_LEASE_TTL`` overrides."""
    val = os.environ.get("REPRO_LEASE_TTL", "")
    if val:
        try:
            return max(float(val), 0.05)
        except ValueError:
            pass
    return DEFAULT_LEASE_TTL


def worker_id() -> str:
    """This process's worker identity for lease claims:
    ``$REPRO_WORKER_ID`` or ``<hostname>-<pid>``."""
    wid = os.environ.get("REPRO_WORKER_ID", "")
    return wid or f"{socket.gethostname()}-{os.getpid()}"


def grid_hash(grid) -> str:
    """Identity hash of an :class:`~repro.core.runner.ExperimentGrid`:
    everything that determines the records (workloads, policies, config
    reprs, scale, seed, GPU shape, sweep limits). Two grids with equal
    hashes produce bit-identical records, so resuming across them is
    sound; a mismatch at resume is refused."""
    doc = {
        "name": grid.name,
        "workloads": list(grid.workloads),
        "policies": list(grid.policies),
        "variants": {k: repr(v) for k, v in (grid.variants or {}).items()},
        "scale": repr(grid.scale),
        "seed": grid.seed,
        "gpu": repr(grid.gpu),
        "best_swl_limits": list(grid.best_swl_limits),
    }
    blob = json.dumps(doc, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


def chunk_key(item_ids: Sequence[str]) -> str:
    """Content-addressed shard key: hash of the sorted global item ids
    (``"<cell>:<limit ordinal>"`` for batched subcells, ``"cell:<i>"``
    for scalar-path cells) this chunk covers."""
    blob = "\n".join(sorted(item_ids)).encode()
    return hashlib.sha256(blob).hexdigest()[:20]


# ------------------------------------------------------- result serializers
# json.dumps writes floats via repr (shortest round-trip form), and
# json.loads parses back the identical double — so doc round-trips are
# bit-exact. The only lossy container is JSON's lack of tuples:
# SimResult.timeline holds (cycle, ipc, active) tuples, restored below.

def sim_to_doc(res: SimResult) -> dict:
    d = dataclasses.asdict(res)
    d["timeline"] = [list(t) for t in res.timeline]
    return d


def doc_to_sim(doc: dict) -> SimResult:
    d = dict(doc)
    d["timeline"] = [tuple(t) for t in d.get("timeline", [])]
    d["pairs"] = [list(p) for p in d.get("pairs", [])]
    return SimResult(**d)


def gpu_to_doc(res: GPUResult) -> dict:
    d = dataclasses.asdict(res)
    d["per_sm"] = [sim_to_doc(r) for r in res.per_sm]
    return d


def doc_to_gpu(doc: dict) -> GPUResult:
    d = dict(doc)
    d["per_sm"] = [doc_to_sim(r) for r in d.get("per_sm", [])]
    return GPUResult(**d)


def result_to_doc(res) -> dict:
    if isinstance(res, GPUResult):
        return {"kind": "gpu", "res": gpu_to_doc(res)}
    return {"kind": "sim", "res": sim_to_doc(res)}


def doc_to_result(doc: dict):
    if doc["kind"] == "gpu":
        return doc_to_gpu(doc["res"])
    return doc_to_sim(doc["res"])


class RunLedger:
    """One run's on-disk checkpoint state (see module docstring).

    Thread-safe: chunk workers save shards concurrently; each shard is
    an independent file and manifest writes are serialized."""

    def __init__(self, run_id: str, root: Optional[pathlib.Path] = None):
        if not run_id or "/" in run_id or run_id.startswith("."):
            raise ValueError(f"bad run id {run_id!r}")
        self.run_id = run_id
        self.dir = (root if root is not None else runs_root()) / run_id
        self.chunk_dir = self.dir / "chunks"
        self.lease_dir = self.dir / "leases"
        self.resplit_dir = self.dir / "resplits"
        self.worker_dir = self.dir / "workers"
        self.manifest_path = self.dir / "manifest.json"
        self._lock = threading.Lock()
        self.manifest: Dict[str, Any] = {}
        self.resumed_chunks = 0

    # ------------------------------------------------------------ lifecycle
    def open(self, manifest: Dict[str, Any], resume: bool = False,
             status: str = "running") -> None:
        """Start (or resume) the run. ``manifest`` must carry
        ``grid_hash``; on resume it is validated against the stored one
        and completed shards are kept. A non-resume open of an existing
        run id wipes stale shards/leases/resplits — a fresh run must
        never absorb another grid's results.

        On resume of a run still marked ``running``, staleness is
        probed (lease/heartbeat/shard activity age): an orphaned run —
        its process died without ``finish()`` — is recorded as an
        interruption rather than silently continuing the lie."""
        self.chunk_dir.mkdir(parents=True, exist_ok=True)
        prev = None
        if self.manifest_path.exists():
            try:
                prev = json.loads(self.manifest_path.read_text())
            except (OSError, ValueError):
                prev = None
        interruptions = 0
        created_ts = time.time()
        if resume:
            if prev is None:
                raise ValueError(
                    f"cannot resume run {self.run_id!r}: no manifest under "
                    f"{self.dir}")
            if prev.get("grid_hash") != manifest.get("grid_hash"):
                raise ValueError(
                    f"cannot resume run {self.run_id!r}: grid hash mismatch "
                    f"(ledger {prev.get('grid_hash')!r} vs current "
                    f"{manifest.get('grid_hash')!r}) — the grid changed "
                    "since the original run")
            interruptions = int(prev.get("interruptions", 0) or 0)
            created_ts = float(prev.get("created_ts", created_ts))
            if self._probe_stale(prev):
                interruptions += 1      # orphan detected: repair the record
        elif prev is not None:
            for sub in (self.chunk_dir, self.lease_dir, self.resplit_dir,
                        self.worker_dir):
                if sub.is_dir():
                    for stale in sub.glob("*.json"):
                        try:
                            stale.unlink()
                        except OSError:
                            pass
        doc = dict(manifest)
        doc.update(schema=LEDGER_SCHEMA, run_id=self.run_id,
                   status=status, created_ts=created_ts,
                   interruptions=interruptions)
        self.manifest = doc
        self._write_manifest()

    def finish(self, status: str) -> None:
        """Seal the run: ``complete`` (all cells succeeded), ``partial``
        (quarantined failures), or ``truncated`` (deadline)."""
        with self._lock:
            self.manifest["status"] = status
        self._write_manifest()

    def _write_manifest(self) -> None:
        with self._lock:
            self.manifest["updated_ts"] = time.time()
            blob = json.dumps(self.manifest, indent=1, sort_keys=True)
        _atomic_write(self.manifest_path, blob)

    def load(self) -> Dict[str, Any]:
        """Read the on-disk manifest into ``self.manifest`` (for
        inspection tooling / ``work`` reattachment; no status change).
        Raises ``ValueError`` when the run does not exist."""
        try:
            self.manifest = json.loads(self.manifest_path.read_text())
        except (OSError, ValueError) as exc:
            raise ValueError(
                f"run {self.run_id!r} has no readable manifest under "
                f"{self.dir}: {exc}") from exc
        return self.manifest

    # --------------------------------------------------------------- shards
    def shard_path(self, key: str) -> pathlib.Path:
        return self.chunk_dir / f"{key}.json"

    def load_chunk(self, key: str) -> Optional[List[dict]]:
        """Items of a completed chunk, or ``None`` if absent/unreadable.
        A corrupt shard (torn write, bad disk) is deleted and treated as
        never-completed — the chunk simply re-runs."""
        path = self.shard_path(key)
        if not path.exists():
            return None
        try:
            doc = json.loads(path.read_text())
            if doc.get("schema") != LEDGER_SCHEMA:
                raise ValueError(f"shard schema {doc.get('schema')!r}")
            items = doc["items"]
            if not isinstance(items, list):
                raise ValueError("shard items not a list")
        except (OSError, ValueError, KeyError):
            try:
                path.unlink()
            except OSError:
                pass
            return None
        with self._lock:
            self.resumed_chunks += 1
        return items

    def save_chunk(self, key: str, items: List[dict]) -> None:
        """Atomically persist a *fully successful* chunk's items.
        Callers only shard chunks whose every item succeeded — failed or
        truncated chunks stay unrecorded so a resume retries them."""
        faults.fire("records.save", key=f"chunk:{key}",
                    path=str(self.shard_path(key)))
        blob = json.dumps({"schema": LEDGER_SCHEMA, "run": self.run_id,
                           "key": key, "items": items}, sort_keys=True)
        _atomic_write(self.shard_path(key), blob)

    def completed_keys(self) -> List[str]:
        if not self.chunk_dir.is_dir():
            return []
        return sorted(p.stem for p in self.chunk_dir.glob("*.json"))

    # --------------------------------------------------------------- leases
    # See the module docstring for the protocol. A lease is advisory:
    # it prevents *wasted* duplicate work, never guards correctness —
    # duplicate completions write byte-identical shards.

    def lease_path(self, key: str) -> pathlib.Path:
        return self.lease_dir / f"{key}.json"

    def read_lease(self, key: str) -> Optional[Dict[str, Any]]:
        """Current lease doc for ``key``, or ``None`` when absent or
        unreadable (a torn/corrupt lease counts as abandoned)."""
        try:
            doc = json.loads(self.lease_path(key).read_text())
        except (OSError, ValueError):
            return None
        return doc if isinstance(doc, dict) and doc.get("nonce") else None

    def claim_lease(self, key: str, worker: str,
                    ttl: Optional[float] = None) -> Optional[Dict[str, Any]]:
        """Atomically claim chunk ``key`` for ``worker``. Returns the
        lease doc (heartbeat with it) on success, ``None`` when another
        worker holds a live lease — the loser backs off.

        A fresh claim publishes the fully-written lease body with
        ``os.link`` (atomic-exclusive: exactly one racing claimer
        wins). An expired or corrupt lease is first moved aside with
        ``os.replace`` — again only one stealer succeeds — and the
        winner claims fresh with ``takeover_of`` recording the dead
        worker. Filesystems without hard links fall back to
        write-then-verify-nonce."""
        self.lease_dir.mkdir(parents=True, exist_ok=True)
        path = self.lease_path(key)
        faults.fire("lease.claim", key=key, path=str(path))
        now = time.time()
        ttl = lease_ttl() if ttl is None else float(ttl)
        nonce = (f"{worker}.{os.getpid()}.{threading.get_ident()}"
                 f".{time.monotonic_ns()}")
        takeover_of = None
        cur = self.read_lease(key)
        if cur is not None or path.exists():
            age = now - float(cur.get("ts", 0.0)) if cur else float("inf")
            cur_ttl = float(cur.get("ttl", ttl)) if cur else 0.0
            if cur is not None and age <= cur_ttl \
                    and cur.get("worker") != worker:
                return None                     # live lease elsewhere
            # dead (expired/corrupt) or our own: move it aside; only one
            # stealer wins the os.replace race.
            aside = self.lease_dir / f".stale-{nonce}"
            try:
                os.replace(path, aside)
            except OSError:
                return None                     # lost the steal race
            try:
                aside.unlink()
            except OSError:
                pass
            if cur is not None and cur.get("worker") != worker:
                takeover_of = cur.get("worker")
        doc = {"schema": LEDGER_SCHEMA, "run": self.run_id, "key": key,
               "worker": worker, "nonce": nonce, "ts": now, "ttl": ttl,
               "takeover_of": takeover_of}
        blob = json.dumps(doc, sort_keys=True)
        tmp = self.lease_dir / f".claim-{nonce}.tmp"
        try:
            with open(tmp, "w") as fh:
                fh.write(blob)
                fh.flush()
                os.fsync(fh.fileno())
            try:
                os.link(tmp, path)              # atomic-exclusive publish
            except FileExistsError:
                return None                     # lost the claim race
            except OSError:
                # no hard-link support: weaker write-then-verify path
                _atomic_write(path, blob)
                back = self.read_lease(key)
                if back is None or back.get("nonce") != nonce:
                    return None
        finally:
            try:
                tmp.unlink()
            except OSError:
                pass
        return doc

    def heartbeat_lease(self, key: str, doc: Dict[str, Any]) -> bool:
        """Refresh a held lease's timestamp (unique temp +
        ``os.replace``). Returns ``False`` when the lease on disk is no
        longer ours (stolen after an expiry, or released) — the caller
        must back off; its in-flight result is still safe to publish
        (identical bytes)."""
        path = self.lease_path(key)
        faults.fire("lease.heartbeat", key=key, path=str(path))
        cur = self.read_lease(key)
        if cur is None or cur.get("nonce") != doc.get("nonce"):
            return False
        fresh = dict(doc, ts=time.time())
        _atomic_write(path, json.dumps(fresh, sort_keys=True))
        return True

    def release_lease(self, key: str, doc: Dict[str, Any]) -> None:
        """Drop a held lease (after the chunk's shard landed, or when
        abandoning it). Only removes the lease if it is still ours."""
        cur = self.read_lease(key)
        if cur is not None and cur.get("nonce") == doc.get("nonce"):
            try:
                self.lease_path(key).unlink()
            except OSError:
                pass

    def leases(self) -> List[Dict[str, Any]]:
        """All current lease docs, each annotated with ``age`` and
        ``expired`` (heartbeat older than its TTL)."""
        if not self.lease_dir.is_dir():
            return []
        now = time.time()
        out = []
        for path in sorted(self.lease_dir.glob("*.json")):
            doc = self.read_lease(path.stem)
            if doc is None:
                continue
            doc["age"] = now - float(doc.get("ts", 0.0))
            doc["expired"] = doc["age"] > float(doc.get("ttl", 0.0))
            out.append(doc)
        return out

    # ------------------------------------------------------------- resplits
    def save_resplit(self, parent_key: str,
                     children: List[List[str]]) -> None:
        """Record that budget-blown chunk ``parent_key`` was split into
        ``children`` (lists of global item ids). Deterministic content
        → concurrent writers produce identical bytes."""
        blob = json.dumps({"schema": LEDGER_SCHEMA, "run": self.run_id,
                           "parent": parent_key,
                           "children": [sorted(c) for c in children]},
                          sort_keys=True)
        _atomic_write(self.resplit_dir / f"{parent_key}.json", blob)

    def load_resplits(self) -> Dict[str, List[List[str]]]:
        """parent chunk key → recorded child item-id lists."""
        if not self.resplit_dir.is_dir():
            return {}
        out: Dict[str, List[List[str]]] = {}
        for path in sorted(self.resplit_dir.glob("*.json")):
            try:
                doc = json.loads(path.read_text())
                kids = doc["children"]
                if not isinstance(kids, list) or not kids:
                    raise ValueError("bad children")
            except (OSError, ValueError, KeyError):
                continue
            out[path.stem] = [list(map(str, c)) for c in kids]
        return out

    # ------------------------------------------------------ worker summaries
    def save_worker_summary(self, worker: str, doc: Dict[str, Any]) -> None:
        safe = "".join(c if (c.isalnum() or c in "-_.") else "_"
                       for c in worker) or "worker"
        blob = json.dumps(dict(doc, worker=worker, ts=time.time()),
                          indent=1, sort_keys=True)
        _atomic_write(self.worker_dir / f"{safe}.json", blob)

    def worker_summaries(self) -> List[Dict[str, Any]]:
        if not self.worker_dir.is_dir():
            return []
        out = []
        for path in sorted(self.worker_dir.glob("*.json")):
            try:
                out.append(json.loads(path.read_text()))
            except (OSError, ValueError):
                continue
        return out

    # ------------------------------------------------------------ staleness
    def last_activity_ts(self) -> float:
        """Most recent mtime across the manifest, shards and leases —
        the run's last observable sign of life."""
        latest = 0.0
        paths = [self.manifest_path]
        for sub in (self.chunk_dir, self.lease_dir, self.resplit_dir,
                    self.worker_dir):
            if sub.is_dir():
                paths.extend(sub.glob("*.json"))
        for p in paths:
            try:
                latest = max(latest, p.stat().st_mtime)
            except OSError:
                continue
        return latest

    def _probe_stale(self, manifest: Dict[str, Any],
                     stale_after: Optional[float] = None) -> bool:
        if manifest.get("status") != "running":
            return False
        for lease in self.leases():
            if not lease["expired"]:
                return False                    # someone is heartbeating
        if stale_after is None:
            stale_after = max(lease_ttl(), DEFAULT_STALE_AFTER)
        return time.time() - self.last_activity_ts() > stale_after

    def probe_status(self, stale_after: Optional[float] = None) -> str:
        """The manifest status, with orphan detection: a ``running``
        run whose leases are all expired and whose files have been
        silent for ``stale_after`` seconds (default
        ``max($REPRO_LEASE_TTL, 600)``) is really ``interrupted``."""
        if not self.manifest:
            self.load()
        status = str(self.manifest.get("status", "unknown"))
        if self._probe_stale(self.manifest, stale_after):
            return "interrupted"
        return status

    def repair_if_stale(self, stale_after: Optional[float] = None) -> bool:
        """Persist ``interrupted`` for an orphaned ``running`` run.
        Returns whether a repair happened."""
        if not self.manifest:
            self.load()
        if not self._probe_stale(self.manifest, stale_after):
            return False
        with self._lock:
            self.manifest["status"] = "interrupted"
            self.manifest["interrupted_ts"] = time.time()
            self.manifest["interruptions"] = \
                int(self.manifest.get("interruptions", 0) or 0) + 1
        self._write_manifest()
        return True

    def remove(self) -> None:
        """Delete the whole run directory (``runs gc``)."""
        shutil.rmtree(self.dir, ignore_errors=True)


class LeaseKeeper(threading.Thread):
    """Daemon heartbeat thread for a worker's held leases.

    ``add``/``remove`` bracket chunk execution; every ``interval``
    seconds each held lease is re-timestamped. A heartbeat that fails
    (fault-injected I/O error) or discovers the lease stolen bumps the
    counters and — when ``on_fatal`` is set, as the ``runs work``
    entrypoint does — invokes it to simulate/handle worker death."""

    def __init__(self, ledger: RunLedger, ttl: float,
                 on_fatal=None):
        super().__init__(name=f"lease-keeper-{ledger.run_id}", daemon=True)
        self.ledger = ledger
        self.interval = min(max(ttl / 4.0, 0.05), 1.0)
        self.on_fatal = on_fatal
        self._held: Dict[str, Dict[str, Any]] = {}
        self._mu = threading.Lock()
        self._halt = threading.Event()
        self.beats = 0
        self.failures = 0
        self.stolen = 0

    def add(self, key: str, doc: Dict[str, Any]) -> None:
        with self._mu:
            self._held[key] = doc

    def remove(self, key: str) -> None:
        with self._mu:
            self._held.pop(key, None)

    def stop(self) -> None:
        self._halt.set()

    def run(self) -> None:
        while not self._halt.wait(self.interval):
            with self._mu:
                held = list(self._held.items())
            for key, doc in held:
                try:
                    ok = self.ledger.heartbeat_lease(key, doc)
                except Exception:
                    self.failures += 1
                    if self.on_fatal is not None:
                        self.on_fatal(f"heartbeat failed for chunk {key}")
                    continue
                if ok:
                    self.beats += 1
                else:
                    self.stolen += 1
                    self.remove(key)    # stolen: stop refreshing it

    def stats(self) -> Dict[str, int]:
        return {"heartbeats": self.beats,
                "heartbeat_failures": self.failures,
                "leases_stolen": self.stolen}


def _atomic_write(path: pathlib.Path, text: str) -> None:
    """Unique temp + fsync + ``os.replace``: concurrent writers never
    collide on the temp name and a crash never leaves a torn file."""
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.parent / (f".{path.name}.{os.getpid()}"
                         f".{threading.get_ident()}.tmp")
    try:
        with open(tmp, "w") as fh:
            fh.write(text)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            tmp.unlink()
        except OSError:
            pass
        raise
