"""Run ledger: durable checkpoint/resume state for ``run_grid`` sweeps.

Long sweeps (auto-tuner searches, 10^5-request serving replays) must
survive a crash without discarding completed work. The ledger is the
on-disk flight recorder that makes that possible:

    results/runs/<run_id>/
        manifest.json           # grid hash, engine, chunk plan, status
        chunks/<key>.json       # one shard per completed chunk

Each *shard* holds the serialized results of one fault-isolated chunk
(per-limit subcell results for the batched engine, whole-cell records
for the scalar path), written atomically (temp + ``os.replace``) only
after the chunk fully succeeds. ``run_grid(..., resume=run_id)`` loads
every shard whose key matches the new run's chunk plan, re-runs the
rest, and reassembles by (cell index, limit ordinal) — so the final
records are **bit-identical** to an uninterrupted run (JSON floats are
serialized via ``repr`` and round-trip doubles exactly; the property
tests in ``tests/test_ledger.py`` pin this).

Chunk keys are *content-addressed* — a hash of the global (cell, limit
ordinal) ids a chunk covers — not positional. A resume with a
different worker count shards the plan differently; keys that still
match are reused, the rest re-run. Correctness never depends on the
plans matching, only the grid hash must (validated at open).

The manifest's ``status`` walks ``running`` → ``complete`` /
``partial`` (quarantined failures) / ``truncated`` (deadline hit). A
crash leaves ``running`` — also resumable.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pathlib
import threading
from typing import Any, Dict, List, Optional, Sequence

from repro.core import faults
from repro.core.gpu import GPUResult
from repro.core.simulator import SimResult

LEDGER_SCHEMA = 1
DEFAULT_ROOT = "results/runs"


def runs_root() -> pathlib.Path:
    """Ledger root directory; ``$REPRO_RUNS_DIR`` overrides."""
    return pathlib.Path(os.environ.get("REPRO_RUNS_DIR", "") or DEFAULT_ROOT)


def grid_hash(grid) -> str:
    """Identity hash of an :class:`~repro.core.runner.ExperimentGrid`:
    everything that determines the records (workloads, policies, config
    reprs, scale, seed, GPU shape, sweep limits). Two grids with equal
    hashes produce bit-identical records, so resuming across them is
    sound; a mismatch at resume is refused."""
    doc = {
        "name": grid.name,
        "workloads": list(grid.workloads),
        "policies": list(grid.policies),
        "variants": {k: repr(v) for k, v in (grid.variants or {}).items()},
        "scale": repr(grid.scale),
        "seed": grid.seed,
        "gpu": repr(grid.gpu),
        "best_swl_limits": list(grid.best_swl_limits),
    }
    blob = json.dumps(doc, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


def chunk_key(item_ids: Sequence[str]) -> str:
    """Content-addressed shard key: hash of the sorted global item ids
    (``"<cell>:<limit ordinal>"`` for batched subcells, ``"cell:<i>"``
    for scalar-path cells) this chunk covers."""
    blob = "\n".join(sorted(item_ids)).encode()
    return hashlib.sha256(blob).hexdigest()[:20]


# ------------------------------------------------------- result serializers
# json.dumps writes floats via repr (shortest round-trip form), and
# json.loads parses back the identical double — so doc round-trips are
# bit-exact. The only lossy container is JSON's lack of tuples:
# SimResult.timeline holds (cycle, ipc, active) tuples, restored below.

def sim_to_doc(res: SimResult) -> dict:
    d = dataclasses.asdict(res)
    d["timeline"] = [list(t) for t in res.timeline]
    return d


def doc_to_sim(doc: dict) -> SimResult:
    d = dict(doc)
    d["timeline"] = [tuple(t) for t in d.get("timeline", [])]
    d["pairs"] = [list(p) for p in d.get("pairs", [])]
    return SimResult(**d)


def gpu_to_doc(res: GPUResult) -> dict:
    d = dataclasses.asdict(res)
    d["per_sm"] = [sim_to_doc(r) for r in res.per_sm]
    return d


def doc_to_gpu(doc: dict) -> GPUResult:
    d = dict(doc)
    d["per_sm"] = [doc_to_sim(r) for r in d.get("per_sm", [])]
    return GPUResult(**d)


def result_to_doc(res) -> dict:
    if isinstance(res, GPUResult):
        return {"kind": "gpu", "res": gpu_to_doc(res)}
    return {"kind": "sim", "res": sim_to_doc(res)}


def doc_to_result(doc: dict):
    if doc["kind"] == "gpu":
        return doc_to_gpu(doc["res"])
    return doc_to_sim(doc["res"])


class RunLedger:
    """One run's on-disk checkpoint state (see module docstring).

    Thread-safe: chunk workers save shards concurrently; each shard is
    an independent file and manifest writes are serialized."""

    def __init__(self, run_id: str, root: Optional[pathlib.Path] = None):
        if not run_id or "/" in run_id or run_id.startswith("."):
            raise ValueError(f"bad run id {run_id!r}")
        self.run_id = run_id
        self.dir = (root if root is not None else runs_root()) / run_id
        self.chunk_dir = self.dir / "chunks"
        self.manifest_path = self.dir / "manifest.json"
        self._lock = threading.Lock()
        self.manifest: Dict[str, Any] = {}
        self.resumed_chunks = 0

    # ------------------------------------------------------------ lifecycle
    def open(self, manifest: Dict[str, Any], resume: bool = False) -> None:
        """Start (or resume) the run. ``manifest`` must carry
        ``grid_hash``; on resume it is validated against the stored one
        and completed shards are kept. A non-resume open of an existing
        run id wipes stale shards — a fresh run must never absorb
        another grid's results."""
        self.chunk_dir.mkdir(parents=True, exist_ok=True)
        prev = None
        if self.manifest_path.exists():
            try:
                prev = json.loads(self.manifest_path.read_text())
            except (OSError, ValueError):
                prev = None
        if resume:
            if prev is None:
                raise ValueError(
                    f"cannot resume run {self.run_id!r}: no manifest under "
                    f"{self.dir}")
            if prev.get("grid_hash") != manifest.get("grid_hash"):
                raise ValueError(
                    f"cannot resume run {self.run_id!r}: grid hash mismatch "
                    f"(ledger {prev.get('grid_hash')!r} vs current "
                    f"{manifest.get('grid_hash')!r}) — the grid changed "
                    "since the original run")
        elif prev is not None:
            for shard in self.chunk_dir.glob("*.json"):
                try:
                    shard.unlink()
                except OSError:
                    pass
        doc = dict(manifest)
        doc.update(schema=LEDGER_SCHEMA, run_id=self.run_id,
                   status="running")
        self.manifest = doc
        self._write_manifest()

    def finish(self, status: str) -> None:
        """Seal the run: ``complete`` (all cells succeeded), ``partial``
        (quarantined failures), or ``truncated`` (deadline)."""
        with self._lock:
            self.manifest["status"] = status
        self._write_manifest()

    def _write_manifest(self) -> None:
        with self._lock:
            blob = json.dumps(self.manifest, indent=1, sort_keys=True)
        _atomic_write(self.manifest_path, blob)

    # --------------------------------------------------------------- shards
    def shard_path(self, key: str) -> pathlib.Path:
        return self.chunk_dir / f"{key}.json"

    def load_chunk(self, key: str) -> Optional[List[dict]]:
        """Items of a completed chunk, or ``None`` if absent/unreadable.
        A corrupt shard (torn write, bad disk) is deleted and treated as
        never-completed — the chunk simply re-runs."""
        path = self.shard_path(key)
        if not path.exists():
            return None
        try:
            doc = json.loads(path.read_text())
            if doc.get("schema") != LEDGER_SCHEMA:
                raise ValueError(f"shard schema {doc.get('schema')!r}")
            items = doc["items"]
            if not isinstance(items, list):
                raise ValueError("shard items not a list")
        except (OSError, ValueError, KeyError):
            try:
                path.unlink()
            except OSError:
                pass
            return None
        with self._lock:
            self.resumed_chunks += 1
        return items

    def save_chunk(self, key: str, items: List[dict]) -> None:
        """Atomically persist a *fully successful* chunk's items.
        Callers only shard chunks whose every item succeeded — failed or
        truncated chunks stay unrecorded so a resume retries them."""
        faults.fire("records.save", key=f"chunk:{key}",
                    path=str(self.shard_path(key)))
        blob = json.dumps({"schema": LEDGER_SCHEMA, "run": self.run_id,
                           "key": key, "items": items}, sort_keys=True)
        _atomic_write(self.shard_path(key), blob)

    def completed_keys(self) -> List[str]:
        if not self.chunk_dir.is_dir():
            return []
        return sorted(p.stem for p in self.chunk_dir.glob("*.json"))


def _atomic_write(path: pathlib.Path, text: str) -> None:
    """Unique temp + fsync + ``os.replace``: concurrent writers never
    collide on the temp name and a crash never leaves a torn file."""
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.parent / (f".{path.name}.{os.getpid()}"
                         f".{threading.get_ident()}.tmp")
    try:
        with open(tmp, "w") as fh:
            fh.write(text)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            tmp.unlink()
        except OSError:
            pass
        raise
