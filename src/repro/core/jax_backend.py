"""Jitted JAX backend for the batched lockstep engine.

This is the third stepper over the same stacked state the numpy and C
steppers drive (:mod:`repro.core.batched`): the whole batch state is a
**pytree of int64/bool/float64 arrays** with a leading batch axis, one
lockstep iteration (one scheduler dispatch per live row, the full
per-access chain, plus the epoch / warp-retirement / timeline servicing
the C stepper runs in-stepper) is a **pure function** ``state -> state``,
and a run is ``jax.jit(lax.while_loop(any_live, iteration, state))``.
Rare per-dispatch events — epoch boundaries, warp retirement, timeline
samples, fully-throttled stretches — are gated with ``lax.cond`` on
batch-level "any row flagged" predicates, so the common iteration skips
their sort/scatter kernels entirely.

**Bit-exactness contract.** Every arithmetic step mirrors the numpy
stepper elementwise under the fixed-point rules of
:mod:`repro.core.epoch`: all counters are int64 (x64 mode is enabled in
a scope around trace and execution — never globally), every cutoff
decision is the single-rounding float64 compare ``hits*act <> cutoff*win``
with operands far below 2**53, sorts are stable, and arg-reductions
break ties on the first index exactly like numpy. ``tests/test_batched.py``
and ``tests/test_jax_backend.py`` pin golden cells and mixed batches
bit-for-bit across all three steppers.

**Gating.** The backend takes single-SM batches (``gpu is None`` — the
post-L1 planes are then private per row, so no cross-row phase
interleaving is needed) whose rows all map to the known policy /
warp-done families (no ``F_OBJECT``/``WD_OBJECT`` object fallbacks —
those need per-cell Python). :func:`supports_engine` is the predicate;
``BatchedSMEngine.run`` with ``backend="jax"`` raises when it does not
hold, and ``runner.run_grid(engine="jax")`` routes only eligible cells
here (the rest fall back to the batched/process paths).

The jit cache is keyed on the static config tuple — shape-affecting
fields only. Scalar knobs that vary within sweeps (latencies, epoch
cutoffs, cycle caps) ride as per-row ``(B,)`` leaves of the ``consts``
pytree, so heterogeneous hyperparameter batches share one compiled
program instead of fragmenting the cache per config. Changing batch
width, warp count or stream length retraces through jax's own
shape-keyed cache. The batch axis is the explicit leading axis of every
leaf, so the compiled step is also ``vmap``-able over an outer grid
axis. Results are written back into the engine's numpy arrays and the
standard ``BatchedSMEngine._finalize`` assembles ``SimResult``s, so
downstream aggregation is shared with the other steppers.
"""
from __future__ import annotations

import functools
import time
from typing import NamedTuple

import numpy as np

try:                                   # gate, never a hard dependency
    import jax
    import jax.numpy as jnp
    from jax import lax
    _IMPORT_ERROR = None
except Exception as exc:               # pragma: no cover - env without jax
    jax = None
    jnp = None
    lax = None
    _IMPORT_ERROR = exc

from repro.core.batched import (F_CCWS, F_CIAO, F_OBJECT, F_STATP,
                                WD_OBJECT, WD_STATP, WD_SWL)
from repro.core.epoch import _DEAD_KEY, NO_WARP
from repro.core.policies import CCWSPolicy
from repro.core.simulator import _HUGE
from repro.workloads import tokens as _tokens

_SHIFT = _tokens.TOKEN_LINE_SHIFT


def available() -> bool:
    """True when jax imports (the backend is usable)."""
    return jax is not None


def unavailable_reason() -> str:
    return "" if jax is not None else f"jax import failed: {_IMPORT_ERROR}"


def supports_engine(eng) -> str:
    """Empty string when the engine can run on the jax backend, else the
    human-readable reason it cannot."""
    if jax is None:
        return unavailable_reason()
    if eng.gpu is not None:
        return "multi-SM batches are not jax-able yet (shared post-L1 " \
               "planes need phase interleaving); use backend='auto'"
    if (eng.fam == F_OBJECT).any() or (eng.wd_kind == WD_OBJECT).any():
        return "batch contains custom policy objects (F_OBJECT/" \
               "WD_OBJECT rows need per-cell Python)"
    return ""


class _Static(NamedTuple):
    """Hashable static config: the jit cache key (together with jax's
    own shape/dtype keying of the traced arrays)."""
    n: int
    L: int
    P: int
    l1_sets: int
    l1_ways: int
    xor_hash: bool
    reuse_filter: bool
    nrb: int
    v_sets: int
    v_k: int
    nw: int
    le: int
    sat_max: int
    l2_sets: int
    l2_ways: int
    dram_channels: int
    max_mlp: int
    timeline_every: int
    tl_cap: int


def _static_of(eng) -> _Static:
    return _Static(
        n=eng.n_warps, L=eng.L, P=eng.P,
        l1_sets=eng.l1_sets, l1_ways=eng.l1_ways,
        xor_hash=bool(eng.xor_hash), reuse_filter=bool(eng.reuse_filter),
        nrb=eng.nrb, v_sets=eng.v_sets, v_k=eng.v_k,
        nw=eng.nw, le=eng.list_entries, sat_max=eng.sat_max,
        l2_sets=eng.l2_sets, l2_ways=eng.l2_ways,
        dram_channels=eng.dram_channels,
        max_mlp=eng.max_mlp,
        timeline_every=eng.timeline_every, tl_cap=eng.tl_cap)


# mutable state: (engine attribute, state key); det planes/consts below
_STATE_ATTRS = (
    "ready", "done", "avail", "iso", "byp", "op_idx", "pend",
    "cycle", "instr", "li", "irs_off", "last_wid", "window_mark",
    "last_instr", "last_cycle", "tick",
    "l1_tags", "l1_owners", "l1_reused", "l1_stamp",
    "smem_tags", "smem_owner",
    "v_addr", "v_evic", "v_head", "v_count", "v_inserts",
    "l2_tags", "l2_stamp", "l2_tick", "l2_hits", "l2_misses",
    "dram_free", "dram_requests", "cnt_dram_reqs",
    "cnt_l1_hit", "cnt_l1_miss", "cnt_smem_hit", "cnt_smem_miss",
    "cnt_smem_migrate", "cnt_bypass", "cnt_evictions",
    "cnt_smem_evictions", "cnt_vta_hits", "vta_hit_events",
    "pair_dense", "next_epoch", "remaining",
    "allowed_pl", "isolated_pl", "bypass_pl", "score_pl",
    "sp_bypass", "sp_base", "swl_next",
    "ciao_stall", "ciao_iso", "stall_len", "iso_len",
    "tl_cycle", "tl_dipc", "tl_act", "tl_n",
)
# detector planes stacked in the state with a d_ prefix
_DET_FIELDS = (
    "inst_total", "irs_inst", "low_idx", "high_idx",
    "low_base_inst", "high_base_inst", "high_crossings",
    "irs_hits", "low_base_hits", "high_base_hits",
    "low_snap_hits", "high_snap_hits", "low_snap_win", "high_snap_win",
    "low_snap_act", "high_snap_act",
    "vta_hits", "interfering", "sat", "pair_list",
)


def _arrays_of(eng):
    """(state, consts) pytrees as numpy arrays; jit converts on entry."""
    state = {k: getattr(eng, k) for k in _STATE_ATTRS}
    for f in _DET_FIELDS:
        state["d_" + f] = getattr(eng.det_pl, f)
    bump = np.zeros(eng.B, np.int64)
    for b, pol in enumerate(eng.policies):
        if isinstance(pol, CCWSPolicy):
            bump[b] = pol.bump
    consts = {
        "toks": eng.toks, "u_of": eng.u_of, "n_ops": eng.n_ops,
        "region_blocks": eng.region_blocks,
        "fam": eng.fam.astype(np.int64), "wd_kind": eng.wd_kind,
        "mode_p": eng.mode_p, "mode_t": eng.mode_t,
        "ccws_base": eng.ccws_base, "ccws_budget": eng.ccws_budget,
        "sp_thresh": eng.sp_thresh, "bump": bump,
        # per-row config planes: knobs that vary within a shape class
        # ride as (B,) consts so heterogeneous sweeps share one compile
        "lat_l1": eng.lat_l1, "lat_smem": eng.lat_smem,
        "lat_migrate": eng.lat_migrate, "lat_l2": eng.lat_l2,
        "lat_dram": eng.lat_dram, "dram_gap": eng.dram_gap,
        "max_cycles": eng.max_cycles,
        "low_epoch": eng.low_epoch, "high_epoch": eng.high_epoch,
        "stride_ok": eng._stride_ok,
        "aging": eng.det_pl.aging_high,
        "low_cutoff": eng.det_pl.low_cutoff,
        "high_cutoff": eng.det_pl.high_cutoff,
    }
    return state, consts


def _write_back(eng, out) -> None:
    for k in _STATE_ATTRS:
        np.copyto(getattr(eng, k), np.asarray(out[k]))
    for f in _DET_FIELDS:
        np.copyto(getattr(eng.det_pl, f), np.asarray(out["d_" + f]))


# -------------------------------------------------------------- kernels
# Everything below is a transliteration of BatchedSMEngine._np_iteration
# / _np_mem_chain / _epoch_batch / _warp_done_rows / _timeline_rows to
# jnp: boolean-subset scatters become `.at[rows, cols].set(where(mask,
# new, old))` full-width masked scatters (one target slot per row, so
# they never collide), and per-cell fallbacks (the VTA FIFO pop) are
# vectorized over the logical window.

def _f64(a):
    return a.astype(jnp.float64)


def _gated(st, mask, fn, *extra):
    """Run ``fn(st, mask, *extra)`` only when any row is flagged."""
    return lax.cond(mask.any(),
                    lambda op: fn(*op),
                    lambda op: op[0],
                    (st, mask) + extra)


def _ccws_tick(S, cst, st, m):
    arB = jnp.arange(st["cycle"].shape[0])
    s0 = st["score_pl"]
    s = s0 - jnp.maximum(1, s0 // 8)
    s = jnp.maximum(s, cst["ccws_base"][:, None])
    score = jnp.where(m[:, None], s, s0)
    alive = ~st["done"]
    key = jnp.where(alive, -s, _DEAD_KEY)
    order = jnp.argsort(key, axis=1, stable=True)
    s_sorted = jnp.take_along_axis(s, order, 1)
    a_sorted = jnp.take_along_axis(alive, order, 1)
    csum = jnp.cumsum(jnp.where(a_sorted, s_sorted, 0), axis=1)
    blk = a_sorted & (csum > cst["ccws_budget"][:, None])
    blk = blk.at[:, 0].set(False)      # the top-score warp always runs
    blocked = jnp.zeros_like(blk).at[arB[:, None], order].set(blk)
    st = dict(st)
    st["score_pl"] = score
    st["allowed_pl"] = jnp.where(m[:, None], ~blocked, st["allowed_pl"])
    return st


def _statp_tick(S, cst, st, m):
    cyc = st["cycle"]
    # single-SM: the chip-wide request counter is the row's own
    reqs = st["dram_requests"]
    util = jnp.where(
        cyc > 0,
        _f64(reqs * cst["dram_gap"])
        / _f64(jnp.maximum(S.dram_channels * cyc, 1)), 0.0)
    util = jnp.minimum(util, 1.0)
    new = util < cst["sp_thresh"]
    ch = m & (new != st["sp_bypass"])
    bm = st["sp_base"]
    st = dict(st)
    st["sp_bypass"] = jnp.where(ch, new, st["sp_bypass"])
    st["allowed_pl"] = jnp.where(ch[:, None],
                                 new[:, None] | bm, st["allowed_pl"])
    st["bypass_pl"] = jnp.where(ch[:, None],
                                new[:, None] & ~bm, st["bypass_pl"])
    return st


def _irs_cum_leq(S, cst, st, wid, act):
    """Single-rounding cumulative-IRS cutoff (epoch.irs_cum_leq)."""
    arB = jnp.arange(st["cycle"].shape[0])
    inst = st["d_irs_inst"]
    hits = st["d_irs_hits"][arB, wid % S.nw]
    bad = (inst <= 0) | (act <= 0)
    return bad | (_f64(hits * act) <= cst["low_cutoff"] * _f64(inst))


def _ciao_low(S, cst, st, m, act):
    """epoch.ciao_low_tick: pop at most one stalled and one isolated
    warp per flagged cell, newest first."""
    arB = jnp.arange(st["cycle"].shape[0])
    le = S.le
    st = dict(st)
    sl = st["stall_len"]
    has = m & (sl > 0)
    top = st["ciao_stall"][arB, jnp.maximum(sl - 1, 0)]
    topc = jnp.where(has, top, 0)
    k1 = st["d_pair_list"][arB, topc % le, 1]
    kc = jnp.where(k1 >= 0, k1, 0)
    pop = has & ((k1 == NO_WARP) | st["done"][arB, kc]
                 | _irs_cum_leq(S, cst, st, kc, act))
    st["stall_len"] = sl - pop
    st["allowed_pl"] = st["allowed_pl"].at[arB, topc].set(
        st["allowed_pl"][arB, topc] | pop)
    st["d_pair_list"] = st["d_pair_list"].at[arB, topc % le, 1].set(
        jnp.where(pop, NO_WARP, st["d_pair_list"][arB, topc % le, 1]))
    # isolated pops read `allowed` after the stall pops (scalar order)
    il = st["iso_len"]
    hasi = m & (il > 0)
    topi = st["ciao_iso"][arB, jnp.maximum(il - 1, 0)]
    tic = jnp.where(hasi, topi, 0)
    ok = hasi & st["allowed_pl"][arB, tic]
    k2 = st["d_pair_list"][arB, tic % le, 0]
    k2c = jnp.where(k2 >= 0, k2, 0)
    pop2 = ok & ((k2 == NO_WARP) | st["done"][arB, k2c]
                 | _irs_cum_leq(S, cst, st, k2c, act))
    st["iso_len"] = il - pop2
    st["isolated_pl"] = st["isolated_pl"].at[arB, tic].set(
        st["isolated_pl"][arB, tic] & ~pop2)
    st["d_pair_list"] = st["d_pair_list"].at[arB, tic % le, 0].set(
        jnp.where(pop2, NO_WARP, st["d_pair_list"][arB, tic % le, 0]))
    return st


def _ciao_high(S, cst, st, m):
    """epoch.ciao_high_tick: the batched descending-IRS walk and the one
    isolate/stall action per flagged cell."""
    B = st["cycle"].shape[0]
    n, le = S.n, S.le
    arB = jnp.arange(B)
    st = dict(st)
    alive = st["allowed_pl"] & ~st["done"]
    act = st["d_high_snap_act"][:, None]
    win = st["d_high_snap_win"][:, None]
    hits = st["d_high_snap_hits"][:, np.arange(n) % S.nw]
    over = _f64(hits * act) > cst["high_cutoff"][:, None] * _f64(win)
    cand = m[:, None] & alive & over \
        & (jnp.sum(alive, axis=1) > 1)[:, None]
    order = jnp.argsort(jnp.where(cand, -hits, _DEAD_KEY), axis=1,
                        stable=True)
    cand_s = jnp.take_along_axis(cand, order, 1)
    j = st["d_interfering"][arB[:, None], order % le]
    jc = jnp.where(j >= 0, j, 0)
    valid = cand_s & (j != NO_WARP) & (j != order) \
        & ~st["done"][arB[:, None], jc]
    iso_j = st["isolated_pl"][arB[:, None], jc]
    alw_j = st["allowed_pl"][arB[:, None], jc]
    mp = cst["mode_p"][:, None]
    mt = cst["mode_t"][:, None]
    p_ok = valid & mp & ~iso_j & alw_j
    t_ok = valid & mt & alw_j & (iso_j | ~mp)
    hit = p_ok | t_ok
    changed = hit.any(axis=1)
    pos = jnp.argmax(hit, axis=1)           # first actionable walk pos
    take_p = changed & p_ok[arB, pos]
    take_t = changed & ~take_p
    jj = jnp.where(changed, j[arB, pos], 0)     # the victim warp
    ii = order[arB, pos]                        # the interferer
    ilc = jnp.minimum(st["iso_len"], n - 1)
    st["isolated_pl"] = st["isolated_pl"].at[arB, jj].set(
        st["isolated_pl"][arB, jj] | take_p)
    st["d_pair_list"] = st["d_pair_list"].at[arB, jj % le, 0].set(
        jnp.where(take_p, ii, st["d_pair_list"][arB, jj % le, 0]))
    st["ciao_iso"] = st["ciao_iso"].at[arB, ilc].set(
        jnp.where(take_p, jj, st["ciao_iso"][arB, ilc]))
    st["iso_len"] = st["iso_len"] + take_p
    slc = jnp.minimum(st["stall_len"], n - 1)
    st["allowed_pl"] = st["allowed_pl"].at[arB, jj].set(
        st["allowed_pl"][arB, jj] & ~take_t)
    st["d_pair_list"] = st["d_pair_list"].at[arB, jj % le, 1].set(
        jnp.where(take_t, ii, st["d_pair_list"][arB, jj % le, 1]))
    st["ciao_stall"] = st["ciao_stall"].at[arB, slc].set(
        jnp.where(take_t, jj, st["ciao_stall"][arB, slc]))
    st["stall_len"] = st["stall_len"] + take_t
    return st


def _ciao_tick(S, cst, st, m):
    """epoch.poll_epochs (snapshots + aging) then the low/high ticks."""
    arB = jnp.arange(st["cycle"].shape[0])
    st = dict(st)
    n_act = jnp.maximum(
        jnp.sum(st["allowed_pl"] & ~st["done"], axis=1), 1)
    ws = np.arange(S.nw) % S.v_sets             # wid -> vta set (static)
    it = st["d_inst_total"]
    cur = st["d_vta_hits"][:, ws]
    lo, hi = cst["low_epoch"], cst["high_epoch"]
    lowm = m & ((it // lo) != st["d_low_idx"])
    win = jnp.maximum(it - st["d_low_base_inst"], 1)
    st["d_low_idx"] = jnp.where(lowm, it // lo, st["d_low_idx"])
    st["d_low_snap_hits"] = jnp.where(
        lowm[:, None], cur - st["d_low_base_hits"], st["d_low_snap_hits"])
    st["d_low_snap_win"] = jnp.where(lowm, win, st["d_low_snap_win"])
    st["d_low_snap_act"] = jnp.where(lowm, n_act, st["d_low_snap_act"])
    st["d_low_base_hits"] = jnp.where(lowm[:, None], cur,
                                      st["d_low_base_hits"])
    st["d_low_base_inst"] = jnp.where(lowm, it, st["d_low_base_inst"])
    highm = m & ((it // hi) != st["d_high_idx"])
    winh = jnp.maximum(it - st["d_high_base_inst"], 1)
    st["d_high_idx"] = jnp.where(highm, it // hi,
                                 st["d_high_idx"])
    st["d_high_snap_hits"] = jnp.where(
        highm[:, None], cur - st["d_high_base_hits"],
        st["d_high_snap_hits"])
    st["d_high_snap_win"] = jnp.where(highm, winh, st["d_high_snap_win"])
    st["d_high_snap_act"] = jnp.where(highm, n_act,
                                      st["d_high_snap_act"])
    st["d_high_base_hits"] = jnp.where(highm[:, None], cur,
                                       st["d_high_base_hits"])
    st["d_high_base_inst"] = jnp.where(highm, it,
                                       st["d_high_base_inst"])
    st["d_high_crossings"] = st["d_high_crossings"] + highm
    ag = cst["aging"]
    aged = highm & (ag > 0) \
        & (st["d_high_crossings"] % jnp.maximum(ag, 1) == 0)
    st["d_irs_inst"] = jnp.where(aged, st["d_irs_inst"] // 2,
                                 st["d_irs_inst"])
    st["d_irs_hits"] = jnp.where(aged[:, None],
                                 st["d_irs_hits"] // 2,
                                 st["d_irs_hits"])
    st = _gated(st, lowm,
                lambda s, mm, a: _ciao_low(S, cst, s, mm, a), n_act)
    st = _gated(st, highm, lambda s, mm: _ciao_high(S, cst, s, mm))
    del arB
    return st


def _epoch_service(S, cst, st, mask, anchor):
    """BatchedSMEngine._epoch_batch: snapshot the IRS denominators, run
    the family ticks, refresh the dispatch masks, advance the anchors."""
    st = dict(st)
    li = st["li"]
    fam = cst["fam"]
    st["d_inst_total"] = jnp.where(mask, li, st["d_inst_total"])
    st["d_irs_inst"] = jnp.where(mask, li - st["irs_off"],
                                 st["d_irs_inst"])
    st = _gated(st, mask & (fam == F_CCWS),
                lambda s, mm: _ccws_tick(S, cst, s, mm))
    st = _gated(st, mask & (fam == F_STATP),
                lambda s, mm: _statp_tick(S, cst, s, mm))
    st = _gated(st, mask & (fam == F_CIAO),
                lambda s, mm: _ciao_tick(S, cst, s, mm))
    st["irs_off"] = jnp.where(mask, li - st["d_irs_inst"],
                              st["irs_off"])             # aging moves it
    st["avail"] = jnp.where(mask[:, None],
                            st["allowed_pl"] & ~st["done"], st["avail"])
    st["iso"] = jnp.where(mask[:, None], st["isolated_pl"], st["iso"])
    st["byp"] = jnp.where(mask[:, None], st["bypass_pl"], st["byp"])
    lo, hi = cst["low_epoch"], cst["high_epoch"]
    nxt = (li // lo + 1) * lo
    skip = cst["stride_ok"] & (fam == F_CIAO) \
        & (st["stall_len"] + st["iso_len"] == 0)
    nxt = jnp.where(skip, (li // hi + 1) * hi, nxt)
    st["next_epoch"] = jnp.where(anchor, nxt, st["next_epoch"])
    return st


def _warp_done(S, cst, st, fin, widc):
    """BatchedSMEngine._warp_done_rows minus the remaining-decrement
    (done by the caller): Best-SWL / statPCAL released-set rotation."""
    arB = jnp.arange(st["cycle"].shape[0])
    n = S.n
    st = dict(st)
    for kind, key in ((WD_SWL, "allowed_pl"), (WD_STATP, "sp_base")):
        km = fin & (cst["wd_kind"] == kind)
        mask_pl = st[key]
        in_set = km & mask_pl[arB, widc]
        mask_pl = mask_pl.at[arB, widc].set(
            mask_pl[arB, widc] & ~in_set)
        nx = st["swl_next"]
        can = in_set & (nx < n)
        nxc = jnp.minimum(nx, n - 1)
        mask_pl = mask_pl.at[arB, nxc].set(mask_pl[arB, nxc] | can)
        st[key] = mask_pl
        st["swl_next"] = jnp.where(can, nx + 1, nx)
        if kind == WD_STATP:
            sb = st["sp_bypass"][:, None]
            st["allowed_pl"] = jnp.where(in_set[:, None],
                                         sb | mask_pl, st["allowed_pl"])
            st["bypass_pl"] = jnp.where(in_set[:, None],
                                        sb & ~mask_pl, st["bypass_pl"])
        st["avail"] = jnp.where(in_set[:, None],
                                st["allowed_pl"] & ~st["done"],
                                st["avail"])
        st["byp"] = jnp.where(in_set[:, None], st["bypass_pl"],
                              st["byp"])
    return st


def _timeline(S, st, m):
    """BatchedSMEngine._timeline_rows."""
    arB = jnp.arange(st["cycle"].shape[0])
    st = dict(st)
    act = jnp.sum(st["allowed_pl"], axis=1)
    k = st["tl_n"]
    kc = jnp.minimum(k, S.tl_cap - 1)           # capacity is proven ample
    cyc, ins = st["cycle"], st["instr"]
    dc = jnp.maximum(cyc - st["last_cycle"], 1)
    dipc = _f64(ins - st["last_instr"]) / _f64(dc)
    st["tl_cycle"] = st["tl_cycle"].at[arB, kc].set(
        jnp.where(m, cyc, st["tl_cycle"][arB, kc]))
    st["tl_dipc"] = st["tl_dipc"].at[arB, kc].set(
        jnp.where(m, dipc, st["tl_dipc"][arB, kc]))
    st["tl_act"] = st["tl_act"].at[arB, kc].set(
        jnp.where(m, act, st["tl_act"][arB, kc]))
    st["tl_n"] = jnp.where(m, k + 1, k)
    st["last_instr"] = jnp.where(m, ins, st["last_instr"])
    st["last_cycle"] = jnp.where(m, cyc, st["last_cycle"])
    st["window_mark"] = jnp.where(m, st["window_mark"] + S.timeline_every,
                                  st["window_mark"])
    return st


def _vta_insert(S, st, mask, owner, victim_line, evictor):
    """BatchedSMEngine._np_vta_insert (circular FIFO insert)."""
    arB = jnp.arange(st["cycle"].shape[0])
    v_k = S.v_k
    st = dict(st)
    s = owner % S.v_sets
    h = st["v_head"][arB, s]
    cc = st["v_count"][arB, s]
    full = cc == v_k
    slot = s * v_k + jnp.where(full, h, (h + cc) % v_k)
    st["v_addr"] = st["v_addr"].at[arB, slot].set(
        jnp.where(mask, victim_line, st["v_addr"][arB, slot]))
    st["v_evic"] = st["v_evic"].at[arB, slot].set(
        jnp.where(mask, evictor, st["v_evic"][arB, slot]))
    st["v_head"] = st["v_head"].at[arB, s].set(
        jnp.where(mask & full, (h + 1) % v_k, h))
    st["v_count"] = st["v_count"].at[arB, s].set(
        jnp.where(mask & ~full, cc + 1, cc))
    st["v_inserts"] = st["v_inserts"] + mask
    return st


def _vta_probe(S, cst, st, pm, widc, line):
    """The probe + FIFO pop + detector bookkeeping, vectorized over the
    logical window (BatchedSMEngine._vta_probe_pop per flagged row)."""
    B = st["cycle"].shape[0]
    arB = jnp.arange(B)
    v_k = S.v_k
    st = dict(st)
    s = widc % S.v_sets
    base = s * v_k
    h = st["v_head"][arB, s]
    cc = st["v_count"][arB, s]
    ar_k = jnp.arange(v_k)
    phys = base[:, None] + (h[:, None] + ar_k) % v_k
    lvals = st["v_addr"][arB[:, None], phys]
    levic = st["v_evic"][arB[:, None], phys]
    member = pm & (lvals == line[:, None]).any(1)
    matchl = (lvals == line[:, None]) & (ar_k[None] < cc[:, None])
    found = member & matchl.any(1)
    jm = jnp.argmax(matchl, axis=1)             # oldest logical match
    evictor = jnp.where(found, levic[arB, jm], NO_WARP)
    shift = found[:, None] & (ar_k >= jm[:, None]) \
        & (ar_k < (cc - 1)[:, None])
    nl = jnp.where(shift, jnp.roll(lvals, -1, axis=1), lvals)
    ne = jnp.where(shift, jnp.roll(levic, -1, axis=1), levic)
    clear = found[:, None] & (ar_k == (cc - 1)[:, None])
    nl = jnp.where(clear, -1, nl)
    ne = jnp.where(clear, -1, ne)
    st["v_addr"] = st["v_addr"].at[arB[:, None], phys].set(nl)
    st["v_evic"] = st["v_evic"].at[arB[:, None], phys].set(ne)
    st["v_count"] = st["v_count"].at[arB, s].set(cc - found)
    st["d_vta_hits"] = st["d_vta_hits"].at[arB, s].add(found)
    st["vta_hit_events"] = st["vta_hit_events"] + member
    st["cnt_vta_hits"] = st["cnt_vta_hits"] + member
    st["d_irs_hits"] = st["d_irs_hits"].at[arB, widc % S.nw].add(member)
    pidx = (evictor + 1) * S.n + widc
    st["pair_dense"] = st["pair_dense"].at[arB, pidx].add(member)
    # interference list (2-bit saturating replacement)
    i = widc % S.le
    interf = st["d_interfering"][arB, i]
    sat = st["d_sat"][arB, i]
    same = interf == evictor
    empty = interf == NO_WARP
    ni = jnp.where(same, interf,
                   jnp.where(empty | (sat == 0), evictor, interf))
    ns = jnp.where(same, jnp.minimum(sat + 1, S.sat_max),
                   jnp.where(empty, 0,
                             jnp.where(sat == 0, sat, sat - 1)))
    st["d_interfering"] = st["d_interfering"].at[arB, i].set(
        jnp.where(member, ni, interf))
    st["d_sat"] = st["d_sat"].at[arB, i].set(jnp.where(member, ns, sat))
    # CCWS lost-locality bump (policy.on_mem_event(wid, "vta_hit"))
    st["score_pl"] = st["score_pl"].at[arB, widc].add(
        jnp.where(member, cst["bump"], 0))
    return st


def _mem_chain(S, cst, st, mem, tok, widc, cycle):
    """BatchedSMEngine._np_mem_chain. Returns (st, lat, done_t parts are
    derived by the caller): all state scatters happen here, ``lat`` is
    the per-row access latency."""
    B = st["cycle"].shape[0]
    arB = jnp.arange(B)
    st = dict(st)
    line = tok >> _SHIFT
    bypm = mem & st["byp"][arB, widc]
    isom = mem & st["iso"][arB, widc] & ~bypm
    norm = mem & ~bypm & ~isom
    st["cnt_bypass"] = st["cnt_bypass"] + bypm
    post = bypm
    lat = jnp.zeros(B, jnp.int64)

    # ---- L1 way scan (shared with the CIAO-P migration probe) ----
    s1 = line % S.l1_sets
    if S.xor_hash:
        s1 = (s1 ^ ((line // S.l1_sets) % S.l1_sets)) % S.l1_sets
    base1 = s1 * S.l1_ways
    way_idx = base1[:, None] + jnp.arange(S.l1_ways)
    tags = st["l1_tags"]
    eq = jnp.take_along_axis(tags, way_idx, 1) == line[:, None]
    resident = eq.any(1)
    f_hit = base1 + jnp.argmax(eq, axis=1)

    hit = norm & resident
    miss = norm & ~resident
    st["cnt_l1_hit"] = st["cnt_l1_hit"] + hit
    st["cnt_l1_miss"] = st["cnt_l1_miss"] + miss
    reused = st["l1_reused"].at[arB, f_hit].set(
        st["l1_reused"][arB, f_hit] | hit)
    stamp = st["l1_stamp"].at[arB, f_hit].set(
        jnp.where(hit, st["tick"], st["l1_stamp"][arB, f_hit]))
    lat = jnp.where(hit, cst["lat_l1"], lat)

    # ---- CIAO-P smem region: evictions insert before the probe ----
    rb = cst["region_blocks"]
    no_region = isom & (rb <= 0)
    post = post | no_region
    iso2 = isom & ~no_region
    sidx = line % jnp.maximum(rb, 1)
    sold = st["smem_tags"][arB, sidx]
    shit = iso2 & (sold == line)
    st["cnt_smem_hit"] = st["cnt_smem_hit"] + shit
    lat = jnp.where(shit, cst["lat_smem"], lat)
    smiss = iso2 & ~shit
    sevict = smiss & (sold >= 0)
    st["cnt_smem_evictions"] = st["cnt_smem_evictions"] + sevict
    sown = st["smem_owner"][arB, sidx]
    st = _vta_insert(S, st, sevict & (sown != widc), sown, sold, widc)

    # ---- VTA probe (after smem inserts, before L1-fill inserts) ----
    st = _vta_probe(S, cst, st, miss | smiss, widc, line)

    # ---- L1 fill (miss path) ----
    vic = base1 + jnp.argmin(jnp.take_along_axis(stamp, way_idx, 1),
                             axis=1)
    old = tags[arB, vic]
    owners = st["l1_owners"]
    oldown = owners[arB, vic]
    oldreu = reused[arB, vic]
    evict = miss & (old >= 0)
    st["cnt_evictions"] = st["cnt_evictions"] + evict
    ins = evict & (oldown != widc)
    if S.reuse_filter:
        ins = ins & oldreu
    st = _vta_insert(S, st, ins, oldown, old, widc)
    tags = tags.at[arB, vic].set(jnp.where(miss, line, old))
    owners = owners.at[arB, vic].set(jnp.where(miss, widc, oldown))
    reused = reused.at[arB, vic].set(jnp.where(miss, False, oldreu))
    stamp = stamp.at[arB, vic].set(
        jnp.where(miss, st["tick"], stamp[arB, vic]))
    post = post | miss

    # ---- smem migration / fill (after the probe, like the scalar) ----
    mig = smiss & resident
    tags = tags.at[arB, f_hit].set(
        jnp.where(mig, -1, tags[arB, f_hit]))
    owners = owners.at[arB, f_hit].set(
        jnp.where(mig, -1, owners[arB, f_hit]))
    st["cnt_smem_migrate"] = st["cnt_smem_migrate"] + mig
    lat = jnp.where(mig, cst["lat_migrate"], lat)
    smiss2 = smiss & ~mig
    st["cnt_smem_miss"] = st["cnt_smem_miss"] + smiss2
    post = post | smiss2
    st["smem_tags"] = st["smem_tags"].at[arB, sidx].set(
        jnp.where(smiss, line, sold))
    st["smem_owner"] = st["smem_owner"].at[arB, sidx].set(
        jnp.where(smiss, widc, st["smem_owner"][arB, sidx]))
    st["l1_tags"], st["l1_owners"] = tags, owners
    st["l1_reused"], st["l1_stamp"] = reused, stamp
    st["tick"] = st["tick"] + norm

    # ---- post-L1 stage: L2 tags + DRAM bandwidth queueing ----
    b2 = (line % S.l2_sets) * S.l2_ways
    wi2 = b2[:, None] + jnp.arange(S.l2_ways)
    t2 = st["l2_tags"]
    eq2 = jnp.take_along_axis(t2, wi2, 1) == line[:, None]
    l2res = eq2.any(1)
    h2 = post & l2res
    m2 = post & ~l2res
    st["l2_hits"] = st["l2_hits"] + h2
    lat = jnp.where(h2, cst["lat_l2"], lat)
    f2 = b2 + jnp.argmax(eq2, axis=1)
    vic2 = b2 + jnp.argmin(jnp.take_along_axis(st["l2_stamp"], wi2, 1),
                           axis=1)
    st["l2_tags"] = t2.at[arB, vic2].set(
        jnp.where(m2, line, t2[arB, vic2]))
    st["l2_misses"] = st["l2_misses"] + m2
    chn = (line >> 2) % S.dram_channels
    free = st["dram_free"][arB, chn]
    start = jnp.maximum(cycle, free)
    st["dram_free"] = st["dram_free"].at[arB, chn].set(
        jnp.where(m2, start + cst["dram_gap"], free))
    st["dram_requests"] = st["dram_requests"] + m2
    st["cnt_dram_reqs"] = st["cnt_dram_reqs"] + m2
    lat = jnp.where(m2, cst["lat_dram"] + start - cycle, lat)
    f2 = jnp.where(m2, vic2, f2)
    st["l2_stamp"] = st["l2_stamp"].at[arB, f2].set(
        jnp.where(post, st["l2_tick"], st["l2_stamp"][arB, f2]))
    st["l2_tick"] = st["l2_tick"] + post
    return st, lat


def _iteration(S, cst, st):
    """One lockstep iteration == BatchedSMEngine._np_iteration for a
    single-SM batch that runs to the cycle cap (until == max_cycles)."""
    B = st["cycle"].shape[0]
    arB = jnp.arange(B)
    st = dict(st)
    cycle = st["cycle"]
    act = (st["remaining"] > 0) & (cycle < cst["max_cycles"])

    # ---- warp selection (greedy-then-oldest + fused event skip) ----
    ready, avail = st["ready"], st["avail"]
    lw = st["last_wid"]
    lw_ok = lw >= 0
    lwc = jnp.where(lw_ok, lw, 0)
    greedy = act & lw_ok & avail[arB, lwc] & (ready[arB, lwc] <= cycle)
    wid = jnp.where(greedy, lw, -1)
    need = act & ~greedy
    cand = (ready <= cycle[:, None]) & avail
    w = jnp.argmax(cand, axis=1)
    found = need & cand[arB, w]
    wid = jnp.where(found, w, wid)
    lw = jnp.where(found, w, lw)
    skip = need & ~found
    sched = jnp.where(avail, ready, _HUGE)
    w2 = jnp.argmin(sched, axis=1)
    thr = skip & ~avail[arB, w2]
    # everything throttled: advance to let epochs fire (no re-anchor)
    st["cycle"] = cycle = jnp.where(thr, cycle + cst["low_epoch"], cycle)
    st["li"] = jnp.where(thr, st["li"] + cst["low_epoch"], st["li"])
    st = _gated(st, thr,
                lambda s, mm: _epoch_service(S, cst, s, mm,
                                             jnp.zeros_like(mm)))
    sk = skip & ~thr
    best = ready[arB, w2]
    clamp = sk & (best >= cst["max_cycles"])    # slice stop at the cap
    st["cycle"] = cycle = jnp.where(
        clamp, cst["max_cycles"], jnp.where(sk & ~clamp, best, cycle))
    sk = sk & ~clamp
    lw_ok2 = lw >= 0
    lwc2 = jnp.where(lw_ok2, lw, 0)
    tie = sk & lw_ok2 & st["avail"][arB, lwc2] \
        & (ready[arB, lwc2] <= best)
    wid = jnp.where(tie, lw, wid)
    w2sel = sk & ~tie
    wid = jnp.where(w2sel, w2, wid)
    lw = jnp.where(w2sel, w2, lw)
    st["last_wid"] = lw

    disp = act & (wid >= 0)
    widc = jnp.where(disp, wid, 0)

    # ---- token fetch ----
    oi = st["op_idx"][arB, widc]
    tok = cst["toks"][cst["u_of"], widc, oi]
    alu = disp & (tok < 0)
    mem = disp & ~alu
    adv = jnp.where(alu, -tok, 0) + mem

    new_ready = st["ready"][arB, widc]
    st, lat = _mem_chain(S, cst, st, mem, tok, widc, cycle)
    done_t = cycle + lat
    dep = mem & ((tok & 1) == 1)
    nondep = mem & ~dep
    new_ready = jnp.where(dep, done_t, new_ready)
    prow = st["pend"][arB, widc]                 # (B, P)
    slot = jnp.argmin(prow, axis=1)              # a stale (<=cycle) slot
    nv = jnp.where(nondep, done_t, prow[arB, slot])
    st["pend"] = st["pend"].at[arB, widc, slot].set(nv)
    prow = prow.at[arB, slot].set(nv)
    valid = prow > cycle[:, None]
    outstanding = jnp.sum(valid, axis=1)
    earliest = jnp.min(jnp.where(valid, prow, _HUGE), axis=1)
    new_ready = jnp.where(
        nondep,
        jnp.where(outstanding >= S.max_mlp, earliest, cycle + 1),
        new_ready)
    new_ready = jnp.where(alu, cycle + adv, new_ready)

    adv = jnp.where(disp, adv, 0)
    st["li"] = st["li"] + adv
    st["cycle"] = cycle = cycle + adv            # mem rows: +1
    st["ready"] = st["ready"].at[arB, widc].set(new_ready)
    oi_new = oi + disp
    st["op_idx"] = st["op_idx"].at[arB, widc].set(oi_new)
    st["instr"] = st["instr"] + adv

    # ---- warp retirement -> epoch -> timeline (the scalar order) ----
    fin = disp & (oi_new >= cst["n_ops"][arB, widc])
    st["done"] = st["done"].at[arB, widc].set(
        st["done"][arB, widc] | fin)
    st["avail"] = st["avail"].at[arB, widc].set(
        st["avail"][arB, widc] & ~fin)
    st["last_wid"] = jnp.where(fin, -1, st["last_wid"])
    st["remaining"] = st["remaining"] - fin
    st = _gated(st, fin,
                lambda s, mm, ww: _warp_done(S, cst, s, mm, ww), widc)
    ep = disp & (st["li"] >= st["next_epoch"])
    st = _gated(st, ep, lambda s, mm: _epoch_service(S, cst, s, mm, mm))
    tl = disp & (st["instr"] >= st["window_mark"])
    st = _gated(st, tl, lambda s, mm: _timeline(S, s, mm))
    return st


@functools.lru_cache(maxsize=None)
def _compiled(S: _Static):
    def run(state, cst):
        def cond(st):
            return jnp.any((st["remaining"] > 0)
                           & (st["cycle"] < cst["max_cycles"]))

        def body(st):
            return _iteration(S, cst, st)
        return lax.while_loop(cond, body, state)
    return jax.jit(run)


def run_engine(eng) -> None:
    """Run every row of a BatchedSMEngine to completion under jit and
    write the final state back into the engine's numpy arrays; the
    engine's ``_finalize`` then assembles results exactly like the
    numpy/C paths. Raises RuntimeError when :func:`supports_engine`
    says no."""
    why = supports_engine(eng)
    if why:
        raise RuntimeError(f"jax backend unavailable for this batch: "
                           f"{why}")
    S = _static_of(eng)
    state, cst = _arrays_of(eng)
    with jax.experimental.enable_x64():
        fn = _compiled(S)
        t0 = time.perf_counter()
        out = jax.device_get(fn(state, cst))
        eng.perf["stepper_s"] += time.perf_counter() - t0
        eng.perf["rounds"] += 1
    t0 = time.perf_counter()
    _write_back(eng, out)
    for b in range(eng.B):
        eng._finalize(b)
    eng.perf["drain_s"] += time.perf_counter() - t0
