from repro.core.vta import VictimTagArray  # noqa: F401
from repro.core.interference import InterferenceDetector, DetectorConfig  # noqa: F401
from repro.core.onchip import OnChipMemory, OnChipConfig  # noqa: F401
from repro.core.policies import (  # noqa: F401
    GTOPolicy, CCWSPolicy, BestSWLPolicy, StatPCALPolicy,
    CIAOPolicy, make_policy, POLICY_NAMES)
from repro.core.simulator import SMSimulator, SimConfig, SimResult  # noqa: F401
from repro.core.traces import make_workload, WORKLOADS  # noqa: F401
