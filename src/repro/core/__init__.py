from repro.core.vta import VictimTagArray  # noqa: F401
from repro.core.interference import InterferenceDetector, DetectorConfig  # noqa: F401
from repro.core.onchip import OnChipMemory, OnChipConfig  # noqa: F401
from repro.core.memory import (  # noqa: F401
    BankedL2, DRAMModel, L2TagArray, MemoryHierarchy)
from repro.core.policies import (  # noqa: F401
    GTOPolicy, CCWSPolicy, BestSWLPolicy, StatPCALPolicy,
    CIAOPolicy, make_policy, POLICY_NAMES)
from repro.core.simulator import (  # noqa: F401
    SMSimulator, SimConfig, SimResult, run_policy_sweep)
from repro.core.gpu import (  # noqa: F401
    CTA, CTAScheduler, GPUConfig, GPUResult, GPUSimulator, make_ctas,
    run_gpu_policy_sweep)
from repro.core.batched import (  # noqa: F401
    BatchCell, BatchedSMEngine, DeadlineExceeded, run_batched,
    supports_config)
from repro.core.faults import (  # noqa: F401
    FaultPlan, FaultSpec, InjectedFault)
from repro.core.ledger import RunLedger, grid_hash  # noqa: F401
from repro.core.runner import (  # noqa: F401
    ExperimentGrid, FailedCell, RunRecord, geomean, index_records,
    load_records, run_grid, save_records)
from repro.workloads import (  # noqa: F401
    WORKLOADS, Workload, load_workload, make_workload, register_workload,
    save_workload)
