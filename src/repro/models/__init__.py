from repro.models import model  # noqa: F401
from repro.models.model import (  # noqa: F401
    init_params,
    param_specs,
    forward_train,
    loss_fn,
    init_cache,
    cache_specs,
    prefill,
    decode_step,
    input_specs,
)
