"""Unified scan-over-layers LM covering all 10 assigned architectures.

Public API:
  init_params(cfg, key, run)          -> params pytree
  param_specs(cfg)                    -> parallel pytree of logical-axis tuples
  forward_train(env, cfg, params, batch, run) -> (B, S, d) final hidden
  loss_fn(env, cfg, params, batch, run)       -> scalar CE loss
  init_cache(cfg, batch, max_len)     -> decode cache pytree
  cache_specs(cfg)                    -> logical-axis tuples for the cache
  prefill(env, cfg, params, batch, run)       -> (last_logits, cache, pos)
  decode_step(env, cfg, params, token, pos, cache, run) -> (logits, cache)
  input_specs(cfg, shape, run)        -> ShapeDtypeStruct stand-ins per mode

Layer stacks are scanned over the repeating block ``pattern`` (HLO size is
O(1) in depth); remainder layers run unscanned. Decode positions are
per-sequence ``(B,)`` vectors so the serving engine can batch ragged
sequences.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (
    ATTN_BLOCKS, BLOCK_GLOBAL_ATTN, BLOCK_LOCAL_ATTN, BLOCK_RGLRU, BLOCK_SSD,
    ModelConfig, RunConfig, ShapeConfig)
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssd as ssd_mod
from repro.parallel.sharding import ShardEnv


# ============================================================ block builders
def _block_init(cfg: ModelConfig, kind: str, key, dtype):
    ks = jax.random.split(key, 8)
    if kind in ATTN_BLOCKS:
        p: Dict[str, Any] = {}
        s: Dict[str, Any] = {}
        p["ln1"], s["ln1"] = L.rmsnorm_init(cfg.d_model)
        p["attn"], s["attn"] = attn.attn_init(cfg, ks[0], dtype)
        p["ln2"], s["ln2"] = L.rmsnorm_init(cfg.d_model)
        if cfg.num_experts:
            p["moe"], s["moe"] = moe_mod.moe_init(cfg, ks[1], dtype)
            if cfg.moe_dense_residual:
                p["mlp"], s["mlp"] = L.mlp_init(
                    ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_activation, dtype)
        else:
            p["mlp"], s["mlp"] = L.mlp_init(
                ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_activation, dtype)
        if cfg.is_encoder_decoder:
            p["ln_cross"], s["ln_cross"] = L.rmsnorm_init(cfg.d_model)
            p["cross"], s["cross"] = attn.attn_init(cfg, ks[3], dtype, cross=True)
        return p, s
    if kind == BLOCK_RGLRU:
        p, s = {}, {}
        p["ln1"], s["ln1"] = L.rmsnorm_init(cfg.d_model)
        p["rglru"], s["rglru"] = rglru_mod.rglru_init(cfg, ks[0], dtype)
        p["ln2"], s["ln2"] = L.rmsnorm_init(cfg.d_model)
        p["mlp"], s["mlp"] = L.mlp_init(
            ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_activation, dtype)
        return p, s
    if kind == BLOCK_SSD:
        p, s = {}, {}
        p["ln1"], s["ln1"] = L.rmsnorm_init(cfg.d_model)
        p["ssd"], s["ssd"] = ssd_mod.ssd_init(cfg, ks[0], dtype)
        return p, s
    raise ValueError(kind)


def _encoder_block_init(cfg, key, dtype):
    ks = jax.random.split(key, 3)
    p, s = {}, {}
    p["ln1"], s["ln1"] = L.rmsnorm_init(cfg.d_model)
    p["attn"], s["attn"] = attn.attn_init(cfg, ks[0], dtype)
    p["ln2"], s["ln2"] = L.rmsnorm_init(cfg.d_model)
    p["mlp"], s["mlp"] = L.mlp_init(
        ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_activation, dtype)
    return p, s


def _add_layers_axis(specs):
    return jax.tree.map(
        lambda sp: ("layers",) + sp,
        specs,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def _stack_init(init_one, repeats: int, key):
    keys = jax.random.split(key, repeats)
    params = jax.vmap(lambda k: init_one(k)[0])(keys)
    _, specs = init_one(key)
    return params, _add_layers_axis(specs)


# ================================================================= full init
def _init(cfg: ModelConfig, key, dtype):
    ks = jax.random.split(key, 12)
    p: Dict[str, Any] = {}
    s: Dict[str, Any] = {}
    p["embed"], s["embed"] = L.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        p["lm_head"], s["lm_head"] = L.lm_head_init(
            ks[1], cfg.d_model, cfg.vocab_size, dtype)

    reps = cfg.scan_repeats
    if reps:
        stack_p, stack_s = {}, {}
        for i, kind in enumerate(cfg.pattern):
            stack_p[f"b{i}"], stack_s[f"b{i}"] = _stack_init(
                lambda k, kind=kind: _block_init(cfg, kind, k, dtype),
                reps, ks[2 + i % 4])
        p["stack"], s["stack"] = stack_p, stack_s
    rem_p, rem_s = [], []
    for i, kind in enumerate(cfg.remainder_blocks):
        bp, bs = _block_init(cfg, kind, jax.random.fold_in(ks[6], i), dtype)
        rem_p.append(bp)
        rem_s.append(bs)
    if rem_p:
        p["rem"], s["rem"] = tuple(rem_p), tuple(rem_s)
    p["final_norm"], s["final_norm"] = L.rmsnorm_init(cfg.d_model)

    if cfg.is_encoder_decoder:
        enc_p, enc_s = {}, {}
        enc_p["stack"], enc_s["stack"] = _stack_init(
            lambda k: _encoder_block_init(cfg, k, dtype),
            cfg.num_encoder_layers, ks[7])
        enc_p["final_norm"], enc_s["final_norm"] = L.rmsnorm_init(cfg.d_model)
        p["encoder"], s["encoder"] = enc_p, enc_s
    return p, s


def init_params(cfg: ModelConfig, key, run: Optional[RunConfig] = None):
    dtype = jnp.dtype((run or RunConfig()).param_dtype)
    return _init(cfg, key, dtype)[0]


def param_specs(cfg: ModelConfig):
    box = {}

    def f(key):
        params, specs = _init(cfg, key, jnp.bfloat16)
        box["s"] = specs
        return params

    jax.eval_shape(f, jax.random.PRNGKey(0))
    return box["s"]


def param_shapes(cfg: ModelConfig, run: Optional[RunConfig] = None):
    dtype = jnp.dtype((run or RunConfig()).param_dtype)
    return jax.eval_shape(
        lambda k: _init(cfg, k, dtype)[0], jax.random.PRNGKey(0))


# ============================================================== block apply
def _mask_kind(cfg, kind, prefix_len):
    if kind == BLOCK_LOCAL_ATTN:
        return "local"
    if cfg.prefix_lm and prefix_len is not None:
        return "prefix"
    return "causal"


def _attn_train(env, cfg, bp, x, kind, positions, prefix_len, chunk,
                enc_out=None, enc_positions=None, encoder_self=False):
    h = L.rmsnorm(bp["ln1"], x, cfg.norm_eps)
    q, k, v = attn.project_qkv(env, cfg, bp["attn"], h,
                               positions=positions)
    mask = "full" if encoder_self else _mask_kind(cfg, kind, prefix_len)
    o = attn.attention_core(env, cfg, q, k, v, mask_kind=mask,
                            prefix_len=prefix_len, chunk=chunk)
    out = attn.output_proj(env, cfg, bp["attn"], o)
    if cfg.parallel_block:
        m = L.mlp_apply(env, bp["mlp"], h, cfg.mlp_activation)
        return x + out + m, (k, v)
    x = x + out
    h2 = L.rmsnorm(bp["ln2"], x, cfg.norm_eps)
    if cfg.is_encoder_decoder and enc_out is not None:
        cq, ck, cv = attn.project_qkv(
            env, cfg, bp["cross"], L.rmsnorm(bp["ln_cross"], x, cfg.norm_eps),
            kv_x=enc_out, positions=positions, kv_positions=enc_positions,
            use_rope=False)
        co = attn.attention_core(env, cfg, cq, ck, cv, mask_kind="full",
                                 chunk=chunk)
        x = x + attn.output_proj(env, cfg, bp["cross"], co)
        h2 = L.rmsnorm(bp["ln2"], x, cfg.norm_eps)
    if cfg.num_experts:
        f = moe_mod.moe_apply(env, cfg, bp["moe"], h2)
        if cfg.moe_dense_residual:
            f = f + L.mlp_apply(env, bp["mlp"], h2, cfg.mlp_activation)
    else:
        f = L.mlp_apply(env, bp["mlp"], h2, cfg.mlp_activation)
    return x + f, (k, v)


def _ffn_part(env, cfg, bp, x):
    h2 = L.rmsnorm(bp["ln2"], x, cfg.norm_eps)
    if cfg.num_experts:
        f = moe_mod.moe_apply(env, cfg, bp["moe"], h2)
        if cfg.moe_dense_residual:
            f = f + L.mlp_apply(env, bp["mlp"], h2, cfg.mlp_activation)
    else:
        f = L.mlp_apply(env, bp["mlp"], h2, cfg.mlp_activation)
    return x + f


def apply_block_train(env, cfg, kind, bp, x, *, positions, prefix_len,
                      chunk, enc_out=None, enc_positions=None):
    if kind in ATTN_BLOCKS:
        x, _ = _attn_train(env, cfg, bp, x, kind, positions, prefix_len,
                           chunk, enc_out, enc_positions)
        return x
    if kind == BLOCK_RGLRU:
        x = x + rglru_mod.rglru_forward(
            env, cfg, bp["rglru"], L.rmsnorm(bp["ln1"], x, cfg.norm_eps))
        return _ffn_part(env, cfg, bp, x)
    if kind == BLOCK_SSD:
        return x + ssd_mod.ssd_forward(
            env, cfg, bp["ssd"], L.rmsnorm(bp["ln1"], x, cfg.norm_eps))
    raise ValueError(kind)


def apply_block_prefill(env, cfg, kind, bp, x, cache_entry, *, positions,
                        prefix_len, chunk, enc_out=None, enc_positions=None):
    """Like train, but fills ``cache_entry`` and returns (x, new_entry)."""
    if kind in ATTN_BLOCKS:
        h = L.rmsnorm(bp["ln1"], x, cfg.norm_eps)
        q, k, v = attn.project_qkv(env, cfg, bp["attn"], h, positions=positions)
        mask = _mask_kind(cfg, kind, prefix_len)
        o = attn.attention_core(env, cfg, q, k, v, mask_kind=mask,
                                prefix_len=prefix_len, chunk=chunk)
        out = attn.output_proj(env, cfg, bp["attn"], o)
        new = dict(cache_entry)
        if kind == BLOCK_LOCAL_ATTN and cache_entry["k"].shape[1] < k.shape[1]:
            new["k"], new["v"] = attn.write_ring_cache(
                cache_entry["k"], cache_entry["v"], k, v)
        else:
            new["k"], new["v"] = attn.write_full_cache(
                cache_entry["k"], cache_entry["v"], k, v, 0)
        if cfg.parallel_block:
            m = L.mlp_apply(env, bp["mlp"], h, cfg.mlp_activation)
            return x + out + m, new
        x = x + out
        if cfg.is_encoder_decoder and enc_out is not None:
            hc = L.rmsnorm(bp["ln_cross"], x, cfg.norm_eps)
            cq, ck, cv = attn.project_qkv(
                env, cfg, bp["cross"], hc, kv_x=enc_out, positions=positions,
                kv_positions=enc_positions, use_rope=False)
            co = attn.attention_core(env, cfg, cq, ck, cv, mask_kind="full",
                                     chunk=chunk)
            x = x + attn.output_proj(env, cfg, bp["cross"], co)
            new["ck"], new["cv"] = ck.astype(new["ck"].dtype), cv.astype(new["cv"].dtype)
        return _ffn_part(env, cfg, bp, x), new
    if kind == BLOCK_RGLRU:
        out, (h_last, conv) = rglru_mod.rglru_forward(
            env, cfg, bp["rglru"], L.rmsnorm(bp["ln1"], x, cfg.norm_eps),
            return_state=True)
        x = x + out
        return _ffn_part(env, cfg, bp, x), {"h": h_last, "conv": conv}
    if kind == BLOCK_SSD:
        out, (h_last, conv) = ssd_mod.ssd_forward(
            env, cfg, bp["ssd"], L.rmsnorm(bp["ln1"], x, cfg.norm_eps),
            return_state=True)
        return x + out, {"h": h_last, "conv": conv}
    raise ValueError(kind)


def apply_block_decode(env, cfg, kind, bp, x_t, cache_entry, *, pos):
    """One-token step. x_t: (B, 1, d); pos: (B,) absolute position."""
    if kind in ATTN_BLOCKS:
        h = L.rmsnorm(bp["ln1"], x_t, cfg.norm_eps)
        q, k, v = attn.project_qkv(env, cfg, bp["attn"], h,
                                   positions=pos[:, None])
        ring = kind == BLOCK_LOCAL_ATTN
        new = dict(cache_entry)
        new["k"], new["v"] = _decode_write_vec(
            cache_entry["k"], cache_entry["v"], k, v, pos, ring)
        window = cfg.local_window if ring else 0
        o = attn.decode_attend(env, cfg, q, new["k"], new["v"], pos,
                               ring=ring, window=window)
        out = attn.output_proj(env, cfg, bp["attn"], o)
        if cfg.parallel_block:
            m = L.mlp_apply(env, bp["mlp"], h, cfg.mlp_activation)
            return x_t + out + m, new
        x_t = x_t + out
        if cfg.is_encoder_decoder and "ck" in cache_entry:
            hc = L.rmsnorm(bp["ln_cross"], x_t, cfg.norm_eps)
            cq = jnp.einsum("bsd,dhk->bshk", hc, bp["cross"]["wq"])
            if cfg.attn_bias:
                cq = cq + bp["cross"]["bq"]
            co = attn.decode_attend(env, cfg, cq, cache_entry["ck"],
                                    cache_entry["cv"], pos, ring=False,
                                    cross=True)
            x_t = x_t + attn.output_proj(env, cfg, bp["cross"], co)
        return _ffn_part(env, cfg, bp, x_t), new
    if kind == BLOCK_RGLRU:
        out, (h_new, conv) = rglru_mod.rglru_step(
            env, cfg, bp["rglru"], L.rmsnorm(bp["ln1"], x_t, cfg.norm_eps),
            (cache_entry["h"], cache_entry["conv"]))
        x_t = x_t + out
        return _ffn_part(env, cfg, bp, x_t), {"h": h_new, "conv": conv}
    if kind == BLOCK_SSD:
        out, (h_new, conv) = ssd_mod.ssd_step(
            env, cfg, bp["ssd"], L.rmsnorm(bp["ln1"], x_t, cfg.norm_eps),
            (cache_entry["h"], cache_entry["conv"]))
        return x_t + out, {"h": h_new, "conv": conv}
    raise ValueError(kind)


def _decode_write_vec(cache_k, cache_v, k_t, v_t, pos, ring: bool):
    """Per-sequence cache write. k_t: (B, 1, H, D); pos: (B,)."""
    w = cache_k.shape[1]
    slots = (pos % w) if ring else pos
    b_idx = jnp.arange(cache_k.shape[0])
    cache_k = cache_k.at[b_idx, slots].set(k_t[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[b_idx, slots].set(v_t[:, 0].astype(cache_v.dtype))
    return cache_k, cache_v


# ======================================================== stacks (scan/rem)
def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn)  # "full"


def _run_stack_train(env, cfg, params, x, *, positions, prefix_len, run,
                     enc_out=None, enc_positions=None, encoder: bool = False):
    pattern = ("global",) * 1 if encoder else cfg.pattern
    stack = params.get("stack")
    chunk = run.attn_chunk

    def body(x, lp):
        if encoder:
            h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
            q, k, v = attn.project_qkv(env, cfg, lp["attn"], h,
                                       positions=positions)
            o = attn.attention_core(env, cfg, q, k, v, mask_kind="full",
                                    chunk=chunk)
            x = x + attn.output_proj(env, cfg, lp["attn"], o)
            h2 = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
            x = x + L.mlp_apply(env, lp["mlp"], h2, cfg.mlp_activation)
            return x, None
        for i, kind in enumerate(cfg.pattern):
            x = apply_block_train(env, cfg, kind, lp[f"b{i}"], x,
                                  positions=positions, prefix_len=prefix_len,
                                  chunk=chunk, enc_out=enc_out,
                                  enc_positions=enc_positions)
        return x, None

    body = _remat(body, run.remat_policy)
    if stack is not None:
        x, _ = jax.lax.scan(lambda c, lp: body(c, lp), x, stack)
    for i, kind in enumerate(() if encoder else cfg.remainder_blocks):
        x = apply_block_train(env, cfg, kind, params["rem"][i], x,
                              positions=positions, prefix_len=prefix_len,
                              chunk=chunk, enc_out=enc_out,
                              enc_positions=enc_positions)
    return x


def _run_stack_prefill(env, cfg, params, x, cache, *, positions, prefix_len,
                       run, enc_out=None, enc_positions=None):
    chunk = run.attn_chunk

    def body(x, lp_lc):
        lp, lc = lp_lc
        new_entries = {}
        for i, kind in enumerate(cfg.pattern):
            x, new_entries[f"b{i}"] = apply_block_prefill(
                env, cfg, kind, lp[f"b{i}"], x, lc[f"b{i}"],
                positions=positions, prefix_len=prefix_len, chunk=chunk,
                enc_out=enc_out, enc_positions=enc_positions)
        return x, new_entries

    if params.get("stack") is not None:
        x, new_cache_stack = jax.lax.scan(
            body, x, (params["stack"], cache["stack"]))
    else:
        new_cache_stack = cache.get("stack")
    new_rem = []
    for i, kind in enumerate(cfg.remainder_blocks):
        x, entry = apply_block_prefill(
            env, cfg, kind, params["rem"][i], x, cache["rem"][i],
            positions=positions, prefix_len=prefix_len, chunk=chunk,
            enc_out=enc_out, enc_positions=enc_positions)
        new_rem.append(entry)
    out_cache = {"stack": new_cache_stack}
    if new_rem:
        out_cache["rem"] = tuple(new_rem)
    return x, out_cache


def _run_stack_decode(env, cfg, params, x_t, cache, *, pos):
    def body(x_t, lp_lc):
        lp, lc = lp_lc
        new_entries = {}
        for i, kind in enumerate(cfg.pattern):
            x_t, new_entries[f"b{i}"] = apply_block_decode(
                env, cfg, kind, lp[f"b{i}"], x_t, lc[f"b{i}"], pos=pos)
        return x_t, new_entries

    if params.get("stack") is not None:
        x_t, new_cache_stack = jax.lax.scan(
            body, x_t, (params["stack"], cache["stack"]))
    else:
        new_cache_stack = cache.get("stack")
    new_rem = []
    for i, kind in enumerate(cfg.remainder_blocks):
        x_t, entry = apply_block_decode(
            env, cfg, kind, params["rem"][i], x_t, cache["rem"][i], pos=pos)
        new_rem.append(entry)
    out_cache = {"stack": new_cache_stack}
    if new_rem:
        out_cache["rem"] = tuple(new_rem)
    return x_t, out_cache


# ============================================================== embeddings
def _embed_inputs(env, cfg, params, batch):
    """Token (+frontend) embedding. Returns (x, positions, prefix_len)."""
    tokens = batch["tokens"]
    x = L.embed_lookup(env, params["embed"], tokens, cfg.embed_scale)
    prefix_len = None
    if cfg.frontend == "vision":
        patches = batch["patch_embeds"].astype(x.dtype)
        x = jnp.concatenate([patches, x], axis=1)
        prefix_len = patches.shape[1]
    return x, jnp.arange(x.shape[1]), prefix_len


def _encode(env, cfg, params, batch, run):
    src = batch["src_embeds"]
    pos = jnp.arange(src.shape[1])
    dtype = params["embed"]["table"].dtype
    enc = _run_stack_train(env, cfg, params["encoder"], src.astype(dtype),
                           positions=pos, prefix_len=None, run=run,
                           encoder=True)
    return L.rmsnorm(params["encoder"]["final_norm"], enc, cfg.norm_eps), pos


# ================================================================== public
def forward_train(env: ShardEnv, cfg: ModelConfig, params, batch,
                  run: RunConfig):
    enc_out = enc_pos = None
    if cfg.is_encoder_decoder:
        enc_out, enc_pos = _encode(env, cfg, params, batch, run)
    x, positions, prefix_len = _embed_inputs(env, cfg, params, batch)
    x = _run_stack_train(env, cfg, params, x, positions=positions,
                         prefix_len=prefix_len, run=run,
                         enc_out=enc_out, enc_positions=enc_pos)
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps)


def _logits(env, cfg, params, x):
    return L.unembed(env, params["embed"], x, cfg.tie_embeddings,
                     head=params.get("lm_head"), cap=cfg.final_logit_softcap)


def _ce(logits, targets, weights):
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None].clip(0), axis=-1)[..., 0]
    nll = (lse - gold) * weights
    return nll.sum(), weights.sum()


def loss_fn(env: ShardEnv, cfg: ModelConfig, params, batch, run: RunConfig):
    x = forward_train(env, cfg, params, batch, run)
    targets = batch["targets"]
    if cfg.frontend == "vision":                   # loss over text suffix only
        x = x[:, -targets.shape[1]:]
    weights = (targets >= 0).astype(jnp.float32)
    if run.loss_chunk and x.shape[1] % run.loss_chunk == 0 and \
            x.shape[1] > run.loss_chunk:
        nc = x.shape[1] // run.loss_chunk
        xs = x.reshape(x.shape[0], nc, run.loss_chunk, x.shape[-1]).swapaxes(0, 1)
        ts = targets.reshape(targets.shape[0], nc, run.loss_chunk).swapaxes(0, 1)
        ws = weights.reshape(weights.shape[0], nc, run.loss_chunk).swapaxes(0, 1)

        @jax.checkpoint
        def chunk_loss(carry, xtw):
            xc, tc, wc = xtw
            n, d = _ce(_logits(env, cfg, params, xc), tc, wc)
            return (carry[0] + n, carry[1] + d), None

        (num, den), _ = jax.lax.scan(chunk_loss, (0.0, 0.0), (xs, ts, ws))
    else:
        num, den = _ce(_logits(env, cfg, params, x), targets, weights)
    return num / jnp.maximum(den, 1.0)


# ==================================================================== cache
def _cache_entry_struct(cfg, kind, batch: int, max_len: int, cross_len: int,
                        kv_dtype=jnp.bfloat16):
    hkv, dh = max(cfg.num_kv_heads, 1), max(cfg.head_dim, 1)
    if kind in ATTN_BLOCKS:
        length = max_len if kind == BLOCK_GLOBAL_ATTN else min(
            cfg.local_window or max_len, max_len)
        e = {"k": ((batch, length, hkv, dh), kv_dtype),
             "v": ((batch, length, hkv, dh), kv_dtype)}
        if cfg.is_encoder_decoder:
            e["ck"] = ((batch, cross_len, hkv, dh), kv_dtype)
            e["cv"] = ((batch, cross_len, hkv, dh), kv_dtype)
        return e
    if kind == BLOCK_RGLRU:
        rw = cfg.rglru_width or cfg.d_model
        return {"h": ((batch, rw), jnp.float32),
                "conv": ((batch, cfg.conv_width - 1, rw), jnp.float32)}
    if kind == BLOCK_SSD:
        return {"h": ((batch, cfg.ssm_num_heads, cfg.ssm_head_dim,
                       cfg.ssm_state_dim), jnp.float32),
                "conv": ((batch, cfg.conv_width - 1,
                          cfg.d_inner + 2 * cfg.ssm_state_dim), jnp.float32)}
    raise ValueError(kind)


def _cache_tree(cfg, batch, max_len, cross_len, make_leaf, kv_dtype):
    tree: Dict[str, Any] = {}
    reps = cfg.scan_repeats
    if reps:
        stack = {}
        for i, kind in enumerate(cfg.pattern):
            entry = _cache_entry_struct(cfg, kind, batch, max_len, cross_len,
                                        kv_dtype)
            stack[f"b{i}"] = {k: make_leaf((reps,) + shape, dt)
                              for k, (shape, dt) in entry.items()}
        tree["stack"] = stack
    rem = []
    for kind in cfg.remainder_blocks:
        entry = _cache_entry_struct(cfg, kind, batch, max_len, cross_len,
                                    kv_dtype)
        rem.append({k: make_leaf(shape, dt) for k, (shape, dt) in entry.items()})
    if rem:
        tree["rem"] = tuple(rem)
    return tree


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               cross_len: int = 0, kv_dtype=jnp.bfloat16):
    return _cache_tree(cfg, batch, max_len, cross_len or max_len,
                       lambda s, d: jnp.zeros(s, d), kv_dtype)


def cache_struct(cfg: ModelConfig, batch: int, max_len: int,
                 cross_len: int = 0, kv_dtype=jnp.bfloat16):
    return _cache_tree(cfg, batch, max_len, cross_len or max_len,
                       jax.ShapeDtypeStruct, kv_dtype)


def cache_specs(cfg: ModelConfig):
    """Logical-axis tuples matching the cache tree."""
    def leaf_spec(key, ndim, stacked):
        if key in ("k", "v", "ck", "cv"):
            sp = ("act_batch", "act_kv_seq", None, None)
        elif key == "h":
            sp = (("act_batch", "act_inner") if ndim - (1 if stacked else 0) == 2
                  else ("act_batch", "act_inner", None, None))
        else:  # conv
            sp = ("act_batch", None, "act_inner")
        return (("layers",) + sp) if stacked else sp

    tree: Dict[str, Any] = {}
    reps = cfg.scan_repeats
    cross = cfg.is_encoder_decoder
    if reps:
        stack = {}
        for i, kind in enumerate(cfg.pattern):
            entry = _cache_entry_struct(cfg, kind, 1, 8, 8)
            stack[f"b{i}"] = {k: leaf_spec(k, len(shape) + 1, True)
                              for k, (shape, dt) in entry.items()}
        tree["stack"] = stack
    rem = []
    for kind in cfg.remainder_blocks:
        entry = _cache_entry_struct(cfg, kind, 1, 8, 8)
        rem.append({k: leaf_spec(k, len(shape), False)
                    for k, (shape, dt) in entry.items()})
    if rem:
        tree["rem"] = tuple(rem)
    return tree


# =========================================================== prefill/decode
def prefill(env: ShardEnv, cfg: ModelConfig, params, batch, run: RunConfig,
            max_len: int = 0, kv_dtype=jnp.bfloat16):
    """Run the prompt, fill the cache, return (last_logits, cache, pos)."""
    enc_out = enc_pos = None
    if cfg.is_encoder_decoder:
        enc_out, enc_pos = _encode(env, cfg, params, batch, run)
    x, positions, prefix_len = _embed_inputs(env, cfg, params, batch)
    s = x.shape[1]
    b = x.shape[0]
    cache = init_cache(cfg, b, max(max_len or s, s),
                       cross_len=(enc_out.shape[1] if enc_out is not None else 0),
                       kv_dtype=kv_dtype)
    x, cache = _run_stack_prefill(env, cfg, params, x, cache,
                                  positions=positions, prefix_len=prefix_len,
                                  run=run, enc_out=enc_out,
                                  enc_positions=enc_pos)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _logits(env, cfg, params, x[:, -1:])[:, 0]
    pos = jnp.full((b,), s - 1, jnp.int32)
    return logits, cache, pos


def decode_step(env: ShardEnv, cfg: ModelConfig, params, token, pos, cache,
                run: RunConfig):
    """One decode step. token: (B, 1) int32; pos: (B,) absolute position of
    the *new* token. Returns (logits (B, V), new_cache)."""
    x = L.embed_lookup(env, params["embed"], token, cfg.embed_scale)
    x, cache = _run_stack_decode(env, cfg, params, x, cache, pos=pos)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _logits(env, cfg, params, x)[:, 0]
    return logits, cache


# ============================================================== input_specs
def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                run: Optional[RunConfig] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train:   tokens/targets (+frontend embeddings)
    prefill: tokens (+frontend embeddings)
    decode:  token (B,1) + pos (B,) + cache of seq_len
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    f = jax.ShapeDtypeStruct
    d = cfg.d_model

    if shape.mode == "train":
        if cfg.is_encoder_decoder:
            tgt = max(s // 4, 8)
            return {"src_embeds": f((b, s, d), bf16),
                    "tokens": f((b, tgt), i32),
                    "targets": f((b, tgt), i32)}
        if cfg.frontend == "vision":
            text = s - cfg.frontend_len
            return {"patch_embeds": f((b, cfg.frontend_len, d), bf16),
                    "tokens": f((b, text), i32),
                    "targets": f((b, text), i32)}
        return {"tokens": f((b, s), i32), "targets": f((b, s), i32)}

    if shape.mode == "prefill":
        if cfg.is_encoder_decoder:
            return {"src_embeds": f((b, s, d), bf16),
                    "tokens": f((b, 8), i32)}
        if cfg.frontend == "vision":
            return {"patch_embeds": f((b, cfg.frontend_len, d), bf16),
                    "tokens": f((b, s - cfg.frontend_len), i32)}
        return {"tokens": f((b, s), i32)}

    # decode: one new token against a cache of seq_len
    cache = cache_struct(cfg, b, s, cross_len=s if cfg.is_encoder_decoder else 0)
    return {"token": f((b, 1), i32), "pos": f((b,), i32), "cache": cache}
