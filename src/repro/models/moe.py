"""Mixture-of-Experts with shard_map expert parallelism.

Token-choice top-k routing with capacity-bounded, sort-based dispatch —
the TPU-native adaptation of megablocks-style grouped matmul:

* **EP mode** (arctic: 128 experts % 16 == 0): experts sharded over the
  ``model`` axis. Activations are batch-sharded over ``data``/``pod`` and
  replicated over ``model``, so each model shard gathers *its own* experts'
  tokens from its local batch locally (no all-to-all needed), computes the
  grouped matmul, scatter-adds weighted outputs, and a single
  ``psum('model')`` combines expert contributions — the same collective
  volume as a TP FFN, with perfectly balanced expert placement.
  Expert weights are additionally FSDP-sharded over ``data`` and
  all-gathered inside the shard_map body (transpose = reduce-scatter on the
  backward pass).

* **TP mode** (granite: 40 experts % 16 != 0): experts replicated, the
  per-expert d_ff sharded over ``model``; the same body runs with
  ``E_local == E`` and psum combining ff-shard partials.

Tokens beyond an expert's capacity are dropped (GShard semantics); tests
use a high capacity factor and compare against the dense oracle.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.models.layers import GATED, mlp_activate, nd_init

BIG = jnp.iinfo(jnp.int32).max


def moe_init(cfg, key, dtype):
    d, e = cfg.d_model, cfg.num_experts
    ff = cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 4)
    gated = cfg.mlp_activation in GATED
    p = {
        "router": nd_init(ks[0], (d, e), d, jnp.float32),
        "w_in": nd_init(ks[1], (e, d, ff), d, dtype),
        "w_out": nd_init(ks[2], (e, ff, d), ff, dtype),
    }
    if cfg.moe_parallelism == "ep":
        s = {
            "router": ("p_embed", "p_none"),
            "w_in": ("p_experts", "p_embed", "p_none"),
            "w_out": ("p_experts", "p_ff_in", "p_none"),
        }
    else:  # tp: ff over model, experts replicated
        s = {
            "router": ("p_embed", "p_none"),
            "w_in": ("p_none", "p_embed", "p_expert_ff"),
            "w_out": ("p_none", "p_expert_ff", "p_embed"),
        }
    if gated:
        p["w_gate"] = nd_init(ks[3], (e, d, ff), d, dtype)
        s["w_gate"] = s["w_in"]
    return p, s


def _dispatch_compute(x, ids, combine, w_in, w_gate, w_out, *,
                      activation: str, capacity: int, e0, e_local: int,
                      fsdp_axis: str):
    """Per-device MoE body. x: (Bl, S, d); ids/combine: (Bl, S, k)."""
    bl, s, d = x.shape
    k = ids.shape[-1]
    t = bl * s
    x_f = x.reshape(t, d)
    a = ids.reshape(t * k)                       # expert id per assignment
    tok = jnp.repeat(jnp.arange(t), k)           # token per assignment
    wgt = combine.reshape(t * k)

    if fsdp_axis:
        w_in = jax.lax.all_gather(w_in, fsdp_axis, axis=1, tiled=True)
        w_out = jax.lax.all_gather(w_out, fsdp_axis, axis=1, tiled=True)
        if w_gate is not None:
            w_gate = jax.lax.all_gather(w_gate, fsdp_axis, axis=1, tiled=True)

    mine = (a >= e0) & (a < e0 + e_local)
    key = jnp.where(mine, a - e0, BIG)
    order = jnp.argsort(key)                     # my assignments first, grouped
    sk = key[order]
    # rank within expert group: position - first index of the group
    change = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]])
    first = jnp.where(change, jnp.arange(t * k), 0)
    first = jax.lax.associative_scan(jnp.maximum, first)
    rank = jnp.arange(t * k) - first
    valid = (sk < BIG) & (rank < capacity)
    dest = jnp.where(valid, sk * capacity + rank, e_local * capacity)

    tok_o = tok[order]
    gathered = jnp.zeros((e_local * capacity + 1, d), x.dtype)
    gathered = gathered.at[dest].add(jnp.where(valid[:, None], x_f[tok_o], 0))
    gx = gathered[:-1].reshape(e_local, capacity, d)

    h = jnp.einsum("ecd,edf->ecf", gx, w_in, preferred_element_type=jnp.float32)
    g = (jnp.einsum("ecd,edf->ecf", gx, w_gate,
                    preferred_element_type=jnp.float32)
         if w_gate is not None else None)
    h = mlp_activate(activation, h, g).astype(x.dtype)
    y = jnp.einsum("ecf,efd->ecd", h, w_out,
                   preferred_element_type=jnp.float32).astype(x.dtype)

    y_f = y.reshape(e_local * capacity, d)
    y_assign = jnp.where(valid[:, None], y_f[jnp.where(valid, dest, 0)], 0)
    out = jnp.zeros((t, d), x.dtype)
    out = out.at[tok_o].add(y_assign * wgt[order][:, None].astype(x.dtype))
    return out.reshape(bl, s, d)


def moe_apply(env, cfg, params, x, *, capacity_factor: float = 2.0):
    """x: (B, S, d) -> (B, S, d). Router in fp32 outside shard_map."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    logits = (x.astype(jnp.float32) @ params["router"])
    gate_w, ids = jax.lax.top_k(logits, k)                     # (B,S,k)
    gate_w = jax.nn.softmax(gate_w, axis=-1)

    dp = env.dp
    t_local = (b * s) // dp
    ep = cfg.moe_parallelism == "ep"
    tp = env.tp
    e_local = e // tp if ep else e
    capacity = max(8, int(capacity_factor * t_local * k / e))
    capacity = min(capacity, t_local * k)

    mesh = env.mesh
    x_spec = env.pspec("act_batch", None, None)
    id_spec = env.pspec("act_batch", None, None)
    if ep:
        w_spec = env.pspec("p_experts", "p_embed", None)
        w2_spec = env.pspec("p_experts", "p_ff_in", None)
    else:
        w_spec = env.pspec(None, None, "p_expert_ff")
        w2_spec = env.pspec(None, "p_expert_ff", None)
    model_ax = "model" if "model" in mesh.axis_names else None
    fsdp_ax = "data" if (ep and "data" in mesh.axis_names
                         and env.rules.get("p_embed") == "data") else ""

    def body(x_l, ids_l, wgt_l, w_in, w_gate, w_out):
        if ep and model_ax:
            e0 = jax.lax.axis_index(model_ax) * e_local
        else:
            e0 = 0
        out = _dispatch_compute(
            x_l, ids_l, wgt_l, w_in, w_gate, w_out,
            activation=cfg.mlp_activation, capacity=capacity,
            e0=e0, e_local=e_local, fsdp_axis=fsdp_ax)
        if model_ax:
            out = jax.lax.psum(out, model_ax)
        return out

    w_gate = params.get("w_gate")
    gate_spec = w_spec if w_gate is not None else None
    in_specs = [x_spec, id_spec, id_spec, w_spec,
                gate_spec if w_gate is not None else P(), w2_spec]
    out_spec = env.pspec("act_batch", None, None)
    fn = shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                   out_specs=out_spec, check_rep=False)
    if w_gate is None:
        w_gate_arg = jnp.zeros((), x.dtype)  # placeholder, unused

        def body_nogate(x_l, i_l, g_l, wi, _pl, wo):
            return body(x_l, i_l, g_l, wi, None, wo)
        fn = shard_map(body_nogate, mesh=mesh, in_specs=tuple(in_specs),
                       out_specs=out_spec, check_rep=False)
        return fn(x, ids, gate_w, params["w_in"], w_gate_arg, params["w_out"])
    return fn(x, ids, gate_w, params["w_in"], w_gate, params["w_out"])


def moe_ref(cfg, params, x):
    """Dense oracle: run every expert on every token, mask by routing.
    No capacity limit — matches moe_apply when nothing is dropped."""
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    logits = x.astype(jnp.float32) @ params["router"]
    gate_w, ids = jax.lax.top_k(logits, k)
    gate_w = jax.nn.softmax(gate_w, axis=-1)
    h = jnp.einsum("bsd,edf->bsef", x, params["w_in"],
                   preferred_element_type=jnp.float32)
    g = (jnp.einsum("bsd,edf->bsef", x, params["w_gate"],
                    preferred_element_type=jnp.float32)
         if "w_gate" in params else None)
    h = mlp_activate(cfg.mlp_activation, h, g).astype(x.dtype)
    y = jnp.einsum("bsef,efd->bsed", h, params["w_out"],
                   preferred_element_type=jnp.float32)
    mask = jax.nn.one_hot(ids, e, dtype=jnp.float32) * gate_w[..., None]
    w_per_expert = mask.sum(axis=2)                            # (B,S,E)
    return jnp.einsum("bsed,bse->bsd", y, w_per_expert).astype(x.dtype)
