"""Primitive layers: inits, RMSNorm, RoPE, MLP variants, softcap.

Every ``*_init`` returns ``(params, specs)`` — two parallel pytrees, the
second holding tuples of logical axis names (see parallel/sharding.py) so the
whole parameter tree's shardings are derivable without tracing.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def nd_init(key, shape, fan_in, dtype):
    """Truncated-normal, 1/sqrt(fan_in) scaled (standard LM init)."""
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32)
            * std).astype(dtype)


def softcap(x, cap: float):
    """gemma2-style tanh logit soft-capping."""
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


# ----------------------------------------------------------------- RMSNorm
def rmsnorm_init(dim: int):
    return {"scale": jnp.zeros((dim,), jnp.float32)}, {"scale": ("p_none",)}


def rmsnorm(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + params["scale"])).astype(dtype)


def rms_headnorm(scale, x, eps: float = 1e-6):
    """Per-head qk-norm (qwen3): normalize over head_dim with learned scale."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale)).astype(dtype)


# -------------------------------------------------------------------- RoPE
def rope(x, positions, theta: float):
    """Apply rotary embedding. x: (..., S, H, D) or (..., H, D) w/ scalar pos.

    positions broadcast against x's sequence dims: shape (..., S) matching
    x.shape[:-2].
    """
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-np.arange(0, half, dtype=np.float32) * 2.0 / d)
    angles = positions.astype(jnp.float32)[..., None, None] * freq  # (...,S,1,half)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- MLP
GATED = ("swiglu", "geglu")


def mlp_init(key, d_model: int, d_ff: int, activation: str, dtype):
    ks = jax.random.split(key, 3)
    gated = activation in GATED
    p = {"w_in": nd_init(ks[0], (d_model, d_ff), d_model, dtype),
         "w_out": nd_init(ks[1], (d_ff, d_model), d_ff, dtype)}
    s = {"w_in": ("p_ff_in", "p_mlp"), "w_out": ("p_mlp", "p_embed")}
    if gated:
        p["w_gate"] = nd_init(ks[2], (d_model, d_ff), d_model, dtype)
        s["w_gate"] = ("p_ff_in", "p_mlp")
    return p, s


def mlp_activate(activation: str, h, g=None):
    if activation == "swiglu":
        return jax.nn.silu(g) * h
    if activation == "geglu":
        return jax.nn.gelu(g, approximate=True) * h
    if activation == "gelu":
        return jax.nn.gelu(h, approximate=True)
    if activation == "squared_relu":
        r = jax.nn.relu(h)
        return r * r
    raise ValueError(activation)


def mlp_apply(env, params, x, activation: str):
    h = x @ params["w_in"]
    g = x @ params["w_gate"] if activation in GATED else None
    # seq dim uses act_seq (None under plain TP; sharded under sequence
    # parallelism, where first-wins dedup drops act_mlp and the TP
    # activation all-reduce disappears in favor of small weight gathers)
    h = env.constrain(h, "act_batch", "act_seq", "act_mlp")
    h = mlp_activate(activation, h, g)
    out = h @ params["w_out"]
    return env.constrain(out, "act_batch", "act_seq", "act_embed")


# -------------------------------------------------------------- Embedding
def embed_init(key, vocab: int, d_model: int, dtype):
    p = {"table": nd_init(key, (vocab, d_model), d_model, dtype)}
    return p, {"table": ("p_vocab", "p_embed")}


def embed_lookup(env, params, tokens, scale: bool):
    x = jnp.take(params["table"], tokens, axis=0)
    if scale:
        x = x * jnp.asarray(math.sqrt(params["table"].shape[1]), x.dtype)
    return env.constrain(x, "act_batch", "act_seq", "act_embed")


def unembed(env, params_embed, x, tie: bool, head=None, cap: float = 0.0):
    table = params_embed["table"] if tie else head["w"]
    logits = x @ (table.T if tie else table)
    logits = softcap(logits, cap)
    return env.constrain(logits, "act_batch", "act_seq", "act_vocab")


def lm_head_init(key, d_model: int, vocab: int, dtype):
    return ({"w": nd_init(key, (d_model, vocab), d_model, dtype)},
            {"w": ("p_embed", "p_vocab")})


# ------------------------------------------------------- depthwise conv1d
def conv1d_init(key, width: int, channels: int, dtype):
    p = {"w": nd_init(key, (width, channels), width, dtype),
         "b": jnp.zeros((channels,), dtype)}
    return p, {"w": ("p_none", "p_inner"), "b": ("p_inner",)}


def conv1d_apply(params, x):
    """Causal depthwise conv over (B, S, C); width from params."""
    width = params["w"].shape[0]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for j in range(width):
        shifted = x if j == 0 else jnp.pad(x, ((0, 0), (j, 0), (0, 0)))[:, :x.shape[1]]
        out = out + shifted.astype(jnp.float32) * params["w"][width - 1 - j].astype(jnp.float32)
    return (out + params["b"].astype(jnp.float32)).astype(x.dtype)


def conv1d_step(params, x_t, state):
    """One decode step. x_t: (B, C); state: (B, width-1, C) past inputs."""
    width = params["w"].shape[0]
    window = jnp.concatenate([state, x_t[:, None, :]], axis=1)  # (B, width, C)
    out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                     params["w"].astype(jnp.float32)) + params["b"].astype(jnp.float32)
    return out.astype(x_t.dtype), window[:, 1:]
