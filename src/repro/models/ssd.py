"""Mamba-2 SSD (state-space duality) block, chunked for the MXU.

The SSD recurrence  h_t = exp(dA_t) h_{t-1} + dt_t B_t x_t^T ;  y_t = C_t h_t
is evaluated chunk-wise (chunk Q = cfg.ssm_chunk): within a chunk the dual
quadratic form (C B^T ⊙ L) X runs as dense Q×Q matmuls (MXU-aligned); across
chunks a lax.scan carries the (nh, P, N) state. This is the TPU-native
blocking of the SSD algorithm — intra-chunk compute is batched matmul, the
sequential dependency is only O(S/Q).

Layout: d_inner = expand*d_model, heads nh = d_inner/P, single B/C group
(ngroups=1), scalar A per head, depthwise conv width 4 over (x, B, C).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import conv1d_apply, conv1d_init, conv1d_step, nd_init


def ssd_init(cfg, key, dtype):
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state_dim
    nh = cfg.ssm_num_heads
    ks = jax.random.split(key, 4)
    conv_ch = di + 2 * n
    conv_p, conv_s = conv1d_init(ks[2], cfg.conv_width, conv_ch, dtype)
    p = {
        # fused in_proj -> [z(di), x(di), B(n), C(n), dt(nh)]
        "w_in": nd_init(ks[0], (d, 2 * di + 2 * n + nh), d, dtype),
        "w_out": nd_init(ks[1], (di, d), di, dtype),
        "conv": conv_p,
        "a_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.zeros((di,), jnp.float32),
    }
    s = {
        "w_in": ("p_embed", "p_inner"), "w_out": ("p_inner", "p_embed"),
        "conv": conv_s, "a_log": ("p_none",), "dt_bias": ("p_none",),
        "d_skip": ("p_none",), "norm_scale": ("p_inner",),
    }
    return p, s


def _split_proj(cfg, proj):
    di, n, nh = cfg.d_inner, cfg.ssm_state_dim, cfg.ssm_num_heads
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * n]
    dt = proj[..., di + di + 2 * n:]
    return z, xbc, dt


def _gated_norm(params, y, z, eps):
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + eps)
    return y * (1.0 + params["norm_scale"])


def ssd_forward(env, cfg, params, x, *, state=None, conv_state=None,
                return_state: bool = False):
    """x: (B, S, d). Chunked SSD. state: (B, nh, P, N) fp32."""
    bsz, s, _ = x.shape
    di, n, nh, p_dim = cfg.d_inner, cfg.ssm_state_dim, cfg.ssm_num_heads, cfg.ssm_head_dim
    q = min(cfg.ssm_chunk, s)
    while s % q:
        q -= 1
    nc = s // q

    proj = x @ params["w_in"]
    proj = env.constrain(proj, "act_batch", "act_seq", "act_mlp")
    z, xbc, dt = _split_proj(cfg, proj)
    if conv_state is not None:
        hist = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
        xbc_c = jax.nn.silu(conv1d_apply(params["conv"], hist)[:, conv_state.shape[1]:])
        new_conv = hist[:, -(cfg.conv_width - 1):]
    else:
        xbc_c = jax.nn.silu(conv1d_apply(params["conv"], xbc))
        new_conv = xbc[:, -(cfg.conv_width - 1):]
    xs = xbc_c[..., :di].reshape(bsz, s, nh, p_dim)
    bmat = xbc_c[..., di:di + n]                                # (B,S,N)
    cmat = xbc_c[..., di + n:]                                  # (B,S,N)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,nh)
    da = -jnp.exp(params["a_log"]) * dt                               # (B,S,nh) <= 0

    # chunk views
    xs_c = xs.reshape(bsz, nc, q, nh, p_dim).astype(jnp.float32)
    b_c = bmat.reshape(bsz, nc, q, n).astype(jnp.float32)
    c_c = cmat.reshape(bsz, nc, q, n).astype(jnp.float32)
    da_c = da.reshape(bsz, nc, q, nh)
    dt_c = dt.reshape(bsz, nc, q, nh)

    cum = jnp.cumsum(da_c, axis=2)                                    # (B,nc,q,nh)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]               # (B,nc,q,q,nh)
    causal = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    # mask BEFORE exp: on causal entries seg <= 0, so exp never overflows;
    # masking after exp produces inf * 0 = NaN in the backward pass.
    l_mat = jnp.exp(jnp.where(causal, seg, -1e30))

    # intra-chunk: Y = (C B^T ⊙ L ⊙ dt_j) X
    cb = jnp.einsum("bcin,bcjn->bcij", c_c, b_c)                      # (B,nc,q,q)
    att = cb[..., None] * l_mat * dt_c[:, :, None, :, :]              # (B,nc,q,q,nh)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att, xs_c)

    # state to carry: S_c = sum_j exp(cum_Q - cum_j) dt_j B_j x_j^T
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)                      # (B,nc,q,nh)
    sin = jnp.einsum("bcjh,bcjn,bcjhp->bchpn",
                     decay_end * dt_c, b_c, xs_c)                     # (B,nc,nh,P,N)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                           # (B,nc,nh)

    def chunk_step(h, inp):
        s_in, dec, c_blk, cum_blk = inp
        # inter-chunk contribution: y_i += C_i exp(cum_i) h_prev
        y_inter = jnp.einsum("bin,bih,bhpn->bihp",
                             c_blk, jnp.exp(cum_blk), h)
        h_new = dec[:, :, None, None] * h + s_in
        return h_new, y_inter

    if state is None:
        state = jnp.zeros((bsz, nh, p_dim, n), jnp.float32)
    h_last, y_inter = jax.lax.scan(
        chunk_step, state,
        (sin.swapaxes(0, 1), chunk_decay.swapaxes(0, 1),
         c_c.swapaxes(0, 1), cum.swapaxes(0, 1)))
    y = y_intra + y_inter.swapaxes(0, 1)                              # (B,nc,q,nh,P)
    y = y.reshape(bsz, s, nh, p_dim)
    y = y + params["d_skip"][:, None] * xs.astype(jnp.float32)
    y = y.reshape(bsz, s, di)
    y = _gated_norm(params, y, z, cfg.norm_eps).astype(x.dtype)
    out = y @ params["w_out"]
    out = env.constrain(out, "act_batch", "act_seq", "act_embed")
    if return_state:
        return out, (h_last, new_conv.astype(jnp.float32))
    return out


def ssd_step(env, cfg, params, x_t, state_tuple):
    """One decode step. x_t: (B, 1, d); state (B, nh, P, N) fp32."""
    h, conv_state = state_tuple
    di, n, nh, p_dim = cfg.d_inner, cfg.ssm_state_dim, cfg.ssm_num_heads, cfg.ssm_head_dim
    proj = x_t[:, 0] @ params["w_in"]
    z, xbc, dt = _split_proj(cfg, proj)
    xbc_c, new_conv = conv1d_step(params["conv"], xbc, conv_state.astype(xbc.dtype))
    xbc_c = jax.nn.silu(xbc_c)
    xs = xbc_c[..., :di].reshape(-1, nh, p_dim).astype(jnp.float32)
    bvec = xbc_c[..., di:di + n].astype(jnp.float32)
    cvec = xbc_c[..., di + n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    da = jnp.exp(-jnp.exp(params["a_log"]) * dt)                      # (B,nh)
    h_new = (da[:, :, None, None] * h
             + jnp.einsum("bh,bn,bhp->bhpn", dt, bvec, xs))
    y = jnp.einsum("bn,bhpn->bhp", cvec, h_new)
    y = y + params["d_skip"][:, None] * xs
    y = y.reshape(-1, di)
    y = _gated_norm(params, y, z, cfg.norm_eps).astype(x_t.dtype)
    out = y @ params["w_out"]
    return out[:, None, :], (h_new, new_conv.astype(jnp.float32))
