"""GQA attention: training/prefill (chunked online-softmax) + decode paths.

Supports every attention variant in the assigned pool: grouped/multi-query
heads, sliding-window (local) masking, prefix-LM masks (paligemma), logit
soft-capping (gemma2), qk-norm (qwen3), biases (seamless), cross-attention
(enc-dec), and ring-buffer local KV caches for O(window) long-context decode.

The training path uses an online-softmax scan over KV chunks (flash-attention
algorithm expressed in jnp) so 32k-token prefill never materializes an S^2
score tensor — this is also what the Pallas ``flash_attn`` kernel computes;
``kernels/flash_attn/ref.py`` delegates here.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import nd_init, rms_headnorm, rope, softcap

NEG_INF = -1e30


# ------------------------------------------------------------------- init
def attn_init(cfg, key, dtype, cross: bool = False):
    d, hq, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": nd_init(ks[0], (d, hq, dh), d, dtype),
        "wk": nd_init(ks[1], (d, hkv, dh), d, dtype),
        "wv": nd_init(ks[2], (d, hkv, dh), d, dtype),
        "wo": nd_init(ks[3], (hq, dh, d), hq * dh, dtype),
    }
    s = {
        "wq": ("p_embed", "p_heads", "p_none"),
        "wk": ("p_embed", "p_heads", "p_none"),
        "wv": ("p_embed", "p_heads", "p_none"),
        "wo": ("p_heads", "p_none", "p_embed"),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((hq, dh), dtype)
        p["bk"] = jnp.zeros((hkv, dh), dtype)
        p["bv"] = jnp.zeros((hkv, dh), dtype)
        p["bo"] = jnp.zeros((d,), dtype)
        s.update({"bq": ("p_heads", "p_none"), "bk": ("p_heads", "p_none"),
                  "bv": ("p_heads", "p_none"), "bo": ("p_embed",)})
    if cfg.use_qk_norm:
        p["q_norm"] = jnp.zeros((dh,), jnp.float32)
        p["k_norm"] = jnp.zeros((dh,), jnp.float32)
        s.update({"q_norm": ("p_none",), "k_norm": ("p_none",)})
    return p, s


def _scale(cfg) -> float:
    return cfg.query_scale or 1.0 / math.sqrt(cfg.head_dim)


def project_qkv(env, cfg, params, x, kv_x=None, *, positions=None,
                kv_positions=None, use_rope=True):
    """Project to (q, k, v); applies qk-norm and RoPE (at absolute positions,
    so cached K never needs re-rotation)."""
    kv_x = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_x, params["wv"])
    if cfg.attn_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    if cfg.use_qk_norm:
        q = rms_headnorm(params["q_norm"], q, cfg.norm_eps)
        k = rms_headnorm(params["k_norm"], k, cfg.norm_eps)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, kv_positions if kv_positions is not None else positions,
                 cfg.rope_theta)
    q = env.constrain(q, "act_batch", "act_seq", "act_heads", None)
    # k/v use the kv-seq axis: under sequence-parallel attention (act_seq
    # sharded) they are gathered once per layer here rather than once per
    # kv-chunk inside the online-softmax scan
    k = env.constrain(k, "act_batch", "act_kv_seq", "act_kv_heads", None)
    v = env.constrain(v, "act_batch", "act_kv_seq", "act_kv_heads", None)
    return q, k, v


def output_proj(env, cfg, params, o):
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"])
    if cfg.attn_bias:
        out = out + params["bo"]
    return env.constrain(out, "act_batch", "act_seq", "act_embed")


# --------------------------------------------------------------- masking
def _mask_block(mask_kind: str, qpos, kpos, window: int, prefix_len):
    """(Sq, C) boolean validity for a KV block. qpos/kpos absolute."""
    q = qpos[:, None]
    kk = kpos[None, :]
    if mask_kind == "full":
        return jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    valid = kk <= q
    if mask_kind == "local" and window:
        valid &= (q - kk) < window
    if mask_kind == "prefix" and prefix_len is not None:
        valid |= kk < prefix_len
    return valid


def _pick_chunk(s: int, want: int) -> int:
    want = min(want if want > 0 else 1024, s)
    while s % want:
        want -= 1
    return max(want, 1)


# ---------------------------------------------- train / prefill attention
def attention_core(env, cfg, q, k, v, *, mask_kind: str, q_offset: int = 0,
                   prefix_len=None, chunk: int = 0):
    """Online-softmax attention over KV chunks.

    q: (B, Sq, Hq, Dh); k, v: (B, Skv, Hkv, Dh). Returns (B, Sq, Hq, Dh).
    """
    b, sq, hq, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = _scale(cfg)
    qg = q.reshape(b, sq, hkv, g, dh)
    qpos = q_offset + jnp.arange(sq)

    c = _pick_chunk(skv, chunk or (skv if skv <= 2048 else 1024))
    nck = skv // c
    ks = k.reshape(b, nck, c, hkv, dh)
    vs = v.reshape(b, nck, c, hkv, dh)

    def scan_body(carry, inputs):
        m, l, acc = carry
        kc, vc, ci = inputs
        kpos = ci * c + jnp.arange(c)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qg, kc,
                       preferred_element_type=jnp.float32) * scale
        s = softcap(s, cfg.attn_logit_softcap)
        valid = _mask_block(mask_kind, qpos, kpos, cfg.local_window, prefix_len)
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bckd->bkgqd", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, dh), jnp.float32)
    if nck == 1:
        (m, l, acc), _ = scan_body((m0, l0, a0),
                                   (ks[:, 0], vs[:, 0], jnp.asarray(0)))
    else:
        (m, l, acc), _ = jax.lax.scan(
            scan_body, (m0, l0, a0),
            (ks.swapaxes(0, 1), vs.swapaxes(0, 1), jnp.arange(nck)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, dh)
    return out.astype(q.dtype)


# -------------------------------------------------------------- KV caches
def write_full_cache(cache_k, cache_v, k, v, pos: int = 0):
    """Write a [pos, pos+S) stripe into a full-length cache."""
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), pos, 1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), pos, 1)
    return cache_k, cache_v


def write_ring_cache(cache_k, cache_v, k, v):
    """Write the tail of a prefill's k/v into a ring buffer of size W.
    Slot for absolute position p is p % W. k: (B, S, H, D), S static."""
    w = cache_k.shape[1]
    s = k.shape[1]
    n = min(s, w)
    idx = (jnp.arange(s - n, s)) % w
    cache_k = cache_k.at[:, idx].set(k[:, s - n:].astype(cache_k.dtype))
    cache_v = cache_v.at[:, idx].set(v[:, s - n:].astype(cache_v.dtype))
    return cache_k, cache_v


def decode_write(cache_k, cache_v, k_t, v_t, pos, ring: bool):
    """Insert one token (B, 1, H, D) at absolute position ``pos`` (traced)."""
    w = cache_k.shape[1]
    slot = (pos % w) if ring else pos
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k_t.astype(cache_k.dtype), slot, 1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v_t.astype(cache_v.dtype), slot, 1)
    return cache_k, cache_v


def decode_attend(env, cfg, q_t, cache_k, cache_v, pos, *, ring: bool,
                  window: int = 0, cross: bool = False):
    """One-token attention against a cache.

    q_t: (B, 1, Hq, Dh); cache: (B, S, Hkv, Dh); pos: current absolute
    position (the new token's index, already written to the cache).
    """
    b, _, hq, dh = q_t.shape
    s, hkv = cache_k.shape[1], cache_k.shape[2]
    g = hq // hkv
    qg = q_t.reshape(b, hkv, g, dh)
    scale = _scale(cfg)

    scores = jnp.einsum("bkgd,bskd->bkgs", qg, cache_k,
                        preferred_element_type=jnp.float32) * scale
    scores = softcap(scores, cfg.attn_logit_softcap)
    pos_b = pos[:, None]                                     # (B, 1)
    slots = jnp.arange(s)[None, :]                           # (1, S)
    if cross:
        valid = jnp.ones((q_t.shape[0], s), bool)
    elif ring:
        abs_pos = pos_b - jnp.mod(pos_b - slots, s)
        valid = abs_pos >= 0
        if window and window < s:
            valid &= (pos_b - abs_pos) < window
    else:
        valid = slots <= pos_b
        if window:
            valid &= (pos_b - slots) < window
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    scores = env.constrain(scores, "act_batch", "act_kv_heads", None, "act_kv_seq")
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgs,bskd->bkgd", (p / l).astype(cache_v.dtype), cache_v,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, 1, hq, dh).astype(q_t.dtype)
