"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Block: x -> [branch A: linear -> GeLU] * [branch B: linear -> causal conv ->
RG-LRU] -> out projection. The RG-LRU uses per-channel (diagonal) gates —
a documented simplification of Griffin's block-diagonal gate matrices (see
DESIGN.md §2.4; parameter count matches ModelConfig.param_count):

    r_t = sigmoid(w_r * u_t + b_r)          (recurrence gate)
    i_t = sigmoid(w_i * u_t + b_i)          (input gate)
    a_t = exp(-c * softplus(lam) * r_t)     (per-channel decay, c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

The sequence recurrence is computed as a chunked linear scan: an
associative_scan inside fixed-size chunks (log-depth, VPU-friendly) with a
lax.scan carrying state across chunks — O(S) work, O(S/C) sequential steps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import conv1d_apply, conv1d_init, conv1d_step, nd_init

RGLRU_C = 8.0


def rglru_init(cfg, key, dtype):
    d = cfg.d_model
    rw = cfg.rglru_width or d
    ks = jax.random.split(key, 8)
    conv_p, conv_s = conv1d_init(ks[3], cfg.conv_width, rw, dtype)
    p = {
        "w_a": nd_init(ks[0], (d, rw), d, dtype),       # branch A (gate)
        "w_b": nd_init(ks[1], (d, rw), d, dtype),       # branch B (recurrent)
        "w_out": nd_init(ks[2], (rw, d), rw, dtype),
        "conv": conv_p,
        "w_r": jnp.zeros((rw,), jnp.float32),
        "b_r": jnp.zeros((rw,), jnp.float32),
        "w_i": jnp.zeros((rw,), jnp.float32),
        "b_i": jnp.zeros((rw,), jnp.float32),
        # init lambda so decay a ~ U[0.9, 0.999]-ish (griffin init)
        "lam": jnp.log(jnp.expm1(-jnp.log(
            jnp.linspace(0.9, 0.999, rw, dtype=jnp.float32)) / RGLRU_C)),
    }
    s = {
        "w_a": ("p_embed", "p_inner"), "w_b": ("p_embed", "p_inner"),
        "w_out": ("p_inner", "p_embed"), "conv": conv_s,
        "w_r": ("p_inner",), "b_r": ("p_inner",),
        "w_i": ("p_inner",), "b_i": ("p_inner",), "lam": ("p_inner",),
    }
    return p, s


def _gates(params, u):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(params["w_r"] * uf + params["b_r"])
    i = jax.nn.sigmoid(params["w_i"] * uf + params["b_i"])
    log_a = -RGLRU_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)
    return a, b


def _linear_scan(a, b, h0, chunk: int):
    """h_t = a_t h_{t-1} + b_t over axis 1. a,b: (B, S, W) fp32."""
    bsz, s, w = a.shape
    c = min(chunk, s)
    while s % c:
        c -= 1
    nc = s // c
    a_c = a.reshape(bsz, nc, c, w).swapaxes(0, 1)
    b_c = b.reshape(bsz, nc, c, w).swapaxes(0, 1)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    def chunk_step(h, ab):
        ac, bc = ab
        cum_a, cum_b = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h_all = cum_a * h[:, None, :] + cum_b
        return h_all[:, -1], h_all

    h_last, h_seq = jax.lax.scan(chunk_step, h0, (a_c, b_c))
    h_seq = h_seq.swapaxes(0, 1).reshape(bsz, s, w)
    return h_seq, h_last


def rglru_forward(env, cfg, params, x, *, chunk: int = 256, h0=None,
                  conv_state=None, return_state: bool = False):
    """x: (B, S, d). Returns (out, (h_last, conv_state)) if return_state."""
    bsz, s, _ = x.shape
    rw = params["w_out"].shape[0]
    ga = jax.nn.gelu(x @ params["w_a"], approximate=True)
    u = x @ params["w_b"]
    u = env.constrain(u, "act_batch", "act_seq", "act_mlp")
    if conv_state is not None:
        u_hist = jnp.concatenate([conv_state.astype(u.dtype), u], axis=1)
        u_conv = conv1d_apply(params["conv"], u_hist)[:, conv_state.shape[1]:]
        new_conv = u_hist[:, -(cfg.conv_width - 1):]
    else:
        u_conv = conv1d_apply(params["conv"], u)
        new_conv = u[:, -(cfg.conv_width - 1):]
    a, b = _gates(params, u_conv)
    if h0 is None:
        h0 = jnp.zeros((bsz, rw), jnp.float32)
    h_seq, h_last = _linear_scan(a, b, h0, chunk)
    out = (ga.astype(jnp.float32) * h_seq).astype(x.dtype) @ params["w_out"]
    out = env.constrain(out, "act_batch", "act_seq", "act_embed")
    if return_state:
        return out, (h_last, new_conv.astype(jnp.float32))
    return out


def rglru_step(env, cfg, params, x_t, state):
    """One decode step. x_t: (B, 1, d); state = (h, conv_state)."""
    h, conv_state = state
    ga = jax.nn.gelu(x_t[:, 0] @ params["w_a"], approximate=True)
    u = x_t[:, 0] @ params["w_b"]
    u_conv, new_conv = conv1d_step(params["conv"], u, conv_state.astype(u.dtype))
    a, b = _gates(params, u_conv)
    h_new = a * h + b
    out = (ga.astype(jnp.float32) * h_new).astype(x_t.dtype) @ params["w_out"]
    return out[:, None, :], (h_new, new_conv.astype(jnp.float32))
