"""Optimizers built from scratch (no optax dependency): AdamW and Adafactor.

AdamW keeps fp32 ``m``/``v`` (3x param bytes of state) — used for every arch
that fits. Adafactor keeps factored second moments (row/col fp32 vectors —
~0 extra bytes) and no momentum — required for arctic-480b, whose Adam state
alone (5.8 TB) exceeds a 512-chip v5e pod-pair (see configs/arctic_480b.py).

Optimizer-state sharding specs are derived mechanically from the parameter
specs (``opt_specs``) so the dry-run can shard state without tracing.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------- schedule
def lr_schedule(step, *, base_lr: float, warmup: int, total: int = 100_000):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return base_lr * warm * (0.1 + 0.9 * cos)


# -------------------------------------------------------------------- norms
def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale), tree), norm


# -------------------------------------------------------------------- AdamW
def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(grads, state, params, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        u = u + weight_decay * p.astype(jnp.float32)
        return m, v, (-lr * u).astype(p.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    updates = treedef.unflatten([o[2] for o in out])
    return updates, {"m": new_m, "v": new_v, "count": count}


# ---------------------------------------------------------------- Adafactor
def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params):
    def init_v(p):
        if _factored(p.shape):
            return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                    "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"v": jax.tree.map(init_v, params,
                              is_leaf=lambda x: hasattr(x, "shape")),
            "count": jnp.zeros((), jnp.int32)}


def adafactor_update(grads, state, params, *, lr, eps=1e-30,
                     weight_decay=0.0, clip_threshold=1.0, **_):
    count = state["count"] + 1
    beta2 = 1.0 - count.astype(jnp.float32) ** -0.8

    def upd(g, v, p):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if _factored(p.shape):
            r = beta2 * v["r"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            c = beta2 * v["c"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(r, axis=-1, keepdims=True), eps)
            vhat = (r / denom)[..., None] * c[..., None, :]
            new_v = {"r": r, "c": c}
        else:
            vhat = beta2 * v["v"] + (1 - beta2) * g2
            new_v = {"v": vhat}
        u = g * jax.lax.rsqrt(vhat + eps)
        rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
        u = u / jnp.maximum(1.0, rms_u / clip_threshold)
        u = u + weight_decay * p.astype(jnp.float32)
        return new_v, (-lr * u).astype(p.dtype)

    is_v_leaf = lambda x: isinstance(x, dict) and ("r" in x or "v" in x)
    flat_g, treedef = jax.tree.flatten(grads)
    flat_v = treedef.flatten_up_to(
        jax.tree.map(lambda x: x, state["v"], is_leaf=is_v_leaf))
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
    new_v = treedef.unflatten([o[0] for o in out])
    updates = treedef.unflatten([o[1] for o in out])
    return updates, {"v": new_v, "count": count}


# ------------------------------------------------------------------ factory
def make_optimizer(name: str):
    if name == "adamw":
        return adamw_init, adamw_update
    if name == "adafactor":
        return adafactor_init, adafactor_update
    raise ValueError(name)


def opt_specs(name: str, p_specs):
    """Optimizer-state logical specs derived from parameter specs."""
    is_spec = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    if name == "adamw":
        return {"m": p_specs, "v": p_specs, "count": ()}
    if name == "adafactor":
        def v_spec(sp):
            if len(sp) >= 2:
                return {"r": sp[:-1], "c": sp[:-2] + sp[-1:]}
            return {"v": sp}
        return {"v": jax.tree.map(v_spec, p_specs, is_leaf=is_spec),
                "count": ()}
    raise ValueError(name)
