from repro.train.optim import (  # noqa: F401
    adamw_init, adamw_update, adafactor_init, adafactor_update,
    make_optimizer, opt_specs, lr_schedule, global_norm, clip_by_global_norm)
from repro.train.train_step import make_train_step, make_serve_steps  # noqa: F401
