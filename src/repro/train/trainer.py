"""Training loop with fault tolerance and straggler mitigation.

* **checkpoint/restart**: periodic (async) sharded checkpoints with atomic
  publish; `Trainer.run` auto-resumes from the latest step, so a crashed
  process restarted by the cluster manager loses at most
  ``checkpoint_every`` steps (tested by injected failures).
* **elastic scaling**: restore accepts a different mesh — the checkpoint is
  mesh-agnostic (see checkpoint.py); `Trainer` re-lowers the step for the
  new topology.
* **straggler mitigation**: per-step wall times feed an EWMA monitor; a
  step slower than ``threshold x`` the EWMA raises a straggler event — on a
  real cluster the callback triggers hot-spare swap / re-sharding; here the
  hook is pluggable and unit-tested with synthetic delays.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models import model as M
from repro.parallel.sharding import ShardEnv, tree_shardings
from repro.train import checkpoint as ckpt
from repro.train import train_step as TS
from repro.train.data import SyntheticLM


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 3.0
    decay: float = 0.9
    ewma: float = 0.0
    events: List[int] = dataclasses.field(default_factory=list)
    on_straggler: Optional[Callable[[int, float, float], None]] = None

    def observe(self, step: int, dt: float) -> bool:
        if self.ewma == 0.0:
            self.ewma = dt
            return False
        is_straggler = dt > self.threshold * self.ewma
        if is_straggler:
            self.events.append(step)
            if self.on_straggler:
                self.on_straggler(step, dt, self.ewma)
            # don't poison the EWMA with the outlier
        else:
            self.ewma = self.decay * self.ewma + (1 - self.decay) * dt
        return is_straggler


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    checkpoint_dir: str = ""
    keep_checkpoints: int = 3
    log_every: int = 10
    async_checkpoint: bool = True


class Trainer:
    def __init__(self, cfg: ModelConfig, run: RunConfig, env: ShardEnv,
                 shape: ShapeConfig, tcfg: TrainerConfig,
                 fail_at_step: Optional[int] = None):
        self.cfg, self.run, self.env, self.shape, self.tcfg = \
            cfg, run, env, shape, tcfg
        self.fail_at_step = fail_at_step     # fault-injection for tests
        self.monitor = StragglerMonitor()
        self.metrics_log: List[Dict[str, float]] = []

        step_fn = TS.make_train_step(cfg, run, env)
        self.npod = (env.mesh.shape["pod"]
                     if "pod" in env.mesh.axis_names else 1)
        self.state_specs = TS.state_logical_specs(cfg, run)
        self.state_struct = TS.train_state_struct(cfg, run, npod=self.npod)
        self.state_sh = tree_shardings(env, self.state_specs,
                                       self.state_struct)
        self.step_fn = jax.jit(step_fn, in_shardings=(self.state_sh, None),
                               donate_argnums=(0,)) \
            if env.mesh.size > 1 else jax.jit(step_fn, donate_argnums=(0,))
        self.ckptr = (ckpt.AsyncCheckpointer(tcfg.checkpoint_dir,
                                             keep=tcfg.keep_checkpoints)
                      if tcfg.checkpoint_dir and tcfg.async_checkpoint
                      else None)

    # ------------------------------------------------------------- state
    def init_or_restore(self, key) -> Any:
        d = self.tcfg.checkpoint_dir
        if d and ckpt.latest_step(d) is not None:
            state, step = ckpt.restore(
                self.state_struct, d,
                shardings=self.state_sh if self.env.mesh.size > 1 else None,
                fingerprint=self.cfg.fingerprint())
            return state, step
        return TS.init_train_state(self.cfg, self.run, key,
                                   npod=self.npod), 0

    def _save(self, state, step: int) -> None:
        if not self.tcfg.checkpoint_dir:
            return
        if self.ckptr is not None:
            self.ckptr.save(state, step, fingerprint=self.cfg.fingerprint())
        else:
            ckpt.save(state, self.tcfg.checkpoint_dir, step,
                      fingerprint=self.cfg.fingerprint(),
                      keep=self.tcfg.keep_checkpoints)

    # --------------------------------------------------------------- run
    def run_loop(self, key=None, batches=None) -> Dict[str, Any]:
        key = key if key is not None else jax.random.PRNGKey(self.run.seed)
        state, start = self.init_or_restore(key)
        data = batches if batches is not None else SyntheticLM(
            self.cfg).batches(self.shape, self.env)
        losses = []
        for step in range(start, self.tcfg.total_steps):
            batch = next(data) if hasattr(data, "__next__") else data[
                step % len(data)]
            t0 = time.time()
            if self.fail_at_step is not None and step == self.fail_at_step:
                self.fail_at_step = None
                raise RuntimeError(f"injected failure at step {step}")
            state, metrics = self.step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            self.monitor.observe(step, dt)
            losses.append(loss)
            if step % self.tcfg.log_every == 0:
                self.metrics_log.append(
                    {"step": step, "loss": loss,
                     "grad_norm": float(metrics["grad_norm"]),
                     "lr": float(metrics["lr"]), "dt": dt})
            if self.tcfg.checkpoint_dir and \
                    (step + 1) % self.tcfg.checkpoint_every == 0:
                self._save(state, step + 1)
        if self.ckptr is not None:
            self.ckptr.wait()
        return {"state": state, "losses": losses,
                "straggler_events": list(self.monitor.events)}
