"""Sharded checkpointing with atomic manifests, async writes, and elastic
restore.

Layout: ``<dir>/step_<n>/arrays.npz`` + ``manifest.json`` (step, config
fingerprint, tree structure), written to a temp dir and atomically renamed —
a partially-written checkpoint is never visible. An optional background
thread makes saves non-blocking (training continues while the previous step
serializes). ``restore`` rebuilds the pytree and ``device_put``s each leaf
with the *target* mesh's shardings — restoring onto a different mesh
(elastic rescale after node loss) is the same code path.

Production note (documented, not needed in this single-process container):
multi-host would write one shard file per host (`arrays.<host>.npz`) with
the same manifest; restore would read the union.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save(state, directory, step: int, *, fingerprint: str = "",
         keep: int = 3) -> pathlib.Path:
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    tmp = directory / f".tmp_step_{step}"
    final = directory / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    flat = {k: np.asarray(v) for k, v in _flatten(state).items()}
    np.savez(tmp / "arrays.npz", **flat)
    manifest = {"step": step, "fingerprint": fingerprint,
                "keys": sorted(flat), "time": time.time()}
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)                       # atomic publish
    _gc(directory, keep)
    return final


def _gc(directory: pathlib.Path, keep: int) -> None:
    steps = sorted((int(p.name.split("_")[1]), p)
                   for p in directory.glob("step_*"))
    for _, p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(directory) -> Optional[int]:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in directory.glob("step_*")]
    return max(steps) if steps else None


def restore(abstract_state, directory, step: Optional[int] = None,
            shardings=None, *, fingerprint: str = ""):
    """Rebuild ``abstract_state``'s pytree from disk; ``shardings`` (same
    tree shape) places each leaf — pass the *new* mesh's shardings for an
    elastic restore."""
    directory = pathlib.Path(directory)
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    d = directory / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    if fingerprint and manifest["fingerprint"] and \
            manifest["fingerprint"] != fingerprint:
        raise ValueError("checkpoint/config fingerprint mismatch: "
                         f"{manifest['fingerprint']} != {fingerprint}")
    arrays = np.load(d / "arrays.npz")
    flat_keys = list(_flatten(abstract_state))
    leaves, treedef = jax.tree_util.tree_flatten(abstract_state)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    out = []
    for key, ref, sh in zip(flat_keys, leaves, shard_leaves):
        arr = arrays[key]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {ref.shape}")
        arr = arr.astype(ref.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.device_put(arr))
    return treedef.unflatten(out), manifest["step"]


class AsyncCheckpointer:
    """Non-blocking saves: the device->host copy happens on the caller
    thread (cheap), serialization + fsync on a worker thread."""

    def __init__(self, directory, *, keep: int = 3):
        self.directory = pathlib.Path(directory)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.error: Optional[BaseException] = None

    def save(self, state, step: int, fingerprint: str = "") -> None:
        self.wait()
        host_state = jax.tree.map(np.asarray, state)

        def worker():
            try:
                save(host_state, self.directory, step,
                     fingerprint=fingerprint, keep=self.keep)
            except BaseException as e:   # surfaced on next wait()
                self.error = e

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.error is not None:
            err, self.error = self.error, None
            raise err
