"""Jittable train / serve steps with full sharding metadata.

``make_train_step`` builds (step_fn, state_specs, batch_specs) for pjit:
grad accumulation (scan over microbatches), global-norm clipping, LR
schedule, AdamW/Adafactor, and optional int8 cross-pod gradient compression
(shard_map manual over ``pod``, auto over data/model, with error feedback).

``make_serve_steps`` builds (prefill_fn, decode_fn) for the serving shapes.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models import model as M
from repro.parallel import compression as C
from repro.parallel.sharding import ShardEnv
from repro.train import optim as O


# ------------------------------------------------------------------- specs
def batch_logical_specs(cfg: ModelConfig, mode: str) -> Dict[str, Any]:
    if mode == "train":
        sp: Dict[str, Any] = {"tokens": ("act_batch", None),
                              "targets": ("act_batch", None)}
        if cfg.frontend == "vision":
            sp["patch_embeds"] = ("act_batch", None, None)
        if cfg.is_encoder_decoder:
            sp["src_embeds"] = ("act_batch", None, None)
        return sp
    if mode == "prefill":
        sp = {"tokens": ("act_batch", None)}
        if cfg.frontend == "vision":
            sp["patch_embeds"] = ("act_batch", None, None)
        if cfg.is_encoder_decoder:
            sp["src_embeds"] = ("act_batch", None, None)
        return sp
    # decode
    return {"token": ("act_batch", None), "pos": ("act_batch",),
            "cache": M.cache_specs(cfg)}


def state_logical_specs(cfg: ModelConfig, run: RunConfig):
    p_specs = M.param_specs(cfg)
    o_specs = O.opt_specs(cfg.optimizer, p_specs)
    state = {"params": p_specs, "opt": o_specs,
             "step": ()}
    if run.gradient_compression:
        from repro.parallel.sharding import is_spec_leaf
        state["err"] = jax.tree.map(lambda sp: ("pod_stack",) + sp,
                                    p_specs, is_leaf=is_spec_leaf)
    return state


# -------------------------------------------------------------- train step
def make_train_step(cfg: ModelConfig, run: RunConfig, env: ShardEnv):
    opt_init, opt_update = O.make_optimizer(cfg.optimizer)
    use_pod_compress = (run.gradient_compression == "int8"
                        and "pod" in env.mesh.axis_names
                        and env.mesh.shape["pod"] > 1)

    # inside the pod-manual shard_map, constraints may not name 'pod'
    env_inner = env.without_axes("pod") if use_pod_compress else env

    def loss_of(params, batch):
        return M.loss_fn(env_inner, cfg, params, batch, run)

    def grads_of(params, batch):
        if run.grad_accum <= 1:
            return jax.value_and_grad(loss_of)(params, batch)

        n = run.grad_accum

        def micro(b):
            return jax.tree.map(
                lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), b)

        def acc_step(carry, mb):
            loss_a, g_a = carry
            loss, g = jax.value_and_grad(loss_of)(params, mb)
            return (loss_a + loss / n,
                    jax.tree.map(lambda a, b: a + b / n, g_a, g)), None

        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(acc_step, (0.0, zero_g), micro(batch))
        return loss, grads

    npod = (env.mesh.shape["pod"]
            if "pod" in env.mesh.axis_names else 1)

    def train_step(state, batch):
        params = state["params"]
        step = state["step"]
        if use_pod_compress:
            # per-pod grads via vmap over a (npod, B/npod, ...) batch split;
            # int8 exchange + error feedback over the pod axis only (see
            # parallel/compression.py)
            def pod_split(x):
                x = x.reshape((npod, x.shape[0] // npod) + x.shape[1:])
                return jax.lax.with_sharding_constraint(
                    x, env.sharding(*("pod_stack", "act_batch")
                                    + (None,) * (x.ndim - 2),
                                    shape=x.shape))
            batch_p = jax.tree.map(pod_split, batch)
            losses, grads_p = jax.vmap(
                jax.value_and_grad(loss_of), in_axes=(None, 0))(
                    params, batch_p)
            loss = jnp.mean(losses)
            # preserve intra-pod grad sharding through the int8 exchange
            from repro.parallel.sharding import is_spec_leaf, tree_shardings
            err_specs = jax.tree.map(lambda sp: ("pod_stack",) + sp,
                                     M.param_specs(cfg), is_leaf=is_spec_leaf)
            err_sh = tree_shardings(env, err_specs, state["err"])
            grads, new_err = C.pod_mean_compressed(
                grads_p, state["err"], env.mesh, shardings=err_sh)
        else:
            loss, grads = grads_of(params, batch)
            new_err = state.get("err")

        grads, gnorm = O.clip_by_global_norm(grads, run.max_grad_norm)
        lr = O.lr_schedule(step, base_lr=run.learning_rate,
                           warmup=run.warmup_steps)
        updates, new_opt = opt_update(
            grads, state["opt"], params, lr=lr, b1=run.adam_b1,
            b2=run.adam_b2, weight_decay=run.weight_decay)
        new_params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)
                          ).astype(p.dtype), params, updates)
        new_state = {"params": new_params, "opt": new_opt, "step": step + 1}
        if "err" in state:
            new_state["err"] = new_err
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_state, metrics

    return train_step


def init_train_state(cfg: ModelConfig, run: RunConfig, key, npod: int = 1):
    params = M.init_params(cfg, key, run)
    opt_init, _ = O.make_optimizer(cfg.optimizer)
    state = {"params": params, "opt": opt_init(params),
             "step": jnp.zeros((), jnp.int32)}
    if run.gradient_compression:
        state["err"] = C.init_error_feedback(params, npod)
    return state


def train_state_struct(cfg: ModelConfig, run: RunConfig, npod: int = 1):
    """abstract state (ShapeDtypeStructs) without allocating."""
    return jax.eval_shape(
        functools.partial(init_train_state, cfg, run, npod=npod),
        jax.random.PRNGKey(0))


# -------------------------------------------------------------- serve steps
def make_serve_steps(cfg: ModelConfig, run: RunConfig, env: ShardEnv):
    def prefill_fn(params, batch):
        return M.prefill(env, cfg, params, batch, run)

    def decode_fn(params, token, pos, cache):
        return M.decode_step(env, cfg, params, token, pos, cache, run)

    return prefill_fn, decode_fn
