"""Synthetic-but-deterministic data pipeline.

Generates reproducible token streams (seeded, host-shardable) with enough
structure for loss to fall (Zipf unigrams + a Markov bigram mixture), plus
the frontend stand-ins (patch/frame embeddings) for the VLM/audio archs.
Batches come out already sharded per the env's ``act_batch`` rules via
``jax.device_put`` so host->device transfer overlaps the step.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.parallel.sharding import ShardEnv


@dataclasses.dataclass
class DataConfig:
    seed: int = 0
    zipf_a: float = 1.2
    markov_mix: float = 0.5      # fraction of tokens drawn from bigram chain
    pad_id: int = -1


class SyntheticLM:
    """Deterministic stream: x_t ~ mix(Zipf unigram, bigram(x_{t-1}))."""

    def __init__(self, cfg: ModelConfig, data: DataConfig = DataConfig()):
        self.cfg = cfg
        self.data = data
        rng = np.random.default_rng(data.seed)
        v = cfg.vocab_size
        # small dense bigram table over a reduced alphabet, tiled over vocab
        base = min(v, 512)
        self._base = base
        self._bigram = rng.dirichlet(np.ones(base) * 0.1, size=base)
        self._unigram = (np.arange(1, base + 1, dtype=np.float64)
                         ** -data.zipf_a)
        self._unigram /= self._unigram.sum()

    def sample_tokens(self, rng: np.random.Generator, batch: int,
                      seq: int) -> np.ndarray:
        base = self._base
        out = np.empty((batch, seq), np.int64)
        prev = rng.integers(0, base, size=batch)
        for t in range(seq):
            from_bigram = rng.random(batch) < self.data.markov_mix
            big = np.array([rng.choice(base, p=self._bigram[p]) for p in
                            prev[from_bigram]]) if from_bigram.any() else []
            uni = rng.choice(base, p=self._unigram,
                             size=int((~from_bigram).sum()))
            nxt = np.empty(batch, np.int64)
            nxt[from_bigram] = big
            nxt[~from_bigram] = uni
            out[:, t] = nxt
            prev = nxt
        return out % self.cfg.vocab_size

    def batches(self, shape: ShapeConfig, env: Optional[ShardEnv] = None,
                host_index: int = 0, num_hosts: int = 1
                ) -> Iterator[Dict[str, jnp.ndarray]]:
        """Infinite iterator of train batches (tokens + shifted targets)."""
        cfg = self.cfg
        b = shape.global_batch // num_hosts
        step = 0
        while True:
            rng = np.random.default_rng(
                (self.data.seed, host_index, step))
            if cfg.is_encoder_decoder:
                tgt = max(shape.seq_len // 4, 8)
                toks = self.sample_tokens(rng, b, tgt + 1)
                batch = {
                    "src_embeds": rng.standard_normal(
                        (b, shape.seq_len, cfg.d_model)).astype(np.float32)
                    * 0.02,
                    "tokens": toks[:, :-1],
                    "targets": toks[:, 1:],
                }
            elif cfg.frontend == "vision":
                text = shape.seq_len - cfg.frontend_len
                toks = self.sample_tokens(rng, b, text + 1)
                batch = {
                    "patch_embeds": rng.standard_normal(
                        (b, cfg.frontend_len, cfg.d_model)).astype(np.float32)
                    * 0.02,
                    "tokens": toks[:, :-1],
                    "targets": toks[:, 1:],
                }
            else:
                toks = self.sample_tokens(rng, b, shape.seq_len + 1)
                batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
            out = {}
            for k, x in batch.items():
                if x.dtype == np.int64:
                    x = x.astype(np.int32)
                elif x.dtype == np.float32 and k != "targets":
                    x = x.astype(np.float32)
                arr = jnp.asarray(x if k in ("tokens", "targets")
                                  else x.astype(jnp.bfloat16)
                                  if k != "targets" else x)
                if env is not None and env.mesh.size > 1:
                    spec = ("act_batch",) + (None,) * (arr.ndim - 1)
                    arr = jax.device_put(arr, env.sharding(
                        *spec, shape=arr.shape))
                out[k] = arr
            yield out
            step += 1
