"""Pure-jnp oracle for flash attention (independent full-softmax impl)."""
from __future__ import annotations

import math

import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, scale: float = 0.0, causal: bool = True,
                  window: int = 0, softcap: float = 0.0):
    """q: (BH, Sq, D); k, v: (BH, Skv, D). fp32 softmax over all keys."""
    d = q.shape[-1]
    scale = scale or 1.0 / math.sqrt(d)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    sq, skv = q.shape[1], k.shape[1]
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    valid = jnp.ones((sq, skv), bool)
    if causal:
        valid &= kpos <= qpos
        if window:
            valid &= (qpos - kpos) < window
    s = jnp.where(valid[None], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
