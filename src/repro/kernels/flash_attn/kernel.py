"""Flash attention TPU kernel: tiled online-softmax with causal/local block
skipping.

Grid = (batch*q_heads, num_q_blocks, num_kv_blocks); the KV axis is the
innermost (sequential on TPU), so the (m, l, acc) running state lives in
VMEM scratch that persists across KV steps. Blocks are MXU-aligned
(block_q x block_kv = 128 x 128 by default, head_dim loaded whole).

Causal/local masking is applied per tile; *fully-masked tiles are skipped*
(pl.when guards the matmuls) — on hardware the skipped tile costs only grid
overhead, recovering the ~2x triangular saving the XLA chunked-scan path
cannot express (see DESIGN.md / EXPERIMENTS.md §Perf). GQA is handled by
mapping each q-head's grid row onto its kv head in the BlockSpec index_map.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: int, softcap: float,
                 block_q: int, block_kv: int, num_kv_blocks: int,
                 seq_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_kv
    # tile relevance: causal -> skip tiles entirely above the diagonal;
    # local  -> also skip tiles entirely outside the window
    relevant = True
    if causal:
        relevant = k_start <= q_start + block_q - 1
        if window:
            relevant &= (k_start + block_kv - 1) >= (q_start - window + 1)

    @pl.when(relevant if not isinstance(relevant, bool) else True)
    def _body():
        q = q_ref[0].astype(jnp.float32)          # (block_q, d)
        k = k_ref[0].astype(jnp.float32)          # (block_kv, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = kpos < seq_len
        if causal:
            valid &= kpos <= qpos
            if window:
                valid &= (qpos - kpos) < window
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, scale: float, causal: bool,
                           window: int, softcap: float,
                           true_skv: int = 0,
                           block_q: int = 128, block_kv: int = 128,
                           interpret: bool = False):
    """q: (BH, Sq, D); k, v: (BH, Skv, D) — kv heads already broadcast.
    Sq/Skv must be multiples of the block sizes (ops.py pads);
    ``true_skv`` masks the KV padding."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    nq = sq // block_q
    nkv = skv // block_kv

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_kv=block_kv,
        num_kv_blocks=nkv, seq_len=true_skv or skv)

    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum l
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
