"""jit'd public wrapper: (B, S, H, D) GQA attention via the Pallas kernel.

Handles GQA head broadcast, scale defaults, padding to block multiples, and
the interpret flag (CPU validation)."""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn.kernel import flash_attention_kernel


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "block_q", "block_kv",
    "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale: float = 0.0,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = False):
    """q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D); Hq % Hkv == 0.
    Returns (B, Sq, Hq, D)."""
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale or 1.0 / math.sqrt(d)

    # broadcast kv heads to q heads, fold heads into batch
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hq, skv, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hq, skv, d)

    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    pad_q = (-sq) % bq
    pad_kv = (-skv) % bkv
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    if pad_kv:
        kf = jnp.pad(kf, ((0, 0), (0, pad_kv), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_kv), (0, 0)))

    out = flash_attention_kernel(
        qf, kf, vf, scale=scale, causal=causal, window=window,
        softcap=softcap, true_skv=skv, block_q=bq, block_kv=bkv,
        interpret=interpret)
    out = out[:, :sq]
    return out.reshape(b, hq, sq, d).transpose(0, 2, 1, 3)
