"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel package has kernel.py (pl.pallas_call + explicit BlockSpec VMEM
tiling), ops.py (jit'd public wrapper) and ref.py (pure-jnp oracle).
Validated on CPU in interpret=True mode; TPU v5e is the lowering target.
"""
