"""Decode attention TPU kernel: one query token vs a long KV cache.

Grid = (batch*heads, num_kv_blocks); KV blocks stream through VMEM while
the partial-softmax state (m, l, acc) accumulates in scratch — the
flash-decoding pattern. Per-sequence lengths mask the tail; block sizes are
lane-aligned (block_kv = 128/256/512).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, softcap: float, block_kv: int,
                   num_kv_blocks: int):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # (1, d)
    k = k_ref[0].astype(jnp.float32)                  # (block_kv, d)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    kpos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = kpos < len_ref[0]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


def decode_attention_kernel(q, k, v, lengths, *, scale: float,
                            softcap: float = 0.0, block_kv: int = 256,
                            interpret: bool = False):
    """q: (BH, 1, D); k, v: (BH, S, D); lengths: (BH,) valid KV lengths."""
    bh, _, d = q.shape
    skv = k.shape[1]
    nkv = skv // block_kv

    kernel = functools.partial(
        _decode_kernel, scale=scale, softcap=softcap, block_kv=block_kv,
        num_kv_blocks=nkv)

    return pl.pallas_call(
        kernel,
        grid=(bh, nkv),
        in_specs=[
            pl.BlockSpec((1,), lambda b, j: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, q, k, v)
