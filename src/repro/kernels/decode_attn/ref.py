"""Pure-jnp oracle for decode attention."""
from __future__ import annotations

import math

import jax.numpy as jnp

NEG_INF = -1e30


def decode_ref(q, k, v, lengths, *, scale: float = 0.0, softcap: float = 0.0):
    """q: (BH, 1, D); k, v: (BH, S, D); lengths: (BH,)."""
    d = q.shape[-1]
    scale = scale or 1.0 / math.sqrt(d)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    valid = jnp.arange(k.shape[1])[None, None, :] < lengths[:, None, None]
    s = jnp.where(valid, s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
