"""jit'd wrapper: GQA decode attention against a (B, S, Hkv, D) cache."""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels.decode_attn.kernel import decode_attention_kernel


@functools.partial(jax.jit, static_argnames=(
    "softcap", "scale", "block_kv", "interpret"))
def decode_attention(q, cache_k, cache_v, lengths, *, softcap: float = 0.0,
                     scale: float = 0.0, block_kv: int = 256,
                     interpret: bool = False):
    """q: (B, 1, Hq, D); cache_k/v: (B, S, Hkv, D); lengths: (B,) number of
    valid cache positions per sequence. Returns (B, 1, Hq, D)."""
    b, _, hq, d = q.shape
    s, hkv = cache_k.shape[1], cache_k.shape[2]
    g = hq // hkv
    scale = scale or 1.0 / math.sqrt(d)

    k = jnp.repeat(cache_k, g, axis=2).transpose(0, 2, 1, 3).reshape(
        b * hq, s, d)
    v = jnp.repeat(cache_v, g, axis=2).transpose(0, 2, 1, 3).reshape(
        b * hq, s, d)
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, 1, d)
    lens = jnp.repeat(lengths, hq).astype(jnp.int32)

    bkv = min(block_kv, s)
    pad = (-s) % bkv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))

    out = decode_attention_kernel(qf, k, v, lens, scale=scale,
                                  softcap=softcap, block_kv=bkv,
                                  interpret=interpret)
    return out.reshape(b, hq, 1, d).transpose(0, 2, 1, 3)
