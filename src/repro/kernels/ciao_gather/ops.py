"""jit'd wrapper for the CIAO cached gather."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ciao_gather.kernel import ciao_gather_kernel


@functools.partial(jax.jit, static_argnames=(
    "c_main", "c_iso", "block_t", "interpret"))
def ciao_gather(table, indices, streams, iso_map, *, c_main: int = 256,
                c_iso: int = 64, block_t: int = 128,
                interpret: bool = False):
    """Gather ``table[indices]`` through the two-partition VMEM cache.

    table: (N, D); indices: (T,) int32 row ids; streams: (T,) int32 stream
    id per request; iso_map: (S,) int32 isolation bits from the host
    detector. Returns (out (T, D), stats (S, 2) [hits, misses])."""
    t = indices.shape[0]
    s = iso_map.shape[0]
    bt = min(block_t, t)
    pad = (-t) % bt
    if pad:
        # route padding to a phantom stream so real stats stay exact
        indices = jnp.pad(indices, (0, pad), constant_values=indices[-1])
        streams = jnp.pad(streams, (0, pad), constant_values=s)
        iso_map = jnp.pad(iso_map, (0, 1))
    out, stats = ciao_gather_kernel(
        table, indices.astype(jnp.int32), streams.astype(jnp.int32),
        iso_map.astype(jnp.int32), c_main=c_main, c_iso=c_iso, block_t=bt,
        interpret=interpret)
    if pad:
        out = out[:t]
        stats = stats[:s]
    return out, stats
