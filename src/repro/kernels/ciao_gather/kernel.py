"""CIAO software-managed VMEM cache kernel (the paper's §III-B on TPU).

Irregular row-gather (embedding rows / KV pages / SpMV index arrays — the
paper's §VI motivation) from an HBM-resident table, staged through a
**two-partition direct-mapped VMEM block cache**:

  * partition 0 ("L1D")        — slots [0, c_main)
  * partition 1 ("unused smem") — slots [c_main, c_main + c_iso): request
    *streams* flagged as interferers by the host-side
    :class:`InterferenceDetector` are redirected here, exactly like CIAO
    redirects interfering warps — isolation is structural (the partition is
    a pure function of the stream's isolation bit), so the single-copy
    coherence invariant of §IV-B holds by construction.

Tags live in **SMEM scratch**, data rows in **VMEM scratch** — the TPU
analogue of the paper's tags-in-the-opposite-bank-group placement: a tag
probe and the data access touch different memories and proceed in parallel.

Per-stream hit/miss counters are emitted (SMEM-accumulated) as the VTA-style
feedback the host scheduler consumes.

NOTE: rows are fetched with dynamic loads from an ANY-space ref; a
production TPU build would issue ``pltpu.make_async_copy`` DMAs with
double-buffering — semantics identical, validated here in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUMemorySpace -> MemorySpace
_ANY_SPACE = getattr(pltpu, "MemorySpace", None) or pltpu.TPUMemorySpace
_ANY = _ANY_SPACE.ANY


def _gather_kernel(idx_ref, stream_ref, iso_ref, table_ref, out_ref,
                   stats_ref, tags_scr, data_scr, cnt_scr, *,
                   block_t: int, c_main: int, c_iso: int, num_streams: int,
                   num_blocks: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        tags_scr[...] = jnp.full_like(tags_scr, -1)
        cnt_scr[...] = jnp.zeros_like(cnt_scr)

    def body(i, _):
        idx = idx_ref[i]
        stream = stream_ref[i]
        iso = iso_ref[stream]
        # partition choice: direct-mapped slot in main or isolated region
        slot_main = jax.lax.rem(idx, jnp.int32(c_main))
        slot_iso = jnp.int32(c_main) + jax.lax.rem(idx, jnp.int32(max(c_iso, 1)))
        slot = jnp.where(iso > 0, slot_iso, slot_main)
        hit = tags_scr[slot] == idx

        def on_hit():
            return pl.load(data_scr, (pl.ds(slot, 1), slice(None)))

        def on_miss():
            row = pl.load(table_ref, (pl.ds(idx, 1), slice(None)))
            pl.store(data_scr, (pl.ds(slot, 1), slice(None)), row)
            tags_scr[slot] = idx
            return row

        row = jax.lax.cond(hit, on_hit, on_miss)
        pl.store(out_ref, (pl.ds(i, 1), slice(None)), row)
        # per-stream hit/miss counters (VTA-style feedback)
        col = jnp.where(hit, 0, 1)
        cnt_scr[stream, col] += 1
        return 0

    jax.lax.fori_loop(0, block_t, body, 0)

    @pl.when(step == num_blocks - 1)
    def _emit():
        stats_ref[...] = cnt_scr[...]


def ciao_gather_kernel(table, indices, streams, iso_map, *,
                       c_main: int = 256, c_iso: int = 64,
                       block_t: int = 128, interpret: bool = False):
    """table: (N, D); indices/streams: (T,) int32; iso_map: (S,) int32.
    Returns (out (T, D), stats (S, 2) int32 [hits, misses] per stream)."""
    t = indices.shape[0]
    n, d = table.shape
    num_streams = iso_map.shape[0]
    nb = t // block_t

    kernel = functools.partial(
        _gather_kernel, block_t=block_t, c_main=c_main, c_iso=c_iso,
        num_streams=num_streams, num_blocks=nb)

    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_t,), lambda i: (i,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((block_t,), lambda i: (i,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((num_streams,), lambda i: (0,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=_ANY),  # table in HBM
        ],
        out_specs=[
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),
            pl.BlockSpec((num_streams, 2), lambda i: (0, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, d), table.dtype),
            jax.ShapeDtypeStruct((num_streams, 2), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.SMEM((c_main + max(c_iso, 1),), jnp.int32),   # tags
            pltpu.VMEM((c_main + max(c_iso, 1), d), table.dtype),  # data
            pltpu.SMEM((num_streams, 2), jnp.int32),            # counters
        ],
        interpret=interpret,
    )(indices, streams, iso_map, table)
