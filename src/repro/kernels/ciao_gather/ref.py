"""Oracles for the CIAO gather kernel.

* ``gather_ref``: the output contract — a plain table gather.
* ``cache_sim_ref``: numpy simulation of the two-partition direct-mapped
  cache, producing the exact per-stream hit/miss counters the kernel must
  emit (same replacement policy, same partition function).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def gather_ref(table, indices):
    return jnp.take(table, indices, axis=0)


def cache_sim_ref(indices, streams, iso_map, *, c_main: int, c_iso: int,
                  num_streams: int):
    tags = -np.ones(c_main + max(c_iso, 1), np.int64)
    stats = np.zeros((num_streams, 2), np.int64)
    for idx, st in zip(np.asarray(indices), np.asarray(streams)):
        iso = iso_map[st] > 0
        slot = (c_main + idx % max(c_iso, 1)) if iso else idx % c_main
        if tags[slot] == idx:
            stats[st, 0] += 1
        else:
            stats[st, 1] += 1
            tags[slot] = idx
    return stats
