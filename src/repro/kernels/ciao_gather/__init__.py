from repro.kernels.ciao_gather.ops import ciao_gather  # noqa: F401
