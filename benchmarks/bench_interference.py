"""Fig. 4 reproduction: non-uniform interference — per-warp max/min
interference frequencies under GTO on an irregular LWS workload.

The (evictor, victim) pair counts are recorded by the interference
detector itself and surface in each ``RunRecord``, so this is a one-cell
``repro.core.runner`` grid plus post-processing."""
from __future__ import annotations

from typing import Optional

from benchmarks.common import emit
from repro.core.runner import ExperimentGrid, run_grid


def main(processes: Optional[int] = None,
         json_path: Optional[str] = None, engine: str = "auto"):
    records = run_grid(ExperimentGrid(name="fig4", workloads=("kmn",),
                                      policies=("gto",)),
                       processes=processes, json_path=json_path,
                       engine=engine)
    pairs = records[0].pairs            # [evictor, victim, count] desc
    if not pairs:
        emit("fig4/interference_pairs", 0.0, "none")
        return
    per_victim: dict = {}
    for ev, wid, c in pairs:
        per_victim.setdefault(wid, []).append(c)
    maxes = [max(v) for v in per_victim.values()]
    mins = [min(v) for v in per_victim.values()]
    ev, wid, c = pairs[0]
    emit("fig4/max_pair", 0.0, f"{ev}->{wid}:{c}")
    emit("fig4/skew", 0.0,
         f"max_freq_mean={sum(maxes)/len(maxes):.1f};"
         f"min_freq_mean={sum(mins)/len(mins):.1f};"
         f"skew_ratio={sum(maxes)/max(sum(mins),1):.1f}")


if __name__ == "__main__":
    main()
