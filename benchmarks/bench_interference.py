"""Fig. 4 reproduction: non-uniform interference — per-warp max/min
interference frequencies under GTO on an irregular LWS workload."""
from __future__ import annotations

from collections import Counter

from benchmarks.common import emit
from repro.core import make_workload
from repro.core.simulator import SMSimulator


def main():
    wl = make_workload("kmn", scale=0.5)
    sim = SMSimulator(wl, "gto")

    pair_counts: Counter = Counter()
    orig = sim.det.on_miss

    def traced(wid, line):
        ev = orig(wid, line)
        if ev is not None:
            pair_counts[(ev, wid)] += 1
        return ev

    sim.det.on_miss = traced
    sim.run()
    if not pair_counts:
        emit("fig4/interference_pairs", 0.0, "none")
        return
    per_victim: dict = {}
    for (ev, wid), c in pair_counts.items():
        per_victim.setdefault(wid, []).append(c)
    maxes = [max(v) for v in per_victim.values()]
    mins = [min(v) for v in per_victim.values()]
    top = pair_counts.most_common(3)
    emit("fig4/max_pair", 0.0,
         f"{top[0][0][0]}->{top[0][0][1]}:{top[0][1]}")
    emit("fig4/skew", 0.0,
         f"max_freq_mean={sum(maxes)/len(maxes):.1f};"
         f"min_freq_mean={sum(mins)/len(mins):.1f};"
         f"skew_ratio={sum(maxes)/max(sum(mins),1):.1f}")


if __name__ == "__main__":
    main()
