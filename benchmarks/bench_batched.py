"""Grid-throughput harness: batched lockstep engine (C / numpy /
jitted-XLA steppers, serial and thread-parallel) vs the PR-2 spawn-pool
path, written to ``BENCH_PR8.json`` at the repo root.

Measures end-to-end ``run_grid`` wall time on two grids, interleaved
best-of-N in one process (the container's absolute speed drifts ~2x
between sessions, so only same-run ratios are meaningful):

* the single-SM **fig8** grid (the paper's Fig. 8 policy × workload
  sweep), four ways — ``pool`` (``engine="process"`` at ``--jobs``
  workers), ``batched`` (auto backend: the C stepper when a compiler is
  available), ``batched_numpy`` (the portable pure-numpy stepper), and
  ``batched_jax`` (``engine="jax"``: the jitted XLA while-loop stepper,
  when jax imports);
* a 2-SM shared-L2 **multi-SM** grid (the paper's multi-programmed
  contention setup) — ``pool`` vs ``batched``, the configuration the
  engine could not batch before PR 5;
* a **hyperparameter sweep** (`sweep` section) — a ≥1000-cell cutoff ×
  throttle-epoch grid (256 detector configs over one shape class, each
  cell horizon-bounded by ``max_cycles`` like an auto-tuner evaluation)
  run through the batched C path two ways: ``shape`` (the PR-8 relaxed
  grouping — one group per shape class, knobs as per-row config planes,
  token planes memoized) vs ``legacy`` (``$REPRO_BATCH_GROUPING=exact``
  + ``$REPRO_NO_TOKEN_MEMO=1``: one group per distinct ``SimConfig``
  re-encoding its token planes, the pre-PR-8 behavior). Records are
  asserted equal; the section reports cells/sec and group counts for
  both, and ``--floor-sweep`` guards the ratio;
* a **jobs scaling curve** for the C-path batched engine —
  ``batched_j2`` / ``batched_jN`` rerun the fig8 grid with the chunk
  scheduler fanned over 2 / ``os.cpu_count()`` worker threads (the
  ctypes stepper releases the GIL, so threads scale across cores).
  Records are asserted equal to every serial leg; the headline
  ``parallel`` block reports the per-jobs walls and ``speedup_at_2``.
  On a single-core machine the curve is still measured (and is honestly
  ~1.0x — there is nothing to scale onto); ``--floor-parallel`` is
  skipped there so 1-core boxes don't fail a multicore guard.

Every engine's records are asserted **equal** before any time is
reported — the speedup is meaningless unless the grids agree cell for
cell. The headline ratio is pool wall time / batched wall time, i.e.
grid-sweep throughput in cells/sec.

**Compile vs steady state.** One-time costs are kept out of the timed
windows for every backend alike: workload generation and the C
stepper's ``cc`` invocation happen in the untimed warm-up, and the jax
leg does one untimed warm run first so trace + XLA compilation are
cached (``jax_backend`` keys its jit cache on the engine's static
shape). The warm run's wall is recorded and ``compile_s`` is estimated
as warm-run wall minus the best steady-state wall, reported per backend
under ``results.*.compile_s`` — so regressions in compile time and in
steady-state throughput are visible separately.

On CPUs the jitted leg is bound by XLA:CPU's per-dispatch overhead
(~microseconds x ~40 fused thunks x tens of thousands of lockstep
iterations) and its wall is nearly independent of batch width; it
exists for accelerator targets and very wide batches, not to beat the
C stepper here. The honest CPU numbers land in the JSON either way.

The batched runs also report a **time breakdown** (`breakdown`):
``stepper_s`` (inside the C/numpy/XLA stepper), ``drain_s`` (vectorized
pause-drain: epoch/policy math; for the C path after the in-stepper
next-trigger scan this is one final drain), ``engine_build_s`` (state
stacking) and ``group_build_s`` (workload load + sweep flattening +
chunking) — so a future regression in the epoch path shows up as
``drain_s`` growth, not just a worse ratio.

Usage::

    python -m benchmarks.bench_batched [--quick] [--repeats N]
                                       [--scale S] [--jobs N]
                                       [--out BENCH_PR8.json]
                                       [--floor-ratio R]
                                       [--floor-multism R]
                                       [--floor-jax R]
                                       [--floor-parallel R]
                                       [--floor-sweep R]

``--floor-ratio R`` exits nonzero if the fig8 batched/pool throughput
ratio falls below R — the CI guard against regressing the batched
engine. ``--floor-multism`` guards the multi-SM ratio,
``--floor-jax`` the steady-state jax/pool ratio (keep it a sanity
bound, e.g. 0.25 — see the note above), ``--floor-parallel`` the
2-worker thread-scaling speedup (auto-skipped when ``os.cpu_count()``
< 2), and ``--floor-sweep`` the sweep shape/legacy grouping ratio.
Ratios, not absolute rates, so noisy runners do not flap the job.

**Chaos mode.** The bench doubles as the CI chaos smoke: run it under a
``$REPRO_FAULT_PLAN`` (see :mod:`repro.core.faults`) and the runner's
resilience layer must absorb the injected failures — every record
equality assertion above still applies (a retried or backend-degraded
chunk must produce bit-identical records), any quarantined
``FailedCell`` fails the bench outright, and the ``resilience`` block
in the JSON reports the retry/fallback/resume counters accumulated
across all legs. ``--require-retries N`` exits nonzero unless at least
N retries were actually exercised — guarding against a silently
inert fault plan.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import platform
import time
from typing import Dict, List, Optional

from benchmarks.common import emit, header

SCHEMA_VERSION = 5

FULL_SET = ("kmn", "bicg", "mvt", "kmeans",            # LWS
            "syrk", "gesummv", "syr2k", "ii",          # SWS
            "backprop", "conv2d", "gaussian", "nw")    # CI
QUICK_SET = ("kmn", "bicg", "syrk", "gesummv", "conv2d", "nw")
POLICIES = ("gto", "ccws", "best-swl", "statpcal", "ciao-p", "ciao-t",
            "ciao-c")
MS_QUICK_SET = ("bicg", "syrk", "nw")
MS_QUICK_POLICIES = ("gto", "ccws", "ciao-p", "ciao-c")


def _grid(quick: bool, scale: float):
    from repro.core.runner import ExperimentGrid
    return ExperimentGrid(name="fig8", policies=POLICIES, scale=scale,
                          workloads=QUICK_SET if quick else FULL_SET)


def _ms_grid(quick: bool, scale: float):
    from repro.core.gpu import GPUConfig
    from repro.core.runner import ExperimentGrid
    return ExperimentGrid(
        name="fig8-2sm",
        policies=MS_QUICK_POLICIES if quick else POLICIES,
        workloads=MS_QUICK_SET if quick else QUICK_SET,
        scale=scale, gpu=GPUConfig(num_sms=2))


SWEEP_WORKLOADS = ("kmn", "syrk", "nw", "bicg")
SWEEP_POLICY = "ciao-c"
SWEEP_MAX_CYCLES = 20_000


def _sweep_grid(quick: bool, scale: float):
    """Cutoff × throttle-epoch hyperparameter grid: one shape class,
    every variant differing only in per-row knob fields. Each cell is
    horizon-bounded (``max_cycles``) like an auto-tuner evaluation, so
    the sweep measures the grouping/build overhead the per-row config
    planes remove, not raw stepper throughput (fig8 covers that)."""
    from repro.core.interference import DetectorConfig
    from repro.core.runner import ExperimentGrid
    from repro.core.simulator import SimConfig
    n_cuts = 8 if quick else 32
    epochs = (50, 200, 800, 3200) if quick \
        else (25, 50, 100, 200, 400, 800, 1600, 3200)
    variants = {}
    for i in range(n_cuts):
        cut = round(0.2 + 0.75 * i / (n_cuts - 1), 3)
        for e in epochs:
            variants[f"c{cut}-e{e}"] = SimConfig(
                max_cycles=SWEEP_MAX_CYCLES,
                detector=DetectorConfig(
                    low_cutoff=cut,
                    high_cutoff=min(cut + 0.2, 0.97),
                    low_epoch=e, high_epoch=e * 20))
    return ExperimentGrid(name="sweep", workloads=SWEEP_WORKLOADS,
                          policies=(SWEEP_POLICY,), variants=variants,
                          scale=scale)


# resilience counters accumulated across every timed/warm run_grid call
# (reported in the JSON's "resilience" block; the chaos-smoke CI leg
# asserts retries > 0 via --require-retries)
_RESILIENCE = {"retries": 0.0, "fallback_cells": 0.0,
               "failed_cells": 0.0, "truncated_cells": 0.0,
               "chunks_resumed": 0.0, "shard_errors": 0.0}


def _time_engine(grid, engine: str, jobs: int, backend: str = "") -> Dict:
    from repro.core.runner import FailedCell, last_batched_perf, run_grid
    prev = os.environ.get("REPRO_BATCHED_BACKEND")
    if backend:
        os.environ["REPRO_BATCHED_BACKEND"] = backend
    try:
        t0 = time.perf_counter()
        records = run_grid(grid, jobs=jobs, engine=engine)
        wall = time.perf_counter() - t0
    finally:
        if backend:
            if prev is None:
                os.environ.pop("REPRO_BATCHED_BACKEND", None)
            else:
                os.environ["REPRO_BATCHED_BACKEND"] = prev
    perf = last_batched_perf() if engine in ("batched", "jax") else {}
    for k in _RESILIENCE:
        _RESILIENCE[k] += perf.get(k, 0.0)
    failed = [r for r in records if isinstance(r, FailedCell)]
    if failed:
        f = failed[0]
        raise RuntimeError(
            f"{len(failed)} cell(s) quarantined under engine {engine!r} "
            f"(first: {f.workload}/{f.policy}/{f.variant}: "
            f"{f.error_type}: {f.error}) — the bench requires every "
            "cell to complete, fault plan or not")
    return {"wall_s": wall, "records": records, "perf": perf}


def _measure(grid, runs, repeats: int, label: str,
             warm_walls: Optional[Dict[str, float]] = None) -> Dict:
    """Interleaved best-of-N over the given (name, engine, backend,
    jobs) runs; asserts every engine's records equal before reporting
    (this is also the determinism check for the parallel legs — any
    worker-count-dependent divergence trips it). ``warm_walls`` maps
    run names to an untimed warm run's wall (one-time trace/compile
    included); ``compile_s`` is that minus the steady best, clamped
    at 0."""
    walls: Dict[str, List[float]] = {name: [] for name, _, _, _ in runs}
    breakdown: Dict[str, Dict] = {}
    ref_records = None
    for _ in range(repeats):
        for name, engine, backend, jobs in runs:
            r = _time_engine(grid, engine, jobs, backend)
            if not walls[name] or r["wall_s"] < min(walls[name]):
                if r["perf"]:
                    breakdown[name] = r["perf"]
            walls[name].append(r["wall_s"])
            if ref_records is None:
                ref_records = r["records"]
            elif r["records"] != ref_records:
                raise RuntimeError(
                    f"{label}: engine {name!r} records diverge from the "
                    "pool path — bit-exactness broken, timings are "
                    "meaningless")
    out: Dict = {"results": {}, "breakdown": breakdown}
    n_cells = len(ref_records)
    for name, ws in walls.items():
        best = min(ws)
        out["results"][name] = {
            "wall_s": best, "cells_per_s": n_cells / best,
            "all_walls_s": ws,
        }
        if warm_walls and name in warm_walls:
            warm = warm_walls[name]
            out["results"][name]["warm_run_wall_s"] = warm
            out["results"][name]["compile_s"] = max(warm - best, 0.0)
        emit(f"batched/{label}/{name}", 0.0,
             f"{n_cells / best:.2f}cells/s;wall={best:.2f}s")
    return out


def _measure_sweep(grid, repeats: int, jobs: int) -> Dict:
    """Interleaved A/B of the batched C path over the sweep grid:
    ``shape`` (relaxed grouping + memoized token planes) vs ``legacy``
    (per-``SimConfig`` grouping, planes re-encoded per group — the
    pre-PR-8 path, restored via env knobs). Asserts record equality
    between the legs before reporting."""
    legs = {
        "shape": {},
        "legacy": {"REPRO_BATCH_GROUPING": "exact",
                   "REPRO_NO_TOKEN_MEMO": "1"},
    }
    walls: Dict[str, List[float]] = {name: [] for name in legs}
    groups: Dict[str, float] = {}
    ref_records = None
    for _ in range(repeats):
        for name, env in legs.items():
            prev = {k: os.environ.get(k) for k in env}
            os.environ.update(env)
            try:
                r = _time_engine(grid, "batched", jobs)
            finally:
                for k, v in prev.items():
                    if v is None:
                        os.environ.pop(k, None)
                    else:
                        os.environ[k] = v
            walls[name].append(r["wall_s"])
            groups[name] = r["perf"].get("groups", 0.0)
            if ref_records is None:
                ref_records = r["records"]
            elif r["records"] != ref_records:
                raise RuntimeError(
                    f"sweep: grouping leg {name!r} records diverge — "
                    "per-row config planes broke bit-exactness")
    n_cells = len(ref_records)
    out: Dict = {"results": {}}
    for name, ws in walls.items():
        best = min(ws)
        out["results"][name] = {
            "wall_s": best, "cells_per_s": n_cells / best,
            "all_walls_s": ws, "groups": groups[name],
        }
        emit(f"batched/sweep/{name}", 0.0,
             f"{n_cells / best:.2f}cells/s;wall={best:.2f}s;"
             f"groups={int(groups[name])}")
    out["ratio"] = out["results"]["legacy"]["wall_s"] / \
        out["results"]["shape"]["wall_s"]
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced grid/scale for the CI perf smoke")
    ap.add_argument("--repeats", type=int, default=0,
                    help="interleaved A/B repeats (default 2, quick 1)")
    ap.add_argument("--scale", type=float, default=0.0,
                    help="trace scale (default 0.5, quick 0.2)")
    ap.add_argument("--jobs", type=int, default=2,
                    help="spawn-pool workers for the baseline")
    ap.add_argument("--out", default="BENCH_PR8.json")
    ap.add_argument("--floor-ratio", type=float, default=0.0,
                    help="fail if fig8 batched/pool ratio is below")
    ap.add_argument("--floor-multism", type=float, default=0.0,
                    help="fail if the multi-SM batched/pool ratio is below")
    ap.add_argument("--floor-jax", type=float, default=0.0,
                    help="fail if the steady-state jax/pool ratio is "
                         "below (sanity bound; see module docstring)")
    ap.add_argument("--floor-parallel", type=float, default=0.0,
                    help="fail if the 2-worker batched speedup over "
                         "1 worker is below (skipped on 1-core hosts)")
    ap.add_argument("--floor-sweep", type=float, default=0.0,
                    help="fail if the sweep shape-grouping/legacy-"
                         "grouping throughput ratio is below")
    ap.add_argument("--skip-parallel", action="store_true",
                    help="skip the jobs scaling curve")
    ap.add_argument("--skip-sweep", action="store_true",
                    help="skip the hyperparameter-sweep grouping A/B")
    ap.add_argument("--skip-numpy", action="store_true",
                    help="skip the pure-numpy stepper measurement")
    ap.add_argument("--skip-jax", action="store_true",
                    help="skip the jitted XLA stepper measurement")
    ap.add_argument("--skip-multism", action="store_true",
                    help="skip the 2-SM shared-L2 grid measurement")
    ap.add_argument("--require-retries", type=int, default=0,
                    help="fail unless at least N chunk retries were "
                         "exercised (the chaos-smoke guard that the "
                         "injected fault plan actually fired)")
    args = ap.parse_args()
    repeats = args.repeats or (1 if args.quick else 2)
    scale = args.scale or (0.2 if args.quick else 0.5)

    from repro.core import _cstep
    from repro.core.runner import _cached_workload, expand_grid, \
        workload_seed

    header()
    grid = _grid(args.quick, scale)
    cells = expand_grid(grid)
    n_cells = len(cells)

    # untimed warm-up: generate/cache every workload and compile the C
    # stepper now, so neither one-time cost lands inside either timed
    # window (a cold cache would otherwise bias the first engine timed)
    batch_size = 0
    for cell in cells:
        wl = _cached_workload(cell.workload,
                              workload_seed(cell.seed, cell.workload),
                              cell.scale)
        if cell.policy in ("best-swl", "statpcal") and \
                not getattr(wl, "n_wrp", 0):
            batch_size += len(cell.best_swl_limits)
        else:
            batch_size += 1     # n_wrp pins the sweep to one limit
    _cstep.available()

    from repro.core import jax_backend
    jax_on = not args.skip_jax and jax_backend.available()
    warm_walls: Dict[str, float] = {}
    if jax_on:
        # untimed warm run: trace + XLA compile land here, cached for
        # the steady-state passes (jit keyed on the static shape)
        t0 = time.perf_counter()
        _time_engine(grid, "jax", args.jobs)
        warm_walls["batched_jax"] = time.perf_counter() - t0
        emit("batched/fig8/jax_warm", 0.0,
             f"wall={warm_walls['batched_jax']:.2f}s")

    # "batched" stays the serial (jobs=1) leg for continuity with the
    # PR 4-6 trajectory; the jobs curve adds thread-parallel legs at 2
    # and (when wider) os.cpu_count() workers
    cpus = os.cpu_count() or 1
    curve_jobs = [] if args.skip_parallel else \
        sorted({2, cpus} - {1})
    runs = [("batched", "batched", "auto", 1),
            ("pool", "process", "", args.jobs)]
    for j in curve_jobs:
        runs.append((f"batched_j{j}", "batched", "auto", j))
    if not args.skip_numpy:
        runs.append(("batched_numpy", "batched", "numpy", 1))
    if jax_on:
        runs.append(("batched_jax", "jax", "", 1))
    fig8 = _measure(grid, runs, repeats, "fig8", warm_walls)

    sweep: Optional[Dict] = None
    sweep_grid = None
    if not args.skip_sweep:
        sweep_grid = _sweep_grid(args.quick, scale)
        sweep_cells = expand_grid(sweep_grid)
        for cell in sweep_cells:
            _cached_workload(cell.workload,
                             workload_seed(cell.seed, cell.workload),
                             cell.scale)
        sweep = _measure_sweep(sweep_grid, repeats, 1)
        sweep["cells"] = len(sweep_cells)

    ms: Optional[Dict] = None
    ms_grid = None
    if not args.skip_multism:
        ms_grid = _ms_grid(args.quick, scale)
        for cell in expand_grid(ms_grid):
            _cached_workload(cell.workload,
                             workload_seed(cell.seed, cell.workload),
                             cell.scale)
        ms = _measure(ms_grid,
                      [("batched", "batched", "auto", 1),
                       ("pool", "process", "", args.jobs)],
                      repeats, "2sm")

    doc: Dict = {
        "schema": SCHEMA_VERSION,
        "unix_time": int(time.time()),
        "machine": {"platform": platform.platform(),
                    "python": platform.python_version(),
                    "cpus": os.cpu_count()},
        "config": {"quick": args.quick, "repeats": repeats,
                   "scale": scale, "jobs": args.jobs,
                   "grid": "fig8", "workloads": list(grid.workloads),
                   "policies": list(POLICIES)},
        "grid_cells": n_cells,
        # lockstep batch width after Best-SWL/statPCAL limit-sweep
        # flattening (this single-SM, single-config grid fits one chunk)
        "batch_size": batch_size,
        "c_stepper": {"available": _cstep.available(),
                      "detail": _cstep.unavailable_reason()},
        "jax_backend": {"available": jax_backend.available(),
                        "measured": jax_on,
                        "detail": jax_backend.unavailable_reason()},
        "results": fig8["results"],
        "breakdown": fig8["breakdown"],
        "resilience": dict(
            _RESILIENCE,
            fault_plan=os.environ.get("REPRO_FAULT_PLAN", ""),
            run_ledger=os.environ.get("REPRO_RUN_LEDGER", "")),
    }
    if sweep is not None:
        from repro.core.batched import config_shape_key
        shape_classes = len({
            config_shape_key(cfg, None)
            for cfg in sweep_grid.variants.values()})
        doc["sweep"] = {
            "grid": "sweep", "cells": sweep["cells"],
            "workloads": list(sweep_grid.workloads),
            "policy": SWEEP_POLICY,
            "configs": len(sweep_grid.variants),
            "shape_classes": shape_classes,
            "max_cycles": SWEEP_MAX_CYCLES,
            "results": sweep["results"],
            "ratio_shape_vs_legacy": sweep["ratio"],
            "note": "shape = relaxed grouping (per-row config planes + "
                    "memoized token planes); legacy = per-SimConfig "
                    "grouping re-encoding planes per group "
                    "(REPRO_BATCH_GROUPING=exact + "
                    "REPRO_NO_TOKEN_MEMO=1). Records asserted equal.",
        }
    if ms is not None:
        doc["multi_sm"] = {
            "grid": "fig8-2sm", "num_sms": 2,
            "workloads": list(ms_grid.workloads),
            "policies": list(ms_grid.policies),
            "results": ms["results"], "breakdown": ms["breakdown"],
        }

    pool_wall = doc["results"]["pool"]["wall_s"]
    serial_wall = doc["results"]["batched"]["wall_s"]
    ratio = pool_wall / serial_wall
    np_r = doc["results"].get("batched_numpy")
    jax_r = doc["results"].get("batched_jax")
    jax_ratio = (pool_wall / jax_r["wall_s"]) if jax_r else None
    ms_ratio = None
    if ms is not None:
        ms_ratio = ms["results"]["pool"]["wall_s"] / \
            ms["results"]["batched"]["wall_s"]
    jobs_curve = {1: serial_wall}
    for j in curve_jobs:
        jobs_curve[j] = doc["results"][f"batched_j{j}"]["wall_s"]
    speedup_at_2 = (serial_wall / jobs_curve[2]) if 2 in jobs_curve \
        else None
    doc["headline"] = {
        "ratio_vs_pool": ratio,
        "parallel": {
            "cpus": cpus,
            # jobs -> best C-path batched wall; threads over the
            # GIL-releasing ctypes stepper, records equal to serial
            "jobs_curve_wall_s": {str(j): w
                                  for j, w in sorted(jobs_curve.items())},
            "speedup_at_2": speedup_at_2,
            "note": "on a 1-core host the curve is flat by "
                    "construction; the floor only applies when "
                    "cpus >= 2",
        },
        "numpy_ratio_vs_pool": (pool_wall / np_r["wall_s"])
                               if np_r else None,
        "jax_ratio_vs_pool": jax_ratio,
        "jax_compile_s": jax_r.get("compile_s") if jax_r else None,
        "multi_sm_ratio_vs_pool": ms_ratio,
        "sweep_ratio_vs_legacy_grouping": (sweep["ratio"]
                                           if sweep else None),
        "note": "ratio = best-of-N interleaved pool/batched wall time on "
                "the same grid, records asserted equal; absolute "
                "cells/sec drifts with the container. The jax leg is "
                "steady-state (compile in the untimed warm run, "
                "reported as compile_s); on XLA:CPU it is dispatch-"
                "overhead bound and nearly batch-width independent — "
                "see the module docstring.",
    }
    emit("batched/ratio", 0.0, f"{ratio:.2f}x")
    if speedup_at_2 is not None:
        emit("batched/parallel_j2", 0.0,
             f"{speedup_at_2:.2f}x;cpus={cpus}")
    if jax_ratio is not None:
        emit("batched/ratio_jax", 0.0, f"{jax_ratio:.2f}x")
    if ms_ratio is not None:
        emit("batched/ratio_2sm", 0.0, f"{ms_ratio:.2f}x")
    if sweep is not None:
        emit("batched/ratio_sweep", 0.0,
             f"{sweep['ratio']:.2f}x;groups="
             f"{int(sweep['results']['shape']['groups'])}vs"
             f"{int(sweep['results']['legacy']['groups'])}")

    out = pathlib.Path(args.out)
    out.write_text(json.dumps(doc, indent=1, sort_keys=True))
    emit("batched/json", 0.0, str(out))

    if _RESILIENCE["retries"] or os.environ.get("REPRO_FAULT_PLAN"):
        emit("batched/resilience", 0.0,
             f"retries={int(_RESILIENCE['retries'])};"
             f"fallback={int(_RESILIENCE['fallback_cells'])};"
             f"resumed={int(_RESILIENCE['chunks_resumed'])}")

    fail = False
    if args.require_retries and \
            _RESILIENCE["retries"] < args.require_retries:
        print(f"# FAIL: only {int(_RESILIENCE['retries'])} chunk "
              f"retries exercised, --require-retries "
              f"{args.require_retries} — the fault plan did not fire")
        fail = True
    elif args.require_retries:
        emit("batched/require_retries", 0.0,
             f"ok:{int(_RESILIENCE['retries'])}>="
             f"{args.require_retries}")
    if args.floor_ratio and ratio < args.floor_ratio:
        print(f"# FAIL: batched/pool ratio {ratio:.2f}x below floor "
              f"{args.floor_ratio:.2f}x")
        fail = True
    elif args.floor_ratio:
        emit("batched/floor", 0.0,
             f"ok:{ratio:.2f}x>={args.floor_ratio:.2f}x")
    if args.floor_multism and ms_ratio is not None \
            and ms_ratio < args.floor_multism:
        print(f"# FAIL: multi-SM batched/pool ratio {ms_ratio:.2f}x "
              f"below floor {args.floor_multism:.2f}x")
        fail = True
    if args.floor_jax and jax_ratio is not None \
            and jax_ratio < args.floor_jax:
        print(f"# FAIL: jax/pool steady-state ratio {jax_ratio:.2f}x "
              f"below floor {args.floor_jax:.2f}x")
        fail = True
    elif args.floor_jax and jax_ratio is not None:
        emit("batched/floor_jax", 0.0,
             f"ok:{jax_ratio:.2f}x>={args.floor_jax:.2f}x")
    if args.floor_sweep and sweep is not None:
        if sweep["ratio"] < args.floor_sweep:
            print(f"# FAIL: sweep shape/legacy grouping ratio "
                  f"{sweep['ratio']:.2f}x below floor "
                  f"{args.floor_sweep:.2f}x")
            fail = True
        else:
            emit("batched/floor_sweep", 0.0,
                 f"ok:{sweep['ratio']:.2f}x>={args.floor_sweep:.2f}x")
    if args.floor_parallel and speedup_at_2 is not None:
        if cpus < 2:
            # a second worker thread has no second core to land on:
            # the guard would only measure scheduler noise here
            print(f"# floor-parallel skipped: host has {cpus} cpu(s), "
                  "nothing to scale onto")
        elif speedup_at_2 < args.floor_parallel:
            print(f"# FAIL: 2-worker batched speedup "
                  f"{speedup_at_2:.2f}x below floor "
                  f"{args.floor_parallel:.2f}x")
            fail = True
        else:
            emit("batched/floor_parallel", 0.0,
                 f"ok:{speedup_at_2:.2f}x>={args.floor_parallel:.2f}x")
    return 1 if fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
