"""PR-4 grid-throughput harness: batched lockstep engine vs the PR-2
spawn-pool path, written to ``BENCH_PR4.json`` at the repo root.

Measures end-to-end ``run_grid`` wall time on the single-SM fig8 grid
(the paper's Fig. 8 policy × workload sweep) three ways, interleaved
best-of-N in one process (the container's absolute speed drifts ~2x
between sessions, so only same-run ratios are meaningful):

* ``pool``          — ``engine="process"`` at ``--jobs`` workers (the
                      PR-2 spawn-pool fan-out; default 2, the dev box's
                      core count),
* ``batched``       — ``engine="batched"`` with the auto backend (the C
                      stepper when a compiler is available),
* ``batched_numpy`` — the same engine forced onto the pure-numpy
                      lockstep stepper (the portable fallback).

Every engine's records are asserted **equal** before any time is
reported — the speedup is meaningless unless the grids agree cell for
cell. The headline ratio is pool wall time / batched wall time, i.e.
grid-sweep throughput in cells/sec.

Usage::

    python -m benchmarks.bench_batched [--quick] [--repeats N]
                                       [--scale S] [--jobs N]
                                       [--out BENCH_PR4.json]
                                       [--floor-ratio R]

``--floor-ratio R`` exits nonzero if the batched/pool throughput ratio
falls below R — the CI guard against regressing the batched engine. A
ratio, not an absolute rate, so noisy runners do not flap the job.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import platform
import time
from typing import Dict, List

from benchmarks.common import emit, header

SCHEMA_VERSION = 1

FULL_SET = ("kmn", "bicg", "mvt", "kmeans",            # LWS
            "syrk", "gesummv", "syr2k", "ii",          # SWS
            "backprop", "conv2d", "gaussian", "nw")    # CI
QUICK_SET = ("kmn", "bicg", "syrk", "gesummv", "conv2d", "nw")
POLICIES = ("gto", "ccws", "best-swl", "statpcal", "ciao-p", "ciao-t",
            "ciao-c")


def _grid(quick: bool, scale: float):
    from repro.core.runner import ExperimentGrid
    return ExperimentGrid(name="fig8", policies=POLICIES, scale=scale,
                          workloads=QUICK_SET if quick else FULL_SET)


def _time_engine(grid, engine: str, jobs: int, backend: str = "") -> Dict:
    from repro.core.runner import run_grid
    prev = os.environ.get("REPRO_BATCHED_BACKEND")
    if backend:
        os.environ["REPRO_BATCHED_BACKEND"] = backend
    try:
        t0 = time.perf_counter()
        records = run_grid(grid, processes=jobs, engine=engine)
        wall = time.perf_counter() - t0
    finally:
        if backend:
            if prev is None:
                os.environ.pop("REPRO_BATCHED_BACKEND", None)
            else:
                os.environ["REPRO_BATCHED_BACKEND"] = prev
    return {"wall_s": wall, "records": records}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced grid/scale for the CI perf smoke")
    ap.add_argument("--repeats", type=int, default=0,
                    help="interleaved A/B repeats (default 2, quick 1)")
    ap.add_argument("--scale", type=float, default=0.0,
                    help="trace scale (default 0.5, quick 0.2)")
    ap.add_argument("--jobs", type=int, default=2,
                    help="spawn-pool workers for the baseline")
    ap.add_argument("--out", default="BENCH_PR4.json")
    ap.add_argument("--floor-ratio", type=float, default=0.0,
                    help="fail if batched/pool throughput ratio is below")
    ap.add_argument("--skip-numpy", action="store_true",
                    help="skip the pure-numpy stepper measurement")
    args = ap.parse_args()
    repeats = args.repeats or (1 if args.quick else 2)
    scale = args.scale or (0.2 if args.quick else 0.5)

    from repro.core import _cstep
    from repro.core.runner import _cached_workload, expand_grid, \
        workload_seed

    header()
    grid = _grid(args.quick, scale)
    cells = expand_grid(grid)
    n_cells = len(cells)

    # untimed warm-up: generate/cache every workload and compile the C
    # stepper now, so neither one-time cost lands inside either timed
    # window (a cold cache would otherwise bias the first engine timed)
    batch_size = 0
    for cell in cells:
        wl = _cached_workload(cell.workload,
                              workload_seed(cell.seed, cell.workload),
                              cell.scale)
        if cell.policy in ("best-swl", "statpcal") and \
                not getattr(wl, "n_wrp", 0):
            batch_size += len(cell.best_swl_limits)
        else:
            batch_size += 1     # n_wrp pins the sweep to one limit
    _cstep.available()

    walls: Dict[str, List[float]] = {"pool": [], "batched": [],
                                     "batched_numpy": []}
    ref_records = None
    for _ in range(repeats):
        runs = [("batched", "batched", args.jobs, "auto"),
                ("pool", "process", args.jobs, "")]
        if not args.skip_numpy:
            runs.append(("batched_numpy", "batched", args.jobs, "numpy"))
        for name, engine, jobs, backend in runs:
            r = _time_engine(grid, engine, jobs, backend)
            walls[name].append(r["wall_s"])
            if ref_records is None:
                ref_records = r["records"]
            elif r["records"] != ref_records:
                raise RuntimeError(
                    f"engine {name!r} records diverge from the pool path "
                    "— bit-exactness broken, timings are meaningless")

    doc: Dict = {
        "schema": SCHEMA_VERSION,
        "unix_time": int(time.time()),
        "machine": {"platform": platform.platform(),
                    "python": platform.python_version(),
                    "cpus": os.cpu_count()},
        "config": {"quick": args.quick, "repeats": repeats,
                   "scale": scale, "jobs": args.jobs,
                   "grid": "fig8", "workloads": list(grid.workloads),
                   "policies": list(POLICIES)},
        "grid_cells": n_cells,
        # lockstep batch width after Best-SWL/statPCAL limit-sweep
        # flattening (this single-SM, single-config grid fits one chunk)
        "batch_size": batch_size,
        "c_stepper": {"available": _cstep.available(),
                      "detail": _cstep.unavailable_reason()},
        "results": {},
    }
    for name, ws in walls.items():
        if not ws:
            continue
        best = min(ws)
        doc["results"][name] = {
            "wall_s": best, "cells_per_s": n_cells / best,
            "all_walls_s": ws,
        }
        emit(f"batched/{name}", 0.0,
             f"{n_cells / best:.2f}cells/s;wall={best:.2f}s")

    ratio = doc["results"]["pool"]["wall_s"] / \
        doc["results"]["batched"]["wall_s"]
    np_r = doc["results"].get("batched_numpy")
    doc["headline"] = {
        "ratio_vs_pool": ratio,
        "numpy_ratio_vs_pool": (doc["results"]["pool"]["wall_s"]
                                / np_r["wall_s"]) if np_r else None,
        "note": "ratio = best-of-N interleaved pool/batched wall time on "
                "the same grid, records asserted equal; absolute "
                "cells/sec drifts with the container",
    }
    emit("batched/ratio", 0.0, f"{ratio:.2f}x")

    out = pathlib.Path(args.out)
    out.write_text(json.dumps(doc, indent=1, sort_keys=True))
    emit("batched/json", 0.0, str(out))

    if args.floor_ratio and ratio < args.floor_ratio:
        print(f"# FAIL: batched/pool ratio {ratio:.2f}x below floor "
              f"{args.floor_ratio:.2f}x")
        return 1
    if args.floor_ratio:
        emit("batched/floor", 0.0,
             f"ok:{ratio:.2f}x>={args.floor_ratio:.2f}x")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
