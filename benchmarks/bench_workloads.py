"""Synthetic vs Pallas-kernel-derived workloads: do the paper's policy
rankings survive on real kernel access streams?

Sweeps every policy family over one synthetic representative per class
(LWS ``bicg``, SWS ``syrk``, CI ``conv2d``), the kernel-derived traces
(``flashattn`` / ``decodeattn`` / ``gather`` — see
:mod:`repro.workloads.derived`), *and* their arrival-jittered twins
(``*-jit``: same walks with per-warp start skew, probing whether the
PR-3 ranking gap comes from lockstep warp arrival capping MLP), through
the unified runner (one grid, batched/pool fan-out, JSON persistence).
Emits per-cell normalized IPC (vs GTO), the per-workload policy
ranking, per-group geomeans, and the Kendall-tau agreement of the
derived and jittered rankings against the synthetic one — the
figure-style answer to "would CIAO's win have shown up if we had only
evaluated on synthetic streams?".
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

from benchmarks.common import emit
from repro.core.runner import (ExperimentGrid, geomean, index_records,
                               run_grid)
from repro.workloads import workload_names

POLICIES = ("gto", "ccws", "best-swl", "statpcal", "ciao-p", "ciao-t",
            "ciao-c")
SYNTHETIC = ("bicg", "syrk", "conv2d")
# bound the Best-SWL/statPCAL offline limit sweep (derived workloads have
# no Table II N_wrp hint, so each such cell would otherwise run 7 limits)
LIMITS = (2, 6, 16, 48)


def _ranking(rel: Dict[str, float]) -> List[str]:
    return sorted(rel, key=lambda p: -rel[p])


def kendall_tau(a: Sequence[str], b: Sequence[str]) -> float:
    """Rank-agreement in [-1, 1] between two orderings of one item set."""
    pos_a = {p: i for i, p in enumerate(a)}
    pos_b = {p: i for i, p in enumerate(b)}
    items = list(a)
    n = len(items)
    concordant = discordant = 0
    for i in range(n):
        for j in range(i + 1, n):
            x = pos_a[items[i]] - pos_a[items[j]]
            y = pos_b[items[i]] - pos_b[items[j]]
            if x * y > 0:
                concordant += 1
            elif x * y < 0:
                discordant += 1
    pairs = n * (n - 1) // 2
    return (concordant - discordant) / max(pairs, 1)


def main(scale: float = 0.5, processes: Optional[int] = None,
         json_path: Optional[str] = None, engine: str = "auto"):
    derived = tuple(sorted(workload_names("derived")))
    # arrival-jittered twins (repro.workloads.derived, ROADMAP ranking-
    # gap study): same walks, staggered warp arrival
    jittered = tuple(sorted(workload_names("derived-jit")))
    grid = ExperimentGrid(name="workloads",
                          workloads=SYNTHETIC + derived + jittered,
                          policies=POLICIES, scale=scale,
                          best_swl_limits=LIMITS)
    t0 = time.perf_counter()
    records = run_grid(grid, processes=processes, json_path=json_path,
                       engine=engine)
    us_per_cell = (time.perf_counter() - t0) * 1e6 / max(len(records), 1)

    by = index_records(records)
    groups = ("synthetic", "derived", "derived_jit")
    group_rel = {g: {p: [] for p in POLICIES} for g in groups}
    for name in grid.workloads:
        group = "derived_jit" if name in jittered else \
            "derived" if name in derived else "synthetic"
        gto = by[name, "gto", "base"].ipc
        rel = {}
        for p in POLICIES:
            rel[p] = by[name, p, "base"].ipc / max(gto, 1e-12)
            group_rel[group][p].append(rel[p])
            emit(f"workloads/{name}/{p}", us_per_cell, f"{rel[p]:.3f}")
        emit(f"workloads/{name}/ranking", 0.0, ">".join(_ranking(rel)))

    group_geo = {g: {p: geomean(v[p]) for p in POLICIES}
                 for g, v in group_rel.items()}
    for g in groups:
        for p in POLICIES:
            emit(f"workloads/geomean_{g}/{p}", 0.0,
                 f"{group_geo[g][p]:.3f}")
        emit(f"workloads/ranking_{g}", 0.0,
             ">".join(_ranking(group_geo[g])))
    tau = kendall_tau(_ranking(group_geo["synthetic"]),
                      _ranking(group_geo["derived"]))
    tau_jit = kendall_tau(_ranking(group_geo["synthetic"]),
                          _ranking(group_geo["derived_jit"]))
    emit("workloads/rank_agreement_tau", 0.0, f"{tau:.3f}")
    emit("workloads/rank_agreement_tau_jit", 0.0, f"{tau_jit:.3f}")
    return {"geomeans": group_geo, "tau": tau, "tau_jit": tau_jit}


if __name__ == "__main__":
    main()
