"""Fig. 9 reproduction: time-phase behaviour on the two-phase ATAX-like
workload and the compute-intensive Backprop-like one (IPC + active warps
over time per scheduler)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import make_workload
from repro.core.simulator import SMSimulator


def main():
    for wl_name in ("atax", "backprop"):
        wl = make_workload(wl_name, scale=0.5)
        for pol in ("best-swl", "ccws", "ciao-t", "ciao-c"):
            kw = {"limit": wl.n_wrp} if pol == "best-swl" and wl.n_wrp else {}
            sim = SMSimulator(wl, pol, policy_kwargs=kw or None)
            r = sim.run(timeline_every=10_000)
            # phase split: first half vs second half of the timeline
            half = max(len(r.timeline) // 2, 1)
            ipc1 = sum(t[1] for t in r.timeline[:half]) / max(half, 1)
            ipc2 = sum(t[1] for t in r.timeline[half:]) / max(
                len(r.timeline) - half, 1)
            act = sum(t[2] for t in r.timeline) / max(len(r.timeline), 1)
            emit(f"fig9/{wl_name}/{pol}",
                 0.0, f"ipc_p1={ipc1:.3f};ipc_p2={ipc2:.3f};"
                      f"act={act:.1f};total_ipc={r.ipc:.3f}")


if __name__ == "__main__":
    main()
