"""Benchmark orchestrator: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (stdout) per the harness contract.

The simulator sweeps (fig4/fig8/fig11) run through the unified
``repro.core.runner`` subsystem: each is a declarative policy × workload ×
config grid, fanned out over a multiprocessing pool (``--jobs``) and
persisted as JSON under ``--out``. ``--quick`` runs a reduced grid as a CI
smoke test.

  python -m benchmarks.run [--only fig8,serving,...] [--scale 0.5]
                           [--jobs N] [--out DIR] [--quick]
                           [--engine auto|batched|process|jax]

``--engine`` picks the runner execution engine for the grid sweeps:
``batched`` forces the in-process batched lockstep engine
(``repro.core.batched``), ``process`` the spawn-pool fan-out, ``jax``
the jitted XLA stepper for single-SM chunks (``repro.core.jax_backend``;
other cells fall back to auto), and ``auto`` (default) batches wide
grids — including multi-SM grids, which stack as (SM × cell) rows —
and falls back per cell only for the queued-L2/MSHR-gated config
corners.
"""
from __future__ import annotations

import argparse
import pathlib
import time

from benchmarks.common import emit, header


def _quick(jobs: int, out: pathlib.Path, engine: str = "auto") -> None:
    """Reduced grid (2 workloads × 3 policies, short traces) exercising
    the runner end-to-end: multiprocessing fan-out + JSON round-trip."""
    from repro.core.runner import ExperimentGrid, load_records, run_grid
    grid = ExperimentGrid(name="quick", workloads=("syrk", "kmn"),
                          policies=("gto", "ciao-p", "ciao-c"), scale=0.2)
    path = out / "quick.json"
    records = run_grid(grid, processes=jobs, json_path=str(path),
                       engine=engine)
    if load_records(str(path)) != records:
        raise RuntimeError("JSON round-trip mismatch in --quick smoke")
    for r in records:
        emit(f"quick/{r.workload}/{r.policy}", 0.0, f"{r.ipc:.4f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: fig4,fig8,fig9,fig10,fig11,fig12,"
                         "workloads,serving,kernels,roofline,perf,"
                         "batched")
    ap.add_argument("--scale", type=float, default=0.5,
                    help="trace-length scale for simulator benches")
    ap.add_argument("--jobs", type=int, default=0,
                    help="multiprocessing fan-out for runner grids "
                         "(0 = all cores)")
    ap.add_argument("--out", default="results",
                    help="directory for JSON grid results")
    ap.add_argument("--quick", action="store_true",
                    help="reduced runner smoke grid, then exit")
    ap.add_argument("--engine", default="auto",
                    choices=("auto", "batched", "process", "jax"),
                    help="runner execution engine for grid sweeps")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    out = pathlib.Path(args.out)
    if args.jobs <= 0:
        from repro.core.runner import default_processes
        jobs = default_processes()
    else:
        jobs = args.jobs

    def want(name: str) -> bool:
        return only is None or name in only

    header()
    t0 = time.time()
    if args.quick:
        _quick(jobs, out, engine=args.engine)
        print(f"# total_bench_seconds,{time.time() - t0:.1f},-")
        return
    if want("fig4"):
        from benchmarks import bench_interference
        bench_interference.main(processes=jobs,
                                json_path=str(out / "fig4.json"),
                                engine=args.engine)
    if want("fig8"):
        from benchmarks import bench_schedulers
        bench_schedulers.main(scale=args.scale, processes=jobs,
                              json_path=str(out / "fig8.json"),
                              engine=args.engine)
    if want("fig9"):
        from benchmarks import bench_phases
        bench_phases.main()
    if want("fig10"):
        from benchmarks import bench_workingset
        bench_workingset.main()
    if want("fig11"):
        from benchmarks import bench_sensitivity
        bench_sensitivity.main(processes=jobs,
                               json_path=str(out / "fig11.json"),
                               engine=args.engine)
    if want("fig12"):
        from benchmarks import bench_onchip
        bench_onchip.main()
    if want("workloads"):
        from benchmarks import bench_workloads
        bench_workloads.main(scale=args.scale, processes=jobs,
                             json_path=str(out / "workloads.json"),
                             engine=args.engine)
    if want("serving"):
        from benchmarks import bench_serving
        bench_serving.main()
    if want("kernels"):
        from benchmarks import bench_kernels
        bench_kernels.main()
    if want("roofline"):
        from benchmarks import roofline
        roofline.main()
    if want("perf"):
        import sys
        from benchmarks import bench_perf
        argv, sys.argv = sys.argv, [sys.argv[0]]
        try:
            bench_perf.main()
        finally:
            sys.argv = argv
    if want("batched"):
        import sys
        from benchmarks import bench_batched
        argv, sys.argv = sys.argv, [sys.argv[0]]
        try:
            bench_batched.main()
        finally:
            sys.argv = argv
    print(f"# total_bench_seconds,{time.time() - t0:.1f},-")


if __name__ == "__main__":
    main()
