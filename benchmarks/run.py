"""Benchmark orchestrator: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (stdout) per the harness contract.

  python -m benchmarks.run [--only fig8,serving,...] [--scale 0.5]
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import header


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: fig4,fig8,fig9,fig10,fig11,fig12,"
                         "serving,kernels,roofline")
    ap.add_argument("--scale", type=float, default=0.5,
                    help="trace-length scale for simulator benches")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    def want(name: str) -> bool:
        return only is None or name in only

    header()
    t0 = time.time()
    if want("fig4"):
        from benchmarks import bench_interference
        bench_interference.main()
    if want("fig8"):
        from benchmarks import bench_schedulers
        bench_schedulers.main(scale=args.scale)
    if want("fig9"):
        from benchmarks import bench_phases
        bench_phases.main()
    if want("fig10"):
        from benchmarks import bench_workingset
        bench_workingset.main()
    if want("fig11"):
        from benchmarks import bench_sensitivity
        bench_sensitivity.main()
    if want("fig12"):
        from benchmarks import bench_onchip
        bench_onchip.main()
    if want("serving"):
        from benchmarks import bench_serving
        bench_serving.main()
    if want("kernels"):
        from benchmarks import bench_kernels
        bench_kernels.main()
    if want("roofline"):
        from benchmarks import roofline
        roofline.main()
    print(f"# total_bench_seconds,{time.time() - t0:.1f},-")


if __name__ == "__main__":
    main()
