"""Kernel microbench (interpret mode on CPU — timings are indicative only;
the derived column carries the correctness check vs the oracle)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.kernels.flash_attn.ops import flash_attention
from repro.kernels.flash_attn.ref import attention_ref
from repro.kernels.decode_attn.ops import decode_attention
from repro.kernels.ciao_gather.ops import ciao_gather


def main():
    key = jax.random.PRNGKey(0)
    b, s, h, d = 1, 256, 4, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, d), jnp.float32)
    us = time_call(flash_attention, q, k, v, causal=True, interpret=True)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    qb = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kb = k.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vb = v.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    ref = attention_ref(qb, kb, vb).reshape(b, h, s, d).transpose(0, 2, 1, 3)
    err = float(jnp.max(jnp.abs(out - ref)))
    flops = 4 * b * h * s * s * d
    emit("kernel/flash_attn_256", us, f"err={err:.1e};flops={flops:.2e}")

    qd = jax.random.normal(ks[0], (2, 1, h, d), jnp.float32)
    ck = jax.random.normal(ks[1], (2, 1024, h, d), jnp.float32)
    cv = jax.random.normal(ks[2], (2, 1024, h, d), jnp.float32)
    lens = jnp.array([900, 1024], jnp.int32)
    us = time_call(decode_attention, qd, ck, cv, lens, interpret=True)
    emit("kernel/decode_attn_1k", us, "ok")

    rng = np.random.default_rng(0)
    table = jax.random.normal(key, (512, 128), jnp.float32)
    streams = rng.integers(0, 4, 1024).astype(np.int32)
    idx = rng.integers(0, 512, 1024).astype(np.int32)
    iso = jnp.array([0, 0, 0, 1], jnp.int32)
    us = time_call(ciao_gather, table, jnp.array(idx), jnp.array(streams),
                   iso, interpret=True)
    _, stats = ciao_gather(table, jnp.array(idx), jnp.array(streams), iso,
                           interpret=True)
    hits = int(np.asarray(stats)[:, 0].sum())
    emit("kernel/ciao_gather_1k", us, f"hits={hits}")


if __name__ == "__main__":
    main()
