"""PR-2 perf-tracking harness: instr/s per component + full-run A/B vs the
vendored seed core, written to ``BENCH_PR2.json`` at the repo root
(``BENCH_PR2.quick.json`` under ``--quick``, so the CI smoke never
clobbers a full local measurement).

Measures the live ``repro.core`` simulator against ``benchmarks.seed_core``
(the PR-1 core frozen at commit 9de8cc9) *in one process, interleaved*:
this container's clock-for-clock speed drifts by ~2x over minutes, so
cross-session absolute instr/s are meaningless — the speedup is reported
as the ratio of best-of-N interleaved times, which both sides sample under
the same conditions.

Sections:

* components — isolated primitive throughput (ops/s), new vs seed:
  L1 path (``OnChipMemory.access``, mixed hit/miss), smem path (isolated
  accesses), detector (eviction+probe pairs), scheduler (a CI-class
  full run, ~95% ALU, dominated by the dispatch loop).
* full_runs — end-to-end ``run()`` instr/s across the paper's workload
  classes (LWS ``bicg``, SWS ``syrk``, CI ``conv2d``, each under the
  class-relevant CIAO policy) and a 2-SM ``GPUSimulator`` run on a shared
  L2/DRAM stage.

Usage::

    python -m benchmarks.bench_perf [--quick] [--repeats N] [--scale S]
                                    [--out BENCH_PR2.json]
                                    [--floor-ratio R]

``--floor-ratio R`` exits nonzero if the headline (bicg/ciao-c) speedup
over the seed core falls below R — the CI guard against accidental
re-Pythonization of the hot path. The floor is a *ratio*, not an absolute
rate, so noisy runners do not flap the job.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import platform
import time
from typing import Callable, Dict, List, Tuple

from benchmarks.common import emit, header

# The seed core measured ~70-110K instr/s on bicg/ciao-c scale=1.0 on the
# PR-2 dev container (81,108 at the session-start measurement; the spread
# is machine drift). Recorded here per the issue; the live baseline is
# re-measured on every harness run.
RECORDED_SEED_BASELINE_INSTR_S = 81_108

SCHEMA_VERSION = 1


def _best_seconds(fn: Callable[[], object], repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _ab(new_fn: Callable[[], object], seed_fn: Callable[[], object],
        repeats: int) -> Tuple[float, float]:
    """Interleaved best-of-N wall times (new, seed)."""
    new_best = seed_best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        new_fn()
        new_best = min(new_best, time.perf_counter() - t0)
        t0 = time.perf_counter()
        seed_fn()
        seed_best = min(seed_best, time.perf_counter() - t0)
    return new_best, seed_best


# ------------------------------------------------------------- components
def _bench_l1(repeats: int, n_ops: int = 120_000) -> Dict[str, float]:
    import numpy as np
    from benchmarks.seed_core.interference import (
        DetectorConfig as SeedDC, InterferenceDetector as SeedDet)
    from benchmarks.seed_core.onchip import (
        OnChipConfig as SeedOC, OnChipMemory as SeedMem)
    from repro.core.interference import DetectorConfig, InterferenceDetector
    from repro.core.onchip import OnChipConfig, OnChipMemory

    rng = np.random.default_rng(0)
    addrs = (rng.integers(0, 4000, n_ops) * 128).tolist()
    wids = (rng.integers(0, 48, n_ops)).tolist()

    def run_new():
        mem = OnChipMemory(OnChipConfig(),
                           InterferenceDetector(DetectorConfig()))
        for w, a in zip(wids, addrs):
            mem.access(w, a, count_instruction=False)

    def run_seed():
        mem = SeedMem(SeedOC(), SeedDet(SeedDC()))
        for w, a in zip(wids, addrs):
            mem.access(w, a, count_instruction=False)

    nb, sb = _ab(run_new, run_seed, repeats)
    return {"new_ops_s": n_ops / nb, "seed_ops_s": n_ops / sb,
            "ratio": sb / nb}


def _bench_smem(repeats: int, n_ops: int = 120_000) -> Dict[str, float]:
    import numpy as np
    from benchmarks.seed_core.interference import (
        DetectorConfig as SeedDC, InterferenceDetector as SeedDet)
    from benchmarks.seed_core.onchip import (
        OnChipConfig as SeedOC, OnChipMemory as SeedMem)
    from repro.core.interference import DetectorConfig, InterferenceDetector
    from repro.core.onchip import OnChipConfig, OnChipMemory

    rng = np.random.default_rng(1)
    addrs = (rng.integers(0, 1200, n_ops) * 128).tolist()
    wids = (rng.integers(0, 48, n_ops)).tolist()

    def run_new():
        mem = OnChipMemory(OnChipConfig(),
                           InterferenceDetector(DetectorConfig()))
        for w, a in zip(wids, addrs):
            mem.access(w, a, isolated=True, count_instruction=False)

    def run_seed():
        mem = SeedMem(SeedOC(), SeedDet(SeedDC()))
        for w, a in zip(wids, addrs):
            mem.access(w, a, isolated=True, count_instruction=False)

    nb, sb = _ab(run_new, run_seed, repeats)
    return {"new_ops_s": n_ops / nb, "seed_ops_s": n_ops / sb,
            "ratio": sb / nb}


def _bench_detector(repeats: int, n_ops: int = 120_000) -> Dict[str, float]:
    import numpy as np
    from benchmarks.seed_core.interference import (
        DetectorConfig as SeedDC, InterferenceDetector as SeedDet)
    from repro.core.interference import DetectorConfig, InterferenceDetector

    rng = np.random.default_rng(2)
    lines = rng.integers(0, 3000, n_ops).tolist()
    owners = rng.integers(0, 48, n_ops).tolist()
    evictors = rng.integers(0, 48, n_ops).tolist()

    def drive(det):
        for o, line, e in zip(owners, lines, evictors):
            det.on_eviction(o, line, e)
            det.on_miss(e, line)

    nb, sb = _ab(lambda: drive(InterferenceDetector(DetectorConfig())),
                 lambda: drive(SeedDet(SeedDC())), repeats)
    return {"new_ops_s": 2 * n_ops / nb, "seed_ops_s": 2 * n_ops / sb,
            "ratio": sb / nb}


# -------------------------------------------------------------- full runs
def _full_run(kind: str, workload_name: str, policy: str, scale: float,
              repeats: int, num_sms: int = 1) -> Dict[str, float]:
    from benchmarks.seed_core.simulator import SMSimulator as SeedSM
    from repro.core.gpu import GPUConfig, GPUSimulator
    from repro.core.simulator import SMSimulator
    from repro.core.traces import make_workload

    wl = make_workload(workload_name, seed=123, scale=scale)
    if kind == "gpu":
        gpu = GPUConfig(num_sms=num_sms)
        res = GPUSimulator(wl, policy, gpu=gpu).run()
        instr = res.instructions
        nb = _best_seconds(
            lambda: GPUSimulator(wl, policy, gpu=gpu).run(), repeats)
        # no multi-SM model exists in the seed core; report absolute only
        return {"instructions": instr, "new_instr_s": instr / nb}
    res = SMSimulator(wl, policy).run()
    instr = res.instructions
    nb, sb = _ab(lambda: SMSimulator(wl, policy).run(),
                 lambda: SeedSM(wl, policy).run(), repeats)
    return {"instructions": instr, "new_instr_s": instr / nb,
            "seed_instr_s": instr / sb, "ratio": sb / nb}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced scale/repeats for the CI perf smoke")
    ap.add_argument("--repeats", type=int, default=0,
                    help="interleaved A/B repeats (default 4, quick 2)")
    ap.add_argument("--scale", type=float, default=0.0,
                    help="trace scale for full runs (default 1.0, "
                         "quick 0.25)")
    ap.add_argument("--out", default="",
                    help="output JSON path (default BENCH_PR2.json, or "
                         "BENCH_PR2.quick.json under --quick so a CI "
                         "smoke run cannot clobber a full measurement)")
    ap.add_argument("--floor-ratio", type=float, default=0.0,
                    help="fail if bicg/ciao-c speedup over the seed core "
                         "is below this ratio")
    args = ap.parse_args()
    repeats = args.repeats or (2 if args.quick else 4)
    scale = args.scale or (0.25 if args.quick else 1.0)

    header()
    doc: Dict = {
        "schema": SCHEMA_VERSION,
        "unix_time": int(time.time()),
        "machine": {"platform": platform.platform(),
                    "python": platform.python_version(),
                    "cpus": os.cpu_count()},
        "recorded_seed_baseline_instr_s": RECORDED_SEED_BASELINE_INSTR_S,
        "seed_core": "benchmarks/seed_core (PR-1 @ 9de8cc9)",
        "config": {"repeats": repeats, "scale": scale,
                   "quick": args.quick},
        "components": {},
        "full_runs": {},
    }

    comp_benches: List[Tuple[str, Callable[[], Dict[str, float]]]] = [
        ("l1_path", lambda: _bench_l1(repeats)),
        ("smem_path", lambda: _bench_smem(repeats)),
        ("detector", lambda: _bench_detector(repeats)),
    ]
    for name, fn in comp_benches:
        r = fn()
        doc["components"][name] = r
        emit(f"perf/component/{name}", 0.0,
             f"new={r['new_ops_s']:,.0f}ops/s;ratio={r['ratio']:.2f}x")

    runs = [
        ("sm", "bicg", "ciao-c", 1),      # LWS headline (issue baseline)
        ("sm", "conv2d", "ciao-c", 1),    # CI class: dispatch/scheduler
    ]
    if not args.quick:
        runs += [
            ("sm", "syrk", "ciao-p", 1),  # SWS class: smem redirection
            ("sm", "bicg", "gto", 1),
            ("gpu", "syrk", "ciao-c", 2),  # shared-L2 2-SM chip
        ]
    for kind, wl_name, policy, sms in runs:
        key = f"{wl_name}/{policy}" + (f"/{sms}sm" if kind == "gpu" else "")
        r = _full_run(kind, wl_name, policy, scale, repeats, num_sms=sms)
        doc["full_runs"][key] = r
        extra = (f";seed={r['seed_instr_s']:,.0f};ratio={r['ratio']:.2f}x"
                 if "ratio" in r else "")
        emit(f"perf/run/{key}", 0.0,
             f"new={r['new_instr_s']:,.0f}instr/s{extra}")

    headline = doc["full_runs"].get("bicg/ciao-c", {})
    doc["headline"] = {
        "workload": "bicg", "policy": "ciao-c",
        "new_instr_s": headline.get("new_instr_s"),
        "seed_instr_s": headline.get("seed_instr_s"),
        "ratio": headline.get("ratio"),
        "note": "ratio = best-of-N interleaved seed/new wall time; the "
                "container's absolute speed drifts ~2x between sessions, "
                "so cross-run instr/s comparisons are not meaningful",
    }

    out = pathlib.Path(args.out or ("BENCH_PR2.quick.json" if args.quick
                                    else "BENCH_PR2.json"))
    out.write_text(json.dumps(doc, indent=1, sort_keys=True))
    emit("perf/json", 0.0, str(out))

    if args.floor_ratio:
        ratio = headline.get("ratio", 0.0)
        if ratio < args.floor_ratio:
            print(f"# FAIL: bicg/ciao-c speedup {ratio:.2f}x below floor "
                  f"{args.floor_ratio:.2f}x")
            return 1
        emit("perf/floor", 0.0,
             f"ok:{ratio:.2f}x>={args.floor_ratio:.2f}x")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
