"""Fig. 12 reproduction: L1D/DRAM configuration sweep — bigger/wider L1D
vs CIAO, and 2x DRAM bandwidth variants."""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit
from repro.core import make_workload
from repro.core.onchip import OnChipConfig
from repro.core.simulator import SMSimulator, SimConfig


def main():
    for name in ("syrk", "kmn"):
        wl = make_workload(name, scale=0.5)
        base = SMSimulator(wl, "gto").run().ipc

        variants = {
            # GTO-cap: L1D 48KB / smem 16KB (Fig. 12 "GTO-cap")
            "gto-cap": ("gto", SimConfig(onchip=OnChipConfig(
                l1_bytes=48 * 1024, smem_bytes=16 * 1024))),
            # GTO-8way
            "gto-8way": ("gto", SimConfig(onchip=OnChipConfig(ways=8))),
            "ciao-c": ("ciao-c", SimConfig()),
            # 2x DRAM bandwidth
            "statpcal-2x": ("statpcal", SimConfig(dram_gap=4)),
            "ciao-c-2x": ("ciao-c", SimConfig(dram_gap=4)),
        }
        for label, (pol, cfg) in variants.items():
            r = SMSimulator(wl, pol, cfg).run()
            emit(f"fig12/{name}/{label}", 0.0,
                 f"ipc={r.ipc / base:.3f};hit={r.l1_hit_rate:.3f}")


if __name__ == "__main__":
    main()
