"""Victim Tag Array (paper §II-C, Fig. 3b; Table I: 8 tags/set, 48 sets, FIFO).

Each cache tag carries the WID of the warp that brought the line in. On
eviction we store (victim address, evictor WID) into the VTA *set of the
owner warp* (the warp whose data was evicted). When a warp's memory request
misses L1D but hits its own VTA set, the warp is re-referencing data it
recently lost — a *VTA hit*, the unit of interference evidence:

  * the stored evictor WID identifies the interfering warp,
  * the per-warp VTA-hit counter feeds IRS (Eq. 1).

CIAO uses 8 entries/warp — half of CCWS' 16 (paper §V-F).
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional, Tuple


class VictimTagArray:
    def __init__(self, num_sets: int = 48, tags_per_set: int = 8):
        self.num_sets = num_sets
        self.tags_per_set = tags_per_set
        # FIFO per warp: deque of (line_addr, evictor_wid)
        self.sets: List[Deque[Tuple[int, int]]] = [
            deque(maxlen=tags_per_set) for _ in range(num_sets)]
        self.hits = [0] * num_sets          # per-warp VTA-hit counters
        self.inserts = 0

    def reset_counters(self) -> None:
        self.hits = [0] * self.num_sets

    def insert(self, owner_wid: int, line_addr: int, evictor_wid: int) -> None:
        """Record an eviction of ``owner_wid``'s line caused by ``evictor_wid``."""
        if owner_wid == evictor_wid:
            return  # self-eviction is capacity pressure, not interference
        s = self.sets[owner_wid % self.num_sets]
        s.append((line_addr, evictor_wid))  # deque(maxlen) = FIFO replacement
        self.inserts += 1

    def probe(self, wid: int, line_addr: int) -> Optional[int]:
        """On an L1D miss by ``wid``: VTA hit returns the evictor WID that
        caused the earlier eviction (and pops the entry); miss returns None."""
        s = self.sets[wid % self.num_sets]
        for i, (addr, evictor) in enumerate(s):
            if addr == line_addr:
                del s[i]
                self.hits[wid % self.num_sets] += 1
                return evictor
        return None

    def hit_count(self, wid: int) -> int:
        return self.hits[wid % self.num_sets]
