"""Vendored PR-1 ("seed") simulator core, frozen at commit 9de8cc9.

Benchmark fixture only: ``benchmarks/bench_perf.py`` runs this core and the
live ``repro.core`` side by side in one process, so the reported speedup is
immune to machine-speed drift (this container's clock-for-clock throughput
varies by ~2x over time). Do not import from production code.
"""
