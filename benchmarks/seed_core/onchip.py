"""On-chip memory model: L1D + shared-memory-as-cache (paper §II-A, §IV-B).

GTX480-like SM (Table I): 16KB L1D, 128-byte lines, 4-way LRU, XOR set-index
hashing [26]; 48KB shared memory in the same physical structure (32 banks).
The CIAO additions:

* **SMMT** — Shared Memory Management Table; one entry per CTA (base, size).
  CIAO reads it to find the *unused* region and reserves that region (a new
  SMMT entry) for its direct-mapped victim-isolation cache.

* **Address translation unit** (Fig. 7c) — splits a global address into
  byte-offset F (3b, 8-byte bank rows), bank B (4b, 16 banks/group), bank
  group G (1b), row R (up to 8b), remainder = tag. A 128-byte data block is
  striped across the 16 banks of group ``G``; its 31-bit tag (25b addr + 6b
  WID) lives in the *opposite* group (``1-G``) so tag probe and data access
  proceed in parallel, bank-conflict-free — asserted structurally in tests.

* **MSHR** — entries extended with the translated shared-memory address so
  L2 fill responses can be routed straight into shared memory; L1D->smem
  *migration* moves a present line through the response queue (single-copy
  coherence invariant, §III-B "Performance optimization and coherence").

Latencies are attached by the simulator; this module returns event kinds:
  'l1_hit' | 'l1_miss' | 'smem_hit' | 'smem_miss' | 'smem_migrate' | 'bypass'
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from benchmarks.seed_core.interference import InterferenceDetector

LINE = 128


@dataclasses.dataclass
class OnChipConfig:
    l1_bytes: int = 16 * 1024
    line_bytes: int = LINE
    ways: int = 4
    smem_bytes: int = 48 * 1024
    smem_banks: int = 32
    bank_row_bytes: int = 8          # 64-bit accesses per bank
    xor_hash: bool = True            # set-index hashing [26]
    mshr_entries: int = 32
    # Refinement over the paper (ablatable): a 1-bit "reused" flag per L1D
    # line; only evictions of *reused* lines enter the VTA. Streaming
    # victims (never re-referenced) otherwise flood the 8-entry per-warp
    # FIFO and push out the genuine lost-locality evidence.
    reuse_filter: bool = False

    @property
    def num_sets(self) -> int:
        return self.l1_bytes // (self.line_bytes * self.ways)


class SMMT:
    """Shared Memory Management Table (§II-A, [17])."""

    def __init__(self, total_bytes: int):
        self.total = total_bytes
        self.entries: Dict[str, Tuple[int, int]] = {}  # name -> (base, size)

    def allocate(self, name: str, size: int) -> int:
        base = sum(s for _, s in self.entries.values())
        if base + size > self.total:
            raise ValueError("shared memory exhausted")
        self.entries[name] = (base, size)
        return base

    def unused(self) -> int:
        return self.total - sum(s for _, s in self.entries.values())

    def reserve_unused(self, name: str = "__ciao__") -> Tuple[int, int]:
        size = self.unused()
        base = self.allocate(name, size)
        return base, size

    def release(self, name: str) -> None:
        self.entries.pop(name, None)


@dataclasses.dataclass
class TranslatedAddr:
    """Fig. 7c field split of a block address within the reserved region."""
    byte_off: int     # F: 3 bits
    bank: int         # B: 4 bits
    group: int        # G: 1 bit
    row: int          # R: row index within the region
    tag: int          # remaining bits (+ 6-bit WID stored alongside)
    tag_group: int    # == 1 - group (opposite bank group)
    tag_bank: int
    tag_row: int


class AddressTranslationUnit:
    """Global address -> shared-memory (row, group, bank) + tag placement."""

    def __init__(self, cfg: OnChipConfig, region_blocks: int):
        self.cfg = cfg
        self.region_blocks = max(region_blocks, 1)

    def translate(self, addr: int, wid: int = 0) -> TranslatedAddr:
        block = addr // LINE
        idx = block % self.region_blocks          # direct-mapped block index
        byte_off = addr % self.cfg.bank_row_bytes                 # F (3b)
        bank = (addr // self.cfg.bank_row_bytes) % 16             # B (4b)
        group = idx % 2                                           # G (1b)
        row = idx // 2                                            # R
        tag = block // self.region_blocks                         # remainder
        # tag goes to the opposite bank group; two tags share one bank row,
        # 32 tags per row of one group. Position derived from the data
        # block's (F,B) bits, G flipped (Fig. 7c).
        tag_group = 1 - group
        tag_bank = idx % 16
        tag_row = idx // 32
        return TranslatedAddr(byte_off, bank, group, row, tag,
                              tag_group, tag_bank, tag_row)


class MSHR:
    def __init__(self, entries: int):
        self.capacity = entries
        self.pending: Dict[int, Dict] = {}   # global line addr -> info

    def reserve(self, line_addr: int, smem_addr: Optional[int] = None) -> bool:
        if line_addr in self.pending:
            return True
        if len(self.pending) >= self.capacity:
            return False
        self.pending[line_addr] = {"smem_addr": smem_addr}
        return True

    def fill(self, line_addr: int) -> Optional[Dict]:
        return self.pending.pop(line_addr, None)


class OnChipMemory:
    """L1D + optional CIAO shared-memory cache region, with VTA feedback."""

    def __init__(self, cfg: OnChipConfig, detector: InterferenceDetector,
                 smem_used_bytes: int = 0):
        self.cfg = cfg
        self.det = detector
        ns = cfg.num_sets
        self.tags = [[-1] * cfg.ways for _ in range(ns)]
        self.owners = [[-1] * cfg.ways for _ in range(ns)]
        self.reused = [[False] * cfg.ways for _ in range(ns)]
        self.lru = [[i for i in range(cfg.ways)] for _ in range(ns)]
        self.smmt = SMMT(cfg.smem_bytes)
        if smem_used_bytes:
            self.smmt.allocate("app", smem_used_bytes)
        base, size = self.smmt.reserve_unused()
        # tags+data co-resident: each 128B block costs 128B + 4B tag share
        self.region_blocks = size // (LINE + 4)
        self.atu = AddressTranslationUnit(cfg, self.region_blocks)
        self.smem_tags: List[int] = [-1] * max(self.region_blocks, 1)
        self.smem_owner: List[int] = [-1] * max(self.region_blocks, 1)
        self.mshr = MSHR(cfg.mshr_entries)
        self.stats = {"l1_hit": 0, "l1_miss": 0, "smem_hit": 0,
                      "smem_miss": 0, "smem_migrate": 0, "bypass": 0,
                      "evictions": 0, "smem_evictions": 0, "vta_hits": 0}

    # ------------------------------------------------------------- L1D path
    def _set_index(self, line_addr: int) -> int:
        ns = self.cfg.num_sets
        idx = line_addr % ns
        if self.cfg.xor_hash:
            idx ^= (line_addr // ns) % ns
        return idx % ns

    def _l1_lookup(self, line_addr: int) -> Tuple[int, Optional[int]]:
        s = self._set_index(line_addr)
        for w in range(self.cfg.ways):
            if self.tags[s][w] == line_addr:
                return s, w
        return s, None

    def _l1_touch(self, s: int, w: int) -> None:
        self.lru[s].remove(w)
        self.lru[s].append(w)

    def _l1_fill(self, wid: int, line_addr: int) -> None:
        s = self._set_index(line_addr)
        victim = self.lru[s][0]
        old_tag, old_owner = self.tags[s][victim], self.owners[s][victim]
        if old_tag >= 0:
            self.stats["evictions"] += 1
            if self.reused[s][victim] or not self.cfg.reuse_filter:
                self.det.on_eviction(old_owner, old_tag, wid)
        self.tags[s][victim] = line_addr
        self.owners[s][victim] = wid
        self.reused[s][victim] = False
        self._l1_touch(s, victim)

    def _l1_invalidate(self, line_addr: int) -> bool:
        s, w = self._l1_lookup(line_addr)
        if w is None:
            return False
        self.tags[s][w] = -1
        self.owners[s][w] = -1
        return True

    # ------------------------------------------------------------ smem path
    def _smem_access(self, wid: int, line_addr: int) -> str:
        if self.region_blocks <= 0:
            return "smem_miss"
        t = self.atu.translate(line_addr * LINE, wid)
        assert t.tag_group != t.group  # parallel tag+data access invariant
        idx = line_addr % self.region_blocks
        if self.smem_tags[idx] == line_addr:
            self.stats["smem_hit"] += 1
            return "smem_hit"
        # miss: victim tracking in the SAME detector/VTA (§III-C)
        old = self.smem_tags[idx]
        if old >= 0:
            self.stats["smem_evictions"] += 1
            self.det.on_eviction(self.smem_owner[idx], old, wid)
        evictor = self.det.on_miss(wid, line_addr)
        if evictor is not None:
            self.stats["vta_hits"] += 1
        # migration: single-copy coherence — if L1D still holds the line,
        # evict it through the response queue into smem (§IV-B).
        migrated = self._l1_invalidate(line_addr)
        self.mshr.reserve(line_addr, smem_addr=idx)
        self.smem_tags[idx] = line_addr
        self.smem_owner[idx] = wid
        self.mshr.fill(line_addr)
        if migrated:
            self.stats["smem_migrate"] += 1
            return "smem_migrate"
        self.stats["smem_miss"] += 1
        return "smem_miss"

    # --------------------------------------------------------------- access
    def access(self, wid: int, addr: int, *, isolated: bool = False,
               bypass: bool = False, count_instruction: bool = True) -> str:
        """One memory request. Returns the event kind (simulator adds
        latency). ``isolated``: CIAO-P redirection to smem. ``bypass``:
        statPCAL-style L1D bypass."""
        line_addr = addr // LINE
        if count_instruction:
            self.det.on_instruction()
        if bypass:
            self.stats["bypass"] += 1
            return "bypass"
        if isolated:
            return self._smem_access(wid, line_addr)
        s, w = self._l1_lookup(line_addr)
        if w is not None:
            self.stats["l1_hit"] += 1
            self.reused[s][w] = True
            self._l1_touch(s, w)
            return "l1_hit"
        self.stats["l1_miss"] += 1
        evictor = self.det.on_miss(wid, line_addr)
        if evictor is not None:
            self.stats["vta_hits"] += 1
        self.mshr.reserve(line_addr)
        self._l1_fill(wid, line_addr)
        self.mshr.fill(line_addr)
        return "l1_miss"

    def hit_rate(self) -> float:
        h = self.stats["l1_hit"] + self.stats["smem_hit"]
        tot = h + self.stats["l1_miss"] + self.stats["smem_miss"] \
            + self.stats["smem_migrate"]
        return h / tot if tot else 0.0
